//! Synthetic sparse matrix generators.
//!
//! The paper evaluates a 26-matrix SuiteSparse suite split into two
//! classes by sparsity structure: **regular** matrices (low coefficient of
//! variation of non-zeros per row: stencils, banded systems, FEM meshes)
//! and **scale-free** matrices (power-law row degrees: social/web graphs).
//! SuiteSparse is not available offline, so these generators produce
//! matrices in the same two classes with controlled statistics; the
//! paper's analysis keys on exactly those statistics (nnz/row mean and
//! CV), which the generators set directly.

use super::coo::CooMatrix;
use super::dtype::SpElem;
use crate::util::rng::Rng;

fn value<T: SpElem>(rng: &mut Rng) -> T {
    // Small integer-friendly values: exact in every type, keeps integer
    // SpMV free of overflow for realistic sizes and float SpMV exactly
    // comparable against the f64 oracle.
    T::from_f64((rng.gen_range(9) as f64) - 4.0)
}

/// Banded (regular) matrix: each row has `band` non-zeros clustered around
/// the diagonal. CV of nnz/row ~ 0 — the paper's "regular" class.
pub fn banded<T: SpElem>(n: usize, band: usize, seed: u64) -> CooMatrix<T> {
    let mut rng = Rng::new(seed);
    let mut triples = Vec::with_capacity(n * band);
    for r in 0..n {
        let half = band / 2;
        let lo = r.saturating_sub(half);
        let hi = (lo + band).min(n);
        let lo = hi.saturating_sub(band);
        for c in lo..hi {
            triples.push((r as u32, c as u32, value::<T>(&mut rng)));
        }
    }
    CooMatrix::from_triples(n, n, triples)
}

/// Uniform random matrix: every row gets exactly `nnz_per_row` non-zeros
/// at uniformly random columns. CV ~ 0 but no locality — separates the
/// "balanced compute" axis from the "vector locality" axis.
pub fn uniform<T: SpElem>(nrows: usize, ncols: usize, nnz_per_row: usize, seed: u64) -> CooMatrix<T> {
    let mut rng = Rng::new(seed);
    let k = nnz_per_row.min(ncols);
    let mut triples = Vec::with_capacity(nrows * k);
    for r in 0..nrows {
        for c in rng.sample_distinct(ncols, k) {
            triples.push((r as u32, c as u32, value::<T>(&mut rng)));
        }
    }
    CooMatrix::from_triples(nrows, ncols, triples)
}

/// Scale-free matrix: row degrees follow a truncated power law
/// (P(k) ∝ k^-alpha over [1, max_degree]), columns drawn with preferential
/// skew. `skew` in [0,1]: 0 = uniform columns, 1 = strongly clustered on
/// low column indices (hub columns). High CV of nnz/row — the paper's
/// "scale-free" class where row-balanced schemes collapse.
pub fn scale_free<T: SpElem>(
    nrows: usize,
    ncols: usize,
    avg_degree: usize,
    skew: f64,
    seed: u64,
) -> CooMatrix<T> {
    let mut rng = Rng::new(seed);
    // Choose alpha ~ 2.1 and rescale degrees to hit the average.
    let alpha = 2.1;
    let max_deg = ncols.min(nrows * avg_degree / 4 + 8);
    let mut degs: Vec<usize> = (0..nrows).map(|_| rng.gen_power_law(alpha, max_deg)).collect();
    let total: usize = degs.iter().sum();
    let want = nrows * avg_degree;
    if total > 0 {
        let scale = want as f64 / total as f64;
        for d in degs.iter_mut() {
            *d = (((*d as f64) * scale).round() as usize).clamp(1, ncols);
        }
    }
    let mut triples = Vec::with_capacity(want);
    for (r, &d) in degs.iter().enumerate() {
        let mut seen = std::collections::HashSet::with_capacity(d * 2);
        let mut emitted = 0;
        let mut attempts = 0;
        while emitted < d && attempts < d * 20 {
            attempts += 1;
            // Preferential attachment approximation: with probability
            // `skew`, square the unit draw so low indices are favored.
            let u = rng.gen_f64();
            let u = if rng.gen_bool(skew) { u * u } else { u };
            let c = ((u * ncols as f64) as usize).min(ncols - 1);
            if seen.insert(c) {
                triples.push((r as u32, c as u32, value::<T>(&mut rng)));
                emitted += 1;
            }
        }
    }
    CooMatrix::from_triples(nrows, ncols, triples)
}

/// Block-structured matrix (FEM-like): dense `bs x bs` blocks dropped on a
/// sparse block pattern. This is the class where BCSR/BCOO shine (fill
/// ratio ~ 1).
pub fn blocked<T: SpElem>(
    n_block_rows: usize,
    n_block_cols: usize,
    bs: usize,
    blocks_per_row: usize,
    seed: u64,
) -> CooMatrix<T> {
    let mut rng = Rng::new(seed);
    let k = blocks_per_row.min(n_block_cols);
    let mut triples = Vec::with_capacity(n_block_rows * k * bs * bs);
    for br in 0..n_block_rows {
        for bc in rng.sample_distinct(n_block_cols, k) {
            for rr in 0..bs {
                for cc in 0..bs {
                    triples.push((
                        (br * bs + rr) as u32,
                        (bc * bs + cc) as u32,
                        value::<T>(&mut rng),
                    ));
                }
            }
        }
    }
    CooMatrix::from_triples(n_block_rows * bs, n_block_cols * bs, triples)
}

/// Diagonal matrix (pathological minimum work per row).
pub fn diagonal<T: SpElem>(n: usize, seed: u64) -> CooMatrix<T> {
    let mut rng = Rng::new(seed);
    let triples = (0..n).map(|i| (i as u32, i as u32, value::<T>(&mut rng))).collect();
    CooMatrix::from_triples(n, n, triples)
}

/// A named matrix in the evaluation suite.
pub struct SuiteEntry {
    pub name: &'static str,
    /// "regular" or "scale-free" — the paper's two classes.
    pub class: &'static str,
    pub gen: fn(u64) -> CooMatrix<f64>,
}

/// The evaluation suite: synthetic stand-ins mirroring the *classes and
/// statistics spread* of the paper's Table 2 (see DESIGN.md §4
/// substitutions). Sizes are scaled down ~10-30x so the full
/// characterization (10 experiments x 25 kernels x suite) runs in minutes
/// on one host; the simulator's ratios are size-stable at these scales.
pub fn suite() -> Vec<SuiteEntry> {
    vec![
        SuiteEntry { name: "band16", class: "regular", gen: |s| banded(16_384, 16, s) },
        SuiteEntry { name: "band64", class: "regular", gen: |s| banded(8_192, 64, s) },
        SuiteEntry { name: "diag", class: "regular", gen: |s| diagonal(32_768, s) },
        SuiteEntry { name: "unif8", class: "regular", gen: |s| uniform(16_384, 16_384, 8, s) },
        SuiteEntry { name: "unif32", class: "regular", gen: |s| uniform(8_192, 8_192, 32, s) },
        SuiteEntry { name: "fem3x3", class: "regular", gen: |s| blocked(2_048, 2_048, 3, 6, s) },
        SuiteEntry { name: "fem8x8", class: "regular", gen: |s| blocked(1_024, 1_024, 8, 4, s) },
        SuiteEntry { name: "sf-low", class: "scale-free", gen: |s| scale_free(16_384, 16_384, 8, 0.3, s) },
        SuiteEntry { name: "sf-mid", class: "scale-free", gen: |s| scale_free(16_384, 16_384, 12, 0.6, s) },
        SuiteEntry { name: "sf-high", class: "scale-free", gen: |s| scale_free(12_288, 12_288, 16, 0.9, s) },
        SuiteEntry { name: "sf-wide", class: "scale-free", gen: |s| scale_free(8_192, 32_768, 10, 0.5, s) },
        SuiteEntry { name: "sf-tall", class: "scale-free", gen: |s| scale_free(32_768, 8_192, 6, 0.5, s) },
    ]
}

/// Smaller suite for unit tests and smoke runs.
pub fn mini_suite() -> Vec<SuiteEntry> {
    vec![
        SuiteEntry { name: "mini-band", class: "regular", gen: |s| banded(512, 8, s) },
        SuiteEntry { name: "mini-unif", class: "regular", gen: |s| uniform(512, 512, 6, s) },
        SuiteEntry { name: "mini-sf", class: "scale-free", gen: |s| scale_free(512, 512, 6, 0.6, s) },
        SuiteEntry { name: "mini-blk", class: "regular", gen: |s| blocked(64, 64, 4, 4, s) },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cv;

    #[test]
    fn banded_has_zero_cv() {
        let m = banded::<f64>(256, 8, 1);
        let counts: Vec<f64> = m.row_counts().iter().map(|&c| c as f64).collect();
        assert!(cv(&counts) < 1e-9, "banded should be perfectly regular");
        assert_eq!(m.nnz(), 256 * 8);
    }

    #[test]
    fn banded_band_stays_in_bounds() {
        let m = banded::<f32>(16, 8, 2);
        for (r, c, _) in m.iter() {
            assert!((r as i64 - c as i64).abs() <= 8);
        }
    }

    #[test]
    fn uniform_exact_row_counts() {
        let m = uniform::<i32>(128, 256, 5, 3);
        assert!(m.row_counts().iter().all(|&c| c == 5));
    }

    #[test]
    fn scale_free_has_high_cv() {
        let m = scale_free::<f64>(2048, 2048, 8, 0.6, 4);
        let counts: Vec<f64> = m.row_counts().iter().map(|&c| c as f64).collect();
        assert!(
            cv(&counts) > 0.5,
            "scale-free CV should be high, got {}",
            cv(&counts)
        );
        // Average degree should be in the right ballpark.
        let avg = m.nnz() as f64 / 2048.0;
        assert!(avg > 3.0 && avg < 16.0, "avg degree {avg}");
    }

    #[test]
    fn blocked_is_fully_dense_in_blocks() {
        let m = blocked::<f64>(8, 8, 4, 3, 5);
        assert_eq!(m.nnz(), 8 * 3 * 16);
        let b = crate::matrix::BcsrMatrix::from_coo(&m, 4, 4);
        assert!((b.fill_ratio() - 1.0).abs() < 1e-12, "no fill for aligned blocks");
    }

    #[test]
    fn generators_are_deterministic() {
        let a = scale_free::<f32>(256, 256, 6, 0.5, 9);
        let b = scale_free::<f32>(256, 256, 6, 0.5, 9);
        assert_eq!(a, b);
        let c = scale_free::<f32>(256, 256, 6, 0.5, 10);
        assert_ne!(a, c);
    }

    #[test]
    fn suite_entries_generate() {
        for e in mini_suite() {
            let m = (e.gen)(7);
            assert!(m.nnz() > 0, "{} empty", e.name);
            assert!(m.nrows() > 0 && m.ncols() > 0);
        }
    }
}
