//! Calibration-table contract tests (hand-rolled; proptest/serde are
//! not in the offline vendor set):
//!
//! * JSON round-trip preserves the table bit-for-bit — every lookup
//!   answers identically before and after a save/load cycle;
//! * the checksum rejects corrupted files instead of silently serving
//!   wrong winners;
//! * nearest-neighbor ties break deterministically (first entry in the
//!   canonical `(matrix, batch)` order wins, every time);
//! * PROPERTY: for random matrices and random synthetic tables over all
//!   25 kernel names with arbitrary stripe counts, a calibrated
//!   selection always yields a spec that `plan()`s on the target system
//!   — calibration can never pick an unplannable configuration;
//! * DIFFERENTIAL (the acceptance criterion): serving the same spec
//!   through a calibrated service and an uncalibrated one produces
//!   bit-identical outputs — calibration only ever changes wall-clock.

use sparsep::coordinator::adaptive::{select_auto, select_calibrated};
use sparsep::coordinator::calibration::sanitize_stripes;
use sparsep::coordinator::{
    BlockPolicy, CalibrationEntry, CalibrationTable, KernelSpec, ServiceBuilder, SpmvExecutor,
};
use sparsep::matrix::{generate, CooMatrix, MatrixStats};
use sparsep::pim::{PimConfig, PimSystem};
use sparsep::util::rng::Rng;

/// A synthetic calibration entry measured "on" matrix `m`.
fn entry_for(m: &CooMatrix<f64>, name: &str, kernel: &str, stripes: usize, batch: usize, block: usize, shards: usize) -> CalibrationEntry {
    CalibrationEntry {
        matrix: name.to_string(),
        class: "synthetic".to_string(),
        features: MatrixStats::of(m).feature_vector(),
        batch,
        kernel: kernel.to_string(),
        stripes,
        block,
        shards,
        grid_cols: 1,
        replicas: 1,
        wall_s: 1e-3,
        heuristic_wall_s: 2e-3,
    }
}

fn random_matrix(rng: &mut Rng) -> CooMatrix<f64> {
    let nrows = 1 + rng.gen_range(300);
    let ncols = 1 + rng.gen_range(300);
    let nnz = rng.gen_range(3 * nrows.min(ncols) + 1);
    let mut triples = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        triples.push((
            rng.gen_range(nrows) as u32,
            rng.gen_range(ncols) as u32,
            (rng.gen_range(9) as f64) - 4.0,
        ));
    }
    CooMatrix::from_triples(nrows, ncols, triples)
}

#[test]
fn round_trip_preserves_every_lookup() {
    let band = generate::banded::<f64>(600, 4, 11);
    let sf = generate::scale_free::<f64>(500, 500, 6, 0.6, 11);
    let unif = generate::uniform::<f64>(400, 500, 5, 11);
    let table = CalibrationTable::new(vec![
        entry_for(&band, "band", "CSR.nnz", 0, 1, 1, 1),
        entry_for(&band, "band", "BCOO.nnz", 0, 16, 8, 2),
        entry_for(&sf, "sf", "DCOO", 4, 8, 4, 2),
        entry_for(&unif, "unif", "COO.nnz", 0, 8, 8, 4),
    ]);

    let text = table.to_json_string();
    let back = CalibrationTable::from_json_str(&text).unwrap();
    assert_eq!(table, back, "round trip must be exact");
    // Serialization is a fixed point: serialize(parse(s)) == s.
    assert_eq!(back.to_json_string(), text);

    // Identical lookups on both sides for a spread of probes.
    for m in [&band, &sf, &unif] {
        let stats = MatrixStats::of(m);
        for batch in [1usize, 4, 8, 16, 64] {
            let a = table.lookup(&stats, batch).expect("non-empty table always answers");
            let b = back.lookup(&stats, batch).unwrap();
            assert_eq!(a, b, "lookup drifted across a save/load cycle");
        }
    }

    // And through actual files.
    let dir = std::env::temp_dir().join("sparsep_calibration_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("round_trip.json");
    table.save(&path).unwrap();
    let loaded = CalibrationTable::load(&path).unwrap();
    assert_eq!(loaded, table);
    std::fs::remove_file(&path).ok();
}

#[test]
fn checksum_rejects_corruption() {
    let band = generate::banded::<f64>(600, 4, 12);
    let table = CalibrationTable::new(vec![entry_for(&band, "band", "CSR.nnz", 0, 8, 4, 2)]);
    let text = table.to_json_string();

    // Flip the kernel name inside the entries payload; the header
    // checksum no longer matches.
    let corrupt = text.replace("CSR.nnz", "COO.nnz");
    assert_ne!(corrupt, text, "corruption must actually change the payload");
    let err = CalibrationTable::from_json_str(&corrupt).unwrap_err();
    assert!(
        err.to_string().contains("checksum"),
        "corruption must be reported as a checksum failure, got: {err}"
    );

    // Truncation and garbage also fail loudly.
    assert!(CalibrationTable::from_json_str(&text[..text.len() / 2]).is_err());
    assert!(CalibrationTable::from_json_str("not json at all").is_err());

    // And a corrupted file on disk is a load error.
    let dir = std::env::temp_dir().join("sparsep_calibration_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("corrupt.json");
    std::fs::write(&path, &corrupt).unwrap();
    assert!(CalibrationTable::load(&path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn nearest_neighbor_ties_break_deterministically() {
    let band = generate::banded::<f64>(600, 4, 11);
    // Two entries with IDENTICAL features and batch but different
    // winners: the probe is equidistant from both. The table sorts by
    // (matrix, batch), so "aaa" must win — on every call, and
    // regardless of insertion order.
    let forward = CalibrationTable::new(vec![
        entry_for(&band, "aaa", "CSR.nnz", 0, 8, 2, 1),
        entry_for(&band, "zzz", "COO.nnz", 0, 8, 4, 2),
    ]);
    let reversed = CalibrationTable::new(vec![
        entry_for(&band, "zzz", "COO.nnz", 0, 8, 4, 2),
        entry_for(&band, "aaa", "CSR.nnz", 0, 8, 2, 1),
    ]);
    let stats = MatrixStats::of(&band);
    for _ in 0..10 {
        assert_eq!(forward.lookup(&stats, 8).unwrap().matrix, "aaa");
        assert_eq!(reversed.lookup(&stats, 8).unwrap().matrix, "aaa");
    }
}

/// PROPERTY: whatever the table holds — any of the 25 kernel names,
/// any stripe count, matched against any random matrix and system size
/// — the calibrated spec plans. `sanitize_stripes` guarantees the 2D
/// divisibility constraint on the *serving* system even when the table
/// was tuned on a differently-sized one.
#[test]
fn prop_calibrated_specs_always_plan() {
    let mut rng = Rng::new(0xCA11B8);
    let names: Vec<String> =
        KernelSpec::all25(8).iter().map(|k| k.name.to_string()).collect();
    for trial in 0..60usize {
        let m = random_matrix(&mut rng);
        let kernel = &names[rng.gen_range(names.len())];
        let stripes = rng.gen_range(17); // 0 (= 1D convention) ..= 16
        let batch = 1 + rng.gen_range(16);
        let entry = entry_for(&m, "probe", kernel, stripes, batch, 1 + rng.gen_range(8), 1);
        let table = CalibrationTable::new(vec![entry]);
        let n_dpus = 1 + rng.gen_range(96); // includes primes and odds
        let cfg = PimConfig { n_dpus, ..Default::default() };
        let tag = format!("trial {trial}: {kernel} stripes={stripes} dpus={n_dpus}");
        let choice = select_calibrated(&m, &cfg, batch, &table)
            .unwrap_or_else(|| panic!("{tag}: single-entry table must answer"));
        if let Some(s) = choice.spec.stripes() {
            assert_eq!(n_dpus % s, 0, "{tag}: stripes {s} must divide the DPU count");
        }
        let exec = SpmvExecutor::new(PimSystem::new(cfg).unwrap());
        exec.plan(&choice.spec, &m)
            .unwrap_or_else(|e| panic!("{tag}: calibrated spec failed to plan: {e}"));
    }
}

#[test]
fn sanitize_stripes_always_divides() {
    for n in 1..=200usize {
        for want in 0..=20usize {
            let s = sanitize_stripes(n, want);
            assert!(s >= 1 && n % s == 0, "sanitize_stripes({n}, {want}) = {s}");
            assert!(s <= want.max(1), "never exceeds the request");
        }
    }
}

/// DIFFERENTIAL: attaching a calibration table never changes results.
/// Same matrix, same spec, same requests — one service calibrated, one
/// not — must produce bit-identical outputs even when the table steers
/// the batch block width away from the adaptive policy's choice.
#[test]
fn calibrated_service_is_bit_identical_to_uncalibrated() {
    let m = generate::scale_free::<f64>(400, 400, 6, 0.6, 13);
    let spec = KernelSpec::csr_nnz();
    let sys = PimSystem::new(PimConfig { n_dpus: 16, ..Default::default() }).unwrap();

    // A table whose nearest entry prescribes an unusual block width so
    // the calibrated path demonstrably diverges from Adaptive.
    let table = CalibrationTable::new(vec![entry_for(&m, "sf", "CSR.nnz", 0, 8, 3, 1)]);

    let plain = ServiceBuilder::new()
        .vector_block(BlockPolicy::Adaptive)
        .build::<f64>(sys.clone())
        .unwrap();
    let calibrated = ServiceBuilder::new()
        .vector_block(BlockPolicy::Adaptive)
        .calibration(std::sync::Arc::new(table.clone()))
        .build::<f64>(sys.clone())
        .unwrap();

    let hp = plain.load(&m, &spec).unwrap();
    let hc = calibrated.load(&m, &spec).unwrap();
    let xs: Vec<Vec<f64>> = (0..8usize)
        .map(|b| (0..m.ncols()).map(|i| ((i + 5 * b) % 9) as f64 - 4.0).collect())
        .collect();

    // The calibrated service really does resolve a different block...
    assert_eq!(calibrated.resolved_block(&hc, 8).unwrap(), 3);

    // ...and still answers bit-identically, for every request kind.
    let want = m.spmv(&xs[0]);
    assert_eq!(plain.spmv(&hp, &xs[0]).unwrap().y, want);
    assert_eq!(calibrated.spmv(&hc, &xs[0]).unwrap().y, want);
    let bp = plain.spmv_batch(&hp, &xs).unwrap();
    let bc = calibrated.spmv_batch(&hc, &xs).unwrap();
    for ((rp, rc), x) in bp.runs.iter().zip(&bc.runs).zip(&xs) {
        assert_eq!(rp.y, rc.y, "calibration changed a batch result");
        assert_eq!(rc.y, m.spmv(x), "host oracle");
    }
    let ip = plain.iterate(&hp, &xs[0], 4).unwrap();
    let ic = calibrated.iterate(&hc, &xs[0], 4).unwrap();
    assert_eq!(ip.last.y, ic.last.y, "calibration changed an iterate result");

    // `select_auto` with this table picks the calibrated kernel; the
    // reason string says so (observability contract for the CLI).
    let cfg = PimConfig { n_dpus: 16, ..Default::default() };
    let c = select_auto(&m, &cfg, 8, Some(&table));
    assert_eq!(c.spec.name, "CSR.nnz");
    assert!(c.reason.starts_with("calibrated"), "reason = {}", c.reason);
}
