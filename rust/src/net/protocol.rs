//! The SparseP wire protocol: length-prefixed binary frames.
//!
//! Every frame is `header ++ payload`:
//!
//! ```text
//! +------+------+------+------+---------+--------+----------------+
//! | 'S'  | 'P'  | 'R'  | 'P'  | version | type   | payload length |
//! +------+------+------+------+---------+--------+----------------+
//!   magic (4 bytes)              u8        u8       u32 LE
//! ```
//!
//! followed by `payload length` bytes of type-specific payload. All
//! integers are little-endian; all floats travel as `f64::to_bits`
//! (bit-exact — NaN payloads and signed zeros survive, which is what
//! lets `tests/net_equivalence.rs` demand *bit-identical* responses
//! against the in-process oracle).
//!
//! Client → server frames: [`Frame::LoadMatrix`], the three
//! `Submit*` shapes (each tagged with a tenant name and an optional
//! deadline), and [`Frame::Poll`]. Server → client frames:
//! [`Frame::Loaded`], [`Frame::Submitted`], streamed
//! [`Frame::Completion`]s, the [`Frame::Overloaded`] backpressure
//! frame, [`Frame::NotReady`], and typed [`Frame::Error`]s.
//!
//! Decoding is fully bounds-checked and never panics: any truncated,
//! oversized, or corrupt input yields a typed [`crate::util::Error`]
//! (or `Ok(None)` from [`decode_stream`] when the frame is merely
//! incomplete). The fuzz tests at the bottom of this file drive random
//! and truncated byte streams through the decoder to lock that in.

use crate::coordinator::{BatchResult, Breakdown, IterationsResult, RunResult, RunStats};
use crate::pim::Energy;
use crate::util::{Error, Result};

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"SPRP";
/// Protocol version carried in every header.
pub const VERSION: u8 = 1;
/// Fixed header size: magic + version + frame type + payload length.
pub const HEADER_LEN: usize = 10;
/// Hard cap on a frame's payload (64 MiB): anything larger is corrupt
/// (or hostile) and is rejected before any allocation happens.
pub const MAX_PAYLOAD: usize = 64 << 20;
/// Cap on an encoded string (tenant / kernel names, error messages).
pub const MAX_STR: usize = 1 << 20;

// Frame type tags. Client -> server:
const T_LOAD_MATRIX: u8 = 1;
const T_SUBMIT_SPMV: u8 = 2;
const T_SUBMIT_BATCH: u8 = 3;
const T_SUBMIT_ITERATE: u8 = 4;
const T_POLL: u8 = 5;
// Server -> client:
const T_LOADED: u8 = 16;
const T_SUBMITTED: u8 = 17;
const T_COMPLETION: u8 = 18;
const T_OVERLOADED: u8 = 19;
const T_NOT_READY: u8 = 20;
const T_ERROR: u8 = 21;

/// Machine-checkable error classification carried by [`Frame::Error`]
/// (the wire twin of [`crate::util::ErrorKind`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireErrorCode {
    /// Anything without a dedicated code.
    Other,
    /// A bounded wait expired (`ErrorKind::ShardTimeout`); the frame's
    /// `shard` field names the wedged shard when known.
    ShardTimeout,
}

/// A completed request's payload, mirroring the request shape.
#[derive(Clone, Debug)]
pub enum Completion {
    Spmv(RunResult<f64>),
    Batch(BatchResult<f64>),
    Iterate(IterationsResult<f64>),
}

/// One protocol frame. See the module docs for the frame catalogue and
/// the byte-level layout of each payload.
#[derive(Clone, Debug)]
pub enum Frame {
    /// Register a matrix (COO triples) under `tenant` with the named
    /// kernel; answered by [`Frame::Loaded`] or [`Frame::Error`].
    LoadMatrix {
        tenant: String,
        kernel: String,
        stripes: u32,
        nrows: u64,
        ncols: u64,
        triples: Vec<(u32, u32, f64)>,
    },
    /// Submit one SpMV. `deadline_ms == 0` means no deadline.
    SubmitSpmv { tenant: String, handle: u64, deadline_ms: u32, x: Vec<f64> },
    /// Submit one batched (multi-vector) request.
    SubmitBatch { tenant: String, handle: u64, deadline_ms: u32, xs: Vec<Vec<f64>> },
    /// Submit one iterated request (`iters` self-applications).
    SubmitIterate { tenant: String, handle: u64, deadline_ms: u32, iters: u32, x: Vec<f64> },
    /// Ask whether `ticket` is still in flight; answered by
    /// [`Frame::NotReady`] (still queued/executing — its completion
    /// will stream when ready) or [`Frame::Error`] (unknown ticket).
    Poll { ticket: u64 },
    /// A [`Frame::LoadMatrix`] succeeded.
    Loaded { handle: u64, nrows: u64, ncols: u64 },
    /// A `Submit*` was accepted; its completion streams later under
    /// the same ticket.
    Submitted { ticket: u64 },
    /// A submitted request finished.
    Completion { ticket: u64, body: Box<Completion> },
    /// Backpressure: the request was shed. `ticket == 0` when the
    /// connection's in-flight cap rejected it before submission (the
    /// frame answers the `Submit*` in request order); a non-zero
    /// ticket is the facade's own typed admission shed
    /// ([`crate::coordinator::Response::Overloaded`]).
    Overloaded { ticket: u64 },
    /// Answer to [`Frame::Poll`]: the ticket is still in flight.
    NotReady { ticket: u64 },
    /// A request failed. `ticket == 0` marks a request rejected before
    /// submission (answers the `Submit*`/`LoadMatrix` in request
    /// order); non-zero names the submitted ticket that failed.
    Error { ticket: u64, code: WireErrorCode, shard: Option<u32>, message: String },
}

impl Frame {
    /// Encode this frame (header + payload) to fresh bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + 64);
        self.encode_into(&mut out);
        out
    }

    /// Append this frame (header + payload) to `out` — the server's
    /// write path reuses pooled buffers through this entry point.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let start = out.len();
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.push(self.type_tag());
        out.extend_from_slice(&[0u8; 4]); // payload length, patched below
        self.encode_payload(out);
        let plen = (out.len() - start - HEADER_LEN) as u32;
        out[start + 6..start + HEADER_LEN].copy_from_slice(&plen.to_le_bytes());
    }

    fn type_tag(&self) -> u8 {
        match self {
            Frame::LoadMatrix { .. } => T_LOAD_MATRIX,
            Frame::SubmitSpmv { .. } => T_SUBMIT_SPMV,
            Frame::SubmitBatch { .. } => T_SUBMIT_BATCH,
            Frame::SubmitIterate { .. } => T_SUBMIT_ITERATE,
            Frame::Poll { .. } => T_POLL,
            Frame::Loaded { .. } => T_LOADED,
            Frame::Submitted { .. } => T_SUBMITTED,
            Frame::Completion { .. } => T_COMPLETION,
            Frame::Overloaded { .. } => T_OVERLOADED,
            Frame::NotReady { .. } => T_NOT_READY,
            Frame::Error { .. } => T_ERROR,
        }
    }

    fn encode_payload(&self, out: &mut Vec<u8>) {
        match self {
            Frame::LoadMatrix { tenant, kernel, stripes, nrows, ncols, triples } => {
                put_str(out, tenant);
                put_str(out, kernel);
                out.extend_from_slice(&stripes.to_le_bytes());
                out.extend_from_slice(&nrows.to_le_bytes());
                out.extend_from_slice(&ncols.to_le_bytes());
                out.extend_from_slice(&(triples.len() as u64).to_le_bytes());
                for &(r, c, v) in triples {
                    out.extend_from_slice(&r.to_le_bytes());
                    out.extend_from_slice(&c.to_le_bytes());
                    out.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
            Frame::SubmitSpmv { tenant, handle, deadline_ms, x } => {
                put_str(out, tenant);
                out.extend_from_slice(&handle.to_le_bytes());
                out.extend_from_slice(&deadline_ms.to_le_bytes());
                put_f64s(out, x);
            }
            Frame::SubmitBatch { tenant, handle, deadline_ms, xs } => {
                put_str(out, tenant);
                out.extend_from_slice(&handle.to_le_bytes());
                out.extend_from_slice(&deadline_ms.to_le_bytes());
                out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
                for x in xs {
                    put_f64s(out, x);
                }
            }
            Frame::SubmitIterate { tenant, handle, deadline_ms, iters, x } => {
                put_str(out, tenant);
                out.extend_from_slice(&handle.to_le_bytes());
                out.extend_from_slice(&deadline_ms.to_le_bytes());
                out.extend_from_slice(&iters.to_le_bytes());
                put_f64s(out, x);
            }
            Frame::Poll { ticket }
            | Frame::Submitted { ticket }
            | Frame::Overloaded { ticket }
            | Frame::NotReady { ticket } => {
                out.extend_from_slice(&ticket.to_le_bytes());
            }
            Frame::Loaded { handle, nrows, ncols } => {
                out.extend_from_slice(&handle.to_le_bytes());
                out.extend_from_slice(&nrows.to_le_bytes());
                out.extend_from_slice(&ncols.to_le_bytes());
            }
            Frame::Completion { ticket, body } => {
                out.extend_from_slice(&ticket.to_le_bytes());
                match &**body {
                    Completion::Spmv(r) => {
                        out.push(0);
                        put_run(out, r);
                    }
                    Completion::Batch(b) => {
                        out.push(1);
                        out.extend_from_slice(&(b.runs.len() as u32).to_le_bytes());
                        for r in &b.runs {
                            put_run(out, r);
                        }
                    }
                    Completion::Iterate(it) => {
                        out.push(2);
                        put_run(out, &it.last);
                        put_breakdown(out, &it.total);
                        put_energy(out, &it.energy);
                        out.extend_from_slice(&(it.iters as u64).to_le_bytes());
                    }
                }
            }
            Frame::Error { ticket, code, shard, message } => {
                out.extend_from_slice(&ticket.to_le_bytes());
                out.push(match code {
                    WireErrorCode::Other => 0,
                    WireErrorCode::ShardTimeout => 1,
                });
                match shard {
                    Some(s) => {
                        out.push(1);
                        out.extend_from_slice(&s.to_le_bytes());
                    }
                    None => {
                        out.push(0);
                        out.extend_from_slice(&0u32.to_le_bytes());
                    }
                }
                put_str(out, message);
            }
        }
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    // Length caps are enforced at decode; encoding truncates nothing —
    // callers never build names/messages anywhere near MAX_STR.
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for v in xs {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

fn put_breakdown(out: &mut Vec<u8>, b: &Breakdown) {
    for v in [b.load_s, b.kernel_s, b.retrieve_s, b.merge_s] {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

fn put_energy(out: &mut Vec<u8>, e: &Energy) {
    for v in [e.dpu_j, e.dpu_idle_j, e.bus_j, e.host_j] {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

fn put_run(out: &mut Vec<u8>, r: &RunResult<f64>) {
    put_f64s(out, &r.y);
    put_breakdown(out, &r.breakdown);
    let s = &r.stats;
    out.extend_from_slice(&s.dpu_imbalance.to_bits().to_le_bytes());
    out.extend_from_slice(&s.kernel_cycles.to_le_bytes());
    out.extend_from_slice(&s.bus_bytes_moved.to_le_bytes());
    out.extend_from_slice(&s.bus_bytes_payload.to_le_bytes());
    out.extend_from_slice(&s.matrix_load_s.to_bits().to_le_bytes());
    out.extend_from_slice(&(s.n_dpus as u64).to_le_bytes());
    out.extend_from_slice(&(s.nnz as u64).to_le_bytes());
    put_energy(out, &r.energy);
}

/// Try to decode one frame from the front of `buf`.
///
/// * `Ok(Some((frame, consumed)))` — a complete frame parsed; the
///   caller drains `consumed` bytes and goes again.
/// * `Ok(None)` — the buffer holds a valid prefix of a frame; read
///   more bytes and retry.
/// * `Err(_)` — the stream is corrupt (bad magic/version, oversized
///   length, truncated or trailing payload bytes, invalid counts);
///   the connection should be dropped.
///
/// Never panics on any input — locked by the fuzz tests below.
pub fn decode_stream(buf: &[u8]) -> Result<Option<(Frame, usize)>> {
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    if buf[..4] != MAGIC {
        return Err(Error::msg("bad frame magic"));
    }
    if buf[4] != VERSION {
        return Err(Error::msg(format!(
            "unsupported protocol version {} (this build speaks {VERSION})",
            buf[4]
        )));
    }
    let ftype = buf[5];
    let plen = u32::from_le_bytes([buf[6], buf[7], buf[8], buf[9]]) as usize;
    if plen > MAX_PAYLOAD {
        return Err(Error::msg(format!("frame payload {plen} exceeds cap {MAX_PAYLOAD}")));
    }
    if buf.len() < HEADER_LEN + plen {
        return Ok(None);
    }
    let frame = decode_payload(ftype, &buf[HEADER_LEN..HEADER_LEN + plen])?;
    Ok(Some((frame, HEADER_LEN + plen)))
}

fn decode_payload(ftype: u8, payload: &[u8]) -> Result<Frame> {
    let mut c = Cur { b: payload, i: 0 };
    let frame = match ftype {
        T_LOAD_MATRIX => {
            let tenant = c.str()?;
            let kernel = c.str()?;
            let stripes = c.u32()?;
            let nrows = c.u64()?;
            let ncols = c.u64()?;
            let nnz = c.u64()? as usize;
            // 16 bytes per triple: reject a count the payload cannot
            // possibly hold before allocating anything.
            if nnz > c.remaining() / 16 {
                return Err(Error::msg(format!(
                    "triple count {nnz} exceeds payload ({} bytes left)",
                    c.remaining()
                )));
            }
            let mut triples = Vec::with_capacity(nnz);
            for _ in 0..nnz {
                let r = c.u32()?;
                let col = c.u32()?;
                let v = c.f64()?;
                triples.push((r, col, v));
            }
            Frame::LoadMatrix { tenant, kernel, stripes, nrows, ncols, triples }
        }
        T_SUBMIT_SPMV => Frame::SubmitSpmv {
            tenant: c.str()?,
            handle: c.u64()?,
            deadline_ms: c.u32()?,
            x: c.f64s()?,
        },
        T_SUBMIT_BATCH => {
            let tenant = c.str()?;
            let handle = c.u64()?;
            let deadline_ms = c.u32()?;
            let nvec = c.u32()? as usize;
            // Each vector costs at least its 4-byte count.
            if nvec > c.remaining() / 4 {
                return Err(Error::msg(format!("batch vector count {nvec} exceeds payload")));
            }
            let mut xs = Vec::with_capacity(nvec);
            for _ in 0..nvec {
                xs.push(c.f64s()?);
            }
            Frame::SubmitBatch { tenant, handle, deadline_ms, xs }
        }
        T_SUBMIT_ITERATE => Frame::SubmitIterate {
            tenant: c.str()?,
            handle: c.u64()?,
            deadline_ms: c.u32()?,
            iters: c.u32()?,
            x: c.f64s()?,
        },
        T_POLL => Frame::Poll { ticket: c.u64()? },
        T_LOADED => Frame::Loaded { handle: c.u64()?, nrows: c.u64()?, ncols: c.u64()? },
        T_SUBMITTED => Frame::Submitted { ticket: c.u64()? },
        T_COMPLETION => {
            let ticket = c.u64()?;
            let body = match c.u8()? {
                0 => Completion::Spmv(get_run(&mut c)?),
                1 => {
                    let nruns = c.u32()? as usize;
                    if nruns > c.remaining() / 4 {
                        return Err(Error::msg(format!("batch run count {nruns} exceeds payload")));
                    }
                    let mut runs = Vec::with_capacity(nruns);
                    for _ in 0..nruns {
                        runs.push(get_run(&mut c)?);
                    }
                    Completion::Batch(BatchResult { runs })
                }
                2 => {
                    let last = get_run(&mut c)?;
                    let total = get_breakdown(&mut c)?;
                    let energy = get_energy(&mut c)?;
                    let iters = c.u64()? as usize;
                    Completion::Iterate(IterationsResult { last, total, energy, iters })
                }
                k => return Err(Error::msg(format!("unknown completion kind {k}"))),
            };
            Frame::Completion { ticket, body: Box::new(body) }
        }
        T_OVERLOADED => Frame::Overloaded { ticket: c.u64()? },
        T_NOT_READY => Frame::NotReady { ticket: c.u64()? },
        T_ERROR => {
            let ticket = c.u64()?;
            let code = match c.u8()? {
                0 => WireErrorCode::Other,
                1 => WireErrorCode::ShardTimeout,
                k => return Err(Error::msg(format!("unknown error code {k}"))),
            };
            let has_shard = c.u8()?;
            let shard_raw = c.u32()?;
            let shard = match has_shard {
                0 => None,
                1 => Some(shard_raw),
                k => return Err(Error::msg(format!("bad shard presence flag {k}"))),
            };
            Frame::Error { ticket, code, shard, message: c.str()? }
        }
        t => return Err(Error::msg(format!("unknown frame type {t}"))),
    };
    c.done()?;
    Ok(frame)
}

/// Bounds-checked little-endian reader over one frame's payload.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::msg(format!(
                "truncated frame payload: wanted {n} bytes, {} left",
                self.remaining()
            )));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        if len > MAX_STR {
            return Err(Error::msg(format!("string length {len} exceeds cap {MAX_STR}")));
        }
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| Error::msg("invalid utf-8 in string"))
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        if n > self.remaining() / 8 {
            return Err(Error::msg(format!(
                "vector count {n} exceeds payload ({} bytes left)",
                self.remaining()
            )));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    /// The payload must be fully consumed — trailing bytes mean the
    /// sender and receiver disagree about the layout.
    fn done(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(Error::msg(format!("{} trailing bytes after frame payload", self.remaining())));
        }
        Ok(())
    }
}

fn get_breakdown(c: &mut Cur<'_>) -> Result<Breakdown> {
    Ok(Breakdown {
        load_s: c.f64()?,
        kernel_s: c.f64()?,
        retrieve_s: c.f64()?,
        merge_s: c.f64()?,
    })
}

fn get_energy(c: &mut Cur<'_>) -> Result<Energy> {
    Ok(Energy { dpu_j: c.f64()?, dpu_idle_j: c.f64()?, bus_j: c.f64()?, host_j: c.f64()? })
}

fn get_run(c: &mut Cur<'_>) -> Result<RunResult<f64>> {
    let y = c.f64s()?;
    let breakdown = get_breakdown(c)?;
    let stats = RunStats {
        dpu_imbalance: c.f64()?,
        kernel_cycles: c.u64()?,
        bus_bytes_moved: c.u64()?,
        bus_bytes_payload: c.u64()?,
        matrix_load_s: c.f64()?,
        n_dpus: c.u64()? as usize,
        nnz: c.u64()? as usize,
    };
    let energy = get_energy(c)?;
    Ok(RunResult { y, breakdown, stats, energy })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_run(seed: f64) -> RunResult<f64> {
        RunResult {
            y: vec![seed, -seed, 0.5 * seed, f64::NAN, -0.0],
            breakdown: Breakdown {
                load_s: 1e-3 + seed,
                kernel_s: 2e-3,
                retrieve_s: 3e-3,
                merge_s: 0.0,
            },
            stats: RunStats {
                dpu_imbalance: 1.25,
                kernel_cycles: 123_456,
                bus_bytes_moved: 789,
                bus_bytes_payload: 700,
                matrix_load_s: 0.25,
                n_dpus: 64,
                nnz: 4096,
            },
            energy: Energy { dpu_j: 0.5, dpu_idle_j: 0.125, bus_j: 0.25, host_j: 1.5 },
        }
    }

    /// Every frame variant survives encode -> decode -> re-encode
    /// bit-exactly (including NaN / -0.0 float payloads).
    #[test]
    fn all_frames_roundtrip_bit_exact() {
        let frames = vec![
            Frame::LoadMatrix {
                tenant: "alice".into(),
                kernel: "coo.nnz".into(),
                stripes: 8,
                nrows: 100,
                ncols: 90,
                triples: vec![(0, 1, 2.5), (99, 89, -1.0), (5, 5, f64::INFINITY)],
            },
            Frame::SubmitSpmv {
                tenant: "bob".into(),
                handle: 7,
                deadline_ms: 0,
                x: vec![1.0, -2.0, f64::NAN],
            },
            Frame::SubmitBatch {
                tenant: "alice".into(),
                handle: 1,
                deadline_ms: 250,
                xs: vec![vec![1.0, 2.0], vec![], vec![-0.0]],
            },
            Frame::SubmitIterate {
                tenant: "t".into(),
                handle: u64::MAX,
                deadline_ms: 1,
                iters: 12,
                x: vec![0.25; 17],
            },
            Frame::Poll { ticket: 42 },
            Frame::Loaded { handle: 3, nrows: 10, ncols: 11 },
            Frame::Submitted { ticket: 9 },
            Frame::Completion { ticket: 5, body: Box::new(Completion::Spmv(sample_run(1.0))) },
            Frame::Completion {
                ticket: 6,
                body: Box::new(Completion::Batch(BatchResult {
                    runs: vec![sample_run(2.0), sample_run(3.0)],
                })),
            },
            Frame::Completion {
                ticket: 7,
                body: Box::new(Completion::Iterate(IterationsResult {
                    last: sample_run(4.0),
                    total: Breakdown { load_s: 9.0, kernel_s: 8.0, retrieve_s: 7.0, merge_s: 6.0 },
                    energy: Energy { dpu_j: 1.0, dpu_idle_j: 2.0, bus_j: 3.0, host_j: 4.0 },
                    iters: 5,
                })),
            },
            Frame::Overloaded { ticket: 0 },
            Frame::NotReady { ticket: 77 },
            Frame::Error {
                ticket: 12,
                code: WireErrorCode::ShardTimeout,
                shard: Some(3),
                message: "shard 3 stalled".into(),
            },
            Frame::Error {
                ticket: 0,
                code: WireErrorCode::Other,
                shard: None,
                message: "tenant \"zed\" not registered".into(),
            },
        ];
        for f in frames {
            let bytes = f.encode();
            let (back, consumed) = decode_stream(&bytes)
                .expect("valid frame must decode")
                .expect("complete frame must not report incomplete");
            assert_eq!(consumed, bytes.len(), "whole frame consumed");
            assert_eq!(back.encode(), bytes, "re-encode must be bit-identical: {f:?}");
        }
    }

    /// Frames arriving back to back in one buffer parse one at a time.
    #[test]
    fn streams_decode_frame_by_frame() {
        let a = Frame::Poll { ticket: 1 };
        let b = Frame::Submitted { ticket: 2 };
        let mut buf = a.encode();
        buf.extend_from_slice(&b.encode());
        let (fa, na) = decode_stream(&buf).unwrap().unwrap();
        assert!(matches!(fa, Frame::Poll { ticket: 1 }));
        let (fb, nb) = decode_stream(&buf[na..]).unwrap().unwrap();
        assert!(matches!(fb, Frame::Submitted { ticket: 2 }));
        assert_eq!(na + nb, buf.len());
    }

    /// Every proper prefix of a valid frame is "incomplete", never an
    /// error and never a bogus success.
    #[test]
    fn truncated_frames_report_incomplete() {
        let frames = vec![
            Frame::SubmitSpmv { tenant: "a".into(), handle: 1, deadline_ms: 0, x: vec![1.0; 9] },
            Frame::Completion { ticket: 3, body: Box::new(Completion::Spmv(sample_run(1.0))) },
            Frame::Error { ticket: 1, code: WireErrorCode::Other, shard: None, message: "m".into() },
        ];
        for f in frames {
            let bytes = f.encode();
            for cut in 0..bytes.len() {
                match decode_stream(&bytes[..cut]) {
                    Ok(None) => {}
                    Ok(Some(_)) => panic!("prefix of length {cut} decoded as a whole frame"),
                    Err(e) => panic!("prefix of length {cut} errored: {e}"),
                }
            }
        }
    }

    #[test]
    fn corrupt_headers_are_typed_errors() {
        // Bad magic.
        let mut bytes = Frame::Poll { ticket: 1 }.encode();
        bytes[0] = b'X';
        assert!(decode_stream(&bytes).is_err());
        // Bad version.
        let mut bytes = Frame::Poll { ticket: 1 }.encode();
        bytes[4] = 99;
        assert!(decode_stream(&bytes).is_err());
        // Unknown frame type.
        let mut bytes = Frame::Poll { ticket: 1 }.encode();
        bytes[5] = 200;
        assert!(decode_stream(&bytes).is_err());
        // Oversized declared payload is rejected up front.
        let mut bytes = Frame::Poll { ticket: 1 }.encode();
        bytes[6..10].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        assert!(decode_stream(&bytes).is_err());
        // Trailing payload bytes (sender/receiver layout mismatch).
        let mut bytes = Frame::Poll { ticket: 1 }.encode();
        bytes.push(0);
        let plen = (bytes.len() - HEADER_LEN) as u32;
        bytes[6..10].copy_from_slice(&plen.to_le_bytes());
        assert!(decode_stream(&bytes).is_err());
    }

    /// A hostile length prefix (huge element count in a tiny payload)
    /// must be rejected before any allocation, not trusted.
    #[test]
    fn hostile_counts_are_rejected() {
        // SubmitSpmv with a claimed 1M-element vector but no bytes.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(VERSION);
        bytes.push(super::T_SUBMIT_SPMV);
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes()); // tenant len 1
        payload.push(b'a');
        payload.extend_from_slice(&1u64.to_le_bytes()); // handle
        payload.extend_from_slice(&0u32.to_le_bytes()); // deadline
        payload.extend_from_slice(&1_000_000u32.to_le_bytes()); // claimed count
        bytes.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        bytes.extend_from_slice(&payload);
        assert!(decode_stream(&bytes).is_err());
    }

    /// Fuzz: random byte soup never panics the decoder — every outcome
    /// is `Ok(None)`, a parsed frame, or a typed error.
    #[test]
    fn fuzz_random_bytes_never_panic() {
        let mut rng = Rng::new(0x5EED_F00D);
        for _ in 0..2000 {
            let len = rng.gen_range(200);
            let mut buf = Vec::with_capacity(len);
            for _ in 0..len {
                buf.push(rng.next_u64() as u8);
            }
            let _ = decode_stream(&buf);
        }
        // Valid header, random payload bytes: exercises every payload
        // decoder against garbage without tripping the magic check.
        for ftype in [1u8, 2, 3, 4, 5, 16, 17, 18, 19, 20, 21] {
            for _ in 0..500 {
                let plen = rng.gen_range(120);
                let mut buf = Vec::with_capacity(HEADER_LEN + plen);
                buf.extend_from_slice(&MAGIC);
                buf.push(VERSION);
                buf.push(ftype);
                buf.extend_from_slice(&(plen as u32).to_le_bytes());
                for _ in 0..plen {
                    buf.push(rng.next_u64() as u8);
                }
                let _ = decode_stream(&buf);
            }
        }
    }

    /// Fuzz: flip bytes inside valid frames; decode must never panic
    /// and a surviving parse must re-encode without panicking.
    #[test]
    fn fuzz_bit_flips_never_panic() {
        let mut rng = Rng::new(0xBADC_0DE);
        let base = Frame::SubmitBatch {
            tenant: "fuzz".into(),
            handle: 3,
            deadline_ms: 9,
            xs: vec![vec![1.0, 2.0, 3.0], vec![4.0]],
        }
        .encode();
        for _ in 0..2000 {
            let mut bytes = base.clone();
            let flips = 1 + rng.gen_range(4);
            for _ in 0..flips {
                let i = rng.gen_range(bytes.len());
                bytes[i] ^= rng.next_u64() as u8;
            }
            if let Ok(Some((frame, _))) = decode_stream(&bytes) {
                let _ = frame.encode();
            }
        }
    }
}
