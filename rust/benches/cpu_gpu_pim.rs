//! Bench E9: CPU vs GPU vs PIM (paper Fig. 16 + Table 3).
//!
//! Three comparison points, as in the paper:
//! * **PIM** — the simulated UPMEM system running the best 1D kernel;
//! * **CPU** — a *measured* multithreaded host SpMV plus the Xeon
//!   roofline model for fraction-of-peak;
//! * **GPU** — the V100 roofline model, with the *measured* AOT
//!   JAX/Pallas ELL kernel executed through XLA/PJRT standing in for the
//!   accelerator-library code path (cuSPARSE in the paper).

mod common;

use sparsep::bench_harness::{figures, measure};
use sparsep::matrix::{generate, CsrMatrix};
use sparsep::runtime::{ell_host, ArtifactRunner};

fn main() {
    common::banner("cpu_gpu_pim", "Fig. 16 + Table 3 CPU/GPU/PIM comparison");
    common::timed("e9_cpu_gpu_pim", || {
        figures::e9_cpu_gpu_pim(common::scale());
    });

    // Measured accelerator path: AOT Pallas ELL kernel through PJRT.
    match ArtifactRunner::load_default() {
        Err(e) => println!("\n[xla path skipped: {e}] (run `make artifacts`)"),
        Ok(runner) => {
            println!("\n-- measured XLA/PJRT accelerator path (AOT Pallas ELL kernel) --");
            let m = generate::uniform::<f64>(4096, 4096, 16, 5).cast::<f32>();
            let csr = CsrMatrix::from_coo(&m);
            let staged = ell_host::stage(&runner, &csr).expect("stage");
            let x: Vec<f32> = (0..m.ncols()).map(|i| ((i % 7) as f32) - 3.0).collect();
            let want = csr.spmv(&x);
            let mut y = Vec::new();
            let s = measure(2, 5, || {
                y = staged.spmv(&runner, &x).expect("spmv");
            });
            let ok = y
                .iter()
                .zip(&want)
                .all(|(a, b)| (a - b).abs() <= 1e-3 * b.abs().max(1.0));
            println!(
                "artifact {}  pad {:.2}x  best {:.3} ms  {:.3} GFLOP/s  verified: {}",
                staged.artifact,
                staged.pad_ratio,
                s.min * 1e3,
                2.0 * m.nnz() as f64 / s.min / 1e9,
                if ok { "OK" } else { "MISMATCH" }
            );
            assert!(ok, "XLA path verification failed");
        }
    }
}
