"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes (the system's core correctness signal for
the compute path), plus deterministic edge cases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bell_spmv import bell_spmv
from compile.kernels.ell_spmv import ell_spmv
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def make_ell(rng, r, k, n, dtype):
    vals = rng.uniform(-2, 2, size=(r, k)).astype(dtype)
    cols = rng.integers(0, n, size=(r, k)).astype(np.int32)
    # Randomly pad some slots (value 0, col 0) like the host conversion.
    pad = rng.uniform(size=(r, k)) < 0.3
    vals[pad] = 0
    cols[pad] = 0
    x = rng.uniform(-1, 1, size=(n,)).astype(dtype)
    return vals, cols, x


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 6),
    tile_r=st.sampled_from([8, 32, 128]),
    k=st.integers(1, 24),
    n=st.sampled_from([16, 257, 1024]),
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(0, 2**32 - 1),
)
def test_ell_matches_ref_hypothesis(tiles, tile_r, k, n, dtype, seed):
    rng = np.random.default_rng(seed)
    r = tiles * tile_r
    vals, cols, x = make_ell(rng, r, k, n, dtype)
    got = ell_spmv(vals, cols, x, tile_r=tile_r)
    want = ref.ell_spmv_ref(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
    rtol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    nbr=st.integers(1, 12),
    bmax=st.integers(1, 8),
    br=st.sampled_from([2, 4, 8]),
    bc=st.sampled_from([2, 4, 8]),
    nbc=st.integers(1, 16),
    seed=st.integers(0, 2**32 - 1),
)
def test_bell_matches_ref_hypothesis(nbr, bmax, br, bc, nbc, seed):
    rng = np.random.default_rng(seed)
    n = nbc * bc
    vals = rng.uniform(-2, 2, size=(nbr, bmax, br, bc)).astype(np.float32)
    cols = rng.integers(0, nbc, size=(nbr, bmax)).astype(np.int32)
    pad = rng.uniform(size=(nbr, bmax)) < 0.25
    vals[pad] = 0
    cols[pad] = 0
    x = rng.uniform(-1, 1, size=(n,)).astype(np.float32)
    got = bell_spmv(vals, cols, x)
    want = ref.bell_spmv_ref(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_ell_zero_matrix():
    vals = np.zeros((64, 4), np.float32)
    cols = np.zeros((64, 4), np.int32)
    x = np.ones(32, np.float32)
    assert np.all(np.asarray(ell_spmv(vals, cols, x, tile_r=32)) == 0)


def test_ell_identity():
    n = 128
    vals = np.ones((n, 1), np.float32)
    cols = np.arange(n, dtype=np.int32)[:, None]
    x = np.random.default_rng(0).uniform(size=n).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ell_spmv(vals, cols, x, tile_r=64)), x, rtol=1e-6)


def test_ell_rejects_ragged_tiles():
    vals = np.zeros((100, 4), np.float32)
    cols = np.zeros((100, 4), np.int32)
    x = np.ones(16, np.float32)
    with pytest.raises(ValueError, match="multiple"):
        ell_spmv(vals, cols, x, tile_r=64)


def test_ell_padding_is_neutral():
    # Padding points at column 0 with value 0: x[0] != 0 must not leak.
    vals = np.array([[5.0, 0.0]], np.float32).repeat(8, axis=0)
    cols = np.array([[1, 0]], np.int32).repeat(8, axis=0)
    x = np.array([100.0, 2.0], np.float32)
    got = np.asarray(ell_spmv(vals, cols, x, tile_r=8))
    np.testing.assert_allclose(got, np.full(8, 10.0), rtol=1e-6)


def test_bell_single_identity_block():
    br = bc = 4
    vals = np.eye(br, dtype=np.float32)[None, None]
    cols = np.zeros((1, 1), np.int32)
    x = np.arange(bc, dtype=np.float32)
    np.testing.assert_allclose(np.asarray(bell_spmv(vals, cols, x)), x, rtol=1e-6)
