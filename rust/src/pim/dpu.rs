//! DPU timing model.
//!
//! SparseP kernels execute *functionally* in plain Rust (producing exact
//! numerical results) while counting, per tasklet, the quantities that
//! determine time on the real DPU:
//!
//! * pipeline instructions issued,
//! * MRAM DMA transfers and bytes (split into streaming and random),
//! * mutex acquisitions and critical-section work,
//! * barriers.
//!
//! This module turns those counts into cycles with the analytic model
//! below, calibrated by [`super::calib`]. The model captures the three
//! first-order behaviours the paper's single-DPU analysis rests on:
//!
//! 1. **Pipeline law**: a tasklet dispatches at most one instruction per
//!    11 cycles, the pipeline at most one per cycle. With per-tasklet
//!    instruction counts `I_t`: `pipeline = max(11 * max_t I_t, sum_t I_t)`.
//!    This produces the paper's saturation knee at 11 tasklets and its
//!    sensitivity to *imbalance across tasklets* (recommendation #1).
//! 2. **DMA engine law**: the per-DPU DMA engine is shared; concurrent
//!    MRAM accesses by different tasklets serialize on its *occupancy*:
//!    `engine = sum_t (occ * n_t + bytes_t / 2)`. SpMV's per-element x
//!    gathers make this the bound for narrow types (memory-bound SpMV),
//!    while software-emulated fp32/fp64 MACs push the pipeline bound
//!    above it (compute-bound) — the paper's Fig. 7 shape.
//! 3. **Latency law**: the *issuing* tasklet additionally blocks for the
//!    full DMA latency (77 cycles + burst), serial with its own
//!    instructions: `latency = max_t (11 * I_t + lat_t)`. With few
//!    tasklets there is nothing to overlap with, so this is what makes
//!    single-tasklet SpMV slow.
//! 4. **Critical-section law**: critical sections execute serially
//!    across tasklets regardless of lock granularity, because their MRAM
//!    accesses serialize anyway: `cs = sum_t cs_cycles_t`. This yields
//!    the paper's "fine-grained locking does not beat coarse-grained"
//!    finding.
//!
//! Total DPU time = `max(pipeline + barriers, engine, latency, cs)` —
//! the resources overlap across tasklets, so the slowest one bounds the
//! kernel.

use super::arch::PimConfig;
use super::calib;

/// Per-tasklet execution counters, filled in by the kernels.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TaskletCounters {
    /// Pipeline instructions issued (includes lock and loop overhead,
    /// excludes DMA wait).
    pub instrs: u64,
    /// Number of MRAM<->WRAM DMA transfers issued.
    pub dma_transfers: u64,
    /// Total bytes moved by those transfers (already rounded up to the
    /// 8-byte MRAM granularity by the caller).
    pub dma_bytes: u64,
    /// Mutex acquisitions (acquire+release instruction cost is *added by
    /// the model*, not by the kernel).
    pub lock_acqs: u64,
    /// Instructions executed while holding a lock.
    pub cs_instrs: u64,
    /// DMA transfers issued while holding a lock.
    pub cs_dma_transfers: u64,
    /// DMA bytes moved while holding a lock.
    pub cs_dma_bytes: u64,
    /// Barrier participations.
    pub barriers: u64,
}

impl TaskletCounters {
    /// Record a DMA of `bytes` (rounded up to MRAM granularity).
    #[inline]
    pub fn dma(&mut self, bytes: usize) {
        self.dma_transfers += 1;
        self.dma_bytes += crate::util::round_up(bytes.max(1), calib::MRAM_MIN_TRANSFER) as u64;
    }

    /// Record a DMA performed inside a critical section.
    #[inline]
    pub fn cs_dma(&mut self, bytes: usize) {
        self.cs_dma_transfers += 1;
        self.cs_dma_bytes += crate::util::round_up(bytes.max(1), calib::MRAM_MIN_TRANSFER) as u64;
        // CS DMA is also ordinary DMA (it occupies the engine).
        self.dma(bytes);
    }

    /// Record a large streaming read split into MAX_TRANSFER chunks (the
    /// kernels stream matrix data MRAM->WRAM in 2 KB tiles).
    pub fn stream(&mut self, bytes: usize) {
        let mut left = bytes;
        while left > 0 {
            let chunk = left.min(calib::MRAM_MAX_TRANSFER);
            self.dma(chunk);
            left -= chunk;
        }
    }

    /// Engine occupancy: what serializes across tasklets.
    fn dma_engine_cycles(&self) -> u64 {
        self.dma_transfers * calib::MRAM_DMA_ENGINE_CYCLES
            + (self.dma_bytes as f64 * calib::MRAM_DMA_CYCLES_PER_BYTE) as u64
    }

    /// Full latency as seen by this tasklet (overlappable with other
    /// tasklets' compute, but serial within the tasklet's own path).
    fn dma_latency_cycles(&self) -> u64 {
        self.dma_transfers * calib::MRAM_DMA_FIXED_CYCLES
            + (self.dma_bytes as f64 * calib::MRAM_DMA_CYCLES_PER_BYTE) as u64
    }

    fn cs_cycles(&self) -> u64 {
        // Inside a critical section nothing overlaps: bill full latency.
        self.cs_instrs
            + self.cs_dma_transfers * calib::MRAM_DMA_FIXED_CYCLES
            + (self.cs_dma_bytes as f64 * calib::MRAM_DMA_CYCLES_PER_BYTE) as u64
    }

    /// Total instructions including the lock-handling overhead.
    fn instrs_with_locks(&self) -> u64 {
        self.instrs
            + self.lock_acqs * (calib::MUTEX_ACQUIRE_INSTRS + calib::MUTEX_RELEASE_INSTRS)
    }
}

/// Cycle breakdown of one DPU's kernel execution.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DpuTiming {
    /// Pipeline-bound cycles (including barrier overhead).
    pub pipeline_cycles: u64,
    /// Serialized-DMA-engine-bound cycles.
    pub dma_cycles: u64,
    /// Slowest single tasklet's own critical path (instructions at the
    /// dispatch interval + its DMA latencies).
    pub latency_cycles: u64,
    /// Serialized-critical-section-bound cycles.
    pub cs_cycles: u64,
    /// Final cycles = max of the bounds (what the kernel run costs).
    pub cycles: u64,
}

impl DpuTiming {
    /// Which resource bounds this DPU?
    pub fn bottleneck(&self) -> &'static str {
        if self.cycles == self.pipeline_cycles {
            "pipeline"
        } else if self.cycles == self.dma_cycles {
            "mram-dma"
        } else if self.cycles == self.cs_cycles {
            "critical-section"
        } else {
            "dma-latency"
        }
    }
}

/// Evaluate the timing model for one DPU given per-tasklet counters.
pub fn dpu_time(cfg: &PimConfig, tasklets: &[TaskletCounters]) -> DpuTiming {
    assert!(!tasklets.is_empty());
    let max_instr = tasklets.iter().map(|t| t.instrs_with_locks()).max().unwrap_or(0);
    let sum_instr: u64 = tasklets.iter().map(|t| t.instrs_with_locks()).sum();
    let n_barriers = tasklets.iter().map(|t| t.barriers).max().unwrap_or(0);
    let barrier_cycles = n_barriers
        * (calib::BARRIER_BASE_CYCLES
            + calib::BARRIER_PER_TASKLET_CYCLES * tasklets.len() as u64);

    let pipeline_cycles =
        (calib::DISPATCH_INTERVAL * max_instr).max(sum_instr) + barrier_cycles;

    let dma_cycles: u64 = if cfg.serialize_mram {
        // Real UPMEM: one DMA engine, occupancy serializes across
        // tasklets.
        tasklets.iter().map(|t| t.dma_engine_cycles()).sum()
    } else {
        // Hypothetical SALP-style hardware: banks/subarrays in parallel.
        tasklets.iter().map(|t| t.dma_engine_cycles()).max().unwrap_or(0)
    };

    // Slowest tasklet's own serial path: dispatch slots + DMA latency.
    let latency_cycles = tasklets
        .iter()
        .map(|t| calib::DISPATCH_INTERVAL * t.instrs_with_locks() + t.dma_latency_cycles())
        .max()
        .unwrap_or(0);

    // Critical sections serialize across tasklets regardless of lock
    // granularity (their MRAM accesses share the DMA engine and the
    // UPMEM mutex is a WRAM atomic): total CS time is the sum.
    let cs_cycles: u64 = tasklets.iter().map(|t| t.cs_cycles()).sum();

    let cycles = pipeline_cycles.max(dma_cycles).max(latency_cycles).max(cs_cycles);
    DpuTiming { pipeline_cycles, dma_cycles, latency_cycles, cs_cycles, cycles }
}

/// Convert DPU cycles to seconds under a config.
pub fn cycles_to_s(cfg: &PimConfig, cycles: u64) -> f64 {
    cycles as f64 * cfg.cycle_s()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn compute_only(instrs: u64) -> TaskletCounters {
        TaskletCounters { instrs, ..Default::default() }
    }

    #[test]
    fn single_tasklet_pays_dispatch_interval() {
        let cfg = PimConfig::default();
        let t = dpu_time(&cfg, &[compute_only(1000)]);
        assert_eq!(t.pipeline_cycles, 11_000);
        assert_eq!(t.bottleneck(), "pipeline");
    }

    #[test]
    fn pipeline_saturates_at_11_tasklets() {
        // The paper's Fig. 5 knee: with balanced work, 11+ tasklets reach
        // 1 instr/cycle and more tasklets stop helping.
        let cfg = PimConfig::default();
        let total = 110_000u64;
        let mut prev = u64::MAX;
        for t in [1usize, 2, 4, 8, 11] {
            let per = total / t as u64;
            let counters = vec![compute_only(per); t];
            let cycles = dpu_time(&cfg, &counters).cycles;
            assert!(cycles < prev, "t={t} should be faster");
            prev = cycles;
        }
        // 11 vs 16 tasklets: same total instructions, same time.
        let c11 = dpu_time(&cfg, &vec![compute_only(total / 11); 11]).cycles;
        let c16 = dpu_time(&cfg, &vec![compute_only(total / 16); 16]).cycles;
        assert!((c16 as f64 - c11 as f64).abs() / (c11 as f64) < 0.02);
    }

    #[test]
    fn imbalance_hurts() {
        // Same total work, one hot tasklet -> slower (recommendation #1).
        let cfg = PimConfig::default();
        let balanced = vec![compute_only(1000); 16];
        let mut skewed = vec![compute_only(500); 16];
        skewed[0].instrs = 8500;
        let b = dpu_time(&cfg, &balanced).cycles;
        let s = dpu_time(&cfg, &skewed).cycles;
        assert!(s > 5 * b, "skewed {s} vs balanced {b}");
    }

    #[test]
    fn dma_serializes_across_tasklets() {
        let cfg = PimConfig::default();
        let mut t = TaskletCounters::default();
        t.dma(64);
        let one = dpu_time(&cfg, &[t]);
        let four = dpu_time(&cfg, &[t; 4]);
        assert_eq!(four.dma_cycles, 4 * one.dma_cycles);
        // With SALP-style hardware they would overlap.
        let salp_cfg = PimConfig { serialize_mram: false, ..Default::default() };
        assert_eq!(dpu_time(&salp_cfg, &[t; 4]).dma_cycles, one.dma_cycles);
    }

    #[test]
    fn min_transfer_granularity_applied() {
        let mut t = TaskletCounters::default();
        t.dma(4); // 4-byte gather still moves 8 bytes
        assert_eq!(t.dma_bytes, 8);
    }

    #[test]
    fn stream_splits_into_chunks() {
        let mut t = TaskletCounters::default();
        t.stream(5000);
        assert_eq!(t.dma_transfers, 3); // 2048 + 2048 + 904
        assert_eq!(t.dma_bytes, 2048 + 2048 + crate::util::round_up(904, 8) as u64);
    }

    #[test]
    fn critical_sections_serialize() {
        let cfg = PimConfig::default();
        let mut t = TaskletCounters::default();
        t.instrs = 100;
        t.lock_acqs = 10;
        t.cs_instrs = 50;
        let timing = dpu_time(&cfg, &vec![t; 16]);
        assert_eq!(timing.cs_cycles, 16 * 50);
        // Lock overhead lands in the pipeline count.
        let expected_instrs =
            100 + 10 * (calib::MUTEX_ACQUIRE_INSTRS + calib::MUTEX_RELEASE_INSTRS);
        assert!(timing.pipeline_cycles >= calib::DISPATCH_INTERVAL * expected_instrs);
    }

    #[test]
    fn barrier_cost_scales_with_tasklets() {
        let cfg = PimConfig::default();
        let mut t = compute_only(10);
        t.barriers = 2;
        let c2 = dpu_time(&cfg, &vec![t; 2]).pipeline_cycles;
        let c16 = dpu_time(&cfg, &vec![t; 16]).pipeline_cycles;
        assert!(c16 > c2);
    }

    #[test]
    fn bottleneck_labels() {
        let cfg = PimConfig::default();
        let mut dma_heavy = TaskletCounters::default();
        dma_heavy.instrs = 10;
        for _ in 0..100 {
            dma_heavy.dma(8);
        }
        // One tasklet: its own DMA latency is the critical path.
        assert_eq!(dpu_time(&cfg, &[dma_heavy]).bottleneck(), "dma-latency");
        // Many tasklets: engine occupancy serializes and dominates.
        assert_eq!(dpu_time(&cfg, &[dma_heavy; 16]).bottleneck(), "mram-dma");
        assert_eq!(dpu_time(&cfg, &[compute_only(1000)]).bottleneck(), "pipeline");
    }

    #[test]
    fn latency_bound_single_tasklet() {
        // 1 tasklet, 1 DMA: cycles include full 77-cycle latency.
        let cfg = PimConfig::default();
        let mut t = TaskletCounters::default();
        t.instrs = 10;
        t.dma(8);
        let timing = dpu_time(&cfg, &[t]);
        assert_eq!(
            timing.latency_cycles,
            10 * calib::DISPATCH_INTERVAL + calib::MRAM_DMA_FIXED_CYCLES + 4
        );
        assert_eq!(timing.cycles, timing.latency_cycles);
    }
}
