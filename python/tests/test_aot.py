"""AOT pipeline: lowering produces valid HLO text + a complete manifest."""

import json
import os

import pytest

from compile import aot, model
import jax
import jax.numpy as jnp


def test_to_hlo_text_produces_hlo_module():
    spec = jax.ShapeDtypeStruct((64, 4), jnp.float32)
    ispec = jax.ShapeDtypeStruct((64, 4), jnp.int32)
    xspec = jax.ShapeDtypeStruct((64,), jnp.float32)
    lowered = jax.jit(model.spmv_ell).lower(spec, ispec, xspec)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    assert "ROOT" in text
    # return_tuple=True: root is a tuple (the Rust side calls to_tuple1).
    assert "tuple" in text


def test_variants_are_unique_and_well_formed():
    vs = aot.variants()
    names = [v[0] for v in vs]
    assert len(names) == len(set(names))
    kinds = {v[3]["kind"] for v in vs}
    assert {"ell", "bell", "dense", "power_iter", "cg_residual"} <= kinds
    for _, _, args, meta in vs:
        assert meta["dtype"] in ("f32", "f64")
        assert all(hasattr(a, "shape") for a in args)


def test_build_writes_manifest(tmp_path):
    # Build just the smallest variant set into a temp dir by monkeypatching.
    small = [v for v in aot.variants() if v[0].startswith("ell_f32_r1024")]
    assert small, "expected the r1024 bucket to exist"
    orig = aot.variants
    aot.variants = lambda: small
    try:
        manifest = aot.build(str(tmp_path))
    finally:
        aot.variants = orig
    m = json.loads((tmp_path / "manifest.json").read_text())
    assert m["format"] == "hlo-text"
    assert len(m["artifacts"]) == len(small)
    for a in m["artifacts"]:
        p = tmp_path / a["file"]
        assert p.exists()
        assert p.read_text().startswith("HloModule")
        assert a["inputs"], "manifest must carry input shapes"
    assert manifest["artifacts"][0]["name"] == small[0][0]
