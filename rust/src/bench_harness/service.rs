//! Serving-pipeline wall-clock benchmark (`sparsep bench-service`).
//!
//! Measures what the [`SpmvService`] request queue buys over synchronous
//! execution: R batched requests served back-to-back through the
//! pipelined engine (all tickets in flight, stages overlapping across
//! requests and blocks) versus the same requests executed one after the
//! other on the synchronous [`crate::coordinator::ExecutionPlan`] path,
//! on the serial and threaded engines. Responses are bit-identical
//! between the two paths (checked here and locked by
//! `tests/service_equivalence.rs`); only the wall clock differs.
//!
//! The matrix is loaded (fingerprint + plan) ONCE per service before
//! any timing — submissions against the [`MatrixHandle`] are hash-free,
//! so the timed region measures serving, not hashing. The JSON summary
//! lands in `BENCH_service.json` next to `BENCH_coordinator.json` and
//! `BENCH_batch.json`.
//!
//! [`MatrixHandle`]: crate::coordinator::MatrixHandle

use crate::coordinator::{
    BlockPolicy, Engine, KernelSpec, PlanCache, Request, ServiceBuilder, SpmvExecutor,
    SpmvService, VECTOR_BLOCK,
};
use crate::matrix::generate;
use crate::pim::{PimConfig, PimSystem};
use crate::util::json::{num, obj, s};
use crate::util::{Context, Result};
use crate::util::sync::Arc;
use std::time::Instant;

/// Knobs for [`run`] (CLI flags of `sparsep bench-service`).
#[derive(Clone, Debug)]
pub struct ServiceBenchOpts {
    /// Matrix dimension (square, scale-free class).
    pub rows: usize,
    /// Average degree (non-zeros per row).
    pub deg: usize,
    /// Number of batched requests per measurement.
    pub requests: usize,
    /// Right-hand-side vectors per request.
    pub batch: usize,
    /// Simulated DPU count.
    pub n_dpus: usize,
    /// Threaded-engine worker count (0 = all cores).
    pub threads: usize,
    /// Kernel name (see `sparsep kernels`).
    pub kernel: String,
    /// Timed samples per measurement (min is reported).
    pub samples: usize,
    /// Service intake-queue depth.
    pub queue_depth: usize,
    /// Output JSON path.
    pub out: String,
}

impl Default for ServiceBenchOpts {
    fn default() -> ServiceBenchOpts {
        ServiceBenchOpts {
            rows: 50_000,
            deg: 8,
            requests: 8,
            batch: 16,
            n_dpus: 256,
            threads: 0,
            kernel: "CSR.nnz".to_string(),
            samples: 2,
            queue_depth: 16,
            out: "BENCH_service.json".to_string(),
        }
    }
}

/// Run the benchmark and write the JSON summary to `opts.out`.
pub fn run(opts: &ServiceBenchOpts) -> Result<()> {
    crate::ensure!(opts.requests >= 1, "bench-service needs --requests >= 1");
    crate::ensure!(opts.batch >= 1, "bench-service needs --batch >= 1");
    crate::ensure!(opts.samples >= 1, "bench-service needs --samples >= 1");
    let spec = KernelSpec::by_name(&opts.kernel, 8)
        .with_context(|| format!("unknown kernel {} (see `sparsep kernels`)", opts.kernel))?;
    let m = generate::scale_free::<f64>(opts.rows, opts.rows, opts.deg, 0.6, 7);
    // Request payloads, deterministic and built outside every timed
    // region (submission consumes owned vectors).
    let payloads: Vec<Vec<Vec<f64>>> = (0..opts.requests)
        .map(|r| {
            (0..opts.batch)
                .map(|b| (0..m.ncols()).map(|i| ((i + 3 * b + 7 * r) % 9) as f64 - 4.0).collect())
                .collect()
        })
        .collect();
    let sys = PimSystem::new(PimConfig { n_dpus: opts.n_dpus, ..Default::default() })?;
    println!(
        "bench-service: {} x{} requests x{} vectors on {}x{} ({} nnz), {} DPUs, queue depth {}",
        spec.name,
        opts.requests,
        opts.batch,
        m.nrows(),
        m.ncols(),
        m.nnz(),
        opts.n_dpus,
        opts.queue_depth
    );

    // One shared plan cache: the fingerprint + plan build happen once,
    // before any timed region, and both engines (same bus shape) reuse
    // the resident plan.
    let cache: Arc<PlanCache<f64>> = Arc::new(PlanCache::new());
    let plan = cache.plan(&SpmvExecutor::new(sys.clone()), &spec, &m)?;

    let wall = |engine: Engine| -> Result<(f64, f64)> {
        let exec = SpmvExecutor::with_engine(sys.clone(), engine);
        // Pin the service to the synchronous path's block width: the two
        // timed paths must differ only in request pipelining, not in how
        // much matrix streaming each fused block amortizes.
        let svc: SpmvService<f64> = ServiceBuilder::new()
            .engine(engine)
            .queue_depth(opts.queue_depth)
            .vector_block(BlockPolicy::Fixed(VECTOR_BLOCK))
            .build_with_cache(sys.clone(), Arc::clone(&cache))?;
        let handle = svc.load(&m, &spec)?; // cache hit: no re-plan, out of timing
        // Sanity: pipelined and synchronous answers agree bit-for-bit.
        let warm_sync = plan.execute_batch_runs(&exec, &payloads[0])?;
        let warm_svc = svc.spmv_batch(&handle, &payloads[0])?;
        for (a, b) in warm_sync.runs.iter().zip(&warm_svc.runs) {
            crate::ensure!(a.y == b.y, "pipelined output diverged from synchronous output");
        }
        let mut sync_s = f64::INFINITY;
        let mut piped_s = f64::INFINITY;
        for _ in 0..opts.samples {
            // Synchronous: each request runs load->kernel->merge to
            // completion before the next starts.
            let t0 = Instant::now();
            for xs in &payloads {
                let b = plan.execute_batch_runs(&exec, xs)?;
                std::hint::black_box(&b.runs.last().unwrap().y);
            }
            sync_s = sync_s.min(t0.elapsed().as_secs_f64());
            // Pipelined: every ticket in flight at once; stages overlap
            // across requests and blocks. Payload Arcs are built before
            // the clock starts (request payloads are shared slices —
            // submitting clones references, not vector data).
            let owned: Vec<Vec<Arc<[f64]>>> = payloads
                .iter()
                .map(|xs| xs.iter().map(|v| Arc::from(&v[..])).collect())
                .collect();
            let t1 = Instant::now();
            let tickets: Vec<_> = owned
                .into_iter()
                .map(|xs| svc.submit(handle, Request::Batch { xs }))
                .collect::<Result<_>>()?;
            for t in tickets {
                let resp = svc.wait(t)?.into_batch()?;
                std::hint::black_box(&resp.runs.last().unwrap().y);
            }
            piped_s = piped_s.min(t1.elapsed().as_secs_f64());
        }
        Ok((sync_s, piped_s))
    };

    let (serial_sync, serial_piped) = wall(Engine::Serial)?;
    let (thr_sync, thr_piped) = wall(Engine::threaded(opts.threads))?;
    let report = |name: &str, sync_s: f64, piped_s: f64| {
        println!(
            "  {:<8} synchronous {:>8.3}s | pipelined {:>8.3}s | speedup {:>5.2}x",
            name,
            sync_s,
            piped_s,
            sync_s / piped_s.max(1e-12)
        );
    };
    report("serial", serial_sync, serial_piped);
    report("threaded", thr_sync, thr_piped);
    println!(
        "  plan cache: {} hit(s), {} miss(es), {} build(s)",
        cache.hits(),
        cache.misses(),
        cache.builds()
    );

    let j = obj(vec![
        ("bench", s("service_request_pipeline")),
        ("kernel", s(&spec.name)),
        ("rows", num(m.nrows() as f64)),
        ("nnz", num(m.nnz() as f64)),
        ("requests", num(opts.requests as f64)),
        ("batch", num(opts.batch as f64)),
        ("dpus", num(opts.n_dpus as f64)),
        ("host_threads", num(opts.threads as f64)),
        ("queue_depth", num(opts.queue_depth as f64)),
        ("samples", num(opts.samples as f64)),
        ("serial_sync_wall_s", num(serial_sync)),
        ("serial_pipelined_wall_s", num(serial_piped)),
        ("threaded_sync_wall_s", num(thr_sync)),
        ("threaded_pipelined_wall_s", num(thr_piped)),
        ("serial_speedup", num(serial_sync / serial_piped.max(1e-12))),
        ("threaded_speedup", num(thr_sync / thr_piped.max(1e-12))),
        ("plan_builds", num(cache.builds() as f64)),
    ]);
    std::fs::write(&opts.out, j.to_string() + "\n")
        .with_context(|| format!("write {}", opts.out))?;
    println!("wrote {}", opts.out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_service_smoke_writes_json() {
        let dir = std::env::temp_dir().join("sparsep_bench_service_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_service_test.json");
        let opts = ServiceBenchOpts {
            rows: 400,
            deg: 4,
            requests: 3,
            batch: 4,
            n_dpus: 8,
            threads: 2,
            samples: 1,
            queue_depth: 2,
            out: out.to_str().unwrap().to_string(),
            ..Default::default()
        };
        run(&opts).unwrap();
        let txt = std::fs::read_to_string(&out).unwrap();
        let j = crate::util::json::Json::parse(&txt).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("service_request_pipeline"));
        assert_eq!(j.get("requests").as_usize(), Some(3));
        assert_eq!(j.get("plan_builds").as_usize(), Some(1));
        assert!(j.get("threaded_pipelined_wall_s").as_f64().unwrap() > 0.0);
        std::fs::remove_file(&out).ok();
    }
}
