//! Jacobi iteration on the PIM service — the simplest stationary
//! solver, and a good stress of the coordinator because it needs the
//! matrix *split* into diagonal and off-diagonal parts.

use super::SolveStats;
use crate::coordinator::{KernelSpec, SpmvService};
use crate::matrix::CooMatrix;
use crate::util::Result;

/// Jacobi outcome.
#[derive(Clone, Debug)]
pub struct JacobiResult {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    pub stats: SolveStats,
}

/// Split `A` into (off-diagonal matrix, diagonal vector).
pub fn split_diagonal(a: &CooMatrix<f64>) -> (CooMatrix<f64>, Vec<f64>) {
    let n = a.nrows();
    let mut diag = vec![0.0f64; n];
    let mut off = Vec::with_capacity(a.nnz());
    for (r, c, v) in a.iter() {
        if r == c {
            diag[r as usize] += v;
        } else {
            off.push((r, c, v));
        }
    }
    (CooMatrix::from_triples(n, a.ncols(), off), diag)
}

/// Jacobi: `x' = D^-1 (b - R x)` with the `R x` SpMV on PIM.
pub fn solve(
    svc: &SpmvService<f64>,
    spec: &KernelSpec,
    a: &CooMatrix<f64>,
    b: &[f64],
    tol: f64,
    max_iters: usize,
) -> Result<JacobiResult> {
    crate::ensure!(a.nrows() == a.ncols(), "Jacobi needs a square matrix");
    let n = a.nrows();
    let (r_mat, diag) = split_diagonal(a);
    crate::ensure!(diag.iter().all(|&d| d != 0.0), "zero diagonal entry");
    // Load once over the off-diagonal matrix; every sweep reuses the
    // handle's resident plan.
    let handle = svc.load(&r_mat, spec)?;
    let mut stats = SolveStats::default();
    let mut x = vec![0.0f64; n];
    let mut converged = false;
    let mut iterations = 0;
    for _ in 0..max_iters {
        let run = svc.spmv(&handle, &x)?;
        stats.absorb(&run);
        let mut delta = 0.0f64;
        for i in 0..n {
            let xi = (b[i] - run.y[i]) / diag[i];
            delta += (xi - x[i]).abs();
            x[i] = xi;
        }
        iterations += 1;
        if delta < tol {
            converged = true;
            break;
        }
    }
    // Release the handle's plan pin: a long-lived service must not
    // accumulate one resident plan per solve call.
    svc.unload(handle);
    Ok(JacobiResult { x, iterations, converged, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::cg::spd_from;
    use crate::coordinator::ServiceBuilder;
    use crate::matrix::generate;
    use crate::pim::PimSystem;

    fn service(n_dpus: usize) -> SpmvService<f64> {
        ServiceBuilder::new().build(PimSystem::with_dpus(n_dpus)).unwrap()
    }

    #[test]
    fn jacobi_converges_on_diagonally_dominant_system() {
        let a = spd_from(&generate::uniform::<f64>(200, 200, 4, 3));
        let b: Vec<f64> = (0..200).map(|i| (i % 5) as f64).collect();
        let svc = service(8);
        let res = solve(&svc, &KernelSpec::coo_nnz(), &a, &b, 1e-12, 2000).unwrap();
        assert!(res.converged, "after {} iters", res.iterations);
        let ax = a.spmv(&res.x);
        for i in 0..200 {
            assert!((ax[i] - b[i]).abs() < 1e-8, "row {i}");
        }
    }

    #[test]
    fn split_diagonal_partitions() {
        let a = spd_from(&generate::banded::<f64>(50, 4, 1));
        let (off, diag) = split_diagonal(&a);
        assert_eq!(off.nnz() + diag.iter().filter(|&&d| d != 0.0).count(), a.nnz());
        for (r, c, _) in off.iter() {
            assert_ne!(r, c);
        }
    }

    #[test]
    fn rejects_zero_diagonal() {
        let a = CooMatrix::from_triples(3, 3, vec![(0, 1, 1.0f64)]);
        let svc = service(2);
        assert!(solve(&svc, &KernelSpec::csr_row(), &a, &vec![1.0; 3], 1e-6, 10).is_err());
    }
}
