//! Roofline models for the paper's processor-centric testbeds.
//!
//! The paper's Fig. 16 / Table 3 argument: SpMV is memory-bound, so on a
//! CPU or GPU it attains `min(peak_compute, AI * mem_bw)` — and since
//! SpMV's arithmetic intensity (AI) is ~0.1-0.25 flop/byte, both attain
//! only a few percent of machine peak. The UPMEM system's compute peak
//! is tiny relative to its *aggregate bank* bandwidth, so SpMV attains a
//! *large* fraction of its peak (51.7% average for fp32 in the paper).
//! These models quantify that for any matrix/type, and calibrate the
//! "GPU" comparison point our XLA-CPU proxy cannot measure directly.

use crate::matrix::{DType, MatrixStats};
use crate::pim::calib;

/// One platform's roofline parameters.
#[derive(Clone, Copy, Debug)]
pub struct Platform {
    pub name: &'static str,
    /// Peak fp32 GFLOP/s (scaled for other dtypes below).
    pub peak_gflops_f32: f64,
    /// Sustained memory bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// TDP-style power for energy estimates, watts.
    pub watts: f64,
}

/// The paper's CPU testbed (Intel Xeon class).
pub const CPU: Platform = Platform {
    name: "CPU (Xeon)",
    peak_gflops_f32: calib::CPU_PEAK_GFLOPS_F32,
    mem_bw_gbs: calib::CPU_MEM_BW_GBS,
    watts: calib::CPU_TDP_WATTS,
};

/// The paper's GPU testbed (NVIDIA Tesla V100).
pub const GPU: Platform = Platform {
    name: "GPU (V100)",
    peak_gflops_f32: calib::GPU_PEAK_GFLOPS_F32,
    mem_bw_gbs: calib::GPU_MEM_BW_GBS,
    watts: calib::GPU_TDP_WATTS,
};

impl Platform {
    /// Peak compute for a data type (fp64 at half fp32 rate, integers at
    /// fp32 rate — close enough for the fraction-of-peak ordering).
    pub fn peak_gflops(&self, dt: DType) -> f64 {
        match dt {
            DType::F64 | DType::I64 => self.peak_gflops_f32 / 2.0,
            _ => self.peak_gflops_f32,
        }
    }

    /// Bytes moved per SpMV iteration for a CSR matrix (matrix streamed
    /// once + x gathered + y written; x gathers counted once per nnz at
    /// cache-line efficiency 0.5 for irregular access).
    pub fn spmv_bytes(&self, stats: &MatrixStats, dt: DType) -> f64 {
        let es = dt.size_bytes() as f64;
        let matrix = stats.nnz as f64 * (4.0 + es) + (stats.nrows as f64 + 1.0) * 4.0;
        let x_gather = stats.nnz as f64 * es * 2.0; // irregular, ~50% line use
        let y = stats.nrows as f64 * es;
        matrix + x_gather + y
    }

    /// Attainable GFLOP/s for SpMV on a matrix: bandwidth-bound roofline.
    pub fn spmv_attainable_gflops(&self, stats: &MatrixStats, dt: DType) -> f64 {
        let flops = 2.0 * stats.nnz as f64;
        let ai = flops / self.spmv_bytes(stats, dt); // flop/byte
        (ai * self.mem_bw_gbs).min(self.peak_gflops(dt))
    }

    /// Fraction of machine peak SpMV attains (the paper's Fig. 16 metric).
    pub fn spmv_fraction_of_peak(&self, stats: &MatrixStats, dt: DType) -> f64 {
        self.spmv_attainable_gflops(stats, dt) / self.peak_gflops(dt)
    }

    /// Modeled SpMV time, seconds.
    pub fn spmv_seconds(&self, stats: &MatrixStats, dt: DType) -> f64 {
        2.0 * stats.nnz as f64 / (self.spmv_attainable_gflops(stats, dt) * 1e9)
    }

    /// Modeled SpMV energy, joules.
    pub fn spmv_energy_j(&self, stats: &MatrixStats, dt: DType) -> f64 {
        self.spmv_seconds(stats, dt) * self.watts
    }
}

/// The PIM system's fraction of peak for comparison: `attained GFLOP/s /
/// (n_dpus * per-DPU peak)`.
pub fn pim_fraction_of_peak(kernel_gflops: f64, n_dpus: usize, dt: DType) -> f64 {
    kernel_gflops / (calib::dpu_peak_gflops(dt) * n_dpus as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{generate, MatrixStats};

    fn stats() -> MatrixStats {
        MatrixStats::of(&generate::uniform::<f64>(8192, 8192, 16, 1))
    }

    #[test]
    fn cpu_gpu_fraction_of_peak_is_small() {
        let s = stats();
        // The paper's observation: processor-centric SpMV sits at a few
        // percent of machine peak.
        let fc = CPU.spmv_fraction_of_peak(&s, DType::F32);
        let fg = GPU.spmv_fraction_of_peak(&s, DType::F32);
        assert!(fc < 0.10, "CPU fraction {fc}");
        assert!(fg < 0.10, "GPU fraction {fg}");
        // PIM at the paper's average (51.7%) dwarfs both.
        assert!(0.517 > 5.0 * fc && 0.517 > 5.0 * fg);
    }

    #[test]
    fn gpu_faster_than_cpu_absolute() {
        let s = stats();
        assert!(
            GPU.spmv_attainable_gflops(&s, DType::F32)
                > 10.0 * CPU.spmv_attainable_gflops(&s, DType::F32)
        );
    }

    #[test]
    fn fp64_halves_peak() {
        assert_eq!(GPU.peak_gflops(DType::F64), GPU.peak_gflops_f32 / 2.0);
    }

    #[test]
    fn pim_fraction_formula() {
        let dt = DType::F32;
        let peak64 = calib::dpu_peak_gflops(dt) * 64.0;
        assert!((pim_fraction_of_peak(peak64 / 2.0, 64, dt) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn energy_scales_with_time() {
        let s = stats();
        let e32 = CPU.spmv_energy_j(&s, DType::F32);
        let e64 = CPU.spmv_energy_j(&s, DType::F64);
        assert!(e64 > e32);
    }
}
