"""AOT pipeline: lower the L2 model (with its L1 Pallas kernels) to HLO
text + a manifest the Rust runtime indexes.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids, so text round-trips cleanly. Lowering goes
stablehlo -> XlaComputation (return_tuple=True; the Rust side unwraps
with `to_tuple1`) -> `as_hlo_text()`.

Usage: python -m compile.aot [--out-dir ../artifacts]
Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

_DTYPES = {"f32": jnp.float32, "f64": jnp.float64}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(dtype, shape):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_of(s):
    return [s.dtype.name, list(s.shape)]


def variants():
    """The artifact set: (name, fn, example-arg specs, metadata).

    ELL shape buckets cover the evaluation suite (the Rust host pads a
    CSR matrix up to the nearest bucket); block-ELL covers the BCSR
    path; dense is the 'GPU library' baseline; the two composed graphs
    prove SpMV embeds into larger programs.
    """
    out = []
    ell_buckets = [(1024, 8, 1024), (2048, 16, 2048), (4096, 32, 4096), (8192, 16, 8192)]
    for dt_name in ("f32", "f64"):
        dt = _DTYPES[dt_name]
        for r, k, n in ell_buckets if dt_name == "f32" else ell_buckets[:1]:
            name = f"ell_{dt_name}_r{r}_k{k}_n{n}"
            args = [_spec(dt, (r, k)), _spec(jnp.int32, (r, k)), _spec(dt, (n,))]
            out.append((name, model.spmv_ell, args, {"kind": "ell", "rows": r, "k": k, "n": n, "dtype": dt_name}))
    # Block-ELL: 8x8 blocks (MXU-shaped micro-tiles).
    for nbr, bmax, br, bc, n in [(128, 8, 8, 8, 1024), (256, 16, 8, 8, 2048)]:
        name = f"bell_f32_nbr{nbr}_b{bmax}_{br}x{bc}_n{n}"
        args = [
            _spec(jnp.float32, (nbr, bmax, br, bc)),
            _spec(jnp.int32, (nbr, bmax)),
            _spec(jnp.float32, (n,)),
        ]
        out.append((name, model.spmv_bell, args, {
            "kind": "bell", "nbr": nbr, "bmax": bmax, "br": br, "bc": bc, "n": n, "dtype": "f32",
        }))
    # Dense baseline.
    for n in (512, 1024):
        name = f"dense_f32_n{n}"
        args = [_spec(jnp.float32, (n, n)), _spec(jnp.float32, (n,))]
        out.append((name, model.spmv_dense, args, {"kind": "dense", "n": n, "dtype": "f32"}))
    # Composed graphs.
    r, k, n = 1024, 8, 1024
    args = [_spec(jnp.float32, (r, k)), _spec(jnp.int32, (r, k)), _spec(jnp.float32, (n,))]
    out.append((f"power_iter_f32_r{r}_k{k}", model.power_iteration_step, args,
                {"kind": "power_iter", "rows": r, "k": k, "n": n, "dtype": "f32"}))
    args_cg = args + [_spec(jnp.float32, (r,))]
    out.append((f"cg_residual_f32_r{r}_k{k}", model.cg_residual_step, args_cg,
                {"kind": "cg_residual", "rows": r, "k": k, "n": n, "dtype": "f32"}))
    return out


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": []}
    for name, fn, args, meta in variants():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = dict(meta)
        entry["name"] = name
        entry["file"] = fname
        entry["inputs"] = [_shape_of(a) for a in args]
        manifest["artifacts"].append(entry)
        print(f"  lowered {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    a = p.parse_args()
    m = build(a.out_dir)
    print(f"wrote {len(m['artifacts'])} artifacts to {a.out_dir}")


if __name__ == "__main__":
    main()
