//! `ShardedService` — multi-tenant serving over several simulated PIM
//! rank groups.
//!
//! SparseP's multi-rank experiments show where the real scaling
//! headroom lives: one logical matrix spread across *independent* PIM
//! ranks, each rank transferring and computing in parallel, with the
//! host balancing load across them. A single [`super::SpmvService`]
//! models one rank group; this module composes `S` of them behind one
//! facade:
//!
//! * **Shard planning** ([`plan_shards`]) splits the matrix's rows into
//!   `S` contiguous, nnz-balanced, never-empty ranges (reusing the
//!   [`crate::partition::balance`] primitives — the same weighted
//!   splitting the 1D partitioners use across DPUs, applied one level
//!   up, across rank groups). Every row and every non-zero lands in
//!   exactly one shard, and the ranges tile `[0, nrows)` — properties
//!   locked by `tests/proptest_shard.rs`.
//! * **Scatter/gather**: a [`Request::Spmv`] fans one sub-SpMV per
//!   shard (row sharding keeps the full column space, so each shard
//!   reads the whole input vector — the 1D broadcast, one level up);
//!   a [`Request::Batch`] fans one sub-batch per shard; gather
//!   concatenates the per-shard output segments in shard (row) order
//!   and folds the metrics (see *merged metrics* below).
//!   [`Request::Iterate`] keeps its feedback loop **across** shards:
//!   each iteration gathers the full output vector and scatters it back
//!   as the next iteration's input, because every shard's slice reads
//!   columns other shards produced.
//!
//! ## 2D grids and replication
//!
//! Row-only sharding stalls on skewed matrices — SparseP's 2D schemes
//! (equally-sized / equally-wide / variable-sized tiles) split columns
//! too, paying a partial-sum merge for the extra parallelism. The
//! facade generalizes accordingly ([`ShardedServiceBuilder::grid`] and
//! [`ShardedServiceBuilder::replicas`], reported by
//! [`ShardedService::grid`] as a [`GridSpec`]):
//!
//! * **Tile planning**: rows split into `R` nnz-balanced bands as
//!   before, then each band's columns split into `C` nnz-balanced,
//!   never-empty stripes (weights counted per band, so a band's skew
//!   determines *its* cuts). Tile `(band, col)` owns the intersection;
//!   its backend reads only the `x` segment of its column stripe and
//!   produces a **partial** output over its row band. The per-row nnz
//!   counts are computed once per registration and shared across the
//!   planner (the `row_counts` hoist).
//! * **Reduction gather**: partials of one row band are **summed
//!   element-wise in fixed ascending-column order** — the reduction
//!   tree is a function of grid coordinates, never of completion
//!   timing, so outputs stay bit-reproducible run to run. Reduced bands
//!   then concatenate exactly like 1D row sharding, and iterate
//!   feedback re-scatters the reduced vector's per-stripe segments.
//!   Partial buffers recycle through the facade's shared
//!   [`BufferPool`]; the assembly point is a
//!   [`crate::util::sync::ReduceSlot`], whose exactly-once /
//!   index-order contract the loom suite checks. `C = 1` bypasses the
//!   reduction entirely: an `R x 1` grid is byte-identical to the
//!   legacy row-sharded facade, metrics included.
//! * **Replication** (`K` replicas per tile): loads and unloads go to
//!   *all* replicas (the shared [`PlanCache`] plans each slice once —
//!   `plan_builds` stays flat); Spmv/Batch/Iterate reads pick the
//!   replica with the fewest outstanding sub-requests
//!   ([`super::scheduler::least_outstanding`], lowest index on ties).
//!   Every replica slot has its own respawn supervision, so a killed
//!   replica recovers exactly like a killed shard. Replicas execute
//!   deterministic simulated work — replica choice never changes
//!   responses.
//!
//! Fault keys stay *linear slot indices* over the grid: slot
//! `(band * C + col) * K + replica` (see [`super::fault`]), so seeded
//! chaos plans replay identically on grid coordinates.
//! * **Fair scheduling**: submissions carry a [`TenantId`]; a
//!   deterministic weighted-round-robin scheduler with per-tenant
//!   in-flight quotas ([`super::scheduler`]) sits between `submit` and
//!   the dispatcher, so a flooding tenant cannot starve the others.
//! * **Handle eviction**: handles are owned by tenants;
//!   [`ShardedService::unload_tenant`] drops every per-shard plan pin
//!   the tenant held and reclaims orphaned plans from the shared
//!   [`PlanCache`] ([`PlanCache::evict_unreferenced`]).
//!
//! All `S` backends share one [`PlanCache`]
//! (via [`super::ServiceBuilder::build_with_cache`]): equal shard
//! slices (e.g. two tenants loading the same matrix) plan once.
//!
//! ## Resilience & SLOs
//!
//! The sharded tier is *supervised*: each backend lives in a swappable
//! slot ([`Backends`]) next to a dead flag, and the facade retains
//! everything needed to rebuild it — the builder configuration (a
//! `BackendRecipe`) plus every registered matrix's per-shard slices.
//! When a backend dies, the next sub-request to touch it respawns a
//! fresh [`SpmvService`] from the recipe, re-loads the affected slices
//! **through the shared [`PlanCache`]** (cache hits — `plan_builds`
//! stays flat, locked by `tests/proptest_shard.rs`), and the in-flight
//! facade request re-scatters its lost sub-requests so the gathered
//! output stays bit-identical to the fault-free run.
//!
//! Failures are injected, never spontaneous: a seed-reproducible
//! [`super::fault::FaultPlan`] (behind the [`FaultInjector`] trait —
//! production configures none and pays nothing) can kill a shard at
//! dispatch or gather time, delay a stage, drop a completion, or stall
//! a shard. `tests/chaos_equivalence.rs` drives every scenario and
//! asserts the chaos run's outputs equal the fault-free oracle's.
//!
//! Three production semantics ride on the same machinery:
//!
//! * **Deadlines**: [`ShardedService::submit_with_deadline`] tags a
//!   request with an absolute deadline; within a tenant the scheduler
//!   dispatches earliest-deadline-first (EDF), while cross-tenant
//!   weighted round-robin is untouched.
//! * **Load shedding**: with [`ShardedServiceBuilder::max_queue`], a
//!   tenant whose scheduler queue is full gets a typed
//!   [`Response::Overloaded`] immediately — shed, counted in
//!   [`super::TenantStats::shed`], never silently dropped.
//! * **Timeouts**: [`ShardedServiceBuilder::wait_timeout`] bounds every
//!   wait; expiry is a typed `ShardTimeout` error naming the wedged
//!   shard when one is known (the ticket survives — a later wait can
//!   still claim the response). Per-tenant latency histograms
//!   (p50/p99/p999, [`super::TenantStats::latency`]) make the SLOs
//!   observable.
//!
//! The synchronous fast paths ([`ShardedService::spmv`] and friends)
//! bypass the scheduler and therefore the fault injector: chaos is a
//! property of the queued pipeline.
//!
//! ## Determinism and the differential harness
//!
//! The sharded path must *buy scale, not drift*. Two contracts, locked
//! by `tests/shard_equivalence.rs`:
//!
//! 1. **Output equivalence**: the gathered output vector is
//!    bit-identical to serving the whole matrix through a single
//!    unsharded [`super::SpmvService`] with the same per-rank system —
//!    for all 25 kernels, both engines, every request kind, any shard
//!    count. (Rows never span shards, and the generators' integer-exact
//!    values make even the element-granular and 2D kernels' partial-sum
//!    regroupings exact.)
//! 2. **`S = 1` degeneration**: with one shard, every response — output
//!    vector, breakdown, stats, energy — is bit-identical to the plain
//!    service, because the single "shard" is the whole matrix and the
//!    metric fold over one part is the identity.
//!
//! **Merged metrics** model `S` rank groups operating concurrently:
//! per-phase times (`load`/`kernel`/`retrieve`/`merge`), the one-time
//! matrix placement and the DPU imbalance take the **max** across
//! shards (the critical path / worst rank group); bus bytes, DPU count,
//! nnz and energy **sum** (they are additive resources). Iterate totals
//! accumulate the merged per-iteration breakdowns in iteration order,
//! exactly like the single-service accumulator.

use super::cache::PlanCache;
use super::calibration::CalibrationTable;
use super::fault::{Fault, FaultInjector};
use super::queue::{BufferPool, Completions, StageGuard, DEFAULT_QUEUE_DEPTH};
use super::scheduler::{least_outstanding, FairScheduler, TenantId, TenantSpec};
use super::service::{BlockPolicy, MatrixHandle, Request, Response, ServiceBuilder, SpmvService, Ticket};
use super::spec::KernelSpec;
use super::{
    BatchResult, Breakdown, Engine, IterationsResult, RunResult, ShardedStats,
};
use crate::format_err;
use crate::matrix::{CooMatrix, MatrixStats, SpElem};
use crate::partition::balance::split_weighted_nonempty;
use crate::pim::{Energy, PimSystem};
use crate::util::{Error, Result};
use std::collections::{HashMap, HashSet};
use std::fmt;
use std::ops::Range;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use crate::util::sync::thread::{spawn_named, JoinHandle};
use crate::util::sync::{Arc, Condvar, Mutex, MutexGuard, ReduceSlot, RespawnSlot};
use std::time::{Duration, Instant};

/// Distinguishes sharded services within a process (handles and tickets
/// from one facade are rejected by another). Stays on `std`'s atomic by
/// full path: `const`-initialized statics can't use the loom-switched
/// facade atomics (loom's `new` is not `const`), and a process-global
/// id counter has no interleaving worth modeling.
static NEXT_SHARDED_ID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

/// Split `m`'s rows into (at most) `shards` contiguous ranges, balanced
/// by non-zeros at row granularity — the across-rank-group analogue of
/// the 1D `*.nnz` partitioning. Guarantees, for any input:
///
/// * the returned ranges tile `[0, nrows)` in order (every row in
///   exactly one shard, hence every stored non-zero in exactly one
///   shard);
/// * no range is empty: the effective shard count is
///   `min(shards, nrows)` (and a 0-row matrix yields one `0..0` shard).
pub fn plan_shards<T: SpElem>(m: &CooMatrix<T>, shards: usize) -> Vec<Range<usize>> {
    let nrows = m.nrows();
    if nrows == 0 {
        return vec![0..0];
    }
    let s = shards.max(1).min(nrows);
    if s == 1 {
        // No split needed — skip the O(nnz) row_counts pass entirely.
        return vec![0..nrows];
    }
    plan_shards_counted(nrows, &m.row_counts(), s)
}

/// [`plan_shards`] over precomputed per-row nnz counts. Registration
/// computes `row_counts` (an O(nnz) pass) once per matrix and shares it
/// with the grid planner instead of recounting per invocation.
pub fn plan_shards_counted(
    nrows: usize,
    row_counts: &[usize],
    shards: usize,
) -> Vec<Range<usize>> {
    debug_assert_eq!(row_counts.len(), nrows);
    if nrows == 0 {
        return vec![0..0];
    }
    let s = shards.max(1).min(nrows);
    if s == 1 {
        return vec![0..nrows];
    }
    // `split_weighted` alone may emit empty ranges on degenerate
    // distributions (e.g. all the weight in the last row); the
    // never-empty variant re-derives boundaries so every shard owns
    // >= 1 row while staying as close to the balanced cut as the
    // remaining row budget allows.
    split_weighted_nonempty(row_counts, s)
}

/// The facade's backend topology: `rows x cols` tiles, each replicated
/// `replicas` times (every field clamped to >= 1 by the builder). The
/// flat backend-slot index of tile `(band, col)`'s replica `k` is
/// `(band * cols + col) * replicas + k` — the linear layout fault keys
/// and respawn counters use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GridSpec {
    /// Row bands (the legacy shard count).
    pub rows: usize,
    /// Column stripes per band (1 = row-only sharding, no reduction).
    pub cols: usize,
    /// Replicas per tile (1 = unreplicated).
    pub replicas: usize,
}

impl GridSpec {
    /// Total backend slots (`rows * cols * replicas`).
    pub fn slots(&self) -> usize {
        self.rows * self.cols * self.replicas
    }

    /// Distinct tiles (`rows * cols`).
    pub fn tiles(&self) -> usize {
        self.rows * self.cols
    }

    /// Flat slot index of tile `(band, col)`'s replica `k`.
    fn slot(&self, band: usize, col: usize, replica: usize) -> usize {
        (band * self.cols + col) * self.replicas + replica
    }

    /// Inverse of [`GridSpec::slot`]: `(band, col, replica)`.
    fn decompose(&self, slot: usize) -> (usize, usize, usize) {
        let replica = slot % self.replicas;
        let tile = slot / self.replicas;
        (tile / self.cols, tile % self.cols, replica)
    }
}

/// One matrix's planned tile grid: per-tile row/column ranges and
/// slices in band-major order (`tile = band * cols_eff + col`).
struct TilePlan<T: SpElem> {
    ranges: Vec<Range<usize>>,
    col_ranges: Vec<Range<usize>>,
    slices: Vec<CooMatrix<T>>,
    bands: usize,
    cols_eff: usize,
}

/// Plan `m`'s R x C tile grid: nnz-balanced never-empty row bands
/// ([`plan_shards_counted`] over counts computed once here), then
/// per-band nnz-balanced never-empty column stripes. The effective
/// dimensions shrink with the matrix (`bands <= min(R, nrows)`,
/// `cols_eff = min(C, ncols)`), mirroring the row-only clamp. With
/// `cols_eff == 1` each band's slice is the tile itself — the exact
/// slices (and plan-cache fingerprints) the legacy row-sharded path
/// produced.
fn plan_tiles<T: SpElem>(m: &CooMatrix<T>, grid: GridSpec) -> TilePlan<T> {
    let band_ranges = if m.nrows() == 0 || grid.rows.min(m.nrows()) <= 1 {
        plan_shards(m, grid.rows)
    } else {
        // One O(nnz) counting pass per registration, shared across the
        // whole planner.
        plan_shards_counted(m.nrows(), &m.row_counts(), grid.rows)
    };
    let bands = band_ranges.len();
    let cols_eff = grid.cols.max(1).min(m.ncols().max(1));
    let mut ranges = Vec::with_capacity(bands * cols_eff);
    let mut col_ranges = Vec::with_capacity(bands * cols_eff);
    let mut slices = Vec::with_capacity(bands * cols_eff);
    for r in &band_ranges {
        let band_slice = m.row_range_slice(r.start, r.end);
        if cols_eff == 1 {
            ranges.push(r.clone());
            col_ranges.push(0..m.ncols());
            slices.push(band_slice);
            continue;
        }
        // Column weights are counted per band: a band's own skew
        // determines its cuts (SparseP's variable-sized tiles).
        let mut weights = vec![0usize; m.ncols()];
        for &c in &band_slice.cols {
            weights[c as usize] += 1;
        }
        let stripes = split_weighted_nonempty(&weights, cols_eff);
        for (tile, cr) in band_slice.split_col_stripes(&stripes).into_iter().zip(&stripes) {
            ranges.push(r.clone());
            col_ranges.push(cr.clone());
            slices.push(tile);
        }
    }
    TilePlan { ranges, col_ranges, slices, bands, cols_eff }
}

/// A matrix registered with one [`ShardedService`]: cheap to copy,
/// valid until [`ShardedService::unload`] / `unload_tenant` (or the
/// facade drops). Behind it sit one per-shard [`MatrixHandle`] and plan
/// per rank group.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ShardedHandle {
    svc: u64,
    id: u64,
    nrows: usize,
    ncols: usize,
}

impl ShardedHandle {
    /// Rows of the registered (whole) matrix.
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Columns of the registered (whole) matrix.
    pub fn ncols(&self) -> usize {
        self.ncols
    }
}

/// A submitted sharded request's claim check (copyable; see
/// [`ShardedService::wait`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ShardedTicket {
    svc: u64,
    id: u64,
}

impl ShardedTicket {
    /// Monotonic per-facade ticket number (submission order).
    pub fn id(&self) -> u64 {
        self.id
    }
}

/// What one registered matrix looks like to the facade: the per-tile
/// slices in band-major order (`tile = band * cols_eff + col`), the
/// handles those slices are pinned under (index `tile * K + replica` —
/// every replica of a tile holds its own handle on its own backend),
/// the row/column ranges each tile covers, and the owning tenant.
///
/// Retaining the slices is the price of supervision: without them a
/// dead backend's rows would be unrecoverable. The handles sit behind
/// a mutex because a respawn rewrites the dead slot's handle in place
/// while requests for other slots keep flowing.
struct ShardEntry<T: SpElem> {
    handles: Mutex<Vec<MatrixHandle>>,
    slices: Vec<CooMatrix<T>>,
    spec: KernelSpec,
    /// Per-tile row range (band-major; bands repeat `cols_eff` times).
    ranges: Vec<Range<usize>>,
    /// Per-tile column range (the `x` segment the tile reads).
    col_ranges: Vec<Range<usize>>,
    /// Effective row bands (`<= min(grid.rows, nrows)`).
    bands: usize,
    /// Effective column stripes per band (`<= min(grid.cols, ncols)`).
    cols_eff: usize,
    nrows: usize,
    ncols: usize,
    owner: TenantId,
}

/// Everything needed to rebuild a shard backend from scratch — the
/// builder knobs a [`ShardedServiceBuilder`] applies per backend.
#[derive(Clone)]
struct BackendRecipe {
    engine: Engine,
    queue_depth: usize,
    block_policy: BlockPolicy,
    calibration: Option<Arc<CalibrationTable>>,
}

impl BackendRecipe {
    fn build<T: SpElem>(
        &self,
        sys: PimSystem,
        cache: Arc<PlanCache<T>>,
    ) -> Result<SpmvService<T>> {
        let mut builder = ServiceBuilder::new()
            .engine(self.engine)
            .queue_depth(self.queue_depth)
            .vector_block(self.block_policy);
        if let Some(table) = &self.calibration {
            builder = builder.calibration(Arc::clone(table));
        }
        builder.build_with_cache(sys, cache)
    }
}

/// The supervised shard backends: one swappable service slot plus a
/// dead flag per shard, the recipe and system to rebuild one, and the
/// matrix registry whose slices a respawn re-loads.
///
/// Lock order (deadlock-free by construction): slot (`slots[i]`) →
/// registry → a `ShardEntry`'s handles. Respawn takes all three in
/// that order; every other path takes a suffix of it.
struct Backends<T: SpElem> {
    /// One [`RespawnSlot`] per backend slot (`grid.slots()` of them,
    /// linear layout `(band * C + col) * K + replica`): the swappable
    /// service plus its dead flag, with the double-checked kill →
    /// respawn protocol (fast-path flag check, re-check under the write
    /// lock) owned by the facade type so the loom suite exercises the
    /// exact code production runs.
    slots: Vec<RespawnSlot<Arc<SpmvService<T>>>>,
    grid: GridSpec,
    /// Per-slot outstanding sub-request counters (replica dispatch:
    /// reads go to the replica with the fewest in flight).
    outstanding: Vec<Arc<AtomicU64>>,
    /// Recycled partial-output buffers for the reduction gather.
    pool: Mutex<BufferPool<T>>,
    sys: PimSystem,
    recipe: BackendRecipe,
    cache: Arc<PlanCache<T>>,
    registry: Mutex<HashMap<u64, Arc<ShardEntry<T>>>>,
    /// Backends respawned over the facade's lifetime.
    respawns: AtomicU64,
}

impl<T: SpElem> Backends<T> {
    /// Distinct tiles (`grid.tiles()` — what "shards" has always meant
    /// to callers: units of matrix ownership, not replica slots).
    fn shard_count(&self) -> usize {
        self.grid.tiles()
    }

    /// The current service in slot `i` (respawns swap the slot, so
    /// callers clone the `Arc` out instead of holding the guard).
    fn service(&self, i: usize) -> Arc<SpmvService<T>> {
        Arc::clone(&*self.slots[i].read())
    }

    /// Mark backend `i` dead (fault injection). The next sub-request
    /// that touches the slot respawns it.
    fn kill(&self, i: usize) {
        if i < self.slots.len() {
            self.slots[i].kill();
        }
    }

    /// Respawn backend `i` if (and only if) it is marked dead. Racing
    /// callers rebuild exactly once ([`RespawnSlot::ensure_alive`]'s
    /// double-checked protocol); only the thread that actually rebuilt
    /// counts a respawn.
    fn ensure_alive(&self, i: usize) -> Result<()> {
        if self.slots[i].ensure_alive(|slot| self.rebuild_into(i, slot))? {
            self.respawns.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Rebuild slot `i` from the recipe and re-load every registered
    /// matrix's slice for that slot's tile through the shared plan
    /// cache. The slices were planned when first loaded, so the
    /// re-loads are cache *hits*: `plan_builds` stays flat across a
    /// respawn. Runs under the slot's write lock (lock order: slot →
    /// registry → a `ShardEntry`'s handles).
    fn rebuild_into(&self, i: usize, slot: &mut Arc<SpmvService<T>>) -> Result<()> {
        let fresh = self.recipe.build(self.sys.clone(), Arc::clone(&self.cache))?;
        let (band, col, replica) = self.grid.decompose(i);
        let entries: Vec<Arc<ShardEntry<T>>> = {
            let reg = self.registry.lock().expect("shard registry poisoned");
            reg.values().cloned().collect()
        };
        for e in entries {
            // Matrices smaller than the grid use fewer bands/stripes.
            if band < e.bands && col < e.cols_eff {
                let t = band * e.cols_eff + col;
                let h = fresh.load(&e.slices[t], &e.spec)?;
                e.handles.lock().expect("shard entry handles poisoned")
                    [t * self.grid.replicas + replica] = h;
            }
        }
        *slot = Arc::new(fresh);
        Ok(())
    }
}

/// Flat backend-slot index of entry tile `tile`'s replica `replica`.
/// Entry tiles are band-major over the *effective* stripe count
/// (`cols_eff <= grid.cols`), while slots are laid out over the
/// configured grid — a small matrix simply leaves trailing column
/// slots unused.
fn tile_slot(grid: GridSpec, cols_eff: usize, tile: usize, replica: usize) -> usize {
    grid.slot(tile / cols_eff, tile % cols_eff, replica)
}

/// RAII bump of a backend slot's outstanding-sub-request counter: the
/// replica dispatcher reads these to route new work to the least
/// loaded replica. Relaxed ordering — the counter is advisory load
/// feedback, never a synchronization edge.
struct OutstandingGuard(Arc<AtomicU64>);

impl OutstandingGuard {
    fn acquire(counter: &Arc<AtomicU64>) -> OutstandingGuard {
        counter.fetch_add(1, Ordering::Relaxed);
        OutstandingGuard(Arc::clone(counter))
    }
}

impl Drop for OutstandingGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// One sub-request in flight against a specific backend incarnation.
/// The `Arc` pins the exact service the ticket was issued by, so a
/// respawn can never orphan a wait. `shard` is the linear backend-slot
/// index (the fault key); `tile` the entry tile it computes.
struct SubTicket<T: SpElem> {
    svc: Arc<SpmvService<T>>,
    ticket: Ticket,
    shard: usize,
    tile: usize,
    /// Held for the sub-request's lifetime (dropped when the ticket is
    /// claimed or aborted), keeping the slot's load counter honest.
    _outstanding: OutstandingGuard,
}

/// One scheduled-but-not-dispatched request.
struct DispatchJob<T: SpElem> {
    ticket: u64,
    entry: Arc<ShardEntry<T>>,
    req: Request<T>,
    /// When the facade accepted the request (latency histograms).
    submitted: Instant,
}

#[derive(Clone, Copy, Debug)]
enum GatherKind {
    Spmv,
    Batch,
    Iterate,
}

/// The scattered request's input payload, kept alive through gather so
/// fault recovery can re-scatter lost sub-requests from the original
/// vectors (shared `Arc`s — no copies).
enum ScatterPayload<T: SpElem> {
    Spmv(Arc<[T]>),
    Batch(Vec<Arc<[T]>>),
}

/// Dispatcher -> gather hand-off: the sub-tickets of one facade
/// request, to be waited, merged and published in dispatch order.
struct GatherItem<T: SpElem> {
    ticket: u64,
    tenant: TenantId,
    entry: Arc<ShardEntry<T>>,
    kind: GatherKind,
    subs: Vec<SubTicket<T>>,
    iters: usize,
    payload: ScatterPayload<T>,
    submitted: Instant,
}

/// Recorded dispatch/completion order (enable with
/// [`ShardedServiceBuilder::record_schedule`]); the deterministic
/// fairness tests read it back via [`ShardedService::schedule_log`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ScheduleLog {
    /// Tenant of each dispatched request, in dispatch order.
    pub dispatched: Vec<TenantId>,
    /// Ticket id of each dispatched request, in dispatch order (the
    /// EDF deadline tests observe reordering through this).
    pub dispatched_tickets: Vec<u64>,
    /// Tenant of each completed request, in completion (publish) order.
    pub completed: Vec<TenantId>,
}

struct SchedState<T: SpElem> {
    fair: FairScheduler<DispatchJob<T>>,
    paused: bool,
    shutdown: bool,
    log: Option<ScheduleLog>,
}

struct Sched<T: SpElem> {
    state: Mutex<SchedState<T>>,
    /// Signaled on enqueue, completion, resume and shutdown.
    ready: Condvar,
}

impl<T: SpElem> Sched<T> {
    fn lock(&self) -> MutexGuard<'_, SchedState<T>> {
        self.state.lock().expect("sharded scheduler poisoned")
    }

    /// Record a facade request's completion: free its tenant's quota
    /// slot, record its end-to-end latency, log it, and wake the
    /// dispatcher.
    fn complete(&self, tenant: TenantId, us: u64) {
        let mut st = self.lock();
        if let Some(log) = st.log.as_mut() {
            log.completed.push(tenant);
        }
        st.fair.record_latency(tenant, us);
        st.fair.complete(tenant);
        drop(st);
        self.ready.notify_all();
    }
}

fn elapsed_us(since: Instant) -> u64 {
    since.elapsed().as_micros() as u64
}

/// Configuration for [`ShardedService`] (see
/// [`ShardedService::builder`]).
#[derive(Clone)]
pub struct ShardedServiceBuilder {
    shards: usize,
    grid_cols: usize,
    replicas: usize,
    engine: Engine,
    cache_capacity: usize,
    queue_depth: usize,
    block_policy: BlockPolicy,
    calibration: Option<Arc<CalibrationTable>>,
    tenants: Vec<TenantSpec>,
    record_schedule: bool,
    start_paused: bool,
    wait_timeout: Option<Duration>,
    max_queue: Option<usize>,
    fault: Option<Arc<dyn FaultInjector>>,
}

impl fmt::Debug for ShardedServiceBuilder {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ShardedServiceBuilder")
            .field("shards", &self.shards)
            .field("grid_cols", &self.grid_cols)
            .field("replicas", &self.replicas)
            .field("engine", &self.engine)
            .field("cache_capacity", &self.cache_capacity)
            .field("queue_depth", &self.queue_depth)
            .field("block_policy", &self.block_policy)
            .field("calibration", &self.calibration)
            .field("tenants", &self.tenants)
            .field("record_schedule", &self.record_schedule)
            .field("start_paused", &self.start_paused)
            .field("wait_timeout", &self.wait_timeout)
            .field("max_queue", &self.max_queue)
            .field("fault", &self.fault.is_some())
            .finish()
    }
}

impl ShardedServiceBuilder {
    /// Defaults: 2 row shards (a 2x1 grid, unreplicated), serial
    /// engine, default cache/queue/block settings, no calibration
    /// table, one `"default"` tenant (weight 1, unlimited quota), no
    /// wait timeout, no admission cap, no fault injection.
    pub fn new() -> ShardedServiceBuilder {
        ShardedServiceBuilder {
            shards: 2,
            grid_cols: 1,
            replicas: 1,
            engine: Engine::Serial,
            cache_capacity: super::cache::DEFAULT_PLAN_CACHE_CAPACITY,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            block_policy: BlockPolicy::Adaptive,
            calibration: None,
            tenants: Vec::new(),
            record_schedule: false,
            start_paused: false,
            wait_timeout: None,
            max_queue: None,
            fault: None,
        }
    }

    /// Number of row shards (simulated rank groups), clamped to >= 1.
    /// Matrices with fewer rows than shards use fewer shards. Leaves
    /// the column dimension untouched — `shards(S)` on a fresh builder
    /// is an `S x 1` grid, the legacy row-sharded facade.
    pub fn shards(mut self, shards: usize) -> ShardedServiceBuilder {
        self.shards = shards.max(1);
        self
    }

    /// A 2D `rows x cols` tile grid (both clamped to >= 1): rows split
    /// into `rows` nnz-balanced bands, each band's columns into `cols`
    /// nnz-balanced stripes. With `cols > 1` each tile computes a
    /// partial output and the gather sums partials per band in fixed
    /// ascending-column order (bit-reproducible; see the module docs).
    /// `grid(S, 1)` is exactly [`Self::shards`]`(S)`.
    pub fn grid(mut self, rows: usize, cols: usize) -> ShardedServiceBuilder {
        self.shards = rows.max(1);
        self.grid_cols = cols.max(1);
        self
    }

    /// Replicas per tile (clamped to >= 1). Loads and unloads go to all
    /// replicas; Spmv/Batch/Iterate reads dispatch to the replica with
    /// the fewest outstanding sub-requests (lowest index on ties). Each
    /// replica slot is supervised independently. Replica choice never
    /// changes responses — the backends compute deterministically.
    pub fn replicas(mut self, replicas: usize) -> ShardedServiceBuilder {
        self.replicas = replicas.max(1);
        self
    }

    /// Execution engine for every backend (never affects results).
    pub fn engine(mut self, engine: Engine) -> ShardedServiceBuilder {
        self.engine = engine;
        self
    }

    /// Shorthand for `engine(Engine::threaded(threads))`.
    pub fn threads(mut self, threads: usize) -> ShardedServiceBuilder {
        self.engine = Engine::threaded(threads);
        self
    }

    /// Shared plan-cache capacity (plans, across all shards).
    pub fn cache_capacity(mut self, capacity: usize) -> ShardedServiceBuilder {
        self.cache_capacity = capacity;
        self
    }

    /// Per-backend intake-queue depth.
    pub fn queue_depth(mut self, depth: usize) -> ShardedServiceBuilder {
        self.queue_depth = depth;
        self
    }

    /// Vector-block policy for batched requests (per backend).
    pub fn vector_block(mut self, policy: BlockPolicy) -> ShardedServiceBuilder {
        self.block_policy = policy;
        self
    }

    /// Attach a measured [`CalibrationTable`] (see
    /// [`super::tuner::tune`]): every shard backend consults it for
    /// adaptive vector-block widths, and [`Self::shards_for_matrix`]
    /// consults it for the shard count itself. Configuration only —
    /// calibration never changes results (locked by
    /// `tests/calibration.rs`).
    pub fn calibration(mut self, table: Arc<CalibrationTable>) -> ShardedServiceBuilder {
        self.calibration = Some(table);
        self
    }

    /// Pick the grid shape from the attached calibration table: the
    /// nearest measured entry for `m` at `batch_hint` vectors per
    /// request supplies its winning row shards, column stripes and
    /// replica count (the full [`GridSpec`] the tuner's grid sweep
    /// persisted). A no-op without a table (or with an empty one) — the
    /// configured [`Self::shards`] / [`Self::grid`] / [`Self::replicas`]
    /// stand, so callers can chain this unconditionally.
    pub fn shards_for_matrix<T: SpElem>(
        mut self,
        m: &CooMatrix<T>,
        batch_hint: usize,
    ) -> ShardedServiceBuilder {
        if let Some(e) = self
            .calibration
            .as_ref()
            .and_then(|t| t.lookup(&MatrixStats::of(m), batch_hint))
        {
            self.shards = e.shards.max(1);
            self.grid_cols = e.grid_cols.max(1);
            self.replicas = e.replicas.max(1);
        }
        self
    }

    /// Declare the tenants (replaces any previous declaration). Without
    /// a declaration the facade runs a single `"default"` tenant.
    pub fn tenants(mut self, tenants: Vec<TenantSpec>) -> ShardedServiceBuilder {
        self.tenants = tenants;
        self
    }

    /// Record the dispatch/completion schedule (see
    /// [`ShardedService::schedule_log`]). Off by default — the log
    /// grows with every request.
    pub fn record_schedule(mut self, record: bool) -> ShardedServiceBuilder {
        self.record_schedule = record;
        self
    }

    /// Start with the scheduler paused: submissions queue behind the
    /// scheduler until [`ShardedService::resume`]. This is what makes
    /// the fairness tests deterministic — enqueue everything, then let
    /// weighted round-robin order the dispatches.
    pub fn start_paused(mut self, paused: bool) -> ShardedServiceBuilder {
        self.start_paused = paused;
        self
    }

    /// Bound every wait on this facade: [`ShardedService::wait`], the
    /// synchronous fast paths and the gather stage's sub-request waits
    /// all time out after `timeout` with a typed `ShardTimeout` error
    /// (naming the wedged shard where one is known) instead of blocking
    /// forever. The ticket survives a timeout — a later wait can still
    /// claim the response. Default: wait indefinitely.
    pub fn wait_timeout(mut self, timeout: Duration) -> ShardedServiceBuilder {
        self.wait_timeout = Some(timeout);
        self
    }

    /// Admission control: cap each tenant's scheduler queue at `cap`
    /// requests. A submit beyond the cap is *shed* — its ticket
    /// resolves immediately to [`Response::Overloaded`] (typed, never a
    /// silent drop) and [`super::TenantStats::shed`] counts it. `0`
    /// sheds everything. Default: unbounded.
    pub fn max_queue(mut self, cap: usize) -> ShardedServiceBuilder {
        self.max_queue = Some(cap);
        self
    }

    /// Inject faults into the queued pipeline (chaos testing): the
    /// dispatcher and gather stages consult `fault` per facade ticket.
    /// See [`super::fault::FaultPlan`] for the seed-reproducible
    /// implementation. Default: none (production pays nothing).
    pub fn fault_injector(mut self, fault: Arc<dyn FaultInjector>) -> ShardedServiceBuilder {
        self.fault = Some(fault);
        self
    }

    /// Build the facade: `shards` backends over clones of
    /// `per_shard_sys` (one simulated rank group each), sharing a fresh
    /// plan cache.
    pub fn build<T: SpElem>(self, per_shard_sys: PimSystem) -> Result<ShardedService<T>> {
        let cache = Arc::new(PlanCache::with_capacity(self.cache_capacity));
        self.build_with_cache(per_shard_sys, cache)
    }

    /// Build the facade over an externally shared plan cache (several
    /// facades — or a facade plus plain services — then plan equal
    /// content exactly once between them).
    pub fn build_with_cache<T: SpElem>(
        self,
        per_shard_sys: PimSystem,
        cache: Arc<PlanCache<T>>,
    ) -> Result<ShardedService<T>> {
        let recipe = BackendRecipe {
            engine: self.engine,
            queue_depth: self.queue_depth,
            block_policy: self.block_policy,
            calibration: self.calibration.clone(),
        };
        let grid =
            GridSpec { rows: self.shards, cols: self.grid_cols, replicas: self.replicas };
        let mut slots = Vec::with_capacity(grid.slots());
        for _ in 0..grid.slots() {
            let svc = recipe.build(per_shard_sys.clone(), Arc::clone(&cache))?;
            slots.push(RespawnSlot::new(Arc::new(svc)));
        }
        let outstanding = (0..grid.slots()).map(|_| Arc::new(AtomicU64::new(0))).collect();
        let backends = Arc::new(Backends {
            slots,
            grid,
            outstanding,
            pool: Mutex::new(BufferPool::new(T::zero())),
            sys: per_shard_sys,
            recipe,
            cache,
            registry: Mutex::new(HashMap::new()),
            respawns: AtomicU64::new(0),
        });
        let tenants = if self.tenants.is_empty() {
            vec![TenantSpec::new("default", 1)]
        } else {
            self.tenants
        };
        let tenant_names: Vec<Arc<str>> = tenants.iter().map(|t| Arc::clone(&t.name)).collect();
        let fair = FairScheduler::new(tenants)?;

        let completions = Arc::new(Completions::new());
        let sched = Arc::new(Sched {
            state: Mutex::new(SchedState {
                fair,
                paused: self.start_paused,
                shutdown: false,
                log: self.record_schedule.then(ScheduleLog::default),
            }),
            ready: Condvar::new(),
        });
        let (tx, rx) = channel::<GatherItem<T>>();

        let (d_backends, d_sched, d_comp, d_fault) = (
            Arc::clone(&backends),
            Arc::clone(&sched),
            Arc::clone(&completions),
            self.fault.clone(),
        );
        let h_dispatch = spawn_named("spmv-shard-dispatch", move || {
            let _failsafe =
                StageGuard { comp: Arc::clone(&d_comp), stage: "shard dispatch" };
            run_dispatcher(d_backends, d_sched, d_comp, tx, d_fault)
        });
        let (g_backends, g_sched, g_comp, g_fault) = (
            Arc::clone(&backends),
            Arc::clone(&sched),
            Arc::clone(&completions),
            self.fault.clone(),
        );
        let g_timeout = self.wait_timeout;
        let h_gather = spawn_named("spmv-shard-gather", move || {
            let _failsafe =
                StageGuard { comp: Arc::clone(&g_comp), stage: "shard gather" };
            run_gather(g_backends, g_sched, g_comp, rx, g_fault, g_timeout)
        });

        Ok(ShardedService {
            id: NEXT_SHARDED_ID.fetch_add(1, Ordering::Relaxed),
            backends,
            next_handle: AtomicU64::new(1),
            next_ticket: AtomicU64::new(1),
            sync_served: AtomicU64::new(0),
            tenant_names,
            completions,
            sched,
            epoch: Instant::now(),
            wait_timeout: self.wait_timeout,
            max_queue: self.max_queue,
            threads: vec![h_dispatch, h_gather],
        })
    }
}

impl Default for ShardedServiceBuilder {
    fn default() -> ShardedServiceBuilder {
        ShardedServiceBuilder::new()
    }
}

/// A multi-tenant serving facade over `S` supervised shard backends
/// (one [`SpmvService`] per simulated rank group). `Sync`: many host
/// threads may `load` / `submit` / `wait` concurrently; a dispatcher
/// thread orders admissions through the fair scheduler and a gather
/// thread merges per-shard partial responses in dispatch order. A
/// backend that dies is respawned from the shared plan cache and the
/// affected sub-requests re-scattered (see the module docs).
///
/// ```
/// use sparsep::coordinator::{KernelSpec, Request, ShardedServiceBuilder};
/// use sparsep::matrix::generate;
/// use sparsep::pim::PimSystem;
///
/// let svc = ShardedServiceBuilder::new()
///     .shards(3)
///     .build::<f64>(PimSystem::with_dpus(4))
///     .unwrap();
/// let m = generate::uniform::<f64>(60, 60, 4, 7);
/// let h = svc.load(&m, &KernelSpec::csr_nnz()).unwrap();
///
/// // Two tickets in flight, claimed out of submission order; the
/// // gathered outputs match the host oracle exactly.
/// let t1 = svc.submit(h, Request::spmv(vec![1.0; 60])).unwrap();
/// let t2 = svc.submit(h, Request::batch(vec![vec![2.0; 60]; 2])).unwrap();
/// let batch = svc.wait(t2).unwrap().into_batch().unwrap();
/// let run = svc.wait(t1).unwrap().into_spmv().unwrap();
/// assert_eq!(run.y, m.spmv(&vec![1.0; 60]));
/// assert_eq!(batch.runs[1].y, m.spmv(&vec![2.0; 60]));
/// ```
pub struct ShardedService<T: SpElem> {
    id: u64,
    backends: Arc<Backends<T>>,
    next_handle: AtomicU64,
    next_ticket: AtomicU64,
    /// Requests served on the synchronous fast path.
    sync_served: AtomicU64,
    tenant_names: Vec<Arc<str>>,
    completions: Arc<Completions<T>>,
    sched: Arc<Sched<T>>,
    /// Deadlines are measured as durations since this facade's birth
    /// (monotonic, per-facade — never wall-clock).
    epoch: Instant,
    wait_timeout: Option<Duration>,
    max_queue: Option<usize>,
    threads: Vec<JoinHandle<()>>,
}

impl<T: SpElem> ShardedService<T> {
    /// Start configuring a sharded service.
    pub fn builder() -> ShardedServiceBuilder {
        ShardedServiceBuilder::new()
    }

    /// Number of shards — distinct tiles (`rows x cols`), not replica
    /// slots: replicas multiply capacity, never matrix ownership.
    pub fn shard_count(&self) -> usize {
        self.backends.shard_count()
    }

    /// The configured backend topology (see
    /// [`ShardedServiceBuilder::grid`] and
    /// [`ShardedServiceBuilder::replicas`]).
    pub fn grid(&self) -> GridSpec {
        self.backends.grid
    }

    /// The default tenant (always registered first).
    pub fn default_tenant(&self) -> TenantId {
        TenantId(0)
    }

    /// Look a tenant up by name.
    pub fn tenant(&self, name: &str) -> Option<TenantId> {
        self.tenant_names.iter().position(|n| &**n == name).map(TenantId)
    }

    /// Registered tenant names, in registration (scheduling) order.
    pub fn tenant_names(&self) -> &[Arc<str>] {
        &self.tenant_names
    }

    fn check_tenant(&self, tenant: TenantId) -> Result<()> {
        crate::ensure!(
            tenant.index() < self.tenant_names.len(),
            "tenant id {} is not registered with this service",
            tenant.index()
        );
        Ok(())
    }

    /// Register `m` for the default tenant (see [`Self::load_for`]).
    pub fn load(&self, m: &CooMatrix<T>, spec: &KernelSpec) -> Result<ShardedHandle> {
        self.load_for(self.default_tenant(), m, spec)
    }

    /// Register `m` under `spec` for `tenant`: plan the tile grid
    /// ([`plan_shards`] row bands, then per-band column stripes — the
    /// per-row nnz counts are computed once here), load each tile's
    /// slice into every one of its replicas (through the shared plan
    /// cache — replicas of a tile, like equal slices anywhere, plan
    /// once), and pin them behind one facade handle owned by the
    /// tenant. The slices are retained so a dead backend can be
    /// respawned with its tile intact.
    pub fn load_for(
        &self,
        tenant: TenantId,
        m: &CooMatrix<T>,
        spec: &KernelSpec,
    ) -> Result<ShardedHandle> {
        self.check_tenant(tenant)?;
        let grid = self.backends.grid;
        let plan = plan_tiles(m, grid);
        let k = grid.replicas;
        let rollback = |backends: &Backends<T>, handles: Vec<MatrixHandle>| {
            for (idx, h) in handles.into_iter().enumerate() {
                let slot = tile_slot(grid, plan.cols_eff, idx / k, idx % k);
                backends.service(slot).unload(h);
            }
        };
        let mut handles = Vec::with_capacity(plan.slices.len() * k);
        for (t, slice) in plan.slices.iter().enumerate() {
            for r in 0..k {
                let slot = tile_slot(grid, plan.cols_eff, t, r);
                if let Err(e) = self.backends.ensure_alive(slot) {
                    rollback(&self.backends, handles);
                    return Err(e);
                }
                match self.backends.service(slot).load(slice, spec) {
                    Ok(h) => handles.push(h),
                    Err(e) => {
                        // Roll back the tiles/replicas already pinned.
                        rollback(&self.backends, handles);
                        return Err(e);
                    }
                }
            }
        }
        let handle = ShardedHandle {
            svc: self.id,
            id: self.next_handle.fetch_add(1, Ordering::Relaxed),
            nrows: m.nrows(),
            ncols: m.ncols(),
        };
        let entry = Arc::new(ShardEntry {
            handles: Mutex::new(handles),
            slices: plan.slices,
            spec: spec.clone(),
            ranges: plan.ranges,
            col_ranges: plan.col_ranges,
            bands: plan.bands,
            cols_eff: plan.cols_eff,
            nrows: m.nrows(),
            ncols: m.ncols(),
            owner: tenant,
        });
        self.backends
            .registry
            .lock()
            .expect("shard registry poisoned")
            .insert(handle.id, entry);
        Ok(handle)
    }

    /// The row ranges `handle`'s tiles cover, in band-major tile order
    /// (diagnostics and the shard-planning property tests). With one
    /// column stripe this is one range per row band — the legacy
    /// row-shard layout; with `C > 1` each band's range repeats once
    /// per stripe.
    pub fn shard_ranges(&self, handle: &ShardedHandle) -> Result<Vec<Range<usize>>> {
        Ok(self.entry_for(handle)?.ranges.clone())
    }

    /// The `(row_range, col_range)` of each of `handle`'s tiles, in
    /// band-major tile order (the grid property tests assert every
    /// non-zero lands in exactly one tile and each band's column
    /// stripes tile `[0, ncols)`).
    pub fn tile_ranges(
        &self,
        handle: &ShardedHandle,
    ) -> Result<Vec<(Range<usize>, Range<usize>)>> {
        let e = self.entry_for(handle)?;
        Ok(e.ranges.iter().cloned().zip(e.col_ranges.iter().cloned()).collect())
    }

    /// Drop a handle's per-shard plan pins. Returns whether the handle
    /// was loaded. (Plans may stay resident in the shared cache; see
    /// [`Self::unload_tenant`] for reclamation.)
    ///
    /// Unloading races loudly, never silently: requests still queued
    /// behind the scheduler fail at dispatch, and an in-flight
    /// [`Request::Iterate`] whose later iterations re-scatter through
    /// the backend handles fails at its next iteration boundary. (This
    /// is stricter than the unsharded [`SpmvService`], whose pipeline
    /// pins the plan at dispatch — already-dispatched sharded spmv and
    /// batch sub-requests are likewise unaffected.)
    pub fn unload(&self, handle: ShardedHandle) -> bool {
        if handle.svc != self.id {
            return false;
        }
        let entry = self
            .backends
            .registry
            .lock()
            .expect("shard registry poisoned")
            .remove(&handle.id);
        match entry {
            None => false,
            Some(e) => {
                unpin_entry(&self.backends, &e);
                true
            }
        }
    }

    /// Evict everything `tenant` has loaded: drop all its handles'
    /// per-shard plan pins, then reclaim now-unreferenced plans from
    /// the shared cache. Returns `(handles_unloaded, plans_evicted)`.
    /// Requests of the tenant still queued behind the scheduler will
    /// fail at dispatch with an unknown-handle error, and in-flight
    /// iterates at their next iteration boundary (loudly, not
    /// silently; see [`Self::unload`]).
    pub fn unload_tenant(&self, tenant: TenantId) -> Result<(usize, usize)> {
        self.check_tenant(tenant)?;
        let victims: Vec<Arc<ShardEntry<T>>> = {
            let mut reg = self.backends.registry.lock().expect("shard registry poisoned");
            let ids: Vec<u64> = reg
                .iter()
                .filter(|(_, e)| e.owner == tenant)
                .map(|(id, _)| *id)
                .collect();
            ids.into_iter().map(|id| reg.remove(&id).expect("registry id")).collect()
        };
        for e in &victims {
            unpin_entry(&self.backends, e);
        }
        let evicted = self.backends.cache.evict_unreferenced();
        Ok((victims.len(), evicted))
    }

    /// Submit for the default tenant (see [`Self::submit_for`]).
    pub fn submit(&self, handle: ShardedHandle, req: Request<T>) -> Result<ShardedTicket> {
        self.submit_for(self.default_tenant(), handle, req)
    }

    /// Enqueue `req` against `handle` on behalf of `tenant`. Shapes are
    /// validated up front; the request then queues behind the fair
    /// scheduler (weighted round-robin across tenants, per-tenant
    /// in-flight quotas) until the dispatcher scatters it across the
    /// shard backends. Returns immediately with the claim ticket.
    pub fn submit_for(
        &self,
        tenant: TenantId,
        handle: ShardedHandle,
        req: Request<T>,
    ) -> Result<ShardedTicket> {
        self.submit_inner(tenant, handle, req, None)
    }

    /// Like [`Self::submit_for`], but tag the request with a deadline
    /// `deadline` from now. Within a tenant the dispatcher serves the
    /// earliest deadline first (EDF; deadline-less requests sort last),
    /// while cross-tenant weighted round-robin is unaffected. Deadlines
    /// order dispatch — they never cancel work; pair with
    /// [`ShardedServiceBuilder::wait_timeout`] to bound waits.
    pub fn submit_with_deadline(
        &self,
        tenant: TenantId,
        handle: ShardedHandle,
        req: Request<T>,
        deadline: Duration,
    ) -> Result<ShardedTicket> {
        let abs = self.epoch.elapsed().saturating_add(deadline).as_micros() as u64;
        self.submit_inner(tenant, handle, req, Some(abs))
    }

    fn submit_inner(
        &self,
        tenant: TenantId,
        handle: ShardedHandle,
        req: Request<T>,
        deadline: Option<u64>,
    ) -> Result<ShardedTicket> {
        self.check_tenant(tenant)?;
        let entry = self.entry_for(&handle)?;
        let check_len = |x: &[T], what: &str| {
            crate::ensure!(
                x.len() == entry.ncols,
                "{what} length {} != ncols {}",
                x.len(),
                entry.ncols
            );
            Ok(())
        };
        let mut empty_batch = false;
        match &req {
            Request::Spmv { x } => check_len(x, "x")?,
            Request::Batch { xs } => {
                for (i, x) in xs.iter().enumerate() {
                    check_len(x, &format!("xs[{i}]"))?;
                }
                empty_batch = xs.is_empty();
            }
            Request::Iterate { x, iters } => {
                check_len(x, "x")?;
                crate::ensure!(*iters >= 1, "Request::Iterate needs iters >= 1");
                crate::ensure!(
                    *iters == 1 || entry.nrows == entry.ncols,
                    "iterated SpMV needs a square matrix, got {}x{}",
                    entry.nrows,
                    entry.ncols
                );
            }
        }
        let ticket =
            ShardedTicket { svc: self.id, id: self.next_ticket.fetch_add(1, Ordering::Relaxed) };
        self.completions.register(ticket.id);
        if empty_batch {
            // Nothing to scatter: resolve now, skipping the scheduler.
            self.completions
                .publish(ticket.id, Ok(Response::Batch(BatchResult { runs: Vec::new() })));
            return Ok(ticket);
        }
        {
            let mut st = self.sched.lock();
            if st.shutdown {
                // Unreachable in practice (drop takes &mut self), kept
                // as a loud failure instead of a lost ticket.
                self.completions
                    .publish(ticket.id, Err(format_err!("sharded service is shut down")));
                return Ok(ticket);
            }
            if let Some(cap) = self.max_queue {
                if st.fair.queued_for(tenant) >= cap {
                    // Admission control: shed typed, never silently.
                    st.fair.record_shed(tenant);
                    drop(st);
                    self.completions.publish(ticket.id, Ok(Response::Overloaded));
                    return Ok(ticket);
                }
            }
            st.fair.enqueue_with_deadline(
                tenant,
                DispatchJob { ticket: ticket.id, entry, req, submitted: Instant::now() },
                deadline,
            );
        }
        self.sched.ready.notify_all();
        Ok(ticket)
    }

    /// Block until `ticket`'s merged response is ready and claim it.
    /// Tickets complete out of order; waiting twice (or on a foreign
    /// ticket) is an error, not a hang. With a configured
    /// [`ShardedServiceBuilder::wait_timeout`] the block is bounded: on
    /// expiry this returns a typed `ShardTimeout` error and the ticket
    /// survives for a later claim.
    pub fn wait(&self, ticket: ShardedTicket) -> Result<Response<T>> {
        crate::ensure!(ticket.svc == self.id, "ticket belongs to a different service");
        match self.wait_timeout {
            None => self.completions.wait(ticket.id),
            Some(d) => self.completions.wait_timeout(ticket.id, d),
        }
    }

    /// Like [`Self::wait`], with an explicit bound overriding the
    /// configured default. On expiry the error is a typed
    /// `ShardTimeout` and the ticket survives — retrying is safe.
    pub fn wait_timeout(&self, ticket: ShardedTicket, timeout: Duration) -> Result<Response<T>> {
        crate::ensure!(ticket.svc == self.id, "ticket belongs to a different service");
        self.completions.wait_timeout(ticket.id, timeout)
    }

    /// Non-blocking poll: like [`SpmvService::try_wait`], for sharded
    /// tickets.
    pub fn try_wait(&self, ticket: ShardedTicket) -> Result<Option<Response<T>>> {
        crate::ensure!(ticket.svc == self.id, "ticket belongs to a different service");
        self.completions.try_claim(ticket.id)
    }

    /// Completion-dispatch wait: claim *whichever* submitted request
    /// completes next, blocking at most `timeout` (`None` on expiry).
    /// `publish` wakes this directly, so one thread can drain every
    /// ticket's completion the moment it lands — no per-ticket poll
    /// loops. Intended for front ends (e.g. [`crate::net::Server`])
    /// that own the facade exclusively: mixing `wait_next` with
    /// concurrent per-ticket [`Self::wait`] calls on the same facade
    /// is a logic error (either side could claim the other's
    /// response).
    pub fn wait_next(&self, timeout: Duration) -> Option<(ShardedTicket, Result<Response<T>>)> {
        self.completions
            .claim_next_timeout(timeout)
            .map(|(id, resp)| (ShardedTicket { svc: self.id, id }, resp))
    }

    /// One SpMV on the caller's thread — the synchronous fast path
    /// (bypasses the scheduler — and hence deadlines, admission control
    /// and the fault injector — like [`SpmvService::spmv`] bypasses the
    /// request queue). Sub-requests still pipeline across all shards
    /// concurrently. Bit-identical to `wait(submit(Request::Spmv))`.
    pub fn spmv(&self, handle: &ShardedHandle, x: &[T]) -> Result<RunResult<T>> {
        let entry = self.entry_for(handle)?;
        crate::ensure!(x.len() == entry.ncols, "x length {} != ncols {}", x.len(), entry.ncols);
        self.sync_served.fetch_add(1, Ordering::Relaxed);
        // One wrap; the scatter below shares it across all shards
        // (column stripes slice their own segment out).
        let x: Arc<[T]> = Arc::from(x);
        let subs = submit_spmv_all(&self.backends, &entry, &x)?;
        let parts = wait_all_spmv(subs, self.wait_timeout)?;
        Ok(merge_grid_runs(&entry, parts, &self.backends.pool))
    }

    /// One batched request on the caller's thread (synchronous fast
    /// path; see [`Self::spmv`]).
    pub fn spmv_batch(&self, handle: &ShardedHandle, xs: &[Vec<T>]) -> Result<BatchResult<T>> {
        let entry = self.entry_for(handle)?;
        for (i, x) in xs.iter().enumerate() {
            crate::ensure!(
                x.len() == entry.ncols,
                "xs[{i}] length {} != ncols {}",
                x.len(),
                entry.ncols
            );
        }
        self.sync_served.fetch_add(1, Ordering::Relaxed);
        if xs.is_empty() {
            return Ok(BatchResult { runs: Vec::new() });
        }
        // One wrap per vector; the scatter shares them across shards.
        let xs: Vec<Arc<[T]>> = xs.iter().map(|v| Arc::from(&v[..])).collect();
        let subs = submit_batch_all(&self.backends, &entry, &xs)?;
        let parts = wait_all_batch(subs, self.wait_timeout)?;
        Ok(merge_grid_batches(&entry, parts, &self.backends.pool))
    }

    /// One iterated request on the caller's thread (synchronous fast
    /// path; see [`Self::spmv`]). The iterate feedback loop runs across
    /// shards: each iteration gathers the full output and scatters it
    /// back as the next input.
    pub fn iterate(
        &self,
        handle: &ShardedHandle,
        x: &[T],
        iters: usize,
    ) -> Result<IterationsResult<T>> {
        let entry = self.entry_for(handle)?;
        crate::ensure!(x.len() == entry.ncols, "x length {} != ncols {}", x.len(), entry.ncols);
        crate::ensure!(iters >= 1, "iterate needs iters >= 1");
        crate::ensure!(
            iters == 1 || entry.nrows == entry.ncols,
            "iterated SpMV needs a square matrix, got {}x{}",
            entry.nrows,
            entry.ncols
        );
        self.sync_served.fetch_add(1, Ordering::Relaxed);
        let x: Arc<[T]> = Arc::from(x);
        let subs = submit_spmv_all(&self.backends, &entry, &x)?;
        match gather_iterate(&self.backends, &entry, subs, iters, None, self.wait_timeout)? {
            Response::Iterate(it) => Ok(it),
            other => Err(format_err!("internal: iterate gathered a {} response", other.kind())),
        }
    }

    /// Pause dispatching: already-dispatched requests finish, new and
    /// queued ones hold behind the scheduler until [`Self::resume`].
    pub fn pause(&self) {
        self.sched.lock().paused = true;
    }

    /// Resume dispatching (see [`Self::pause`] and
    /// [`ShardedServiceBuilder::start_paused`]).
    pub fn resume(&self) {
        self.sched.lock().paused = false;
        self.sched.ready.notify_all();
    }

    /// The recorded dispatch/completion schedule, if
    /// [`ShardedServiceBuilder::record_schedule`] was enabled.
    pub fn schedule_log(&self) -> Option<ScheduleLog> {
        self.sched.lock().log.clone()
    }

    /// Facade-level counters: scheduled + fast-path requests, the
    /// shared plan-cache traffic, backend respawns, and per-tenant
    /// scheduling counters (with latency quantiles and shed counts).
    pub fn stats(&self) -> ShardedStats {
        let sync = self.sync_served.load(Ordering::Relaxed);
        let tenants = self.sched.lock().fair.stats();
        ShardedStats {
            shards: self.backends.shard_count(),
            grid_rows: self.backends.grid.rows,
            grid_cols: self.backends.grid.cols,
            replicas: self.backends.grid.replicas,
            submitted: self.completions.submitted() + sync,
            completed: self.completions.completed() + sync,
            loaded_handles: self
                .backends
                .registry
                .lock()
                .expect("shard registry poisoned")
                .len(),
            cache_hits: self.backends.cache.hits(),
            cache_misses: self.backends.cache.misses(),
            plan_builds: self.backends.cache.builds(),
            resident_plans: self.backends.cache.len(),
            respawns: self.backends.respawns.load(Ordering::Relaxed),
            tenants,
        }
    }

    fn entry_for(&self, handle: &ShardedHandle) -> Result<Arc<ShardEntry<T>>> {
        crate::ensure!(
            handle.svc == self.id,
            "matrix handle belongs to a different service"
        );
        self.backends
            .registry
            .lock()
            .expect("shard registry poisoned")
            .get(&handle.id)
            .cloned()
            .ok_or_else(|| format_err!("unknown matrix handle (already unloaded?)"))
    }
}

impl<T: SpElem> Drop for ShardedService<T> {
    fn drop(&mut self) {
        self.sched.lock().shutdown = true;
        self.sched.ready.notify_all();
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
        // Requests still queued behind the scheduler never dispatched:
        // fail their tickets loudly so a late `wait` errors instead of
        // hanging. (Dispatched requests were drained by the gather
        // thread before it exited.)
        let queued = self.sched.lock().fair.drain_queued();
        for (_, job) in queued {
            self.completions.publish(
                job.ticket,
                Err(format_err!("sharded service shut down before this request was dispatched")),
            );
        }
        self.completions.fail_all_unanswered("sharded service shut down");
    }
}

/// Drop an entry's per-tile-per-replica plan pins. Clones the handle
/// list out so the entry's handles lock is released before the slot
/// reads (lock order: slot → registry → handles, never backwards).
fn unpin_entry<T: SpElem>(b: &Backends<T>, e: &ShardEntry<T>) {
    let handles: Vec<MatrixHandle> =
        e.handles.lock().expect("shard entry handles poisoned").clone();
    let k = b.grid.replicas;
    for (idx, h) in handles.into_iter().enumerate() {
        let slot = tile_slot(b.grid, e.cols_eff, idx / k, idx % k);
        b.service(slot).unload(h);
    }
}

/// Dispatcher: pull admissions from the fair scheduler in WRR order
/// (EDF within a tenant) and scatter each request's sub-requests across
/// the shard backends. A single thread, so every shard's intake sees
/// facade requests in the same (dispatch) order. Dispatch-time faults
/// fire here, *before* the scatter — a killed shard is respawned by the
/// scatter itself.
fn run_dispatcher<T: SpElem>(
    backends: Arc<Backends<T>>,
    sched: Arc<Sched<T>>,
    comp: Arc<Completions<T>>,
    tx: Sender<GatherItem<T>>,
    fault: Option<Arc<dyn FaultInjector>>,
) {
    loop {
        let (tenant, job) = {
            let mut st = sched.lock();
            loop {
                if st.shutdown {
                    return;
                }
                let popped = if st.paused { None } else { st.fair.pop() };
                if let Some((tenant, job)) = popped {
                    if let Some(log) = st.log.as_mut() {
                        log.dispatched.push(tenant);
                        log.dispatched_tickets.push(job.ticket);
                    }
                    break (tenant, job);
                }
                st = sched.ready.wait(st).expect("sharded scheduler poisoned");
            }
        };
        let DispatchJob { ticket, entry, req, submitted } = job;
        if let Some(f) = &fault {
            for flt in f.at_dispatch(ticket) {
                match flt {
                    Fault::KillShard { shard } => backends.kill(shard),
                    Fault::Delay { millis } => {
                        std::thread::sleep(Duration::from_millis(millis))
                    }
                    // Completion faults act at gather time; at dispatch
                    // they are no-ops.
                    Fault::DropCompletion { .. } | Fault::StallShard { .. } => {}
                }
            }
        }
        let scattered = match req {
            Request::Spmv { x } => submit_spmv_all(&backends, &entry, &x)
                .map(|subs| (GatherKind::Spmv, subs, 1, ScatterPayload::Spmv(x))),
            Request::Batch { xs } => submit_batch_all(&backends, &entry, &xs)
                .map(|subs| (GatherKind::Batch, subs, 1, ScatterPayload::Batch(xs))),
            Request::Iterate { x, iters } => submit_spmv_all(&backends, &entry, &x)
                .map(|subs| (GatherKind::Iterate, subs, iters, ScatterPayload::Spmv(x))),
        };
        match scattered {
            Ok((kind, subs, iters, payload)) => {
                let item =
                    GatherItem { ticket, tenant, entry, kind, subs, iters, payload, submitted };
                if let Err(e) = tx.send(item) {
                    // Gather thread is gone (shutdown / panic): claim
                    // the orphaned sub-responses and fail the ticket.
                    let item = e.0;
                    abort_subs(item.subs);
                    comp.publish(
                        item.ticket,
                        Err(format_err!("sharded gather stage is down")),
                    );
                    sched.complete(tenant, elapsed_us(item.submitted));
                }
            }
            Err(e) => {
                // Scatter failed (e.g. the handle was evicted while the
                // request sat in the scheduler queue).
                comp.publish(ticket, Err(e));
                sched.complete(tenant, elapsed_us(submitted));
            }
        }
    }
}

/// Gather-time faults of one facade request, regrouped per shard for
/// the recovery walk.
#[derive(Debug, Default)]
struct Recovery {
    kill: HashSet<usize>,
    dropped: HashSet<usize>,
    stall: HashSet<usize>,
    delay_ms: u64,
}

impl Recovery {
    fn from_faults(faults: &[Fault]) -> Recovery {
        let mut r = Recovery::default();
        for f in faults {
            match *f {
                Fault::KillShard { shard } => {
                    r.kill.insert(shard);
                }
                Fault::DropCompletion { shard } => {
                    r.dropped.insert(shard);
                }
                Fault::StallShard { shard } => {
                    r.stall.insert(shard);
                }
                Fault::Delay { millis } => r.delay_ms += millis,
            }
        }
        r
    }
}

/// A gather item parked behind a stalled shard: instead of sleeping out
/// the stall bound inline (which would head-of-line-block every other
/// ticket's completion on the single gather thread), the item waits
/// here with an absolute deadline while the gather loop keeps draining
/// the channel. [`fail_parked`] expires it with the typed
/// `ShardTimeout` once the bound elapses.
struct Parked<T: SpElem> {
    deadline: Instant,
    /// The configured bound (for the error message).
    bound: Duration,
    /// The stalled shard the timeout names (lowest stalled shard index,
    /// matching the former in-shard-order walk).
    shard: usize,
    ticket: u64,
    tenant: TenantId,
    subs: Vec<SubTicket<T>>,
    submitted: Instant,
}

/// Gather: wait each dispatched request's sub-tickets (FIFO in dispatch
/// order), merge the per-shard partials, drive iterate feedback, and
/// publish the response. Gather-time faults fire per item: kills are
/// recovered by re-scattering the lost sub-requests from the retained
/// payload, drops by re-executing, stalls by parking the item behind a
/// deadline ([`Parked`]) so a single wedged shard cannot
/// head-of-line-block completions for healthy tickets.
fn run_gather<T: SpElem>(
    backends: Arc<Backends<T>>,
    sched: Arc<Sched<T>>,
    comp: Arc<Completions<T>>,
    rx: Receiver<GatherItem<T>>,
    fault: Option<Arc<dyn FaultInjector>>,
    timeout: Option<Duration>,
) {
    let mut parked: Vec<Parked<T>> = Vec::new();
    loop {
        // Block for the next item — bounded by the earliest parked
        // deadline so stalled tickets expire even while the channel
        // idles.
        let next = if let Some(wake) = parked.iter().map(|p| p.deadline).min() {
            match wake.checked_duration_since(Instant::now()) {
                // A deadline already passed: sweep before waiting.
                None => None,
                Some(wait) => match rx.recv_timeout(wait) {
                    Ok(item) => Some(item),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break,
                },
            }
        } else {
            match rx.recv() {
                Ok(item) => Some(item),
                Err(_) => break,
            }
        };
        if let Some(item) = next {
            if let Some(p) = gather_one(&backends, &sched, &comp, &fault, timeout, item) {
                parked.push(p);
            }
        }
        let now = Instant::now();
        let mut i = 0;
        while i < parked.len() {
            if parked[i].deadline <= now {
                let p = parked.swap_remove(i);
                fail_parked(&sched, &comp, p);
            } else {
                i += 1;
            }
        }
    }
    // The dispatcher hung up (shutdown): no further completions are
    // coming, so expire the remaining parked items now rather than
    // leaking unanswered tickets.
    for p in parked.drain(..) {
        fail_parked(&sched, &comp, p);
    }
}

/// Process one gather item to completion, or return it parked when a
/// stalled shard must be timed out without blocking the gather thread.
fn gather_one<T: SpElem>(
    backends: &Arc<Backends<T>>,
    sched: &Sched<T>,
    comp: &Completions<T>,
    fault: &Option<Arc<dyn FaultInjector>>,
    timeout: Option<Duration>,
    item: GatherItem<T>,
) -> Option<Parked<T>> {
    let GatherItem { ticket, tenant, entry, kind, subs, iters, payload, submitted } = item;
    let rec = match fault {
        Some(f) => Recovery::from_faults(&f.at_gather(ticket)),
        None => Recovery::default(),
    };
    if rec.delay_ms > 0 {
        std::thread::sleep(Duration::from_millis(rec.delay_ms));
    }
    for &s in &rec.kill {
        backends.kill(s);
    }
    if let Some(d) = timeout {
        // Park instead of sleeping inline. Without a configured timeout
        // a stall is indistinguishable from a slow shard and is
        // ignored, as before.
        if let Some(shard) = subs.iter().map(|s| s.shard).filter(|s| rec.stall.contains(s)).min() {
            drop(payload);
            return Some(Parked {
                deadline: Instant::now() + d,
                bound: d,
                shard,
                ticket,
                tenant,
                subs,
                submitted,
            });
        }
    }
    let resp = match (kind, &payload) {
        (GatherKind::Spmv, ScatterPayload::Spmv(x)) => {
            recover_wait_spmv(backends, &entry, subs, &rec, timeout, x)
                .map(|p| Response::Spmv(merge_grid_runs(&entry, p, &backends.pool)))
        }
        (GatherKind::Batch, ScatterPayload::Batch(xs)) => {
            recover_wait_batch(backends, &entry, subs, &rec, timeout, xs)
                .map(|p| Response::Batch(merge_grid_batches(&entry, p, &backends.pool)))
        }
        (GatherKind::Iterate, ScatterPayload::Spmv(x)) => {
            gather_iterate(backends, &entry, subs, iters, Some((x, &rec)), timeout)
        }
        _ => Err(format_err!("internal: sharded gather payload/kind mismatch")),
    };
    drop(payload);
    sched.complete(tenant, elapsed_us(submitted));
    comp.publish(ticket, resp);
    None
}

/// Expire one parked item: claim-discard its sub-responses (the stall
/// is simulated at gather time only — the backends did the work, so
/// nothing parks forever in a shard's completion store), release the
/// tenant's quota slot, and publish the typed `ShardTimeout`.
fn fail_parked<T: SpElem>(sched: &Sched<T>, comp: &Completions<T>, p: Parked<T>) {
    let Parked { bound, shard, ticket, tenant, subs, submitted, .. } = p;
    abort_subs(subs);
    sched.complete(tenant, elapsed_us(submitted));
    comp.publish(
        ticket,
        Err(Error::shard_timeout(
            Some(shard),
            format!("shard {shard} stalled: no sub-response within {bound:?}"),
        )),
    );
}

/// Submit one sub-request to tile `tile`'s replica `replica`,
/// respawning that backend slot first if it is marked dead. The
/// returned [`SubTicket`] pins the exact service the ticket came from.
fn submit_tile<T: SpElem>(
    b: &Backends<T>,
    entry: &Arc<ShardEntry<T>>,
    tile: usize,
    replica: usize,
    req: Request<T>,
) -> Result<SubTicket<T>> {
    let i = tile_slot(b.grid, entry.cols_eff, tile, replica);
    b.ensure_alive(i)?;
    let slot = b.slots[i].read();
    let h = entry.handles.lock().expect("shard entry handles poisoned")
        [tile * b.grid.replicas + replica];
    let outstanding = OutstandingGuard::acquire(&b.outstanding[i]);
    let t = slot.submit(h, req)?;
    Ok(SubTicket {
        svc: Arc::clone(&*slot),
        ticket: t,
        shard: i,
        tile,
        _outstanding: outstanding,
    })
}

/// The replica a read dispatches to: the one with the fewest
/// outstanding sub-requests, lowest index on ties ([`least_outstanding`]).
/// Unreplicated tiles skip the counter reads entirely.
fn pick_replica<T: SpElem>(b: &Backends<T>, entry: &ShardEntry<T>, tile: usize) -> usize {
    let k = b.grid.replicas;
    if k <= 1 {
        return 0;
    }
    let loads: Vec<u64> = (0..k)
        .map(|r| {
            b.outstanding[tile_slot(b.grid, entry.cols_eff, tile, r)].load(Ordering::Relaxed)
        })
        .collect();
    least_outstanding(&loads)
}

/// The `x` segment tile `tile` reads. Row-only layouts (one column
/// stripe) share the caller's `Arc` untouched — the zero-copy scatter
/// `tests/zero_copy.rs` locks in; column stripes slice their own
/// segment out (one copy of `x` total across a band, same bytes the
/// row-only broadcast would have shipped).
fn tile_input<T: SpElem>(entry: &ShardEntry<T>, tile: usize, x: &Arc<[T]>) -> Arc<[T]> {
    if entry.cols_eff <= 1 {
        Arc::clone(x)
    } else {
        Arc::from(&x[entry.col_ranges[tile].clone()])
    }
}

/// Scatter one SpMV across the tile grid in band-major (reduction)
/// order: each tile's chosen replica computes a partial output over its
/// row band from its column stripe's `x` segment.
///
/// With one column stripe the payload is the caller's `Arc<[T]>`: all
/// `S` sub-requests share one allocation (S reference-count bumps),
/// where this scatter used to memcpy the vector once per shard — the
/// O(S x payload) copy the ROADMAP called out.
fn submit_spmv_all<T: SpElem>(
    b: &Backends<T>,
    entry: &Arc<ShardEntry<T>>,
    x: &Arc<[T]>,
) -> Result<Vec<SubTicket<T>>> {
    let n = entry.slices.len();
    let mut subs = Vec::with_capacity(n);
    for t in 0..n {
        let req = Request::Spmv { x: tile_input(entry, t, x) };
        match submit_tile(b, entry, t, pick_replica(b, entry, t), req) {
            Ok(s) => subs.push(s),
            Err(e) => {
                abort_subs(subs);
                return Err(e);
            }
        }
    }
    Ok(subs)
}

/// Scatter one batch: every tile serves the whole vector set against
/// its row band / column stripe. Like [`submit_spmv_all`], the
/// per-vector `Arc`s are shared across row-only shards, never copied.
fn submit_batch_all<T: SpElem>(
    b: &Backends<T>,
    entry: &Arc<ShardEntry<T>>,
    xs: &[Arc<[T]>],
) -> Result<Vec<SubTicket<T>>> {
    let n = entry.slices.len();
    let mut subs = Vec::with_capacity(n);
    for t in 0..n {
        let txs: Vec<Arc<[T]>> = xs.iter().map(|x| tile_input(entry, t, x)).collect();
        match submit_tile(b, entry, t, pick_replica(b, entry, t), Request::Batch { xs: txs }) {
            Ok(s) => subs.push(s),
            Err(e) => {
                abort_subs(subs);
                return Err(e);
            }
        }
    }
    Ok(subs)
}

/// A scatter failed part-way: claim the sub-responses already in flight
/// so nothing parks forever in a shard's completion store.
fn abort_subs<T: SpElem>(subs: Vec<SubTicket<T>>) {
    for s in subs {
        let _ = s.svc.wait(s.ticket);
    }
}

/// Wait one sub-ticket, bounded by `timeout` when configured. A
/// sub-level timeout is re-wrapped to name the shard that wedged.
fn wait_sub<T: SpElem>(sub: &SubTicket<T>, timeout: Option<Duration>) -> Result<Response<T>> {
    match timeout {
        None => sub.svc.wait(sub.ticket),
        Some(d) => sub.svc.wait_timeout(sub.ticket, d).map_err(|e| {
            if e.is_shard_timeout() {
                Error::shard_timeout(Some(sub.shard), format!("shard {}: {e}", sub.shard))
            } else {
                e
            }
        }),
    }
}

/// Wait one sub-ticket through the fault-recovery state machine:
///
/// * **killed**: the sub-response died with the backend — claim-discard
///   it, re-submit via `mk_req` (the submit respawns the dead backend),
///   and wait the fresh sub-ticket.
/// * **dropped**: the completion was lost in transit — claim-discard
///   and re-execute on the (live) backend.
///
/// Stalls never reach here: [`gather_one`] parks the whole item behind
/// a deadline instead (see [`Parked`]), so the gather thread keeps
/// draining other tickets' completions while the stall bound runs.
///
/// Recovery re-executes deterministic simulated work, so the recovered
/// response is bit-identical to the fault-free one. The re-submit goes
/// to the *same* tile and replica slot the fault named (never re-picks
/// a replica), so seeded chaos replays identically.
fn recover_sub<T: SpElem>(
    b: &Backends<T>,
    entry: &Arc<ShardEntry<T>>,
    sub: SubTicket<T>,
    rec: &Recovery,
    timeout: Option<Duration>,
    mk_req: impl Fn(usize) -> Request<T>,
) -> Result<Response<T>> {
    let i = sub.shard;
    if rec.kill.contains(&i) || rec.dropped.contains(&i) {
        let tile = sub.tile;
        let replica = i % b.grid.replicas;
        let _ = sub.svc.wait(sub.ticket);
        drop(sub);
        let fresh = submit_tile(b, entry, tile, replica, mk_req(tile))?;
        return wait_sub(&fresh, timeout);
    }
    wait_sub(&sub, timeout)
}

/// Wait all sub-SpMVs through fault recovery, in shard order. Every
/// sub-ticket is claimed even when one fails (no parked responses
/// leak); the first error wins.
fn recover_wait_spmv<T: SpElem>(
    b: &Backends<T>,
    entry: &Arc<ShardEntry<T>>,
    subs: Vec<SubTicket<T>>,
    rec: &Recovery,
    timeout: Option<Duration>,
    x: &Arc<[T]>,
) -> Result<Vec<RunResult<T>>> {
    let mut out = Vec::with_capacity(subs.len());
    let mut err = None;
    for sub in subs {
        let waited = recover_sub(b, entry, sub, rec, timeout, |t| Request::Spmv {
            x: tile_input(entry, t, x),
        });
        match waited.and_then(Response::into_spmv) {
            Ok(r) => out.push(r),
            Err(e) => err = err.or(Some(e)),
        }
    }
    match err {
        None => Ok(out),
        Some(e) => Err(e),
    }
}

/// Wait all sub-batches through fault recovery, in shard order (see
/// [`recover_wait_spmv`]).
fn recover_wait_batch<T: SpElem>(
    b: &Backends<T>,
    entry: &Arc<ShardEntry<T>>,
    subs: Vec<SubTicket<T>>,
    rec: &Recovery,
    timeout: Option<Duration>,
    xs: &[Arc<[T]>],
) -> Result<Vec<BatchResult<T>>> {
    let mut out = Vec::with_capacity(subs.len());
    let mut err = None;
    for sub in subs {
        let waited = recover_sub(b, entry, sub, rec, timeout, |t| Request::Batch {
            xs: xs.iter().map(|x| tile_input(entry, t, x)).collect(),
        });
        match waited.and_then(Response::into_batch) {
            Ok(r) => out.push(r),
            Err(e) => err = err.or(Some(e)),
        }
    }
    match err {
        None => Ok(out),
        Some(e) => Err(e),
    }
}

/// Wait all sub-SpMVs, in shard order, without fault recovery (the
/// fast paths and iterate's later waves). Every sub-ticket is claimed
/// even when one fails; the first error wins.
fn wait_all_spmv<T: SpElem>(
    subs: Vec<SubTicket<T>>,
    timeout: Option<Duration>,
) -> Result<Vec<RunResult<T>>> {
    let mut out = Vec::with_capacity(subs.len());
    let mut err = None;
    for sub in subs {
        match wait_sub(&sub, timeout).and_then(Response::into_spmv) {
            Ok(r) => out.push(r),
            Err(e) => err = err.or(Some(e)),
        }
    }
    match err {
        None => Ok(out),
        Some(e) => Err(e),
    }
}

/// Wait all sub-batches, in shard order (see [`wait_all_spmv`]).
fn wait_all_batch<T: SpElem>(
    subs: Vec<SubTicket<T>>,
    timeout: Option<Duration>,
) -> Result<Vec<BatchResult<T>>> {
    let mut out = Vec::with_capacity(subs.len());
    let mut err = None;
    for sub in subs {
        match wait_sub(&sub, timeout).and_then(Response::into_batch) {
            Ok(b) => out.push(b),
            Err(e) => err = err.or(Some(e)),
        }
    }
    match err {
        None => Ok(out),
        Some(e) => Err(e),
    }
}

/// The iterate feedback loop across shards: wait the current wave,
/// merge, accumulate totals like the single-service accumulator
/// (breakdown then energy, in iteration order), and scatter the merged
/// output as the next iteration's input. `first_wave` carries the
/// original input and the gather-time faults: recovery applies to the
/// first wave only (later waves were scattered after the faults fired).
fn gather_iterate<T: SpElem>(
    b: &Backends<T>,
    entry: &Arc<ShardEntry<T>>,
    mut subs: Vec<SubTicket<T>>,
    iters: usize,
    first_wave: Option<(&Arc<[T]>, &Recovery)>,
    timeout: Option<Duration>,
) -> Result<Response<T>> {
    let mut total = Breakdown::default();
    let mut energy = Energy::default();
    let mut last: Option<RunResult<T>> = None;
    for iter in 0..iters {
        let wave = std::mem::take(&mut subs);
        let parts = match (iter, first_wave) {
            (0, Some((x, rec))) => recover_wait_spmv(b, entry, wave, rec, timeout, x)?,
            _ => wait_all_spmv(wave, timeout)?,
        };
        let merged = merge_grid_runs(entry, parts, &b.pool);
        total.accumulate(&merged.breakdown);
        energy = energy.add(merged.energy);
        if iter + 1 < iters {
            // Re-wrap the reduced output once per iteration; the
            // scatter re-slices per column stripe (or shares the one
            // allocation across row-only shards).
            let next: Arc<[T]> = Arc::from(&merged.y[..]);
            subs = submit_spmv_all(b, entry, &next)?;
        }
        last = Some(merged);
    }
    Ok(Response::Iterate(IterationsResult {
        last: last.expect("iters >= 1 was validated at submit"),
        total,
        energy,
        iters,
    }))
}

/// Fold `p`'s metrics into `merged` (the one fold rule everywhere —
/// across a band's column tiles exactly as across bands): per-phase
/// times, matrix placement, DPU imbalance and kernel cycles take the
/// max across the concurrently-operating rank groups (critical path);
/// bus bytes, DPU count, nnz and energy sum. Returns `p`'s output
/// vector for the caller to concatenate, reduce or recycle. Folding
/// one part is the identity — `S = 1` degenerates bit-exactly to the
/// plain service.
fn fold_run_metrics<T: SpElem>(merged: &mut RunResult<T>, p: RunResult<T>) -> Vec<T> {
    let b = &mut merged.breakdown;
    b.load_s = b.load_s.max(p.breakdown.load_s);
    b.kernel_s = b.kernel_s.max(p.breakdown.kernel_s);
    b.retrieve_s = b.retrieve_s.max(p.breakdown.retrieve_s);
    b.merge_s = b.merge_s.max(p.breakdown.merge_s);
    let s = &mut merged.stats;
    s.dpu_imbalance = s.dpu_imbalance.max(p.stats.dpu_imbalance);
    s.kernel_cycles = s.kernel_cycles.max(p.stats.kernel_cycles);
    s.bus_bytes_moved += p.stats.bus_bytes_moved;
    s.bus_bytes_payload += p.stats.bus_bytes_payload;
    s.matrix_load_s = s.matrix_load_s.max(p.stats.matrix_load_s);
    s.n_dpus += p.stats.n_dpus;
    s.nnz += p.stats.nnz;
    merged.energy = merged.energy.add(p.energy);
    p.y
}

/// Merge per-shard [`RunResult`]s (in shard/band order): outputs
/// concatenate, metrics fold ([`fold_run_metrics`]).
fn merge_shard_runs<T: SpElem>(parts: Vec<RunResult<T>>) -> RunResult<T> {
    let mut it = parts.into_iter();
    let mut merged = it.next().expect("at least one shard result");
    for p in it {
        let y = fold_run_metrics(&mut merged, p);
        merged.y.extend(y);
    }
    merged
}

/// Reduce one row band's column partials: sum element-wise into a
/// pooled zeroed accumulator, folding in **fixed ascending-column
/// order** — the parts arrive pre-ordered (the scatter is band-major
/// and the waits preserve it), pass through a [`ReduceSlot`] (the
/// exactly-once / index-order rendezvous the loom suite checks), and
/// fold left-to-right from `+0.0`. The reduction tree is a function of
/// grid coordinates, never completion timing, so outputs are
/// bit-reproducible run to run. Consumed partial buffers recycle
/// through the facade's [`BufferPool`].
fn reduce_band<T: SpElem>(parts: Vec<RunResult<T>>, pool: &Mutex<BufferPool<T>>) -> RunResult<T> {
    debug_assert!(!parts.is_empty(), "a band reduces at least one partial");
    let slot = ReduceSlot::new(parts.len());
    for (c, p) in parts.into_iter().enumerate() {
        let _fresh = slot.publish(c, p);
        debug_assert!(_fresh, "duplicate partial for column stripe {c}");
    }
    let ordered = slot.wait_all();
    let mut it = ordered.into_iter();
    let mut merged = it.next().expect("at least one column partial");
    let n = merged.y.len();
    let mut pool = pool.lock().expect("partial buffer pool poisoned");
    let mut acc = pool.take_zeroed(n);
    let first = std::mem::take(&mut merged.y);
    for (a, v) in acc.iter_mut().zip(&first) {
        *a = (*a).add(*v);
    }
    pool.put(first);
    for p in it {
        let y = fold_run_metrics(&mut merged, p);
        debug_assert_eq!(y.len(), n, "column partials of one band diverged in length");
        for (a, v) in acc.iter_mut().zip(&y) {
            *a = (*a).add(*v);
        }
        pool.put(y);
    }
    drop(pool);
    merged.y = acc;
    merged
}

/// Merge per-tile [`RunResult`]s (band-major order) into the facade's
/// response: each band's column partials reduce ([`reduce_band`]),
/// reduced bands concatenate exactly like 1D row shards. One column
/// stripe bypasses the reduction entirely — byte-identical to the
/// legacy row-sharded merge, metrics included.
fn merge_grid_runs<T: SpElem>(
    entry: &ShardEntry<T>,
    parts: Vec<RunResult<T>>,
    pool: &Mutex<BufferPool<T>>,
) -> RunResult<T> {
    if entry.cols_eff <= 1 {
        return merge_shard_runs(parts);
    }
    let c = entry.cols_eff;
    debug_assert_eq!(parts.len(), entry.bands * c, "tile parts diverged from the plan");
    let mut it = parts.into_iter();
    let mut bands = Vec::with_capacity(entry.bands);
    loop {
        let band: Vec<RunResult<T>> = it.by_ref().take(c).collect();
        if band.is_empty() {
            break;
        }
        bands.push(reduce_band(band, pool));
    }
    merge_shard_runs(bands)
}

/// Merge per-tile [`BatchResult`]s: vector `v`'s response merges the
/// tiles' `runs[v]` through [`merge_grid_runs`], in input order.
fn merge_grid_batches<T: SpElem>(
    entry: &ShardEntry<T>,
    parts: Vec<BatchResult<T>>,
    pool: &Mutex<BufferPool<T>>,
) -> BatchResult<T> {
    let nvec = parts.first().map_or(0, |b| b.len());
    debug_assert!(parts.iter().all(|b| b.len() == nvec), "shard batch sizes diverged");
    let mut per_tile: Vec<std::vec::IntoIter<RunResult<T>>> =
        parts.into_iter().map(|b| b.runs.into_iter()).collect();
    let mut runs = Vec::with_capacity(nvec);
    for _ in 0..nvec {
        let vparts: Vec<RunResult<T>> = per_tile
            .iter_mut()
            .map(|it| it.next().expect("shard batch returned too few runs"))
            .collect();
        runs.push(merge_grid_runs(entry, vparts, pool));
    }
    BatchResult { runs }
}

#[cfg(test)]
mod tests {
    use super::super::fault::FaultPlan;
    use super::*;
    use crate::matrix::generate;

    fn sharded(shards: usize, dpus: usize) -> ShardedService<f64> {
        ShardedServiceBuilder::new()
            .shards(shards)
            .build(PimSystem::with_dpus(dpus))
            .unwrap()
    }

    #[test]
    fn plan_shards_tiles_rows_without_empties() {
        let m = generate::scale_free::<f64>(157, 157, 6, 0.7, 3);
        for s in [1usize, 2, 3, 5, 8, 157, 500] {
            let ranges = plan_shards(&m, s);
            assert_eq!(ranges.len(), s.min(157), "shards={s}");
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, 157);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "ranges must tile contiguously");
            }
            assert!(ranges.iter().all(|r| !r.is_empty()), "shards={s}: empty range");
        }
    }

    #[test]
    fn plan_shards_handles_degenerate_weight_distributions() {
        // All the weight in the last row used to make split_weighted
        // emit an empty tail chunk; the fixup must still tile.
        let triples: Vec<(u32, u32, f64)> = (0..9).map(|c| (9u32, c, 1.0)).collect();
        let m = CooMatrix::from_triples(10, 10, triples);
        let ranges = plan_shards(&m, 4);
        assert_eq!(ranges.len(), 4);
        assert_eq!(ranges[0].start, 0);
        assert_eq!(ranges.last().unwrap().end, 10);
        assert!(ranges.iter().all(|r| !r.is_empty()));
        // Zero-row matrix: one degenerate shard.
        let empty = CooMatrix::<f64>::zeros(0, 5);
        assert_eq!(plan_shards(&empty, 3), vec![0..0]);
    }

    #[test]
    fn shards_for_matrix_consults_the_calibration_table() {
        use super::super::calibration::{CalibrationEntry, CalibrationTable};
        let m = generate::uniform::<f64>(96, 96, 4, 5);
        let st = MatrixStats::of(&m);
        let table = Arc::new(CalibrationTable::new(vec![CalibrationEntry {
            matrix: "probe".into(),
            class: st.class().into(),
            features: st.feature_vector(),
            batch: 4,
            kernel: "COO.nnz".into(),
            stripes: 0,
            block: 2,
            shards: 3,
            grid_cols: 2,
            replicas: 2,
            wall_s: 1e-3,
            heuristic_wall_s: 2e-3,
        }]));
        // Calibrated: the table's winner sets the full grid shape.
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .calibration(Arc::clone(&table))
            .shards_for_matrix(&m, 4)
            .build(PimSystem::with_dpus(4))
            .unwrap();
        assert_eq!(svc.grid(), GridSpec { rows: 3, cols: 2, replicas: 2 });
        assert_eq!(svc.shard_count(), 6, "3x2 grid = 6 tiles");
        // And serves correctly at that count.
        let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        let x: Vec<f64> = (0..96).map(|i| (i % 7) as f64 - 3.0).collect();
        assert_eq!(svc.spmv(&h, &x).unwrap().y, m.spmv(&x));
        // Without a table the chain is a no-op: configured count stands.
        let plain: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(5)
            .shards_for_matrix(&m, 4)
            .build(PimSystem::with_dpus(4))
            .unwrap();
        assert_eq!(plain.shard_count(), 5);
        // An empty table is a no-op too.
        let empty: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(5)
            .calibration(Arc::new(CalibrationTable::default()))
            .shards_for_matrix(&m, 4)
            .build(PimSystem::with_dpus(4))
            .unwrap();
        assert_eq!(empty.shard_count(), 5);
    }

    #[test]
    fn sharded_spmv_matches_host_oracle() {
        let m = generate::scale_free::<f64>(150, 150, 6, 0.6, 11);
        let x: Vec<f64> = (0..150).map(|i| ((i % 9) as f64) - 4.0).collect();
        for shards in [1usize, 2, 3, 5] {
            let svc = sharded(shards, 8);
            let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
            assert_eq!((h.nrows(), h.ncols()), (150, 150));
            // Fast path and the scheduled path agree with the oracle.
            let fast = svc.spmv(&h, &x).unwrap();
            assert_eq!(fast.y, m.spmv(&x), "shards={shards} fast path");
            let queued = svc
                .wait(svc.submit(h, Request::spmv(x.clone())).unwrap())
                .unwrap()
                .into_spmv()
                .unwrap();
            assert_eq!(queued.y, fast.y, "shards={shards} queued vs fast");
            assert_eq!(queued.breakdown, fast.breakdown);
            assert_eq!(queued.stats, fast.stats);
            assert_eq!(queued.energy, fast.energy);
            assert_eq!(queued.stats.nnz, m.nnz());
            assert_eq!(queued.stats.n_dpus, 8 * svc.shard_count().min(150));
        }
    }

    #[test]
    fn grid_tiles_partition_rows_and_columns() {
        let m = generate::scale_free::<f64>(90, 70, 5, 0.7, 13);
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .grid(3, 2)
            .build(PimSystem::with_dpus(4))
            .unwrap();
        assert_eq!(svc.grid(), GridSpec { rows: 3, cols: 2, replicas: 1 });
        assert_eq!(svc.shard_count(), 6);
        let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        let tiles = svc.tile_ranges(&h).unwrap();
        assert_eq!(tiles.len(), 6);
        // Band-major: a band's stripes share its row range and their
        // column stripes tile [0, ncols) without empties.
        for band in tiles.chunks(2) {
            assert!(band.iter().all(|(r, _)| *r == band[0].0));
            assert_eq!(band[0].1.start, 0);
            assert_eq!(band[1].1.end, 70);
            assert_eq!(band[0].1.end, band[1].1.start);
            assert!(band.iter().all(|(_, c)| !c.is_empty()));
        }
        // And the reduced gather still equals the host oracle.
        let x: Vec<f64> = (0..70).map(|i| (i % 9) as f64 - 4.0).collect();
        assert_eq!(svc.spmv(&h, &x).unwrap().y, m.spmv(&x));
    }

    #[test]
    fn replicated_reads_match_and_share_plans() {
        let m = generate::uniform::<f64>(60, 60, 4, 21);
        let x: Vec<f64> = (0..60).map(|i| (i % 5) as f64 - 2.0).collect();
        let base = sharded(2, 4);
        let hb = base.load(&m, &KernelSpec::coo_nnz()).unwrap();
        let want = base.spmv(&hb, &x).unwrap();
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(2)
            .replicas(3)
            .build(PimSystem::with_dpus(4))
            .unwrap();
        assert_eq!(svc.shard_count(), 2, "replicas multiply capacity, not shards");
        assert_eq!(svc.grid(), GridSpec { rows: 2, cols: 1, replicas: 3 });
        let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        for _ in 0..4 {
            let got = svc.spmv(&h, &x).unwrap();
            assert_eq!(got.y, want.y);
            assert_eq!(got.stats, want.stats, "replica choice never changes metrics");
        }
        // Replicas of a tile load the same slice: the shared cache
        // plans each of the 2 slices once across all 6 replica slots.
        let st = svc.stats();
        assert_eq!(st.resident_plans, 2);
        assert_eq!(st.plan_builds, 2);
        assert_eq!((st.grid_rows, st.grid_cols, st.replicas), (2, 1, 3));
    }

    #[test]
    fn handles_and_tickets_are_facade_scoped() {
        let a = sharded(2, 4);
        let b = sharded(2, 4);
        let m = generate::uniform::<f64>(40, 40, 3, 2);
        let ha = a.load(&m, &KernelSpec::coo_row()).unwrap();
        assert!(b.submit(ha, Request::spmv(vec![0.0; 40])).is_err());
        let ta = a.submit(ha, Request::spmv(vec![0.0; 40])).unwrap();
        assert!(b.wait(ta).is_err());
        assert!(a.wait(ta).is_ok());
        assert!(a.wait(ta).is_err(), "double wait must error");
        assert!(a.unload(ha));
        assert!(!a.unload(ha));
        assert!(a.submit(ha, Request::spmv(vec![0.0; 40])).is_err());
    }

    #[test]
    fn submit_validates_shapes_up_front() {
        let svc = sharded(3, 4);
        let m = generate::uniform::<f64>(48, 48, 4, 5);
        let h = svc.load(&m, &KernelSpec::csr_nnz()).unwrap();
        assert!(svc.submit(h, Request::spmv(vec![0.0; 47])).is_err());
        assert!(svc
            .submit(h, Request::batch(vec![vec![0.0; 48], vec![0.0; 1]]))
            .is_err());
        assert!(svc.submit(h, Request::iterate(vec![0.0; 48], 0)).is_err());
        let rect = generate::uniform::<f64>(32, 48, 3, 5);
        let hr = svc.load(&rect, &KernelSpec::csr_nnz()).unwrap();
        assert!(svc.submit(hr, Request::iterate(vec![0.0; 48], 2)).is_err());
        assert!(svc.submit(hr, Request::iterate(vec![0.0; 48], 1)).is_ok());
        // Unknown tenants are rejected.
        assert!(svc.submit_for(TenantId(7), h, Request::spmv(vec![0.0; 48])).is_err());
        // Empty batches resolve immediately.
        let t = svc.submit(h, Request::Batch { xs: Vec::new() }).unwrap();
        assert!(svc.wait(t).unwrap().into_batch().unwrap().is_empty());
        assert!(svc.spmv_batch(&h, &[]).unwrap().is_empty());
    }

    #[test]
    fn unload_tenant_evicts_handles_and_plans() {
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(2)
            .tenants(vec![TenantSpec::new("a", 1), TenantSpec::new("b", 1)])
            .build(PimSystem::with_dpus(4))
            .unwrap();
        let (ta, tb) = (svc.tenant("a").unwrap(), svc.tenant("b").unwrap());
        let ma = generate::uniform::<f64>(64, 64, 4, 1);
        let mb = generate::uniform::<f64>(64, 64, 4, 2);
        let ha = svc.load_for(ta, &ma, &KernelSpec::coo_row()).unwrap();
        let hb = svc.load_for(tb, &mb, &KernelSpec::coo_row()).unwrap();
        let st = svc.stats();
        assert_eq!(st.loaded_handles, 2);
        assert_eq!(st.resident_plans, 4, "2 matrices x 2 shard slices");
        let (unloaded, evicted) = svc.unload_tenant(ta).unwrap();
        assert_eq!(unloaded, 1);
        assert_eq!(evicted, 2, "tenant a's two shard plans reclaimed");
        assert_eq!(svc.stats().resident_plans, 2);
        // a's handle is gone, b's still serves.
        assert!(svc.submit_for(ta, ha, Request::spmv(vec![0.0; 64])).is_err());
        let x: Vec<f64> = (0..64).map(|i| (i % 5) as f64 - 2.0).collect();
        let r = svc.spmv(&hb, &x).unwrap();
        assert_eq!(r.y, mb.spmv(&x));
        assert!(svc.unload_tenant(TenantId(9)).is_err());
    }

    #[test]
    fn queued_request_fails_loudly_when_its_handle_is_evicted() {
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(2)
            .start_paused(true)
            .build(PimSystem::with_dpus(4))
            .unwrap();
        let m = generate::uniform::<f64>(32, 32, 3, 3);
        let h = svc.load(&m, &KernelSpec::coo_row()).unwrap();
        let t = svc.submit(h, Request::spmv(vec![1.0; 32])).unwrap();
        // Evict while the request is still queued behind the (paused)
        // scheduler, then let it dispatch.
        assert!(svc.unload(h));
        svc.resume();
        assert!(svc.wait(t).is_err(), "dispatch against an evicted handle must fail");
        // The facade stays serviceable.
        let h2 = svc.load(&m, &KernelSpec::coo_row()).unwrap();
        let x = vec![1.0; 32];
        assert_eq!(svc.spmv(&h2, &x).unwrap().y, m.spmv(&x));
    }

    #[test]
    fn drop_with_queued_requests_fails_their_tickets() {
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(2)
            .start_paused(true)
            .build(PimSystem::with_dpus(4))
            .unwrap();
        let m = generate::uniform::<f64>(24, 24, 3, 4);
        let h = svc.load(&m, &KernelSpec::coo_row()).unwrap();
        let _t = svc.submit(h, Request::spmv(vec![1.0; 24])).unwrap();
        // Dropping with a queued (never-dispatched) request must not
        // hang; the ticket is failed internally.
        drop(svc);
    }

    #[test]
    fn wrr_schedule_is_deterministic_end_to_end() {
        // The satellite's fairness contract, end to end: tenants at
        // weight 1:3 with everything enqueued up front dispatch AND
        // complete in exactly the weighted-round-robin order.
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(2)
            .tenants(vec![TenantSpec::new("a", 1), TenantSpec::new("b", 3)])
            .start_paused(true)
            .record_schedule(true)
            .build(PimSystem::with_dpus(4))
            .unwrap();
        let (ta, tb) = (svc.tenant("a").unwrap(), svc.tenant("b").unwrap());
        let m = generate::uniform::<f64>(48, 48, 4, 9);
        let ha = svc.load_for(ta, &m, &KernelSpec::coo_nnz()).unwrap();
        let hb = svc.load_for(tb, &m, &KernelSpec::coo_nnz()).unwrap();
        let x: Vec<f64> = (0..48).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut tickets = Vec::new();
        for _ in 0..3 {
            tickets.push(svc.submit_for(ta, ha, Request::spmv(x.clone())).unwrap());
        }
        for _ in 0..9 {
            tickets.push(svc.submit_for(tb, hb, Request::spmv(x.clone())).unwrap());
        }
        svc.resume();
        for t in tickets {
            let r = svc.wait(t).unwrap().into_spmv().unwrap();
            assert_eq!(r.y, m.spmv(&x));
        }
        let log = svc.schedule_log().unwrap();
        let want: Vec<TenantId> =
            (0..3).flat_map(|_| [ta, tb, tb, tb]).collect();
        assert_eq!(log.dispatched, want, "dispatch order must be the WRR schedule");
        assert_eq!(log.completed, want, "completion order must follow dispatch order");
        let st = svc.stats();
        assert_eq!(st.tenants[ta.index()].completed, 3);
        assert_eq!(st.tenants[tb.index()].completed, 9);
        assert_eq!(st.in_flight(), 0);
    }

    #[test]
    fn wait_timeout_turns_a_wedged_wait_into_a_typed_error() {
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(2)
            .start_paused(true)
            .wait_timeout(Duration::from_millis(40))
            .build(PimSystem::with_dpus(4))
            .unwrap();
        let m = generate::uniform::<f64>(40, 40, 3, 8);
        let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        let x: Vec<f64> = (0..40).map(|i| (i % 5) as f64 - 2.0).collect();
        let t = svc.submit(h, Request::spmv(x.clone())).unwrap();
        // The scheduler is paused, so the request cannot complete: the
        // configured wait timeout turns the would-be hang into a typed
        // error instead.
        let err = svc.wait(t).unwrap_err();
        assert!(err.is_shard_timeout(), "want ShardTimeout, got: {err}");
        assert_eq!(err.timed_out_shard(), None, "a facade-level timeout names no shard");
        // The ticket survives the timeout: resume and claim it late.
        svc.resume();
        let run = loop {
            match svc.wait_timeout(t, Duration::from_millis(200)) {
                Ok(r) => break r.into_spmv().unwrap(),
                Err(e) if e.is_shard_timeout() => continue,
                Err(e) => panic!("unexpected error: {e}"),
            }
        };
        assert_eq!(run.y, m.spmv(&x));
    }

    #[test]
    fn killed_backend_respawns_from_the_shared_cache() {
        // Ticket 1's dispatch kills shard 1; the scatter respawns it
        // from the shared plan cache and serves bit-identically.
        let plan = FaultPlan::new(11).on_dispatch(1, Fault::KillShard { shard: 1 });
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(3)
            .fault_injector(Arc::new(plan))
            .build(PimSystem::with_dpus(4))
            .unwrap();
        let m = generate::scale_free::<f64>(90, 90, 5, 0.6, 17);
        let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        let builds_before = svc.stats().plan_builds;
        let x: Vec<f64> = (0..90).map(|i| (i % 7) as f64 - 3.0).collect();
        let t = svc.submit(h, Request::spmv(x.clone())).unwrap();
        let run = svc.wait(t).unwrap().into_spmv().unwrap();
        assert_eq!(run.y, m.spmv(&x), "post-respawn gather must match the oracle");
        let st = svc.stats();
        assert_eq!(st.respawns, 1, "the killed backend respawned exactly once");
        assert_eq!(
            st.plan_builds, builds_before,
            "respawn must re-plan through cache hits, not fresh builds"
        );
        // The facade stays fully serviceable after the recovery.
        assert_eq!(svc.spmv(&h, &x).unwrap().y, m.spmv(&x));
    }

    #[test]
    fn admission_control_sheds_typed_overloads() {
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(2)
            .start_paused(true)
            .max_queue(2)
            .build(PimSystem::with_dpus(4))
            .unwrap();
        let m = generate::uniform::<f64>(32, 32, 3, 5);
        let h = svc.load(&m, &KernelSpec::coo_row()).unwrap();
        let x: Vec<f64> = (0..32).map(|i| (i % 3) as f64).collect();
        let tickets: Vec<ShardedTicket> =
            (0..5).map(|_| svc.submit(h, Request::spmv(x.clone())).unwrap()).collect();
        // The first two fit the queue cap; the other three shed
        // instantly with a typed Overloaded response — no silent drops,
        // no submit errors.
        for t in &tickets[2..] {
            let r = svc.wait_timeout(*t, Duration::from_secs(5)).unwrap();
            assert!(r.is_overloaded(), "over-cap submits must shed typed");
        }
        svc.resume();
        for t in &tickets[..2] {
            let r = svc.wait_timeout(*t, Duration::from_secs(5)).unwrap().into_spmv().unwrap();
            assert_eq!(r.y, m.spmv(&x));
        }
        let st = svc.stats();
        assert_eq!(st.tenants[0].shed, 3);
        assert_eq!(st.tenants[0].completed, 2);
    }

    #[test]
    fn deadline_dispatch_is_edf_within_a_tenant() {
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(2)
            .start_paused(true)
            .record_schedule(true)
            .build(PimSystem::with_dpus(4))
            .unwrap();
        let m = generate::uniform::<f64>(24, 24, 3, 6);
        let h = svc.load(&m, &KernelSpec::coo_row()).unwrap();
        let x = vec![1.0; 24];
        let dt = svc.default_tenant();
        let loose = svc
            .submit_with_deadline(dt, h, Request::spmv(x.clone()), Duration::from_secs(60))
            .unwrap();
        let tight = svc
            .submit_with_deadline(dt, h, Request::spmv(x.clone()), Duration::from_millis(1))
            .unwrap();
        let none = svc.submit(h, Request::spmv(x.clone())).unwrap();
        svc.resume();
        for t in [loose, tight, none] {
            assert_eq!(svc.wait(t).unwrap().into_spmv().unwrap().y, m.spmv(&x));
        }
        let log = svc.schedule_log().unwrap();
        assert_eq!(
            log.dispatched_tickets,
            vec![tight.id(), loose.id(), none.id()],
            "EDF: tightest deadline first, deadline-less last"
        );
    }
}
