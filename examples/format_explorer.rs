//! Format explorer: how the compressed format + balancing scheme
//! interact with the sparsity pattern (the paper's software
//! recommendation #2/#3 in action).
//!
//! For each matrix class the tool prints per-format storage, fill-in,
//! single-DPU kernel time, and the across-DPU picture at 256 DPUs, then
//! derives the "adaptive" choice the paper advocates.

use sparsep::bench_harness::Table;
use sparsep::coordinator::{Engine, KernelSpec, SpmvExecutor};
use sparsep::matrix::{generate, BcsrMatrix, CooMatrix, CsrMatrix, MatrixStats};
use sparsep::pim::PimSystem;

fn explore(name: &str, m: &CooMatrix<f64>) -> sparsep::util::Result<(String, f64)> {
    let stats = MatrixStats::of(m);
    println!(
        "\n== {name}: {}x{} nnz={} cv={:.2} ({}) ==",
        stats.nrows,
        stats.ncols,
        stats.nnz,
        stats.nnz_per_row_cv,
        stats.class()
    );

    // Storage footprint per format.
    let csr = CsrMatrix::from_coo(m);
    let b44 = BcsrMatrix::from_coo(m, 4, 4);
    let b88 = BcsrMatrix::from_coo(m, 8, 8);
    let mut t = Table::new(&["format", "bytes", "fill-in"]);
    t.row(&["CSR".into(), csr.size_bytes().to_string(), "1.00".into()]);
    t.row(&["COO".into(), m.size_bytes().to_string(), "1.00".into()]);
    t.row(&["BCSR 4x4".into(), b44.size_bytes().to_string(), format!("{:.2}", b44.fill_ratio())]);
    t.row(&["BCSR 8x8".into(), b88.size_bytes().to_string(), format!("{:.2}", b88.fill_ratio())]);
    t.print();

    // End-to-end at 256 DPUs across kernel families (plan + execute;
    // threaded engine for wall-clock, results identical to serial).
    let exec = SpmvExecutor::with_engine(PimSystem::with_dpus(256), Engine::threaded(0));
    let x = vec![1.0f64; m.ncols()];
    let mut t = Table::new(&["kernel", "kernel-ms", "total-ms", "imbalance"]);
    let mut best = (String::new(), f64::INFINITY);
    for spec in KernelSpec::all25(8) {
        let plan = exec.plan(&spec, m)?;
        let r = plan.execute(&exec, &x)?;
        assert_eq!(r.y, m.spmv(&x), "{} must be exact", spec.name);
        let total = r.breakdown.total_s();
        t.row(&[
            spec.name.clone(),
            format!("{:.3}", r.breakdown.kernel_s * 1e3),
            format!("{:.3}", total * 1e3),
            format!("{:.2}x", r.stats.dpu_imbalance),
        ]);
        if total < best.1 {
            best = (spec.name.clone(), total);
        }
    }
    t.print();
    println!("--> best for {name}: {} ({:.3} ms)", best.0, best.1 * 1e3);
    Ok(best)
}

fn main() -> sparsep::util::Result<()> {
    let cases: Vec<(&str, CooMatrix<f64>)> = vec![
        ("banded (regular)", generate::banded(4096, 16, 3)),
        ("block-structured", generate::blocked(512, 512, 4, 5, 3)),
        ("scale-free", generate::scale_free(4096, 4096, 10, 0.7, 3)),
    ];
    let mut winners = Vec::new();
    for (name, m) in &cases {
        winners.push((name.to_string(), explore(name, m)?));
    }
    println!("\n== adaptive-selection summary (paper recommendation #3) ==");
    for (name, (kernel, t)) in &winners {
        println!("  {name:<18} -> {kernel} ({:.3} ms)", t * 1e3);
    }
    let distinct: std::collections::HashSet<_> = winners.iter().map(|(_, (k, _))| k).collect();
    if distinct.len() > 1 {
        println!("  (no single kernel wins everywhere — pick per input, as the paper concludes)");
    }
    Ok(())
}
