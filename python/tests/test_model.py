"""L2 correctness: model graphs (kernel composed with surrounding ops)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def _ell_fixture(seed=0, r=256, k=8, n=256):
    rng = np.random.default_rng(seed)
    vals = rng.uniform(-1, 1, size=(r, k)).astype(np.float32)
    cols = rng.integers(0, n, size=(r, k)).astype(np.int32)
    x = rng.uniform(-1, 1, size=(n,)).astype(np.float32)
    return vals, cols, x


def test_spmv_ell_tuple_shape():
    vals, cols, x = _ell_fixture()
    (y,) = model.spmv_ell(vals, cols, x)
    assert y.shape == (256,)
    want = ref.ell_spmv_ref(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-6)


def test_spmv_dense_matches_numpy():
    rng = np.random.default_rng(1)
    a = rng.uniform(size=(64, 64)).astype(np.float32)
    x = rng.uniform(size=64).astype(np.float32)
    (y,) = model.spmv_dense(a, x)
    np.testing.assert_allclose(np.asarray(y), a @ x, rtol=1e-5)


def test_power_iteration_step_normalizes():
    vals, cols, x = _ell_fixture(seed=2)
    (y,) = model.power_iteration_step(vals, cols, x)
    assert y.shape == (256,)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)), 1.0, rtol=1e-4)


def test_cg_residual_matches_manual():
    vals, cols, x = _ell_fixture(seed=3)
    b = np.random.default_rng(4).uniform(size=256).astype(np.float32)
    r_vec, r_norm2 = model.cg_residual_step(vals, cols, x, b)
    want = b - np.asarray(ref.ell_spmv_ref(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x)))
    np.testing.assert_allclose(np.asarray(r_vec), want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(r_norm2), float((want * want).sum()), rtol=1e-4)
