#!/usr/bin/env bash
# Perf smokes, emitted as JSON at the repo root so successive PRs can
# track the trajectory:
#
#   BENCH_coordinator.json  50 load-once CG iterations on a 100k x 100k
#                           scale-free SPD system, serial vs threaded
#   BENCH_batch.json        batched (SpMM-style) vs looped single-vector
#                           serving of a vector batch over one plan
#   BENCH_service.json      queued-pipelined SpmvService vs synchronous
#                           execution of a batched request stream,
#                           serial + threaded
#   BENCH_shard.json        the same request stream served by a
#                           ShardedService at 1/2/4/8 shards (rank
#                           groups), serial + threaded
#   BENCH_hotpath.json      hot-path overhaul: persistent pooled engine
#                           vs legacy spawn-per-wave threading vs serial
#                           for spmv/batch/iterate at 1 and 4 shards
#   BENCH_resilience.json   resilience tier: kill-per-request chaos
#                           stream vs fault-free (recovery overhead,
#                           verified bit-identical; the chaos seed is
#                           printed for exact replay) + typed shed rate
#                           and served-latency percentiles under a
#                           per-tenant admission cap
#   BENCH_net.json          TCP front end under open-loop Poisson load
#                           at two offered rates: p50/p99/p999 latency,
#                           achieved throughput, typed shed/error
#                           counts per level (in-process server; point
#                           bench-net --addr at a live one instead)
#   BENCH_grid.json         2D grid sharding: the same batched request
#                           stream served by the row-only S x 1 shape,
#                           every R x C shape at the same backend
#                           budget, and the tuned winner replicated x2
#                           (row-only is candidate zero of the sweep,
#                           so tuned_over_row_serial >= 1.0 by
#                           construction)
#   BENCH_tune.json         autotuner search: calibrated-vs-heuristic
#                           wall-clock per (matrix, batch) cell; also
#                           writes calibration.json, the table
#                           run/serve --calibration loads (fails if any
#                           cell regresses beyond the tolerance)
#
# After the reports are written, `bench-check` compares them against the
# committed baseline of by-construction ratio statistics
# (scripts/bench_baseline.json) and fails the run on any shortfall —
# with --missing fail, since this script produces every report.
#
# Knobs:
#   BENCH_ROWS   (default 100000)   CG matrix dimension
#   BENCH_ITERS  (default 50)       CG iterations
#   BENCH_DPUS   (default 256)      simulated DPU count
#   BENCH_THREADS (default: nproc)  threaded-engine workers
#   BENCH_BATCH_ROWS (default 50000)  batch-bench matrix dimension
#   BENCH_BATCH  (default 32)       batch-bench vector count
#   BENCH_REQUESTS (default 8)      service-bench batched requests
#   BENCH_SERVICE_BATCH (default 16)  vectors per service request
#   BENCH_SHARD_ROWS (default 50000)  shard-bench matrix dimension
#   BENCH_SHARD_BATCH (default 8)   vectors per sharded request
#   BENCH_SHARD_DPUS (default 64)   simulated DPUs per shard
#   BENCH_GRID_ROWS (default 50000) grid-bench matrix dimension
#   BENCH_GRID_SHARDS (default 4)   grid-bench total backends per shape
#   BENCH_HOTPATH_ROWS (default 20000)  hotpath-bench matrix dimension
#   BENCH_HOTPATH_ITERS (default 80)    hotpath iterate depth (waves)
#   BENCH_HOTPATH_BATCH (default 16)    hotpath batch width
#   BENCH_RESILIENCE_ROWS (default 20000)  resilience matrix dimension
#   BENCH_RESILIENCE_SHARDS (default 4)    resilience shard count
#   BENCH_RESILIENCE_CAP (default 4)       per-tenant admission cap
#   BENCH_RESILIENCE_OFFERED (default 16)  offered load (> cap sheds)
#   BENCH_NET_ROWS (default 1500)      net-bench matrix dimension
#   BENCH_NET_CONNS (default 2)        concurrent client connections
#   BENCH_NET_REQUESTS (default 240)   requests per offered-load level
#   BENCH_NET_RATES (default 300,1200) offered rates, req/s per level
set -euo pipefail
cd "$(dirname "$0")/.."

THREADS="${BENCH_THREADS:-$(nproc 2>/dev/null || echo 4)}"

cargo run --release -- bench-coordinator \
  --rows "${BENCH_ROWS:-100000}" \
  --deg 8 \
  --iters "${BENCH_ITERS:-50}" \
  --dpus "${BENCH_DPUS:-256}" \
  --threads "$THREADS" \
  --out BENCH_coordinator.json

cat BENCH_coordinator.json

cargo run --release -- bench-batch \
  --rows "${BENCH_BATCH_ROWS:-50000}" \
  --deg 8 \
  --batch "${BENCH_BATCH:-32}" \
  --dpus "${BENCH_DPUS:-256}" \
  --threads "$THREADS" \
  --out BENCH_batch.json

cat BENCH_batch.json

cargo run --release -- bench-service \
  --rows "${BENCH_BATCH_ROWS:-50000}" \
  --deg 8 \
  --requests "${BENCH_REQUESTS:-8}" \
  --batch "${BENCH_SERVICE_BATCH:-16}" \
  --dpus "${BENCH_DPUS:-256}" \
  --threads "$THREADS" \
  --out BENCH_service.json

cat BENCH_service.json

cargo run --release -- bench-shard \
  --rows "${BENCH_SHARD_ROWS:-50000}" \
  --deg 8 \
  --requests "${BENCH_REQUESTS:-8}" \
  --batch "${BENCH_SHARD_BATCH:-8}" \
  --dpus "${BENCH_SHARD_DPUS:-64}" \
  --threads "$THREADS" \
  --out BENCH_shard.json

cat BENCH_shard.json

cargo run --release -- bench-hotpath \
  --rows "${BENCH_HOTPATH_ROWS:-20000}" \
  --deg 8 \
  --iters "${BENCH_HOTPATH_ITERS:-80}" \
  --batch "${BENCH_HOTPATH_BATCH:-16}" \
  --dpus "${BENCH_DPUS:-256}" \
  --threads "$THREADS" \
  --out BENCH_hotpath.json

cat BENCH_hotpath.json

cargo run --release -- bench-resilience \
  --rows "${BENCH_RESILIENCE_ROWS:-20000}" \
  --deg 8 \
  --requests "${BENCH_REQUESTS:-8}" \
  --shards "${BENCH_RESILIENCE_SHARDS:-4}" \
  --dpus "${BENCH_SHARD_DPUS:-64}" \
  --threads "$THREADS" \
  --max-queue "${BENCH_RESILIENCE_CAP:-4}" \
  --offered "${BENCH_RESILIENCE_OFFERED:-16}" \
  --out BENCH_resilience.json

cat BENCH_resilience.json

cargo run --release -- bench-net \
  --rows "${BENCH_NET_ROWS:-1500}" \
  --deg 6 \
  --shards 2 \
  --dpus 16 \
  --conns "${BENCH_NET_CONNS:-2}" \
  --requests "${BENCH_NET_REQUESTS:-240}" \
  --rates "${BENCH_NET_RATES:-300,1200}" \
  --out BENCH_net.json

cat BENCH_net.json

cargo run --release -- bench-grid \
  --rows "${BENCH_GRID_ROWS:-50000}" \
  --deg 8 \
  --shards "${BENCH_GRID_SHARDS:-4}" \
  --requests "${BENCH_REQUESTS:-8}" \
  --batch "${BENCH_SHARD_BATCH:-8}" \
  --dpus "${BENCH_SHARD_DPUS:-64}" \
  --threads "$THREADS" \
  --out BENCH_grid.json

cat BENCH_grid.json

# --quick = mini-suite smoke search (seconds). BENCH_TUNE_FULL=1 runs
# the paper-scale search instead (minutes).
if [[ "${BENCH_TUNE_FULL:-0}" == "1" ]]; then
  cargo run --release -- tune \
    --dpus "${BENCH_DPUS:-256}" \
    --out calibration.json \
    --report BENCH_tune.json
else
  cargo run --release -- tune --quick \
    --out calibration.json \
    --report BENCH_tune.json
fi

cat BENCH_tune.json

# Every report above exists now, so a missing file is itself a
# regression (a renamed output or a silently skipped bench).
cargo run --release -- bench-check \
  --baseline scripts/bench_baseline.json \
  --missing fail
