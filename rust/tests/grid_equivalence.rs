//! Differential harness for 2D grid sharding and shard replication.
//!
//! The contract extends `tests/shard_equivalence.rs` to the grid axes:
//! the grid shape and the replica count are *performance* knobs — they
//! may change how work is scattered, reduced and dispatched, but never
//! what the facade answers.
//!
//! 1. **Unsharded oracle** — every grid shape in {1×2, 2×2, 3×2, 2×3}
//!    × replicas {1, 2}, on both engines and a square *and* rectangular
//!    matrix, serves the full request mix (queued spmv, batch, iterate,
//!    plus the fast path) with output vectors bit-identical to a single
//!    unsharded `SpmvService`. Column stripes reduce in fixed
//!    ascending-column order and the suite's generator values are
//!    integer-exact, so even the partial-sum regrouping cannot round.
//! 2. **Row-only degeneracy** — an `R×1` grid is *byte-identical*
//!    (breakdown, stats, energy included) to the legacy `.shards(R)`
//!    facade, replicated or not: replication must be invisible in every
//!    response field.
//! 3. **Chaos replay on grid coordinates** — a seeded random fault plan
//!    over all `R*C*K` backend slots replays bit-identically across two
//!    identically-configured facades, and matches the fault-free
//!    reference in full.
//! 4. **Replica loss is free** — killing a replica slot mid-flight
//!    still answers oracle-exact, respawns the slot, and builds zero
//!    new plans (replicas share the tile's cached plan).
//! 5. **Calibrated grids** — `shards_for_matrix` resolves the full
//!    (rows, cols, replicas) shape from a tuner-written table.

use sparsep::coordinator::{
    BatchResult, CalibrationEntry, CalibrationTable, Engine, Fault, FaultPlan, GridSpec,
    IterationsResult, KernelSpec, Request, RunResult, ServiceBuilder, ShardedService,
    ShardedServiceBuilder, SpmvService,
};
use sparsep::matrix::{generate, CooMatrix, MatrixStats};
use sparsep::pim::PimSystem;
use std::sync::Arc;

const N: usize = 96;
const ITERS: usize = 3;
const DPUS_PER_SHARD: usize = 4;
const GRIDS: [(usize, usize); 4] = [(1, 2), (2, 2), (3, 2), (2, 3)];
const REPLICAS: [usize; 2] = [1, 2];

fn square() -> CooMatrix<f64> {
    generate::scale_free::<f64>(N, N, 5, 0.7, 31)
}

/// Rectangular case: column striping must tile `[0, ncols)` even when
/// `ncols != nrows` (iterate is skipped — y cannot re-enter as x).
fn rect() -> CooMatrix<f64> {
    generate::scale_free::<f64>(60, 90, 4, 0.6, 17)
}

fn x_for(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i % 11) as f64) - 5.0).collect()
}

fn batch_for(n: usize) -> Vec<Vec<f64>> {
    (0..3)
        .map(|b| (0..n).map(|i| ((i + 3 * b) % 7) as f64 - 3.0).collect())
        .collect()
}

/// The full request mix one facade serves: queued spmv + batch
/// (+ iterate when the matrix is square), waited out of submission
/// order, plus a fast-path spmv.
struct Mix {
    spmv: RunResult<f64>,
    fast: RunResult<f64>,
    batch: BatchResult<f64>,
    iter: Option<IterationsResult<f64>>,
}

fn serve_mix(svc: &ShardedService<f64>, m: &CooMatrix<f64>, spec: &KernelSpec) -> Mix {
    let iterate = m.nrows() == m.ncols();
    let h = svc.load(m, spec).unwrap();
    let x = x_for(m.ncols());
    let t1 = svc.submit(h, Request::spmv(x.clone())).unwrap();
    let tb = svc.submit(h, Request::batch(batch_for(m.ncols()))).unwrap();
    let ti = iterate.then(|| svc.submit(h, Request::iterate(x.clone(), ITERS)).unwrap());
    let iter = ti.map(|t| svc.wait(t).unwrap().into_iterations().unwrap());
    let batch = svc.wait(tb).unwrap().into_batch().unwrap();
    let spmv = svc.wait(t1).unwrap().into_spmv().unwrap();
    let fast = svc.spmv(&h, &x).unwrap();
    Mix { spmv, fast, batch, iter }
}

fn unsharded_mix(engine: Engine, m: &CooMatrix<f64>, spec: &KernelSpec) -> Mix {
    let svc: SpmvService<f64> = ServiceBuilder::new()
        .engine(engine)
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap();
    let iterate = m.nrows() == m.ncols();
    let h = svc.load(m, spec).unwrap();
    let x = x_for(m.ncols());
    let t1 = svc.submit(h, Request::spmv(x.clone())).unwrap();
    let tb = svc.submit(h, Request::batch(batch_for(m.ncols()))).unwrap();
    let ti = iterate.then(|| svc.submit(h, Request::iterate(x.clone(), ITERS)).unwrap());
    let iter = ti.map(|t| svc.wait(t).unwrap().into_iterations().unwrap());
    let batch = svc.wait(tb).unwrap().into_batch().unwrap();
    let spmv = svc.wait(t1).unwrap().into_spmv().unwrap();
    let fast = svc.spmv(&h, &x).unwrap();
    Mix { spmv, fast, batch, iter }
}

fn assert_runs_identical(a: &RunResult<f64>, b: &RunResult<f64>, tag: &str) {
    assert_eq!(a.y, b.y, "{tag}: output vector differs");
    assert_eq!(a.breakdown, b.breakdown, "{tag}: breakdown differs");
    assert_eq!(a.stats, b.stats, "{tag}: stats differ");
    assert_eq!(a.energy, b.energy, "{tag}: energy differs");
}

/// Byte-identity over the full mix, metrics included.
fn assert_mixes_identical(a: &Mix, b: &Mix, tag: &str) {
    assert_runs_identical(&a.spmv, &b.spmv, &format!("{tag} spmv"));
    assert_runs_identical(&a.fast, &b.fast, &format!("{tag} fast"));
    assert_eq!(a.batch.len(), b.batch.len(), "{tag}: batch size differs");
    for (i, (ra, rb)) in a.batch.runs.iter().zip(&b.batch.runs).enumerate() {
        assert_runs_identical(ra, rb, &format!("{tag} batch vec={i}"));
    }
    assert_eq!(a.iter.is_some(), b.iter.is_some(), "{tag}: iterate presence differs");
    if let (Some(ia), Some(ib)) = (&a.iter, &b.iter) {
        assert_runs_identical(&ia.last, &ib.last, &format!("{tag} iterate last"));
        assert_eq!(ia.total, ib.total, "{tag}: iterate totals differ");
        assert_eq!(ia.energy, ib.energy, "{tag}: iterate energy differs");
        assert_eq!(ia.iters, ib.iters, "{tag}: iterate count differs");
    }
}

/// Output-vector identity only (grids with C > 1 regroup the metric
/// folds across tiles, so only the answers are pinned to the oracle).
fn assert_outputs_match(got: &Mix, oracle: &Mix, tag: &str) {
    assert_eq!(got.spmv.y, oracle.spmv.y, "{tag}: spmv output != unsharded oracle");
    assert_eq!(got.fast.y, oracle.fast.y, "{tag}: fast-path output != unsharded oracle");
    assert_eq!(got.batch.len(), oracle.batch.len(), "{tag}: batch size");
    for (i, (a, b)) in got.batch.runs.iter().zip(&oracle.batch.runs).enumerate() {
        assert_eq!(a.y, b.y, "{tag}: batch vec {i} output != unsharded oracle");
    }
    if let (Some(ia), Some(ib)) = (&got.iter, &oracle.iter) {
        assert_eq!(ia.last.y, ib.last.y, "{tag}: iterate output != unsharded oracle");
        assert_eq!(ia.iters, ib.iters, "{tag}: iterate count");
    }
}

fn gridded(engine: Engine, grid: (usize, usize), replicas: usize) -> ShardedService<f64> {
    ShardedServiceBuilder::new()
        .grid(grid.0, grid.1)
        .replicas(replicas)
        .engine(engine)
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap()
}

/// PROPERTY: every grid shape × replica count × engine × matrix shape
/// answers the full request mix bit-identically to the unsharded
/// single-service oracle, and the merged stats still account for every
/// non-zero exactly once.
#[test]
fn prop_grids_and_replicas_match_the_unsharded_oracle() {
    let spec = KernelSpec::coo_nnz();
    for m in [square(), rect()] {
        for (engine, ename) in [(Engine::Serial, "serial"), (Engine::threaded(2), "threaded")] {
            let oracle = unsharded_mix(engine, &m, &spec);
            for grid in GRIDS {
                for replicas in REPLICAS {
                    let tag = format!(
                        "{}x{} grid={}x{} K={replicas} {ename}",
                        m.nrows(),
                        m.ncols(),
                        grid.0,
                        grid.1
                    );
                    let svc = gridded(engine, grid, replicas);
                    let mix = serve_mix(&svc, &m, &spec);
                    assert_outputs_match(&mix, &oracle, &tag);
                    // Column tiles partition the non-zeros: the summed
                    // per-tile counts cover every entry exactly once.
                    assert_eq!(mix.spmv.stats.nnz, m.nnz(), "{tag}: merged nnz");
                    let st = svc.stats();
                    assert_eq!(
                        (st.grid_rows, st.grid_cols, st.replicas),
                        (grid.0, grid.1, replicas),
                        "{tag}: stats topology"
                    );
                    assert_eq!(st.completed, st.submitted, "{tag}: every ticket resolved");
                }
            }
        }
    }
}

/// An `R×1` grid is the row-sharded facade, byte for byte — and
/// replication never shows up in any response field.
#[test]
fn row_only_grids_are_byte_identical_to_row_sharding() {
    let m = square();
    let spec = KernelSpec::csr_nnz();
    for (engine, ename) in [(Engine::Serial, "serial"), (Engine::threaded(2), "threaded")] {
        for r in [2usize, 3] {
            let legacy: ShardedService<f64> = ShardedServiceBuilder::new()
                .shards(r)
                .engine(engine)
                .build(PimSystem::with_dpus(DPUS_PER_SHARD))
                .unwrap();
            let want = serve_mix(&legacy, &m, &spec);
            let via_grid = serve_mix(&gridded(engine, (r, 1), 1), &m, &spec);
            assert_mixes_identical(&via_grid, &want, &format!("grid {r}x1 {ename}"));
            let replicated = serve_mix(&gridded(engine, (r, 1), 2), &m, &spec);
            assert_mixes_identical(&replicated, &want, &format!("grid {r}x1 K=2 {ename}"));
        }
    }
}

/// Seeded chaos on grid coordinates: a random plan over all
/// `R*C*K = 8` backend slots replays bit-identically across two
/// identically-configured facades and changes nothing observable
/// against the fault-free reference.
#[test]
fn seeded_chaos_replays_identically_on_grid_coordinates() {
    let m = square();
    let spec = KernelSpec::coo_nnz();
    let reference = gridded(Engine::Serial, (2, 2), 2);
    let ref_mixes = [serve_mix(&reference, &m, &spec), serve_mix(&reference, &m, &spec)];
    for seed in [3u64, 0xD1CE_0F8A] {
        // 2 mixes x 3 tickets = 6 tickets; 2x2 grid x2 replicas = 8 slots.
        let plan_a = FaultPlan::random(seed, 6, 8, 0.4);
        let plan_b = FaultPlan::random(seed, 6, 8, 0.4);
        assert_eq!(plan_a, plan_b, "seed={seed:#x}: random grid plan must rebuild identically");
        let mk = |plan: FaultPlan| -> ShardedService<f64> {
            ShardedServiceBuilder::new()
                .grid(2, 2)
                .replicas(2)
                .fault_injector(Arc::new(plan))
                .build(PimSystem::with_dpus(DPUS_PER_SHARD))
                .unwrap()
        };
        let (svc_a, svc_b) = (mk(plan_a), mk(plan_b));
        for round in 0..2 {
            let tag = format!("chaos grid 2x2 K=2 seed={seed:#x} round={round}");
            let a = serve_mix(&svc_a, &m, &spec);
            let b = serve_mix(&svc_b, &m, &spec);
            assert_mixes_identical(&a, &b, &format!("{tag} replay"));
            assert_mixes_identical(&a, &ref_mixes[round], &format!("{tag} vs fault-free"));
        }
        // Respawn *counts* may differ run to run (a killed replica only
        // respawns when some later sub-request or load touches its
        // slot), but every ticket must resolve on both facades.
        for svc in [&svc_a, &svc_b] {
            let st = svc.stats();
            assert_eq!(st.completed, st.submitted, "seed={seed:#x}: unresolved tickets");
        }
    }
}

/// Killing one replica of a tile mid-flight: the surviving topology
/// still answers oracle-exact, the slot respawns, and recovery builds
/// zero new plans — replicas share the tile's cached plan.
#[test]
fn replica_kill_mid_flight_matches_oracle_with_flat_plan_builds() {
    let m = square();
    let spec = KernelSpec::coo_nnz();
    // 2x2 grid, 2 replicas: slot 7 = (band 1, col 1, replica 1).
    let mut plan = FaultPlan::new(0xBADC_AB1E);
    for t in 1..=4u64 {
        plan = plan.on_dispatch(t, Fault::KillShard { shard: 7 });
    }
    let svc: ShardedService<f64> = ShardedServiceBuilder::new()
        .grid(2, 2)
        .replicas(2)
        .fault_injector(Arc::new(plan))
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap();
    let h = svc.load(&m, &spec).unwrap();
    let builds_after_load = svc.stats().plan_builds;
    assert_eq!(builds_after_load, 4, "4 tiles plan once each; replicas share");
    let x = x_for(N);
    let xs = batch_for(N);
    let t1 = svc.submit(h, Request::spmv(x.clone())).unwrap();
    let t2 = svc.submit(h, Request::batch(xs.clone())).unwrap();
    let t3 = svc.submit(h, Request::iterate(x.clone(), ITERS)).unwrap();
    let t4 = svc.submit(h, Request::spmv(x.clone())).unwrap();
    assert_eq!(svc.wait(t1).unwrap().into_spmv().unwrap().y, m.spmv(&x));
    let batch = svc.wait(t2).unwrap().into_batch().unwrap();
    for (v, want) in xs.iter().map(|x| m.spmv(x)).enumerate() {
        assert_eq!(batch.runs[v].y, want, "batch vec {v}");
    }
    let mut it_y = x.clone();
    for _ in 0..ITERS {
        it_y = m.spmv(&it_y);
    }
    assert_eq!(svc.wait(t3).unwrap().into_iterations().unwrap().last.y, it_y);
    assert_eq!(svc.wait(t4).unwrap().into_spmv().unwrap().y, m.spmv(&x));
    // A read only touches the killed slot if least-outstanding picks
    // it, so force the respawn deterministically: a re-load of the same
    // matrix ensure_alives every slot (and is a pure plan-cache hit).
    let _h2 = svc.load(&m, &spec).unwrap();
    let st = svc.stats();
    assert!(st.respawns >= 1, "the killed replica slot must respawn");
    assert_eq!(
        st.plan_builds, builds_after_load,
        "replica recovery must reuse the tile's cached plan, not re-plan"
    );
    assert_eq!(st.completed, st.submitted);
}

/// `--shards auto` end to end: the builder resolves the full
/// (rows, cols, replicas) shape from a calibration entry, and the
/// resolved facade still answers oracle-exact.
#[test]
fn builder_resolves_a_full_grid_from_the_calibration_table() {
    let m = square();
    let st = MatrixStats::of(&m);
    let table = Arc::new(CalibrationTable::new(vec![CalibrationEntry {
        matrix: "probe".into(),
        class: st.class().into(),
        features: st.feature_vector(),
        batch: 1,
        kernel: "COO.nnz".into(),
        stripes: 0,
        block: 2,
        shards: 2,
        grid_cols: 3,
        replicas: 2,
        wall_s: 1e-3,
        heuristic_wall_s: 2e-3,
    }]));
    let svc: ShardedService<f64> = ShardedServiceBuilder::new()
        .calibration(table)
        .shards_for_matrix(&m, 1)
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap();
    assert_eq!(svc.grid(), GridSpec { rows: 2, cols: 3, replicas: 2 });
    assert_eq!(svc.shard_count(), 6, "2x3 grid = 6 tiles");
    let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
    let x = x_for(N);
    assert_eq!(svc.spmv(&h, &x).unwrap().y, m.spmv(&x));
}
