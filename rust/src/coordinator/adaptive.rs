//! Adaptive kernel selection — the paper's software recommendation #3
//! turned into a feature.
//!
//! > "Design adaptive algorithms that (i) trade off computation balance
//! > for lower data transfer costs and (ii) select the load balancing
//! > strategy and data partitioning policy based on the particular
//! > sparsity pattern of the input matrix and the characteristics of
//! > the underlying PIM hardware."
//!
//! Two selectors:
//! * [`select_heuristic`] — O(1) decision rules over [`MatrixStats`] and
//!   the [`PimConfig`], encoding the paper's findings (block structure
//!   -> BCOO; high CV -> element-granularity COO; many DPUs + wide
//!   vector -> 2D; etc.).
//! * [`autotune`] — exhaustive search over the 25 kernels on the actual
//!   executor (ground truth, costs 25 simulated runs).
//!
//! The unit tests check the heuristic agrees with the autotuner's
//! *family* (1D vs 2D, balanced vs not) on the canonical matrix classes.

use super::{KernelSpec, SpmvExecutor};
use crate::matrix::{BcsrMatrix, CooMatrix, Format, MatrixStats, SpElem};
use crate::pim::PimConfig;

/// Why the heuristic picked what it picked (for logs and the CLI).
#[derive(Clone, Debug)]
pub struct Choice {
    pub spec: KernelSpec,
    pub reason: String,
}

/// Rule-based selection from sparsity statistics + hardware shape.
pub fn select_heuristic<T: SpElem>(m: &CooMatrix<T>, cfg: &PimConfig) -> Choice {
    let stats = MatrixStats::of(m);
    let n_dpus = cfg.n_dpus.max(1);

    // 1. Broadcast-wall test: 1D copies the whole vector to every DPU.
    //    Compare broadcast bytes against the kernel's useful work; when
    //    the vector dominates, go 2D (fewer bytes per DPU, stripes keep
    //    partials manageable).
    let bytes_broadcast = stats.ncols * T::DTYPE.size_bytes() * n_dpus;
    let work_per_iter = stats.nnz * 16; // rough bytes-equivalent of compute
    let two_d_pays = n_dpus >= 64 && bytes_broadcast > 4 * work_per_iter;

    // 2. Block-structure test: does 4x4 blocking stay dense enough that
    //    the per-block savings beat the fill-in?
    let fill = BcsrMatrix::from_coo(m, 4, 4).fill_ratio();
    let blocky = fill < 1.6;

    // 3. Skew test: CV of nnz/row decides the balancing granularity.
    let skewed = stats.nnz_per_row_cv > 0.5;

    if two_d_pays {
        let stripes = pick_stripes(n_dpus);
        let fmt = if blocky { Format::Bcoo } else { Format::Coo };
        let spec = if skewed {
            KernelSpec::two_d_balanced(fmt, stripes)
        } else {
            KernelSpec::two_d_equally_wide(fmt, stripes)
        };
        return Choice {
            reason: format!(
                "broadcast {}B > 4x work {}B at {n_dpus} DPUs -> 2D/{} ({}, cv={:.2}, fill={fill:.2})",
                bytes_broadcast, work_per_iter, stripes, spec.name, stats.nnz_per_row_cv
            ),
            spec,
        };
    }

    // 1D: pick format + balancing by structure.
    let spec = if blocky && !skewed {
        KernelSpec::bcoo_nnz()
    } else if skewed {
        // Element-granularity COO is the only scheme that tames hot rows.
        KernelSpec::coo_nnz()
    } else {
        KernelSpec::csr_nnz()
    };
    Choice {
        reason: format!(
            "1D: cv={:.2} fill={fill:.2} -> {} (skewed={skewed}, blocky={blocky})",
            stats.nnz_per_row_cv, spec.name
        ),
        spec,
    }
}

/// Largest power-of-two stripe count <= sqrt(n_dpus) that divides it —
/// balances the broadcast saving against partial-result volume.
fn pick_stripes(n_dpus: usize) -> usize {
    let mut s = 1usize;
    while s * 2 * s * 2 <= n_dpus && n_dpus % (s * 2) == 0 {
        s *= 2;
    }
    s.max(2.min(n_dpus))
}

/// Ground-truth selection: run all 25 kernels, return the fastest
/// end-to-end plus the full ranking.
pub fn autotune<T: SpElem>(
    exec: &SpmvExecutor,
    m: &CooMatrix<T>,
    x: &[T],
    stripes: usize,
) -> crate::util::Result<(KernelSpec, Vec<(String, f64)>)> {
    let mut ranking = Vec::new();
    let mut best: Option<(KernelSpec, f64)> = None;
    for spec in KernelSpec::all25(stripes) {
        let plan = exec.plan(&spec, m)?;
        let r = plan.execute(exec, x)?;
        let t = r.breakdown.total_s();
        ranking.push((spec.name.clone(), t));
        if best.as_ref().map_or(true, |(_, bt)| t < *bt) {
            best = Some((spec, t));
        }
    }
    ranking.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    Ok((best.unwrap().0, ranking))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Partitioning;
    use crate::matrix::generate;
    use crate::pim::PimSystem;

    fn cfg(n_dpus: usize) -> PimConfig {
        PimConfig { n_dpus, ..Default::default() }
    }

    #[test]
    fn skewed_matrices_get_element_granularity() {
        let m = generate::scale_free::<f64>(2048, 2048, 8, 0.8, 3);
        let c = select_heuristic(&m, &cfg(16));
        assert_eq!(c.spec.name, "COO.nnz", "{}", c.reason);
    }

    #[test]
    fn regular_unstructured_matrices_get_csr() {
        // Uniform-random columns: regular row counts but no block
        // structure (4x4 fill-in would be huge).
        let m = generate::uniform::<f64>(2048, 2048, 16, 3);
        let c = select_heuristic(&m, &cfg(16));
        assert_eq!(c.spec.name, "CSR.nnz", "{}", c.reason);
    }

    #[test]
    fn banded_matrices_may_use_blocking() {
        // A contiguous band blocks densely: BCOO is a legitimate pick.
        let m = generate::banded::<f64>(2048, 16, 3);
        let c = select_heuristic(&m, &cfg(16));
        assert!(
            c.spec.name == "BCOO.nnz" || c.spec.name == "CSR.nnz",
            "{} ({})",
            c.spec.name,
            c.reason
        );
    }

    #[test]
    fn block_matrices_get_bcoo() {
        let m = generate::blocked::<f64>(256, 256, 4, 6, 3);
        let c = select_heuristic(&m, &cfg(16));
        assert_eq!(c.spec.name, "BCOO.nnz", "{}", c.reason);
    }

    #[test]
    fn sparse_wide_at_scale_goes_two_d() {
        // Few nnz per row + thousands of DPUs: broadcast dominates -> 2D.
        let m = generate::uniform::<f64>(16384, 16384, 4, 3);
        let c = select_heuristic(&m, &cfg(2048));
        assert!(c.spec.is_two_d(), "{}", c.reason);
        if let Partitioning::TwoD(_, stripes) = c.spec.partitioning {
            assert!(2048 % stripes == 0);
        }
    }

    #[test]
    fn pick_stripes_divides() {
        for d in [64usize, 128, 256, 512, 1024, 2048] {
            let s = pick_stripes(d);
            assert!(d % s == 0, "stripes {s} must divide {d}");
            assert!(s * s <= d * 2);
        }
    }

    #[test]
    fn heuristic_close_to_autotuned_ground_truth() {
        // The heuristic need not be optimal, but it must land within 2x
        // of the autotuner's best on each canonical class.
        for e in generate::mini_suite() {
            let m = (e.gen)(11);
            let x: Vec<f64> = (0..m.ncols()).map(|i| (i % 7) as f64).collect();
            let exec = SpmvExecutor::new(PimSystem::with_dpus(64));
            let (best_spec, ranking) = autotune(&exec, &m, &x, 8).unwrap();
            let best_t = ranking[0].1;
            let choice = select_heuristic(&m, &exec.sys.cfg);
            let choice_plan = exec.plan(&choice.spec, &m).unwrap();
            let choice_t = choice_plan.execute(&exec, &x).unwrap().breakdown.total_s();
            assert!(
                choice_t <= best_t * 2.0,
                "{}: heuristic {} ({choice_t:.6}s) vs best {} ({best_t:.6}s)",
                e.name,
                choice.spec.name,
                best_spec.name
            );
        }
    }

    #[test]
    fn autotune_ranking_is_sorted_and_complete() {
        let m = generate::uniform::<f64>(256, 256, 6, 5);
        let x = vec![1.0f64; 256];
        let exec = SpmvExecutor::new(PimSystem::with_dpus(16));
        let (_, ranking) = autotune(&exec, &m, &x, 4).unwrap();
        assert_eq!(ranking.len(), 25);
        assert!(ranking.windows(2).all(|w| w[0].1 <= w[1].1));
    }
}
