//! `tuner` — the offline search loop behind the calibration table.
//!
//! SparseP's central finding is that no single (format, partitioning,
//! balance) choice wins across sparsity patterns; the paper picks the
//! winner empirically per matrix class. This module turns that empirical
//! procedure into a subsystem: **enumerate → measure → keep the
//! winners**, persisting the winners in a
//! [`CalibrationTable`](super::calibration::CalibrationTable) that the
//! serving stack consults at load time (see
//! [`super::adaptive::select_auto`], the service's block resolution, and
//! [`super::ShardedServiceBuilder::shards_for_matrix`]).
//!
//! The search is two-staged, because the two halves of the configuration
//! space are observable through different instruments:
//!
//! 1. **Kernel ranking (modeled).** The per-run
//!    [`Breakdown`](super::Breakdown) is a deterministic model of the
//!    PIM system — perfect for ranking the 25
//!    [`KernelSpec`](super::KernelSpec)s (it is exactly what they
//!    differ in) and immune to host noise. [`super::adaptive::autotune`]
//!    is the measurement primitive: all 25 kernels planned and executed
//!    on the actual engine against the actual vector batch.
//! 2. **Block × grid sweep (wall-clock).** Vector-block width, shard
//!    grid shape, and replica count never change modeled time — they
//!    change *host* pipeline behavior (streaming amortization,
//!    schedulable units, scatter/gather overlap, reduction fan-in).
//!    So stage 2 measures host wall-clock: the top-K kernels from
//!    stage 1 crossed with the block grid and the R×C×replicas shard
//!    grids, each configuration served through a real
//!    [`ShardedService`](super::ShardedService) (min over `samples`
//!    timed repetitions, after an untimed warmup).
//!
//! **The heuristic is candidate zero.** The baseline configuration —
//! [`select_heuristic`](super::adaptive::select_heuristic)'s spec with
//! [`BlockPolicy::Adaptive`]'s width on one shard — is measured first,
//! in the same harness as every other candidate, and the winner is the
//! minimum over *all* candidates including it. Calibrated selection is
//! therefore never slower than the heuristic on the tuned suite by
//! construction; the per-row `speedup = heuristic_wall / winner_wall`
//! is ≥ 1.0 identically, not statistically.

use super::adaptive::{self, pick_stripes};
use super::calibration::{CalibrationEntry, CalibrationTable};
use super::service::BlockPolicy;
use super::shard::ShardedServiceBuilder;
use super::spec::KernelSpec;
use super::{Engine, SpmvExecutor};
use crate::matrix::{generate, CooMatrix, MatrixStats};
use crate::pim::{PimConfig, PimSystem};
use crate::util::Result;
use std::time::Instant;

/// Search-space definition for one [`tune`] run.
#[derive(Clone, Debug)]
pub struct TuneOpts {
    /// DPUs per rank group (per shard backend).
    pub n_dpus: usize,
    /// Tasklets per DPU.
    pub tasklets: usize,
    /// Host engine driving per-DPU simulations during wall-clock
    /// measurement (never affects results).
    pub engine: Engine,
    /// Batch widths to tune for (each gets its own table entries —
    /// lookups are batch-aware).
    pub batches: Vec<usize>,
    /// Vector-block widths to sweep (stage 2).
    pub block_grid: Vec<usize>,
    /// Shard counts (grid rows / row bands) to sweep (stage 2).
    pub shard_grid: Vec<usize>,
    /// Column-tile counts to sweep (stage 2) — crossed with
    /// `shard_grid`, so the swept shapes are R×C grids.
    pub col_grid: Vec<usize>,
    /// Replica counts per tile to sweep (stage 2).
    pub replica_grid: Vec<usize>,
    /// How many stage-1 kernels advance to the wall-clock sweep.
    pub top_kernels: usize,
    /// Timed repetitions per candidate; the minimum is kept.
    pub samples: usize,
    /// Matrix-generator seed (the suite is deterministic given this).
    pub seed: u64,
    /// `true` = mini suite (CI smoke), `false` = full paper-scale suite.
    pub quick: bool,
}

impl TuneOpts {
    /// CI-sized search: the mini suite, one batch width, coarse grids.
    /// Runs in seconds; exists so `tune --quick` can gate every build.
    pub fn quick() -> TuneOpts {
        TuneOpts {
            n_dpus: 64,
            tasklets: 16,
            engine: Engine::Serial,
            batches: vec![8],
            block_grid: vec![2, 8, 32],
            shard_grid: vec![1, 2],
            col_grid: vec![1, 2],
            replica_grid: vec![1],
            top_kernels: 2,
            samples: 2,
            seed: 3,
            quick: true,
        }
    }

    /// The full search: paper-scale suite, three batch regimes, fine
    /// block/shard grids. Minutes, not seconds — run offline, ship the
    /// table.
    pub fn full() -> TuneOpts {
        TuneOpts {
            n_dpus: 256,
            tasklets: 16,
            engine: Engine::Serial,
            batches: vec![1, 8, 32],
            block_grid: vec![1, 2, 4, 8, 16, 32],
            shard_grid: vec![1, 2, 4, 8],
            col_grid: vec![1, 2],
            replica_grid: vec![1, 2],
            top_kernels: 3,
            samples: 3,
            seed: 3,
            quick: false,
        }
    }
}

/// One (matrix, batch) cell of the search: the measured heuristic
/// baseline, the winning configuration, and their ratio.
#[derive(Clone, Debug)]
pub struct TuneRow {
    pub matrix: String,
    pub class: String,
    pub batch: usize,
    /// The heuristic baseline actually measured (candidate zero).
    pub heuristic_kernel: String,
    pub heuristic_block: usize,
    pub heuristic_wall_s: f64,
    /// The winner (minimum wall-clock over all candidates).
    pub kernel: String,
    pub block: usize,
    pub shards: usize,
    /// Column tiles per row band in the winning grid (1 = row-only).
    pub grid_cols: usize,
    /// Replicas per tile in the winning grid (1 = unreplicated).
    pub replicas: usize,
    pub wall_s: f64,
    /// `heuristic_wall_s / wall_s` — ≥ 1.0 by construction (the
    /// heuristic is one of the candidates the minimum ranges over).
    pub speedup: f64,
}

/// The result of one [`tune`] run: the per-cell rows (reporting) and
/// the winners as a loadable [`CalibrationTable`] (serving).
#[derive(Clone, Debug)]
pub struct TuneReport {
    pub rows: Vec<TuneRow>,
    pub table: CalibrationTable,
}

impl TuneReport {
    /// Smallest per-row speedup (the CI gate's statistic). 1.0 for an
    /// empty report.
    pub fn min_speedup(&self) -> f64 {
        if self.rows.is_empty() {
            return 1.0;
        }
        self.rows.iter().map(|r| r.speedup).fold(f64::INFINITY, f64::min)
    }
}

/// Deterministic input batch: `batch` vectors of small integer-exact
/// values (keyed off `seed` so distinct runs are distinct but
/// reproducible).
fn make_vectors(ncols: usize, batch: usize, seed: u64) -> Vec<Vec<f64>> {
    (0..batch.max(1))
        .map(|v| {
            (0..ncols)
                .map(|i| ((i as u64 + 13 * v as u64 + seed) % 11) as f64 - 5.0)
                .collect()
        })
        .collect()
}

/// Measure one candidate configuration: host wall-clock of a
/// `batch`-vector request served through a [`ShardedServiceBuilder`]
/// stack (a `shards`×`cols` grid with `reps` replicas per tile,
/// `engine`, fixed-or-adaptive block), min over `samples` repetitions
/// after one untimed warmup. Returns `(wall_s, resolved_block)` — the
/// block actually used, so adaptive baselines record a concrete width
/// in the table.
#[allow(clippy::too_many_arguments)]
fn measure_wall(
    sys: &PimSystem,
    engine: Engine,
    m: &CooMatrix<f64>,
    spec: &KernelSpec,
    policy: BlockPolicy,
    shards: usize,
    cols: usize,
    reps: usize,
    xs: &[Vec<f64>],
    samples: usize,
) -> Result<(f64, usize)> {
    let svc = ShardedServiceBuilder::new()
        .grid(shards, cols)
        .replicas(reps)
        .engine(engine)
        .vector_block(policy)
        .build::<f64>(sys.clone())?;
    let h = svc.load(m, spec)?;
    // Warmup: touches every plan and warms the engine's worker pool so
    // the timed repetitions measure steady state.
    svc.spmv_batch(&h, xs)?;
    let mut best = f64::INFINITY;
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        svc.spmv_batch(&h, xs)?;
        best = best.min(t0.elapsed().as_secs_f64());
    }
    let block = match policy {
        BlockPolicy::Fixed(b) => b.max(1).min(xs.len().max(1)),
        // Ask a plain (unsharded) probe what Adaptive resolves to for
        // this plan shape — the concrete width the table records.
        BlockPolicy::Adaptive => {
            let plan = SpmvExecutor::with_engine(sys.clone(), engine).plan(spec, m)?;
            policy.resolve(xs.len(), plan.nnz() / plan.items().len().max(1))
        }
    };
    Ok((best, block))
}

/// Run the search over the generated suite and return the winners.
///
/// Per (matrix, batch) cell: stage 1 ranks all 25 kernels by modeled
/// time ([`adaptive::autotune`]); stage 2 sweeps the top-K kernels ×
/// `block_grid` × `shard_grid` × `col_grid` × `replica_grid` by host
/// wall-clock, with the heuristic configuration (one unreplicated
/// row-only shard) measured first as candidate zero. Deterministic
/// iteration order + strict-minimum keep-first makes the winner (and
/// hence the table) reproducible for a given `TuneOpts` up to host
/// timing noise.
pub fn tune(opts: &TuneOpts) -> Result<TuneReport> {
    crate::ensure!(!opts.batches.is_empty(), "tune needs at least one batch width");
    crate::ensure!(!opts.block_grid.is_empty(), "tune needs a non-empty block grid");
    crate::ensure!(!opts.shard_grid.is_empty(), "tune needs a non-empty shard grid");
    crate::ensure!(!opts.col_grid.is_empty(), "tune needs a non-empty column grid");
    crate::ensure!(!opts.replica_grid.is_empty(), "tune needs a non-empty replica grid");
    let sys = PimSystem::new(PimConfig {
        n_dpus: opts.n_dpus,
        tasklets: opts.tasklets,
        ..Default::default()
    })?;
    let exec = SpmvExecutor::new(sys.clone());
    let stripes = pick_stripes(opts.n_dpus);
    let suite = if opts.quick { generate::mini_suite() } else { generate::suite() };

    let mut rows = Vec::new();
    let mut entries = Vec::new();
    for e in &suite {
        let m = (e.gen)(opts.seed);
        let stats = MatrixStats::of(&m);
        for &batch in &opts.batches {
            let xs = make_vectors(m.ncols(), batch, opts.seed);

            // Stage 1: modeled ranking of all 25 kernels on this batch.
            let (_, ranking) = adaptive::autotune(&exec, &m, &xs, stripes)?;
            let finalists: Vec<KernelSpec> = ranking
                .iter()
                .take(opts.top_kernels.max(1))
                .filter_map(|(name, _)| KernelSpec::by_name(name, stripes))
                .collect();

            // Candidate zero: the heuristic baseline, measured through
            // the identical harness (1 shard, adaptive block).
            let heur = adaptive::select_heuristic(&m, &sys.cfg);
            let (heur_wall, heur_block) = measure_wall(
                &sys,
                opts.engine,
                &m,
                &heur.spec,
                BlockPolicy::Adaptive,
                1,
                1,
                1,
                &xs,
                opts.samples,
            )?;
            let mut best = (heur.spec.clone(), heur_block, 1usize, 1usize, 1usize, heur_wall);

            // Stage 2: wall-clock sweep, strict-minimum, keep-first.
            for spec in &finalists {
                for &block in &opts.block_grid {
                    // Widths beyond the batch clamp to it — dedup.
                    if block > batch.max(1) && opts.block_grid.iter().any(|&b| b == batch.max(1)) {
                        continue;
                    }
                    for &shards in &opts.shard_grid {
                        for &cols in &opts.col_grid {
                            for &reps in &opts.replica_grid {
                                let (wall, used_block) = measure_wall(
                                    &sys,
                                    opts.engine,
                                    &m,
                                    spec,
                                    BlockPolicy::Fixed(block),
                                    shards,
                                    cols,
                                    reps,
                                    &xs,
                                    opts.samples,
                                )?;
                                if wall < best.5 {
                                    best = (spec.clone(), used_block, shards, cols, reps, wall);
                                }
                            }
                        }
                    }
                }
            }

            let (spec, block, shards, cols, reps, wall) = best;
            rows.push(TuneRow {
                matrix: e.name.to_string(),
                class: e.class.to_string(),
                batch,
                heuristic_kernel: heur.spec.name.clone(),
                heuristic_block: heur_block,
                heuristic_wall_s: heur_wall,
                kernel: spec.name.clone(),
                block,
                shards,
                grid_cols: cols,
                replicas: reps,
                wall_s: wall,
                speedup: heur_wall / wall.max(f64::MIN_POSITIVE),
            });
            entries.push(CalibrationEntry {
                matrix: e.name.to_string(),
                class: e.class.to_string(),
                features: stats.feature_vector(),
                batch,
                kernel: spec.name.clone(),
                stripes: spec.stripes().unwrap_or(0),
                block,
                shards,
                grid_cols: cols,
                replicas: reps,
                wall_s: wall,
                heuristic_wall_s: heur_wall,
            });
        }
    }
    Ok(TuneReport { rows, table: CalibrationTable::new(entries) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::PimSystem;

    /// A deliberately tiny search so the test stays fast while still
    /// exercising both stages end to end.
    fn tiny_opts() -> TuneOpts {
        TuneOpts {
            n_dpus: 16,
            tasklets: 8,
            engine: Engine::Serial,
            batches: vec![2],
            block_grid: vec![1, 2],
            shard_grid: vec![1, 2],
            col_grid: vec![1],
            replica_grid: vec![1],
            top_kernels: 1,
            samples: 1,
            seed: 7,
            quick: true,
        }
    }

    #[test]
    fn tune_produces_winners_no_worse_than_the_heuristic() {
        let report = tune(&tiny_opts()).unwrap();
        assert_eq!(report.rows.len(), 4, "one row per mini-suite matrix x batch");
        for row in &report.rows {
            assert!(
                row.speedup >= 1.0,
                "{} @batch {}: calibrated {} must not lose to heuristic {} ({} vs {})",
                row.matrix,
                row.batch,
                row.kernel,
                row.heuristic_kernel,
                row.wall_s,
                row.heuristic_wall_s
            );
            assert!(row.wall_s > 0.0 && row.heuristic_wall_s > 0.0);
            assert!(row.block >= 1 && row.shards >= 1);
        }
        assert!(report.min_speedup() >= 1.0);
    }

    #[test]
    fn tune_table_round_trips_and_its_specs_plan() {
        let opts = tiny_opts();
        let report = tune(&opts).unwrap();
        let table = &report.table;
        assert_eq!(table.len(), report.rows.len());

        // Round trip through the on-disk format.
        let doc = table.to_json_string();
        let back = CalibrationTable::from_json_str(&doc).unwrap();
        assert_eq!(&back, table);

        // Every recorded winner must reconstruct and plan on the matrix
        // it was tuned for — and on a hostile DPU count.
        let exec = SpmvExecutor::new(PimSystem::with_dpus(opts.n_dpus));
        let exec_odd = SpmvExecutor::new(PimSystem::with_dpus(7));
        for e in table.entries() {
            let suite_entry = generate::mini_suite()
                .into_iter()
                .find(|s| s.name == e.matrix)
                .expect("table entry names a suite matrix");
            let m = (suite_entry.gen)(opts.seed);
            for ex in [&exec, &exec_odd] {
                let spec = table.spec_for(e, &ex.sys.cfg).expect("winner reconstructs");
                ex.plan(&spec, &m).expect("calibrated winner must plan");
            }
        }
    }

    #[test]
    fn tune_validates_its_grids() {
        let mut o = tiny_opts();
        o.batches.clear();
        assert!(tune(&o).is_err());
        let mut o = tiny_opts();
        o.block_grid.clear();
        assert!(tune(&o).is_err());
        let mut o = tiny_opts();
        o.shard_grid.clear();
        assert!(tune(&o).is_err());
        let mut o = tiny_opts();
        o.col_grid.clear();
        assert!(tune(&o).is_err());
        let mut o = tiny_opts();
        o.replica_grid.clear();
        assert!(tune(&o).is_err());
    }
}
