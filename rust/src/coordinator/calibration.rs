//! Calibration table — the persisted output of the search-based
//! autotuner ([`super::tuner`]) and the lookup structure the serving
//! stack consults at plan/serve time.
//!
//! The paper's central finding is that the best (format, partitioning,
//! balance) choice depends on the sparsity pattern, and that the
//! performance cliffs of a real PIM system are discovered by
//! measurement, not modeled a priori. The tuner therefore *measures*:
//! it sweeps kernel/block/shard configurations over a generated matrix
//! suite and records each winner here, keyed by the matrix's
//! [`MatrixStats`] feature vector. At serve time an unseen matrix is
//! matched to its nearest calibrated neighbor over normalized features;
//! when no table is loaded (or no kernel of the recorded name exists),
//! callers fall back to the hand-tuned heuristics unchanged.
//!
//! ## On-disk format
//!
//! One JSON object:
//!
//! ```json
//! {"version": 1, "checksum": "0f3a...", "entries": [ ... ]}
//! ```
//!
//! `checksum` is the FNV-1a hash (hex, 16 digits) of the serialized
//! `entries` array — the same hash family
//! [`crate::matrix::CooMatrix::fingerprint`] uses for plan-cache keys.
//! [`CalibrationTable::from_json_str`] recomputes it and rejects files
//! whose payload does not match (truncated copies, hand edits, bit
//! rot), so a corrupt table can never silently steer kernel selection.
//!
//! ## Determinism
//!
//! Entries are kept sorted by `(matrix, batch)` and lookups keep the
//! *first* entry at the minimum distance (strict `<` improvement), so
//! nearest-neighbor ties break identically across runs, processes and
//! serialize/parse round trips.

use crate::matrix::MatrixStats;
use crate::pim::PimConfig;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::{Context, Result};

use super::spec::KernelSpec;

/// Dimensionality of [`MatrixStats::feature_vector`].
pub const FEATURE_DIM: usize = 6;

/// Current on-disk format version.
pub const TABLE_VERSION: u64 = 1;

/// Per-feature normalization scales: roughly the dynamic range each
/// component spans across the evaluation suite, so no single axis
/// dominates the nearest-neighbor distance. Order matches
/// [`MatrixStats::feature_vector`]: log2 rows, log2 cols, log2 nnz/row,
/// CV, class indicator, log10 density.
const FEATURE_SCALE: [f64; FEATURE_DIM] = [16.0, 16.0, 8.0, 1.0, 1.0, 6.0];

/// Weight of the batch-width term in the lookup distance (log2 batch,
/// scaled like the feature axes).
const BATCH_SCALE: f64 = 4.0;

/// One calibrated winner: the configuration that measured fastest for
/// a (matrix, batch-width) point of the tuning suite.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibrationEntry {
    /// Suite name of the matrix this entry was tuned on.
    pub matrix: String,
    /// The paper's class ("regular" / "scale-free") — informational.
    pub class: String,
    /// [`MatrixStats::feature_vector`] of the tuning matrix.
    pub features: [f64; FEATURE_DIM],
    /// Batch width the entry was tuned for (1 = single-vector SpMV).
    pub batch: usize,
    /// Winning kernel, by paper name (reconstructed via
    /// [`KernelSpec::by_name`]).
    pub kernel: String,
    /// Stripe count the winner was tuned with (0 for 1D kernels, where
    /// the axis does not exist). Sanitized against the serving system's
    /// DPU count at reconstruction time.
    pub stripes: usize,
    /// Winning vector-block width.
    pub block: usize,
    /// Winning shard count for the sharded facade — the row dimension
    /// of the winning grid.
    pub shards: usize,
    /// Winning column-stripe count of the grid (1 = row-only sharding).
    /// Tables written before the grid sweep omit the field; parsing
    /// defaults it to 1, so PR-6 era tables keep loading.
    pub grid_cols: usize,
    /// Winning replica count per tile (1 = unreplicated; defaults to 1
    /// when absent, like `grid_cols`).
    pub replicas: usize,
    /// The winner's measured wall-clock (seconds, min over samples).
    pub wall_s: f64,
    /// The heuristic baseline's wall-clock measured in the same sweep.
    pub heuristic_wall_s: f64,
}

impl CalibrationEntry {
    fn to_json(&self) -> Json {
        obj(vec![
            ("matrix", s(&self.matrix)),
            ("class", s(&self.class)),
            ("features", arr(self.features.iter().map(|&f| num(f)).collect())),
            ("batch", num(self.batch as f64)),
            ("kernel", s(&self.kernel)),
            ("stripes", num(self.stripes as f64)),
            ("block", num(self.block as f64)),
            ("shards", num(self.shards as f64)),
            ("grid_cols", num(self.grid_cols as f64)),
            ("replicas", num(self.replicas as f64)),
            ("wall_s", num(self.wall_s)),
            ("heuristic_wall_s", num(self.heuristic_wall_s)),
        ])
    }

    fn from_json(j: &Json) -> Result<CalibrationEntry> {
        let field = |k: &str| -> Result<f64> {
            j.get(k).as_f64().ok_or_else(|| crate::format_err!("entry missing numeric {k:?}"))
        };
        let fs = j.get("features").as_arr().context("entry missing features array")?;
        crate::ensure!(
            fs.len() == FEATURE_DIM,
            "entry has {} features, expected {FEATURE_DIM}",
            fs.len()
        );
        let mut features = [0.0; FEATURE_DIM];
        for (d, f) in features.iter_mut().zip(fs) {
            *d = f.as_f64().context("non-numeric feature")?;
        }
        // Grid fields are optional (default 1): tables written before
        // the grid sweep stay loadable — their checksums still verify,
        // since the hash covers the entries text as written.
        let optional = |k: &str| -> usize {
            j.get(k).as_f64().map(|v| v as usize).unwrap_or(1).max(1)
        };
        Ok(CalibrationEntry {
            matrix: j.get("matrix").as_str().context("entry missing matrix")?.to_string(),
            class: j.get("class").as_str().context("entry missing class")?.to_string(),
            features,
            batch: field("batch")? as usize,
            kernel: j.get("kernel").as_str().context("entry missing kernel")?.to_string(),
            stripes: field("stripes")? as usize,
            block: field("block")? as usize,
            shards: field("shards")? as usize,
            grid_cols: optional("grid_cols"),
            replicas: optional("replicas"),
            wall_s: field("wall_s")?,
            heuristic_wall_s: field("heuristic_wall_s")?,
        })
    }
}

/// A set of calibrated winners with nearest-neighbor lookup. See the
/// module docs for format and determinism guarantees.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CalibrationTable {
    entries: Vec<CalibrationEntry>,
}

impl CalibrationTable {
    /// Build a table from entries (sorted internally for deterministic
    /// tie-breaking; see module docs).
    pub fn new(mut entries: Vec<CalibrationEntry>) -> CalibrationTable {
        entries.sort_by(|a, b| (a.matrix.as_str(), a.batch).cmp(&(b.matrix.as_str(), b.batch)));
        CalibrationTable { entries }
    }

    /// The calibrated entries, in the canonical sorted order.
    pub fn entries(&self) -> &[CalibrationEntry] {
        &self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Nearest calibrated entry for a matrix with statistics `stats`
    /// served at `batch` vectors per request. `None` only for an empty
    /// table. Ties keep the first entry in canonical order.
    pub fn lookup(&self, stats: &MatrixStats, batch: usize) -> Option<&CalibrationEntry> {
        let probe = stats.feature_vector();
        let probe_b = (batch.max(1) as f64).log2();
        let mut best: Option<(&CalibrationEntry, f64)> = None;
        for e in &self.entries {
            let mut d = feature_distance(&probe, &e.features);
            let db = (probe_b - (e.batch.max(1) as f64).log2()) / BATCH_SCALE;
            d += db * db;
            if best.as_ref().map_or(true, |(_, bd)| d < *bd) {
                best = Some((e, d));
            }
        }
        best.map(|(e, _)| e)
    }

    /// Reconstruct the kernel the entry recorded, sanitized for `cfg`:
    /// a 2D stripe count that does not divide the serving system's DPU
    /// count is replaced by the largest divisor not above it (stripes of
    /// 1 always divide), so the returned spec always plans. `None` when
    /// the recorded kernel name is unknown (e.g. a table from a future
    /// version) — callers fall back to the heuristic.
    pub fn spec_for(&self, e: &CalibrationEntry, cfg: &PimConfig) -> Option<KernelSpec> {
        let want = if e.stripes == 0 { 1 } else { e.stripes };
        KernelSpec::by_name(&e.kernel, sanitize_stripes(cfg.n_dpus, want))
    }

    // --- serialization ----------------------------------------------

    fn entries_json(&self) -> Json {
        Json::Arr(self.entries.iter().map(|e| e.to_json()).collect())
    }

    /// Serialize to the on-disk JSON document (checksummed payload).
    pub fn to_json_string(&self) -> String {
        let entries = self.entries_json();
        let checksum = format!("{:016x}", fnv1a(entries.to_string().as_bytes()));
        obj(vec![
            ("version", num(TABLE_VERSION as f64)),
            ("checksum", s(&checksum)),
            ("entries", entries),
        ])
        .to_string()
            + "\n"
    }

    /// Parse and verify a table document: the version must be known and
    /// the payload must match its recorded checksum.
    pub fn from_json_str(text: &str) -> Result<CalibrationTable> {
        let doc = Json::parse(text).map_err(|e| crate::format_err!("calibration table: {e}"))?;
        let version = doc.get("version").as_usize().context("calibration table missing version")?;
        crate::ensure!(
            version as u64 == TABLE_VERSION,
            "calibration table version {version} (this build reads {TABLE_VERSION})"
        );
        let recorded = doc.get("checksum").as_str().context("calibration table missing checksum")?;
        let entries_j = doc.get("entries");
        crate::ensure!(entries_j.as_arr().is_some(), "calibration table missing entries array");
        let actual = format!("{:016x}", fnv1a(entries_j.to_string().as_bytes()));
        crate::ensure!(
            recorded == actual,
            "calibration table checksum mismatch (recorded {recorded}, payload hashes to {actual}); refusing a corrupt table"
        );
        let entries = entries_j
            .as_arr()
            .unwrap()
            .iter()
            .map(CalibrationEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(CalibrationTable::new(entries))
    }

    /// Write the table to `path`.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json_string())
            .with_context(|| format!("write calibration table {}", path.display()))
    }

    /// Load and verify a table from `path`.
    pub fn load(path: &std::path::Path) -> Result<CalibrationTable> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read calibration table {}", path.display()))?;
        Self::from_json_str(&text)
            .with_context(|| format!("parse calibration table {}", path.display()))
    }
}

/// Normalized squared distance between two feature vectors.
fn feature_distance(a: &[f64; FEATURE_DIM], b: &[f64; FEATURE_DIM]) -> f64 {
    let mut d = 0.0;
    for i in 0..FEATURE_DIM {
        let t = (a[i] - b[i]) / FEATURE_SCALE[i];
        d += t * t;
    }
    d
}

/// Largest divisor of `n_dpus` that is `<= want` (at least 1, which
/// divides everything). This is how recorded stripe counts survive a
/// move to a system with a different DPU count: the 2D partitioner
/// requires stripes to divide the DPU count, so a calibrated spec is
/// snapped to the nearest feasible stripe count at or below the
/// recorded one instead of failing to plan.
pub fn sanitize_stripes(n_dpus: usize, want: usize) -> usize {
    let n = n_dpus.max(1);
    let mut d = want.clamp(1, n);
    while d > 1 && n % d != 0 {
        d -= 1;
    }
    d
}

/// FNV-1a 64-bit (same family as the matrix fingerprint).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate;

    fn entry(matrix: &str, batch: usize, kernel: &str, features: [f64; FEATURE_DIM]) -> CalibrationEntry {
        CalibrationEntry {
            matrix: matrix.to_string(),
            class: "regular".to_string(),
            features,
            batch,
            kernel: kernel.to_string(),
            stripes: 4,
            block: 8,
            shards: 2,
            grid_cols: 2,
            replicas: 2,
            wall_s: 1e-3,
            heuristic_wall_s: 2e-3,
        }
    }

    #[test]
    fn roundtrip_preserves_entries_and_lookups() {
        let m = generate::banded::<f64>(256, 8, 1);
        let st = MatrixStats::of(&m);
        let t = CalibrationTable::new(vec![
            entry("a", 1, "CSR.nnz", st.feature_vector()),
            entry("b", 8, "COO.nnz", [1.0; FEATURE_DIM]),
        ]);
        let text = t.to_json_string();
        let back = CalibrationTable::from_json_str(&text).unwrap();
        assert_eq!(t, back);
        assert_eq!(
            t.lookup(&st, 1).unwrap().kernel,
            back.lookup(&st, 1).unwrap().kernel
        );
        // Serialization is a fixed point: parse -> serialize is stable.
        assert_eq!(back.to_json_string(), text);
    }

    #[test]
    fn pre_grid_tables_parse_with_default_grid() {
        // A PR-6 era document has no grid_cols/replicas keys. Its
        // checksum covers the entries payload, so it still verifies —
        // and the missing fields default to 1 (row-only, unreplicated),
        // never an error.
        let old_entry = obj(vec![
            ("matrix", s("a")),
            ("class", s("regular")),
            ("features", arr((0..FEATURE_DIM).map(|_| num(0.5)).collect())),
            ("batch", num(1.0)),
            ("kernel", s("CSR.nnz")),
            ("stripes", num(0.0)),
            ("block", num(8.0)),
            ("shards", num(3.0)),
            ("wall_s", num(1e-3)),
            ("heuristic_wall_s", num(2e-3)),
        ]);
        let entries = Json::Arr(vec![old_entry]);
        let checksum = format!("{:016x}", fnv1a(entries.to_string().as_bytes()));
        let doc = obj(vec![
            ("version", num(TABLE_VERSION as f64)),
            ("checksum", s(&checksum)),
            ("entries", entries),
        ])
        .to_string();
        let t = CalibrationTable::from_json_str(&doc).unwrap();
        assert_eq!(t.len(), 1);
        let e = &t.entries()[0];
        assert_eq!((e.shards, e.grid_cols, e.replicas), (3, 1, 1));
        // Re-serializing writes the grid fields explicitly.
        let back = CalibrationTable::from_json_str(&t.to_json_string()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn checksum_rejects_corruption() {
        let t = CalibrationTable::new(vec![entry("a", 1, "CSR.nnz", [0.5; FEATURE_DIM])]);
        let text = t.to_json_string();
        // Flip payload content without touching the recorded checksum.
        let bad = text.replace("CSR.nnz", "COO.nnz");
        assert_ne!(bad, text);
        let err = CalibrationTable::from_json_str(&bad).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // A wrong version is rejected too.
        let vbad = text.replace("\"version\":1", "\"version\":99");
        assert!(CalibrationTable::from_json_str(&vbad).is_err());
        // And so is plain garbage.
        assert!(CalibrationTable::from_json_str("{not json").is_err());
    }

    #[test]
    fn lookup_ties_break_deterministically() {
        // Two entries at the exact same feature point: the lookup must
        // keep the first in canonical (matrix, batch) order, however
        // the entries were supplied.
        let f = [0.25; FEATURE_DIM];
        let fwd = CalibrationTable::new(vec![entry("a", 4, "CSR.nnz", f), entry("b", 4, "COO.nnz", f)]);
        let rev = CalibrationTable::new(vec![entry("b", 4, "COO.nnz", f), entry("a", 4, "CSR.nnz", f)]);
        let m = generate::banded::<f64>(64, 4, 1);
        let st = MatrixStats::of(&m);
        assert_eq!(fwd.lookup(&st, 4).unwrap().matrix, "a");
        assert_eq!(rev.lookup(&st, 4).unwrap().matrix, "a");
        assert!(CalibrationTable::default().lookup(&st, 4).is_none());
    }

    #[test]
    fn lookup_is_batch_aware() {
        let m = generate::banded::<f64>(256, 8, 1);
        let st = MatrixStats::of(&m);
        let f = st.feature_vector();
        let t = CalibrationTable::new(vec![
            entry("a", 1, "CSR.nnz", f),
            entry("a", 32, "COO.nnz", f),
        ]);
        assert_eq!(t.lookup(&st, 1).unwrap().kernel, "CSR.nnz");
        assert_eq!(t.lookup(&st, 32).unwrap().kernel, "COO.nnz");
    }

    #[test]
    fn sanitize_stripes_always_divides() {
        for n in [1usize, 2, 6, 7, 13, 16, 64, 97, 100, 1021] {
            for want in [0usize, 1, 2, 3, 8, 64, 10_000] {
                let s = sanitize_stripes(n, want);
                assert!(s >= 1 && n % s == 0, "sanitize({n}, {want}) = {s}");
                assert!(s <= want.max(1));
            }
        }
        assert_eq!(sanitize_stripes(64, 8), 8, "feasible counts pass through");
        assert_eq!(sanitize_stripes(7, 8), 7);
        assert_eq!(sanitize_stripes(7, 5), 1, "prime: only 1 divides below sqrt-ish asks");
    }

    #[test]
    fn spec_for_always_plans() {
        let e = entry("a", 1, "DCOO", [0.0; FEATURE_DIM]);
        // 7 DPUs: recorded stripes 4 do not divide; snapped to 1.
        let cfg = PimConfig { n_dpus: 7, ..Default::default() };
        let t = CalibrationTable::new(vec![e.clone()]);
        let spec = t.spec_for(&e, &cfg).unwrap();
        let m = generate::uniform::<f64>(64, 64, 4, 3);
        let exec = crate::coordinator::SpmvExecutor::new(crate::pim::PimSystem::new(cfg).unwrap());
        assert!(exec.plan(&spec, &m).is_ok());
        // Unknown kernel names report None instead of a bogus spec.
        let mut bogus = e;
        bogus.kernel = "NOPE".into();
        assert!(t.spec_for(&bogus, &PimConfig::default()).is_none());
    }
}
