//! Minimal error handling (anyhow is not in the offline vendor set —
//! this mirrors how [`crate::util::json`] replaces serde).
//!
//! Provides a string-backed [`Error`], a crate-wide [`Result`] alias, a
//! [`Context`] extension trait for `Result`/`Option`, and the
//! [`crate::bail!`] / [`crate::ensure!`] / [`crate::format_err!`] macros.
//! The surface intentionally matches the subset of `anyhow` this crate
//! used, so call sites read the same.

use std::fmt;

/// Machine-checkable classification of an [`Error`]. Most errors are
/// [`ErrorKind::Other`]; kinds exist only where a caller needs to make
/// a control-flow decision (retry, re-scatter, report a wedged shard)
/// that matching on a message string could not support.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// Anything without a dedicated kind.
    Other,
    /// A bounded wait expired before the response arrived. `shard`
    /// names the wedged backend shard when the waiter knows it (the
    /// sharded gather thread does; a plain service waiter does not).
    ShardTimeout { shard: Option<usize> },
}

/// A string-backed error with an optional chain of context messages
/// and an optional machine-checkable [`ErrorKind`].
pub struct Error {
    msg: String,
    kind: ErrorKind,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string(), kind: ErrorKind::Other }
    }

    /// Build a typed [`ErrorKind::ShardTimeout`]: a wait deadline
    /// expired. Pass `Some(shard)` when the wedged backend is known.
    pub fn shard_timeout(shard: Option<usize>, m: impl fmt::Display) -> Error {
        Error { msg: m.to_string(), kind: ErrorKind::ShardTimeout { shard } }
    }

    /// The machine-checkable classification of this error.
    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// True when this error is a timed-out wait (any shard).
    pub fn is_shard_timeout(&self) -> bool {
        matches!(self.kind, ErrorKind::ShardTimeout { .. })
    }

    /// The wedged shard named by a [`ErrorKind::ShardTimeout`], if any.
    pub fn timed_out_shard(&self) -> Option<usize> {
        match self.kind {
            ErrorKind::ShardTimeout { shard } => shard,
            ErrorKind::Other => None,
        }
    }

    /// Prepend a context message (outermost first, like anyhow's
    /// chain). The kind survives wrapping.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg), kind: self.kind }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result alias; defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(...)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::Error::msg(format!($($arg)*)))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

/// Build (but do not return) a formatted [`Error`].
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        crate::bail!("boom {}", 42);
    }

    #[test]
    fn bail_and_ensure() {
        assert_eq!(fails().unwrap_err().to_string(), "boom 42");
        let ok = || -> Result<u32> {
            crate::ensure!(1 + 1 == 2, "math broke");
            Ok(7)
        };
        assert_eq!(ok().unwrap(), 7);
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn shard_timeout_kind_survives_context() {
        let e = Error::shard_timeout(Some(3), "shard 3 did not answer");
        assert!(e.is_shard_timeout());
        assert_eq!(e.timed_out_shard(), Some(3));
        assert_eq!(e.kind(), ErrorKind::ShardTimeout { shard: Some(3) });
        let wrapped = e.context("gather");
        assert!(wrapped.is_shard_timeout(), "context must preserve the kind");
        assert_eq!(wrapped.to_string(), "gather: shard 3 did not answer");
        // Plain errors stay Other and name no shard.
        let plain = Error::msg("boom");
        assert!(!plain.is_shard_timeout());
        assert_eq!(plain.timed_out_shard(), None);
        assert_eq!(Error::shard_timeout(None, "x").timed_out_shard(), None);
    }

    #[test]
    fn from_parse_and_io() {
        let e: Error = "zz".parse::<usize>().unwrap_err().into();
        assert!(!e.to_string().is_empty());
        let e2 = crate::format_err!("x={}", 1).context("ctx");
        assert_eq!(e2.to_string(), "ctx: x=1");
    }
}
