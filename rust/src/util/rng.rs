//! Deterministic PRNG (splitmix64 + xoshiro256**) used by the matrix
//! generators and the property tests.
//!
//! `rand` is not in the offline vendor set; this is a small, well-known
//! generator pair with excellent statistical quality for simulation use.

/// xoshiro256** seeded via splitmix64 — deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (any value, including 0).
    pub fn new(seed: u64) -> Self {
        // splitmix64 to expand the seed into the full state, as recommended
        // by the xoshiro authors.
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method
    /// to avoid modulo bias.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n || n.is_power_of_two() {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Sample from a (truncated) discrete power-law: returns `k` in
    /// `[1, max]` with P(k) ∝ k^-alpha. Used for scale-free row degrees.
    pub fn gen_power_law(&mut self, alpha: f64, max: usize) -> usize {
        // Inverse-CDF sampling of the continuous Pareto, then clamped.
        // For alpha close to 1 the closed form degenerates; handle both.
        let u = self.gen_f64().max(1e-300);
        let max_f = max as f64;
        let k = if (alpha - 1.0).abs() < 1e-9 {
            max_f.powf(u)
        } else {
            let a1 = 1.0 - alpha;
            ((max_f.powf(a1) - 1.0) * u + 1.0).powf(1.0 / a1)
        };
        (k.floor() as usize).clamp(1, max)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    ///
    /// Uses Floyd's algorithm: O(k) expected draws, no O(n) allocation.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for n in [1usize, 2, 3, 17, 1000] {
            for _ in 0..500 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn gen_range_covers_small_domain() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[r.gen_range(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn power_law_in_range_and_skewed() {
        let mut r = Rng::new(11);
        let mut small = 0usize;
        for _ in 0..2000 {
            let k = r.gen_power_law(2.0, 100);
            assert!((1..=100).contains(&k));
            if k <= 3 {
                small += 1;
            }
        }
        // With alpha=2 the mass near 1 dominates strongly.
        assert!(small > 1000, "power-law not skewed: {small}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_distinct(50, 20);
        assert_eq!(s.len(), 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
