//! `SpmvService` — the handle-based serving API.
//!
//! The executor/plan layer answers "how do I run one SpMV"; this module
//! answers the serving question the ROADMAP's north star asks: many
//! callers, many requests, one resident set of matrices. A
//! [`SpmvService`] is a long-lived object, configured once through
//! [`ServiceBuilder`] (engine, plan-cache capacity, intake-queue depth,
//! vector-block policy), that owns the [`super::PlanCache`] and a
//! pipelined request engine ([`super::queue`]).
//!
//! The serving vocabulary is small:
//!
//! * [`SpmvService::load`] registers a matrix under a [`KernelSpec`]
//!   and returns a [`MatrixHandle`] — planning (partition + per-DPU
//!   format conversion + transfer pricing) happens here, once,
//!   content-fingerprinted through the plan cache. Loading an equal
//!   matrix again is a cache hit, not a re-plan.
//! * [`SpmvService::submit`] enqueues a typed [`Request`] against a
//!   handle and returns a [`Ticket`] immediately (blocking only when
//!   the intake queue is at its configured depth).
//! * [`SpmvService::wait`] blocks until the ticket's [`Response`] is
//!   ready. Tickets may be waited on in any order — responses park in
//!   a completion store until claimed.
//!
//! Responses are **bit-identical** to the synchronous
//! [`super::ExecutionPlan`] path (`tests/service_equivalence.rs` locks
//! all 25 kernels x engines x request mixes), so the pipeline buys
//! wall-clock overlap, never answer drift.

use super::cache::{PlanCache, DEFAULT_PLAN_CACHE_CAPACITY};
use super::calibration::CalibrationTable;
use super::plan::ExecutionPlan;
use super::queue::{Job, RequestQueue, ResponseKind, DEFAULT_QUEUE_DEPTH};
use super::spec::KernelSpec;
use super::{
    BatchResult, Engine, IterationsResult, RunResult, ServiceStats, SpmvExecutor, VECTOR_BLOCK,
};
use crate::matrix::{CooMatrix, MatrixStats, SpElem};
use crate::pim::PimSystem;
use crate::util::Result;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex};
use std::collections::HashMap;

/// Distinguishes services within a process so handles and tickets from
/// one service are rejected by another instead of aliasing. Stays on
/// `std`'s atomic by full path: `const`-initialized statics can't use
/// the loom-switched facade atomics (loom's `new` is not `const`).
static NEXT_SERVICE_ID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

/// How a batch is cut into vector blocks (the fused-kernel unit: each
/// (work-item, block) pair streams the matrix slice once for the whole
/// block). The width never changes results — only how much matrix
/// streaming is amortized per pass versus how many independently
/// schedulable units the engine gets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockPolicy {
    /// Always use this many vectors per block (clamped to >= 1).
    /// `Fixed(VECTOR_BLOCK)` reproduces the executor path's historical
    /// behavior.
    Fixed(usize),
    /// Choose the width from the batch width and the mean per-DPU slice
    /// population: big slices amortize more streaming per fused pass
    /// (wider blocks), small slices leave the engine starved for units
    /// (narrower blocks).
    Adaptive,
}

impl BlockPolicy {
    /// Resolve the block width for a `batch`-vector request over slices
    /// averaging `mean_slice_nnz` stored non-zeros.
    pub fn resolve(self, batch: usize, mean_slice_nnz: usize) -> usize {
        match self {
            BlockPolicy::Fixed(b) => b.max(1),
            BlockPolicy::Adaptive => {
                if batch <= 1 {
                    return 1;
                }
                // Each fused pass streams the whole slice once; the
                // per-vector cost it amortizes grows with the slice, so
                // wider blocks pay off on fat slices. Thin slices finish
                // fast either way — prefer more, smaller units so the
                // threaded engine's dynamic scheduler has freedom.
                let width = if mean_slice_nnz >= 1 << 16 {
                    4 * VECTOR_BLOCK
                } else if mean_slice_nnz >= 1 << 12 {
                    2 * VECTOR_BLOCK
                } else if mean_slice_nnz >= 1 << 8 {
                    VECTOR_BLOCK
                } else {
                    VECTOR_BLOCK / 2
                };
                width.max(1).min(batch)
            }
        }
    }
}

impl Default for BlockPolicy {
    fn default() -> BlockPolicy {
        BlockPolicy::Adaptive
    }
}

/// A matrix registered with one [`SpmvService`]: cheap to copy, valid
/// until [`SpmvService::unload`] (or the service drops). The plan
/// behind it stays resident — submitting against a handle never
/// re-fingerprints or re-plans the matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MatrixHandle {
    svc: u64,
    id: u64,
    nrows: usize,
    ncols: usize,
}

impl MatrixHandle {
    /// Rows of the registered matrix.
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Columns of the registered matrix.
    pub fn ncols(&self) -> usize {
        self.ncols
    }
}

/// A submitted request's claim check (copyable; see
/// [`SpmvService::wait`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Ticket {
    svc: u64,
    id: u64,
}

impl Ticket {
    /// Monotonic per-service ticket number (submission order).
    pub fn id(&self) -> u64 {
        self.id
    }
}

/// A unit of work against a resident matrix.
///
/// Payloads are reference-counted slices (`Arc<[T]>`), not owned
/// vectors: cloning a request — which is exactly what the sharded
/// facade's dispatcher does to scatter one request across `S` shard
/// backends — shares the allocation instead of copying it, so an
/// S-shard scatter costs S reference-count bumps where it used to cost
/// S payload memcpys. `Vec<T>` converts in via the std
/// `From<Vec<T>> for Arc<[T]>` impl; the [`Request::spmv`],
/// [`Request::batch`] and [`Request::iterate`] constructors accept
/// either form.
#[derive(Clone, Debug)]
pub enum Request<T> {
    /// One SpMV `y = A * x`.
    Spmv { x: Arc<[T]> },
    /// SpMM-style multi-vector execution `Y = A * X` (may be empty).
    Batch { xs: Vec<Arc<[T]>> },
    /// Iterated self-application `y <- A * y`, `iters` times starting
    /// from `x` (requires a square matrix for `iters > 1`).
    Iterate { x: Arc<[T]>, iters: usize },
}

impl<T> Request<T> {
    /// One SpMV request; takes `Vec<T>`, `Arc<[T]>`, or anything else
    /// that converts into a shared slice.
    pub fn spmv(x: impl Into<Arc<[T]>>) -> Request<T> {
        Request::Spmv { x: x.into() }
    }

    /// A batched request over any iterable of convertible payloads
    /// (e.g. a `Vec<Vec<T>>`, or already-shared `Arc<[T]>`s).
    pub fn batch<I>(xs: I) -> Request<T>
    where
        I: IntoIterator,
        I::Item: Into<Arc<[T]>>,
    {
        Request::Batch { xs: xs.into_iter().map(Into::into).collect() }
    }

    /// An iterated request (see [`Request::Iterate`]).
    pub fn iterate(x: impl Into<Arc<[T]>>, iters: usize) -> Request<T> {
        Request::Iterate { x: x.into(), iters }
    }
}

/// The completed result of a [`Request`], mirroring its shape.
#[derive(Clone, Debug)]
pub enum Response<T> {
    /// Result of [`Request::Spmv`].
    Spmv(RunResult<T>),
    /// Result of [`Request::Batch`] (one run per vector, input order).
    Batch(BatchResult<T>),
    /// Result of [`Request::Iterate`].
    Iterate(IterationsResult<T>),
    /// The request was shed by admission control: the tenant's queue
    /// was at its configured depth cap, so the request was answered
    /// immediately instead of queueing unboundedly. Typed — a shed is a
    /// normal response the caller must handle (back off and retry), not
    /// an `Err` and never a silent drop.
    Overloaded,
}

impl<T> Response<T> {
    /// Response kind name (logs, errors).
    pub fn kind(&self) -> &'static str {
        match self {
            Response::Spmv(_) => "spmv",
            Response::Batch(_) => "batch",
            Response::Iterate(_) => "iterate",
            Response::Overloaded => "overloaded",
        }
    }

    /// True when the request was shed by admission control.
    pub fn is_overloaded(&self) -> bool {
        matches!(self, Response::Overloaded)
    }

    /// Unwrap a [`Response::Spmv`].
    pub fn into_spmv(self) -> Result<RunResult<T>> {
        match self {
            Response::Spmv(r) => Ok(r),
            other => Err(crate::format_err!("expected an spmv response, got {}", other.kind())),
        }
    }

    /// Unwrap a [`Response::Batch`].
    pub fn into_batch(self) -> Result<BatchResult<T>> {
        match self {
            Response::Batch(b) => Ok(b),
            other => Err(crate::format_err!("expected a batch response, got {}", other.kind())),
        }
    }

    /// Unwrap a [`Response::Iterate`].
    pub fn into_iterations(self) -> Result<IterationsResult<T>> {
        match self {
            Response::Iterate(it) => Ok(it),
            other => {
                Err(crate::format_err!("expected an iterate response, got {}", other.kind()))
            }
        }
    }
}

/// Configuration for [`SpmvService`] (see [`SpmvService::builder`]).
#[derive(Clone, Debug)]
pub struct ServiceBuilder {
    engine: Engine,
    cache_capacity: usize,
    queue_depth: usize,
    block_policy: BlockPolicy,
    calibration: Option<Arc<CalibrationTable>>,
}

impl ServiceBuilder {
    /// Defaults: serial engine, [`DEFAULT_PLAN_CACHE_CAPACITY`] plans,
    /// [`DEFAULT_QUEUE_DEPTH`] queued requests, adaptive vector blocks,
    /// no calibration table.
    pub fn new() -> ServiceBuilder {
        ServiceBuilder {
            engine: Engine::Serial,
            cache_capacity: DEFAULT_PLAN_CACHE_CAPACITY,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            block_policy: BlockPolicy::Adaptive,
            calibration: None,
        }
    }

    /// Execution engine for per-DPU kernel simulations (never affects
    /// results, only wall-clock).
    pub fn engine(mut self, engine: Engine) -> ServiceBuilder {
        self.engine = engine;
        self
    }

    /// Shorthand for `engine(Engine::threaded(threads))` (0 = all
    /// hardware threads).
    pub fn threads(mut self, threads: usize) -> ServiceBuilder {
        self.engine = Engine::threaded(threads);
        self
    }

    /// Plan-cache capacity in plans (clamped to >= 1).
    pub fn cache_capacity(mut self, capacity: usize) -> ServiceBuilder {
        self.cache_capacity = capacity;
        self
    }

    /// Intake-queue depth: how many requests may sit between `submit`
    /// and the pipeline before `submit` blocks (clamped to >= 1).
    pub fn queue_depth(mut self, depth: usize) -> ServiceBuilder {
        self.queue_depth = depth;
        self
    }

    /// Vector-block policy for batched requests.
    pub fn vector_block(mut self, policy: BlockPolicy) -> ServiceBuilder {
        self.block_policy = policy;
        self
    }

    /// Attach a measured [`CalibrationTable`]
    /// (see [`super::tuner::tune`]): with [`BlockPolicy::Adaptive`],
    /// batched requests take their vector-block width from the table's
    /// nearest calibrated entry (matched by the loaded matrix's
    /// statistics, batch-aware) instead of the hand-tuned cutoffs.
    /// Block width never changes results, only wall-clock — so a
    /// calibrated service answers bit-identically to an uncalibrated
    /// one (locked by `tests/calibration.rs`).
    pub fn calibration(mut self, table: Arc<CalibrationTable>) -> ServiceBuilder {
        self.calibration = Some(table);
        self
    }

    /// Build a service over `sys` with its own plan cache.
    pub fn build<T: SpElem>(self, sys: PimSystem) -> Result<SpmvService<T>> {
        let cache = Arc::new(PlanCache::with_capacity(self.cache_capacity));
        self.build_with_cache(sys, cache)
    }

    /// Build a service over `sys` sharing an external plan cache —
    /// several services (e.g. per-tasklet-count sweeps over one bus
    /// shape) then plan each matrix exactly once between them.
    pub fn build_with_cache<T: SpElem>(
        self,
        sys: PimSystem,
        cache: Arc<PlanCache<T>>,
    ) -> Result<SpmvService<T>> {
        sys.cfg.validate()?;
        let exec = SpmvExecutor::with_engine(sys, self.engine);
        let queue = RequestQueue::spawn(exec.clone(), self.queue_depth);
        Ok(SpmvService {
            id: NEXT_SERVICE_ID.fetch_add(1, Ordering::Relaxed),
            exec,
            cache,
            plans: Mutex::new(HashMap::new()),
            handle_stats: Mutex::new(HashMap::new()),
            next_handle: AtomicU64::new(1),
            next_ticket: AtomicU64::new(1),
            sync_served: AtomicU64::new(0),
            block_policy: self.block_policy,
            calibration: self.calibration,
            queue,
        })
    }
}

impl Default for ServiceBuilder {
    fn default() -> ServiceBuilder {
        ServiceBuilder::new()
    }
}

/// A long-lived SpMV serving endpoint: resident matrices behind
/// [`MatrixHandle`]s, typed requests through a pipelined worker queue.
/// The service is `Sync` — one instance can take `load`/`submit`/`wait`
/// calls from many host threads concurrently.
pub struct SpmvService<T: SpElem> {
    id: u64,
    exec: SpmvExecutor,
    cache: Arc<PlanCache<T>>,
    plans: Mutex<HashMap<u64, Arc<ExecutionPlan<T>>>>,
    /// Per-handle sparsity statistics, populated at [`Self::load`] only
    /// when a calibration table is attached (they feed its lookups).
    handle_stats: Mutex<HashMap<u64, MatrixStats>>,
    next_handle: AtomicU64,
    next_ticket: AtomicU64,
    /// Requests served on the synchronous fast path ([`Self::spmv`] and
    /// friends), counted next to the queue's submitted/completed.
    sync_served: AtomicU64,
    block_policy: BlockPolicy,
    calibration: Option<Arc<CalibrationTable>>,
    queue: RequestQueue<T>,
}

impl<T: SpElem> SpmvService<T> {
    /// Start configuring a service.
    pub fn builder() -> ServiceBuilder {
        ServiceBuilder::new()
    }

    /// Register `m` under `spec`: plan (or fetch the cached plan for
    /// equal content) and pin it behind a handle. O(nnz) fingerprint +
    /// first-time planning cost; submissions against the handle are
    /// hash-free.
    pub fn load(&self, m: &CooMatrix<T>, spec: &KernelSpec) -> Result<MatrixHandle> {
        let plan = self.cache.plan(&self.exec, spec, m)?;
        let handle = MatrixHandle {
            svc: self.id,
            id: self.next_handle.fetch_add(1, Ordering::Relaxed),
            nrows: plan.nrows(),
            ncols: plan.ncols(),
        };
        if self.calibration.is_some() {
            // O(nnz) stats pass, once per load, only when a table will
            // actually consult them.
            self.handle_stats
                .lock()
                .expect("service registry poisoned")
                .insert(handle.id, MatrixStats::of(m));
        }
        self.plans.lock().expect("service registry poisoned").insert(handle.id, plan);
        Ok(handle)
    }

    /// Drop a handle's plan pin. Returns whether the handle was loaded.
    /// (The plan may stay resident in the cache for future loads.)
    pub fn unload(&self, handle: MatrixHandle) -> bool {
        if handle.svc != self.id {
            return false;
        }
        self.handle_stats.lock().expect("service registry poisoned").remove(&handle.id);
        self.plans.lock().expect("service registry poisoned").remove(&handle.id).is_some()
    }

    /// Enqueue `req` against `handle`. Validates shapes up front (a bad
    /// request fails here, not at `wait`), then hands the work to the
    /// pipelined request engine. Returns the claim [`Ticket`]; blocks
    /// only while the intake queue is at its configured depth.
    ///
    /// Every issued ticket should eventually be claimed with
    /// [`Self::wait`]: unclaimed responses park in the completion store
    /// (holding their output vectors) until the ticket is waited on or
    /// the service is dropped.
    ///
    /// ```
    /// use sparsep::coordinator::{KernelSpec, Request, ServiceBuilder};
    /// use sparsep::matrix::generate;
    /// use sparsep::pim::PimSystem;
    ///
    /// let svc = ServiceBuilder::new()
    ///     .threads(2)
    ///     .build::<f64>(PimSystem::with_dpus(4))
    ///     .unwrap();
    /// let m = generate::uniform::<f64>(64, 64, 4, 7);
    /// let h = svc.load(&m, &KernelSpec::csr_nnz()).unwrap();
    ///
    /// // Two tickets in flight at once, waited out of submission order.
    /// // Payloads are Arc<[T]> — Vec<T> converts in, and an Arc you
    /// // already hold is shared, never copied.
    /// let t1 = svc.submit(h, Request::spmv(vec![1.0; 64])).unwrap();
    /// let t2 = svc.submit(h, Request::batch(vec![vec![2.0; 64]; 3])).unwrap();
    /// let batch = svc.wait(t2).unwrap().into_batch().unwrap();
    /// let run = svc.wait(t1).unwrap().into_spmv().unwrap();
    ///
    /// assert_eq!(run.y, m.spmv(&vec![1.0; 64]));
    /// assert_eq!(batch.len(), 3);
    /// assert_eq!(batch.runs[0].y, m.spmv(&vec![2.0; 64]));
    /// ```
    pub fn submit(&self, handle: MatrixHandle, req: Request<T>) -> Result<Ticket> {
        let plan = self.plan_for(&handle)?;
        let check_len = |x: &[T], what: &str| {
            crate::ensure!(
                x.len() == plan.ncols(),
                "{what} length {} != ncols {}",
                x.len(),
                plan.ncols()
            );
            Ok(())
        };
        let (xs, iters, kind) = match req {
            Request::Spmv { x } => {
                check_len(&x, "x")?;
                (vec![x], 1, ResponseKind::Spmv)
            }
            Request::Batch { xs } => {
                for (i, x) in xs.iter().enumerate() {
                    check_len(x, &format!("xs[{i}]"))?;
                }
                (xs, 1, ResponseKind::Batch)
            }
            Request::Iterate { x, iters } => {
                check_len(&x, "x")?;
                crate::ensure!(iters >= 1, "Request::Iterate needs iters >= 1");
                crate::ensure!(
                    iters == 1 || plan.nrows() == plan.ncols(),
                    "iterated SpMV needs a square matrix, got {}x{}",
                    plan.nrows(),
                    plan.ncols()
                );
                (vec![x], iters, ResponseKind::Iterate)
            }
        };
        let ticket = Ticket { svc: self.id, id: self.next_ticket.fetch_add(1, Ordering::Relaxed) };
        self.queue.register(ticket.id);
        if xs.is_empty() {
            // An empty batch has nothing to pipeline: resolve it now.
            self.queue
                .publish_direct(ticket.id, Ok(Response::Batch(BatchResult { runs: Vec::new() })));
            return Ok(ticket);
        }
        let block = self.resolve_block(&handle, &plan, xs.len());
        self.queue.submit(Job { ticket: ticket.id, plan, xs, iters, block, kind })?;
        Ok(ticket)
    }

    /// Block until `ticket`'s response is ready and claim it. Tickets
    /// may be waited on in any order; waiting twice (or on a foreign
    /// ticket) is an error, not a hang.
    pub fn wait(&self, ticket: Ticket) -> Result<Response<T>> {
        crate::ensure!(ticket.svc == self.id, "ticket belongs to a different service");
        self.queue.wait(ticket.id)
    }

    /// Bounded [`Self::wait`]: blocks at most `timeout`, then returns a
    /// typed [`crate::util::ErrorKind::ShardTimeout`] error instead of
    /// hanging on a wedged pipeline. The ticket survives a timeout — a
    /// later `wait`/`try_wait` can still claim a late response.
    pub fn wait_timeout(
        &self,
        ticket: Ticket,
        timeout: std::time::Duration,
    ) -> Result<Response<T>> {
        crate::ensure!(ticket.svc == self.id, "ticket belongs to a different service");
        self.queue.wait_timeout(ticket.id, timeout)
    }

    /// Non-blocking poll: claim `ticket`'s response if it is ready
    /// (`Ok(Some)`), report "still in flight" (`Ok(None)`) otherwise.
    /// Unknown or already-claimed tickets are an error, exactly like
    /// [`Self::wait`]. This is the first step toward an async front
    /// end: one host thread can drive many tickets (or many services)
    /// by polling instead of parking a thread per response. A ticket
    /// claimed here must not be waited on again.
    pub fn try_wait(&self, ticket: Ticket) -> Result<Option<Response<T>>> {
        crate::ensure!(ticket.svc == self.id, "ticket belongs to a different service");
        self.queue.try_wait(ticket.id)
    }

    /// One SpMV against the handle, on the caller's thread — the
    /// synchronous **fast path**. A blocking caller has nothing for the
    /// pipeline to overlap, so this skips the queue round trip and the
    /// owned-vector copy; the result is bit-identical to
    /// `wait(submit(Request::Spmv))` (locked by
    /// `tests/service_equivalence.rs`). Iterative solvers call this in
    /// their hot loop.
    pub fn spmv(&self, handle: &MatrixHandle, x: &[T]) -> Result<RunResult<T>> {
        let plan = self.plan_for(handle)?;
        self.sync_served.fetch_add(1, Ordering::Relaxed);
        self.exec.execute_inner(&plan, x)
    }

    /// One batched request against the handle, on the caller's thread
    /// (synchronous fast path; see [`Self::spmv`]). Uses the same
    /// [`BlockPolicy`] as queued batches.
    pub fn spmv_batch(&self, handle: &MatrixHandle, xs: &[Vec<T>]) -> Result<BatchResult<T>> {
        let plan = self.plan_for(handle)?;
        let block = self.resolve_block(handle, &plan, xs.len());
        self.sync_served.fetch_add(1, Ordering::Relaxed);
        self.exec.execute_batch_inner(&plan, xs, block)
    }

    /// One iterated request against the handle, on the caller's thread
    /// (synchronous fast path; see [`Self::spmv`]).
    pub fn iterate(
        &self,
        handle: &MatrixHandle,
        x: &[T],
        iters: usize,
    ) -> Result<IterationsResult<T>> {
        let plan = self.plan_for(handle)?;
        self.sync_served.fetch_add(1, Ordering::Relaxed);
        self.exec.run_iterations_inner(&plan, x, iters)
    }

    /// The vector-block width this service would use for a
    /// `batch`-vector request against `handle` (diagnostics; the width
    /// never changes results).
    pub fn resolved_block(&self, handle: &MatrixHandle, batch: usize) -> Result<usize> {
        let plan = self.plan_for(handle)?;
        Ok(self.resolve_block(handle, &plan, batch))
    }

    /// Resolve the vector-block width for a `batch`-vector request
    /// against `handle`: when a calibration table is attached and the
    /// policy is [`BlockPolicy::Adaptive`], the width comes from the
    /// table's nearest measured entry (clamped to the batch);
    /// otherwise — `Fixed` policies, no table, or a handle loaded
    /// before the stats pass existed — the policy's own rule applies.
    fn resolve_block(&self, handle: &MatrixHandle, plan: &ExecutionPlan<T>, batch: usize) -> usize {
        if let (Some(table), BlockPolicy::Adaptive) = (&self.calibration, self.block_policy) {
            let stats = self.handle_stats.lock().expect("service registry poisoned");
            if let Some(e) = stats.get(&handle.id).and_then(|s| table.lookup(s, batch)) {
                return e.block.max(1).min(batch.max(1));
            }
        }
        self.block_policy.resolve(batch, Self::mean_slice_nnz(plan))
    }

    /// Look up a handle's resident plan (shared by `submit`, the fast
    /// path and diagnostics).
    fn plan_for(&self, handle: &MatrixHandle) -> Result<Arc<ExecutionPlan<T>>> {
        crate::ensure!(
            handle.svc == self.id,
            "matrix handle belongs to a different service"
        );
        self.plans
            .lock()
            .expect("service registry poisoned")
            .get(&handle.id)
            .cloned()
            .ok_or_else(|| crate::format_err!("unknown matrix handle (already unloaded?)"))
    }

    /// The configured vector-block policy.
    pub fn block_policy(&self) -> BlockPolicy {
        self.block_policy
    }

    /// The engine driving per-DPU kernel simulations.
    pub fn engine(&self) -> Engine {
        self.exec.engine
    }

    /// The simulated PIM system this service serves.
    pub fn system(&self) -> &PimSystem {
        &self.exec.sys
    }

    /// Service-level counters (requests, plan-cache traffic).
    /// Fast-path requests count as submitted-and-completed.
    pub fn stats(&self) -> ServiceStats {
        let sync = self.sync_served.load(Ordering::Relaxed);
        ServiceStats {
            submitted: self.queue.submitted() + sync,
            completed: self.queue.completed() + sync,
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            plan_builds: self.cache.builds(),
            resident_plans: self.cache.len(),
            loaded_handles: self.plans.lock().expect("service registry poisoned").len(),
        }
    }

    fn mean_slice_nnz(plan: &ExecutionPlan<T>) -> usize {
        plan.nnz() / plan.items().len().max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate;

    fn service(n_dpus: usize) -> SpmvService<f64> {
        ServiceBuilder::new().build(PimSystem::with_dpus(n_dpus)).unwrap()
    }

    #[test]
    fn load_submit_wait_roundtrip() {
        let svc = service(8);
        let m = generate::scale_free::<f64>(200, 200, 6, 0.6, 5);
        let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        assert_eq!((h.nrows(), h.ncols()), (200, 200));
        let x: Vec<f64> = (0..200).map(|i| ((i % 7) as f64) - 3.0).collect();
        let r = svc.spmv(&h, &x).unwrap();
        assert_eq!(r.y, m.spmv(&x));
        // The fast path answers bit-identically to submit + wait.
        let queued =
            svc.wait(svc.submit(h, Request::spmv(x.clone())).unwrap()).unwrap();
        match queued {
            Response::Spmv(q) => {
                assert_eq!(q.y, r.y);
                assert_eq!(q.breakdown, r.breakdown);
                assert_eq!(q.stats, r.stats);
                assert_eq!(q.energy, r.energy);
            }
            other => panic!("expected spmv, got {}", other.kind()),
        }
        let st = svc.stats();
        assert_eq!(st.submitted, 2, "fast path + queued request");
        assert_eq!(st.completed, 2);
        assert_eq!(st.in_flight(), 0);
        assert_eq!(st.loaded_handles, 1);
    }

    #[test]
    fn out_of_order_waits_resolve_correctly() {
        let svc = service(8);
        let m = generate::uniform::<f64>(96, 96, 4, 11);
        let h = svc.load(&m, &KernelSpec::csr_nnz()).unwrap();
        let xs: Vec<Vec<f64>> = (0..4)
            .map(|s| (0..96).map(|i| ((i + 11 * s) % 5) as f64 - 2.0).collect())
            .collect();
        let tickets: Vec<Ticket> =
            xs.iter().map(|x| svc.submit(h, Request::spmv(x.clone())).unwrap()).collect();
        // Claim in reverse submission order.
        for (x, t) in xs.iter().zip(&tickets).rev() {
            let r = svc.wait(*t).unwrap().into_spmv().unwrap();
            assert_eq!(r.y, m.spmv(x));
        }
        // A second wait on a claimed ticket errors instead of hanging.
        assert!(svc.wait(tickets[0]).is_err());
    }

    #[test]
    fn submit_validates_shapes_up_front() {
        let svc = service(4);
        let m = generate::uniform::<f64>(64, 64, 4, 3);
        let h = svc.load(&m, &KernelSpec::coo_row()).unwrap();
        assert!(svc.submit(h, Request::spmv(vec![0.0; 63])).is_err());
        assert!(svc
            .submit(h, Request::batch(vec![vec![0.0; 64], vec![0.0; 1]]))
            .is_err());
        assert!(svc.submit(h, Request::iterate(vec![0.0; 64], 0)).is_err());
        let rect = generate::uniform::<f64>(48, 64, 3, 3);
        let hr = svc.load(&rect, &KernelSpec::coo_row()).unwrap();
        assert!(svc.submit(hr, Request::iterate(vec![0.0; 64], 2)).is_err());
        assert!(svc.submit(hr, Request::iterate(vec![0.0; 64], 1)).is_ok());
    }

    #[test]
    fn try_wait_polls_to_the_same_response_as_wait() {
        let svc = service(8);
        let m = generate::uniform::<f64>(96, 96, 4, 19);
        let h = svc.load(&m, &KernelSpec::csr_nnz()).unwrap();
        let x: Vec<f64> = (0..96).map(|i| ((i % 5) as f64) - 2.0).collect();
        // Two identical requests: one claimed by blocking wait, one by
        // polling; the responses must be bit-identical.
        let t_wait = svc.submit(h, Request::spmv(x.clone())).unwrap();
        let t_poll = svc.submit(h, Request::spmv(x.clone())).unwrap();
        let gold = svc.wait(t_wait).unwrap().into_spmv().unwrap();
        let polled = loop {
            match svc.try_wait(t_poll).unwrap() {
                Some(resp) => break resp.into_spmv().unwrap(),
                None => std::thread::yield_now(),
            }
        };
        assert_eq!(polled.y, gold.y);
        assert_eq!(polled.breakdown, gold.breakdown);
        assert_eq!(polled.stats, gold.stats);
        assert_eq!(polled.energy, gold.energy);
        // The poll claimed the ticket: both further polls and waits err.
        assert!(svc.try_wait(t_poll).is_err());
        assert!(svc.wait(t_poll).is_err());
        // Foreign tickets are rejected up front.
        let other = service(8);
        assert!(other.try_wait(t_wait).is_err());
    }

    #[test]
    fn try_wait_reports_in_flight_without_claiming() {
        // Deep iterate request: the first poll(s) race the pipeline, so
        // Ok(None) must leave the ticket claimable.
        let svc = service(4);
        let m = generate::uniform::<f64>(64, 64, 4, 23);
        let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        let x = vec![1.0f64; 64];
        let t = svc.submit(h, Request::iterate(x.clone(), 8)).unwrap();
        let mut polls = 0usize;
        let resp = loop {
            match svc.try_wait(t).unwrap() {
                Some(resp) => break resp,
                None => {
                    polls += 1;
                    std::thread::yield_now();
                }
            }
        };
        let it = resp.into_iterations().unwrap();
        let mut want = x;
        for _ in 0..8 {
            want = m.spmv(&want);
        }
        assert_eq!(it.last.y, want);
        // polls is timing-dependent (>= 0); the point is no poll lost
        // the ticket before the response landed.
        let _ = polls;
    }

    #[test]
    fn empty_batch_resolves_immediately() {
        let svc = service(4);
        let m = generate::uniform::<f64>(32, 32, 3, 1);
        let h = svc.load(&m, &KernelSpec::coo_row()).unwrap();
        // Queued: resolved at submit time without touching the pipeline.
        let t = svc.submit(h, Request::Batch { xs: Vec::new() }).unwrap();
        assert!(svc.wait(t).unwrap().into_batch().unwrap().is_empty());
        // Fast path agrees.
        assert!(svc.spmv_batch(&h, &[]).unwrap().is_empty());
    }

    #[test]
    fn handles_and_tickets_are_service_scoped() {
        let a = service(4);
        let b = service(4);
        let m = generate::uniform::<f64>(32, 32, 3, 2);
        let ha = a.load(&m, &KernelSpec::coo_row()).unwrap();
        assert!(b.submit(ha, Request::spmv(vec![0.0; 32])).is_err());
        let ta = a.submit(ha, Request::spmv(vec![0.0; 32])).unwrap();
        assert!(b.wait(ta).is_err());
        assert!(a.wait(ta).is_ok());
        // Unloading invalidates the handle for new submissions.
        assert!(a.unload(ha));
        assert!(!a.unload(ha));
        assert!(a.submit(ha, Request::spmv(vec![0.0; 32])).is_err());
    }

    #[test]
    fn equal_matrices_share_one_plan_build() {
        let svc = service(8);
        let m = generate::uniform::<f64>(128, 128, 4, 9);
        let h1 = svc.load(&m, &KernelSpec::csr_nnz()).unwrap();
        let h2 = svc.load(&m.clone(), &KernelSpec::csr_nnz()).unwrap();
        assert_ne!(h1, h2, "handles are distinct registrations");
        let st = svc.stats();
        assert_eq!(st.plan_builds, 1, "equal content must not re-plan");
        assert_eq!(st.cache_hits, 1);
        assert_eq!(st.loaded_handles, 2);
    }

    #[test]
    fn block_policy_resolution() {
        assert_eq!(BlockPolicy::Fixed(0).resolve(10, 1000), 1);
        assert_eq!(BlockPolicy::Fixed(5).resolve(10, 1000), 5);
        assert_eq!(BlockPolicy::Adaptive.resolve(1, 1 << 20), 1);
        assert_eq!(BlockPolicy::Adaptive.resolve(3, 1 << 20), 3, "clamped to batch");
        assert_eq!(BlockPolicy::Adaptive.resolve(100, 1 << 20), 4 * VECTOR_BLOCK);
        assert_eq!(BlockPolicy::Adaptive.resolve(100, 1 << 13), 2 * VECTOR_BLOCK);
        assert_eq!(BlockPolicy::Adaptive.resolve(100, 1 << 10), VECTOR_BLOCK);
        assert_eq!(BlockPolicy::Adaptive.resolve(100, 10), VECTOR_BLOCK / 2);
    }

    #[test]
    fn calibrated_block_resolution_overrides_adaptive() {
        use crate::coordinator::calibration::{CalibrationEntry, CalibrationTable};
        use crate::matrix::MatrixStats;
        let m = generate::uniform::<f64>(128, 128, 4, 9);
        let st = MatrixStats::of(&m);
        let table = Arc::new(CalibrationTable::new(vec![CalibrationEntry {
            matrix: "probe".into(),
            class: st.class().into(),
            features: st.feature_vector(),
            batch: 8,
            kernel: "CSR.nnz".into(),
            stripes: 0,
            block: 5,
            shards: 1,
            grid_cols: 1,
            replicas: 1,
            wall_s: 1e-3,
            heuristic_wall_s: 2e-3,
        }]));
        let svc: SpmvService<f64> = ServiceBuilder::new()
            .calibration(Arc::clone(&table))
            .build(PimSystem::with_dpus(8))
            .unwrap();
        let h = svc.load(&m, &KernelSpec::csr_nnz()).unwrap();
        // Calibrated width, clamped to the batch.
        assert_eq!(svc.resolved_block(&h, 8).unwrap(), 5);
        assert_eq!(svc.resolved_block(&h, 3).unwrap(), 3, "clamped to batch");
        // Fixed policies ignore the table.
        let fixed: SpmvService<f64> = ServiceBuilder::new()
            .calibration(table)
            .vector_block(BlockPolicy::Fixed(2))
            .build(PimSystem::with_dpus(8))
            .unwrap();
        let hf = fixed.load(&m, &KernelSpec::csr_nnz()).unwrap();
        assert_eq!(fixed.resolved_block(&hf, 8).unwrap(), 2);
        // An empty table falls back to the hand-tuned adaptive rule —
        // identical to a service with no table at all.
        let plain = service(8);
        let hp = plain.load(&m, &KernelSpec::csr_nnz()).unwrap();
        let empty: SpmvService<f64> = ServiceBuilder::new()
            .calibration(Arc::new(CalibrationTable::default()))
            .build(PimSystem::with_dpus(8))
            .unwrap();
        let he = empty.load(&m, &KernelSpec::csr_nnz()).unwrap();
        assert_eq!(
            empty.resolved_block(&he, 8).unwrap(),
            plain.resolved_block(&hp, 8).unwrap()
        );
    }

    #[test]
    fn block_policies_do_not_change_results() {
        let m = generate::scale_free::<f64>(160, 160, 6, 0.7, 21);
        let xs: Vec<Vec<f64>> = (0..11)
            .map(|s| (0..160).map(|i| ((i + 3 * s) % 9) as f64 - 4.0).collect())
            .collect();
        let mut golds: Option<Vec<Vec<f64>>> = None;
        for policy in [
            BlockPolicy::Fixed(1),
            BlockPolicy::Fixed(3),
            BlockPolicy::Fixed(64),
            BlockPolicy::Adaptive,
        ] {
            let svc: SpmvService<f64> = ServiceBuilder::new()
                .vector_block(policy)
                .build(PimSystem::with_dpus(8))
                .unwrap();
            let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
            let b = svc.spmv_batch(&h, &xs).unwrap();
            let ys: Vec<Vec<f64>> = b.runs.iter().map(|r| r.y.clone()).collect();
            match &golds {
                None => golds = Some(ys),
                Some(g) => assert_eq!(&ys, g, "{policy:?} diverged"),
            }
        }
    }

    #[test]
    fn concurrent_submitters_share_one_service() {
        let svc = Arc::new(service(8));
        let m = generate::uniform::<f64>(120, 120, 5, 17);
        let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        std::thread::scope(|s| {
            for tid in 0..4usize {
                let svc = Arc::clone(&svc);
                let m = &m;
                s.spawn(move || {
                    for k in 0..3usize {
                        let x: Vec<f64> =
                            (0..120).map(|i| ((i + 7 * tid + k) % 5) as f64 - 2.0).collect();
                        let r = svc.spmv(&h, &x).unwrap();
                        assert_eq!(r.y, m.spmv(&x));
                    }
                });
            }
        });
        assert_eq!(svc.stats().completed, 12);
    }
}
