//! Calibration constants for the UPMEM PIM model.
//!
//! Every number in this file is an architecture-level parameter of the
//! real UPMEM system, taken from the PrIM characterization papers
//! (Gómez-Luna et al., "Benchmarking a New Paradigm: An Experimental
//! Analysis of a Real Processing-in-Memory Architecture", 2021 — refs
//! [9, 10] of the SparseP abstract), the UPMEM SDK documentation, and the
//! SparseP paper itself. The simulator is *analytic*: kernels count
//! operations and the model in [`super::dpu`] turns counts into cycles
//! using these constants. The paper's conclusions depend on the *ratios*
//! between these quantities (pipeline vs DMA vs bus), not their third
//! significant digit.

/// DPU clock frequency in Hz (UPMEM P21 silicon: 350 MHz).
pub const DPU_FREQ_HZ: f64 = 350.0e6;

/// Pipeline dispatch interval: the DPU core is a 14-stage fine-grained
/// multithreaded in-order pipeline in which the *same* tasklet can
/// dispatch a new instruction only every 11 cycles ("revolver"
/// scheduling). Consequence (PrIM §3.1.1): single-tasklet IPC = 1/11, and
/// the pipeline reaches its 1-instruction/cycle peak only with >= 11
/// active tasklets — the saturation knee of the paper's Fig. 5.
pub const DISPATCH_INTERVAL: u64 = 11;

/// Maximum hardware tasklets (threads) per DPU.
pub const MAX_TASKLETS: usize = 24;

/// WRAM (working SRAM scratchpad) per DPU, bytes.
pub const WRAM_BYTES: usize = 64 * 1024;

/// MRAM (DRAM bank) per DPU, bytes.
pub const MRAM_BYTES: usize = 64 * 1024 * 1024;

/// DPUs per rank (one PIM DIMM rank = 64 DPUs in the UPMEM system).
pub const DPUS_PER_RANK: usize = 64;

/// Full-system DPU count of the paper's testbed (20 DIMMs, 2560 DPUs;
/// 2432 usable in their setup — we expose the nominal 2560).
pub const MAX_SYSTEM_DPUS: usize = 2560;

// ---------------------------------------------------------------------
// MRAM <-> WRAM DMA model (PrIM §3.2: latency grows linearly with
// transfer size; the DMA engine is shared by all tasklets of a DPU, so
// concurrent accesses from different tasklets are *serialized* — the
// hardware fact behind the paper's "fine-grained locking does not help"
// recommendation #1 for hardware designers).
// ---------------------------------------------------------------------

/// Latency of one MRAM DMA transfer as seen by the *issuing tasklet*,
/// cycles (setup + row access + first word). While one tasklet waits,
/// the pipeline keeps running other tasklets — latency is overlappable;
/// engine occupancy (below) is not.
pub const MRAM_DMA_FIXED_CYCLES: u64 = 77;

/// DMA-engine occupancy per transfer, cycles: the arbitration + burst
/// setup time during which the single per-DPU DMA engine can serve no
/// one else. Concurrent accesses by different tasklets serialize on
/// this (PrIM §3.2) — the quantity that makes SpMV's per-element x
/// gathers memory-bound for narrow types.
pub const MRAM_DMA_ENGINE_CYCLES: u64 = 20;

/// Streaming cost per byte once a DMA burst is running, cycles/byte.
/// 0.5 cycles/byte = 2 B/cycle = 700 MB/s at 350 MHz, the PrIM-measured
/// large-transfer MRAM read bandwidth.
pub const MRAM_DMA_CYCLES_PER_BYTE: f64 = 0.5;

/// Minimum MRAM transfer granularity, bytes (UPMEM DMA: 8-byte aligned,
/// 8-byte minimum). An SpMV gather of a 4-byte x[col] still moves 8 bytes.
pub const MRAM_MIN_TRANSFER: usize = 8;

/// Maximum single DMA transfer size, bytes (UPMEM SDK: 2048).
pub const MRAM_MAX_TRANSFER: usize = 2048;

// ---------------------------------------------------------------------
// Intra-DPU synchronization costs (UPMEM SDK mutex/barrier primitives,
// measured in PrIM/SynCron-style microbenchmarks).
// ---------------------------------------------------------------------

/// Instructions to acquire an uncontended mutex.
pub const MUTEX_ACQUIRE_INSTRS: u64 = 7;

/// Instructions to release a mutex.
pub const MUTEX_RELEASE_INSTRS: u64 = 5;

/// Fixed cycles for a barrier among T tasklets is
/// `BARRIER_BASE_CYCLES + T * BARRIER_PER_TASKLET_CYCLES`.
pub const BARRIER_BASE_CYCLES: u64 = 20;
pub const BARRIER_PER_TASKLET_CYCLES: u64 = 6;

// ---------------------------------------------------------------------
// Host <-> PIM transfer model (PrIM §3.3). All transfers traverse the
// narrow off-chip DDR4 bus; the UPMEM runtime copies via the CPU. Rates
// in GB/s; latency is the fixed software+bus overhead per transfer call.
// ---------------------------------------------------------------------

/// Peak aggregate host->PIM bandwidth for *parallel* transfers
/// (different data to each DPU), GB/s. PrIM measures ~6.68 GB/s with all
/// ranks in flight.
pub const CPU_TO_DPU_PEAK_GBS: f64 = 6.68;

/// Peak aggregate PIM->host bandwidth (gather), GB/s (PrIM: ~4.74).
pub const DPU_TO_CPU_PEAK_GBS: f64 = 4.74;

/// Per-rank sustained bandwidth, GB/s. Aggregate scales with the number
/// of ranks in flight until it hits the peak above.
pub const CPU_TO_DPU_RANK_GBS: f64 = 0.42;
pub const DPU_TO_CPU_RANK_GBS: f64 = 0.30;

/// Broadcast (same buffer to every DPU) sustains a higher aggregate rate
/// because the source buffer stays hot in the CPU caches (PrIM: ~16.88
/// GB/s). The *per-bank* bytes are unchanged — which is exactly why 1D
/// SpMV, which broadcasts the whole input vector to every DPU, stops
/// scaling (paper's hardware recommendation #2).
pub const BROADCAST_PEAK_GBS: f64 = 16.88;
pub const BROADCAST_RANK_GBS: f64 = 1.05;

/// Fixed software overhead per transfer call (driver + rank setup), sec.
pub const TRANSFER_LATENCY_S: f64 = 20.0e-6;

// ---------------------------------------------------------------------
// Arithmetic cost model: instructions per multiply-accumulate, by type.
//
// The DPU has no FPU and only an 8x8-bit hardware multiplier, so wider
// multiplies and all floating-point are software-emulated by the
// compiler's runtime (PrIM §3.1.2, Fig. 7): throughput drops sharply
// from int8 to fp64. The numbers below are effective instruction counts
// per a*b+c including operand shuffling, derived from the PrIM
// arithmetic-throughput microbenchmarks (ops/s at 350 MHz with a full
// pipeline ~= 350e6 / instrs_per_op).
// ---------------------------------------------------------------------

use crate::matrix::DType;

/// Instructions for one multiply-accumulate of the given type.
pub fn mac_instrs(dt: DType) -> u64 {
    match dt {
        DType::I8 => 4,   // hw 8x8 multiplier + add
        DType::I16 => 6,  // 2 partial products
        DType::I32 => 12, // 4 partial products + carries
        DType::I64 => 28, // 16 partial products + carries
        DType::F32 => 52, // sw float: unpack, align, multiply, normalize
        DType::F64 => 116,
    }
}

/// Instructions for one addition of the given type (used by merge-style
/// kernel phases and the tree reductions of 2D kernels).
pub fn add_instrs(dt: DType) -> u64 {
    match dt {
        DType::I8 | DType::I16 | DType::I32 => 1,
        DType::I64 => 2,
        DType::F32 => 20,
        DType::F64 => 42,
    }
}

/// Per-element loop overhead of an SpMV inner loop (index load from the
/// streamed WRAM tile, pointer bump, loop branch), instructions.
pub const ELEM_LOOP_INSTRS: u64 = 6;

/// Per-row overhead (row setup, accumulator init, y store bookkeeping).
pub const ROW_LOOP_INSTRS: u64 = 12;

/// Per-block overhead of the BCSR/BCOO kernels (block header decode,
/// base-pointer computation).
pub const BLOCK_LOOP_INSTRS: u64 = 14;

// ---------------------------------------------------------------------
// Energy model (J). UPMEM power from the vendor's DIMM specs; CPU/GPU
// comparison points use TDP-style figures like the paper's Table 3.
// ---------------------------------------------------------------------

/// Active power of one DPU core + its bank interface, watts.
/// (~23 W per 128-DPU DIMM => ~0.18 W/DPU at full activity.)
pub const DPU_ACTIVE_WATTS: f64 = 0.18;

/// Idle power of one DPU, watts.
pub const DPU_IDLE_WATTS: f64 = 0.02;

/// Energy per byte moved over the host<->PIM bus, joules (DDR4 access +
/// copy overheads, ~15 pJ/bit).
pub const BUS_ENERGY_J_PER_BYTE: f64 = 15.0e-12 * 8.0;

/// Host-side merge bandwidth for reducing 2D partial results, GB/s
/// (single-socket streaming add over gathered buffers).
pub const HOST_MERGE_GBS: f64 = 8.0;

/// Host CPU package power while driving transfers/merge, watts.
pub const HOST_ACTIVE_WATTS: f64 = 105.0;

/// Paper's CPU comparison point (Intel Xeon Silver 4110-class TDP).
pub const CPU_TDP_WATTS: f64 = 85.0;

/// Paper's GPU comparison point (NVIDIA Tesla V100 TDP).
pub const GPU_TDP_WATTS: f64 = 300.0;

// ---------------------------------------------------------------------
// Peak-performance figures for the fraction-of-peak analysis (paper's
// Fig. 16 / Table 3: SpMV reaches ~51.7% of the UPMEM system's fp32
// peak vs a few percent on CPU/GPU, because the PIM system's compute
// peak is tiny relative to its aggregate bank bandwidth).
// ---------------------------------------------------------------------

/// Peak fp32 GFLOP/s of one DPU: 350 MHz / 52 instr per MAC * 2 flops.
pub fn dpu_peak_gflops(dt: DType) -> f64 {
    DPU_FREQ_HZ / mac_instrs(dt) as f64 * 2.0 / 1e9
}

/// Paper-testbed CPU peak (Xeon Silver 4110, 2 sockets: ~0.66 TFLOP/s
/// fp32) and memory bandwidth (~23.1 GB/s measured stream).
pub const CPU_PEAK_GFLOPS_F32: f64 = 660.0;
pub const CPU_MEM_BW_GBS: f64 = 23.1;

/// Paper-testbed GPU peak (V100: 14 TFLOP/s fp32, 900 GB/s HBM2).
pub const GPU_PEAK_GFLOPS_F32: f64 = 14_000.0;
pub const GPU_MEM_BW_GBS: f64 = 900.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_cost_ordering_matches_paper() {
        // Fig. 7 ordering: int8 < int16 < int32 < int64 < fp32 < fp64.
        let order = [DType::I8, DType::I16, DType::I32, DType::I64, DType::F32, DType::F64];
        for w in order.windows(2) {
            assert!(
                mac_instrs(w[0]) < mac_instrs(w[1]),
                "{:?} should cost less than {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn dpu_peak_is_small() {
        // One DPU's fp32 peak is ~0.013 GFLOP/s: the whole point of the
        // paper's fraction-of-peak argument.
        let p = dpu_peak_gflops(DType::F32);
        assert!(p > 0.005 && p < 0.05, "dpu fp32 peak {p}");
        // 2560 DPUs: tens of GFLOP/s system peak, vs 14 TFLOP/s for V100.
        assert!(p * (MAX_SYSTEM_DPUS as f64) < GPU_PEAK_GFLOPS_F32 / 100.0);
    }

    #[test]
    fn broadcast_faster_than_parallel() {
        assert!(BROADCAST_PEAK_GBS > CPU_TO_DPU_PEAK_GBS);
        assert!(CPU_TO_DPU_PEAK_GBS > DPU_TO_CPU_PEAK_GBS);
    }
}
