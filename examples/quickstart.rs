//! Quickstart: run one SpMV on the simulated PIM system and read the
//! paper-style breakdown.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use sparsep::coordinator::{KernelSpec, SpmvExecutor};
use sparsep::matrix::generate;
use sparsep::pim::PimSystem;

fn main() -> anyhow::Result<()> {
    // 1. A sparse matrix. Generators mirror the paper's two matrix
    //    classes; @file.mtx loading is available via matrix::mtx.
    let m = generate::scale_free::<f32>(8192, 8192, 10, 0.6, 42);
    println!(
        "matrix: {}x{}, {} nnz (scale-free class)",
        m.nrows(),
        m.ncols(),
        m.nnz()
    );

    // 2. A PIM system: 256 DPUs, 16 tasklets each (UPMEM defaults).
    let exec = SpmvExecutor::new(PimSystem::with_dpus(256));

    // 3. Pick a kernel from the 25 (here: COO with nnz balancing) and run.
    let x = vec![1.0f32; m.ncols()];
    let run = exec.run(&KernelSpec::coo_nnz_rgrn(), &m, &x)?;

    // 4. Exact result + modeled breakdown.
    assert_eq!(run.y, m.spmv(&x), "simulator output is exact");
    let b = run.breakdown;
    println!("verified: output matches host oracle");
    println!(
        "breakdown: load {:.3} ms | kernel {:.3} ms | retrieve {:.3} ms ({} dominated)",
        b.load_s * 1e3,
        b.kernel_s * 1e3,
        b.retrieve_s * 1e3,
        b.dominant()
    );
    println!(
        "kernel {:.2} GFLOP/s | e2e {:.2} GFLOP/s | imbalance {:.2}x | energy {:.2e} J",
        run.kernel_gflops(),
        run.e2e_gflops(),
        run.stats.dpu_imbalance,
        run.energy.total_j()
    );

    // 5. The same matrix through every kernel family, one line each.
    println!("\nall-25 sweep (total end-to-end ms):");
    for spec in KernelSpec::all25(8) {
        let r = exec.run(&spec, &m, &x)?;
        println!("  {:<14} {:>9.3} ms", spec.name, r.breakdown.total_s() * 1e3);
    }
    Ok(())
}
