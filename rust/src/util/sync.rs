//! Loom-checkable synchronization facade.
//!
//! Every host-side synchronization primitive in this crate goes through
//! this module instead of `std::sync` directly. Normally the types here
//! are thin zero-cost wrappers over `std`; under `--cfg loom` they
//! switch to [loom](https://docs.rs/loom)'s model-checked versions, so
//! the concurrency protocols in `coordinator::{engine, queue, cache,
//! shard}` can be explored exhaustively over all legal interleavings
//! (`rust/tests/loom_models.rs`, run by `scripts/analyze.sh`).
//!
//! The wrappers are deliberately *new types*, not re-exports: the
//! repo-wide `clippy.toml` `disallowed-types` gate forbids raw
//! `std::sync::{Mutex, RwLock, Condvar}` (and raw thread spawns) by
//! definition-id, and a plain re-export would share the forbidden id.
//! Only this module carries the `allow`.
//!
//! Documented deviations from a "pure" loom facade:
//!
//! * [`Arc`] is always `std::sync::Arc`, even under loom. Loom's `Arc`
//!   cannot coerce to unsized `Arc<[T]>` / `Arc<str>`, which the
//!   zero-copy payload path depends on. This is sound for the models we
//!   check: every protocol's synchronization flows through the facade's
//!   `Mutex`/`Condvar`/atomics, never through `Arc`'s reference count.
//! * [`mpsc`] is always `std`'s. The staged request queue's hand-off
//!   channels are not loom-modeled (loom has no mpsc); the modeled
//!   protocols (`Completions`, the worker pool, the recycle pool, the
//!   respawn slot) drive their sharing through facade primitives.
//! * Statics that need a `const` constructor (the process-wide service
//!   and facade id counters) stay on `std::sync::atomic` by full path —
//!   loom atomics have non-`const` `new`. Atomics are not on the
//!   disallow list for exactly this reason.

#![allow(clippy::disallowed_types, clippy::disallowed_methods)]

use std::fmt;
use std::time::Duration;

pub use std::sync::Arc;
pub use std::sync::mpsc;
pub use std::sync::{LockResult, PoisonError};

#[cfg(not(loom))]
use std::sync as imp;

#[cfg(loom)]
use loom::sync as imp;

/// The guard type returned by [`Mutex::lock`] (std's or loom's).
pub type MutexGuard<'a, T> = imp::MutexGuard<'a, T>;
/// The guard type returned by [`RwLock::read`].
pub type RwLockReadGuard<'a, T> = imp::RwLockReadGuard<'a, T>;
/// The guard type returned by [`RwLock::write`].
pub type RwLockWriteGuard<'a, T> = imp::RwLockWriteGuard<'a, T>;
/// Returned by [`Condvar::wait_timeout`]; `timed_out()` distinguishes
/// deadline expiry from a notification (under loom the expiry branch is
/// explored nondeterministically — there is no virtual clock).
pub type WaitTimeoutResult = imp::WaitTimeoutResult;

/// Mutual exclusion — `std::sync::Mutex` normally, `loom::sync::Mutex`
/// under `--cfg loom`.
pub struct Mutex<T>(imp::Mutex<T>);

impl<T> Mutex<T> {
    /// Create an unlocked mutex. Not `const` (loom's isn't): statics
    /// wanting a mutex lazily initialize through `OnceLock`.
    pub fn new(value: T) -> Self {
        Mutex(imp::Mutex::new(value))
    }

    /// Block until the lock is held.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        self.0.lock()
    }

    /// Consume the mutex, returning the inner value.
    pub fn into_inner(self) -> LockResult<T> {
        self.0.into_inner()
    }
}

impl<T> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad("Mutex")
    }
}

/// Reader-writer lock — `std::sync::RwLock` normally, loom's under
/// `--cfg loom`.
pub struct RwLock<T>(imp::RwLock<T>);

impl<T> RwLock<T> {
    /// Create an unlocked lock.
    pub fn new(value: T) -> Self {
        RwLock(imp::RwLock::new(value))
    }

    /// Block until a shared read guard is held.
    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        self.0.read()
    }

    /// Block until the exclusive write guard is held.
    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        self.0.write()
    }
}

impl<T> fmt::Debug for RwLock<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad("RwLock")
    }
}

/// Condition variable — `std::sync::Condvar` normally, loom's under
/// `--cfg loom`. All waits in this crate are predicate-guarded loops
/// (spurious wakes are always legal), which is also what makes them
/// loom-explorable.
pub struct Condvar(imp::Condvar);

impl Condvar {
    /// Create a condition variable.
    pub fn new() -> Self {
        Condvar(imp::Condvar::new())
    }

    /// Atomically release the guard and block until notified.
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        self.0.wait(guard)
    }

    /// [`Condvar::wait`] bounded by `timeout`. Under loom the duration
    /// is ignored and the timed-out branch is explored as one more
    /// nondeterministic outcome.
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        timeout: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        self.0.wait_timeout(guard, timeout)
    }

    /// Wake one waiter.
    pub fn notify_one(&self) {
        self.0.notify_one();
    }

    /// Wake every waiter.
    pub fn notify_all(&self) {
        self.0.notify_all();
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

impl fmt::Debug for Condvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad("Condvar")
    }
}

/// Atomics — std's normally, loom's under `--cfg loom`. `Ordering` is
/// the same enum either way (loom re-exports core's).
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

/// Thread handling: named spawns (every long-lived thread in this crate
/// has a `sparsep-`/`spmv-` name for debuggers and sanitizer reports)
/// plus the handful of scheduling hints the serving stack uses.
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::JoinHandle;

    #[cfg(loom)]
    pub use loom::thread::JoinHandle;

    /// Spawn a named thread. Panics if the OS refuses the spawn (an
    /// OOM-class failure every caller previously `expect`ed anyway).
    /// Under loom the name is dropped (loom threads are anonymous) and
    /// the thread participates in model exploration.
    #[cfg(not(loom))]
    pub fn spawn_named<F, T>(name: &str, f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        std::thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .unwrap_or_else(|e| panic!("failed to spawn thread {name}: {e}"))
    }

    /// Loom twin of [`spawn_named`]: the name is dropped.
    #[cfg(loom)]
    pub fn spawn_named<F, T>(name: &str, f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let _ = name;
        loom::thread::spawn(f)
    }

    /// Yield the scheduler (a loom exploration point under `--cfg loom`).
    pub fn yield_now() {
        #[cfg(not(loom))]
        std::thread::yield_now();
        #[cfg(loom)]
        loom::thread::yield_now();
    }
}

/// A supervised, respawnable slot: a value behind a reader-writer lock
/// plus an atomic dead flag. This is the shard-supervision protocol
/// (`coordinator::shard::Backends`) extracted so its exactly-one-respawn
/// guarantee can be model-checked in isolation
/// (`rust/tests/loom_models.rs::respawn_slot_respawns_exactly_once`).
///
/// Protocol: readers take the read lock ([`RespawnSlot::read`]) and
/// never observe a half-rebuilt value. [`RespawnSlot::kill`] marks the
/// slot dead without touching the value. [`RespawnSlot::ensure_alive`]
/// is the double-checked respawn: a fast dead-flag load, then the write
/// lock, then a *re-check* of the flag under the lock — so when many
/// threads race `ensure_alive`, exactly one runs the rebuild closure
/// and the rest see the flag already cleared. A failed rebuild leaves
/// the flag set (the next caller retries) and propagates the error.
pub struct RespawnSlot<S> {
    slot: RwLock<S>,
    dead: atomic::AtomicBool,
}

impl<S> RespawnSlot<S> {
    /// A live slot holding `value`.
    pub fn new(value: S) -> Self {
        RespawnSlot {
            slot: RwLock::new(value),
            dead: atomic::AtomicBool::new(false),
        }
    }

    /// Shared access to the current value (alive or not — killing a
    /// slot does not invalidate the value, it schedules a rebuild).
    pub fn read(&self) -> RwLockReadGuard<'_, S> {
        self.slot.read().expect("respawn slot poisoned")
    }

    /// Mark the slot dead; the next [`RespawnSlot::ensure_alive`]
    /// rebuilds it.
    pub fn kill(&self) {
        self.dead.store(true, atomic::Ordering::SeqCst);
    }

    /// Is the slot currently marked dead?
    pub fn is_dead(&self) -> bool {
        self.dead.load(atomic::Ordering::SeqCst)
    }

    /// Rebuild the value if (and only if) the slot is dead. Returns
    /// `Ok(true)` iff *this* call ran `rebuild`; racing callers that
    /// lose the write-lock race return `Ok(false)` once the winner has
    /// cleared the flag. On `Err` the flag stays set and the error
    /// propagates.
    pub fn ensure_alive<E>(&self, rebuild: impl FnOnce(&mut S) -> Result<(), E>) -> Result<bool, E> {
        if !self.is_dead() {
            return Ok(false);
        }
        let mut slot = self.slot.write().expect("respawn slot poisoned");
        // Re-check under the write lock: a racing respawner may have
        // rebuilt (and cleared the flag) while we queued for the lock.
        if !self.is_dead() {
            return Ok(false);
        }
        rebuild(&mut slot)?;
        self.dead.store(false, atomic::Ordering::SeqCst);
        Ok(true)
    }
}

impl<S> fmt::Debug for RespawnSlot<S> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RespawnSlot").field("dead", &self.is_dead()).finish()
    }
}

/// An `n`-way reduction rendezvous: `n` partial results are published
/// by index — in any arrival order, at most once per index — and then
/// claimed as a single index-ordered vector. This is the 2D grid
/// gather's accumulation slot (`coordinator::shard`'s column reduction
/// assembles one row band's partials through it before summing them in
/// fixed column order), extracted so its exactly-once / index-order /
/// no-lost-wakeup contract can be model-checked in isolation
/// (`rust/tests/loom_models.rs::reduce_slot_*`).
///
/// Protocol: [`ReduceSlot::publish`] stores index `i`'s partial under
/// the mutex and returns whether this call filled the slot (a racing
/// duplicate publish returns `false` and its value is dropped — the sum
/// downstream sees each partial exactly once). The publish that fills
/// the *last* empty index notifies the waiter; [`ReduceSlot::wait_all`]
/// is a predicate-guarded wait (`filled == n`), so the wakeup cannot be
/// lost to the publish/wait race. Today's facade gather claims partials
/// in tile order on a single thread, so the slot degenerates to an
/// ordered hand-off; the contract exists (and is loom-checked) so the
/// reduction stays correct under any future concurrent claim order.
pub struct ReduceSlot<P> {
    state: Mutex<ReduceState<P>>,
    all_in: Condvar,
}

struct ReduceState<P> {
    parts: Vec<Option<P>>,
    filled: usize,
}

impl<P> ReduceSlot<P> {
    /// An empty slot awaiting `n` partials (indices `0..n`).
    pub fn new(n: usize) -> Self {
        ReduceSlot {
            state: Mutex::new(ReduceState {
                parts: (0..n).map(|_| None).collect(),
                filled: 0,
            }),
            all_in: Condvar::new(),
        }
    }

    /// How many partials the slot collects.
    pub fn len(&self) -> usize {
        self.state.lock().expect("reduce slot poisoned").parts.len()
    }

    /// `true` iff the slot collects zero partials.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Publish index `i`'s partial. Returns `true` iff this call stored
    /// the value; a duplicate publish for an already-filled index
    /// returns `false` and drops `part` (exactly-once accumulation).
    /// The call that fills the last empty index wakes the waiter.
    ///
    /// Panics if `i >= n`.
    pub fn publish(&self, i: usize, part: P) -> bool {
        let mut st = self.state.lock().expect("reduce slot poisoned");
        if st.parts[i].is_some() {
            return false;
        }
        st.parts[i] = Some(part);
        st.filled += 1;
        let complete = st.filled == st.parts.len();
        drop(st);
        if complete {
            self.all_in.notify_all();
        }
        true
    }

    /// Block until every index is filled, then take all partials in
    /// index order (regardless of arrival order). Single-consumer:
    /// panics if the slot was already claimed.
    pub fn wait_all(&self) -> Vec<P> {
        let mut st = self.state.lock().expect("reduce slot poisoned");
        while st.filled < st.parts.len() {
            st = self.all_in.wait(st).expect("reduce slot poisoned");
        }
        st.parts
            .iter_mut()
            .map(|p| p.take().expect("reduce slot already claimed"))
            .collect()
    }
}

impl<P> fmt::Debug for ReduceSlot<P> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let st = self.state.lock().expect("reduce slot poisoned");
        f.debug_struct("ReduceSlot")
            .field("n", &st.parts.len())
            .field("filled", &st.filled)
            .finish()
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn facade_mutex_condvar_roundtrip() {
        let m = Mutex::new(0usize);
        let cv = Condvar::new();
        {
            let mut g = m.lock().unwrap();
            *g = 7;
            cv.notify_all(); // no waiters: must not block or panic
        }
        assert_eq!(m.into_inner().unwrap(), 7);
    }

    #[test]
    fn reduce_slot_orders_and_deduplicates_partials() {
        let slot: ReduceSlot<u32> = ReduceSlot::new(3);
        assert_eq!(slot.len(), 3);
        assert!(!slot.is_empty());
        // Out-of-order publishes; wait_all returns index order.
        assert!(slot.publish(2, 22));
        assert!(slot.publish(0, 10));
        // Duplicate publish is rejected (exactly-once accumulation).
        assert!(!slot.publish(0, 99));
        assert!(slot.publish(1, 11));
        assert_eq!(slot.wait_all(), vec![10, 11, 22]);
        // Zero-partial slot completes immediately.
        let empty: ReduceSlot<u32> = ReduceSlot::new(0);
        assert!(empty.is_empty());
        assert_eq!(empty.wait_all(), Vec::<u32>::new());
        // Publishers on another thread: the waiter sees all partials.
        let shared = Arc::new(ReduceSlot::new(2));
        let pusher = {
            let s = Arc::clone(&shared);
            thread::spawn_named("reduce-pub", move || {
                assert!(s.publish(1, 5));
                assert!(s.publish(0, 4));
            })
        };
        assert_eq!(shared.wait_all(), vec![4, 5]);
        pusher.join().unwrap();
    }

    #[test]
    fn respawn_slot_double_checked_protocol() {
        let slot = RespawnSlot::new(1u32);
        assert!(!slot.is_dead());
        assert_eq!(*slot.read(), 1);
        // ensure_alive on a live slot never runs the rebuild.
        let ran = slot.ensure_alive(|_| -> Result<(), ()> { panic!("must not rebuild") });
        assert_eq!(ran, Ok(false));
        // Killed: the next ensure_alive rebuilds exactly once.
        slot.kill();
        assert!(slot.is_dead());
        assert_eq!(slot.ensure_alive(|v| -> Result<(), ()> {
            *v = 2;
            Ok(())
        }), Ok(true));
        assert!(!slot.is_dead());
        assert_eq!(*slot.read(), 2);
        // A failed rebuild leaves the slot dead for the next caller.
        slot.kill();
        assert_eq!(slot.ensure_alive(|_| Err("boom")), Err("boom"));
        assert!(slot.is_dead());
        assert_eq!(slot.ensure_alive(|v| -> Result<(), &str> {
            *v = 3;
            Ok(())
        }), Ok(true));
        assert_eq!(*slot.read(), 3);
    }
}
