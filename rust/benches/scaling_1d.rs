//! Bench E5: 1D scaling with the number of DPUs (paper Fig. 9),
//! kernel-only throughput for row- vs nnz-balanced kernels.

mod common;
use sparsep::bench_harness::figures;

fn main() {
    common::banner("scaling_1d", "Fig. 9 1D kernel-only scaling");
    common::timed("e5_scaling_1d", || {
        figures::e5_scaling_1d(common::scale());
    });
}
