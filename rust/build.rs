fn main() {
    // Declare `--cfg loom` (set by scripts/analyze.sh for the model
    // suite) so `unexpected_cfgs` stays quiet under `-D warnings` on
    // rustc >= 1.80; older cargos ignore unknown build-script output.
    println!("cargo:rustc-check-cfg=cfg(loom)");
}
