//! PageRank power iteration on the PIM executor (graph-analytics
//! workload — the scale-free matrices of the paper's suite are exactly
//! web/social graph adjacency structures).

use super::{norm2, SolveStats};
use crate::coordinator::{KernelSpec, SpmvExecutor};
use crate::matrix::CooMatrix;
use crate::util::Result;

/// PageRank outcome.
#[derive(Clone, Debug)]
pub struct PageRankResult {
    pub ranks: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    pub stats: SolveStats,
}

/// Column-stochastic transition matrix from an adjacency pattern:
/// `P[j,i] = 1/outdeg(i)` for each edge i->j (value sign/magnitude of
/// the input is ignored; the pattern is the graph).
pub fn transition_matrix(adj: &CooMatrix<f64>) -> CooMatrix<f64> {
    let n = adj.nrows().max(adj.ncols());
    let mut outdeg = vec![0usize; n];
    for (r, _c, _v) in adj.iter() {
        outdeg[r as usize] += 1;
    }
    let triples = adj
        .iter()
        .map(|(r, c, _v)| (c, r, 1.0 / outdeg[r as usize] as f64))
        .collect();
    CooMatrix::from_triples(n, n, triples)
}

/// Power iteration: `rank = d * P * rank + (1-d)/n`, until the L1 delta
/// falls below `tol`.
pub fn pagerank(
    exec: &SpmvExecutor,
    spec: &KernelSpec,
    p: &CooMatrix<f64>,
    damping: f64,
    tol: f64,
    max_iters: usize,
) -> Result<PageRankResult> {
    crate::ensure!(p.nrows() == p.ncols(), "transition matrix must be square");
    let n = p.nrows();
    // Plan once: the transition matrix is fixed across power iterations.
    let plan = exec.plan(spec, p)?;
    let mut stats = SolveStats::default();
    let mut rank = vec![1.0 / n as f64; n];
    let teleport = (1.0 - damping) / n as f64;
    let mut converged = false;
    let mut iterations = 0;

    for _ in 0..max_iters {
        let run = exec.execute(&plan, &rank)?;
        stats.absorb(&run);
        let mut next: Vec<f64> = run.y.iter().map(|v| damping * v + teleport).collect();
        // Redistribute dangling mass so the vector stays a distribution.
        let mass: f64 = next.iter().sum();
        let fix = (1.0 - mass) / n as f64;
        for v in next.iter_mut() {
            *v += fix;
        }
        let delta: f64 = next.iter().zip(&rank).map(|(a, b)| (a - b).abs()).sum();
        rank = next;
        iterations += 1;
        if delta < tol {
            converged = true;
            break;
        }
    }
    Ok(PageRankResult { ranks: rank, iterations, converged, stats })
}

/// Host-only oracle for tests.
pub fn pagerank_host(p: &CooMatrix<f64>, damping: f64, tol: f64, max_iters: usize) -> Vec<f64> {
    let n = p.nrows();
    let mut rank = vec![1.0 / n as f64; n];
    let teleport = (1.0 - damping) / n as f64;
    for _ in 0..max_iters {
        let y = p.spmv(&rank);
        let mut next: Vec<f64> = y.iter().map(|v| damping * v + teleport).collect();
        let mass: f64 = next.iter().sum();
        let fix = (1.0 - mass) / n as f64;
        for v in next.iter_mut() {
            *v += fix;
        }
        let delta: f64 = next.iter().zip(&rank).map(|(a, b)| (a - b).abs()).sum();
        rank = next;
        if delta < tol {
            break;
        }
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate;
    use crate::pim::PimSystem;

    #[test]
    fn pagerank_matches_host_oracle_exactly() {
        let adj = generate::scale_free::<f64>(400, 400, 6, 0.6, 3);
        let p = transition_matrix(&adj);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(16));
        let res = pagerank(&exec, &KernelSpec::coo_nnz(), &p, 0.85, 1e-10, 100).unwrap();
        let oracle = pagerank_host(&p, 0.85, 1e-10, 100);
        // The PIM SpMV computes the same sums in a different association
        // order (per-DPU partials), so match to float round-off.
        for i in 0..400 {
            assert!(
                (res.ranks[i] - oracle[i]).abs() <= 1e-12 * oracle[i].abs().max(1e-12),
                "rank {i}: {} vs {}",
                res.ranks[i],
                oracle[i]
            );
        }
        assert!(res.converged);
    }

    #[test]
    fn ranks_form_a_distribution() {
        let adj = generate::uniform::<f64>(200, 200, 5, 9);
        let p = transition_matrix(&adj);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        let res = pagerank(&exec, &KernelSpec::coo_nnz_rgrn(), &p, 0.85, 1e-9, 200).unwrap();
        let sum: f64 = res.ranks.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "mass {sum}");
        assert!(res.ranks.iter().all(|&r| r >= 0.0));
    }

    #[test]
    fn hub_nodes_rank_higher() {
        // Star graph: everything points at node 0.
        let triples: Vec<(u32, u32, f64)> = (1..100u32).map(|i| (i, 0, 1.0)).collect();
        let adj = crate::matrix::CooMatrix::from_triples(100, 100, triples);
        let p = transition_matrix(&adj);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(4));
        let res = pagerank(&exec, &KernelSpec::coo_nnz(), &p, 0.85, 1e-12, 200).unwrap();
        for i in 1..100 {
            assert!(res.ranks[0] > res.ranks[i], "hub must out-rank leaf {i}");
        }
    }
}
