//! Shared bench plumbing: scale from env, banner, wall-clock wrapper.

use sparsep::bench_harness::figures::Scale;

/// Bench scale from `SPARSEP_BENCH_SCALE` (default 0.25: the full paper
/// sweep at ~1/4 matrix linear size; 1.0 regenerates the DESIGN.md-sized
/// evaluation and takes a few minutes).
pub fn scale() -> Scale {
    Scale(
        std::env::var("SPARSEP_BENCH_SCALE")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.25),
    )
}

pub fn banner(name: &str, what: &str) {
    println!("\n################################################################");
    println!("# bench {name}: {what}");
    println!("# (scale={}; set SPARSEP_BENCH_SCALE to change)", scale().0);
    println!("################################################################");
}

/// Time a whole driver once and report (drivers print their own tables).
pub fn timed<F: FnOnce()>(label: &str, f: F) {
    let t0 = std::time::Instant::now();
    f();
    println!("[bench-wall] {label}: {:.2}s", t0.elapsed().as_secs_f64());
}
