//! Property tests for the execution engines: `SerialEngine`, the legacy
//! spawn-per-wave `ThreadedEngine`, and the persistent worker-pool
//! `PooledEngine` (the threaded default) must produce bit-identical
//! `RunResult`s — output vector, breakdown, stats (cycles included) and
//! energy — across formats x balancing schemes x sync schemes x thread
//! counts, on both canonical and randomized inputs. The engines only
//! move *where* the per-DPU simulations run; any divergence is a
//! determinism bug.

// These suites deliberately exercise `SpmvExecutor`'s deprecated
// compatibility wrappers (`execute` / `execute_batch` / `run_iterations`
// / `run_iterations_batch` / `run`): they lock the wrappers' behavior
// until a future major removal. New code routes through
// `coordinator::SpmvService` or `ExecutionPlan::{execute, ...}`.
#![allow(deprecated)]

use sparsep::coordinator::{Engine, KernelSpec, Partitioning, RunResult, SpmvExecutor};
use sparsep::kernels::SyncScheme;
use sparsep::matrix::{CooMatrix, SpElem};
use sparsep::pim::{PimConfig, PimSystem};
use sparsep::util::rng::Rng;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

fn assert_identical<T: SpElem>(a: &RunResult<T>, b: &RunResult<T>, tag: &str) {
    assert_eq!(a.y, b.y, "{tag}: output vector differs");
    assert_eq!(a.breakdown, b.breakdown, "{tag}: breakdown differs");
    assert_eq!(a.stats, b.stats, "{tag}: stats differ");
    assert_eq!(a.energy, b.energy, "{tag}: energy differs");
}

/// Run one (spec, matrix, system) with the serial engine and every
/// concurrent engine (legacy spawn-per-wave threading AND the pooled
/// default) at every width, asserting bit-identical results throughout.
fn check_engines<T: SpElem>(spec: &KernelSpec, m: &CooMatrix<T>, x: &[T], n_dpus: usize) {
    let sys = || PimSystem {
        cfg: PimConfig { n_dpus, ..Default::default() },
    };
    let serial_exec = SpmvExecutor::with_engine(sys(), Engine::Serial);
    let serial = serial_exec.run(spec, m, x).unwrap();
    for t in THREAD_COUNTS {
        for engine in [Engine::spawning(t), Engine::threaded(t)] {
            let exec = SpmvExecutor::with_engine(sys(), engine);
            let threaded = exec.run(spec, m, x).unwrap();
            assert_identical(
                &serial,
                &threaded,
                &format!("{} d={n_dpus} t={t} {engine:?}", spec.name),
            );
            // Plan reuse must be deterministic too: executing the same
            // plan twice on a concurrent engine is bit-stable.
            let plan = exec.plan(spec, m).unwrap();
            let r1 = exec.execute(&plan, x).unwrap();
            let r2 = exec.execute(&plan, x).unwrap();
            assert_identical(&r1, &r2, &format!("{} plan-reuse t={t} {engine:?}", spec.name));
            assert_identical(&serial, &r1, &format!("{} plan-vs-run t={t} {engine:?}", spec.name));
        }
    }
}

/// PROPERTY: all 25 kernels (formats x partitionings x balancing) are
/// engine-independent on a skewed matrix — the case where per-DPU work,
/// and therefore thread scheduling, is most uneven.
#[test]
fn prop_all25_identical_across_engines() {
    let m = sparsep::matrix::generate::scale_free::<f64>(600, 600, 7, 0.7, 19);
    let x: Vec<f64> = (0..600).map(|i| ((i % 13) as f64) - 6.0).collect();
    for spec in KernelSpec::all25(4) {
        check_engines(&spec, &m, &x, 16);
    }
}

/// PROPERTY: the three sync schemes (which change per-tasklet cycle
/// accounting, the part aggregated across threads) stay identical.
#[test]
fn prop_sync_schemes_identical_across_engines() {
    let m = sparsep::matrix::generate::scale_free::<f64>(400, 400, 10, 0.8, 5);
    let x: Vec<f64> = (0..400).map(|i| ((i % 9) as f64) - 4.0).collect();
    for base in [KernelSpec::coo_nnz(), KernelSpec::bcoo_block()] {
        for sync in [SyncScheme::LockFree, SyncScheme::CoarseLock, SyncScheme::FineLock] {
            check_engines(&base.clone().with_sync(sync), &m, &x, 8);
        }
    }
}

/// PROPERTY: randomized (matrix, kernel, system) triples are engine-
/// independent — including thread counts exceeding the DPU count and
/// DPU counts that leave some workers empty.
#[test]
fn prop_random_runs_identical_across_engines() {
    let mut rng = Rng::new(0xE9E9);
    for trial in 0..40 {
        let nrows = 1 + rng.gen_range(250);
        let ncols = 1 + rng.gen_range(250);
        let nnz = rng.gen_range(4 * nrows.min(ncols) + 1);
        let triples: Vec<(u32, u32, f64)> = (0..nnz)
            .map(|_| {
                (
                    rng.gen_range(nrows) as u32,
                    rng.gen_range(ncols) as u32,
                    (rng.gen_range(9) as f64) - 4.0,
                )
            })
            .collect();
        let m = CooMatrix::from_triples(nrows, ncols, triples);
        let all = KernelSpec::all25(1 + rng.gen_range(8));
        let spec = all[rng.gen_range(all.len())].clone();
        let n_dpus = 1 + rng.gen_range(60);
        let n_dpus = match spec.partitioning {
            Partitioning::TwoD(_, stripes) => {
                sparsep::util::round_up(n_dpus.max(stripes), stripes)
            }
            _ => n_dpus,
        };
        let x: Vec<f64> = (0..m.ncols()).map(|i| ((i % 11) as f64) - 5.0).collect();
        let _ = trial;
        check_engines(&spec, &m, &x, n_dpus);
    }
}

/// PROPERTY: integer kernels (wrapping arithmetic) are engine-independent
/// too — a different code path through the MAC accounting.
#[test]
fn prop_integer_runs_identical_across_engines() {
    let m64 = sparsep::matrix::generate::uniform::<f64>(300, 300, 8, 13);
    let mi: CooMatrix<i32> = m64.cast();
    let x: Vec<i32> = (0..300).map(|i| (i % 7) as i32 - 3).collect();
    for spec in [KernelSpec::coo_nnz(), KernelSpec::csr_nnz(), KernelSpec::bcoo_nnz()] {
        check_engines(&spec, &mi, &x, 12);
    }
}

/// PROPERTY: iterated execution over one plan is engine-independent
/// end to end (vector feedback amplifies any divergence).
#[test]
fn prop_run_iterations_identical_across_engines() {
    let m = sparsep::matrix::generate::uniform::<f64>(256, 256, 6, 29);
    let x: Vec<f64> = (0..256).map(|i| ((i % 5) as f64) - 2.0).collect();
    let spec = KernelSpec::coo_nnz();
    let sys = || PimSystem::with_dpus(16);
    let se = SpmvExecutor::with_engine(sys(), Engine::Serial);
    let sp = se.plan(&spec, &m).unwrap();
    let serial = se.run_iterations(&sp, &x, 5).unwrap();
    for t in THREAD_COUNTS {
        for engine in [Engine::spawning(t), Engine::threaded(t)] {
            let te = SpmvExecutor::with_engine(sys(), engine);
            let tp = te.plan(&spec, &m).unwrap();
            let threaded = te.run_iterations(&tp, &x, 5).unwrap();
            assert_identical(&serial.last, &threaded.last, &format!("iterations t={t} {engine:?}"));
            assert_eq!(serial.total, threaded.total, "iteration totals t={t} {engine:?}");
            assert_eq!(serial.energy, threaded.energy, "iteration energy t={t} {engine:?}");
        }
    }
}

/// PROPERTY: a plan built under one tasklet count executes bit-identically
/// on an executor with a *different* tasklet count (the cached plan-time
/// split must fall back to an on-the-fly split, never a stale one) —
/// compared against a plan built natively for that count, on every
/// engine.
#[test]
fn prop_plan_time_splits_respect_executor_tasklet_count() {
    let m = sparsep::matrix::generate::scale_free::<f64>(300, 300, 6, 0.7, 41);
    let x: Vec<f64> = (0..300).map(|i| ((i % 11) as f64) - 5.0).collect();
    let sys_with = |tasklets: usize| PimSystem {
        cfg: PimConfig { n_dpus: 8, tasklets, ..Default::default() },
    };
    for spec in [
        KernelSpec::csr_nnz(),
        KernelSpec::coo_nnz(),
        KernelSpec::bcsr_nnz(),
        KernelSpec::bcoo_nnz(),
    ] {
        // Plan under 16 tasklets, execute under 4 (and vice versa).
        for (plan_t, exec_t) in [(16usize, 4usize), (4, 16)] {
            let planner = SpmvExecutor::new(sys_with(plan_t));
            let plan = planner.plan(&spec, &m).unwrap();
            for engine in [Engine::Serial, Engine::spawning(3), Engine::threaded(3)] {
                let exec = SpmvExecutor::with_engine(sys_with(exec_t), engine);
                let native_plan = exec.plan(&spec, &m).unwrap();
                let crossed = exec.execute(&plan, &x).unwrap();
                let native = exec.execute(&native_plan, &x).unwrap();
                assert_identical(
                    &crossed,
                    &native,
                    &format!("{} plan@{plan_t} exec@{exec_t} {engine:?}", spec.name),
                );
            }
        }
    }
}
