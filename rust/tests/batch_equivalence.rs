//! Property tests for the batched (SpMM-style) execution path:
//! `SpmvExecutor::execute_batch` must be bit-identical — output vector,
//! breakdown, stats and energy, per vector — to looping the
//! single-vector `execute` over the same plan, across all 25 kernel
//! specs, both engines, and batch sizes including 1 and ragged last
//! blocks. `run_iterations_batch` must match per-vector
//! `run_iterations` the same way, and `PlanCache`-served plans must be
//! indistinguishable from freshly built ones.

// These suites deliberately exercise `SpmvExecutor`'s deprecated
// compatibility wrappers (`execute` / `execute_batch` / `run_iterations`
// / `run_iterations_batch` / `run`): they lock the wrappers' behavior
// until a future major removal. New code routes through
// `coordinator::SpmvService` or `ExecutionPlan::{execute, ...}`.
#![allow(deprecated)]

use sparsep::coordinator::{
    Engine, KernelSpec, Partitioning, PlanCache, RunResult, SpmvExecutor, VECTOR_BLOCK,
};
use sparsep::matrix::{CooMatrix, SpElem};
use sparsep::pim::PimSystem;
use sparsep::util::rng::Rng;

fn assert_identical<T: SpElem>(a: &RunResult<T>, b: &RunResult<T>, tag: &str) {
    assert_eq!(a.y, b.y, "{tag}: output vector differs");
    assert_eq!(a.breakdown, b.breakdown, "{tag}: breakdown differs");
    assert_eq!(a.stats, b.stats, "{tag}: stats differ");
    assert_eq!(a.energy, b.energy, "{tag}: energy differs");
}

fn vectors(ncols: usize, batch: usize) -> Vec<Vec<f64>> {
    (0..batch)
        .map(|b| (0..ncols).map(|i| ((i + 5 * b) % 11) as f64 - 5.0).collect())
        .collect()
}

/// Batched vs looped over one plan, one executor.
fn check_batch<T: SpElem>(
    exec: &SpmvExecutor,
    spec: &KernelSpec,
    m: &CooMatrix<T>,
    xs: &[Vec<T>],
    tag: &str,
) {
    let plan = exec.plan(spec, m).unwrap();
    let batch = exec.execute_batch(&plan, xs).unwrap();
    assert_eq!(batch.len(), xs.len(), "{tag}: batch size");
    for (i, (x, run)) in xs.iter().zip(&batch.runs).enumerate() {
        let single = exec.execute(&plan, x).unwrap();
        assert_identical(run, &single, &format!("{tag} vec={i}"));
    }
}

/// PROPERTY: all 25 kernels are batch/looped-identical on a skewed
/// matrix — covering a ragged last block (11 = VECTOR_BLOCK + 3) — on
/// the serial and threaded engines alike.
#[test]
fn prop_all25_batch_identical_to_looped() {
    assert_eq!(VECTOR_BLOCK, 8, "batch sizes below assume the 8-vector block");
    let m = sparsep::matrix::generate::scale_free::<f64>(320, 320, 6, 0.7, 29);
    let xs = vectors(320, VECTOR_BLOCK + 3);
    for spec in KernelSpec::all25(4) {
        let serial = SpmvExecutor::new(PimSystem::with_dpus(16));
        check_batch(&serial, &spec, &m, &xs, &format!("{} serial", spec.name));
        let threaded = SpmvExecutor::threaded(PimSystem::with_dpus(16), 4);
        check_batch(&threaded, &spec, &m, &xs, &format!("{} threaded", spec.name));
    }
}

/// PROPERTY: every batch size around the block boundary — 1, a partial
/// block, exact blocks, exact-plus-ragged — is identical to looped
/// execution, and the engines agree with each other.
#[test]
fn prop_batch_sizes_identical_including_ragged() {
    let m = sparsep::matrix::generate::scale_free::<f64>(256, 256, 7, 0.6, 51);
    let specs = [
        KernelSpec::coo_nnz(),
        KernelSpec::csr_nnz(),
        KernelSpec::two_d(sparsep::matrix::Format::Coo, 4),
    ];
    for batch in [1, 3, VECTOR_BLOCK - 1, VECTOR_BLOCK, VECTOR_BLOCK + 1, 2 * VECTOR_BLOCK, 2 * VECTOR_BLOCK + 5] {
        let xs = vectors(256, batch);
        for spec in &specs {
            let serial = SpmvExecutor::new(PimSystem::with_dpus(8));
            check_batch(&serial, spec, &m, &xs, &format!("{} b={batch} serial", spec.name));
            for t in [1usize, 2, 8] {
                let exec = SpmvExecutor::threaded(PimSystem::with_dpus(8), t);
                let plan = exec.plan(spec, &m).unwrap();
                let b = exec.execute_batch(&plan, &xs).unwrap();
                let sb = serial.execute_batch(&serial.plan(spec, &m).unwrap(), &xs).unwrap();
                for (i, (tr, sr)) in b.runs.iter().zip(&sb.runs).enumerate() {
                    assert_identical(
                        tr,
                        sr,
                        &format!("{} b={batch} t={t} vec={i} cross-engine", spec.name),
                    );
                }
            }
        }
    }
}

/// PROPERTY: randomized (matrix, kernel, system, batch-size) tuples are
/// batch/looped-identical — including empty-ish DPUs, thread counts
/// exceeding the unit count, and integer dtypes.
#[test]
fn prop_random_batches_identical_to_looped() {
    let mut rng = Rng::new(0xBA7C);
    for _trial in 0..25 {
        let nrows = 1 + rng.gen_range(200);
        let ncols = 1 + rng.gen_range(200);
        let nnz = rng.gen_range(4 * nrows.min(ncols) + 1);
        let triples: Vec<(u32, u32, f64)> = (0..nnz)
            .map(|_| {
                (
                    rng.gen_range(nrows) as u32,
                    rng.gen_range(ncols) as u32,
                    (rng.gen_range(9) as f64) - 4.0,
                )
            })
            .collect();
        let m = CooMatrix::from_triples(nrows, ncols, triples);
        let all = KernelSpec::all25(1 + rng.gen_range(6));
        let spec = all[rng.gen_range(all.len())].clone();
        let n_dpus = 1 + rng.gen_range(40);
        let n_dpus = match spec.partitioning {
            Partitioning::TwoD(_, stripes) => {
                sparsep::util::round_up(n_dpus.max(stripes), stripes)
            }
            _ => n_dpus,
        };
        let batch = 1 + rng.gen_range(2 * VECTOR_BLOCK);
        let xs = vectors(m.ncols(), batch);
        let exec = if rng.gen_range(2) == 0 {
            SpmvExecutor::new(PimSystem::with_dpus(n_dpus))
        } else {
            SpmvExecutor::threaded(PimSystem::with_dpus(n_dpus), 1 + rng.gen_range(8))
        };
        check_batch(&exec, &spec, &m, &xs, &format!("random {} d={n_dpus} b={batch}", spec.name));
    }
}

/// PROPERTY: integer batches (wrapping arithmetic) are batch/looped-
/// identical too.
#[test]
fn prop_integer_batches_identical() {
    let m64 = sparsep::matrix::generate::uniform::<f64>(200, 200, 6, 31);
    let mi: CooMatrix<i32> = m64.cast();
    let xs: Vec<Vec<i32>> = (0..5)
        .map(|b| (0..200).map(|i| ((i + b) % 7) as i32 - 3).collect())
        .collect();
    for spec in [KernelSpec::coo_nnz(), KernelSpec::csr_nnz(), KernelSpec::bcoo_nnz()] {
        let exec = SpmvExecutor::threaded(PimSystem::with_dpus(12), 3);
        check_batch(&exec, &spec, &mi, &xs, &format!("{} i32", spec.name));
    }
}

/// PROPERTY: iterated batched execution matches per-vector
/// `run_iterations` bit-for-bit, on both engines (vector feedback
/// amplifies any divergence).
#[test]
fn prop_run_iterations_batch_identical_to_per_vector() {
    let m = sparsep::matrix::generate::uniform::<f64>(192, 192, 5, 43);
    let xs = vectors(192, 5);
    let spec = KernelSpec::coo_nnz();
    for engine in [Engine::Serial, Engine::threaded(4)] {
        let exec = SpmvExecutor::with_engine(PimSystem::with_dpus(16), engine);
        let plan = exec.plan(&spec, &m).unwrap();
        let batch = exec.run_iterations_batch(&plan, &xs, 6).unwrap();
        assert_eq!(batch.iters, 6);
        let mut want_total = sparsep::coordinator::Breakdown::default();
        for (x, last) in xs.iter().zip(&batch.last.runs) {
            let single = exec.run_iterations(&plan, x, 6).unwrap();
            assert_identical(last, &single.last, "iterated batch");
            want_total.accumulate(&single.total);
        }
        assert_eq!(batch.total, want_total, "iterated totals");
    }
}

/// PROPERTY: a PlanCache-served plan is indistinguishable from a fresh
/// one — hit or miss — and the cache actually hits on equal content.
#[test]
fn prop_plan_cache_serves_equivalent_plans() {
    let m = sparsep::matrix::generate::scale_free::<f64>(300, 300, 6, 0.6, 77);
    let xs = vectors(300, VECTOR_BLOCK + 1);
    let cache: PlanCache<f64> = PlanCache::new();
    let exec = SpmvExecutor::threaded(PimSystem::with_dpus(16), 4);
    for spec in [KernelSpec::csr_nnz(), KernelSpec::coo_nnz()] {
        let fresh = exec.plan(&spec, &m).unwrap();
        let miss = cache.plan(&exec, &spec, &m).unwrap();
        // Equal matrix content (a clone) must hit, not re-plan.
        let hit = cache.plan(&exec, &spec, &m.clone()).unwrap();
        assert!(std::sync::Arc::ptr_eq(&miss, &hit), "{}: clone must hit", spec.name);
        let a = exec.execute_batch(&fresh, &xs).unwrap();
        let b = exec.execute_batch(&hit, &xs).unwrap();
        for (i, (ra, rb)) in a.runs.iter().zip(&b.runs).enumerate() {
            assert_identical(ra, rb, &format!("{} cache vec={i}", spec.name));
        }
    }
    assert_eq!(cache.hits(), 2);
    assert_eq!(cache.misses(), 2);
}
