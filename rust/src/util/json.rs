//! Minimal JSON support (serde is not in the offline vendor set).
//!
//! Only what this crate needs: a writer used by the bench harness to emit
//! machine-readable results, and a small recursive-descent parser used by
//! the [`crate::runtime`] to read `artifacts/manifest.json` produced by
//! `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["k"]` that returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literals; `write!("{n}")`
                    // would emit `NaN`/`inf`, which `Json::parse` (and any
                    // other JSON reader) rejects. Degrade to null so one
                    // bad ratio can't corrupt a whole BENCH_*.json file.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building result objects in the harness.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let c = match cp {
                                // High surrogate: must be followed by an
                                // escaped low surrogate; combine the pair
                                // into one astral-plane scalar.
                                0xD800..=0xDBFF => {
                                    if self.peek() != Some(b'\\') {
                                        return Err("lone high surrogate in \\u escape".into());
                                    }
                                    self.i += 1;
                                    if self.peek() != Some(b'u') {
                                        return Err("lone high surrogate in \\u escape".into());
                                    }
                                    self.i += 1;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..=0xDFFF).contains(&lo) {
                                        return Err("lone high surrogate in \\u escape".into());
                                    }
                                    let scalar =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(scalar).ok_or("bad \\u escape")?
                                }
                                // Low surrogate with no preceding high half.
                                0xDC00..=0xDFFF => {
                                    return Err("lone low surrogate in \\u escape".into())
                                }
                                _ => char::from_u32(cp).ok_or("bad \\u escape")?,
                            };
                            out.push(c);
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// Read exactly four hex digits (the payload of a `\u` escape).
    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("bad \\u escape".into());
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4]).map_err(|_| "bad \\u escape")?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
        self.i += 4;
        Ok(cp)
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let j = obj(vec![
            ("name", s("ell_f32")),
            ("rows", num(1024.0)),
            ("ok", Json::Bool(true)),
            ("tags", arr(vec![s("a"), s("b")])),
        ]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2.5, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(j.get("c").as_str(), Some("x\ny"));
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
    }

    /// Regression: NaN / ±inf used to serialize as `NaN` / `inf`, which is
    /// not JSON — a single 0/0 speedup corrupted the whole BENCH file and
    /// `Json::parse` rejected the round-trip. They must degrade to null.
    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(bad).to_string(), "null");
        }
        let j = obj(vec![("speedup", num(f64::NAN)), ("ok", num(2.0))]);
        let text = j.to_string();
        let back = Json::parse(&text).expect("non-finite must still round-trip as a document");
        assert_eq!(back.get("speedup"), &Json::Null);
        assert_eq!(back.get("ok").as_f64(), Some(2.0));
    }

    /// Regression: the `\uXXXX` parser treated each escape in isolation, so
    /// a surrogate pair like `😀` (U+1F600) decoded to two
    /// replacement characters instead of the astral-plane scalar.
    #[test]
    fn surrogate_pairs_combine() {
        // U+1F600 as an escaped surrogate pair.
        let j = Json::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(j.as_str(), Some("\u{1F600}"));
        // Mixed with surrounding text and a BMP escape (U+1D11E musical clef).
        let j = Json::parse("\"a\\u00e9 \\ud834\\udd1e z\"").unwrap();
        assert_eq!(j.as_str(), Some("a\u{e9} \u{1D11E} z"));
        // A serialized astral char survives a parse round-trip (writer emits
        // raw UTF-8, parser must accept it unchanged).
        let j = Json::Str("\u{1F600}".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn lone_surrogates_are_rejected() {
        // Lone high surrogate (end of string, non-escape follower, bad low half).
        assert!(Json::parse("\"\\ud83d\"").is_err());
        assert!(Json::parse("\"\\ud83dx\"").is_err());
        assert!(Json::parse("\"\\ud83d\\n\"").is_err());
        assert!(Json::parse("\"\\ud83d\\u0041\"").is_err());
        // Lone low surrogate.
        assert!(Json::parse("\"\\ude00\"").is_err());
        // Truncated escapes still fail cleanly.
        assert!(Json::parse("\"\\u12").is_err());
        assert!(Json::parse("\"\\ud83d\\u").is_err());
    }
}
