//! Fair multi-tenant request scheduling — the admission layer in front
//! of the sharded serving queue.
//!
//! A production SpMV service is shared: several tenants (applications,
//! users, jobs) submit request streams against one pool of simulated
//! PIM ranks, and the PIM benchmarking literature's first lesson about
//! shared accelerators applies — without an explicit scheduler, a
//! flooding tenant owns the queue and every other tenant's latency is
//! unbounded. This module provides the deterministic core that
//! [`super::ShardedService`] puts in front of its dispatcher:
//!
//! * every tenant is declared up front as a [`TenantSpec`] — a name, a
//!   **weight** (its share of dispatch slots in weighted round-robin),
//!   and a **quota** (`max_in_flight`: how many of its requests may
//!   occupy the shard pipelines simultaneously);
//! * [`FairScheduler`] keeps one FIFO queue per tenant and dispatches
//!   by **weighted round-robin**: in each cycle tenant *t* may dispatch
//!   up to `weight_t` requests before the cursor moves on, and a tenant
//!   at its in-flight quota is skipped until a completion frees a slot.
//!
//! The scheduler is intentionally **not** thread-safe and performs no
//! blocking: [`FairScheduler::pop`] either returns the next dispatch or
//! `None` (nothing eligible). The service wraps it in a mutex/condvar
//! pair; tests drive it directly, which is what makes the fairness
//! properties *deterministic* — the dispatch order for a given enqueue
//! history is a pure function, locked by the unit tests below and the
//! end-to-end suite in `tests/shard_equivalence.rs`.
//!
//! **Starvation bound.** A tenant with queued work and free quota waits
//! at most `sum(weight_other)` dispatches between two of its own: each
//! other tenant serves at most its weight per cycle before the cursor
//! reaches the waiting tenant again. A flooding tenant therefore cannot
//! starve anyone — it only fills the slots its weight entitles it to.

use super::metrics::{LatencyHistogram, TenantStats};
use crate::util::Result;
use std::collections::VecDeque;
use crate::util::sync::Arc;

/// A tenant's identity within one scheduler (and the
/// [`super::ShardedService`] that owns it). Copyable tag carried by
/// submissions; obtained from [`FairScheduler::tenant`] /
/// `ShardedService::tenant`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId(pub(crate) usize);

impl TenantId {
    /// Index of this tenant in registration order.
    pub fn index(&self) -> usize {
        self.0
    }
}

/// Declared scheduling parameters of one tenant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantSpec {
    /// Tenant name (unique within a scheduler). Interned as `Arc<str>`:
    /// everything that reports the name — per-decision stats snapshots,
    /// the facade's tenant table, log lines — bumps a reference count
    /// instead of allocating a `String` clone, keeping the WRR
    /// dispatch/record loop allocation-free.
    pub name: Arc<str>,
    /// Weighted-round-robin share: up to this many dispatches per cycle
    /// (>= 1).
    pub weight: usize,
    /// In-flight quota: at most this many of the tenant's requests may
    /// be dispatched-but-not-completed at once (>= 1).
    pub max_in_flight: usize,
}

impl TenantSpec {
    /// A tenant with the given weight and an effectively unlimited
    /// in-flight quota.
    pub fn new(name: &str, weight: usize) -> TenantSpec {
        TenantSpec { name: Arc::from(name), weight, max_in_flight: usize::MAX }
    }

    /// Set the in-flight quota.
    pub fn with_quota(mut self, max_in_flight: usize) -> TenantSpec {
        self.max_in_flight = max_in_flight;
        self
    }

    /// Parse a CLI-style tenant list: comma-separated
    /// `name:weight[:quota]` entries, e.g. `alice:3,bob:1` or
    /// `batch:1:2,online:4:8`. Weight and quota must be >= 1, names
    /// must be non-empty and unique — everything
    /// [`FairScheduler::new`] would reject is rejected here too, so a
    /// bad `--tenants` flag fails at parse time with the entry named,
    /// not later at scheduler construction.
    pub fn parse_list(spec: &str) -> Result<Vec<TenantSpec>> {
        let mut out: Vec<TenantSpec> = Vec::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let parts: Vec<&str> = entry.split(':').collect();
            crate::ensure!(
                parts.len() == 2 || parts.len() == 3,
                "tenant entry {entry:?} must be name:weight or name:weight:quota"
            );
            let name = parts[0].trim();
            crate::ensure!(!name.is_empty(), "tenant entry {entry:?} has an empty name");
            crate::ensure!(
                !out.iter().any(|t| &*t.name == name),
                "duplicate tenant name {name:?} in spec {spec:?}"
            );
            let weight: usize = parts[1]
                .trim()
                .parse()
                .map_err(|_| crate::format_err!("tenant {entry:?}: weight must be an integer"))?;
            crate::ensure!(weight >= 1, "tenant {entry:?}: weight must be >= 1");
            let mut t = TenantSpec::new(name, weight);
            if parts.len() == 3 {
                let quota: usize = parts[2].trim().parse().map_err(|_| {
                    crate::format_err!("tenant {entry:?}: quota must be an integer")
                })?;
                crate::ensure!(quota >= 1, "tenant {entry:?}: quota must be >= 1");
                t = t.with_quota(quota);
            }
            out.push(t);
        }
        crate::ensure!(!out.is_empty(), "tenant spec {spec:?} declares no tenants");
        Ok(out)
    }
}

/// One queued work item: the payload plus its EDF key. `deadline` is an
/// absolute instant in the caller's clock (the facade uses microseconds
/// since its epoch); `None` sorts after every dated item.
struct Queued<W> {
    deadline: Option<u64>,
    work: W,
}

impl<W> Queued<W> {
    fn key(&self) -> u64 {
        self.deadline.unwrap_or(u64::MAX)
    }
}

struct TenantState<W> {
    spec: TenantSpec,
    queue: VecDeque<Queued<W>>,
    in_flight: usize,
    enqueued: u64,
    dispatched: u64,
    completed: u64,
    shed: u64,
    hist: LatencyHistogram,
}

/// Deterministic weighted-round-robin scheduler with per-tenant
/// in-flight quotas. Single-threaded by design; see the module docs.
pub struct FairScheduler<W> {
    tenants: Vec<TenantState<W>>,
    /// Tenant whose turn it currently is.
    cursor: usize,
    /// Dispatches already granted to `cursor`'s current turn.
    served_in_turn: usize,
}

impl<W> FairScheduler<W> {
    /// Build a scheduler over the declared tenants (>= 1, unique names,
    /// weights and quotas >= 1).
    pub fn new(specs: Vec<TenantSpec>) -> Result<FairScheduler<W>> {
        crate::ensure!(!specs.is_empty(), "a scheduler needs at least one tenant");
        for (i, s) in specs.iter().enumerate() {
            crate::ensure!(s.weight >= 1, "tenant {:?}: weight must be >= 1", s.name);
            crate::ensure!(s.max_in_flight >= 1, "tenant {:?}: quota must be >= 1", s.name);
            crate::ensure!(
                !specs[..i].iter().any(|o| o.name == s.name),
                "duplicate tenant name {:?}",
                s.name
            );
        }
        Ok(FairScheduler {
            tenants: specs
                .into_iter()
                .map(|spec| TenantState {
                    spec,
                    queue: VecDeque::new(),
                    in_flight: 0,
                    enqueued: 0,
                    dispatched: 0,
                    completed: 0,
                    shed: 0,
                    hist: LatencyHistogram::new(),
                })
                .collect(),
            cursor: 0,
            served_in_turn: 0,
        })
    }

    /// Number of registered tenants.
    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Look a tenant up by name.
    pub fn tenant(&self, name: &str) -> Option<TenantId> {
        self.tenants.iter().position(|t| &*t.spec.name == name).map(TenantId)
    }

    /// The tenant's declared spec.
    pub fn spec(&self, t: TenantId) -> &TenantSpec {
        &self.tenants[t.0].spec
    }

    /// Append `work` to the tenant's FIFO queue (no deadline: dispatch
    /// in arrival order after every dated request).
    pub fn enqueue(&mut self, t: TenantId, work: W) {
        self.enqueue_with_deadline(t, work, None);
    }

    /// Enqueue `work` with an optional absolute deadline (EDF within
    /// the tenant's queue). The cross-tenant weighted-round-robin share
    /// is untouched — a deadline can only reorder a tenant's *own*
    /// queue, so no deadline choice lets one tenant cut into another's
    /// slots. Within one tenant the earliest deadline dispatches first;
    /// ties and undated requests keep arrival (FIFO) order, undated
    /// after dated.
    pub fn enqueue_with_deadline(&mut self, t: TenantId, work: W, deadline: Option<u64>) {
        let st = &mut self.tenants[t.0];
        st.enqueued += 1;
        let q = Queued { deadline, work };
        // Stable EDF insert: after every item with key <= ours.
        match st.queue.iter().position(|o| o.key() > q.key()) {
            Some(i) => st.queue.insert(i, q),
            None => st.queue.push_back(q),
        }
    }

    /// Dispatch the next eligible request under weighted round-robin:
    /// the cursor tenant serves until its weight for this turn is
    /// exhausted, its queue empties, or it hits its in-flight quota;
    /// then the turn passes on. Returns `None` when no tenant is
    /// eligible (all queues empty or quota-blocked).
    ///
    /// A `pop` that dispatches nothing is **side-effect-free**: the
    /// cursor and turn budget are restored, so fruitless polls (e.g.
    /// spurious wakeups of a dispatcher loop) can never rotate the
    /// schedule — the dispatch order stays a pure function of the
    /// enqueue/complete history.
    pub fn pop(&mut self) -> Option<(TenantId, W)> {
        let n = self.tenants.len();
        let (cursor_before, served_before) = (self.cursor, self.served_in_turn);
        // Up to n advances brings the cursor full circle (with a fresh
        // turn for the starting tenant); one more check covers it.
        let mut advances = 0;
        while advances <= n {
            let t = self.cursor;
            let st = &mut self.tenants[t];
            if self.served_in_turn < st.spec.weight
                && st.in_flight < st.spec.max_in_flight
                && !st.queue.is_empty()
            {
                self.served_in_turn += 1;
                st.in_flight += 1;
                st.dispatched += 1;
                let work = st.queue.pop_front().expect("non-empty queue").work;
                return Some((TenantId(t), work));
            }
            self.cursor = (t + 1) % n;
            self.served_in_turn = 0;
            advances += 1;
        }
        self.cursor = cursor_before;
        self.served_in_turn = served_before;
        None
    }

    /// Record a dispatched request's completion, freeing one of the
    /// tenant's in-flight quota slots.
    pub fn complete(&mut self, t: TenantId) {
        let st = &mut self.tenants[t.0];
        debug_assert!(st.in_flight > 0, "completion without a dispatch");
        st.in_flight = st.in_flight.saturating_sub(1);
        st.completed += 1;
    }

    /// Record a completed request's submit-to-publish latency (in
    /// microseconds) into the tenant's log-bucketed histogram.
    pub fn record_latency(&mut self, t: TenantId, us: u64) {
        self.tenants[t.0].hist.record(us);
    }

    /// Record an admission-control shed: the request was answered
    /// `Overloaded` and never entered the queue.
    pub fn record_shed(&mut self, t: TenantId) {
        self.tenants[t.0].shed += 1;
    }

    /// Total requests queued (not yet dispatched) across tenants.
    pub fn queued(&self) -> usize {
        self.tenants.iter().map(|t| t.queue.len()).sum()
    }

    /// Requests queued (not yet dispatched) for one tenant — the
    /// admission-control depth check.
    pub fn queued_for(&self, t: TenantId) -> usize {
        self.tenants[t.0].queue.len()
    }

    /// Total dispatched-but-not-completed requests across tenants.
    pub fn in_flight(&self) -> usize {
        self.tenants.iter().map(|t| t.in_flight).sum()
    }

    /// Drain every queued (never-dispatched) request, in tenant order
    /// (used at shutdown to fail their tickets loudly).
    pub fn drain_queued(&mut self) -> Vec<(TenantId, W)> {
        let mut out = Vec::new();
        for (i, st) in self.tenants.iter_mut().enumerate() {
            while let Some(q) = st.queue.pop_front() {
                out.push((TenantId(i), q.work));
            }
        }
        out
    }

    /// Per-tenant counters, in registration order. Names are shared
    /// `Arc<str>` handles — snapshotting stats never allocates strings.
    pub fn stats(&self) -> Vec<TenantStats> {
        self.tenants
            .iter()
            .map(|t| TenantStats {
                name: Arc::clone(&t.spec.name),
                weight: t.spec.weight,
                max_in_flight: t.spec.max_in_flight,
                enqueued: t.enqueued,
                dispatched: t.dispatched,
                completed: t.completed,
                shed: t.shed,
                in_flight: t.in_flight,
                queued: t.queue.len(),
                latency: t.hist.snapshot(),
            })
            .collect()
    }
}

/// Least-outstanding replica dispatch: the index of the smallest load,
/// **lowest index on ties**. The tie rule is what keeps replicated
/// facades deterministic at rest — an idle tile always dispatches to
/// replica 0, so single-threaded request streams replay identically.
/// `loads` must be non-empty (a tile always has >= 1 replica).
pub fn least_outstanding(loads: &[u64]) -> usize {
    debug_assert!(!loads.is_empty(), "least_outstanding over zero replicas");
    let mut best = 0;
    for (i, &l) in loads.iter().enumerate().skip(1) {
        if l < loads[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(specs: &[(&str, usize, usize)]) -> FairScheduler<usize> {
        FairScheduler::new(
            specs.iter().map(|&(n, w, q)| TenantSpec::new(n, w).with_quota(q)).collect(),
        )
        .unwrap()
    }

    /// Drain the scheduler assuming every dispatch completes before the
    /// next pop (serialized execution): the pure WRR order.
    fn drain_serialized(s: &mut FairScheduler<usize>) -> Vec<String> {
        let mut order = Vec::new();
        while let Some((t, _)) = s.pop() {
            order.push(s.spec(t).name.to_string());
            s.complete(t);
        }
        order
    }

    #[test]
    fn least_outstanding_picks_minimum_lowest_index_first() {
        assert_eq!(least_outstanding(&[0]), 0);
        assert_eq!(least_outstanding(&[3, 1, 2]), 1);
        assert_eq!(least_outstanding(&[5, 5, 5]), 0, "all tied: lowest index");
        assert_eq!(least_outstanding(&[2, 0, 0, 1]), 1, "tied minimum: first wins");
        assert_eq!(least_outstanding(&[9, 8, 7, 0]), 3);
    }

    #[test]
    fn weighted_round_robin_order_is_deterministic() {
        // The satellite's canonical case: two tenants at 1:3 submitting
        // identical streams interleave exactly A B B B A B B B ...
        let mut s = sched(&[("a", 1, usize::MAX), ("b", 3, usize::MAX)]);
        let a = s.tenant("a").unwrap();
        let b = s.tenant("b").unwrap();
        for i in 0..4 {
            s.enqueue(a, i);
        }
        for i in 0..12 {
            s.enqueue(b, i);
        }
        let order = drain_serialized(&mut s);
        let want: Vec<String> = (0..4)
            .flat_map(|_| ["a", "b", "b", "b"])
            .map(str::to_string)
            .collect();
        assert_eq!(order, want);
    }

    #[test]
    fn flooding_tenant_cannot_starve_the_other() {
        // Tenant a floods 50 requests; b has 5. With weights 1:1, b's
        // i-th dispatch happens by global position 2*i + 1 (bounded
        // wait), after which a drains alone.
        let mut s = sched(&[("a", 1, usize::MAX), ("b", 1, usize::MAX)]);
        let (a, b) = (s.tenant("a").unwrap(), s.tenant("b").unwrap());
        for i in 0..50 {
            s.enqueue(a, i);
        }
        for i in 0..5 {
            s.enqueue(b, i);
        }
        let order = drain_serialized(&mut s);
        assert_eq!(order.len(), 55);
        let b_positions: Vec<usize> = order
            .iter()
            .enumerate()
            .filter_map(|(i, n)| (n == "b").then_some(i))
            .collect();
        assert_eq!(b_positions.len(), 5);
        for (i, &pos) in b_positions.iter().enumerate() {
            assert!(
                pos <= 2 * i + 1,
                "b's dispatch {i} waited until position {pos} (bound {})",
                2 * i + 1
            );
        }
        // The tail is all a: the flood still gets served afterwards.
        assert!(order[10..].iter().all(|n| n == "a"));
    }

    #[test]
    fn quota_blocks_dispatch_until_completion() {
        let mut s = sched(&[("a", 2, 1)]);
        let a = s.tenant("a").unwrap();
        s.enqueue(a, 1);
        s.enqueue(a, 2);
        let (t, w) = s.pop().expect("first dispatch");
        assert_eq!((t, w), (a, 1));
        // Quota 1: nothing more until the first completes.
        assert!(s.pop().is_none(), "quota must block the second dispatch");
        assert_eq!(s.in_flight(), 1);
        assert_eq!(s.queued(), 1);
        s.complete(a);
        assert_eq!(s.pop(), Some((a, 2)));
        s.complete(a);
        assert!(s.pop().is_none());
        let st = &s.stats()[0];
        assert_eq!((st.enqueued, st.dispatched, st.completed), (2, 2, 2));
    }

    #[test]
    fn quota_blocked_tenant_does_not_block_others() {
        let mut s = sched(&[("a", 3, 1), ("b", 1, usize::MAX)]);
        let (a, b) = (s.tenant("a").unwrap(), s.tenant("b").unwrap());
        for i in 0..3 {
            s.enqueue(a, i);
            s.enqueue(b, 10 + i);
        }
        // a dispatches once (quota 1), then b flows while a is blocked.
        assert_eq!(s.pop(), Some((a, 0)));
        assert_eq!(s.pop(), Some((b, 10)));
        assert_eq!(s.pop(), Some((b, 11)));
        assert_eq!(s.pop(), Some((b, 12)));
        assert!(s.pop().is_none(), "a quota-blocked, b drained");
        s.complete(a);
        assert_eq!(s.pop(), Some((a, 1)));
    }

    #[test]
    fn fruitless_pops_do_not_rotate_the_schedule() {
        // A pop that dispatches nothing must be side-effect-free: any
        // number of empty polls (spurious dispatcher wakeups) before
        // work arrives cannot change who dispatches first or the WRR
        // interleaving after it.
        let mut s = sched(&[("a", 1, usize::MAX), ("b", 3, usize::MAX)]);
        let (a, b) = (s.tenant("a").unwrap(), s.tenant("b").unwrap());
        for _ in 0..5 {
            assert!(s.pop().is_none());
        }
        for i in 0..2 {
            s.enqueue(a, i);
        }
        for i in 0..6 {
            s.enqueue(b, i);
        }
        let order = drain_serialized(&mut s);
        let want: Vec<String> =
            (0..2).flat_map(|_| ["a", "b", "b", "b"]).map(str::to_string).collect();
        assert_eq!(order, want, "empty polls must not have rotated the cursor");
        // Mid-stream fruitless polls are harmless too.
        let mut s = sched(&[("a", 2, usize::MAX)]);
        let a = s.tenant("a").unwrap();
        s.enqueue(a, 1);
        assert_eq!(s.pop(), Some((a, 1)));
        assert!(s.pop().is_none());
        assert!(s.pop().is_none());
        s.enqueue(a, 2);
        // Turn budget was restored: the second dispatch still fits in
        // the same weight-2 turn.
        assert_eq!(s.pop(), Some((a, 2)));
        s.complete(a);
        s.complete(a);
    }

    #[test]
    fn single_tenant_keeps_dispatching_across_turns() {
        // A lone tenant's weight never limits throughput: the cursor
        // cycles back and its turn refreshes.
        let mut s = sched(&[("only", 2, usize::MAX)]);
        let t = s.tenant("only").unwrap();
        for i in 0..7 {
            s.enqueue(t, i);
        }
        let got: Vec<usize> = std::iter::from_fn(|| s.pop().map(|(_, w)| w)).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn empty_queue_tenants_are_skipped() {
        let mut s = sched(&[("a", 4, usize::MAX), ("b", 4, usize::MAX), ("c", 4, usize::MAX)]);
        let c = s.tenant("c").unwrap();
        s.enqueue(c, 9);
        assert_eq!(s.pop(), Some((c, 9)));
        assert!(s.pop().is_none());
    }

    #[test]
    fn drain_queued_returns_undispatched_work() {
        let mut s = sched(&[("a", 1, usize::MAX), ("b", 1, usize::MAX)]);
        let (a, b) = (s.tenant("a").unwrap(), s.tenant("b").unwrap());
        s.enqueue(a, 1);
        s.enqueue(b, 2);
        s.enqueue(a, 3);
        let _ = s.pop();
        let rest = s.drain_queued();
        assert_eq!(rest, vec![(a, 3), (b, 2)]);
        assert_eq!(s.queued(), 0);
    }

    #[test]
    fn constructor_validates() {
        assert!(FairScheduler::<usize>::new(vec![]).is_err());
        assert!(FairScheduler::<usize>::new(vec![TenantSpec::new("a", 0)]).is_err());
        assert!(
            FairScheduler::<usize>::new(vec![TenantSpec::new("a", 1).with_quota(0)]).is_err()
        );
        assert!(FairScheduler::<usize>::new(vec![
            TenantSpec::new("a", 1),
            TenantSpec::new("a", 2),
        ])
        .is_err());
    }

    #[test]
    fn parse_list_roundtrips() {
        let ts = TenantSpec::parse_list("alice:3,bob:1").unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!((&*ts[0].name, ts[0].weight, ts[0].max_in_flight), ("alice", 3, usize::MAX));
        let ts = TenantSpec::parse_list("batch:1:2, online:4:8").unwrap();
        assert_eq!((&*ts[1].name, ts[1].weight, ts[1].max_in_flight), ("online", 4, 8));
        assert!(TenantSpec::parse_list("").is_err());
        assert!(TenantSpec::parse_list("a").is_err());
        assert!(TenantSpec::parse_list("a:x").is_err());
        assert!(TenantSpec::parse_list("a:1:y").is_err());
    }

    #[test]
    fn parse_list_rejects_duplicates_and_zero_knobs() {
        // Duplicate names fail at parse time with the name in the
        // message, not later at scheduler construction.
        let e = TenantSpec::parse_list("alice:3,bob:1,alice:2").unwrap_err();
        assert!(e.to_string().contains("alice"), "error must name the duplicate: {e}");
        // Whitespace does not hide a duplicate.
        assert!(TenantSpec::parse_list("a:1,  a :2").is_err());
        // Zero weight / zero quota are rejected where the entry is named.
        let e = TenantSpec::parse_list("a:0").unwrap_err();
        assert!(e.to_string().contains("weight"), "{e}");
        let e = TenantSpec::parse_list("a:1:0").unwrap_err();
        assert!(e.to_string().contains("quota"), "{e}");
        // Empty names (":1" or " :1") are rejected.
        assert!(TenantSpec::parse_list(":1").is_err());
        assert!(TenantSpec::parse_list(" :1,b:2").is_err());
    }

    #[test]
    fn parse_list_whitespace_and_empty_entries() {
        // Entries trim; empty comma segments (trailing commas, doubled
        // commas) are skipped rather than rejected.
        let ts = TenantSpec::parse_list("  a : 2 , , b : 1 : 3 ,").unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!((&*ts[0].name, ts[0].weight), ("a", 2));
        assert_eq!((&*ts[1].name, ts[1].weight, ts[1].max_in_flight), ("b", 1, 3));
        // All-whitespace / all-commas specs declare no tenants.
        assert!(TenantSpec::parse_list("   ").is_err());
        assert!(TenantSpec::parse_list(",,,").is_err());
    }

    #[test]
    fn edf_reorders_within_a_tenant_only() {
        // Within one tenant: earliest deadline first; undated requests
        // go last in arrival order; equal deadlines keep FIFO order.
        let mut s = sched(&[("a", 10, usize::MAX)]);
        let a = s.tenant("a").unwrap();
        s.enqueue(a, 0); // undated, arrived first
        s.enqueue_with_deadline(a, 1, Some(500));
        s.enqueue_with_deadline(a, 2, Some(100));
        s.enqueue_with_deadline(a, 3, Some(500));
        s.enqueue(a, 4); // undated, arrived last
        let got: Vec<usize> = std::iter::from_fn(|| {
            s.pop().map(|(t, w)| {
                s.complete(t);
                w
            })
        })
        .collect();
        assert_eq!(got, vec![2, 1, 3, 0, 4]);
    }

    #[test]
    fn edf_cannot_cut_into_another_tenants_share() {
        // b's urgent deadlines reorder b's own queue but the 1:1 WRR
        // interleave with a is unchanged — deadlines are not a priority
        // escalation mechanism across tenants.
        let mut s = sched(&[("a", 1, usize::MAX), ("b", 1, usize::MAX)]);
        let (a, b) = (s.tenant("a").unwrap(), s.tenant("b").unwrap());
        for i in 0..3 {
            s.enqueue(a, i);
            s.enqueue_with_deadline(b, 10 + i, Some(1000 - i as u64));
        }
        let order = drain_serialized(&mut s);
        assert_eq!(order, vec!["a", "b", "a", "b", "a", "b"]);
        // And b's internal order followed its (descending-enqueued)
        // deadlines: 12, 11, 10.
        let mut s = sched(&[("b", 1, usize::MAX)]);
        let b = s.tenant("b").unwrap();
        for i in 0..3 {
            s.enqueue_with_deadline(b, 10 + i, Some(1000 - i as u64));
        }
        let got: Vec<usize> = std::iter::from_fn(|| {
            s.pop().map(|(t, w)| {
                s.complete(t);
                w
            })
        })
        .collect();
        assert_eq!(got, vec![12, 11, 10]);
    }

    #[test]
    fn shed_and_latency_land_in_stats() {
        let mut s = sched(&[("a", 1, usize::MAX)]);
        let a = s.tenant("a").unwrap();
        s.record_shed(a);
        s.record_shed(a);
        s.record_latency(a, 100);
        s.record_latency(a, 200);
        s.record_latency(a, 400);
        let st = &s.stats()[0];
        assert_eq!(st.shed, 2);
        assert_eq!(st.latency.count, 3);
        assert_eq!(st.latency.max_us, 400);
        assert!(st.latency.p50_us >= 100 && st.latency.p50_us <= 255);
        assert_eq!(s.queued_for(a), 0);
        s.enqueue(a, 1);
        assert_eq!(s.queued_for(a), 1);
    }
}
