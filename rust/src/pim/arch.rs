//! PIM system topology and configuration.

use super::calib;

/// Configuration of a simulated UPMEM-class PIM system.
///
/// Defaults mirror the paper's testbed: 350 MHz DPUs, 64 KB WRAM, 64 MB
/// MRAM per DPU, 64 DPUs per rank, up to 2,560 DPUs. All parameters are
/// overridable so the "suggestions for hardware designers" experiments
/// (e.g. a faster bus, more banks per core) can be explored.
#[derive(Clone, Debug)]
pub struct PimConfig {
    /// Total number of DPUs allocated to the kernel.
    pub n_dpus: usize,
    /// DPUs per rank (transfer parallelism granularity).
    pub dpus_per_rank: usize,
    /// Tasklets (hardware threads) launched per DPU.
    pub tasklets: usize,
    /// DPU clock, Hz.
    pub freq_hz: f64,
    /// WRAM bytes per DPU.
    pub wram_bytes: usize,
    /// MRAM bytes per DPU.
    pub mram_bytes: usize,
    /// Scale factor on host<->PIM bus bandwidth (1.0 = the real UPMEM
    /// bus; >1 models the paper's "optimize broadcast/gather" hardware
    /// suggestions).
    pub bus_scale: f64,
    /// If true, concurrent MRAM accesses by different tasklets are
    /// serialized (the real UPMEM behaviour). Setting this to false
    /// models the paper's "subarray-level parallelism" hardware
    /// suggestion (SALP [23]) and is used by the ablation bench.
    pub serialize_mram: bool,
}

impl Default for PimConfig {
    fn default() -> Self {
        PimConfig {
            n_dpus: 64,
            dpus_per_rank: calib::DPUS_PER_RANK,
            tasklets: 16,
            freq_hz: calib::DPU_FREQ_HZ,
            wram_bytes: calib::WRAM_BYTES,
            mram_bytes: calib::MRAM_BYTES,
            bus_scale: 1.0,
            serialize_mram: true,
        }
    }
}

impl PimConfig {
    pub fn validate(&self) -> crate::util::Result<()> {
        crate::ensure!(self.n_dpus > 0, "need at least one DPU");
        crate::ensure!(
            self.n_dpus <= calib::MAX_SYSTEM_DPUS,
            "n_dpus {} exceeds system maximum {}",
            self.n_dpus,
            calib::MAX_SYSTEM_DPUS
        );
        crate::ensure!(
            (1..=calib::MAX_TASKLETS).contains(&self.tasklets),
            "tasklets must be in 1..={}",
            calib::MAX_TASKLETS
        );
        crate::ensure!(self.dpus_per_rank > 0, "dpus_per_rank");
        crate::ensure!(self.bus_scale > 0.0, "bus_scale");
        Ok(())
    }

    /// Number of (possibly partial) ranks spanned by the allocation.
    pub fn n_ranks(&self) -> usize {
        crate::util::ceil_div(self.n_dpus, self.dpus_per_rank)
    }

    /// Seconds per DPU cycle.
    pub fn cycle_s(&self) -> f64 {
        1.0 / self.freq_hz
    }
}

/// A simulated PIM system: configuration + derived topology.
///
/// The system is stateless between kernels (the coordinator owns data
/// placement); it exists to carry the configuration and to evaluate the
/// timing/energy models.
#[derive(Clone, Debug, Default)]
pub struct PimSystem {
    pub cfg: PimConfig,
}

impl PimSystem {
    pub fn new(cfg: PimConfig) -> crate::util::Result<Self> {
        cfg.validate()?;
        Ok(PimSystem { cfg })
    }

    /// Shorthand: default config with `n` DPUs.
    pub fn with_dpus(n: usize) -> Self {
        PimSystem { cfg: PimConfig { n_dpus: n, ..Default::default() } }
    }

    /// Shorthand: single DPU with `t` tasklets (the paper's §"one DPU"
    /// analysis).
    pub fn single_dpu(t: usize) -> Self {
        PimSystem { cfg: PimConfig { n_dpus: 1, tasklets: t, ..Default::default() } }
    }

    pub fn n_dpus(&self) -> usize {
        self.cfg.n_dpus
    }

    pub fn tasklets(&self) -> usize {
        self.cfg.tasklets
    }

    /// Peak GFLOP/s of the allocated DPUs for a data type.
    pub fn peak_gflops(&self, dt: crate::matrix::DType) -> f64 {
        calib::dpu_peak_gflops(dt) * self.cfg.n_dpus as f64 * self.cfg.freq_hz
            / calib::DPU_FREQ_HZ
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        PimConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(PimConfig { n_dpus: 0, ..Default::default() }.validate().is_err());
        assert!(PimConfig { n_dpus: 99999, ..Default::default() }.validate().is_err());
        assert!(PimConfig { tasklets: 0, ..Default::default() }.validate().is_err());
        assert!(PimConfig { tasklets: 25, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn rank_math() {
        assert_eq!(PimSystem::with_dpus(64).cfg.n_ranks(), 1);
        assert_eq!(PimSystem::with_dpus(65).cfg.n_ranks(), 2);
        assert_eq!(PimSystem::with_dpus(2560).cfg.n_ranks(), 40);
    }

    #[test]
    fn peak_scales_with_dpus() {
        let a = PimSystem::with_dpus(64).peak_gflops(crate::matrix::DType::F32);
        let b = PimSystem::with_dpus(128).peak_gflops(crate::matrix::DType::F32);
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
