//! Bench E2: synchronization approaches (paper Fig. 6): lock-free vs
//! coarse-grained vs fine-grained locking on a multithreaded DPU.

mod common;
use sparsep::bench_harness::figures;

fn main() {
    common::banner("sync_schemes", "Fig. 6 synchronization approaches");
    common::timed("e2_sync_schemes", || {
        figures::e2_sync_schemes(common::scale());
    });
}
