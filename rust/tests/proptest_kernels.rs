//! Property-based tests (hand-rolled; proptest is not in the offline
//! vendor set): randomized matrices, kernels, and system shapes must
//! always produce the exact host-oracle result and satisfy the
//! coordinator's structural invariants.

// These suites deliberately exercise `SpmvExecutor`'s deprecated
// compatibility wrappers (`execute` / `execute_batch` / `run_iterations`
// / `run_iterations_batch` / `run`): they lock the wrappers' behavior
// until a future major removal. New code routes through
// `coordinator::SpmvService` or `ExecutionPlan::{execute, ...}`.
#![allow(deprecated)]

use sparsep::coordinator::{KernelSpec, Partitioning, SpmvExecutor};
use sparsep::kernels::SyncScheme;
use sparsep::matrix::CooMatrix;
use sparsep::partition::balance::{split_even, split_weighted};
use sparsep::pim::{PimConfig, PimSystem};
use sparsep::util::rng::Rng;

/// Random sparse matrix with rng-chosen shape and density.
fn random_matrix(rng: &mut Rng) -> CooMatrix<f64> {
    let nrows = 1 + rng.gen_range(300);
    let ncols = 1 + rng.gen_range(300);
    let nnz = rng.gen_range(4 * nrows.min(ncols) + 1);
    let mut triples = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        triples.push((
            rng.gen_range(nrows) as u32,
            rng.gen_range(ncols) as u32,
            (rng.gen_range(9) as f64) - 4.0,
        ));
    }
    CooMatrix::from_triples(nrows, ncols, triples)
}

fn random_spec(rng: &mut Rng) -> KernelSpec {
    let all = KernelSpec::all25(1 + rng.gen_range(8));
    let mut spec = all[rng.gen_range(all.len())].clone();
    // Randomize the orthogonal axes too.
    spec = spec.with_sync(
        [SyncScheme::LockFree, SyncScheme::CoarseLock, SyncScheme::FineLock][rng.gen_range(3)],
    );
    let (br, bc) = ([1usize, 2, 3, 4, 8][rng.gen_range(5)], [1usize, 2, 4, 8][rng.gen_range(4)]);
    spec.with_block(br, bc)
}

/// PROPERTY: every (matrix, kernel, system) triple is exact.
#[test]
fn prop_random_runs_are_exact() {
    let mut rng = Rng::new(0xC0FFEE);
    for trial in 0..120 {
        let m = random_matrix(&mut rng);
        let spec = random_spec(&mut rng);
        let n_dpus = 1 + rng.gen_range(100);
        let tasklets = 1 + rng.gen_range(24);
        // 2D needs n_dpus divisible by stripes; round up.
        let (spec, n_dpus) = match spec.partitioning {
            Partitioning::TwoD(_, stripes) => {
                (spec, sparsep::util::round_up(n_dpus.max(stripes), stripes))
            }
            _ => (spec, n_dpus),
        };
        let exec = SpmvExecutor::new(PimSystem {
            cfg: PimConfig { n_dpus, tasklets, ..Default::default() },
        });
        let x: Vec<f64> = (0..m.ncols()).map(|i| ((i % 13) as f64) - 6.0).collect();
        let r = exec
            .run(&spec, &m, &x)
            .unwrap_or_else(|e| panic!("trial {trial} {} failed: {e}", spec.name));
        assert_eq!(
            r.y,
            m.spmv(&x),
            "trial {trial}: kernel {} d={n_dpus} t={tasklets} {}x{} nnz={}",
            spec.name,
            m.nrows(),
            m.ncols(),
            m.nnz()
        );
        // Structural invariants.
        assert!(r.breakdown.total_s() >= 0.0);
        assert!(r.stats.dpu_imbalance >= 0.99, "imbalance {}", r.stats.dpu_imbalance);
        assert!(r.stats.padding_overhead() >= 0.99);
        assert!(r.energy.total_j() >= 0.0);
    }
}

/// PROPERTY: weighted splits cover the index space exactly once, in
/// order, for arbitrary weights.
#[test]
fn prop_splits_partition_domain() {
    let mut rng = Rng::new(42);
    for _ in 0..300 {
        let n = rng.gen_range(200);
        let k = 1 + rng.gen_range(40);
        let weights: Vec<usize> = (0..n).map(|_| rng.gen_range(50)).collect();
        for chunks in [split_even(n, k), split_weighted(&weights, k)] {
            assert_eq!(chunks.len(), k);
            let mut expect = 0usize;
            for c in &chunks {
                assert_eq!(c.start, expect, "gap/overlap");
                assert!(c.end >= c.start);
                expect = c.end;
            }
            assert_eq!(expect, n, "must cover the whole domain");
        }
    }
}

/// PROPERTY: timing is monotone in work — adding non-zeros never makes
/// the modeled kernel faster (same shape, same system).
#[test]
fn prop_more_nnz_never_faster() {
    let mut rng = Rng::new(7);
    let exec = SpmvExecutor::new(PimSystem::with_dpus(4));
    for _ in 0..20 {
        let n = 64 + rng.gen_range(100);
        let base_nnz = 1 + rng.gen_range(400);
        let mut triples: Vec<(u32, u32, f64)> = (0..base_nnz)
            .map(|_| (rng.gen_range(n) as u32, rng.gen_range(n) as u32, 1.0))
            .collect();
        let m1 = CooMatrix::from_triples(n, n, triples.clone());
        // Superset matrix: strictly more non-zeros.
        for _ in 0..200 {
            triples.push((rng.gen_range(n) as u32, rng.gen_range(n) as u32, 1.0));
        }
        let m2 = CooMatrix::from_triples(n, n, triples);
        if m2.nnz() <= m1.nnz() {
            continue; // all extras were duplicates
        }
        let x = vec![1.0f64; n];
        let c1 = exec.run(&KernelSpec::coo_nnz(), &m1, &x).unwrap().stats.kernel_cycles;
        let c2 = exec.run(&KernelSpec::coo_nnz(), &m2, &x).unwrap().stats.kernel_cycles;
        assert!(c2 >= c1, "more work ran faster: {c1} -> {c2}");
    }
}

/// PROPERTY: the linearity of SpMV — A(x + y) == Ax + Ay — holds through
/// the whole coordinator (catches partial-merge bugs that a single
/// oracle comparison might mask).
#[test]
fn prop_spmv_linearity() {
    let mut rng = Rng::new(99);
    let exec = SpmvExecutor::new(PimSystem::with_dpus(16));
    for _ in 0..20 {
        let m = random_matrix(&mut rng);
        let xa: Vec<f64> = (0..m.ncols()).map(|_| rng.gen_range(7) as f64).collect();
        let xb: Vec<f64> = (0..m.ncols()).map(|_| rng.gen_range(7) as f64).collect();
        let xs: Vec<f64> = xa.iter().zip(&xb).map(|(a, b)| a + b).collect();
        let ya = exec.run(&KernelSpec::coo_nnz(), &m, &xa).unwrap().y;
        let yb = exec.run(&KernelSpec::coo_nnz(), &m, &xb).unwrap().y;
        let ys = exec.run(&KernelSpec::coo_nnz(), &m, &xs).unwrap().y;
        for i in 0..m.nrows() {
            assert_eq!(ys[i], ya[i] + yb[i], "row {i} (integer-valued, exact)");
        }
    }
}

/// PROPERTY: fine-grained locking never beats coarse-grained on the
/// modeled hardware (the paper's serialization finding), across random
/// shared-row-heavy inputs.
#[test]
fn prop_fine_lock_never_wins() {
    let mut rng = Rng::new(1234);
    let exec = SpmvExecutor::new(PimSystem::single_dpu(16));
    for _ in 0..15 {
        // Few rows, many elements: element splits must share rows.
        let nrows = 1 + rng.gen_range(6);
        let ncols = 64 + rng.gen_range(400);
        let nnz = 500 + rng.gen_range(1500);
        let triples: Vec<(u32, u32, f64)> = (0..nnz)
            .map(|_| (rng.gen_range(nrows) as u32, rng.gen_range(ncols) as u32, 1.0))
            .collect();
        let m = CooMatrix::from_triples(nrows, ncols, triples);
        let x = vec![1.0f64; ncols];
        let coarse = exec
            .run(&KernelSpec::coo_nnz().with_sync(SyncScheme::CoarseLock), &m, &x)
            .unwrap()
            .stats
            .kernel_cycles;
        let fine = exec
            .run(&KernelSpec::coo_nnz().with_sync(SyncScheme::FineLock), &m, &x)
            .unwrap()
            .stats
            .kernel_cycles;
        assert!(fine >= coarse, "fine {fine} beat coarse {coarse}");
    }
}
