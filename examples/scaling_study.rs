//! Scaling study: where 1D stops scaling and what 2D buys back —
//! the condensed story of the paper's Figs. 9-15.
//!
//! Sweeps the DPU count for the best 1D kernel (kernel-only vs
//! end-to-end) and then sweeps the stripe count for the three 2D schemes
//! at the largest system size.

use sparsep::bench_harness::Table;
use sparsep::coordinator::{Engine, KernelSpec, SpmvExecutor};
use sparsep::matrix::{generate, Format};
use sparsep::pim::PimSystem;

fn main() -> sparsep::util::Result<()> {
    let m = generate::uniform::<f64>(16384, 16384, 16, 7);
    let x = vec![1.0f64; m.ncols()];
    println!("matrix: {}x{} nnz={}", m.nrows(), m.ncols(), m.nnz());

    println!("\n== 1D scaling (COO.nnz-rgrn): kernel-only vs end-to-end ==");
    let mut t = Table::new(&["dpus", "kernel GF/s", "e2e GF/s", "load-share", "dominant"]);
    for d in [16usize, 64, 256, 1024, 2048] {
        let exec = SpmvExecutor::with_engine(PimSystem::with_dpus(d), Engine::threaded(0));
        let r = exec.plan(&KernelSpec::coo_nnz_rgrn(), &m)?.execute(&exec, &x)?;
        let b = r.breakdown;
        t.row(&[
            d.to_string(),
            format!("{:.2}", r.kernel_gflops()),
            format!("{:.2}", r.e2e_gflops()),
            format!("{:.0}%", 100.0 * b.load_s / b.total_s()),
            b.dominant().into(),
        ]);
    }
    t.print();
    println!("(kernel-only keeps scaling; end-to-end hits the broadcast wall)");

    println!("\n== 2D at 2048 DPUs: stripes sweep per scheme ==");
    let exec = SpmvExecutor::with_engine(PimSystem::with_dpus(2048), Engine::threaded(0));
    for scheme in [
        KernelSpec::two_d(Format::Coo, 2),
        KernelSpec::two_d_equally_wide(Format::Coo, 2),
        KernelSpec::two_d_balanced(Format::Coo, 2),
    ] {
        let mut t = Table::new(&["stripes", "e2e GF/s", "load-ms", "retr-ms", "merge-ms", "pad"]);
        let mut best = (0usize, 0.0f64);
        for stripes in [2usize, 4, 8, 16, 32] {
            let spec = scheme.clone().with_stripes(stripes);
            let plan = exec.plan(&spec, &m)?;
            let r = plan.execute(&exec, &x)?;
            let g = r.e2e_gflops();
            if g > best.1 {
                best = (stripes, g);
            }
            t.row(&[
                stripes.to_string(),
                format!("{g:.2}"),
                format!("{:.3}", r.breakdown.load_s * 1e3),
                format!("{:.3}", r.breakdown.retrieve_s * 1e3),
                format!("{:.3}", r.breakdown.merge_s * 1e3),
                format!("{:.2}x", r.stats.padding_overhead()),
            ]);
        }
        println!("-- {} -- (best: {} stripes, {:.2} GF/s)", scheme.name, best.0, best.1);
        t.print();
    }

    println!("\n== best 1D vs best 2D, end-to-end ==");
    let one = exec.plan(&KernelSpec::coo_nnz_rgrn(), &m)?.execute(&exec, &x)?;
    let two = exec.plan(&KernelSpec::two_d_equally_wide(Format::Coo, 16), &m)?.execute(&exec, &x)?;
    println!(
        "1D COO.nnz-rgrn: {:.2} GF/s   2D RBDCOO/16: {:.2} GF/s   winner: {}",
        one.e2e_gflops(),
        two.e2e_gflops(),
        if one.e2e_gflops() > two.e2e_gflops() { "1D" } else { "2D" }
    );
    Ok(())
}
