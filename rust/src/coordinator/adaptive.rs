//! Adaptive kernel selection — the paper's software recommendation #3
//! turned into a feature.
//!
//! > "Design adaptive algorithms that (i) trade off computation balance
//! > for lower data transfer costs and (ii) select the load balancing
//! > strategy and data partitioning policy based on the particular
//! > sparsity pattern of the input matrix and the characteristics of
//! > the underlying PIM hardware."
//!
//! Three selectors, cheapest to dearest:
//! * [`select_heuristic`] — O(1) decision rules over [`MatrixStats`] and
//!   the [`PimConfig`], encoding the paper's findings (block structure
//!   -> BCOO; high CV -> element-granularity COO; many DPUs + wide
//!   vector -> 2D; etc.).
//! * [`select_auto`] — consult a measured
//!   [`CalibrationTable`](super::calibration::CalibrationTable) by
//!   nearest-neighbor over normalized sparsity statistics (batch-aware),
//!   falling back to the heuristic when no table is loaded or the
//!   recorded winner cannot be reconstructed on this system.
//! * [`autotune`] — exhaustive search over the 25 kernels on the actual
//!   executor (ground truth; 25 planned-and-executed runs). This is the
//!   inner measurement primitive of the offline search in
//!   [`super::tuner`], and it is batch-aware: ranking a kernel for a
//!   `B`-vector serving workload measures a `B`-vector batch, not a
//!   single SpMV.
//!
//! The unit tests check the heuristic agrees with the autotuner's
//! *family* (1D vs 2D, balanced vs not) on the canonical matrix classes.

use super::calibration::CalibrationTable;
use super::{KernelSpec, SpmvExecutor};
use crate::matrix::{BcsrMatrix, CooMatrix, Format, MatrixStats, SpElem};
use crate::pim::PimConfig;

/// Why the selector picked what it picked (for logs and the CLI).
#[derive(Clone, Debug)]
pub struct Choice {
    pub spec: KernelSpec,
    pub reason: String,
}

/// Rule-based selection from sparsity statistics + hardware shape.
pub fn select_heuristic<T: SpElem>(m: &CooMatrix<T>, cfg: &PimConfig) -> Choice {
    let stats = MatrixStats::of(m);
    let n_dpus = cfg.n_dpus.max(1);

    // 1. Broadcast-wall test: 1D copies the whole vector to every DPU.
    //    Compare broadcast bytes against the kernel's useful work; when
    //    the vector dominates, go 2D (fewer bytes per DPU, stripes keep
    //    partials manageable).
    let bytes_broadcast = stats.ncols * T::DTYPE.size_bytes() * n_dpus;
    let work_per_iter = stats.nnz * 16; // rough bytes-equivalent of compute
    let two_d_pays = n_dpus >= 64 && bytes_broadcast > 4 * work_per_iter;

    // 2. Block-structure test: does 4x4 blocking stay dense enough that
    //    the per-block savings beat the fill-in?
    let fill = BcsrMatrix::from_coo(m, 4, 4).fill_ratio();
    let blocky = fill < 1.6;

    // 3. Skew test: CV of nnz/row decides the balancing granularity.
    let skewed = stats.nnz_per_row_cv > 0.5;

    if two_d_pays {
        let stripes = pick_stripes(n_dpus);
        let fmt = if blocky { Format::Bcoo } else { Format::Coo };
        let spec = if skewed {
            KernelSpec::two_d_balanced(fmt, stripes)
        } else {
            KernelSpec::two_d_equally_wide(fmt, stripes)
        };
        return Choice {
            reason: format!(
                "broadcast {}B > 4x work {}B at {n_dpus} DPUs -> 2D/{} ({}, cv={:.2}, fill={fill:.2})",
                bytes_broadcast, work_per_iter, stripes, spec.name, stats.nnz_per_row_cv
            ),
            spec,
        };
    }

    // 1D: pick format + balancing by structure.
    let spec = if blocky && !skewed {
        KernelSpec::bcoo_nnz()
    } else if skewed {
        // Element-granularity COO is the only scheme that tames hot rows.
        KernelSpec::coo_nnz()
    } else {
        KernelSpec::csr_nnz()
    };
    Choice {
        reason: format!(
            "1D: cv={:.2} fill={fill:.2} -> {} (skewed={skewed}, blocky={blocky})",
            stats.nnz_per_row_cv, spec.name
        ),
        spec,
    }
}

/// Calibrated selection: nearest-neighbor over the table's normalized
/// feature vectors (batch-aware). `None` when the table is empty or the
/// recorded winner's kernel name cannot be reconstructed on this build —
/// callers fall back to [`select_heuristic`].
pub fn select_calibrated<T: SpElem>(
    m: &CooMatrix<T>,
    cfg: &PimConfig,
    batch: usize,
    table: &CalibrationTable,
) -> Option<Choice> {
    let stats = MatrixStats::of(m);
    let entry = table.lookup(&stats, batch)?;
    let spec = table.spec_for(entry, cfg)?;
    Some(Choice {
        reason: format!(
            "calibrated: nearest entry {} @batch {} ({}, measured {:.3} ms vs heuristic {:.3} ms) -> {}",
            entry.matrix,
            entry.batch,
            entry.class,
            entry.wall_s * 1e3,
            entry.heuristic_wall_s * 1e3,
            spec.name
        ),
        spec,
    })
}

/// The serving stack's selection entry point: calibrated when a table is
/// loaded (and usable), heuristic otherwise. This is what replaces every
/// direct `select_heuristic` call on the `run`/`serve` paths.
pub fn select_auto<T: SpElem>(
    m: &CooMatrix<T>,
    cfg: &PimConfig,
    batch: usize,
    table: Option<&CalibrationTable>,
) -> Choice {
    table
        .and_then(|t| select_calibrated(m, cfg, batch, t))
        .unwrap_or_else(|| select_heuristic(m, cfg))
}

/// Stripe count for the heuristic's 2D picks: the largest power-of-two
/// `s` with `(2s)^2 <= n_dpus` that divides `n_dpus` — balancing the
/// broadcast saving against partial-result volume. When no power of two
/// divides (odd DPU counts), fall back to the largest divisor
/// `<= sqrt(n_dpus)`, and to 1 when none exists (prime counts): the 2D
/// partitioner requires stripes to divide the DPU count, so returning a
/// non-divisor (as this function once did for primes) would make every
/// 2D plan fail.
pub(crate) fn pick_stripes(n_dpus: usize) -> usize {
    let n = n_dpus.max(1);
    let mut s = 1usize;
    while s * 2 * s * 2 <= n && n % (s * 2) == 0 {
        s *= 2;
    }
    if s > 1 {
        return s;
    }
    // Odd (or tiny) counts: largest divisor <= sqrt(n); 1 for primes.
    let mut best = 1;
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            best = d;
        }
        d += 1;
    }
    best
}

/// Ground-truth selection: plan and execute all 25 kernels on the actual
/// (simulated) system and return the fastest end-to-end plus the full
/// ranking. Batch-aware: `xs` is the vector batch of the workload being
/// tuned for (one vector = classic single-SpMV tuning); a kernel's score
/// is its summed modeled time over the whole batch, so kernels whose
/// load cost amortizes across vectors rank accordingly. This is the
/// inner measurement primitive [`super::tuner::tune`] builds on.
pub fn autotune<T: SpElem>(
    exec: &SpmvExecutor,
    m: &CooMatrix<T>,
    xs: &[Vec<T>],
    stripes: usize,
) -> crate::util::Result<(KernelSpec, Vec<(String, f64)>)> {
    crate::ensure!(!xs.is_empty(), "autotune needs at least one vector");
    let mut ranking = Vec::new();
    let mut best: Option<(KernelSpec, f64)> = None;
    for spec in KernelSpec::all25(stripes) {
        let plan = exec.plan(&spec, m)?;
        let batch = plan.execute_batch_runs(exec, xs)?;
        let t: f64 = batch.runs.iter().map(|r| r.breakdown.total_s()).sum();
        ranking.push((spec.name.clone(), t));
        if best.as_ref().map_or(true, |(_, bt)| t < *bt) {
            best = Some((spec, t));
        }
    }
    ranking.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    Ok((best.unwrap().0, ranking))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::calibration::CalibrationEntry;
    use crate::coordinator::Partitioning;
    use crate::matrix::generate;
    use crate::pim::PimSystem;

    fn cfg(n_dpus: usize) -> PimConfig {
        PimConfig { n_dpus, ..Default::default() }
    }

    #[test]
    fn skewed_matrices_get_element_granularity() {
        let m = generate::scale_free::<f64>(2048, 2048, 8, 0.8, 3);
        let c = select_heuristic(&m, &cfg(16));
        assert_eq!(c.spec.name, "COO.nnz", "{}", c.reason);
    }

    #[test]
    fn regular_unstructured_matrices_get_csr() {
        // Uniform-random columns: regular row counts but no block
        // structure (4x4 fill-in would be huge).
        let m = generate::uniform::<f64>(2048, 2048, 16, 3);
        let c = select_heuristic(&m, &cfg(16));
        assert_eq!(c.spec.name, "CSR.nnz", "{}", c.reason);
    }

    #[test]
    fn banded_matrices_may_use_blocking() {
        // A contiguous band blocks densely: BCOO is a legitimate pick.
        let m = generate::banded::<f64>(2048, 16, 3);
        let c = select_heuristic(&m, &cfg(16));
        assert!(
            c.spec.name == "BCOO.nnz" || c.spec.name == "CSR.nnz",
            "{} ({})",
            c.spec.name,
            c.reason
        );
    }

    #[test]
    fn block_matrices_get_bcoo() {
        let m = generate::blocked::<f64>(256, 256, 4, 6, 3);
        let c = select_heuristic(&m, &cfg(16));
        assert_eq!(c.spec.name, "BCOO.nnz", "{}", c.reason);
    }

    #[test]
    fn sparse_wide_at_scale_goes_two_d() {
        // Few nnz per row + thousands of DPUs: broadcast dominates -> 2D.
        let m = generate::uniform::<f64>(16384, 16384, 4, 3);
        let c = select_heuristic(&m, &cfg(2048));
        assert!(c.spec.is_two_d(), "{}", c.reason);
        if let Partitioning::TwoD(_, stripes) = c.spec.partitioning {
            assert!(2048 % stripes == 0);
        }
    }

    #[test]
    fn pick_stripes_divides() {
        for d in [64usize, 128, 256, 512, 1024, 2048] {
            let s = pick_stripes(d);
            assert!(d % s == 0, "stripes {s} must divide {d}");
            assert!(s * s <= d * 2);
        }
    }

    #[test]
    fn pick_stripes_handles_prime_and_odd_counts() {
        // Primes: no divisor <= sqrt(n) but 1 — must return 1, never a
        // non-divisor (the old code returned 2 for every prime).
        for p in [2usize, 3, 7, 13, 97, 101, 1021] {
            assert_eq!(pick_stripes(p), if p == 4 { 2 } else { 1 }, "prime {p}");
        }
        // Odd composites: largest divisor <= sqrt(n).
        assert_eq!(pick_stripes(9), 3);
        assert_eq!(pick_stripes(15), 3);
        assert_eq!(pick_stripes(81), 9);
        assert_eq!(pick_stripes(45), 5);
        // Every count yields a divisor.
        for n in 1..=300 {
            let s = pick_stripes(n);
            assert!(s >= 1 && n % s == 0, "pick_stripes({n}) = {s}");
        }
        assert_eq!(pick_stripes(0), 1, "degenerate count clamps");
    }

    #[test]
    fn select_auto_falls_back_without_a_table() {
        let m = generate::uniform::<f64>(512, 512, 6, 3);
        let h = select_heuristic(&m, &cfg(16));
        let a = select_auto(&m, &cfg(16), 1, None);
        assert_eq!(a.spec.name, h.spec.name);
        // An empty table falls back too.
        let empty = CalibrationTable::default();
        let a = select_auto(&m, &cfg(16), 1, Some(&empty));
        assert_eq!(a.spec.name, h.spec.name);
    }

    #[test]
    fn select_auto_uses_the_table_when_loaded() {
        let m = generate::uniform::<f64>(512, 512, 6, 3);
        let st = MatrixStats::of(&m);
        let table = CalibrationTable::new(vec![CalibrationEntry {
            matrix: "probe".into(),
            class: st.class().into(),
            features: st.feature_vector(),
            batch: 1,
            kernel: "BCOO.nnz".into(),
            stripes: 0,
            block: 4,
            shards: 2,
            grid_cols: 1,
            replicas: 1,
            wall_s: 1e-3,
            heuristic_wall_s: 2e-3,
        }]);
        let c = select_auto(&m, &cfg(16), 1, Some(&table));
        assert_eq!(c.spec.name, "BCOO.nnz", "{}", c.reason);
        assert!(c.reason.contains("calibrated"), "{}", c.reason);
        // A table whose winner can't be reconstructed falls back.
        let bogus = CalibrationTable::new(vec![CalibrationEntry {
            kernel: "NOPE".into(),
            ..table.entries()[0].clone()
        }]);
        let c = select_auto(&m, &cfg(16), 1, Some(&bogus));
        assert_eq!(c.spec.name, select_heuristic(&m, &cfg(16)).spec.name);
    }

    #[test]
    fn heuristic_close_to_autotuned_ground_truth() {
        // The heuristic need not be optimal, but it must land within 2x
        // of the autotuner's best on each canonical class.
        for e in generate::mini_suite() {
            let m = (e.gen)(11);
            let x: Vec<f64> = (0..m.ncols()).map(|i| (i % 7) as f64).collect();
            let exec = SpmvExecutor::new(PimSystem::with_dpus(64));
            let (best_spec, ranking) =
                autotune(&exec, &m, std::slice::from_ref(&x), 8).unwrap();
            let best_t = ranking[0].1;
            let choice = select_heuristic(&m, &exec.sys.cfg);
            let choice_plan = exec.plan(&choice.spec, &m).unwrap();
            let choice_t = choice_plan.execute(&exec, &x).unwrap().breakdown.total_s();
            assert!(
                choice_t <= best_t * 2.0,
                "{}: heuristic {} ({choice_t:.6}s) vs best {} ({best_t:.6}s)",
                e.name,
                choice.spec.name,
                best_spec.name
            );
        }
    }

    #[test]
    fn autotune_ranking_is_sorted_and_complete() {
        let m = generate::uniform::<f64>(256, 256, 6, 5);
        let x = vec![1.0f64; 256];
        let exec = SpmvExecutor::new(PimSystem::with_dpus(16));
        let (_, ranking) = autotune(&exec, &m, std::slice::from_ref(&x), 4).unwrap();
        assert_eq!(ranking.len(), 25);
        assert!(ranking.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    #[test]
    fn autotune_is_batch_aware() {
        // A B-vector batch costs B x the modeled single-vector time for
        // every kernel (modeled costs are per vector), so the batched
        // ranking must agree with B * the single-vector ranking — and an
        // empty batch is rejected.
        let m = generate::uniform::<f64>(256, 256, 6, 5);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(16));
        let xs: Vec<Vec<f64>> = (0..3)
            .map(|s| (0..256).map(|i| ((i + s) % 7) as f64).collect())
            .collect();
        let (_, single) = autotune(&exec, &m, &xs[..1], 4).unwrap();
        let (_, batched) = autotune(&exec, &m, &xs, 4).unwrap();
        let single: std::collections::HashMap<_, _> = single.into_iter().collect();
        for (name, t) in &batched {
            let expect = single[name] * 3.0;
            assert!((t - expect).abs() <= 1e-9 * expect.max(1e-30), "{name}: {t} vs {expect}");
        }
        assert!(autotune(&exec, &m, &[], 4).is_err());
    }
}
