//! Open-loop Poisson load generator for the TCP serving front end
//! (`sparsep bench-net`), emitting `BENCH_net.json`.
//!
//! *Open loop* means arrivals are scheduled by a Poisson process (one
//! independent sender per connection, exponential inter-arrival
//! times), not by response completion — so when the server slows
//! down, requests keep arriving and queueing delay shows up in the
//! measured latency instead of silently throttling the offered load
//! (the classic closed-loop coordinated-omission trap). Each level
//! also ramps its instantaneous rate from 50% to 150% of the nominal
//! figure across the run, so a level sweeps through its own
//! neighborhood instead of sampling one operating point.
//!
//! Per connection, one submit thread writes `SubmitSpmv` frames on the
//! Poisson schedule (tenants drawn 2:1 alice:bob, matching the served
//! facade's weights) and one reader thread consumes the streamed
//! responses: `Submitted` acks pair with submissions in request order,
//! `Completion`s record end-to-end latency into a
//! [`LatencyHistogram`], and both shed layers (`Overloaded {0}` at the
//! connection cap, `Overloaded {ticket}` from admission control) are
//! counted as typed sheds, never as losses. The report carries
//! p50/p99/p999/max per offered-load level — at least two levels, so
//! the latency/throughput curve has a slope, not a point.

use crate::coordinator::queue::DEFAULT_QUEUE_DEPTH;
use crate::coordinator::{
    Engine, LatencyHistogram, LatencySnapshot, ShardedService, ShardedServiceBuilder, TenantSpec,
};
use crate::matrix::{generate, CooMatrix};
use crate::net::client::Client;
use crate::net::protocol::{decode_stream, Frame};
use crate::net::server::{Server, ServerOpts};
use crate::pim::PimSystem;
use crate::util::json::{arr, num, obj, s};
use crate::util::rng::Rng;
use crate::util::sync::{thread, Arc, Mutex};
use crate::util::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::time::{Duration, Instant};

/// Kernel every load-generated matrix is served with.
const KERNEL: &str = "COO.nnz";
/// Tenant mix: weight-proportional draw, matching the facade's WRR
/// weights (2:1).
const TENANTS: [(&str, usize); 2] = [("alice", 2), ("bob", 1)];
/// How long a level waits for in-flight requests to drain after the
/// last submission before counting the stragglers as lost.
const DRAIN_DEADLINE: Duration = Duration::from_secs(30);

/// Options for `sparsep bench-net`.
#[derive(Clone, Debug)]
pub struct LoadgenOpts {
    /// Square matrix dimension served during the run.
    pub rows: usize,
    /// Mean non-zeros per row of the generated matrix.
    pub deg: usize,
    /// Shards of the in-process server (ignored with `addr`).
    pub shards: usize,
    /// DPUs per shard of the in-process server (ignored with `addr`).
    pub n_dpus: usize,
    /// Concurrent client connections per level.
    pub conns: usize,
    /// Requests per offered-load level (split across connections).
    pub requests: usize,
    /// Offered load levels, requests/second. At least two, so the
    /// report is a curve; each level also ramps 50% -> 150% internally.
    pub rates: Vec<f64>,
    /// Per-tenant admission cap of the in-process server — small
    /// enough caps make the top level shed visibly (typed, counted).
    pub max_queue: usize,
    /// Deterministic seed (matrix + arrival schedule + tenant draw).
    pub seed: u64,
    /// Aim at an already-running server instead of spawning one.
    pub addr: Option<String>,
    /// Report path.
    pub out: String,
}

impl Default for LoadgenOpts {
    fn default() -> LoadgenOpts {
        LoadgenOpts {
            rows: 1500,
            deg: 6,
            shards: 2,
            n_dpus: 16,
            conns: 2,
            requests: 240,
            rates: vec![300.0, 1200.0],
            max_queue: 128,
            seed: 0x10AD,
            addr: None,
            out: "BENCH_net.json".to_string(),
        }
    }
}

/// One level's aggregated outcome.
struct LevelStats {
    offered: f64,
    achieved: f64,
    submitted: u64,
    completed: u64,
    shed: u64,
    errors: u64,
    lost: u64,
    snap: LatencySnapshot,
}

/// Level-wide counters shared by every connection's reader.
#[derive(Default)]
struct LevelAgg {
    hist: LatencyHistogram,
    completed: u64,
    shed: u64,
    errors: u64,
}

/// Per-connection pairing state: submit instants waiting for their
/// ack (acks arrive in request order), then in-flight by ticket.
#[derive(Default)]
struct ConnState {
    pending: VecDeque<Instant>,
    in_flight: HashMap<u64, Instant>,
}

/// Run the generator: spawn (or dial) a server, drive every offered
/// load level, print a summary table, write the JSON report.
pub fn run(opts: &LoadgenOpts) -> Result<()> {
    crate::ensure!(!opts.rates.is_empty(), "bench-net needs at least one --rates level");
    crate::ensure!(opts.conns >= 1, "bench-net needs at least one connection");
    let server = match &opts.addr {
        Some(_) => None,
        None => Some(spawn_local(opts)?),
    };
    let addr = match &opts.addr {
        Some(a) => a.clone(),
        None => server.as_ref().expect("spawned above").local_addr().to_string(),
    };
    let m = generate::scale_free::<f64>(opts.rows, opts.rows, opts.deg, 0.7, opts.seed);
    println!(
        "bench-net: {}x{} ({} nnz) via {KERNEL} at {addr}, {} conn(s), {} req/level",
        m.nrows(),
        m.ncols(),
        m.nnz(),
        opts.conns,
        opts.requests
    );

    let mut levels = Vec::with_capacity(opts.rates.len());
    for (li, &rate) in opts.rates.iter().enumerate() {
        let lv = run_level(&addr, opts, &m, rate, li as u64)?;
        println!(
            "  level {:>8.1} rps offered: {:>8.1} achieved, {}/{} completed, {} shed, {} errors{}  \
             p50/p99/p999 {}/{}/{} us (max {})",
            lv.offered,
            lv.achieved,
            lv.completed,
            lv.submitted,
            lv.shed,
            lv.errors,
            if lv.lost > 0 { format!(", {} LOST", lv.lost) } else { String::new() },
            lv.snap.p50_us,
            lv.snap.p99_us,
            lv.snap.p999_us,
            lv.snap.max_us
        );
        levels.push(lv);
    }

    let j = obj(vec![
        ("bench", s("net")),
        ("rows", num(opts.rows as f64)),
        ("deg", num(opts.deg as f64)),
        ("shards", num(opts.shards as f64)),
        ("conns", num(opts.conns as f64)),
        (
            "levels",
            arr(levels
                .iter()
                .map(|lv| {
                    obj(vec![
                        ("offered_rps", num(lv.offered)),
                        ("achieved_rps", num(lv.achieved)),
                        ("requests", num(lv.submitted as f64)),
                        ("completed", num(lv.completed as f64)),
                        ("shed", num(lv.shed as f64)),
                        ("errors", num(lv.errors as f64)),
                        ("lost", num(lv.lost as f64)),
                        ("p50_us", num(lv.snap.p50_us as f64)),
                        ("p99_us", num(lv.snap.p99_us as f64)),
                        ("p999_us", num(lv.snap.p999_us as f64)),
                        ("max_us", num(lv.snap.max_us as f64)),
                    ])
                })
                .collect()),
        ),
    ]);
    std::fs::write(&opts.out, j.to_string() + "\n")
        .with_context(|| format!("write {}", opts.out))?;
    println!("wrote {}", opts.out);
    Ok(())
}

/// The in-process server the generator aims at when no `addr` is
/// given: tenants matching [`TENANTS`], typed admission shedding at
/// `max_queue`.
fn spawn_local(opts: &LoadgenOpts) -> Result<Server> {
    let mut b = ShardedServiceBuilder::new()
        .shards(opts.shards.max(1))
        .engine(Engine::Serial)
        .queue_depth(DEFAULT_QUEUE_DEPTH)
        .tenants(TENANTS.iter().map(|&(n, w)| TenantSpec::new(n, w)).collect());
    if opts.max_queue > 0 {
        b = b.max_queue(opts.max_queue);
    }
    let svc: ShardedService<f64> = b.build(PimSystem::with_dpus(opts.n_dpus.max(1)))?;
    Server::spawn(svc, "127.0.0.1:0", ServerOpts::default())
}

fn run_level(
    addr: &str,
    opts: &LoadgenOpts,
    m: &CooMatrix<f64>,
    rate: f64,
    level_idx: u64,
) -> Result<LevelStats> {
    let level = Arc::new(Mutex::new(LevelAgg::default()));
    let mut conn_states: Vec<Arc<Mutex<ConnState>>> = Vec::with_capacity(opts.conns);
    let mut submitters = Vec::with_capacity(opts.conns);
    let mut readers = Vec::with_capacity(opts.conns);
    let mut shut: Vec<TcpStream> = Vec::with_capacity(opts.conns);
    let rate_per_conn = rate / opts.conns as f64;
    let t0 = Instant::now();
    let mut submitted_total = 0u64;

    for c in 0..opts.conns {
        // Synchronous load phase: one handle per tenant, then unwrap
        // the raw socket for the open-loop threads.
        let mut cl = Client::connect(addr)?;
        let h_alice = cl.load(TENANTS[0].0, m, KERNEL, 8)?;
        let h_bob = cl.load(TENANTS[1].0, m, KERNEL, 8)?;
        let stream = cl.into_stream()?;
        let rstream = stream.try_clone().context("clone socket for the reader thread")?;
        shut.push(stream.try_clone().context("clone socket for level teardown")?);

        let n = opts.requests / opts.conns + usize::from(c < opts.requests % opts.conns);
        submitted_total += n as u64;
        let state = Arc::new(Mutex::new(ConnState::default()));
        conn_states.push(Arc::clone(&state));

        let rd_state = Arc::clone(&state);
        let rd_level = Arc::clone(&level);
        readers.push(thread::spawn_named(&format!("spmv-loadgen-read-{c}"), move || {
            reader_loop(rstream, &rd_state, &rd_level);
        }));

        let ncols = m.ncols();
        let seed = opts.seed ^ (level_idx << 32) ^ (c as u64).wrapping_mul(0x9E37_79B9);
        submitters.push(thread::spawn_named(&format!("spmv-loadgen-send-{c}"), move || {
            submit_loop(stream, &state, ncols, h_alice, h_bob, n, rate_per_conn, seed);
        }));
    }

    for h in submitters {
        let _ = h.join();
    }
    // Drain: the responses of everything submitted are still streaming.
    let deadline = Instant::now() + DRAIN_DEADLINE;
    loop {
        let busy = conn_states.iter().any(|st| {
            let st = st.lock().expect("conn state poisoned");
            !st.pending.is_empty() || !st.in_flight.is_empty()
        });
        if !busy || Instant::now() > deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let elapsed = t0.elapsed().as_secs_f64();
    for sock in shut {
        let _ = sock.shutdown(Shutdown::Both);
    }
    for h in readers {
        let _ = h.join();
    }

    let lost: u64 = conn_states
        .iter()
        .map(|st| {
            let st = st.lock().expect("conn state poisoned");
            (st.pending.len() + st.in_flight.len()) as u64
        })
        .sum();
    let agg = level.lock().expect("level aggregate poisoned");
    Ok(LevelStats {
        offered: rate,
        achieved: agg.completed as f64 / elapsed.max(1e-9),
        submitted: submitted_total,
        completed: agg.completed,
        shed: agg.shed,
        errors: agg.errors,
        lost,
        snap: agg.hist.snapshot(),
    })
}

/// One connection's open-loop sender: Poisson arrivals at a ramping
/// rate, tenants drawn weight-proportionally, every submission's
/// instant queued for the reader to pair with its in-order ack.
#[allow(clippy::too_many_arguments)]
fn submit_loop(
    mut stream: TcpStream,
    state: &Arc<Mutex<ConnState>>,
    ncols: usize,
    h_alice: u64,
    h_bob: u64,
    n: usize,
    rate: f64,
    seed: u64,
) {
    let mut rng = Rng::new(seed);
    let weight_total: usize = TENANTS.iter().map(|&(_, w)| w).sum();
    for i in 0..n {
        // Ramp profile: instantaneous rate sweeps 0.5x -> 1.5x of the
        // level's nominal rate across the run.
        let progress = i as f64 / n.max(1) as f64;
        let r = (rate * (0.5 + progress)).max(1e-9);
        // Exponential inter-arrival (inverse CDF); capped so a tiny
        // configured rate cannot wedge the level.
        let dt = (-(1.0 - rng.gen_f64()).ln() / r).min(0.25);
        std::thread::sleep(Duration::from_secs_f64(dt));
        let (tenant, handle) = if rng.gen_range(weight_total) < TENANTS[0].1 {
            (TENANTS[0].0, h_alice)
        } else {
            (TENANTS[1].0, h_bob)
        };
        let x: Vec<f64> = (0..ncols).map(|j| (((j + i) % 7) as f64) - 3.0).collect();
        let frame =
            Frame::SubmitSpmv { tenant: tenant.to_string(), handle, deadline_ms: 0, x };
        state.lock().expect("conn state poisoned").pending.push_back(Instant::now());
        if stream.write_all(&frame.encode()).is_err() {
            // Server gone: retract the unpaired submission and stop.
            state.lock().expect("conn state poisoned").pending.pop_back();
            break;
        }
    }
}

/// One connection's reader: pair acks with submissions (request
/// order), record completion latencies, count both shed layers and
/// typed errors. Exits on EOF / socket shutdown.
fn reader_loop(mut stream: TcpStream, state: &Arc<Mutex<ConnState>>, level: &Arc<Mutex<LevelAgg>>) {
    let mut rbuf: Vec<u8> = Vec::new();
    loop {
        loop {
            match decode_stream(&rbuf) {
                Ok(Some((frame, n))) => {
                    rbuf.drain(..n);
                    on_frame(frame, state, level);
                }
                Ok(None) => break,
                Err(_) => return, // corrupt stream; the level's drain accounts the loss
            }
        }
        let mut chunk = [0u8; 16 * 1024];
        match stream.read(&mut chunk) {
            Ok(0) => return,
            Ok(n) => rbuf.extend_from_slice(&chunk[..n]),
            Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    }
}

fn on_frame(frame: Frame, state: &Arc<Mutex<ConnState>>, level: &Arc<Mutex<LevelAgg>>) {
    match frame {
        Frame::Submitted { ticket } => {
            let mut st = state.lock().expect("conn state poisoned");
            if let Some(t0) = st.pending.pop_front() {
                st.in_flight.insert(ticket, t0);
            }
        }
        Frame::Overloaded { ticket: 0 } => {
            state.lock().expect("conn state poisoned").pending.pop_front();
            level.lock().expect("level aggregate poisoned").shed += 1;
        }
        Frame::Overloaded { ticket } => {
            if state.lock().expect("conn state poisoned").in_flight.remove(&ticket).is_some() {
                level.lock().expect("level aggregate poisoned").shed += 1;
            }
        }
        Frame::Completion { ticket, .. } => {
            let t0 = state.lock().expect("conn state poisoned").in_flight.remove(&ticket);
            if let Some(t0) = t0 {
                let mut agg = level.lock().expect("level aggregate poisoned");
                agg.hist.record(t0.elapsed().as_micros() as u64);
                agg.completed += 1;
            }
        }
        Frame::Error { ticket: 0, .. } => {
            state.lock().expect("conn state poisoned").pending.pop_front();
            level.lock().expect("level aggregate poisoned").errors += 1;
        }
        Frame::Error { ticket, .. } => {
            state.lock().expect("conn state poisoned").in_flight.remove(&ticket);
            level.lock().expect("level aggregate poisoned").errors += 1;
        }
        _ => {} // Loaded/NotReady/etc: nothing to account
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    /// End-to-end smoke: a tiny run against an in-process server must
    /// produce a parseable BENCH_net.json with one entry per offered
    /// level, full accounting, and integer percentiles.
    #[test]
    fn loadgen_smoke_emits_report() {
        let out = std::env::temp_dir()
            .join(format!("sparsep_bench_net_{}.json", std::process::id()));
        let opts = LoadgenOpts {
            rows: 48,
            deg: 3,
            shards: 1,
            n_dpus: 4,
            conns: 1,
            requests: 12,
            rates: vec![500.0, 1500.0],
            max_queue: 64,
            seed: 0xBEEF,
            addr: None,
            out: out.to_string_lossy().into_owned(),
        };
        run(&opts).expect("loadgen must run clean");
        let text = std::fs::read_to_string(&out).expect("report written");
        let j = Json::parse(&text).expect("report is valid json");
        assert_eq!(j.get("bench").as_str(), Some("net"));
        let levels = j.get("levels").as_arr().expect("levels array");
        assert_eq!(levels.len(), 2, "one report entry per offered level");
        for lv in levels {
            let total = lv.get("completed").as_f64().unwrap()
                + lv.get("shed").as_f64().unwrap()
                + lv.get("errors").as_f64().unwrap()
                + lv.get("lost").as_f64().unwrap();
            assert_eq!(total, lv.get("requests").as_f64().unwrap(), "full accounting");
            assert_eq!(lv.get("lost").as_f64(), Some(0.0), "a healthy local run loses nothing");
            assert!(lv.get("p50_us").as_f64().is_some(), "percentiles present");
            assert!(
                lv.get("p99_us").as_f64().unwrap() >= lv.get("p50_us").as_f64().unwrap(),
                "quantiles are ordered"
            );
        }
        let _ = std::fs::remove_file(&out);
    }
}
