//! Bench E3: data-type sweep (paper Fig. 7): int8..fp64 SpMV throughput
//! on one DPU, with the per-type DPU peak and fraction of peak.

mod common;
use sparsep::bench_harness::figures;

fn main() {
    common::banner("dtype_sweep", "Fig. 7 data types");
    common::timed("e3_dtype_sweep", || {
        figures::e3_dtype_sweep(common::scale());
    });
}
