//! The SparseP host coordinator.
//!
//! This is the library's front door: given a [`KernelSpec`], a sparse
//! matrix and an input vector, the executor plans the data partitioning,
//! models the host->PIM transfers (matrix placement once, input vector
//! every iteration), runs the per-DPU kernels (exactly, with cycle
//! accounting), models the gather of outputs / partial results, merges
//! 2D partials on the host, and returns the exact output vector together
//! with the paper's load/kernel/retrieve/merge breakdown, structural
//! statistics and energy estimate.

pub mod adaptive;
pub mod metrics;
pub mod spec;

pub use metrics::{Breakdown, RunResult, RunStats};
pub use spec::{KernelSpec, Partitioning};

use crate::kernels::{self, DpuKernelOutput};
use crate::matrix::{BcooMatrix, BcsrMatrix, CooMatrix, CsrMatrix, Format, SpElem};
use crate::partition::balance::split_weighted;
use crate::partition::{balance::split_even, TwoDPartitioner};
use crate::pim::{calib, transfer, Energy, PimSystem};
use anyhow::Result;

/// Host-side SpMV executor over a (simulated) PIM system.
#[derive(Clone, Debug)]
pub struct SpmvExecutor {
    pub sys: PimSystem,
}

impl SpmvExecutor {
    pub fn new(sys: PimSystem) -> Self {
        SpmvExecutor { sys }
    }

    /// Execute one SpMV: `y = A * x` under `spec`.
    pub fn run<T: SpElem>(
        &self,
        spec: &KernelSpec,
        m: &CooMatrix<T>,
        x: &[T],
    ) -> Result<RunResult<T>> {
        anyhow::ensure!(x.len() == m.ncols(), "x length {} != ncols {}", x.len(), m.ncols());
        self.sys.cfg.validate()?;
        match spec.partitioning {
            Partitioning::OneD(bal) => self.run_one_d(spec, bal, m, x),
            Partitioning::TwoD(scheme, stripes) => self.run_two_d(spec, scheme, stripes, m, x),
        }
    }

    // ------------------------------------------------------------------
    // 1D: whole rows per DPU + broadcast of the full input vector.
    // ------------------------------------------------------------------
    fn run_one_d<T: SpElem>(
        &self,
        spec: &KernelSpec,
        bal: crate::partition::DpuBalance,
        m: &CooMatrix<T>,
        x: &[T],
    ) -> Result<RunResult<T>> {
        if bal == crate::partition::DpuBalance::NnzElement {
            anyhow::ensure!(
                spec.format == Format::Coo,
                "element-granularity 1D partitioning requires COO (row boundaries are implicit in the other formats)"
            );
            return self.run_one_d_elem(spec, m, x);
        }
        let cfg = &self.sys.cfg;
        let n_dpus = cfg.n_dpus;
        let dt = T::DTYPE;

        // Row ranges per DPU. Blocked formats partition at *block-row*
        // granularity so a block row never spans two DPUs.
        let row_ranges: Vec<std::ops::Range<usize>> = if spec.format.is_blocked() {
            let br = spec.block.0;
            let nbr = crate::util::ceil_div(m.nrows().max(1), br);
            let full = BcsrMatrix::from_coo(m, spec.block.0, spec.block.1);
            let weights: Vec<usize> = match bal {
                crate::partition::DpuBalance::Rows => vec![1; nbr],
                crate::partition::DpuBalance::Blocks => {
                    (0..nbr).map(|i| full.block_row_nblocks(i)).collect()
                }
                crate::partition::DpuBalance::Nnz | crate::partition::DpuBalance::NnzElement => {
                    (0..nbr)
                        .map(|i| full.block_row_nblocks(i) * spec.block.0 * spec.block.1)
                        .collect()
                }
            };
            let chunks = match bal {
                crate::partition::DpuBalance::Rows => split_even(nbr, n_dpus),
                _ => split_weighted(&weights, n_dpus),
            };
            chunks
                .iter()
                .map(|c| (c.start * br).min(m.nrows())..(c.end * br).min(m.nrows()))
                .collect()
        } else {
            let p = crate::partition::OneDPartitioner::plan_coo(m, n_dpus, bal);
            p.row_ranges
        };

        // Build per-DPU slices and run the kernels.
        let mut outputs: Vec<DpuKernelOutput<T>> = Vec::with_capacity(n_dpus);
        let mut slice_bytes = Vec::with_capacity(n_dpus);
        let mut slice_nnz = Vec::with_capacity(n_dpus);
        for range in &row_ranges {
            let slice = m.row_range_slice(range.start, range.end);
            slice_nnz.push(slice.nnz());
            let out = run_format_kernel(cfg, spec, &slice, x, &mut slice_bytes);
            outputs.push(out);
        }

        // --- transfer model ---
        // One-time matrix placement (scatter, padded).
        let mat_load = transfer::scatter(cfg, &slice_bytes);
        // Per-iteration: broadcast x to every DPU.
        let x_bytes = m.ncols() * dt.size_bytes();
        let load = transfer::broadcast(cfg, x_bytes, n_dpus);
        // Retrieve: gather each DPU's y range (ragged when balancing by
        // nnz -> padding rule bites).
        let y_sizes: Vec<usize> =
            row_ranges.iter().map(|r| r.len() * dt.size_bytes()).collect();
        let retrieve = transfer::gather(cfg, &y_sizes);

        // --- assemble output ---
        let mut y = vec![T::zero(); m.nrows()];
        for (range, out) in row_ranges.iter().zip(&outputs) {
            y[range.clone()].copy_from_slice(&out.y);
        }

        Ok(self.finish(spec, m, outputs, slice_nnz, mat_load, load, retrieve, 0, y))
    }

    // ------------------------------------------------------------------
    // 1D at element granularity (`COO.nnz`): equal non-zeros per DPU,
    // rows may span two DPUs; boundary partials merged on the host.
    // ------------------------------------------------------------------
    fn run_one_d_elem<T: SpElem>(
        &self,
        spec: &KernelSpec,
        m: &CooMatrix<T>,
        x: &[T],
    ) -> Result<RunResult<T>> {
        let cfg = &self.sys.cfg;
        let n_dpus = cfg.n_dpus;
        let dt = T::DTYPE;
        let ranges = crate::partition::balance::split_elements(m.nnz(), n_dpus);

        let mut outputs: Vec<DpuKernelOutput<T>> = Vec::with_capacity(n_dpus);
        let mut first_rows = Vec::with_capacity(n_dpus);
        let mut slice_bytes = Vec::with_capacity(n_dpus);
        let mut slice_nnz = Vec::with_capacity(n_dpus);
        let mut y_sizes = Vec::with_capacity(n_dpus);
        for r in &ranges {
            let (slice, first_row) = m.element_range_slice(r.start, r.end);
            slice_nnz.push(slice.nnz());
            slice_bytes.push(slice.size_bytes());
            y_sizes.push(slice.nrows() * dt.size_bytes());
            first_rows.push(first_row);
            let out =
                kernels::coo::run_coo_dpu(cfg, &slice, x, spec.tasklet_balance, spec.sync);
            outputs.push(out);
        }

        let mat_load = transfer::scatter(cfg, &slice_bytes);
        let load = transfer::broadcast(cfg, m.ncols() * dt.size_bytes(), n_dpus);
        let retrieve = transfer::gather(cfg, &y_sizes);

        // Host merge: partials overlap only on the shared boundary rows.
        let mut y = vec![T::zero(); m.nrows()];
        let mut partial_rows = 0usize;
        for (first_row, out) in first_rows.iter().zip(&outputs) {
            partial_rows += out.y.len();
            for (i, v) in out.y.iter().enumerate() {
                let r = first_row + i;
                y[r] = y[r].add(*v);
            }
        }
        // Only the duplicated boundary rows cost merge work.
        let covered_rows: usize = m.row_counts().iter().filter(|&&c| c > 0).count();
        let merged_bytes = partial_rows.saturating_sub(covered_rows) as u64 * dt.size_bytes() as u64;

        Ok(self.finish(spec, m, outputs, slice_nnz, mat_load, load, retrieve, merged_bytes, y))
    }

    // ------------------------------------------------------------------
    // 2D: tiles per DPU, x-slices scattered, partials gathered + merged.
    // ------------------------------------------------------------------
    fn run_two_d<T: SpElem>(
        &self,
        spec: &KernelSpec,
        scheme: crate::partition::TwoDScheme,
        stripes: usize,
        m: &CooMatrix<T>,
        x: &[T],
    ) -> Result<RunResult<T>> {
        let cfg = &self.sys.cfg;
        let n_dpus = cfg.n_dpus;
        let dt = T::DTYPE;
        let plan = TwoDPartitioner::plan(m, n_dpus, stripes, scheme)?;

        let mut outputs: Vec<DpuKernelOutput<T>> = Vec::with_capacity(n_dpus);
        let mut slice_bytes = Vec::with_capacity(n_dpus);
        let mut slice_nnz = Vec::with_capacity(n_dpus);
        let mut x_sizes = Vec::with_capacity(n_dpus);
        let mut y_sizes = Vec::with_capacity(n_dpus);

        // All stripes in one pass over the matrix (§Perf iteration 7).
        let stripe_ranges: Vec<std::ops::Range<usize>> = (0..plan.n_col_stripes)
            .map(|s| plan.tiles[s * plan.n_row_tiles].cols.clone())
            .collect();
        let stripes = m.split_col_stripes(&stripe_ranges);
        for s in 0..plan.n_col_stripes {
            let stripe_tiles =
                &plan.tiles[s * plan.n_row_tiles..(s + 1) * plan.n_row_tiles];
            let cr = stripe_tiles[0].cols.clone();
            let stripe = &stripes[s];
            let x_slice = &x[cr.clone()];
            for tile in stripe_tiles {
                let slice = stripe.row_range_slice(tile.rows.start, tile.rows.end);
                slice_nnz.push(slice.nnz());
                x_sizes.push(cr.len() * dt.size_bytes());
                y_sizes.push(tile.rows.len() * dt.size_bytes());
                let out = run_format_kernel(cfg, spec, &slice, x_slice, &mut slice_bytes);
                outputs.push(out);
            }
        }

        // --- transfer model ---
        let mat_load = transfer::scatter(cfg, &slice_bytes);
        // Per-iteration: scatter x-slices (every DPU of a stripe gets the
        // same slice; the runtime still moves one copy per DPU).
        let load = transfer::scatter(cfg, &x_sizes);
        // Retrieve: gather partial y per tile — ragged sizes + padding.
        let retrieve = transfer::gather(cfg, &y_sizes);

        // --- host merge of partials ---
        let mut y = vec![T::zero(); m.nrows()];
        let mut merged_bytes = 0u64;
        for (tile, out) in plan.tiles.iter().zip(&outputs) {
            for (i, r) in tile.rows.clone().enumerate() {
                y[r] = y[r].add(out.y[i]);
            }
            merged_bytes += (tile.rows.len() * dt.size_bytes()) as u64;
        }

        Ok(self.finish(
            spec,
            m,
            outputs,
            slice_nnz,
            mat_load,
            load,
            retrieve,
            merged_bytes,
            y,
        ))
    }

    #[allow(clippy::too_many_arguments)]
    fn finish<T: SpElem>(
        &self,
        _spec: &KernelSpec,
        m: &CooMatrix<T>,
        outputs: Vec<DpuKernelOutput<T>>,
        slice_nnz: Vec<usize>,
        mat_load: transfer::TransferCost,
        load: transfer::TransferCost,
        retrieve: transfer::TransferCost,
        merged_bytes: u64,
        y: Vec<T>,
    ) -> RunResult<T> {
        let cfg = &self.sys.cfg;
        let kernel_cycles = kernels::slowest_dpu_cycles(
            &outputs.iter().map(|o| o.timing).collect::<Vec<_>>(),
        );
        let kernel_s = kernel_cycles as f64 * cfg.cycle_s();
        let merge_s = merged_bytes as f64 / (calib::HOST_MERGE_GBS * 1e9);

        let breakdown = Breakdown {
            load_s: load.seconds,
            kernel_s,
            retrieve_s: retrieve.seconds,
            merge_s,
        };

        let ideal = m.nnz() as f64 / cfg.n_dpus as f64;
        let dpu_imbalance = if ideal == 0.0 {
            1.0
        } else {
            slice_nnz.iter().copied().max().unwrap_or(0) as f64 / ideal
        };

        let per_dpu_s: Vec<f64> =
            outputs.iter().map(|o| o.timing.cycles as f64 * cfg.cycle_s()).collect();
        let energy = Energy::pim_kernel(cfg.n_dpus, &per_dpu_s)
            .add(Energy::transfer(
                load.moved_bytes + retrieve.moved_bytes,
                load.seconds + retrieve.seconds,
            ))
            .add(Energy::host(merge_s));

        let stats = RunStats {
            dpu_imbalance,
            kernel_cycles,
            bus_bytes_moved: load.moved_bytes + retrieve.moved_bytes,
            bus_bytes_payload: load.payload_bytes + retrieve.payload_bytes,
            matrix_load_s: mat_load.seconds,
            n_dpus: cfg.n_dpus,
            nnz: m.nnz(),
        };

        RunResult { y, breakdown, stats, energy }
    }
}

/// Convert a COO slice into `spec.format` and run the matching DPU
/// kernel; records the slice's storage bytes into `slice_bytes`.
fn run_format_kernel<T: SpElem>(
    cfg: &crate::pim::PimConfig,
    spec: &KernelSpec,
    slice: &CooMatrix<T>,
    x: &[T],
    slice_bytes: &mut Vec<usize>,
) -> DpuKernelOutput<T> {
    match spec.format {
        Format::Csr => {
            let csr = CsrMatrix::from_coo(slice);
            slice_bytes.push(csr.size_bytes());
            kernels::csr::run_csr_dpu(cfg, &csr, x, spec.tasklet_balance, spec.sync)
        }
        Format::Coo => {
            slice_bytes.push(slice.size_bytes());
            kernels::coo::run_coo_dpu(cfg, slice, x, spec.tasklet_balance, spec.sync)
        }
        Format::Bcsr => {
            let b = BcsrMatrix::from_coo(slice, spec.block.0, spec.block.1);
            slice_bytes.push(b.size_bytes());
            kernels::bcsr::run_bcsr_dpu(cfg, &b, x, spec.tasklet_balance, spec.sync)
        }
        Format::Bcoo => {
            let b = BcooMatrix::from_coo(slice, spec.block.0, spec.block.1);
            slice_bytes.push(b.size_bytes());
            kernels::bcoo::run_bcoo_dpu(cfg, &b, x, spec.tasklet_balance, spec.sync)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate;

    fn x_for(n: usize) -> Vec<f64> {
        (0..n).map(|i| ((i % 13) as f64) - 6.0).collect()
    }

    #[test]
    fn all_25_kernels_are_exact() {
        let m = generate::scale_free::<f64>(600, 600, 6, 0.5, 17);
        let x = x_for(600);
        let gold = m.spmv(&x);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        for spec in KernelSpec::all25(4) {
            let r = exec.run(&spec, &m, &x).unwrap();
            assert_eq!(r.y, gold, "kernel {} wrong", spec.name);
        }
    }

    #[test]
    fn one_d_breakdown_has_no_merge() {
        let m = generate::banded::<f64>(1024, 8, 3);
        let x = x_for(1024);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(16));
        let r = exec.run(&KernelSpec::csr_nnz(), &m, &x).unwrap();
        assert_eq!(r.breakdown.merge_s, 0.0);
        assert!(r.breakdown.load_s > 0.0);
        assert!(r.breakdown.kernel_s > 0.0);
        assert!(r.breakdown.retrieve_s > 0.0);
    }

    #[test]
    fn two_d_merges_partials() {
        let m = generate::uniform::<f64>(512, 512, 8, 5);
        let x = x_for(512);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(16));
        let spec = KernelSpec::two_d(Format::Coo, 4);
        let r = exec.run(&spec, &m, &x).unwrap();
        assert_eq!(r.y, m.spmv(&x));
        assert!(r.breakdown.merge_s > 0.0);
    }

    #[test]
    fn two_d_loads_less_than_one_d_on_many_dpus() {
        // The paper's core 1D-vs-2D trade: 2D scatters slices instead of
        // broadcasting the whole vector.
        let m = generate::uniform::<f64>(4096, 4096, 8, 7);
        let x = x_for(4096);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(256));
        let one_d = exec.run(&KernelSpec::coo_nnz_rgrn(), &m, &x).unwrap();
        let two_d = exec.run(&KernelSpec::two_d_equally_wide(Format::Coo, 16), &m, &x).unwrap();
        assert!(
            two_d.breakdown.load_s < one_d.breakdown.load_s,
            "2D load {} !< 1D load {}",
            two_d.breakdown.load_s,
            one_d.breakdown.load_s
        );
        // ...but pays more on retrieve (partials from every stripe).
        assert!(
            two_d.breakdown.retrieve_s > one_d.breakdown.retrieve_s,
            "2D retrieve {} !> 1D retrieve {}",
            two_d.breakdown.retrieve_s,
            one_d.breakdown.retrieve_s
        );
    }

    #[test]
    fn x_length_checked() {
        let m = generate::banded::<f64>(64, 4, 1);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(4));
        assert!(exec.run(&KernelSpec::csr_row(), &m, &vec![0.0; 63]).is_err());
    }

    #[test]
    fn integer_kernels_are_exact() {
        let m = generate::uniform::<f64>(256, 256, 6, 9);
        let mi: CooMatrix<i32> = m.cast();
        let x: Vec<i32> = (0..256).map(|i| (i % 7) as i32 - 3).collect();
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        for spec in [KernelSpec::coo_nnz(), KernelSpec::bcoo_nnz(), KernelSpec::csr_row()] {
            let r = exec.run(&spec, &mi, &x).unwrap();
            assert_eq!(r.y, mi.spmv(&x), "{}", spec.name);
        }
    }

    #[test]
    fn energy_is_positive_and_decomposed() {
        let m = generate::banded::<f64>(512, 8, 2);
        let x = x_for(512);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        let r = exec.run(&KernelSpec::csr_nnz(), &m, &x).unwrap();
        assert!(r.energy.total_j() > 0.0);
        assert!(r.energy.dpu_j > 0.0);
        assert!(r.energy.bus_j > 0.0);
    }
}
