//! COO (coordinate) format: parallel arrays of (row, col, value) triples.
//!
//! COO is the most flexible of the paper's formats: because each non-zero
//! carries its own row index, an nnz-balanced partition can split *inside*
//! a row — which is exactly what the `COO.nnz` kernels exploit, at the
//! price of synchronization on shared rows.

use super::dtype::SpElem;

/// A sparse matrix in coordinate format, sorted by (row, col).
#[derive(Clone, Debug, PartialEq)]
pub struct CooMatrix<T: SpElem> {
    nrows: usize,
    ncols: usize,
    /// Row index of each non-zero (sorted, ties broken by column).
    pub rows: Vec<u32>,
    /// Column index of each non-zero.
    pub cols: Vec<u32>,
    /// Value of each non-zero.
    pub vals: Vec<T>,
}

impl<T: SpElem> CooMatrix<T> {
    /// Build from triples. Duplicate (row, col) entries are summed,
    /// entries are sorted by (row, col), explicit zeros are kept (they
    /// are non-zeros from the storage format's point of view).
    pub fn from_triples(
        nrows: usize,
        ncols: usize,
        mut triples: Vec<(u32, u32, T)>,
    ) -> Self {
        triples.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut rows = Vec::with_capacity(triples.len());
        let mut cols = Vec::with_capacity(triples.len());
        let mut vals: Vec<T> = Vec::with_capacity(triples.len());
        for (r, c, v) in triples {
            assert!((r as usize) < nrows && (c as usize) < ncols, "triple out of bounds");
            if let (Some(&lr), Some(&lc)) = (rows.last(), cols.last()) {
                if lr == r && lc == c {
                    let last = vals.last_mut().unwrap();
                    *last = last.add(v);
                    continue;
                }
            }
            rows.push(r);
            cols.push(c);
            vals.push(v);
        }
        CooMatrix { nrows, ncols, rows, cols, vals }
    }

    /// An empty matrix of the given shape.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        CooMatrix { nrows, ncols, rows: vec![], cols: vec![], vals: vec![] }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }
    pub fn ncols(&self) -> usize {
        self.ncols
    }
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Iterate over the stored triples in (row, col) order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32, T)> + '_ {
        (0..self.nnz()).map(move |i| (self.rows[i], self.cols[i], self.vals[i]))
    }

    /// Reference SpMV: `y = A * x`. Gold standard used by every test.
    pub fn spmv(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![T::zero(); self.nrows];
        for i in 0..self.nnz() {
            let r = self.rows[i] as usize;
            let c = self.cols[i] as usize;
            y[r] = T::mac(y[r], self.vals[i], x[c]);
        }
        y
    }

    /// Number of non-zeros in each row.
    pub fn row_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.nrows];
        for &r in &self.rows {
            counts[r as usize] += 1;
        }
        counts
    }

    /// Extract rows `[r0, r1)` re-indexed to start at 0, keeping the full
    /// column space. O(log nnz + slice) thanks to canonical row ordering —
    /// this is the 1D partitioning hot path.
    pub fn row_range_slice(&self, r0: usize, r1: usize) -> CooMatrix<T> {
        assert!(r0 <= r1 && r1 <= self.nrows);
        let lo = self.rows.partition_point(|&r| (r as usize) < r0);
        let hi = self.rows.partition_point(|&r| (r as usize) < r1);
        CooMatrix {
            nrows: r1 - r0,
            ncols: self.ncols,
            rows: self.rows[lo..hi].iter().map(|&r| r - r0 as u32).collect(),
            cols: self.cols[lo..hi].to_vec(),
            vals: self.vals[lo..hi].to_vec(),
        }
    }

    /// Extract non-zeros `[lo, hi)` *by storage position* (canonical
    /// (row, col) order), re-indexed so the first covered row becomes
    /// row 0. Returns the slice and the original index of that first
    /// row. This is the element-granularity 1D partitioning primitive
    /// (`COO.nnz`): the cut may fall inside a row, in which case the
    /// boundary row's partial sums are produced by two DPUs and merged
    /// on the host.
    pub fn element_range_slice(&self, lo: usize, hi: usize) -> (CooMatrix<T>, usize) {
        assert!(lo <= hi && hi <= self.nnz());
        if lo == hi {
            return (CooMatrix::zeros(0, self.ncols), 0);
        }
        let first_row = self.rows[lo] as usize;
        let last_row = self.rows[hi - 1] as usize;
        (
            CooMatrix {
                nrows: last_row - first_row + 1,
                ncols: self.ncols,
                rows: self.rows[lo..hi].iter().map(|&r| r - first_row as u32).collect(),
                cols: self.cols[lo..hi].to_vec(),
                vals: self.vals[lo..hi].to_vec(),
            },
            first_row,
        )
    }

    /// Split into column stripes in ONE pass: `stripe_ranges` are the
    /// disjoint, ordered `[start, end)` column ranges covering the
    /// matrix; returns one re-indexed sub-matrix per stripe, each in
    /// canonical order. O(nnz log stripes) — the 2D executor's bulk
    /// replacement for calling [`CooMatrix::filter_cols`] per stripe.
    pub fn split_col_stripes(&self, stripe_ranges: &[std::ops::Range<usize>]) -> Vec<CooMatrix<T>> {
        let ends: Vec<usize> = stripe_ranges.iter().map(|r| r.end).collect();
        debug_assert!(ends.windows(2).all(|w| w[0] <= w[1]));
        debug_assert_eq!(*ends.last().unwrap_or(&0), self.ncols);
        let mut parts: Vec<(Vec<u32>, Vec<u32>, Vec<T>)> =
            stripe_ranges.iter().map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
        for i in 0..self.nnz() {
            let c = self.cols[i] as usize;
            let s = ends.partition_point(|&e| e <= c);
            let p = &mut parts[s];
            p.0.push(self.rows[i]);
            p.1.push((c - stripe_ranges[s].start) as u32);
            p.2.push(self.vals[i]);
        }
        // Filtering a canonically-sorted sequence preserves (row, col)
        // order within each stripe, so no re-sort is needed.
        parts
            .into_iter()
            .zip(stripe_ranges)
            .map(|((rows, cols, vals), cr)| CooMatrix {
                nrows: self.nrows,
                ncols: cr.len(),
                rows,
                cols,
                vals,
            })
            .collect()
    }

    /// Keep only columns `[c0, c1)`, re-indexed to start at 0 (row space
    /// kept). O(nnz). The 2D partitioners call this once per stripe.
    pub fn filter_cols(&self, c0: usize, c1: usize) -> CooMatrix<T> {
        assert!(c0 <= c1 && c1 <= self.ncols);
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..self.nnz() {
            let c = self.cols[i] as usize;
            if c >= c0 && c < c1 {
                rows.push(self.rows[i]);
                cols.push((c - c0) as u32);
                vals.push(self.vals[i]);
            }
        }
        CooMatrix { nrows: self.nrows, ncols: c1 - c0, rows, cols, vals }
    }

    /// Extract the sub-matrix of rows `[r0, r1)` and columns `[c0, c1)`,
    /// re-indexed to a (r1-r0) x (c1-c0) matrix. Used by the 2D
    /// partitioners.
    pub fn submatrix(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> CooMatrix<T> {
        assert!(r0 <= r1 && r1 <= self.nrows && c0 <= c1 && c1 <= self.ncols);
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..self.nnz() {
            let (r, c) = (self.rows[i] as usize, self.cols[i] as usize);
            if r >= r0 && r < r1 && c >= c0 && c < c1 {
                rows.push((r - r0) as u32);
                cols.push((c - c0) as u32);
                vals.push(self.vals[i]);
            }
        }
        CooMatrix { nrows: r1 - r0, ncols: c1 - c0, rows, cols, vals }
    }

    /// Convert elements to another supported type (used by the dtype
    /// sweep: the same sparsity pattern evaluated at all six types).
    pub fn cast<U: SpElem>(&self) -> CooMatrix<U> {
        CooMatrix {
            nrows: self.nrows,
            ncols: self.ncols,
            rows: self.rows.clone(),
            cols: self.cols.clone(),
            vals: self.vals.iter().map(|v| U::from_f64(v.to_f64())).collect(),
        }
    }

    /// Total storage footprint of the format in bytes (paper's transfer
    /// cost accounting: 4-byte row + 4-byte col index per element).
    pub fn size_bytes(&self) -> usize {
        self.nnz() * (8 + T::DTYPE.size_bytes())
    }

    /// Order-stable 64-bit fingerprint of the matrix content: shape,
    /// sparsity pattern and native value bits
    /// ([`SpElem::fingerprint_bits`], lossless for every dtype), FNV-1a
    /// over the canonical (row, col) triple order.
    /// [`crate::coordinator::PlanCache`] keys plans on it so equal
    /// matrices share cached plans without the cache holding the
    /// matrices themselves. One O(nnz) pass; not cryptographic —
    /// accidental collisions are astronomically unlikely, adversarial
    /// ones are constructible.
    pub fn fingerprint(&self) -> u64 {
        const PRIME: u64 = 0x100000001b3;
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |v: u64| {
            for byte in v.to_le_bytes() {
                h = (h ^ byte as u64).wrapping_mul(PRIME);
            }
        };
        mix(self.nrows as u64);
        mix(self.ncols as u64);
        mix(self.rows.len() as u64);
        for i in 0..self.rows.len() {
            mix(self.rows[i] as u64);
            mix(self.cols[i] as u64);
            mix(self.vals[i].fingerprint_bits());
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_stable_and_content_sensitive() {
        let a = small();
        assert_eq!(a.fingerprint(), small().fingerprint(), "deterministic");
        assert_eq!(a.fingerprint(), a.clone().fingerprint(), "clone-stable");
        // A changed value, a changed pattern and a changed shape all move
        // the fingerprint.
        let v = CooMatrix::from_triples(
            3,
            3,
            vec![(2, 1, 5.0), (0, 0, 1.0), (2, 0, 3.0), (0, 2, 2.0)],
        );
        assert_ne!(a.fingerprint(), v.fingerprint());
        let p = CooMatrix::from_triples(
            3,
            3,
            vec![(1, 1, 4.0), (0, 0, 1.0), (2, 0, 3.0), (0, 2, 2.0)],
        );
        assert_ne!(a.fingerprint(), p.fingerprint());
        assert_ne!(
            CooMatrix::<f64>::zeros(4, 4).fingerprint(),
            CooMatrix::<f64>::zeros(4, 5).fingerprint()
        );
        // Native value bits: i64 values beyond f64's 53-bit mantissa
        // (indistinguishable after an f64 round-trip) must still
        // separate fingerprints.
        let big = |v: i64| CooMatrix::from_triples(1, 1, vec![(0u32, 0u32, v)]);
        assert_ne!(big(1i64 << 53).fingerprint(), big((1i64 << 53) + 1).fingerprint());
        // ...and negative integers keep distinct patterns.
        assert_ne!(big(-1).fingerprint(), big(1).fingerprint());
    }

    fn small() -> CooMatrix<f64> {
        // [ 1 0 2 ]
        // [ 0 0 0 ]
        // [ 3 4 0 ]
        CooMatrix::from_triples(
            3,
            3,
            vec![(2, 1, 4.0), (0, 0, 1.0), (2, 0, 3.0), (0, 2, 2.0)],
        )
    }

    #[test]
    fn from_triples_sorts() {
        let m = small();
        assert_eq!(m.rows, vec![0, 0, 2, 2]);
        assert_eq!(m.cols, vec![0, 2, 0, 1]);
        assert_eq!(m.nnz(), 4);
    }

    #[test]
    fn duplicates_are_summed() {
        let m = CooMatrix::from_triples(2, 2, vec![(0, 0, 1.0f32), (0, 0, 2.5), (1, 1, 1.0)]);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.vals[0], 3.5);
    }

    #[test]
    fn spmv_matches_dense() {
        let m = small();
        let y = m.spmv(&[1.0, 10.0, 100.0]);
        assert_eq!(y, vec![201.0, 0.0, 43.0]);
    }

    #[test]
    fn row_counts() {
        assert_eq!(small().row_counts(), vec![2, 0, 2]);
    }

    #[test]
    fn submatrix_reindexes() {
        let m = small();
        let s = m.submatrix(1, 3, 0, 2);
        assert_eq!(s.nrows(), 2);
        assert_eq!(s.ncols(), 2);
        assert_eq!(s.nnz(), 2); // (2,0,3.0) and (2,1,4.0) -> rows 1
        assert_eq!(s.rows, vec![1, 1]);
        assert_eq!(s.cols, vec![0, 1]);
    }

    #[test]
    fn cast_preserves_pattern() {
        let m = small();
        let mi: CooMatrix<i32> = m.cast();
        assert_eq!(mi.rows, m.rows);
        assert_eq!(mi.vals, vec![1, 2, 3, 4]);
    }

    #[test]
    fn row_range_slice_matches_submatrix() {
        let m = small();
        assert_eq!(m.row_range_slice(1, 3), m.submatrix(1, 3, 0, 3));
        assert_eq!(m.row_range_slice(0, 0).nnz(), 0);
        assert_eq!(m.row_range_slice(0, 3), m);
    }

    #[test]
    fn element_range_slice_covers_and_reindexes() {
        let m = small(); // 4 nnz in rows 0,0,2,2
        let (s1, f1) = m.element_range_slice(0, 2);
        assert_eq!(f1, 0);
        assert_eq!(s1.nrows(), 1);
        let (s2, f2) = m.element_range_slice(1, 3);
        assert_eq!(f2, 0);
        assert_eq!(s2.nrows(), 3); // spans rows 0..=2
        assert_eq!(s2.nnz(), 2);
        let (s3, f3) = m.element_range_slice(2, 4);
        assert_eq!(f3, 2);
        assert_eq!(s3.nrows(), 1);
        let (s4, _) = m.element_range_slice(1, 1);
        assert_eq!(s4.nnz(), 0);
    }

    #[test]
    fn filter_cols_matches_submatrix() {
        let m = small();
        assert_eq!(m.filter_cols(1, 3), m.submatrix(0, 3, 1, 3));
        assert_eq!(m.filter_cols(0, 3), m);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_triple_panics() {
        CooMatrix::from_triples(2, 2, vec![(2, 0, 1.0f32)]);
    }
}
