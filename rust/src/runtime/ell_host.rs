//! Host-side glue between the sparse-matrix substrate and the AOT ELL
//! artifacts: convert a CSR matrix to the padded layout of a chosen
//! artifact bucket and execute SpMV through PJRT.
//!
//! This is the "accelerator library" path of the CPU/GPU comparison: the
//! same role cuSPARSE plays in the paper's GPU baseline, except our
//! kernel is the AOT-compiled JAX/Pallas module, proving the three-layer
//! stack end to end (L1 Pallas kernel -> L2 jax graph -> HLO text ->
//! Rust PJRT execution).

use super::{ArtifactMeta, ArtifactRunner};
use crate::matrix::dense::EllMatrix;
use crate::matrix::CsrMatrix;
use crate::util::{Context, Result};

/// A CSR matrix staged into one ELL artifact bucket.
pub struct StagedEll {
    pub artifact: String,
    /// Padded values, row-major (rows*k of the artifact bucket).
    pub vals: Vec<f32>,
    /// Padded column indices.
    pub cols: Vec<i32>,
    /// Logical rows (output truncation).
    pub nrows: usize,
    /// Logical columns (x padding).
    pub ncols: usize,
    /// Artifact x length.
    pub n_padded: usize,
    /// Storage blow-up vs nnz (the ELL padding trade-off).
    pub pad_ratio: f64,
}

/// Stage a CSR matrix into the smallest fitting artifact bucket.
pub fn stage(runner: &ArtifactRunner, csr: &CsrMatrix<f32>) -> Result<StagedEll> {
    let k_needed = (0..csr.nrows()).map(|r| csr.row_nnz(r)).max().unwrap_or(1).max(1);
    let meta: &ArtifactMeta = runner
        .pick_ell_bucket("f32", csr.nrows(), k_needed)
        .with_context(|| {
            format!(
                "no ELL artifact bucket fits rows={} k={} (rebuild artifacts with larger buckets)",
                csr.nrows(),
                k_needed
            )
        })?;
    crate::ensure!(
        meta.dims["n"] >= csr.ncols(),
        "artifact x length {} < matrix cols {}",
        meta.dims["n"],
        csr.ncols()
    );
    let (rows_b, k_b) = (meta.dims["rows"], meta.dims["k"]);
    // Reuse the EllMatrix conversion, then pad out to the bucket.
    let ell = EllMatrix::from_csr(csr, k_b, 1);
    let mut vals = vec![0f32; rows_b * k_b];
    let mut cols = vec![0i32; rows_b * k_b];
    for r in 0..csr.nrows() {
        for i in 0..ell.k.min(k_b) {
            vals[r * k_b + i] = ell.vals[r * ell.k + i];
            cols[r * k_b + i] = ell.cols[r * ell.k + i];
        }
    }
    Ok(StagedEll {
        artifact: meta.name.clone(),
        vals,
        cols,
        nrows: csr.nrows(),
        ncols: csr.ncols(),
        n_padded: meta.dims["n"],
        pad_ratio: (rows_b * k_b) as f64 / csr.nnz().max(1) as f64,
    })
}

impl StagedEll {
    /// Execute `y = A @ x` through the artifact; truncates to logical rows.
    pub fn spmv(&self, runner: &ArtifactRunner, x: &[f32]) -> Result<Vec<f32>> {
        crate::ensure!(x.len() == self.ncols, "x length");
        let mut xp = vec![0f32; self.n_padded];
        xp[..x.len()].copy_from_slice(x);
        let mut y = runner.run_ell_f32(&self.artifact, &self.vals, &self.cols, &xp)?;
        y.truncate(self.nrows);
        Ok(y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{generate, CsrMatrix};
    use std::path::Path;

    fn runner() -> Option<ArtifactRunner> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some(ArtifactRunner::load(&dir).unwrap())
    }

    #[test]
    fn staged_spmv_matches_host() {
        let Some(rn) = runner() else { return };
        let m = generate::uniform::<f64>(1000, 1000, 6, 5);
        let mf: crate::matrix::CooMatrix<f32> = m.cast();
        let csr = CsrMatrix::from_coo(&mf);
        let staged = stage(&rn, &csr).unwrap();
        let x: Vec<f32> = (0..1000).map(|i| ((i % 7) as f32) - 3.0).collect();
        let y = staged.spmv(&rn, &x).unwrap();
        let want = csr.spmv(&x);
        assert_eq!(y.len(), 1000);
        for i in 0..1000 {
            assert!((y[i] - want[i]).abs() <= 1e-3 * want[i].abs().max(1.0), "row {i}");
        }
    }

    #[test]
    fn stage_reports_pad_ratio() {
        let Some(rn) = runner() else { return };
        let m = generate::diagonal::<f64>(512, 2);
        let csr = CsrMatrix::from_coo(&m.cast::<f32>());
        let staged = stage(&rn, &csr).unwrap();
        // Diagonal: 1 nnz/row into a k>=8 bucket of >=1024 rows.
        assert!(staged.pad_ratio >= 8.0, "pad ratio {}", staged.pad_ratio);
    }

    #[test]
    fn stage_rejects_oversize() {
        let Some(rn) = runner() else { return };
        let m = generate::banded::<f64>(100_000, 2, 1);
        let csr = CsrMatrix::from_coo(&m.cast::<f32>());
        assert!(stage(&rn, &csr).is_err());
    }
}
