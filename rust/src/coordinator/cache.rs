//! Plan caching: plan-once-serve-many without hand-threading plans.
//!
//! [`super::ExecutionPlan`] already gives iterative apps plan reuse —
//! when they can hold onto the plan. Serving-style callers often cannot:
//! a CLI command, a request handler or a benchmark loop sees (matrix,
//! kernel) pairs arrive repeatedly with no good place to stash the plan
//! between calls. [`PlanCache`] closes that gap: plans are keyed by
//! (matrix fingerprint, kernel spec, system shape) and built on first
//! use, so every later call with an equal matrix and spec gets the
//! cached plan in O(nnz) fingerprint time instead of a full re-plan
//! (partitioning + per-DPU format conversion + transfer pricing).
//!
//! The cache is internally synchronized (`&self` API) and hands out
//! [`Arc`]s, so one cache can serve concurrent request threads.

use super::plan::ExecutionPlan;
use super::spec::KernelSpec;
use super::SpmvExecutor;
use crate::matrix::{CooMatrix, SpElem};
use crate::util::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

/// Default capacity of [`PlanCache::new`], in plans.
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 32;

struct Inner<T: SpElem> {
    map: HashMap<String, Arc<ExecutionPlan<T>>>,
    /// Insertion order for FIFO eviction (keys always present in `map`).
    order: VecDeque<String>,
    hits: u64,
    misses: u64,
}

/// A bounded, thread-safe cache of [`ExecutionPlan`]s keyed by matrix
/// fingerprint + kernel spec + system shape.
///
/// Plans depend only on the (matrix, spec, bus-shape) triple — never on
/// the input vector or the tasklet count — so the key carries exactly
/// the matrix [`CooMatrix::fingerprint`], every [`KernelSpec`] field and
/// the executor's `n_dpus` / `dpus_per_rank` / `bus_scale`. Eviction is
/// FIFO once `capacity` distinct plans are resident.
pub struct PlanCache<T: SpElem> {
    inner: Mutex<Inner<T>>,
    capacity: usize,
}

impl<T: SpElem> PlanCache<T> {
    /// Cache with the default capacity
    /// ([`DEFAULT_PLAN_CACHE_CAPACITY`]).
    pub fn new() -> PlanCache<T> {
        Self::with_capacity(DEFAULT_PLAN_CACHE_CAPACITY)
    }

    /// Cache holding at most `capacity` plans (clamped to >= 1).
    pub fn with_capacity(capacity: usize) -> PlanCache<T> {
        PlanCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: VecDeque::new(),
                hits: 0,
                misses: 0,
            }),
            capacity: capacity.max(1),
        }
    }

    /// The plan for (`spec`, `m`) on `exec`'s system: served from cache
    /// when an equal matrix/spec/system was planned before, built via
    /// [`SpmvExecutor::plan`] (and inserted) otherwise.
    pub fn plan(
        &self,
        exec: &SpmvExecutor,
        spec: &KernelSpec,
        m: &CooMatrix<T>,
    ) -> Result<Arc<ExecutionPlan<T>>> {
        let key = Self::key(exec, spec, m);
        {
            let mut inner = self.lock();
            if let Some(p) = inner.map.get(&key).cloned() {
                inner.hits += 1;
                return Ok(p);
            }
            inner.misses += 1;
        }
        // Plan outside the lock: planning is O(nnz)-heavy and must not
        // serialize concurrent requests for *different* matrices. Two
        // threads racing on the same key both plan; the loser's insert
        // is dropped in favor of the winner's (plans for equal keys are
        // interchangeable).
        let built = Arc::new(exec.plan(spec, m)?);
        let mut inner = self.lock();
        if let Some(p) = inner.map.get(&key) {
            return Ok(Arc::clone(p));
        }
        if inner.map.len() >= self.capacity {
            if let Some(old) = inner.order.pop_front() {
                inner.map.remove(&old);
            }
        }
        inner.map.insert(key.clone(), Arc::clone(&built));
        inner.order.push_back(key);
        Ok(built)
    }

    /// Resident plan count.
    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    /// True when no plans are resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from cache since construction (or [`Self::clear`]).
    pub fn hits(&self) -> u64 {
        self.lock().hits
    }

    /// Lookups that had to build a plan.
    pub fn misses(&self) -> u64 {
        self.lock().misses
    }

    /// Maximum resident plans.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Drop every resident plan and reset the hit/miss counters.
    pub fn clear(&self) {
        let mut inner = self.lock();
        inner.map.clear();
        inner.order.clear();
        inner.hits = 0;
        inner.misses = 0;
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner<T>> {
        self.inner.lock().expect("plan cache poisoned")
    }

    /// Cache key: matrix fingerprint + the full spec + the system-shape
    /// fields an [`ExecutionPlan`] is checked against at execute time.
    /// `Debug` on [`KernelSpec`] covers every spec field; `bus_scale`
    /// keys on its exact bits. Shape and nnz ride along next to the
    /// 64-bit hash so whole classes of fingerprint collisions (any two
    /// matrices differing in dimensions or population) cannot alias.
    fn key(exec: &SpmvExecutor, spec: &KernelSpec, m: &CooMatrix<T>) -> String {
        let cfg = &exec.sys.cfg;
        format!(
            "{:016x}:{}x{}n{}|d{}r{}b{:016x}|{:?}",
            m.fingerprint(),
            m.nrows(),
            m.ncols(),
            m.nnz(),
            cfg.n_dpus,
            cfg.dpus_per_rank,
            cfg.bus_scale.to_bits(),
            spec
        )
    }
}

impl<T: SpElem> Default for PlanCache<T> {
    fn default() -> PlanCache<T> {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate;
    use crate::pim::PimSystem;

    #[test]
    fn cache_hits_on_equal_matrix_and_spec() {
        let m = generate::uniform::<f64>(128, 128, 4, 5);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        let cache = PlanCache::new();
        let p1 = cache.plan(&exec, &KernelSpec::csr_nnz(), &m).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        // An equal (cloned) matrix hits: keys are content-based.
        let p2 = cache.plan(&exec, &KernelSpec::csr_nnz(), &m.clone()).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert!(Arc::ptr_eq(&p1, &p2), "hit must return the resident plan");
        // The cached plan executes like a fresh one.
        let x = vec![1.0; 128];
        let fresh = exec.run(&KernelSpec::csr_nnz(), &m, &x).unwrap();
        let cached = exec.execute(&p2, &x).unwrap();
        assert_eq!(cached.y, fresh.y);
        assert_eq!(cached.breakdown, fresh.breakdown);
    }

    #[test]
    fn cache_misses_on_different_spec_matrix_or_system() {
        let m = generate::uniform::<f64>(96, 96, 4, 5);
        let exec8 = SpmvExecutor::new(PimSystem::with_dpus(8));
        let cache = PlanCache::new();
        cache.plan(&exec8, &KernelSpec::csr_nnz(), &m).unwrap();
        cache.plan(&exec8, &KernelSpec::coo_nnz(), &m).unwrap();
        let m2 = generate::uniform::<f64>(96, 96, 4, 6);
        cache.plan(&exec8, &KernelSpec::csr_nnz(), &m2).unwrap();
        let exec16 = SpmvExecutor::new(PimSystem::with_dpus(16));
        cache.plan(&exec16, &KernelSpec::csr_nnz(), &m).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 4));
        assert_eq!(cache.len(), 4);
    }

    #[test]
    fn capacity_evicts_fifo() {
        let exec = SpmvExecutor::new(PimSystem::with_dpus(4));
        let cache = PlanCache::with_capacity(2);
        let ms: Vec<_> =
            (0..3).map(|s| generate::uniform::<f64>(64, 64, 3, s as u64)).collect();
        for m in &ms {
            cache.plan(&exec, &KernelSpec::coo_row(), m).unwrap();
        }
        assert_eq!(cache.len(), 2);
        // ms[0] was evicted -> miss; ms[2] is resident -> hit.
        cache.plan(&exec, &KernelSpec::coo_row(), &ms[2]).unwrap();
        assert_eq!(cache.hits(), 1);
        cache.plan(&exec, &KernelSpec::coo_row(), &ms[0]).unwrap();
        assert_eq!(cache.misses(), 4);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!((cache.hits(), cache.misses()), (0, 0));
    }
}
