//! Bench E6 + E8 + hardware ablation: end-to-end breakdowns (paper
//! Fig. 10), best-1D vs best-2D (Figs. 14-15), and the what-if hardware
//! experiments behind the paper's suggestions to hardware designers.

mod common;
use sparsep::bench_harness::figures;

fn main() {
    common::banner("breakdown_e2e", "Fig. 10 breakdown + Figs. 14-15 1D-vs-2D + HW ablation");
    let s = common::scale();
    common::timed("e6_breakdown_1d", || {
        figures::e6_breakdown_1d(s);
    });
    common::timed("e8_one_vs_two", || {
        figures::e8_one_vs_two(s);
    });
    common::timed("ablation_hw", || {
        figures::ablation_hw(s);
    });
}
