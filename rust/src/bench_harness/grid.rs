//! 2D-grid sharding benchmark (`sparsep bench-grid`).
//!
//! Quantifies what the grid dimensions buy over plain row sharding on a
//! skewed (scale-free) matrix: the same batched request stream is
//! served by
//!
//! 1. an **unsharded baseline** (a 1×1 grid — one backend);
//! 2. the **row-only heuristic** (an S×1 grid, exactly what
//!    `--shards S` built before grids existed);
//! 3. a **tuned grid**: a mini-sweep over R×C shapes with the same
//!    total backend count S, row-only included as candidate zero —
//!    so `tuned_over_row ≥ 1.0` holds *by construction* (the winner is
//!    the minimum over a set containing the row-only shape), mirroring
//!    the heuristic-as-candidate-zero contract of `sparsep tune`;
//! 4. the tuned shape **replicated ×2**, serving the identical
//!    read-only stream through least-outstanding replica dispatch.
//!
//! Every configuration runs on both the serial and threaded engines.
//! Gathered outputs are verified against the host oracle once; grid
//! shape and replication never change answers (locked by
//! `tests/grid_equivalence.rs`), only wall clock. The JSON summary
//! lands in `BENCH_grid.json` next to the other `BENCH_*.json` files.

use crate::coordinator::{Engine, KernelSpec, Request, ShardedService, ShardedServiceBuilder};
use crate::matrix::generate;
use crate::pim::{PimConfig, PimSystem};
use crate::util::json::{arr, num, obj, s};
use crate::util::{Context, Result};
use std::time::Instant;

/// Knobs for [`run`] (CLI flags of `sparsep bench-grid`).
#[derive(Clone, Debug)]
pub struct GridBenchOpts {
    /// Matrix dimension (square, scale-free class — the skewed shape
    /// 2D grids exist for).
    pub rows: usize,
    /// Average degree (non-zeros per row).
    pub deg: usize,
    /// Total backends per gridded configuration (the sweep holds
    /// R×C = shards fixed and varies the shape).
    pub shards: usize,
    /// Batched requests per measurement.
    pub requests: usize,
    /// Right-hand-side vectors per request.
    pub batch: usize,
    /// Simulated DPUs per backend tile.
    pub dpus_per_shard: usize,
    /// Threaded-engine worker count (0 = all cores).
    pub threads: usize,
    /// Kernel name (see `sparsep kernels`).
    pub kernel: String,
    /// Timed samples per configuration (min is reported).
    pub samples: usize,
    /// Output JSON path.
    pub out: String,
}

impl Default for GridBenchOpts {
    fn default() -> GridBenchOpts {
        GridBenchOpts {
            rows: 50_000,
            deg: 8,
            shards: 4,
            requests: 8,
            batch: 8,
            dpus_per_shard: 64,
            threads: 0,
            kernel: "CSR.nnz".to_string(),
            samples: 2,
            out: "BENCH_grid.json".to_string(),
        }
    }
}

/// The swept R×C shapes for a total backend budget of `shards`:
/// row-only first (candidate zero), then progressively column-heavier
/// shapes at the same R×C product, deduplicated in order.
fn shapes_for(shards: usize) -> Vec<(usize, usize)> {
    let s = shards.max(1);
    let mut shapes = vec![(s, 1)];
    for cand in [(s.div_euclid(2).max(1), 2), (2, s.div_euclid(2).max(1)), (1, s)] {
        if cand.0 * cand.1 == s && !shapes.contains(&cand) {
            shapes.push(cand);
        }
    }
    shapes
}

/// Run the benchmark and write the JSON summary to `opts.out`.
pub fn run(opts: &GridBenchOpts) -> Result<()> {
    crate::ensure!(opts.shards >= 1, "bench-grid needs --shards >= 1");
    crate::ensure!(opts.requests >= 1, "bench-grid needs --requests >= 1");
    crate::ensure!(opts.batch >= 1, "bench-grid needs --batch >= 1");
    crate::ensure!(opts.samples >= 1, "bench-grid needs --samples >= 1");
    let spec = KernelSpec::by_name(&opts.kernel, 8)
        .with_context(|| format!("unknown kernel {} (see `sparsep kernels`)", opts.kernel))?;
    let m = generate::scale_free::<f64>(opts.rows, opts.rows, opts.deg, 0.6, 7);
    let payloads: Vec<Vec<Vec<f64>>> = (0..opts.requests)
        .map(|r| {
            (0..opts.batch)
                .map(|b| {
                    (0..m.ncols()).map(|i| ((i + 3 * b + 7 * r) % 9) as f64 - 4.0).collect()
                })
                .collect()
        })
        .collect();
    let sys = PimSystem::new(PimConfig { n_dpus: opts.dpus_per_shard, ..Default::default() })?;
    let shapes = shapes_for(opts.shards);
    println!(
        "bench-grid: {} x{} requests x{} vectors on {}x{} ({} nnz), {} DPUs/tile, shapes {:?}",
        spec.name,
        opts.requests,
        opts.batch,
        m.nrows(),
        m.ncols(),
        m.nnz(),
        opts.dpus_per_shard,
        shapes
    );

    let one = |engine: Engine, grid: (usize, usize), replicas: usize, verify: bool| -> Result<f64> {
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .grid(grid.0, grid.1)
            .replicas(replicas)
            .engine(engine)
            .build(sys.clone())?;
        let handle = svc.load(&m, &spec)?; // tile planning + plans, out of timing
        if verify {
            let b = svc.spmv_batch(&handle, &payloads[0])?;
            for (x, run) in payloads[0].iter().zip(&b.runs) {
                crate::ensure!(run.y == m.spmv(x), "gridded output diverged from host oracle");
            }
        }
        let mut best = f64::INFINITY;
        for _ in 0..opts.samples {
            // Payload Arcs built outside the clock; the facade's scatter
            // shares them across tiles instead of copying per tile.
            let owned: Vec<Vec<crate::util::sync::Arc<[f64]>>> = payloads
                .iter()
                .map(|xs| xs.iter().map(|v| crate::util::sync::Arc::from(&v[..])).collect())
                .collect();
            let t0 = Instant::now();
            let tickets: Vec<_> = owned
                .into_iter()
                .map(|xs| svc.submit(handle, Request::Batch { xs }))
                .collect::<Result<_>>()?;
            for t in tickets {
                let resp = svc.wait(t)?.into_batch()?;
                std::hint::black_box(&resp.runs.last().unwrap().y);
            }
            best = best.min(t0.elapsed().as_secs_f64());
        }
        Ok(best)
    };

    let base_serial = one(Engine::Serial, (1, 1), 1, true)?;
    let base_threaded = one(Engine::threaded(opts.threads), (1, 1), 1, false)?;
    println!("  1x1 baseline: serial {base_serial:>8.3}s | threaded {base_threaded:>8.3}s");

    let mut serial_walls = Vec::with_capacity(shapes.len());
    let mut threaded_walls = Vec::with_capacity(shapes.len());
    for &(r, c) in &shapes {
        let serial = one(Engine::Serial, (r, c), 1, false)?;
        let threaded = one(Engine::threaded(opts.threads), (r, c), 1, false)?;
        println!("  {r}x{c}: serial {serial:>8.3}s | threaded {threaded:>8.3}s");
        serial_walls.push(serial);
        threaded_walls.push(threaded);
    }

    // The tuned shape is the serial-wall argmin over the sweep; shapes[0]
    // is row-only, so tuned_over_row is >= 1.0 by construction.
    let tuned_idx = serial_walls
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    let tuned = shapes[tuned_idx];
    let tuned_over_row_serial = serial_walls[0] / serial_walls[tuned_idx].max(1e-12);
    let tuned_over_row_threaded = threaded_walls[0] / threaded_walls[tuned_idx].max(1e-12);

    let rep_serial = one(Engine::Serial, tuned, 2, false)?;
    let rep_threaded = one(Engine::threaded(opts.threads), tuned, 2, false)?;
    println!(
        "  tuned {}x{} (x1.0 row-only floor: serial {:.2}x) | replicated x2: serial {rep_serial:>8.3}s | threaded {rep_threaded:>8.3}s",
        tuned.0, tuned.1, tuned_over_row_serial
    );

    let j = obj(vec![
        ("bench", s("grid_sharding")),
        ("kernel", s(&spec.name)),
        ("rows", num(m.nrows() as f64)),
        ("nnz", num(m.nnz() as f64)),
        ("requests", num(opts.requests as f64)),
        ("batch", num(opts.batch as f64)),
        ("dpus_per_shard", num(opts.dpus_per_shard as f64)),
        ("host_threads", num(opts.threads as f64)),
        ("samples", num(opts.samples as f64)),
        ("shards", num(opts.shards as f64)),
        ("shapes", arr(shapes.iter().map(|&(r, c)| s(&format!("{r}x{c}"))).collect())),
        ("serial_wall_s", arr(serial_walls.iter().map(|&w| num(w)).collect())),
        ("threaded_wall_s", arr(threaded_walls.iter().map(|&w| num(w)).collect())),
        ("baseline_serial_wall_s", num(base_serial)),
        ("baseline_threaded_wall_s", num(base_threaded)),
        ("tuned_shape", s(&format!("{}x{}", tuned.0, tuned.1))),
        ("tuned_serial_wall_s", num(serial_walls[tuned_idx])),
        ("tuned_threaded_wall_s", num(threaded_walls[tuned_idx])),
        ("tuned_over_row_serial", num(tuned_over_row_serial)),
        ("tuned_over_row_threaded", num(tuned_over_row_threaded)),
        ("replicated_serial_wall_s", num(rep_serial)),
        ("replicated_threaded_wall_s", num(rep_threaded)),
    ]);
    std::fs::write(&opts.out, j.to_string() + "\n")
        .with_context(|| format!("write {}", opts.out))?;
    println!("wrote {}", opts.out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_keep_the_backend_budget_and_lead_with_row_only() {
        assert_eq!(shapes_for(4), vec![(4, 1), (2, 2), (1, 4)]);
        assert_eq!(shapes_for(1), vec![(1, 1)]);
        assert_eq!(shapes_for(2), vec![(2, 1), (1, 2)]);
        for s in 1..=8usize {
            let shapes = shapes_for(s);
            assert_eq!(shapes[0], (s, 1), "row-only must be candidate zero");
            for (r, c) in shapes {
                assert_eq!(r * c, s, "every shape spends the same backend budget");
            }
        }
    }

    #[test]
    fn bench_grid_smoke_writes_json_with_row_floor() {
        let dir = std::env::temp_dir().join("sparsep_bench_grid_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_grid_test.json");
        let opts = GridBenchOpts {
            rows: 240,
            deg: 4,
            shards: 2,
            requests: 2,
            batch: 2,
            dpus_per_shard: 4,
            threads: 2,
            samples: 1,
            out: out.to_str().unwrap().to_string(),
            ..Default::default()
        };
        run(&opts).unwrap();
        let txt = std::fs::read_to_string(&out).unwrap();
        let j = crate::util::json::Json::parse(&txt).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("grid_sharding"));
        assert_eq!(j.get("shapes").as_arr().unwrap().len(), 2);
        assert_eq!(j.get("serial_wall_s").as_arr().unwrap().len(), 2);
        assert!(j.get("baseline_serial_wall_s").as_f64().unwrap() > 0.0);
        assert!(j.get("replicated_threaded_wall_s").as_f64().unwrap() > 0.0);
        // The row-only floor: the tuned winner ranges over a set that
        // includes row-only, so the ratio cannot dip below 1.
        assert!(j.get("tuned_over_row_serial").as_f64().unwrap() >= 1.0);
        let shape = j.get("tuned_shape").as_str().unwrap();
        assert!(shape == "2x1" || shape == "1x2", "tuned shape {shape} not in the sweep");
        std::fs::remove_file(&out).ok();
    }
}
