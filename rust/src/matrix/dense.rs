//! Dense helpers and the padded ELL / block-ELL layouts used by the
//! XLA/PJRT accelerator path.
//!
//! The AOT-compiled JAX/Pallas kernels operate on *static* shapes, so the
//! host converts a sparse matrix into a padded ELL (values + column
//! indices, `rows x K` where `K = max nnz/row` rounded up to a bucket) or
//! block-ELL layout before execution. Padding columns point at column 0
//! with value 0, which leaves the product unchanged — the classic
//! GPU-SpMV trick, and the TPU re-think of the paper's 2D padding
//! trade-off (see DESIGN.md §Hardware-Adaptation).

use super::csr::CsrMatrix;
use super::dtype::SpElem;

/// A dense row-major matrix (used in tests and as the XLA input layout).
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix<T: SpElem> {
    pub nrows: usize,
    pub ncols: usize,
    pub data: Vec<T>,
}

impl<T: SpElem> DenseMatrix<T> {
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        DenseMatrix { nrows, ncols, data: vec![T::zero(); nrows * ncols] }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> T {
        self.data[r * self.ncols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: T) {
        self.data[r * self.ncols + c] = v;
    }

    /// Dense mat-vec (oracle for tiny tests).
    pub fn matvec(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.ncols);
        (0..self.nrows)
            .map(|r| {
                let mut acc = T::zero();
                for c in 0..self.ncols {
                    acc = T::mac(acc, self.get(r, c), x[c]);
                }
                acc
            })
            .collect()
    }
}

/// Padded ELL layout: `vals[r*k..(r+1)*k]` and `cols[..]` with zero-fill.
///
/// This is the layout `python/compile/kernels/ell_spmv.py` consumes, and
/// what [`crate::runtime::ArtifactRunner`] feeds to the compiled HLO.
#[derive(Clone, Debug)]
pub struct EllMatrix<T: SpElem> {
    /// Padded row count (rounded up to the artifact's row bucket).
    pub nrows: usize,
    /// Logical (unpadded) row count.
    pub nrows_orig: usize,
    pub ncols: usize,
    /// Entries per row after padding.
    pub k: usize,
    /// `nrows * k` values, zero-padded.
    pub vals: Vec<T>,
    /// `nrows * k` column indices (padding points at column 0).
    pub cols: Vec<i32>,
}

impl<T: SpElem> EllMatrix<T> {
    /// Convert CSR -> ELL, padding rows to `k_min.max(max nnz/row)` and
    /// the row count up to a multiple of `row_multiple` (grid tiling).
    pub fn from_csr(csr: &CsrMatrix<T>, k_min: usize, row_multiple: usize) -> Self {
        let k_data = (0..csr.nrows()).map(|r| csr.row_nnz(r)).max().unwrap_or(0);
        let k = k_data.max(k_min).max(1);
        let nrows = crate::util::round_up(csr.nrows().max(1), row_multiple.max(1));
        let mut vals = vec![T::zero(); nrows * k];
        let mut cols = vec![0i32; nrows * k];
        for r in 0..csr.nrows() {
            let (rc, rv) = csr.row(r);
            for (i, (&c, &v)) in rc.iter().zip(rv).enumerate() {
                vals[r * k + i] = v;
                cols[r * k + i] = c as i32;
            }
        }
        EllMatrix { nrows, nrows_orig: csr.nrows(), ncols: csr.ncols(), k, vals, cols }
    }

    /// Reference SpMV over the padded layout (truncated to logical rows).
    pub fn spmv(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.ncols);
        (0..self.nrows_orig)
            .map(|r| {
                let mut acc = T::zero();
                for i in 0..self.k {
                    acc = T::mac(acc, self.vals[r * self.k + i], x[self.cols[r * self.k + i] as usize]);
                }
                acc
            })
            .collect()
    }

    /// Padding overhead: stored entries / real nnz.
    pub fn pad_ratio(&self, nnz: usize) -> f64 {
        if nnz == 0 {
            1.0
        } else {
            (self.nrows * self.k) as f64 / nnz as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::coo::CooMatrix;

    #[test]
    fn dense_matvec() {
        let mut d = DenseMatrix::zeros(2, 3);
        d.set(0, 0, 1.0f32);
        d.set(1, 2, 2.0);
        assert_eq!(d.matvec(&[1.0, 1.0, 10.0]), vec![1.0, 20.0]);
    }

    #[test]
    fn ell_roundtrip_spmv() {
        let coo = CooMatrix::from_triples(
            3,
            4,
            vec![(0, 0, 1.0f64), (0, 3, 2.0), (1, 1, 3.0), (2, 0, 4.0), (2, 1, 5.0), (2, 2, 6.0)],
        );
        let csr = CsrMatrix::from_coo(&coo);
        let ell = EllMatrix::from_csr(&csr, 1, 8);
        assert_eq!(ell.k, 3); // max row nnz
        assert_eq!(ell.nrows, 8); // padded to multiple of 8
        let x = [1.0, 10.0, 100.0, 1000.0];
        assert_eq!(ell.spmv(&x), csr.spmv(&x));
    }

    #[test]
    fn ell_k_min_respected() {
        let coo = CooMatrix::from_triples(2, 2, vec![(0, 0, 1.0f32)]);
        let ell = EllMatrix::from_csr(&CsrMatrix::from_coo(&coo), 16, 1);
        assert_eq!(ell.k, 16);
        assert!(ell.pad_ratio(1) >= 16.0);
    }

    #[test]
    fn ell_padding_is_neutral() {
        // Padding points at column 0 with value 0 -> contributes nothing
        // even when x[0] != 0.
        let coo = CooMatrix::from_triples(2, 2, vec![(0, 1, 5.0f64), (1, 0, 7.0)]);
        let ell = EllMatrix::from_csr(&CsrMatrix::from_coo(&coo), 4, 1);
        assert_eq!(ell.spmv(&[100.0, 1.0]), vec![5.0, 700.0]);
    }
}
