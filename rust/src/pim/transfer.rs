//! Host <-> PIM transfer model.
//!
//! All data reaches PIM-enabled memory over the regular DDR4 bus, driven
//! by the host CPU — the central structural constraint of real near-bank
//! PIM systems and the source of the paper's two collective-operation
//! findings:
//!
//! * **Broadcast** (1D kernels copy the *whole* input vector to every
//!   DPU): total moved bytes scale with `n_dpus * |x|`, so 1D SpMV stops
//!   scaling once the broadcast dominates (hardware suggestion #2).
//! * **Gather with padding** (2D kernels retrieve partial outputs): the
//!   UPMEM runtime requires *the same transfer size for every DPU* in a
//!   parallel transfer, so ragged partial results are padded to the
//!   maximum — wasted bus bytes the paper calls out (hardware
//!   suggestion #3).

use super::arch::PimConfig;
use super::calib;

/// Direction of a host<->PIM transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// Host -> PIM (scatter / broadcast).
    ToPim,
    /// PIM -> host (gather).
    FromPim,
}

/// Cost of one collective transfer.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TransferCost {
    /// Wall-clock seconds on the bus.
    pub seconds: f64,
    /// Payload bytes the caller asked to move.
    pub payload_bytes: u64,
    /// Bytes actually moved including same-size padding.
    pub moved_bytes: u64,
}

impl TransferCost {
    pub fn padding_overhead(&self) -> f64 {
        if self.payload_bytes == 0 {
            0.0
        } else {
            self.moved_bytes as f64 / self.payload_bytes as f64
        }
    }

    /// Combine sequential transfers.
    pub fn then(self, other: TransferCost) -> TransferCost {
        TransferCost {
            seconds: self.seconds + other.seconds,
            payload_bytes: self.payload_bytes + other.payload_bytes,
            moved_bytes: self.moved_bytes + other.moved_bytes,
        }
    }
}

fn aggregate_bw(cfg: &PimConfig, per_rank: f64, peak: f64) -> f64 {
    let ranks = cfg.n_ranks() as f64;
    (per_rank * ranks).min(peak) * cfg.bus_scale
}

/// A *parallel* transfer: possibly different sizes per DPU, same
/// direction. The UPMEM runtime issues one bus transaction shape for all
/// DPUs of a rank batch, so every DPU's slot is padded to the maximum
/// size across the batch (paper's hardware suggestion #3).
pub fn parallel(cfg: &PimConfig, dir: Dir, sizes_per_dpu: &[usize]) -> TransferCost {
    assert!(sizes_per_dpu.len() <= cfg.n_dpus, "more slots than DPUs");
    if sizes_per_dpu.is_empty() {
        return TransferCost::default();
    }
    let payload: u64 = sizes_per_dpu.iter().map(|&s| s as u64).sum();
    let max = *sizes_per_dpu.iter().max().unwrap();
    let max = crate::util::round_up(max, 8);
    let moved = (max * sizes_per_dpu.len()) as u64;
    let (per_rank, peak) = match dir {
        Dir::ToPim => (calib::CPU_TO_DPU_RANK_GBS, calib::CPU_TO_DPU_PEAK_GBS),
        Dir::FromPim => (calib::DPU_TO_CPU_RANK_GBS, calib::DPU_TO_CPU_PEAK_GBS),
    };
    let bw = aggregate_bw(cfg, per_rank, peak) * 1e9;
    TransferCost {
        seconds: calib::TRANSFER_LATENCY_S + moved as f64 / bw,
        payload_bytes: payload,
        moved_bytes: moved,
    }
}

/// Broadcast the same `bytes`-sized buffer to `n_dpus` DPUs.
///
/// The source stays hot in host caches so the sustained aggregate rate is
/// higher than a parallel scatter, but the moved bytes still multiply by
/// the DPU count — the 1D scaling wall.
pub fn broadcast(cfg: &PimConfig, bytes: usize, n_dpus: usize) -> TransferCost {
    if bytes == 0 || n_dpus == 0 {
        return TransferCost::default();
    }
    let bytes = crate::util::round_up(bytes, 8);
    let moved = (bytes * n_dpus) as u64;
    let bw = aggregate_bw(cfg, calib::BROADCAST_RANK_GBS, calib::BROADCAST_PEAK_GBS) * 1e9;
    TransferCost {
        seconds: calib::TRANSFER_LATENCY_S + moved as f64 / bw,
        payload_bytes: moved,
        moved_bytes: moved,
    }
}

/// Gather results from DPUs (`sizes_per_dpu[i]` bytes from DPU i) — a
/// parallel transfer in the FromPim direction, padding rule included.
pub fn gather(cfg: &PimConfig, sizes_per_dpu: &[usize]) -> TransferCost {
    parallel(cfg, Dir::FromPim, sizes_per_dpu)
}

/// Scatter distinct buffers to DPUs.
pub fn scatter(cfg: &PimConfig, sizes_per_dpu: &[usize]) -> TransferCost {
    parallel(cfg, Dir::ToPim, sizes_per_dpu)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n_dpus: usize) -> PimConfig {
        PimConfig { n_dpus, ..Default::default() }
    }

    #[test]
    fn empty_transfer_is_free() {
        assert_eq!(parallel(&cfg(4), Dir::ToPim, &[]).seconds, 0.0);
        assert_eq!(broadcast(&cfg(4), 0, 4).seconds, 0.0);
    }

    #[test]
    fn padding_rule_inflates_ragged_transfers() {
        let c = cfg(4);
        let even = gather(&c, &[1024, 1024, 1024, 1024]);
        let ragged = gather(&c, &[1024, 8, 8, 8]);
        assert_eq!(even.moved_bytes, 4096);
        assert_eq!(ragged.moved_bytes, 4096, "padded to max size");
        assert!(ragged.padding_overhead() > 3.0);
        assert!((even.padding_overhead() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn broadcast_bytes_scale_with_dpus() {
        let c2560 = cfg(2560);
        let c64 = cfg(64);
        let b2560 = broadcast(&c2560, 1 << 20, 2560);
        let b64 = broadcast(&c64, 1 << 20, 64);
        assert_eq!(b2560.moved_bytes, 40 * b64.moved_bytes);
        // Below the bus cap, bytes and bandwidth both scale with ranks
        // and broadcast time stays flat; past the cap (16 ranks at 1.05
        // GB/s/rank) the bytes keep growing while bandwidth doesn't —
        // the 1D scaling wall.
        assert!(b2560.seconds > 2.0 * b64.seconds);
    }

    #[test]
    fn bandwidth_caps_at_peak() {
        // 40 ranks would give 40 * 0.42 = 16.8 GB/s uncapped; cap is 6.68.
        let c = cfg(2560);
        let bytes = 1usize << 26;
        let t = scatter(&c, &vec![bytes / 2560; 2560]);
        let implied_bw = t.moved_bytes as f64 / (t.seconds - calib::TRANSFER_LATENCY_S) / 1e9;
        assert!(implied_bw <= calib::CPU_TO_DPU_PEAK_GBS * 1.01, "bw {implied_bw}");
    }

    #[test]
    fn gather_slower_than_scatter() {
        let c = cfg(64);
        let sizes = vec![1 << 16; 64];
        assert!(gather(&c, &sizes).seconds > scatter(&c, &sizes).seconds);
    }

    #[test]
    fn bus_scale_ablation_speeds_up() {
        let base = cfg(64);
        let fast = PimConfig { bus_scale: 4.0, ..cfg(64) };
        let sizes = vec![1 << 16; 64];
        assert!(scatter(&fast, &sizes).seconds < scatter(&base, &sizes).seconds);
    }

    #[test]
    fn then_accumulates() {
        let c = cfg(4);
        let a = gather(&c, &[8, 8, 8, 8]);
        let b = gather(&c, &[16, 16, 16, 16]);
        let t = a.then(b);
        assert!((t.seconds - (a.seconds + b.seconds)).abs() < 1e-12);
        assert_eq!(t.moved_bytes, a.moved_bytes + b.moved_bytes);
    }
}
