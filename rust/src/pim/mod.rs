//! UPMEM-class PIM system simulator.
//!
//! The paper's testbed — the UPMEM PIM system, the first commercially
//! available real-world near-bank PIM architecture — is not available in
//! this environment, so this module *is* that substrate (see DESIGN.md §4
//! substitutions): a functional simulator with an analytic timing model
//! calibrated against the published PrIM microbenchmark numbers.
//!
//! Submodules:
//! * [`calib`] — every calibration constant, with sources.
//! * [`arch`] — topology and configuration ([`PimSystem`], [`PimConfig`]).
//! * [`dpu`] — per-DPU timing: pipeline / DMA / critical-section laws.
//! * [`transfer`] — host<->PIM collectives (broadcast/scatter/gather with
//!   the same-size padding rule).
//! * [`energy`] — component-level energy accounting.

pub mod arch;
pub mod calib;
pub mod dpu;
pub mod energy;
pub mod transfer;

pub use arch::{PimConfig, PimSystem};
pub use dpu::{dpu_time, DpuTiming, TaskletCounters};
pub use energy::Energy;
pub use transfer::{broadcast, gather, scatter, Dir, TransferCost};
