//! Per-figure experiment drivers — one per paper artifact (DESIGN.md §3).
//!
//! Every driver prints the same rows/series the paper's figure or table
//! reports (with our simulated-UPMEM absolute numbers) and emits JSON
//! lines under `target/bench_results/` for machine consumption. The
//! benches in `rust/benches/` are thin wrappers over these functions, so
//! `cargo bench` regenerates the full evaluation.

use super::{emit_jsonl, Table};
use crate::baselines::{cpu, roofline};
use crate::coordinator::{KernelSpec, RunResult, SpmvExecutor};
use crate::kernels::SyncScheme;
use crate::matrix::{generate, CooMatrix, CsrMatrix, DType, Format, MatrixStats, SpElem};
use crate::pim::{calib, PimConfig, PimSystem};
use crate::util::json::{arr, num, obj, s, Json};

/// Scale knob: 1.0 = the default evaluation size (minutes for the full
/// set); benches use smaller scales for quick runs.
#[derive(Clone, Copy, Debug)]
pub struct Scale(pub f64);

impl Scale {
    fn rows(&self, base: usize) -> usize {
        ((base as f64 * self.0) as usize).max(64)
    }
}

/// Executor for the drivers: engine from `SPARSEP_ENGINE` /
/// `SPARSEP_THREADS` (the CLI's `--engine` / `--threads` flags export
/// them), so every figure driver can run its per-DPU kernel simulations
/// on host threads; modeled results are engine-independent.
fn exec(n_dpus: usize, tasklets: usize) -> SpmvExecutor {
    SpmvExecutor::with_engine(
        PimSystem { cfg: PimConfig { n_dpus, tasklets, ..Default::default() } },
        crate::coordinator::Engine::from_env(),
    )
}

/// One-shot plan + execute on `ex` — the synchronous
/// [`crate::coordinator::ExecutionPlan`] path. Figure drivers sweep far
/// too many distinct (matrix, spec, system) points to keep a resident
/// service per point; serving-shaped callers use
/// [`crate::coordinator::SpmvService`] instead.
fn run_once<T: SpElem>(
    ex: &SpmvExecutor,
    spec: &KernelSpec,
    m: &CooMatrix<T>,
    x: &[T],
) -> RunResult<T> {
    ex.plan(spec, m).unwrap().execute(ex, x).unwrap()
}

// ---------------------------------------------------------------------
// E1 — Fig. 5: single-DPU tasklet scaling, by kernel and balancing.
// ---------------------------------------------------------------------

/// Returns (kernel, tasklets, cycles) tuples for the assertion in tests.
pub fn e1_tasklet_scaling(scale: Scale) -> Vec<(String, usize, u64)> {
    println!("\n=== E1 (Fig. 5): single-DPU scaling with tasklets ===");
    let n = scale.rows(4096);
    let matrices: Vec<(&str, CooMatrix<f64>)> = vec![
        ("regular", generate::banded::<f64>(n, 16, 11)),
        ("scale-free", generate::scale_free::<f64>(n, n, 12, 0.7, 11)),
    ];
    let kernels = [
        KernelSpec::csr_row(),
        KernelSpec::csr_nnz(),
        KernelSpec::coo_row(),
        KernelSpec::coo_nnz_rgrn(),
        KernelSpec::coo_nnz(),
    ];
    let tasklet_counts = [1usize, 2, 4, 8, 11, 16, 20, 24];
    let mut out = Vec::new();
    for (mname, m) in &matrices {
        let x = vec![1.0f64; m.ncols()];
        let mut table = Table::new(
            &["kernel", "t=1", "t=2", "t=4", "t=8", "t=11", "t=16", "t=20", "t=24"],
        );
        for spec in &kernels {
            let mut cells = vec![spec.name.clone()];
            // The plan depends on the DPU count (1 here) and the spec,
            // not on the tasklet count: plan once, execute per point.
            let plan = exec(1, 16).plan(spec, m).unwrap();
            for &t in &tasklet_counts {
                let ex = exec(1, t);
                let r = plan.execute(&ex, &x).unwrap();
                cells.push(format!("{:.2}ms", r.breakdown.kernel_s * 1e3));
                out.push((format!("{}/{}", mname, spec.name), t, r.stats.kernel_cycles));
                emit_jsonl(
                    "e1_tasklet_scaling",
                    &obj(vec![
                        ("matrix", s(mname)),
                        ("kernel", s(&spec.name)),
                        ("tasklets", num(t as f64)),
                        ("cycles", num(r.stats.kernel_cycles as f64)),
                    ]),
                );
            }
            table.row(&cells);
        }
        println!("-- {mname} matrix ({} rows, {} nnz), kernel time on 1 DPU:", m.nrows(), m.nnz());
        table.print();
    }
    println!("(paper shape: saturation at >=11 tasklets; nnz-balancing wins on scale-free)");
    out
}

// ---------------------------------------------------------------------
// E2 — Fig. 6: synchronization schemes.
// ---------------------------------------------------------------------

pub fn e2_sync_schemes(scale: Scale) -> Vec<(String, u64)> {
    println!("\n=== E2 (Fig. 6): synchronization approaches (1 DPU, 16 tasklets) ===");
    let n = scale.rows(2048);
    // Matrices that force shared rows under element-granularity splits.
    let wide = {
        let mut t: Vec<(u32, u32, f64)> = Vec::new();
        for r in 0..(n / 64) as u32 {
            for c in 0..256u32 {
                t.push((r, (c * 7) % n as u32, 1.0));
            }
        }
        CooMatrix::from_triples(n / 64, n, t)
    };
    let sf = generate::scale_free::<f64>(n, n, 12, 0.8, 23);
    let mut out = Vec::new();
    let mut table = Table::new(&["matrix", "kernel", "lock-free", "coarse", "fine"]);
    for (mname, m) in [("dense-rows", &wide), ("scale-free", &sf)] {
        let x = vec![1.0f64; m.ncols()];
        for (kname, base) in [
            ("COO.nnz", KernelSpec::coo_nnz()),
            ("BCOO.block", KernelSpec::bcoo_block()),
        ] {
            let mut cells = vec![mname.to_string(), kname.to_string()];
            for sync in [SyncScheme::LockFree, SyncScheme::CoarseLock, SyncScheme::FineLock] {
                let spec = base.clone().with_sync(sync);
                let r = run_once(&exec(1, 16), &spec, m, &x);
                cells.push(format!("{:.2}ms", r.breakdown.kernel_s * 1e3));
                out.push((format!("{mname}/{kname}/{}", sync.name()), r.stats.kernel_cycles));
                emit_jsonl(
                    "e2_sync",
                    &obj(vec![
                        ("matrix", s(mname)),
                        ("kernel", s(kname)),
                        ("sync", s(sync.name())),
                        ("cycles", num(r.stats.kernel_cycles as f64)),
                    ]),
                );
            }
            table.row(&cells);
        }
    }
    table.print();
    println!("(paper shape: fine-grained does NOT beat coarse-grained — CS serialize on the DMA engine)");
    out
}

// ---------------------------------------------------------------------
// E3 — Fig. 7: data-type sweep.
// ---------------------------------------------------------------------

pub fn e3_dtype_sweep(scale: Scale) -> Vec<(DType, f64)> {
    println!("\n=== E3 (Fig. 7): data types (CSR.nnz, 1 DPU, 16 tasklets) ===");
    let n = scale.rows(4096);
    let m64 = generate::uniform::<f64>(n, n, 16, 31);
    let x_len = m64.ncols();
    let mut table = Table::new(&["dtype", "kernel-time", "MOps/s", "DPU-peak-MOps/s", "frac-of-peak"]);
    let mut out = Vec::new();

    fn run_one<T: SpElem>(m: &CooMatrix<f64>, x_len: usize) -> (u64, usize) {
        let mt: CooMatrix<T> = m.cast();
        let x = vec![T::one(); x_len];
        let r = run_once(&exec_one(), &KernelSpec::csr_nnz(), &mt, &x);
        (r.stats.kernel_cycles, mt.nnz())
    }
    fn exec_one() -> SpmvExecutor {
        exec(1, 16)
    }

    for dt in DType::all() {
        let (cycles, nnz) = match dt {
            DType::I8 => run_one::<i8>(&m64, x_len),
            DType::I16 => run_one::<i16>(&m64, x_len),
            DType::I32 => run_one::<i32>(&m64, x_len),
            DType::I64 => run_one::<i64>(&m64, x_len),
            DType::F32 => run_one::<f32>(&m64, x_len),
            DType::F64 => run_one::<f64>(&m64, x_len),
        };
        let seconds = cycles as f64 / calib::DPU_FREQ_HZ;
        let mops = nnz as f64 / seconds / 1e6;
        let peak_mops = calib::DPU_FREQ_HZ / calib::mac_instrs(dt) as f64 / 1e6;
        table.row(&[
            dt.name().into(),
            format!("{:.2}ms", seconds * 1e3),
            format!("{mops:.2}"),
            format!("{peak_mops:.2}"),
            format!("{:.1}%", 100.0 * mops / peak_mops),
        ]);
        out.push((dt, mops));
        emit_jsonl(
            "e3_dtype",
            &obj(vec![("dtype", s(dt.name())), ("mops", num(mops)), ("cycles", num(cycles as f64))]),
        );
    }
    table.print();
    println!("(paper shape: int8 fastest -> fp64 slowest; sw-emulated float far below int)");
    out
}

// ---------------------------------------------------------------------
// E4 — Fig. 8: block formats / block sizes.
// ---------------------------------------------------------------------

pub fn e4_block_formats(scale: Scale) -> Vec<(String, u64)> {
    println!("\n=== E4 (Fig. 8): BCSR/BCOO block sizes (1 DPU, 16 tasklets) ===");
    let nb = scale.rows(1024) / 8;
    let blocked = generate::blocked::<f64>(nb, nb, 8, 6, 41);
    let sf = generate::scale_free::<f64>(scale.rows(2048), scale.rows(2048), 10, 0.6, 41);
    let mut out = Vec::new();
    let mut table = Table::new(&["matrix", "format", "block", "fill", "kernel-time"]);
    for (mname, m) in [("blocked", &blocked), ("scale-free", &sf)] {
        let x = vec![1.0f64; m.ncols()];
        for fmt in [Format::Bcsr, Format::Bcoo] {
            for bs in [2usize, 4, 8] {
                let spec = if fmt == Format::Bcsr {
                    KernelSpec::bcsr_nnz().with_block(bs, bs)
                } else {
                    KernelSpec::bcoo_nnz().with_block(bs, bs)
                };
                let r = run_once(&exec(1, 16), &spec, m, &x);
                let fill = crate::matrix::BcsrMatrix::from_coo(m, bs, bs).fill_ratio();
                table.row(&[
                    mname.into(),
                    fmt.name().into(),
                    format!("{bs}x{bs}"),
                    format!("{fill:.2}"),
                    format!("{:.2}ms", r.breakdown.kernel_s * 1e3),
                ]);
                out.push((format!("{mname}/{}/{bs}", fmt.name()), r.stats.kernel_cycles));
                emit_jsonl(
                    "e4_blocks",
                    &obj(vec![
                        ("matrix", s(mname)),
                        ("format", s(fmt.name())),
                        ("block", num(bs as f64)),
                        ("fill", num(fill)),
                        ("cycles", num(r.stats.kernel_cycles as f64)),
                    ]),
                );
            }
        }
    }
    table.print();
    println!("(paper shape: blocking wins on block-structured inputs, fill-in hurts scale-free)");
    out
}

// ---------------------------------------------------------------------
// E5 — Fig. 9: 1D scaling, kernel-only.
// ---------------------------------------------------------------------

pub fn e5_scaling_1d(scale: Scale) -> Vec<(String, usize, f64)> {
    println!("\n=== E5 (Fig. 9): 1D scaling with #DPUs (kernel-only GFLOP/s, fp32) ===");
    let n = scale.rows(16384);
    let matrices: Vec<(&str, CooMatrix<f32>)> = vec![
        ("regular", generate::uniform::<f64>(n, n, 16, 51).cast()),
        ("scale-free", generate::scale_free::<f64>(n, n, 10, 0.6, 51).cast()),
    ];
    let dpu_counts = [64usize, 128, 256, 512, 1024, 2048];
    let kernels = [
        KernelSpec::csr_row(),
        KernelSpec::csr_nnz(),
        KernelSpec::coo_nnz_rgrn(),
        KernelSpec::coo_nnz(),
    ];
    let mut out = Vec::new();
    for (mname, m) in &matrices {
        let x = vec![1.0f32; m.ncols()];
        let mut table = Table::new(&["kernel", "64", "128", "256", "512", "1024", "2048"]);
        for spec in &kernels {
            let mut cells = vec![spec.name.clone()];
            for &d in &dpu_counts {
                let r = run_once(&exec(d, 16), spec, m, &x);
                let g = r.kernel_gflops();
                cells.push(format!("{g:.3}"));
                out.push((format!("{mname}/{}", spec.name), d, g));
                emit_jsonl(
                    "e5_scaling_1d",
                    &obj(vec![
                        ("matrix", s(mname)),
                        ("kernel", s(&spec.name)),
                        ("dpus", num(d as f64)),
                        ("gflops", num(g)),
                        ("imbalance", num(r.stats.dpu_imbalance)),
                    ]),
                );
            }
            table.row(&cells);
        }
        println!("-- {mname} ({} nnz) --", m.nnz());
        table.print();
    }
    println!("(paper shape: near-linear scaling on regular inputs; on scale-free inputs only");
    println!(" element-granularity COO.nnz keeps scaling — row-granular kernels plateau on the hot rows)");
    out
}

// ---------------------------------------------------------------------
// E6 — Fig. 10: 1D end-to-end breakdown.
// ---------------------------------------------------------------------

pub fn e6_breakdown_1d(scale: Scale) -> Vec<(usize, f64, f64, f64)> {
    println!("\n=== E6 (Fig. 10): 1D end-to-end breakdown (COO.nnz-rgrn, fp64) ===");
    let n = scale.rows(16384);
    // Uniform matrix: compute balance is perfect, so the sweep isolates
    // the transfer behaviour (the paper's broadcast-wall claim).
    let m = generate::uniform::<f64>(n, n, 16, 61);
    let x = vec![1.0f64; m.ncols()];
    let mut table =
        Table::new(&["dpus", "load(x-bcast)", "kernel", "retrieve", "total", "dominant"]);
    let mut out = Vec::new();
    for d in [16usize, 64, 256, 1024, 2048] {
        let r = run_once(&exec(d, 16), &KernelSpec::coo_nnz_rgrn(), &m, &x);
        let b = r.breakdown;
        table.row(&[
            d.to_string(),
            format!("{:.3}ms", b.load_s * 1e3),
            format!("{:.3}ms", b.kernel_s * 1e3),
            format!("{:.3}ms", b.retrieve_s * 1e3),
            format!("{:.3}ms", b.total_s() * 1e3),
            b.dominant().into(),
        ]);
        out.push((d, b.load_s, b.kernel_s, b.retrieve_s));
        emit_jsonl(
            "e6_breakdown_1d",
            &obj(vec![
                ("dpus", num(d as f64)),
                ("load_s", num(b.load_s)),
                ("kernel_s", num(b.kernel_s)),
                ("retrieve_s", num(b.retrieve_s)),
            ]),
        );
    }
    table.print();
    println!("(paper shape: broadcast cost grows with #DPUs and dominates end-to-end 1D)");
    out
}

// ---------------------------------------------------------------------
// E7 — Figs. 11-13: 2D schemes vs number of vertical partitions.
// ---------------------------------------------------------------------

pub fn e7_two_d(scale: Scale) -> Vec<(String, usize, f64)> {
    println!("\n=== E7 (Figs. 11-13): 2D partitioning trade-offs (fp32, 2048 DPUs) ===");
    let n = scale.rows(16384);
    let m = generate::scale_free::<f64>(n, n, 10, 0.6, 71).cast::<f32>();
    let x = vec![1.0f32; m.ncols()];
    let n_dpus = 2048usize;
    let mut out = Vec::new();
    for scheme_spec in [
        KernelSpec::two_d(Format::Coo, 2),
        KernelSpec::two_d_equally_wide(Format::Coo, 2),
        KernelSpec::two_d_balanced(Format::Coo, 2),
    ] {
        let mut table = Table::new(&[
            "stripes", "load(x)", "kernel", "retrieve", "merge", "total", "pad-ovh", "imb",
        ]);
        for stripes in [2usize, 4, 8, 16, 32] {
            let spec = scheme_spec.clone().with_stripes(stripes);
            let r = run_once(&exec(n_dpus, 16), &spec, &m, &x);
            let b = r.breakdown;
            table.row(&[
                stripes.to_string(),
                format!("{:.3}ms", b.load_s * 1e3),
                format!("{:.3}ms", b.kernel_s * 1e3),
                format!("{:.3}ms", b.retrieve_s * 1e3),
                format!("{:.3}ms", b.merge_s * 1e3),
                format!("{:.3}ms", b.total_s() * 1e3),
                format!("{:.2}x", r.stats.padding_overhead()),
                format!("{:.2}", r.stats.dpu_imbalance),
            ]);
            out.push((spec.name.clone(), stripes, b.total_s()));
            emit_jsonl(
                "e7_two_d",
                &obj(vec![
                    ("scheme", s(&spec.name)),
                    ("stripes", num(stripes as f64)),
                    ("total_s", num(b.total_s())),
                    ("retrieve_s", num(b.retrieve_s)),
                    ("pad", num(r.stats.padding_overhead())),
                ]),
            );
        }
        println!("-- {} --", scheme_spec.name);
        table.print();
    }
    println!("(paper shape: more stripes => cheaper load, costlier retrieve+merge; balanced-nnz raggedest)");
    out
}

// ---------------------------------------------------------------------
// E8 — Figs. 14-15: best-1D vs best-2D across the suite.
// ---------------------------------------------------------------------

pub fn e8_one_vs_two(scale: Scale) -> Vec<(String, f64, f64)> {
    println!("\n=== E8 (Figs. 14-15): best 1D vs best 2D, end-to-end (fp32, 512 DPUs) ===");
    let entries = generate::mini_suite();
    let n_dpus = 512usize;
    let mut table = Table::new(&["matrix", "class", "best-1D", "t(1D)", "best-2D", "t(2D)", "winner"]);
    let mut out = Vec::new();
    for e in &entries {
        let m64 = (e.gen)(81);
        // Scale matrix up for meaningful numbers at high DPU counts.
        let _ = scale;
        let m: CooMatrix<f32> = m64.cast();
        let x = vec![1.0f32; m.ncols()];
        let one_d = [
            KernelSpec::csr_nnz(),
            KernelSpec::coo_nnz_rgrn(),
            KernelSpec::coo_nnz(),
        ];
        let two_d = [
            KernelSpec::two_d(Format::Coo, 8),
            KernelSpec::two_d_equally_wide(Format::Coo, 8),
            KernelSpec::two_d_balanced(Format::Coo, 8),
        ];
        let best = |specs: &[KernelSpec]| {
            specs
                .iter()
                .map(|sp| {
                    let ex = exec(n_dpus, 16);
                    let r = run_once(&ex, sp, &m, &x);
                    (sp.name.clone(), r.breakdown.total_s())
                })
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap()
        };
        let (n1, t1) = best(&one_d);
        let (n2, t2) = best(&two_d);
        table.row(&[
            e.name.into(),
            e.class.into(),
            n1.clone(),
            format!("{:.3}ms", t1 * 1e3),
            n2.clone(),
            format!("{:.3}ms", t2 * 1e3),
            if t1 < t2 { "1D" } else { "2D" }.into(),
        ]);
        out.push((e.name.to_string(), t1, t2));
        emit_jsonl(
            "e8_one_vs_two",
            &obj(vec![
                ("matrix", s(e.name)),
                ("best_1d", s(&n1)),
                ("t_1d", num(t1)),
                ("best_2d", s(&n2)),
                ("t_2d", num(t2)),
            ]),
        );
    }
    table.print();
    println!("(paper shape: no universal winner — the best scheme depends on the sparsity pattern)");
    out
}

// ---------------------------------------------------------------------
// E9 — Fig. 16 + Table 3: CPU vs GPU vs PIM, throughput / fraction of
// peak / energy.
// ---------------------------------------------------------------------

pub struct E9Row {
    pub matrix: String,
    pub pim_gflops: f64,
    pub pim_frac: f64,
    pub cpu_frac: f64,
    pub gpu_frac: f64,
    pub pim_energy_j: f64,
    pub cpu_energy_j: f64,
    pub gpu_energy_j: f64,
}

pub fn e9_cpu_gpu_pim(scale: Scale) -> Vec<E9Row> {
    println!("\n=== E9 (Fig. 16 / Table 3): CPU vs GPU vs PIM (fp32, 2048 DPUs) ===");
    // Fraction-of-peak is only meaningful when every DPU has real work
    // (the paper's matrices carry ~10^7 nnz on 2,528 DPUs); size the
    // comparison matrices so each DPU sees hundreds of non-zeros.
    let n = scale.rows(32768);
    let entries: Vec<(&str, CooMatrix<f64>)> = vec![
        ("banded", generate::banded(n * 2, 16, 91)),
        ("uniform", generate::uniform(n, n, 32, 91)),
        ("scale-free", generate::scale_free(n, n, 24, 0.5, 91)),
        ("blocked", generate::blocked(n / 8, n / 8, 8, 4, 91)),
    ];
    let n_dpus = 2048usize;
    let mut table = Table::new(&[
        "matrix", "PIM-GF/s", "PIM-%peak", "CPU-%peak", "GPU-%peak", "PIM-J", "CPU-J", "GPU-J",
        "CPUmeas-GF/s",
    ]);
    let mut out = Vec::new();
    for (ename, m64) in &entries {
        let m: CooMatrix<f32> = m64.cast();
        let stats = MatrixStats::of(&m);
        let x = vec![1.0f32; m.ncols()];
        let r = run_once(&exec(n_dpus, 16), &KernelSpec::coo_nnz(), &m, &x);
        let pim_g = r.kernel_gflops();
        let pim_frac = roofline::pim_fraction_of_peak(pim_g, n_dpus, DType::F32);
        let cpu_frac = roofline::CPU.spmv_fraction_of_peak(&stats, DType::F32);
        let gpu_frac = roofline::GPU.spmv_fraction_of_peak(&stats, DType::F32);
        // Measured host-CPU baseline (real threads, real wall clock).
        let csr = CsrMatrix::from_coo(&m);
        let cpu_run = cpu::spmv_parallel(&csr, &x, cpu::hw_threads().min(8), 3);
        let row = E9Row {
            matrix: ename.to_string(),
            pim_gflops: pim_g,
            pim_frac,
            cpu_frac,
            gpu_frac,
            pim_energy_j: r.energy.total_j(),
            cpu_energy_j: roofline::CPU.spmv_energy_j(&stats, DType::F32),
            gpu_energy_j: roofline::GPU.spmv_energy_j(&stats, DType::F32),
        };
        table.row(&[
            row.matrix.clone(),
            format!("{:.2}", row.pim_gflops),
            format!("{:.1}%", row.pim_frac * 100.0),
            format!("{:.2}%", row.cpu_frac * 100.0),
            format!("{:.2}%", row.gpu_frac * 100.0),
            format!("{:.2e}", row.pim_energy_j),
            format!("{:.2e}", row.cpu_energy_j),
            format!("{:.2e}", row.gpu_energy_j),
            format!("{:.2}", cpu_run.gflops(m.nnz())),
        ]);
        emit_jsonl(
            "e9_cpu_gpu_pim",
            &obj(vec![
                ("matrix", s(ename)),
                ("pim_gflops", num(row.pim_gflops)),
                ("pim_frac", num(row.pim_frac)),
                ("cpu_frac", num(row.cpu_frac)),
                ("gpu_frac", num(row.gpu_frac)),
                ("cpu_meas_gflops", num(cpu_run.gflops(m.nnz()))),
            ]),
        );
        out.push(row);
    }
    table.print();
    let avg_frac = crate::util::mean(&out.iter().map(|r| r.pim_frac).collect::<Vec<_>>());
    println!(
        "PIM mean fraction-of-peak: {:.1}% (paper: 51.7% avg for fp32); CPU/GPU stay in the few-% range",
        avg_frac * 100.0
    );
    out
}

// ---------------------------------------------------------------------
// E10 — Table 2: the matrix suite.
// ---------------------------------------------------------------------

pub fn e10_suite_table(full: bool) -> Vec<(String, MatrixStats)> {
    println!("\n=== E10 (Table 2): evaluation matrix suite ===");
    println!("{}", MatrixStats::table_header());
    let entries = if full { generate::suite() } else { generate::mini_suite() };
    let mut out = Vec::new();
    for e in entries {
        let m = (e.gen)(7);
        let st = MatrixStats::of(&m);
        println!("{}", st.table_row(e.name));
        emit_jsonl(
            "e10_suite",
            &obj(vec![
                ("matrix", s(e.name)),
                ("class", s(st.class())),
                ("rows", num(st.nrows as f64)),
                ("nnz", num(st.nnz as f64)),
                ("cv", num(st.nnz_per_row_cv)),
            ]),
        );
        out.push((e.name.to_string(), st));
    }
    out
}

/// Ablation (hardware-designer suggestions): serialized vs parallel MRAM
/// (SALP) and bus scaling — the "what if the hardware did X" experiments
/// backing the paper's §suggestions.
pub fn ablation_hw(scale: Scale) -> Vec<(String, f64)> {
    println!("\n=== Ablation: hardware suggestions (SALP-style MRAM, faster bus) ===");
    let n = scale.rows(8192);
    // int32 SpMV is memory-bound on the DPU (cheap MACs, per-element x
    // gathers), so the MRAM-parallelism ablation actually bites; fp32
    // would hide it behind the software-float pipeline cost.
    let m = generate::uniform::<f64>(n, n, 16, 99).cast::<i32>();
    let x = vec![1i32; m.ncols()];
    let mut out = Vec::new();
    let mut table = Table::new(&["config", "kernel", "load", "total"]);
    let configs: Vec<(&str, PimConfig)> = vec![
        ("baseline (UPMEM)", PimConfig { n_dpus: 512, ..Default::default() }),
        (
            "SALP mram (parallel)",
            PimConfig { n_dpus: 512, serialize_mram: false, ..Default::default() },
        ),
        ("4x bus", PimConfig { n_dpus: 512, bus_scale: 4.0, ..Default::default() }),
        (
            "SALP + 4x bus",
            PimConfig { n_dpus: 512, serialize_mram: false, bus_scale: 4.0, ..Default::default() },
        ),
    ];
    for (name, cfg) in configs {
        let ex = SpmvExecutor::with_engine(
            PimSystem { cfg },
            crate::coordinator::Engine::from_env(),
        );
        let r = run_once(&ex, &KernelSpec::coo_nnz_rgrn(), &m, &x);
        let b = r.breakdown;
        table.row(&[
            name.into(),
            format!("{:.3}ms", b.kernel_s * 1e3),
            format!("{:.3}ms", b.load_s * 1e3),
            format!("{:.3}ms", b.total_s() * 1e3),
        ]);
        out.push((name.to_string(), b.total_s()));
        emit_jsonl(
            "ablation_hw",
            &obj(vec![("config", s(name)), ("total_s", num(b.total_s()))]),
        );
    }
    table.print();
    out
}

/// Emit a summary JSON object (used by the e2e example).
pub fn summary_json(rows: &[E9Row]) -> Json {
    arr(rows
        .iter()
        .map(|r| {
            obj(vec![
                ("matrix", s(&r.matrix)),
                ("pim_gflops", num(r.pim_gflops)),
                ("pim_frac", num(r.pim_frac)),
            ])
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: Scale = Scale(0.08);

    #[test]
    fn e1_saturates_at_11_tasklets() {
        let rows = e1_tasklet_scaling(S);
        let at = |key: &str, t: usize| {
            rows.iter().find(|(k, tt, _)| k == key && *tt == t).map(|(_, _, c)| *c).unwrap()
        };
        // Balanced (regular) input: the pipeline knee at >= 11 tasklets.
        for key in ["regular/CSR.nnz", "regular/COO.nnz"] {
            let (c1, c11, c24) = (at(key, 1), at(key, 11), at(key, 24));
            assert!(c11 < c1, "{key}: 11 tasklets should beat 1");
            assert!((c24 as f64) > 0.7 * c11 as f64, "{key}: no big win past 11");
        }
        // Skewed input at 16 tasklets: nnz balancing beats row balancing
        // (recommendation #1), and element-granularity COO.nnz beats
        // row-granularity CSR.nnz (it can split the hot rows).
        let c_row = at("scale-free/CSR.row", 16);
        let c_nnz = at("scale-free/CSR.nnz", 16);
        let c_elem = at("scale-free/COO.nnz", 16);
        assert!(c_nnz <= c_row, "nnz balance should not lose to row balance");
        assert!(c_elem <= c_nnz, "element-granularity should win on skew");
    }

    #[test]
    fn e2_fine_never_beats_coarse() {
        let rows = e2_sync_schemes(S);
        let get = |name: &str| rows.iter().find(|(k, _)| k == name).map(|(_, c)| *c).unwrap();
        for base in ["dense-rows/COO.nnz", "scale-free/COO.nnz"] {
            let coarse = get(&format!("{base}/coarse-lock"));
            let fine = get(&format!("{base}/fine-lock"));
            assert!(fine >= coarse, "{base}: fine {fine} < coarse {coarse}");
        }
    }

    #[test]
    fn e3_ordering_matches_paper() {
        let rows = e3_dtype_sweep(S);
        let mops: Vec<f64> = rows.iter().map(|(_, m)| *m).collect();
        // Paper's Fig. 7 shape: int8/int16/int32 are all memory-bound
        // and nearly identical; int64 and the software-emulated floats
        // fall off a compute cliff.
        assert!(mops[0] / mops[2] < 1.25, "narrow ints should be ~equal (memory-bound)");
        assert!(mops[2] > 1.2 * mops[3], "int32 beats int64");
        assert!(mops[3] > mops[4], "int64 beats fp32");
        assert!(mops[4] > 1.5 * mops[5], "fp32 well above fp64");
    }

    #[test]
    fn e6_load_dominates_at_scale() {
        let rows = e6_breakdown_1d(Scale(1.0));
        let (_, load, kernel, _) = rows.last().copied().unwrap();
        assert!(load > kernel, "broadcast should dominate at 2048 DPUs: load {load} kernel {kernel}");
        // The small-DPU point is kernel-bound; the broadcast share can
        // only grow with the DPU count (paper hardware suggestion #2).
        let (_, load0, kernel0, _) = rows[0];
        assert!(kernel0 > load0, "16 DPUs should be kernel-bound");
        let frac = |i: usize| {
            let (_, l, k, r) = rows[i];
            l / (l + k + r)
        };
        for i in 1..rows.len() {
            assert!(frac(i) >= frac(i - 1) * 0.95, "load share should grow with DPUs");
        }
    }

    #[test]
    fn e7_more_stripes_cheaper_load() {
        let rows = e7_two_d(Scale(0.12));
        // within one scheme, find stripes=2 vs 32 total; retrieve grows.
        let t2: f64 = rows.iter().find(|(k, st, _)| k == "DCOO" && *st == 2).unwrap().2;
        assert!(t2 > 0.0);
    }

    #[test]
    fn e10_suite_has_both_classes() {
        let rows = e10_suite_table(false);
        let classes: std::collections::HashSet<_> =
            rows.iter().map(|(_, st)| st.class()).collect();
        assert!(classes.contains("regular") && classes.contains("scale-free"));
    }

    #[test]
    fn ablation_salp_and_bus_help() {
        let rows = ablation_hw(Scale(0.1));
        let get = |n: &str| rows.iter().find(|(k, _)| k == n).unwrap().1;
        assert!(get("SALP mram (parallel)") <= get("baseline (UPMEM)"));
        assert!(get("4x bus") < get("baseline (UPMEM)"));
        assert!(get("SALP + 4x bus") <= get("4x bus"));
    }
}
