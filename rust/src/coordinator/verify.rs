//! `cfg(loom)`-only model drivers for the concurrency verification
//! suite (`rust/tests/loom_models.rs`, run by `scripts/analyze.sh`).
//!
//! Each function here is the *body* of one loom model iteration: it
//! builds fresh state, runs a scaled-down instance of a production
//! protocol across loom-instrumented threads, asserts the protocol's
//! invariant, and joins every thread it spawned (loom requires
//! terminating threads). The drivers live inside the crate so they can
//! exercise the real `pub(crate)` machinery — [`pool::WorkerPool`],
//! [`Completions`], [`BufferPool`] — rather than re-implementations;
//! the test binary only picks the schedule explorer's knobs.
//!
//! Everything here goes through [`crate::util::sync`], so under
//! `--cfg loom` the exact locks, condvars and atomics production runs
//! on are the ones being exhaustively interleaved.
//!
//! [`pool::WorkerPool`]: super::engine::pool::WorkerPool
//! [`Completions`]: super::queue::Completions
//! [`BufferPool`]: super::queue::BufferPool

use super::engine::pool::WorkerPool;
use super::metrics::{Breakdown, RunResult};
use super::queue::{BufferPool, Completions};
use super::scheduler::{FairScheduler, TenantSpec};
use super::service::Response;
use crate::pim::Energy;
use crate::util::sync::atomic::{AtomicUsize, Ordering};
use crate::util::sync::{thread, Arc, Condvar, Mutex};

fn spmv_response(v: f64) -> crate::util::Result<Response<f64>> {
    Ok(Response::Spmv(RunResult {
        y: vec![v],
        breakdown: Breakdown::default(),
        stats: Default::default(),
        energy: Energy::default(),
    }))
}

/// One round of the pooled-engine wave protocol: a local pool of
/// `workers` threads, one wave of `n` indices submitted through
/// [`WorkerPool::run_wave`] (the submitter helps drain), then shutdown
/// and join. Invariant: every index runs exactly once, and by the time
/// `run_wave` returns every result write is visible to the submitter —
/// the soundness argument for the lifetime-erased `TaskPtr`.
pub fn pool_wave_round(workers: usize, n: usize) {
    let (pool, handles) = WorkerPool::with_workers(workers);
    let slots: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
    pool.run_wave(n, &|i| {
        slots[i].fetch_add(1, Ordering::SeqCst);
    });
    for (i, s) in slots.iter().enumerate() {
        assert_eq!(s.load(Ordering::SeqCst), 1, "wave index {i} must run exactly once");
    }
    pool.shutdown();
    for h in handles {
        h.join().expect("pool worker panicked");
    }
}

/// The wave protocol's panic path: a task panics on whichever thread
/// claimed it; the payload must re-raise on the *submitter* after the
/// wave retires, and no pool worker may die (a dead worker would
/// strand every later wave).
pub fn pool_panic_round() {
    let (pool, handles) = WorkerPool::with_workers(1);
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.run_wave(2, &|i| {
            if i == 1 {
                panic!("injected task panic");
            }
        });
    }));
    assert!(outcome.is_err(), "a task panic must re-raise on the submitting thread");
    pool.shutdown();
    for h in handles {
        h.join().expect("a pool worker died on a task panic instead of containing it");
    }
}

/// The ticket store's bounded wait racing its publisher. Whatever the
/// interleaving — publish before the wait, mid-wait, or after a
/// "timed-out" wake (loom explores the timeout branch
/// nondeterministically) — the published response must end up claimed
/// exactly once and never lost; a lost wakeup would surface as a loom
/// deadlock.
pub fn completions_claim_round() {
    let comp: Arc<Completions<f64>> = Arc::new(Completions::new());
    comp.register(1);
    let publisher_comp = Arc::clone(&comp);
    let publisher = thread::spawn_named("verify-publish", move || {
        publisher_comp.publish(1, spmv_response(42.0));
    });
    let mut claimed = false;
    match comp.wait_timeout(1, std::time::Duration::from_secs(1)) {
        Ok(Response::Spmv(run)) => {
            assert_eq!(run.y, vec![42.0]);
            claimed = true;
        }
        Ok(other) => panic!("wrong response kind {:?}", other.kind()),
        Err(e) => assert!(e.is_shard_timeout(), "only a timeout may end the wait: {e}"),
    }
    publisher.join().expect("publisher panicked");
    // The publish has happened (join above); the timed-out branch must
    // find the response parked, and the claimed branch must find the
    // ticket retired — in no branch is the response lost.
    match comp.try_claim(1) {
        Ok(Some(Response::Spmv(run))) => {
            assert!(!claimed, "a response must not be claimable twice");
            assert_eq!(run.y, vec![42.0]);
        }
        Ok(Some(other)) => panic!("wrong response kind {:?}", other.kind()),
        Ok(None) => panic!("ticket still in flight after its publish"),
        Err(_) => assert!(claimed, "unclaimed ticket vanished from the store"),
    }
}

/// The stage-1 ↔ stage-3 buffer-recycle handoff, against the real
/// [`BufferPool`]. `std::sync::mpsc` (the production recycle channel)
/// is not loom-instrumented, so the model routes the retired buffer
/// through a facade mutex + condvar pair — the same
/// synchronizes-with edge `Sender::send` / `Receiver::recv` provide.
/// Invariant: the retired buffer reaches the pool and comes back
/// zeroed, never dropped and never observed with stale contents.
pub fn buffer_pool_recycle_round() {
    type RecycleChan = (Mutex<Vec<Vec<f64>>>, Condvar);
    let chan: Arc<RecycleChan> = Arc::new((Mutex::new(Vec::new()), Condvar::new()));
    let tx = Arc::clone(&chan);
    let stage1 = thread::spawn_named("verify-stage1", move || {
        // Stage 1 retires an iterate payload whose wave just finished.
        let (lock, cv) = &*tx;
        lock.lock().expect("recycle channel poisoned").push(vec![3.0f64; 4]);
        cv.notify_all();
    });
    // Stage 3: drain the recycle channel into the pool, then take the
    // next merge buffer.
    let mut pool: BufferPool<f64> = BufferPool::new(0.0);
    {
        let (lock, cv) = &*chan;
        let mut q = lock.lock().expect("recycle channel poisoned");
        while q.is_empty() {
            q = cv.wait(q).expect("recycle channel poisoned");
        }
        for buf in q.drain(..) {
            pool.put(buf);
        }
    }
    let y = pool.take_zeroed(4);
    assert_eq!(y.len(), 4);
    assert!(y.iter().all(|&v| v == 0.0), "recycled buffer must come back zeroed");
    stage1.join().expect("stage 1 panicked");
}

/// Satellite model: weighted-round-robin dispatch against a paused
/// scheduler and a quota-full tenant queue. The dispatcher parks on
/// the condvar while paused (predicate-guarded); a racing resume must
/// always wake it, and the per-tenant in-flight quota (1, with 2 jobs
/// queued) must never wedge the drain — a missed resume or a
/// quota-deadlock surfaces as a loom deadlock.
pub fn scheduler_pause_resume_round() {
    struct Sched {
        fair: FairScheduler<u32>,
        paused: bool,
    }
    let mut fair: FairScheduler<u32> =
        FairScheduler::new(vec![TenantSpec::new("a", 1).with_quota(1)])
            .expect("tenant spec rejected");
    let t = fair.tenant("a").expect("tenant a exists");
    fair.enqueue(t, 10);
    fair.enqueue(t, 11); // quota 1: full tenant queue behind one slot
    let state = Arc::new((Mutex::new(Sched { fair, paused: true }), Condvar::new()));

    let resume_state = Arc::clone(&state);
    let resumer = thread::spawn_named("verify-resume", move || {
        let (lock, cv) = &*resume_state;
        lock.lock().expect("scheduler state poisoned").paused = false;
        cv.notify_all();
    });

    // Dispatcher: drain both jobs, waiting while paused.
    let (lock, cv) = &*state;
    let mut st = lock.lock().expect("scheduler state poisoned");
    let mut served = Vec::new();
    while served.len() < 2 {
        if st.paused {
            st = cv.wait(st).expect("scheduler state poisoned");
            continue;
        }
        let (tenant, job) = st
            .fair
            .pop()
            .expect("a resumed scheduler with queued work must dispatch");
        served.push(job);
        st.fair.complete(tenant); // frees the quota slot for the next pop
    }
    assert_eq!(served, vec![10, 11], "WRR must drain the tenant queue in order");
    assert_eq!(st.fair.queued(), 0);
    assert_eq!(st.fair.in_flight(), 0);
    drop(st);
    resumer.join().expect("resumer panicked");
}
