//! Hand-rolled CLI (clap is not in the offline vendor set).
//!
//! Grammar: `sparsep <command> [--flag value]...`. See
//! [`print_usage`] or run `sparsep help` for the command list.

use crate::baselines::cpu;
use crate::bench_harness::figures::{self, Scale};
use crate::coordinator::queue::DEFAULT_QUEUE_DEPTH;
use crate::coordinator::{
    BlockPolicy, CalibrationTable, Engine, FaultPlan, KernelSpec, Request, ServiceBuilder,
    ShardedService, ShardedServiceBuilder, ShardedTicket, SpmvExecutor, SpmvService, TenantId,
    TenantSpec, Ticket,
};
use crate::matrix::{generate, CooMatrix, CsrMatrix, DType, SpElem};
use crate::pim::{PimConfig, PimSystem};
use crate::util::{Context, Result};
use crate::bail;
use std::collections::HashMap;

/// Parsed command line: positional command + `--key value` flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(cmd) = it.next() {
            if cmd.starts_with("--") {
                bail!("expected a command before flags, got {cmd}");
            }
            out.command = cmd;
        }
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument: {a}");
            };
            // Boolean flags (no value / next is a flag).
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap(),
                _ => "true".to_string(),
            };
            out.flags.insert(key.to_string(), val);
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

pub fn print_usage() {
    println!(
        "sparsep — SpMV on a (simulated) real PIM system [SparseP reproduction]

USAGE: sparsep <command> [--flag value]...

COMMANDS:
  kernels                         list the 25 SpMV kernels
  suite [--full]                  print the matrix-suite table (Table 2)
  run --matrix M [--kernel K]     run one kernel through SpmvService
      [--dpus N] [--tasklets T]   (no --kernel: auto-select, calibrated
      [--dtype D] [--stripes S]   when --calibration is loaded):
      [--seed X]
      [--batch B]                 B > 1: batched SpMM-style request of
                                  B vectors over one handle, all verified
  serve --matrix M                demo serving loop: load once, submit a
      [--requests R] [--batch B]  mixed request stream (spmv / batch /
      [--iters I] [--dpus N]      iterate) with all tickets in flight,
      [--kernel K] [--seed X]     wait out of order, verify every answer
      [--shards S|auto]           S > 0: serve through a ShardedService
      [--tenants name:w[:q],...]  (S rank groups, --dpus per shard) with
                                  weighted-round-robin multi-tenant
                                  scheduling (weight w, in-flight quota q);
                                  auto: full grid shape (R x C x replicas)
                                  from the calibration
      [--grid RxC]                shard as an R x C grid: R row bands x C
                                  nnz-balanced column tiles per band, with
                                  partials reduced in fixed column order
                                  (overrides --shards; answers unchanged)
      [--replicas K]              K replicas per tile; Spmv/Batch reads go
                                  to the least-loaded replica, loads and
                                  iterate writes to all K
      [--chaos] [--chaos-seed X]  seeded fault injection (kill/delay/drop/
                                  stall); killed shard backends respawn
                                  from the shared plan cache, answers stay
                                  bit-identical, seed printed for replay
      [--deadline-ms D]           earliest-deadline-first dispatch within
                                  each tenant (WRR across tenants intact)
      [--max-queue Q]             per-tenant admission cap; overflow sheds
                                  as typed Overloaded, never silently
      [--timeout-ms T]            bound waits: a wedged shard surfaces as
                                  a typed ShardTimeout naming the shard
      [--listen ADDR]             serve over TCP instead of the demo loop:
                                  bind ADDR (e.g. 127.0.0.1:7878) and speak
                                  the SPRP wire protocol until killed; all
                                  sharding/tenant/chaos flags above apply
      [--max-in-flight N]         per-connection in-flight cap (--listen
                                  only); overflow answers as a typed
                                  Overloaded frame before submission
  tune [--quick]                  search-based autotuner: sweep kernel x
      [--dpus N] [--tasklets T]   block x shard-grid x replicas per
      [--threads T] [--samples S] (matrix, batch) cell, write the winners
                                  as a calibration
      [--seed X] [--tolerance E]  table for --calibration, and report
      [--out calibration.json]    calibrated-vs-heuristic speedup per
      [--report BENCH_tune.json]  class (fails if any cell regresses
                                  beyond E); --quick = mini-suite smoke
  exp <id> [--scale F] [--full]   regenerate an experiment:
      e1 tasklet-scaling   e2 sync-schemes    e3 dtype
      e4 block-formats     e5 1d-scaling      e6 1d-breakdown
      e7 2d-tradeoff       e8 1d-vs-2d        e9 cpu-gpu-pim
      e10 suite            ablation           all
  adaptive --matrix M [--dpus N]  heuristic vs autotuned kernel choice
  solve --app cg|jacobi|pagerank --matrix M [--dpus N]
                                  iterative solver with SpMV on PIM
      [--seeds a,b,c]             pagerank only: multi-seed personalized
                                  PageRank via the batched serving path
  bench-coordinator               load-once CG wall-clock, serial vs
      [--rows N] [--deg K] [--iters I] [--dpus N] [--out F]
                                  threaded; writes BENCH_coordinator.json
  bench-batch                     batched vs looped single-vector SpMV
      [--rows N] [--deg K] [--batch B] [--dpus N] [--kernel K]
      [--threads T] [--samples S] [--out F]
                                  wall-clock; writes BENCH_batch.json
  bench-service                   queued-pipelined service vs synchronous
      [--rows N] [--deg K] [--requests R] [--batch B] [--dpus N]
      [--kernel K] [--threads T] [--samples S] [--out F]
                                  wall-clock; writes BENCH_service.json
  bench-shard                     sharded serving at 1/2/4/8 shards,
      [--rows N] [--deg K] [--requests R] [--batch B] [--dpus N]
      [--kernel K] [--threads T] [--samples S] [--out F]
                                  serial + threaded wall-clock;
                                  writes BENCH_shard.json (--dpus = per shard)
  bench-grid                      2D grid sharding vs row-only sharding:
      [--rows N] [--deg K] [--shards S] [--requests R] [--batch B]
      [--dpus N] [--kernel K] [--threads T] [--samples S] [--out F]
                                  1x1 baseline, Sx1 row-only, tuned R x C
                                  sweep (row-only = candidate zero, so
                                  tuned >= row-only by construction), and
                                  the tuned shape replicated x2; serial +
                                  threaded; writes BENCH_grid.json
  bench-check                     gate BENCH_*.json against a committed
      [--baseline F] [--dir D]    baseline manifest of by-construction
      [--tolerance E]             ratio statistics; fails on any value
      [--missing skip|fail]       below min*(1-E); missing bench files
                                  skip or fail per --missing
  bench-resilience                resilience tier: recovery overhead
      [--rows N] [--deg K] [--requests R] [--shards S] [--dpus N]
      [--kernel K] [--threads T] [--samples S] [--max-queue Q]
      [--offered L] [--seed X] [--out F]
                                  (kill-per-request vs fault-free wall,
                                  verified bit-identical) + typed shed
                                  rate and served-latency percentiles
                                  under overload; writes
                                  BENCH_resilience.json
  bench-net                       TCP front-end load test: open-loop
      [--rows N] [--deg K] [--shards S] [--dpus N] [--conns C]
      [--requests R] [--rates A,B,...] [--max-queue Q] [--seed X]
      [--addr HOST:PORT] [--out F]
                                  Poisson arrivals at each offered rate
                                  (req/s) against an in-process server
                                  (or --addr for a live one); reports
                                  p50/p99/p999 latency + typed shed rate
                                  per level; writes BENCH_net.json
  bench-hotpath                   host hot-path overhaul bench: pooled
      [--rows N] [--deg K] [--iters I] [--batch B] [--dpus N]
      [--kernel K] [--threads T] [--samples S] [--out F]
                                  worker-pool engine vs legacy spawn-per-
                                  wave threading vs serial, for spmv /
                                  batch / iterate at 1 and 4 shards;
                                  writes BENCH_hotpath.json
  artifacts                       list AOT artifacts + PJRT platform
  xla --rows N --deg K            SpMV through the AOT XLA path, verified
  cpu --rows N --deg K [--threads T]  measured host-CPU baseline
  help                            this message

SERVICE FLAGS (run / serve / solve):
  --engine serial|threaded|pooled|spawning
                                  how per-DPU kernel simulations execute
                                  (threaded == pooled: the persistent
                                  worker pool; spawning: legacy per-wave
                                  thread spawn/join)
  --threads N                     worker threads for the threaded engine
  --vector-block auto|N           vectors per fused batch block
                                  (auto = adaptive policy, the default)
  --queue-depth Q                 request intake depth before submit blocks
  --calibration file.json         load a `sparsep tune` calibration table:
                                  kernel/block/shard choices come from
                                  measured winners instead of heuristics
  (results are bit-identical across engines, block widths, queue depths
  and calibration tables; only wall-clock changes)"
    );
}

/// Engine selection from `--engine` / `--threads` (defaults to the
/// `SPARSEP_ENGINE` / `SPARSEP_THREADS` environment, i.e. serial).
/// `threaded` (and its alias `pooled`) is the persistent worker-pool
/// engine; `spawning` is the legacy spawn-per-wave threading kept as
/// the `bench-hotpath` baseline.
fn engine_from_args(args: &Args) -> Result<Engine> {
    let threads = args.get_usize("threads", 0)?;
    match args.get("engine") {
        None if threads > 0 => Ok(Engine::threaded(threads)),
        None => Ok(Engine::from_env()),
        Some("serial") => Ok(Engine::Serial),
        Some("threaded") | Some("pooled") => Ok(Engine::threaded(threads)),
        Some("spawning") => Ok(Engine::spawning(threads)),
        Some(other) => bail!("unknown --engine {other} (serial|threaded|pooled|spawning)"),
    }
}

/// Vector-block policy from `--vector-block` (`auto` or a fixed width;
/// default adaptive).
fn block_policy_from_args(args: &Args) -> Result<BlockPolicy> {
    match args.get("vector-block") {
        None | Some("auto") => Ok(BlockPolicy::Adaptive),
        Some(v) => {
            let width: usize =
                v.parse().context("--vector-block must be `auto` or a positive integer")?;
            crate::ensure!(width >= 1, "--vector-block must be `auto` or a positive integer");
            Ok(BlockPolicy::Fixed(width))
        }
    }
}

/// Parse `--grid RxC` (e.g. `4x2`) into `(rows, cols)`, if given.
fn grid_from_args(args: &Args) -> Result<Option<(usize, usize)>> {
    let Some(spec) = args.get("grid") else { return Ok(None) };
    let (r, c) = spec
        .split_once('x')
        .with_context(|| format!("--grid must look like RxC (e.g. 4x2), got {spec}"))?;
    let rows: usize =
        r.trim().parse().with_context(|| format!("--grid rows must be an integer in {spec:?}"))?;
    let cols: usize =
        c.trim().parse().with_context(|| format!("--grid cols must be an integer in {spec:?}"))?;
    crate::ensure!(rows >= 1 && cols >= 1, "--grid dimensions must be >= 1, got {spec}");
    Ok(Some((rows, cols)))
}

/// Parse `--replicas K`, if given.
fn replicas_from_args(args: &Args) -> Result<Option<usize>> {
    if args.get("replicas").is_none() {
        return Ok(None);
    }
    let k = args.get_usize("replicas", 1)?;
    crate::ensure!(k >= 1, "--replicas must be >= 1");
    Ok(Some(k))
}

/// Load the table behind `--calibration file.json`, if given. A path
/// that does not load (missing file, corrupt checksum) is a hard error
/// rather than a silent fallback to the heuristics.
fn calibration_from_args(args: &Args) -> Result<Option<crate::util::sync::Arc<CalibrationTable>>> {
    match args.get("calibration") {
        None => Ok(None),
        Some(path) => {
            let t = CalibrationTable::load(std::path::Path::new(path))
                .with_context(|| format!("load --calibration {path}"))?;
            Ok(Some(crate::util::sync::Arc::new(t)))
        }
    }
}

/// Build an [`SpmvService`] from the common service flags.
fn service_from_args<T: SpElem>(args: &Args, sys: PimSystem) -> Result<SpmvService<T>> {
    let mut b = ServiceBuilder::new()
        .engine(engine_from_args(args)?)
        .vector_block(block_policy_from_args(args)?)
        .queue_depth(args.get_usize("queue-depth", DEFAULT_QUEUE_DEPTH)?);
    if let Some(table) = calibration_from_args(args)? {
        b = b.calibration(table);
    }
    b.build(sys)
}

fn matrix_by_name(name: &str, seed: u64) -> Result<CooMatrix<f64>> {
    if let Some(e) = generate::suite().into_iter().find(|e| e.name == name) {
        return Ok((e.gen)(seed));
    }
    if let Some(e) = generate::mini_suite().into_iter().find(|e| e.name == name) {
        return Ok((e.gen)(seed));
    }
    if let Some(path) = name.strip_prefix('@') {
        return crate::matrix::mtx::read_mtx(std::path::Path::new(path));
    }
    bail!(
        "unknown matrix {name}; use a suite name ({}) or @path/to/file.mtx",
        generate::suite().iter().map(|e| e.name).collect::<Vec<_>>().join(", ")
    )
}

fn run_spec<T: SpElem>(
    spec: &KernelSpec,
    m64: &CooMatrix<f64>,
    svc: &SpmvService<T>,
    batch: usize,
) -> Result<()> {
    let m: CooMatrix<T> = m64.cast();
    // Register once: plan + fingerprint happen here, then requests
    // against the handle are hash-free.
    let handle = svc.load(&m, spec)?;
    if batch > 1 {
        return run_spec_batch(spec, &m, svc, handle, batch);
    }
    let x: Vec<T> = (0..m.ncols()).map(|i| T::from_f64(((i % 9) as f64) - 4.0)).collect();
    let r = svc.spmv(&handle, &x)?;
    // Verify against the host oracle.
    let ok = r.y == m.spmv(&x);
    let b = r.breakdown;
    println!("kernel     : {}", spec.name);
    println!("dtype      : {}", T::DTYPE.name());
    println!("matrix     : {} x {}, {} nnz", m.nrows(), m.ncols(), m.nnz());
    println!("dpus       : {} ({} tasklets)", r.stats.n_dpus, svc.system().tasklets());
    println!("verified   : {}", if ok { "OK (matches host oracle)" } else { "MISMATCH" });
    println!("matrix load: {:.3} ms (one-time)", r.stats.matrix_load_s * 1e3);
    println!(
        "breakdown  : load {:.3} ms | kernel {:.3} ms | retrieve {:.3} ms | merge {:.3} ms",
        b.load_s * 1e3,
        b.kernel_s * 1e3,
        b.retrieve_s * 1e3,
        b.merge_s * 1e3
    );
    println!("total      : {:.3} ms ({} dominated)", b.total_s() * 1e3, b.dominant());
    println!("kernel perf: {:.3} GFLOP/s  e2e {:.3} GFLOP/s", r.kernel_gflops(), r.e2e_gflops());
    println!("imbalance  : {:.2}x   padding {:.2}x", r.stats.dpu_imbalance, r.stats.padding_overhead());
    println!("energy     : {:.3e} J (dpu {:.1e} / bus {:.1e} / host {:.1e})",
        r.energy.total_j(), r.energy.dpu_j + r.energy.dpu_idle_j, r.energy.bus_j, r.energy.host_j);
    if !ok {
        bail!("verification failed");
    }
    Ok(())
}

/// Batched `run`: B deterministic vectors through one
/// [`Request::Batch`] against the resident handle, every output
/// verified against the host oracle.
fn run_spec_batch<T: SpElem>(
    spec: &KernelSpec,
    m: &CooMatrix<T>,
    svc: &SpmvService<T>,
    handle: crate::coordinator::MatrixHandle,
    batch: usize,
) -> Result<()> {
    let xs: Vec<Vec<T>> = (0..batch)
        .map(|b| {
            (0..m.ncols()).map(|i| T::from_f64((((i + 3 * b) % 9) as f64) - 4.0)).collect()
        })
        .collect();
    let block = svc.resolved_block(&handle, batch)?;
    let t0 = std::time::Instant::now();
    let res = svc.spmv_batch(&handle, &xs)?;
    let wall = t0.elapsed().as_secs_f64();
    let ok = res.runs.iter().zip(&xs).all(|(r, x)| r.y == m.spmv(x));
    let total = res.total();
    println!("kernel     : {} (batched x{batch})", spec.name);
    println!("dtype      : {}", T::DTYPE.name());
    println!("matrix     : {} x {}, {} nnz", m.nrows(), m.ncols(), m.nnz());
    println!("dpus       : {} ({} tasklets)", svc.system().n_dpus(), svc.system().tasklets());
    println!(
        "verified   : {}",
        if ok { "OK (all outputs match host oracle)" } else { "MISMATCH" }
    );
    println!(
        "matrix load: {:.3} ms (one-time, shared by the whole batch)",
        res.runs.first().map_or(0.0, |r| r.stats.matrix_load_s) * 1e3
    );
    println!(
        "modeled    : {:.3} ms total over the batch ({:.3} ms/vector)",
        total.total_s() * 1e3,
        total.total_s() / batch as f64 * 1e3
    );
    println!(
        "host wall  : {:.3} ms for the batch ({:.3} ms/vector, {} engine, {:?} -> block {})",
        wall * 1e3,
        wall / batch as f64 * 1e3,
        engine_name(svc.engine()),
        svc.block_policy(),
        block
    );
    if !ok {
        bail!("batched verification failed");
    }
    Ok(())
}

/// Expected host-oracle answer of one serve-demo request.
enum ServeExpect {
    Spmv(Vec<f64>),
    Batch(Vec<Vec<f64>>),
    Iterate(Vec<f64>),
}

/// The serve demo's deterministic request mix — spmv / batch / iterate
/// round-robin (iterate degrades to spmv on non-square matrices) —
/// each paired with its host-oracle expectation. Shared by the plain
/// and sharded `serve` paths so the mix can never drift between them.
fn serve_demo_requests(
    m: &CooMatrix<f64>,
    requests: usize,
    batch: usize,
    iters: usize,
) -> Vec<(Request<f64>, ServeExpect)> {
    let vec_for = |s: usize| -> Vec<f64> {
        (0..m.ncols()).map(|i| ((i + 3 * s) % 9) as f64 - 4.0).collect()
    };
    let square = m.nrows() == m.ncols();
    let mut out = Vec::with_capacity(requests);
    for r in 0..requests {
        let entry = match r % 3 {
            0 => {
                let x = vec_for(r);
                let want = m.spmv(&x);
                (Request::spmv(x), ServeExpect::Spmv(want))
            }
            1 => {
                let xs: Vec<Vec<f64>> = (0..batch).map(|b| vec_for(r + b)).collect();
                let want = xs.iter().map(|x| m.spmv(x)).collect();
                (Request::batch(xs), ServeExpect::Batch(want))
            }
            _ if square => {
                let x = vec_for(r);
                let mut want = x.clone();
                for _ in 0..iters {
                    want = m.spmv(&want);
                }
                (Request::iterate(x, iters), ServeExpect::Iterate(want))
            }
            _ => {
                // Non-square matrices cannot iterate; substitute an spmv.
                let x = vec_for(r);
                let want = m.spmv(&x);
                (Request::spmv(x), ServeExpect::Spmv(want))
            }
        };
        out.push(entry);
    }
    out
}

/// Claim the demo's tickets out of submission order (evens forward,
/// odds backward), verify every response against its oracle, and
/// return the per-kind counts (`[spmv, batch, iterate]`), the number of
/// typed [`Response::Overloaded`] sheds (admission control under
/// `--max-queue`; never a silent drop), and the modeled simulated
/// seconds served. Generic over the ticket type so the plain and
/// sharded paths share one verifier.
fn serve_claim_and_verify<TK: Copy>(
    pending: &[(TK, ServeExpect)],
    wait: impl Fn(TK) -> Result<crate::coordinator::Response<f64>>,
) -> Result<([usize; 3], usize, f64)> {
    let mut order: Vec<usize> = (0..pending.len()).step_by(2).collect();
    order.extend((0..pending.len()).skip(1).step_by(2).rev());
    let mut counts = [0usize; 3];
    let mut shed = 0usize;
    let mut modeled_s = 0.0f64;
    for idx in order {
        let (ticket, expect) = &pending[idx];
        match (wait(*ticket)?, expect) {
            (crate::coordinator::Response::Overloaded, _) => shed += 1,
            (crate::coordinator::Response::Spmv(r), ServeExpect::Spmv(want)) => {
                crate::ensure!(&r.y == want, "spmv request {idx} mismatch");
                counts[0] += 1;
                modeled_s += r.breakdown.total_s();
            }
            (crate::coordinator::Response::Batch(b), ServeExpect::Batch(want)) => {
                crate::ensure!(
                    b.runs.iter().map(|r| &r.y).eq(want.iter()),
                    "batch request {idx} mismatch"
                );
                counts[1] += 1;
                modeled_s += b.total().total_s();
            }
            (crate::coordinator::Response::Iterate(it), ServeExpect::Iterate(want)) => {
                crate::ensure!(&it.last.y == want, "iterate request {idx} mismatch");
                counts[2] += 1;
                modeled_s += it.total.total_s();
            }
            _ => bail!("response kind does not match request kind"),
        }
    }
    Ok((counts, shed, modeled_s))
}

/// `sparsep serve --shards S [--tenants spec]`: the multi-tenant
/// sharded serving demo — one logical matrix split across S rank
/// groups, every tenant loading its own handle (shared plan cache:
/// equal slices plan once) and submitting a mixed request stream
/// through the weighted-round-robin scheduler; all tickets in flight,
/// waited out of order, every answer verified against host oracles.
fn serve_sharded(args: &Args) -> Result<()> {
    let mname = args.get("matrix").unwrap_or("mini-sf");
    let m = matrix_by_name(mname, args.get_usize("seed", 7)? as u64)?;
    let tenants = match args.get("tenants") {
        Some(spec) => TenantSpec::parse_list(spec)?,
        None => vec![TenantSpec::new("default", 1)],
    };
    let cfg = PimConfig {
        n_dpus: args.get_usize("dpus", 64)?,
        tasklets: args.get_usize("tasklets", 16)?,
        ..Default::default()
    };
    let requests = args.get_usize("requests", 12)?;
    let batch = args.get_usize("batch", 8)?;
    let iters = args.get_usize("iters", 5)?;
    let calibration = calibration_from_args(args)?;
    let mut builder = ShardedServiceBuilder::new()
        .engine(engine_from_args(args)?)
        .vector_block(block_policy_from_args(args)?)
        .queue_depth(args.get_usize("queue-depth", DEFAULT_QUEUE_DEPTH)?)
        .tenants(tenants.clone());
    if let Some(table) = &calibration {
        builder = builder.calibration(crate::util::sync::Arc::clone(table));
    }
    // `--shards auto` asks the calibration table for the full grid
    // shape — R x C x replicas (no table / no entry: the builder's
    // default stands). Explicit `--grid`/`--replicas` flags override
    // whatever was resolved; absent flags never clobber it.
    let grid = grid_from_args(args)?;
    let replicas = replicas_from_args(args)?;
    builder = match args.get("shards") {
        Some("auto") => builder.shards_for_matrix(&m, batch),
        _ => builder.shards(args.get_usize("shards", 2)?),
    };
    if let Some((r, c)) = grid {
        builder = builder.grid(r, c);
    }
    if let Some(k) = replicas {
        builder = builder.replicas(k);
    }
    // Resilience knobs: per-tenant admission cap (sheds surface as
    // typed Overloaded responses), bounded waits (wedged shards surface
    // as typed ShardTimeout errors), and a seeded chaos plan.
    if args.get("max-queue").is_some() {
        let cap = args.get_usize("max-queue", 0)?;
        crate::ensure!(cap >= 1, "--max-queue must be >= 1");
        builder = builder.max_queue(cap);
    }
    if args.get("timeout-ms").is_some() {
        let ms = args.get_usize("timeout-ms", 0)?;
        crate::ensure!(ms >= 1, "--timeout-ms must be >= 1");
        builder = builder.wait_timeout(std::time::Duration::from_millis(ms as u64));
    }
    let chaos = args.get_bool("chaos") || args.get("chaos-seed").is_some();
    if chaos {
        let seed = args.get_usize("chaos-seed", 0xC4A05)? as u64;
        // Aim kills across every backend slot of the requested grid —
        // R x C tiles x K replicas, keyed by the linear slot layout
        // (band*C + col)*K + replica; out-of-range targets under
        // `--shards auto` are harmless no-ops. Random plans draw from
        // kill / dropped-completion / delay — every answer still
        // verifies bit-identically below.
        let bands = grid.map(|(r, _)| r).unwrap_or_else(|| args.get_usize("shards", 2).unwrap_or(2));
        let chaos_slots =
            (bands.max(1) * grid.map(|(_, c)| c).unwrap_or(1) * replicas.unwrap_or(1)).max(1);
        let plan = FaultPlan::random(seed, requests as u64, chaos_slots, 0.4);
        println!(
            "chaos      : {} fault(s) over {} ticket(s) from seed {seed:#x} \
             (reproduce with --chaos-seed {seed})",
            plan.len(),
            requests
        );
        builder = builder.fault_injector(crate::util::sync::Arc::new(plan));
    }
    let svc: ShardedService<f64> = builder.build(PimSystem::new(cfg.clone())?)?;
    let stripes = args.get_usize("stripes", 8)?;
    let spec = match args.get("kernel") {
        Some(k) => KernelSpec::by_name(k, stripes)
            .with_context(|| format!("unknown kernel {k} (see `sparsep kernels`)"))?,
        // Select against the per-shard system actually being served
        // (same config serve() would use), not a default one; with a
        // calibration table loaded the choice is measured, not guessed.
        None => {
            let c = crate::coordinator::adaptive::select_auto(
                &m,
                &cfg,
                batch,
                calibration.as_deref(),
            );
            println!("selected   : {}  ({})", c.spec.name, c.reason);
            c.spec
        }
    };
    let g = svc.grid();
    println!(
        "serve (sharded): {} ({}x{}, {} nnz) via {} on a {}x{} grid x{} replica(s) x {} DPUs, tenants {:?}",
        mname,
        m.nrows(),
        m.ncols(),
        m.nnz(),
        spec.name,
        g.rows,
        g.cols,
        g.replicas,
        cfg.n_dpus,
        svc.tenant_names()
    );

    // Every tenant loads its own handle over the same matrix — the
    // shared plan cache makes the per-shard plans build exactly once.
    let t_load = std::time::Instant::now();
    let handles: Vec<(TenantId, crate::coordinator::ShardedHandle)> = tenants
        .iter()
        .map(|ts| {
            let t = svc
                .tenant(&ts.name)
                .ok_or_else(|| crate::format_err!("tenant {:?} not registered", ts.name))?;
            svc.load_for(t, &m, &spec).map(|h| (t, h))
        })
        .collect::<Result<_>>()?;
    println!(
        "load       : {} handle(s) after {:.3} ms ({} plan build(s) for {} tile slice(s))",
        handles.len(),
        t_load.elapsed().as_secs_f64() * 1e3,
        svc.stats().plan_builds,
        svc.shard_count()
    );

    // `--deadline-ms D` tags every request with a deadline D from its
    // submit instant: the dispatcher serves earliest-deadline-first
    // within each tenant (cross-tenant weighted round-robin is
    // untouched). Deadlines order dispatch; they never cancel work.
    let deadline = match args.get("deadline-ms") {
        Some(_) => {
            let ms = args.get_usize("deadline-ms", 0)?;
            crate::ensure!(ms >= 1, "--deadline-ms must be >= 1");
            Some(std::time::Duration::from_millis(ms as u64))
        }
        None => None,
    };
    let plan_reqs = serve_demo_requests(&m, requests, batch, iters);
    let t0 = std::time::Instant::now();
    let mut pending: Vec<(ShardedTicket, ServeExpect)> = Vec::with_capacity(requests);
    for (r, (req, expect)) in plan_reqs.into_iter().enumerate() {
        let (tenant, handle) = handles[r % handles.len()];
        let ticket = match deadline {
            Some(d) => svc.submit_with_deadline(tenant, handle, req, d)?,
            None => svc.submit_for(tenant, handle, req)?,
        };
        pending.push((ticket, expect));
    }
    let (counts, shed, modeled_s) = serve_claim_and_verify(&pending, |t| svc.wait(t))?;
    let wall = t0.elapsed().as_secs_f64();
    let st = svc.stats();
    println!(
        "requests   : {} ({} spmv / {} batch x{} / {} iterate x{}), all verified OK{}",
        requests - shed,
        counts[0],
        counts[1],
        batch,
        counts[2],
        iters,
        if shed > 0 {
            format!("; {shed} shed as typed Overloaded (admission cap)")
        } else {
            String::new()
        }
    );
    if st.respawns > 0 {
        println!("respawns   : {} shard backend(s) respawned from the shared plan cache", st.respawns);
    }
    println!(
        "wall       : {:.3} ms total ({:.1} req/s)",
        wall * 1e3,
        requests as f64 / wall.max(1e-12)
    );
    println!("modeled    : {:.3} ms of simulated PIM time served", modeled_s * 1e3);
    println!(
        "service    : {} submitted / {} completed, cache {} hit / {} miss / {} build, {} plan(s) resident",
        st.submitted, st.completed, st.cache_hits, st.cache_misses, st.plan_builds, st.resident_plans
    );
    for t in &st.tenants {
        let quota = if t.max_in_flight == usize::MAX {
            "inf".to_string()
        } else {
            t.max_in_flight.to_string()
        };
        println!(
            "  tenant {:<10} weight {:>2} quota {:>4}: {} submitted, {} completed, {} shed, \
             latency p50/p99/p999 {}/{}/{} us",
            t.name,
            t.weight,
            quota,
            t.enqueued,
            t.completed,
            t.shed,
            t.latency.p50_us,
            t.latency.p99_us,
            t.latency.p999_us
        );
    }
    // Tenant unload demo: evict the first tenant's handles and reclaim
    // its plans from the shared cache.
    let (first, _) = handles[0];
    let (unloaded, evicted) = svc.unload_tenant(first)?;
    println!(
        "unload     : tenant {:?} released {} handle(s), {} plan(s) evicted from cache",
        st.tenants[0].name, unloaded, evicted
    );
    Ok(())
}

/// `sparsep serve --listen ADDR`: the TCP front end. Builds the same
/// sharded multi-tenant facade `serve_sharded` demos (all its flags
/// apply), binds the SPRP wire protocol on ADDR, and runs until the
/// process is killed — clients load their own matrices over the wire,
/// so `--matrix` is not needed here.
fn serve_listen(args: &Args) -> Result<()> {
    let listen = args.get("listen").expect("checked by serve()");
    let tenants = match args.get("tenants") {
        Some(spec) => TenantSpec::parse_list(spec)?,
        None => vec![TenantSpec::new("default", 1)],
    };
    let cfg = PimConfig {
        n_dpus: args.get_usize("dpus", 64)?,
        tasklets: args.get_usize("tasklets", 16)?,
        ..Default::default()
    };
    let grid = grid_from_args(args)?;
    let replicas = replicas_from_args(args)?;
    let mut builder = ShardedServiceBuilder::new()
        .engine(engine_from_args(args)?)
        .vector_block(block_policy_from_args(args)?)
        .queue_depth(args.get_usize("queue-depth", DEFAULT_QUEUE_DEPTH)?)
        .shards(args.get_usize("shards", 2)?)
        .tenants(tenants);
    if let Some((r, c)) = grid {
        builder = builder.grid(r, c);
    }
    if let Some(k) = replicas {
        builder = builder.replicas(k);
    }
    if let Some(table) = calibration_from_args(args)? {
        builder = builder.calibration(table);
    }
    if args.get("max-queue").is_some() {
        let cap = args.get_usize("max-queue", 0)?;
        crate::ensure!(cap >= 1, "--max-queue must be >= 1");
        builder = builder.max_queue(cap);
    }
    if args.get("timeout-ms").is_some() {
        let ms = args.get_usize("timeout-ms", 0)?;
        crate::ensure!(ms >= 1, "--timeout-ms must be >= 1");
        builder = builder.wait_timeout(std::time::Duration::from_millis(ms as u64));
    }
    if args.get_bool("chaos") || args.get("chaos-seed").is_some() {
        let seed = args.get_usize("chaos-seed", 0xC4A05)? as u64;
        let bands = grid.map(|(r, _)| r).unwrap_or(args.get_usize("shards", 2)?);
        let chaos_slots =
            (bands.max(1) * grid.map(|(_, c)| c).unwrap_or(1) * replicas.unwrap_or(1)).max(1);
        let horizon = args.get_usize("requests", 64)? as u64;
        let plan = FaultPlan::random(seed, horizon, chaos_slots, 0.4);
        println!(
            "chaos      : {} fault(s) over the first {horizon} ticket(s) from seed {seed:#x}",
            plan.len()
        );
        builder = builder.fault_injector(crate::util::sync::Arc::new(plan));
    }
    let svc: ShardedService<f64> = builder.build(PimSystem::new(cfg.clone())?)?;
    let opts = crate::net::ServerOpts {
        max_in_flight_per_conn: args.get_usize("max-in-flight", 64)?,
    };
    let shards = svc.shard_count();
    let tenant_names = svc.tenant_names().to_vec();
    let server = crate::net::Server::spawn(svc, listen, opts)?;
    println!(
        "listening  : {} ({} shard(s) x {} DPUs, tenants {:?}, {} in flight per conn)",
        server.local_addr(),
        shards,
        cfg.n_dpus,
        tenant_names,
        opts.max_in_flight_per_conn
    );
    println!("serving    : SPRP wire protocol; stop with ctrl-c");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `sparsep serve`: a deterministic demo of the serving API — load one
/// matrix, put a mixed request stream in flight at once, wait for the
/// tickets out of submission order, verify every answer against host
/// oracles, and report throughput + service counters.
fn serve(args: &Args) -> Result<()> {
    if args.get("listen").is_some() {
        return serve_listen(args);
    }
    if args.get("shards").is_some()
        || args.get("tenants").is_some()
        || args.get("grid").is_some()
        || args.get("replicas").is_some()
    {
        return serve_sharded(args);
    }
    let mname = args.get("matrix").unwrap_or("mini-sf");
    let m = matrix_by_name(mname, args.get_usize("seed", 7)? as u64)?;
    let cfg = PimConfig {
        n_dpus: args.get_usize("dpus", 64)?,
        tasklets: args.get_usize("tasklets", 16)?,
        ..Default::default()
    };
    let svc: SpmvService<f64> = service_from_args(args, PimSystem::new(cfg)?)?;
    let requests = args.get_usize("requests", 12)?;
    let batch = args.get_usize("batch", 8)?;
    let iters = args.get_usize("iters", 5)?;
    let stripes = args.get_usize("stripes", 8)?;
    let spec = match args.get("kernel") {
        Some(k) => KernelSpec::by_name(k, stripes)
            .with_context(|| format!("unknown kernel {k} (see `sparsep kernels`)"))?,
        None => {
            let c = crate::coordinator::adaptive::select_auto(
                &m,
                &svc.system().cfg,
                batch,
                calibration_from_args(args)?.as_deref(),
            );
            println!("selected   : {}  ({})", c.spec.name, c.reason);
            c.spec
        }
    };
    println!(
        "serve: {} ({}x{}, {} nnz) via {} on {} DPUs, {} engine, {:?} blocks",
        mname,
        m.nrows(),
        m.ncols(),
        m.nnz(),
        spec.name,
        svc.system().n_dpus(),
        engine_name(svc.engine()),
        svc.block_policy()
    );

    let t_load = std::time::Instant::now();
    let handle = svc.load(&m, &spec)?;
    println!("load       : handle after {:.3} ms (fingerprint + plan, once)", t_load.elapsed().as_secs_f64() * 1e3);

    // What each ticket should answer (host oracles computed up front).
    let plan_reqs = serve_demo_requests(&m, requests, batch, iters);

    // Submit everything, then claim tickets out of submission order
    // (evens forward, odds backward) — responses park until claimed.
    let t0 = std::time::Instant::now();
    let mut pending: Vec<(Ticket, ServeExpect)> = Vec::with_capacity(requests);
    for (req, expect) in plan_reqs {
        pending.push((svc.submit(handle, req)?, expect));
    }
    let submitted_in = t0.elapsed().as_secs_f64();
    // The plain (unsharded) service has no admission cap: shed is 0.
    let (counts, _shed, modeled_s) = serve_claim_and_verify(&pending, |t| svc.wait(t))?;
    let wall = t0.elapsed().as_secs_f64();
    let st = svc.stats();
    println!(
        "requests   : {} ({} spmv / {} batch x{} / {} iterate x{}), all verified OK",
        requests, counts[0], counts[1], batch, counts[2], iters
    );
    println!(
        "wall       : {:.3} ms total ({:.3} ms submitting, {:.1} req/s)",
        wall * 1e3,
        submitted_in * 1e3,
        requests as f64 / wall.max(1e-12)
    );
    println!("modeled    : {:.3} ms of simulated PIM time served", modeled_s * 1e3);
    println!(
        "service    : {} submitted / {} completed, cache {} hit / {} miss / {} build, {} plan(s) resident",
        st.submitted, st.completed, st.cache_hits, st.cache_misses, st.plan_builds, st.resident_plans
    );
    Ok(())
}

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn run(args: Args) -> Result<()> {
    match args.command.as_str() {
        "" | "help" | "--help" | "-h" => print_usage(),
        "kernels" => {
            let stripes = args.get_usize("stripes", 8)?;
            println!("{:<14} {:>6} {:>12} {:>10} {:>11}", "name", "format", "partition", "tasklet", "sync");
            for k in KernelSpec::all25(stripes) {
                let part = match k.partitioning {
                    crate::coordinator::Partitioning::OneD(b) => format!("1D/{}", b.name()),
                    crate::coordinator::Partitioning::TwoD(s, n) => format!("2D/{}x{n}", s.name()),
                };
                println!(
                    "{:<14} {:>6} {:>12} {:>10} {:>11}",
                    k.name,
                    k.format.name(),
                    part,
                    k.tasklet_balance.name(),
                    k.sync.name()
                );
            }
        }
        "suite" => {
            figures::e10_suite_table(args.get_bool("full"));
        }
        "run" => {
            let mname = args.get("matrix").unwrap_or("mini-sf");
            let m = matrix_by_name(mname, args.get_usize("seed", 7)? as u64)?;
            let cfg = PimConfig {
                n_dpus: args.get_usize("dpus", 64)?,
                tasklets: args.get_usize("tasklets", 16)?,
                ..Default::default()
            };
            let batch = args.get_usize("batch", 1)?;
            let stripes = args.get_usize("stripes", 8)?;
            let spec = match args.get("kernel") {
                Some(kname) => KernelSpec::by_name(kname, stripes)
                    .with_context(|| format!("unknown kernel {kname} (see `sparsep kernels`)"))?,
                // No --kernel: pick one — calibrated when a table is
                // loaded, the static heuristic otherwise.
                None => {
                    let c = crate::coordinator::adaptive::select_auto(
                        &m,
                        &cfg,
                        batch,
                        calibration_from_args(&args)?.as_deref(),
                    );
                    println!("selected   : {}  ({})", c.spec.name, c.reason);
                    c.spec
                }
            };
            let sys = PimSystem::new(cfg)?;
            let dt = DType::from_name(args.get("dtype").unwrap_or("fp64"))
                .context("bad --dtype (int8|int16|int32|int64|fp32|fp64)")?;
            match dt {
                DType::I8 => run_spec::<i8>(&spec, &m, &service_from_args(&args, sys)?, batch)?,
                DType::I16 => run_spec::<i16>(&spec, &m, &service_from_args(&args, sys)?, batch)?,
                DType::I32 => run_spec::<i32>(&spec, &m, &service_from_args(&args, sys)?, batch)?,
                DType::I64 => run_spec::<i64>(&spec, &m, &service_from_args(&args, sys)?, batch)?,
                DType::F32 => run_spec::<f32>(&spec, &m, &service_from_args(&args, sys)?, batch)?,
                DType::F64 => run_spec::<f64>(&spec, &m, &service_from_args(&args, sys)?, batch)?,
            }
        }
        "serve" => {
            serve(&args)?;
        }
        "exp" => {
            let id = args.get("id").map(str::to_string).unwrap_or_else(|| {
                // allow `sparsep exp e5 --scale ..` via flags-only too
                String::new()
            });
            let id = if id.is_empty() {
                args.flags
                    .keys()
                    .find(|k| k.starts_with('e') || *k == "ablation" || *k == "all")
                    .cloned()
                    .context("usage: sparsep exp --id e5 (or e1..e10, ablation, all)")?
            } else {
                id
            };
            // Figure drivers build their own executors; publish the
            // engine choice through the environment so they pick it up.
            engine_from_args(&args)?.export_env();
            let sc = Scale(args.get_f64("scale", 0.25)?);
            match id.as_str() {
                "e1" => drop(figures::e1_tasklet_scaling(sc)),
                "e2" => drop(figures::e2_sync_schemes(sc)),
                "e3" => drop(figures::e3_dtype_sweep(sc)),
                "e4" => drop(figures::e4_block_formats(sc)),
                "e5" => drop(figures::e5_scaling_1d(sc)),
                "e6" => drop(figures::e6_breakdown_1d(sc)),
                "e7" => drop(figures::e7_two_d(sc)),
                "e8" => drop(figures::e8_one_vs_two(sc)),
                "e9" => drop(figures::e9_cpu_gpu_pim(sc)),
                "e10" => drop(figures::e10_suite_table(args.get_bool("full"))),
                "ablation" => drop(figures::ablation_hw(sc)),
                "all" => {
                    figures::e10_suite_table(args.get_bool("full"));
                    figures::e1_tasklet_scaling(sc);
                    figures::e2_sync_schemes(sc);
                    figures::e3_dtype_sweep(sc);
                    figures::e4_block_formats(sc);
                    figures::e5_scaling_1d(sc);
                    figures::e6_breakdown_1d(sc);
                    figures::e7_two_d(sc);
                    figures::e8_one_vs_two(sc);
                    figures::e9_cpu_gpu_pim(sc);
                    figures::ablation_hw(sc);
                }
                other => bail!("unknown experiment {other}"),
            }
        }
        "adaptive" => {
            let mname = args.get("matrix").unwrap_or("sf-mid");
            let m = matrix_by_name(mname, 7)?;
            let cfg = PimConfig { n_dpus: args.get_usize("dpus", 256)?, ..Default::default() };
            let exec = SpmvExecutor::with_engine(PimSystem::new(cfg)?, engine_from_args(&args)?);
            let choice = crate::coordinator::adaptive::select_heuristic(&m, &exec.sys.cfg);
            println!("heuristic  : {}  ({})", choice.spec.name, choice.reason);
            let x: Vec<f64> = (0..m.ncols()).map(|i| (i % 7) as f64).collect();
            let t_h = exec.plan(&choice.spec, &m)?.execute(&exec, &x)?.breakdown.total_s();
            let (best, ranking) = crate::coordinator::adaptive::autotune(
                &exec,
                &m,
                std::slice::from_ref(&x),
                args.get_usize("stripes", 8)?,
            )?;
            println!("autotuned  : {}  ({:.3} ms)", best.name, ranking[0].1 * 1e3);
            println!("heuristic time: {:.3} ms ({:.2}x of best)", t_h * 1e3, t_h / ranking[0].1);
            println!("top 5:");
            for (name, t) in ranking.iter().take(5) {
                println!("  {:<14} {:>9.3} ms", name, t * 1e3);
            }
        }
        "solve" => {
            let app = args.get("app").context("--app cg|jacobi|pagerank")?;
            let mname = args.get("matrix").unwrap_or("mini-unif");
            let m = matrix_by_name(mname, 7)?;
            let cfg = PimConfig { n_dpus: args.get_usize("dpus", 64)?, ..Default::default() };
            let svc: SpmvService<f64> = service_from_args(&args, PimSystem::new(cfg)?)?;
            let spec = crate::coordinator::adaptive::select_heuristic(&m, &svc.system().cfg).spec;
            println!("matrix {} ({}x{}, {} nnz), kernel {}", mname, m.nrows(), m.ncols(), m.nnz(), spec.name);
            match app {
                "cg" => {
                    let a = crate::apps::cg::spd_from(&m);
                    let b = vec![1.0f64; a.nrows()];
                    let r = crate::apps::cg::solve(&svc, &spec, &a, &b, 1e-8, 1000)?;
                    println!(
                        "CG: converged={} iters={} residual={:.2e}",
                        r.converged,
                        r.stats.iterations,
                        r.residuals.last().unwrap()
                    );
                    print_solve_stats(&r.stats);
                }
                "jacobi" => {
                    let a = crate::apps::cg::spd_from(&m);
                    let b = vec![1.0f64; a.nrows()];
                    let r = crate::apps::jacobi::solve(&svc, &spec, &a, &b, 1e-10, 5000)?;
                    println!("Jacobi: converged={} iters={}", r.converged, r.iterations);
                    print_solve_stats(&r.stats);
                }
                "pagerank" => {
                    let p = crate::apps::pagerank::transition_matrix(&m);
                    if let Some(list) = args.get("seeds") {
                        // Multi-seed personalized PageRank: one batched
                        // power iteration serves every seed.
                        let seeds: Vec<usize> = list
                            .split(',')
                            .map(|t| t.trim().parse::<usize>())
                            .collect::<std::result::Result<_, _>>()
                            .context("--seeds must be a comma-separated list of node ids")?;
                        let r = crate::apps::pagerank::personalized_pagerank(
                            &svc, &spec, &p, &seeds, 0.85, 1e-9, 200,
                        )?;
                        println!(
                            "personalized PageRank: {} seeds, converged={} iters={}",
                            seeds.len(),
                            r.converged,
                            r.iterations
                        );
                        for (ranks, &seed) in r.ranks.iter().zip(&seeds) {
                            let mut top: Vec<(usize, f64)> =
                                ranks.iter().copied().enumerate().collect();
                            top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                            println!("  seed {seed}: top {:?}", &top[..top.len().min(3)]);
                        }
                        print_solve_stats(&r.stats);
                    } else {
                        let r =
                            crate::apps::pagerank::pagerank(&svc, &spec, &p, 0.85, 1e-9, 200)?;
                        let mut top: Vec<(usize, f64)> =
                            r.ranks.iter().copied().enumerate().collect();
                        top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                        println!("PageRank: converged={} iters={}", r.converged, r.iterations);
                        println!("top nodes: {:?}", &top[..top.len().min(5)]);
                        print_solve_stats(&r.stats);
                    }
                }
                other => bail!("unknown app {other}"),
            }
        }
        "tune" => {
            let d = crate::bench_harness::tune::TuneBenchOpts::default();
            let opts = crate::bench_harness::tune::TuneBenchOpts {
                quick: args.get_bool("quick"),
                n_dpus: args.get_usize("dpus", d.n_dpus)?,
                tasklets: args.get_usize("tasklets", d.tasklets)?,
                threads: args.get_usize("threads", d.threads)?,
                samples: args.get_usize("samples", d.samples)?,
                seed: args.get_usize("seed", d.seed as usize)? as u64,
                table_out: args.get("out").unwrap_or(d.table_out.as_str()).to_string(),
                out: args.get("report").unwrap_or(d.out.as_str()).to_string(),
                tolerance: args.get_f64("tolerance", d.tolerance)?,
            };
            crate::bench_harness::tune::run(&opts)?;
        }
        "bench-coordinator" => {
            bench_coordinator(&args)?;
        }
        "bench-batch" => {
            let d = crate::bench_harness::batch::BatchBenchOpts::default();
            let opts = crate::bench_harness::batch::BatchBenchOpts {
                rows: args.get_usize("rows", d.rows)?,
                deg: args.get_usize("deg", d.deg)?,
                batch: args.get_usize("batch", d.batch)?,
                n_dpus: args.get_usize("dpus", d.n_dpus)?,
                threads: args.get_usize("threads", cpu::hw_threads())?,
                kernel: args.get("kernel").unwrap_or(d.kernel.as_str()).to_string(),
                samples: args.get_usize("samples", d.samples)?,
                out: args.get("out").unwrap_or(d.out.as_str()).to_string(),
            };
            crate::bench_harness::batch::run(&opts)?;
        }
        "bench-service" => {
            let d = crate::bench_harness::service::ServiceBenchOpts::default();
            let opts = crate::bench_harness::service::ServiceBenchOpts {
                rows: args.get_usize("rows", d.rows)?,
                deg: args.get_usize("deg", d.deg)?,
                requests: args.get_usize("requests", d.requests)?,
                batch: args.get_usize("batch", d.batch)?,
                n_dpus: args.get_usize("dpus", d.n_dpus)?,
                threads: args.get_usize("threads", cpu::hw_threads())?,
                kernel: args.get("kernel").unwrap_or(d.kernel.as_str()).to_string(),
                samples: args.get_usize("samples", d.samples)?,
                queue_depth: args.get_usize("queue-depth", d.queue_depth)?,
                out: args.get("out").unwrap_or(d.out.as_str()).to_string(),
            };
            crate::bench_harness::service::run(&opts)?;
        }
        "bench-hotpath" => {
            let d = crate::bench_harness::hotpath::HotpathBenchOpts::default();
            let opts = crate::bench_harness::hotpath::HotpathBenchOpts {
                rows: args.get_usize("rows", d.rows)?,
                deg: args.get_usize("deg", d.deg)?,
                iters: args.get_usize("iters", d.iters)?,
                batch: args.get_usize("batch", d.batch)?,
                n_dpus: args.get_usize("dpus", d.n_dpus)?,
                threads: args.get_usize("threads", cpu::hw_threads())?,
                kernel: args.get("kernel").unwrap_or(d.kernel.as_str()).to_string(),
                samples: args.get_usize("samples", d.samples)?,
                out: args.get("out").unwrap_or(d.out.as_str()).to_string(),
            };
            crate::bench_harness::hotpath::run(&opts)?;
        }
        "bench-shard" => {
            let d = crate::bench_harness::shard::ShardBenchOpts::default();
            let opts = crate::bench_harness::shard::ShardBenchOpts {
                rows: args.get_usize("rows", d.rows)?,
                deg: args.get_usize("deg", d.deg)?,
                requests: args.get_usize("requests", d.requests)?,
                batch: args.get_usize("batch", d.batch)?,
                dpus_per_shard: args.get_usize("dpus", d.dpus_per_shard)?,
                threads: args.get_usize("threads", cpu::hw_threads())?,
                kernel: args.get("kernel").unwrap_or(d.kernel.as_str()).to_string(),
                samples: args.get_usize("samples", d.samples)?,
                out: args.get("out").unwrap_or(d.out.as_str()).to_string(),
            };
            crate::bench_harness::shard::run(&opts)?;
        }
        "bench-grid" => {
            let d = crate::bench_harness::grid::GridBenchOpts::default();
            let opts = crate::bench_harness::grid::GridBenchOpts {
                rows: args.get_usize("rows", d.rows)?,
                deg: args.get_usize("deg", d.deg)?,
                shards: args.get_usize("shards", d.shards)?,
                requests: args.get_usize("requests", d.requests)?,
                batch: args.get_usize("batch", d.batch)?,
                dpus_per_shard: args.get_usize("dpus", d.dpus_per_shard)?,
                threads: args.get_usize("threads", cpu::hw_threads())?,
                kernel: args.get("kernel").unwrap_or(d.kernel.as_str()).to_string(),
                samples: args.get_usize("samples", d.samples)?,
                out: args.get("out").unwrap_or(d.out.as_str()).to_string(),
            };
            crate::bench_harness::grid::run(&opts)?;
        }
        "bench-check" => {
            let d = crate::bench_harness::check::CheckOpts::default();
            let opts = crate::bench_harness::check::CheckOpts {
                baseline: args.get("baseline").unwrap_or(d.baseline.as_str()).to_string(),
                dir: args.get("dir").unwrap_or(d.dir.as_str()).to_string(),
                tolerance: args.get_f64("tolerance", d.tolerance)?,
                missing: args.get("missing").unwrap_or(d.missing.as_str()).to_string(),
            };
            crate::bench_harness::check::run(&opts)?;
        }
        "bench-resilience" => {
            let d = crate::bench_harness::resilience::ResilienceBenchOpts::default();
            let opts = crate::bench_harness::resilience::ResilienceBenchOpts {
                rows: args.get_usize("rows", d.rows)?,
                deg: args.get_usize("deg", d.deg)?,
                requests: args.get_usize("requests", d.requests)?,
                shards: args.get_usize("shards", d.shards)?,
                dpus_per_shard: args.get_usize("dpus", d.dpus_per_shard)?,
                threads: args.get_usize("threads", cpu::hw_threads())?,
                kernel: args.get("kernel").unwrap_or(d.kernel.as_str()).to_string(),
                samples: args.get_usize("samples", d.samples)?,
                max_queue: args.get_usize("max-queue", d.max_queue)?,
                offered: args.get_usize("offered", d.offered)?,
                seed: args.get_usize("seed", d.seed as usize)? as u64,
                out: args.get("out").unwrap_or(d.out.as_str()).to_string(),
            };
            crate::bench_harness::resilience::run(&opts)?;
        }
        "bench-net" => {
            let d = crate::net::LoadgenOpts::default();
            let rates = match args.get("rates") {
                None => d.rates,
                Some(spec) => spec
                    .split(',')
                    .map(|r| {
                        r.trim()
                            .parse::<f64>()
                            .with_context(|| format!("bad --rates entry {r:?}"))
                    })
                    .collect::<Result<Vec<f64>>>()?,
            };
            let opts = crate::net::LoadgenOpts {
                rows: args.get_usize("rows", d.rows)?,
                deg: args.get_usize("deg", d.deg)?,
                shards: args.get_usize("shards", d.shards)?,
                n_dpus: args.get_usize("dpus", d.n_dpus)?,
                conns: args.get_usize("conns", d.conns)?,
                requests: args.get_usize("requests", d.requests)?,
                rates,
                max_queue: args.get_usize("max-queue", d.max_queue)?,
                seed: args.get_usize("seed", d.seed as usize)? as u64,
                addr: args.get("addr").map(str::to_string),
                out: args.get("out").unwrap_or(d.out.as_str()).to_string(),
            };
            crate::net::loadgen::run(&opts)?;
        }
        "artifacts" => {
            let r = crate::runtime::ArtifactRunner::load_default()?;
            println!("PJRT platform: {}", r.platform());
            for n in r.names() {
                let m = r.meta(n).unwrap();
                println!("  {:<34} kind={:<11} dtype={}", n, m.kind, m.dtype);
            }
        }
        "xla" => {
            let rows = args.get_usize("rows", 1000)?;
            let deg = args.get_usize("deg", 6)?;
            let rn = crate::runtime::ArtifactRunner::load_default()?;
            let m = generate::uniform::<f64>(rows, rows, deg, 5).cast::<f32>();
            let csr = CsrMatrix::from_coo(&m);
            let staged = crate::runtime::ell_host::stage(&rn, &csr)?;
            let x: Vec<f32> = (0..rows).map(|i| ((i % 7) as f32) - 3.0).collect();
            let t0 = std::time::Instant::now();
            let y = staged.spmv(&rn, &x)?;
            let dt = t0.elapsed().as_secs_f64();
            let want = csr.spmv(&x);
            let ok = y
                .iter()
                .zip(&want)
                .all(|(a, b)| (a - b).abs() <= 1e-3 * b.abs().max(1.0));
            println!(
                "xla path: artifact {} pad {:.1}x  {:.3} ms  {:.3} GFLOP/s  verified: {}",
                staged.artifact,
                staged.pad_ratio,
                dt * 1e3,
                gfl(m.nnz(), dt),
                if ok { "OK" } else { "MISMATCH" }
            );
            if !ok {
                bail!("xla path verification failed");
            }
        }
        "cpu" => {
            let rows = args.get_usize("rows", 8192)?;
            let deg = args.get_usize("deg", 16)?;
            let threads = args.get_usize("threads", cpu::hw_threads())?;
            let m = generate::uniform::<f64>(rows, rows, deg, 5);
            let csr = CsrMatrix::from_coo(&m);
            let x = vec![1.0f64; rows];
            let run = cpu::spmv_parallel(&csr, &x, threads, 5);
            println!(
                "cpu baseline: {} threads  {:.3} ms/iter  {:.3} GFLOP/s",
                run.threads,
                run.seconds * 1e3,
                run.gflops(m.nnz())
            );
        }
        other => {
            print_usage();
            bail!("unknown command {other}");
        }
    }
    Ok(())
}

fn gfl(nnz: usize, s: f64) -> f64 {
    2.0 * nnz as f64 / s / 1e9
}

/// Wall-clock smoke benchmark for the plan/execute coordinator: CG
/// iterations on a scale-free SPD system, serial vs threaded engine.
/// Emits a JSON summary so successive PRs have a perf trajectory.
fn bench_coordinator(args: &Args) -> Result<()> {
    let rows = args.get_usize("rows", 100_000)?;
    let deg = args.get_usize("deg", 8)?;
    let iters = args.get_usize("iters", 50)?;
    let n_dpus = args.get_usize("dpus", 256)?;
    let threads = args.get_usize("threads", cpu::hw_threads())?;
    let out_path = args.get("out").unwrap_or("BENCH_coordinator.json");

    let base = generate::scale_free::<f64>(rows, rows, deg, 0.6, 7);
    let a = crate::apps::cg::spd_from(&base);
    let b = vec![1.0f64; a.nrows()];
    println!(
        "bench-coordinator: CG x{iters} on {}x{} ({} nnz), {n_dpus} DPUs, {threads} host threads",
        a.nrows(),
        a.ncols(),
        a.nnz()
    );
    let sys = PimSystem::new(PimConfig { n_dpus, ..Default::default() })?;
    let spec = KernelSpec::coo_nnz();
    // tol = 0 forces exactly `iters` SpMV iterations (no early exit), so
    // the two engines do identical work. Both services share one plan
    // cache, pre-warmed HERE for the matrix CG actually loads (the SPD
    // system `a`): the O(nnz) fingerprint + plan build stay outside both
    // timed regions, so neither engine's wall clock includes planning
    // and the serial/threaded comparison is symmetric.
    let cache = crate::util::sync::Arc::new(crate::coordinator::PlanCache::<f64>::new());
    cache.plan(&SpmvExecutor::new(sys.clone()), &spec, &a)?;
    let wall = |engine: Engine| -> Result<(f64, usize)> {
        let svc: SpmvService<f64> = ServiceBuilder::new()
            .engine(engine)
            .build_with_cache(sys.clone(), crate::util::sync::Arc::clone(&cache))?;
        let t0 = std::time::Instant::now();
        let r = crate::apps::cg::solve(&svc, &spec, &a, &b, 0.0, iters)?;
        let dt = t0.elapsed().as_secs_f64();
        println!("  {:<8} {:>8.3}s wall ({} iters)", engine_name(engine), dt, r.stats.iterations);
        Ok((dt, r.stats.iterations))
    };
    let (serial_s, iters_done) = wall(Engine::Serial)?;
    let (threaded_s, _) = wall(Engine::threaded(threads))?;
    let speedup = serial_s / threaded_s.max(1e-12);
    println!("  speedup  {speedup:>8.2}x (threaded vs serial)");

    use crate::util::json::{num, obj, s};
    let j = obj(vec![
        ("bench", s("coordinator_cg_plan_execute")),
        ("rows", num(a.nrows() as f64)),
        ("nnz", num(a.nnz() as f64)),
        ("iters", num(iters_done as f64)),
        ("dpus", num(n_dpus as f64)),
        ("host_threads", num(threads as f64)),
        ("host_cores", num(cpu::hw_threads() as f64)),
        ("serial_wall_s", num(serial_s)),
        ("threaded_wall_s", num(threaded_s)),
        ("speedup", num(speedup)),
    ]);
    std::fs::write(out_path, j.to_string() + "\n")
        .with_context(|| format!("write {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

fn engine_name(e: Engine) -> &'static str {
    use crate::coordinator::ExecutionEngine;
    e.name()
}

fn print_solve_stats(st: &crate::apps::SolveStats) {
    println!(
        "PIM cost: matrix-load {:.3} ms (once) + per-iter avg [load {:.3} | kernel {:.3} | retrieve {:.3} | merge {:.3}] ms, energy {:.2e} J",
        st.matrix_load_s * 1e3,
        st.pim.load_s / st.iterations.max(1) as f64 * 1e3,
        st.pim.kernel_s / st.iterations.max(1) as f64 * 1e3,
        st.pim.retrieve_s / st.iterations.max(1) as f64 * 1e3,
        st.pim.merge_s / st.iterations.max(1) as f64 * 1e3,
        st.energy_j
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_command_and_flags() {
        let a = Args::parse(
            ["run", "--kernel", "CSR.nnz", "--dpus", "64", "--full"].map(String::from),
        )
        .unwrap();
        assert_eq!(a.command, "run");
        assert_eq!(a.get("kernel"), Some("CSR.nnz"));
        assert_eq!(a.get_usize("dpus", 0).unwrap(), 64);
        assert!(a.get_bool("full"));
        assert_eq!(a.get_usize("missing", 9).unwrap(), 9);
    }

    #[test]
    fn parse_rejects_stray_positional() {
        assert!(Args::parse(["run", "oops"].map(String::from)).is_err());
        assert!(Args::parse(["--flag-first"].map(String::from)).is_err());
    }

    #[test]
    fn matrix_lookup() {
        assert!(matrix_by_name("mini-sf", 1).is_ok());
        assert!(matrix_by_name("sf-mid", 1).is_ok());
        assert!(matrix_by_name("nope", 1).is_err());
    }

    #[test]
    fn run_command_smoke() {
        let a = Args::parse(
            ["run", "--kernel", "COO.nnz", "--matrix", "mini-band", "--dpus", "8", "--dtype", "int32"]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();
    }

    #[test]
    fn kernels_command_smoke() {
        run(Args::parse(["kernels"].map(String::from)).unwrap()).unwrap();
    }

    #[test]
    fn run_command_batched_smoke() {
        let a = Args::parse(
            ["run", "--kernel", "CSR.nnz", "--matrix", "mini-band", "--dpus", "8", "--batch", "5"]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();
    }

    #[test]
    fn run_command_batched_with_fixed_block() {
        let a = Args::parse(
            ["run", "--kernel", "BCOO.nnz", "--matrix", "mini-band", "--dpus", "8",
             "--batch", "5", "--vector-block", "2"]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();
        // Bad block policies are rejected at parse time.
        let bad = Args::parse(
            ["run", "--kernel", "CSR.nnz", "--matrix", "mini-band", "--vector-block", "wide"]
                .map(String::from),
        )
        .unwrap();
        assert!(run(bad).is_err());
        let zero = Args::parse(
            ["run", "--kernel", "CSR.nnz", "--matrix", "mini-band", "--vector-block", "0"]
                .map(String::from),
        )
        .unwrap();
        assert!(run(zero).is_err());
    }

    #[test]
    fn serve_command_smoke() {
        let a = Args::parse(
            ["serve", "--matrix", "mini-band", "--dpus", "8", "--requests", "7", "--batch", "3",
             "--iters", "3", "--threads", "2", "--queue-depth", "2"]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();
    }

    #[test]
    fn serve_sharded_command_smoke() {
        let a = Args::parse(
            ["serve", "--matrix", "mini-band", "--dpus", "8", "--shards", "3", "--requests", "7",
             "--batch", "3", "--iters", "3", "--tenants", "alice:3,bob:1:4"]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();
        // A bad tenant spec is rejected.
        let bad = Args::parse(
            ["serve", "--matrix", "mini-band", "--shards", "2", "--tenants", "alice"]
                .map(String::from),
        )
        .unwrap();
        assert!(run(bad).is_err());
    }

    #[test]
    fn serve_grid_command_smoke() {
        // 2x2 grid with 2 replicas per tile, chaos on — every answer
        // still verifies against the host oracle inside serve().
        let a = Args::parse(
            ["serve", "--matrix", "mini-band", "--dpus", "8", "--grid", "2x2", "--replicas", "2",
             "--requests", "6", "--batch", "2", "--iters", "2", "--chaos-seed", "11"]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();
        // Malformed grid specs are rejected at parse time.
        for bad in ["4", "x2", "2x", "2xtwo", "0x2"] {
            let a = Args::parse(
                ["serve", "--matrix", "mini-band", "--grid", bad].map(String::from),
            )
            .unwrap();
            assert!(run(a).is_err(), "--grid {bad} must be rejected");
        }
        let a = Args::parse(
            ["serve", "--matrix", "mini-band", "--shards", "2", "--replicas", "0"]
                .map(String::from),
        )
        .unwrap();
        assert!(run(a).is_err(), "--replicas 0 must be rejected");
    }

    #[test]
    fn tune_then_calibrated_run_and_serve_smoke() {
        let dir = std::env::temp_dir().join("sparsep_cli_tune_test");
        std::fs::create_dir_all(&dir).unwrap();
        let table = dir.join("calibration_cli.json");
        let report = dir.join("BENCH_tune_cli.json");
        let a = Args::parse(
            ["tune", "--quick", "--dpus", "16", "--tasklets", "8", "--samples", "1",
             "--out", table.to_str().unwrap(), "--report", report.to_str().unwrap()]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();

        // `run` without --kernel auto-selects from the table just tuned.
        let a = Args::parse(
            ["run", "--matrix", "mini-band", "--dpus", "16", "--batch", "3",
             "--calibration", table.to_str().unwrap()]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();

        // Sharded serve with calibrated spec + automatic shard count.
        let a = Args::parse(
            ["serve", "--matrix", "mini-band", "--dpus", "8", "--shards", "auto",
             "--requests", "4", "--batch", "2", "--iters", "2",
             "--calibration", table.to_str().unwrap()]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();

        // A table that cannot load is a hard error, not a fallback.
        let bad = Args::parse(
            ["run", "--matrix", "mini-band", "--calibration", "/nonexistent/cal.json"]
                .map(String::from),
        )
        .unwrap();
        assert!(run(bad).is_err());
        std::fs::remove_file(&table).ok();
        std::fs::remove_file(&report).ok();
    }

    #[test]
    fn run_without_kernel_uses_the_heuristic() {
        let a = Args::parse(
            ["run", "--matrix", "mini-band", "--dpus", "8"].map(String::from),
        )
        .unwrap();
        run(a).unwrap();
    }

    #[test]
    fn solve_personalized_pagerank_smoke() {
        let a = Args::parse(
            ["solve", "--app", "pagerank", "--matrix", "mini-sf", "--dpus", "8", "--seeds", "0,3"]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();
        assert!(Args::parse(
            ["solve", "--app", "pagerank", "--matrix", "mini-sf", "--seeds", "zero"]
                .map(String::from)
        )
        .map(run)
        .unwrap()
        .is_err());
    }
}
