//! Sparse matrix substrate.
//!
//! SparseP supports the four most popular compressed formats — CSR, COO,
//! BCSR and BCOO — over six element types. This module provides those
//! formats, conversions between them, MatrixMarket I/O, synthetic matrix
//! generators matching the paper's two matrix classes (regular /
//! scale-free), and the sparsity statistics the paper's Table 2 reports.

pub mod dtype;
pub mod coo;
pub mod csr;
pub mod bcsr;
pub mod bcoo;
pub mod dense;
pub mod mtx;
pub mod generate;
pub mod stats;

pub use bcoo::BcooMatrix;
pub use bcsr::BcsrMatrix;
pub use coo::CooMatrix;
pub use csr::CsrMatrix;
pub use dtype::{DType, SpElem};
pub use stats::MatrixStats;

/// The four compressed formats of the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Format {
    Csr,
    Coo,
    Bcsr,
    Bcoo,
}

impl Format {
    pub fn name(self) -> &'static str {
        match self {
            Format::Csr => "CSR",
            Format::Coo => "COO",
            Format::Bcsr => "BCSR",
            Format::Bcoo => "BCOO",
        }
    }

    pub fn from_name(s: &str) -> Option<Format> {
        Some(match s.to_ascii_uppercase().as_str() {
            "CSR" => Format::Csr,
            "COO" => Format::Coo,
            "BCSR" => Format::Bcsr,
            "BCOO" => Format::Bcoo,
            _ => return None,
        })
    }

    /// Whether this is one of the block formats.
    pub fn is_blocked(self) -> bool {
        matches!(self, Format::Bcsr | Format::Bcoo)
    }

    pub fn all() -> [Format; 4] {
        [Format::Csr, Format::Coo, Format::Bcsr, Format::Bcoo]
    }
}

impl std::fmt::Display for Format {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_names_roundtrip() {
        for f in Format::all() {
            assert_eq!(Format::from_name(f.name()), Some(f));
        }
        assert_eq!(Format::from_name("csr"), Some(Format::Csr));
        assert_eq!(Format::from_name("ELL"), None);
    }

    #[test]
    fn blockedness() {
        assert!(!Format::Csr.is_blocked());
        assert!(Format::Bcoo.is_blocked());
    }
}
