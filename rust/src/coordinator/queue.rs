//! The pipelined request engine behind [`super::SpmvService`].
//!
//! SparseP on real hardware spends most of an SpMV's end-to-end time
//! moving data: the input-vector load and the output retrieve dominate
//! once the DPU count grows (the paper's broadcast wall), so a serving
//! system must overlap those phases across requests instead of running
//! each request's load -> kernel -> retrieve/merge sequence to
//! completion before starting the next. This module does that on the
//! host side of the simulator: three stage threads connected by
//! bounded, double-buffered hand-off channels,
//!
//! ```text
//!  submit -> [intake queue] -> prep/load -> kernel -> retrieve/merge -> wait
//!               (depth Q)      (stage 1)  (stage 2)     (stage 3)
//! ```
//!
//! * **Stage 1 — prep/load** pops one request at a time, splits its
//!   vectors into [`super::BlockPolicy`]-sized blocks (the per-request
//!   width was resolved at submit) and streams one message per block
//!   downstream — the host-side analogue of staging each block's input
//!   vectors for transfer.
//! * **Stage 2 — kernel** runs each block's per-DPU kernels through the
//!   service's [`super::Engine`] (one engine wave per block over the
//!   plan's work items).
//! * **Stage 3 — retrieve/merge** merges per-DPU partials into output
//!   vectors through the plan's merge metadata, prices the run, and
//!   publishes the assembled [`super::Response`] under its ticket.
//!
//! While stage 2 simulates block *k*'s kernels, stage 1 is already
//! preparing block *k+1* (possibly from the next queued request) and
//! stage 3 is merging block *k-1*: the pipeline overlaps work across
//! queued requests and across batch blocks. The inter-stage channels
//! are bounded at [`HANDOFF_DEPTH`] (double buffering — one message
//! being consumed, one ready), so a slow stage throttles its producer
//! instead of ballooning memory.
//!
//! **Determinism.** Stages are single threads connected by FIFO
//! channels, every per-(work-item, block) unit is computed by the same
//! pure kernel calls as the synchronous path, and merging happens in
//! block-then-vector order — so responses are bit-identical to
//! [`super::ExecutionPlan::execute`] / `execute_batch_runs` /
//! `run_iterations` on the same plan, regardless of engine, block
//! width, queue depth or how requests interleave. The
//! `tests/service_equivalence.rs` suite locks this in.
//!
//! Iterated requests ([`super::Request::Iterate`]) feed back: stage 3
//! returns each iteration's output vector to stage 1 over an unbounded
//! feedback channel, which emits the next iteration's blocks. Stage 1
//! waits on that feedback (an iteration depends on its predecessor), so
//! an iterate request serializes the *intake* while its in-flight
//! blocks still overlap across the three stages; queued requests behind
//! it wait their turn, preserving FIFO service order.
//!
//! **Zero-copy payloads.** Vector payloads flow through the pipeline as
//! the `Arc<[T]>`s the request carried: block messages clone references
//! into stage 2, never vector data. Iterate feedback is zero-copy too —
//! stage 3 moves each iteration's owned output vector to stage 1, which
//! feeds it to the next wave as-is; when that wave retires the buffer,
//! stage 1 returns it to stage 3's length-keyed pool over a recycle
//! channel, so a steady-state iterate ping-pongs two buffers with no
//! per-iteration allocation or copy at all.

use super::engine::ExecutionEngine;
use super::plan::{self, ExecutionPlan};
use super::service::Response;
use super::{BatchResult, Breakdown, IterationsResult, RunResult, SpmvExecutor};
use crate::format_err;
use crate::kernels::DpuKernelOutput;
use crate::matrix::SpElem;
use crate::pim::Energy;
use crate::util::Result;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use crate::util::sync::thread::{spawn_named, JoinHandle};
use crate::util::sync::{Arc, Condvar, Mutex};
use std::collections::{HashMap, HashSet};
use std::ops::Range;

/// Inter-stage hand-off depth: each channel between pipeline stages
/// holds this many in-flight block messages (double buffering: one
/// being consumed, one staged behind it).
pub const HANDOFF_DEPTH: usize = 2;

/// Default intake-queue depth of [`super::ServiceBuilder`]: how many
/// requests may sit between `submit` and stage 1 before `submit`
/// blocks (backpressure).
pub const DEFAULT_QUEUE_DEPTH: usize = 16;

/// What the submitted request's response should look like.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum ResponseKind {
    Spmv,
    Batch,
    Iterate,
}

/// One queued request, normalized: every kind is (vectors, iterations).
/// Payloads arrive as the `Arc<[T]>`s the [`super::Request`] carried —
/// the pipeline never copies vector data, it only clones references.
pub(crate) struct Job<T: SpElem> {
    pub ticket: u64,
    pub plan: Arc<ExecutionPlan<T>>,
    /// Input vectors (exactly one for `Spmv` and `Iterate`).
    pub xs: Vec<Arc<[T]>>,
    /// Self-application count (1 for `Spmv` / `Batch`).
    pub iters: usize,
    /// Resolved vector-block width for this request.
    pub block: usize,
    pub kind: ResponseKind,
}

/// Wave bookkeeping carried alongside every block message (a *wave* is
/// one iteration of one ticket).
#[derive(Clone, Copy, Debug)]
struct WaveInfo {
    kind: ResponseKind,
    n_blocks: usize,
    block_index: usize,
    iter_index: usize,
    iters_total: usize,
}

/// The vector set one wave reads: the request's shared payload slices,
/// or — for iterate feedback — the previous iteration's owned output,
/// moved through the pipeline without copying its data (wrapping a
/// `Vec<T>` in `Arc<Vec<T>>` moves three words, not the buffer).
enum WaveXs<T: SpElem> {
    /// Request payloads as submitted (`Arc` clones, never copies).
    Shared(Arc<Vec<Arc<[T]>>>),
    /// One iterate-feedback vector (iterations are single-vector).
    Fed(Arc<Vec<T>>),
}

impl<T: SpElem> WaveXs<T> {
    fn len(&self) -> usize {
        match self {
            WaveXs::Shared(v) => v.len(),
            WaveXs::Fed(_) => 1,
        }
    }

    /// Vector `i` of the wave, as a slice.
    fn window(&self, i: usize) -> &[T] {
        match self {
            WaveXs::Shared(v) => &v[i][..],
            WaveXs::Fed(v) => {
                debug_assert_eq!(i, 0, "feedback waves hold exactly one vector");
                &v[..]
            }
        }
    }
}

impl<T: SpElem> Clone for WaveXs<T> {
    fn clone(&self) -> WaveXs<T> {
        match self {
            WaveXs::Shared(v) => WaveXs::Shared(Arc::clone(v)),
            WaveXs::Fed(v) => WaveXs::Fed(Arc::clone(v)),
        }
    }
}

/// Stage 1 -> stage 2: one vector block to run kernels for. `xs` is the
/// whole wave's vector set (shared, not copied); `blk` selects this
/// message's block.
struct BlockMsg<T: SpElem> {
    ticket: u64,
    plan: Arc<ExecutionPlan<T>>,
    xs: WaveXs<T>,
    blk: Range<usize>,
    wave: WaveInfo,
}

/// Stage 2 -> stage 3: the block's raw per-DPU outputs, indexed
/// `[work_item][vector_in_block]`.
struct MergeMsg<T: SpElem> {
    ticket: u64,
    plan: Arc<ExecutionPlan<T>>,
    wave: WaveInfo,
    outputs: Vec<Vec<DpuKernelOutput<T>>>,
}

/// Ticket completion store: `submit` registers, stage 3 publishes,
/// `wait` claims. One mutex guards both maps so a ticket can never be
/// claimed twice or waited on after being claimed.
///
/// `pub(crate)` because [`super::shard::ShardedService`]'s dispatcher /
/// gather pair reuses exactly this store for its own tickets.
pub(crate) struct Completions<T: SpElem> {
    state: Mutex<CompState<T>>,
    ready: Condvar,
    submitted: AtomicU64,
    completed: AtomicU64,
}

struct CompState<T: SpElem> {
    /// Tickets issued and not yet claimed by a `wait`.
    pending: HashSet<u64>,
    /// Published responses awaiting their `wait`.
    done: HashMap<u64, Result<Response<T>>>,
}

impl<T: SpElem> Completions<T> {
    pub(crate) fn new() -> Completions<T> {
        Completions {
            state: Mutex::new(CompState { pending: HashSet::new(), done: HashMap::new() }),
            ready: Condvar::new(),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
        }
    }

    pub(crate) fn register(&self, ticket: u64) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.state.lock().expect("completion store poisoned").pending.insert(ticket);
    }

    pub(crate) fn publish(&self, ticket: u64, resp: Result<Response<T>>) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.state.lock().expect("completion store poisoned").done.insert(ticket, resp);
        self.ready.notify_all();
    }

    /// Non-blocking claim: `Ok(Some)` when the response is ready,
    /// `Ok(None)` when the ticket is registered but still in flight,
    /// `Err` for unknown / already-claimed tickets.
    pub(crate) fn try_claim(&self, ticket: u64) -> Result<Option<Response<T>>> {
        let mut state = self.state.lock().expect("completion store poisoned");
        if let Some(resp) = state.done.remove(&ticket) {
            state.pending.remove(&ticket);
            return resp.map(Some);
        }
        if state.pending.contains(&ticket) {
            return Ok(None);
        }
        Err(format_err!(
            "unknown ticket {ticket} (never submitted here, or already waited on)"
        ))
    }

    pub(crate) fn wait(&self, ticket: u64) -> Result<Response<T>> {
        let mut state = self.state.lock().expect("completion store poisoned");
        loop {
            if let Some(resp) = state.done.remove(&ticket) {
                state.pending.remove(&ticket);
                return resp;
            }
            if !state.pending.contains(&ticket) {
                return Err(format_err!(
                    "unknown ticket {ticket} (never submitted here, or already waited on)"
                ));
            }
            state = self.ready.wait(state).expect("completion store poisoned");
        }
    }

    /// Bounded [`Completions::wait`]: blocks at most `timeout`, then
    /// returns a typed [`crate::util::ErrorKind::ShardTimeout`] error
    /// instead of hanging on a wedged publisher. The ticket stays
    /// registered — a later `wait`/`try_wait` can still claim the
    /// response if it eventually arrives.
    #[cfg(not(loom))]
    pub(crate) fn wait_timeout(
        &self,
        ticket: u64,
        timeout: std::time::Duration,
    ) -> Result<Response<T>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut state = self.state.lock().expect("completion store poisoned");
        loop {
            if let Some(resp) = state.done.remove(&ticket) {
                state.pending.remove(&ticket);
                return resp;
            }
            if !state.pending.contains(&ticket) {
                return Err(format_err!(
                    "unknown ticket {ticket} (never submitted here, or already waited on)"
                ));
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(crate::util::Error::shard_timeout(
                    None,
                    format!("ticket {ticket} not completed within {timeout:?}"),
                ));
            }
            let (st, _) = self
                .ready
                .wait_timeout(state, deadline - now)
                .expect("completion store poisoned");
            state = st;
        }
    }

    /// Loom twin of `wait_timeout`: loom's condvar has no virtual clock —
    /// its `wait_timeout` nondeterministically explores the timed-out
    /// branch instead of measuring time. Treat any timed-out wake as
    /// deadline expiry, but only after one final claim re-check so a
    /// publish that raced the "timeout" is never lost (the property the
    /// model in rust/tests/loom_models.rs asserts).
    #[cfg(loom)]
    pub(crate) fn wait_timeout(
        &self,
        ticket: u64,
        timeout: std::time::Duration,
    ) -> Result<Response<T>> {
        let mut state = self.state.lock().expect("completion store poisoned");
        loop {
            if let Some(resp) = state.done.remove(&ticket) {
                state.pending.remove(&ticket);
                return resp;
            }
            if !state.pending.contains(&ticket) {
                return Err(format_err!(
                    "unknown ticket {ticket} (never submitted here, or already waited on)"
                ));
            }
            let (st, res) = self
                .ready
                .wait_timeout(state, timeout)
                .expect("completion store poisoned");
            state = st;
            if res.timed_out() {
                // Final re-check under the lock: a publish that landed
                // between the wake and this point must win over the
                // timeout error.
                if let Some(resp) = state.done.remove(&ticket) {
                    state.pending.remove(&ticket);
                    return resp;
                }
                return Err(crate::util::Error::shard_timeout(
                    None,
                    format!("ticket {ticket} not completed within {timeout:?}"),
                ));
            }
        }
    }

    /// Claim *any* published response, blocking at most `timeout`:
    /// `Some((ticket, response))` as soon as one is available, `None`
    /// on expiry. This is the backbone of completion-dispatch front
    /// ends (one thread drains every ticket's completion the moment
    /// `publish` lands — no per-ticket poll loops): `publish`'s
    /// `notify_all` wakes this wait directly. Only meaningful when the
    /// caller is the store's sole waiter — a concurrent per-ticket
    /// `wait` could otherwise lose its response to this claim.
    #[cfg(not(loom))]
    pub(crate) fn claim_next_timeout(
        &self,
        timeout: std::time::Duration,
    ) -> Option<(u64, Result<Response<T>>)> {
        let deadline = std::time::Instant::now() + timeout;
        let mut state = self.state.lock().expect("completion store poisoned");
        loop {
            if let Some(&ticket) = state.done.keys().next() {
                let resp = state.done.remove(&ticket).expect("key observed under the lock");
                state.pending.remove(&ticket);
                return Some((ticket, resp));
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (st, _) = self
                .ready
                .wait_timeout(state, deadline - now)
                .expect("completion store poisoned");
            state = st;
        }
    }

    /// Loom twin of `claim_next_timeout` (see [`Completions::wait_timeout`]):
    /// loom's condvar explores the timed-out branch nondeterministically,
    /// so any timed-out wake counts as expiry after one final re-check
    /// under the lock (a racing publish is never lost).
    #[cfg(loom)]
    pub(crate) fn claim_next_timeout(
        &self,
        timeout: std::time::Duration,
    ) -> Option<(u64, Result<Response<T>>)> {
        let mut state = self.state.lock().expect("completion store poisoned");
        loop {
            if let Some(&ticket) = state.done.keys().next() {
                let resp = state.done.remove(&ticket).expect("key observed under the lock");
                state.pending.remove(&ticket);
                return Some((ticket, resp));
            }
            let (st, res) = self
                .ready
                .wait_timeout(state, timeout)
                .expect("completion store poisoned");
            state = st;
            if res.timed_out() {
                if let Some(&ticket) = state.done.keys().next() {
                    let resp = state.done.remove(&ticket).expect("key observed under the lock");
                    state.pending.remove(&ticket);
                    return Some((ticket, resp));
                }
                return None;
            }
        }
    }

    /// Tickets registered since construction.
    pub(crate) fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Responses published since construction.
    pub(crate) fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Fail every registered ticket that has no response yet (a pipeline
    /// stage died: nothing will ever publish them). Published-but-
    /// unclaimed responses are left intact for their `wait`.
    pub(crate) fn fail_all_unanswered(&self, why: &str) {
        let mut state = self.state.lock().expect("completion store poisoned");
        let orphans: Vec<u64> = state
            .pending
            .iter()
            .copied()
            .filter(|t| !state.done.contains_key(t))
            .collect();
        for t in orphans {
            state.done.insert(t, Err(format_err!("{why}")));
            self.completed.fetch_add(1, Ordering::Relaxed);
        }
        drop(state);
        self.ready.notify_all();
    }
}

/// Failsafe carried by every stage thread: if the stage unwinds
/// (panics), fail all unanswered tickets so `wait` errors loudly
/// instead of blocking forever on a response nobody will publish.
/// (`pub(crate)`: the sharded facade's dispatcher/gather threads carry
/// the same guard over their shared [`Completions`] store.)
pub(crate) struct StageGuard<T: SpElem> {
    pub(crate) comp: Arc<Completions<T>>,
    pub(crate) stage: &'static str,
}

impl<T: SpElem> Drop for StageGuard<T> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.comp.fail_all_unanswered(&format!(
                "request pipeline {} stage panicked",
                self.stage
            ));
        }
    }
}

/// The request queue [`super::SpmvService`] owns: intake channel,
/// pipeline stage threads, and the completion store.
pub(crate) struct RequestQueue<T: SpElem> {
    /// `None` only during drop (taking it closes the intake).
    intake: Option<SyncSender<Job<T>>>,
    completions: Arc<Completions<T>>,
    handles: Vec<JoinHandle<()>>,
}

impl<T: SpElem> RequestQueue<T> {
    /// Spawn the three pipeline stages for `exec` with an intake queue
    /// of `queue_depth` requests.
    pub(crate) fn spawn(exec: SpmvExecutor, queue_depth: usize) -> RequestQueue<T> {
        let (tx_in, rx_in) = sync_channel::<Job<T>>(queue_depth.max(1));
        let (tx_blk, rx_blk) = sync_channel::<BlockMsg<T>>(HANDOFF_DEPTH);
        let (tx_mrg, rx_mrg) = sync_channel::<MergeMsg<T>>(HANDOFF_DEPTH);
        let (tx_fb, rx_fb) = channel::<Vec<T>>();
        // Buffer-return loop: stage 1 sends retired iterate payloads
        // back to stage 3's pool, so a steady-state iterate ping-pongs
        // two buffers with no allocation at all.
        let (tx_rec, rx_rec) = channel::<Vec<T>>();
        let completions = Arc::new(Completions::new());

        let comp1 = Arc::clone(&completions);
        let h1 = spawn_named("spmv-svc-prep", move || {
            let _failsafe = StageGuard { comp: Arc::clone(&comp1), stage: "prep" };
            stage_prep(rx_in, tx_blk, rx_fb, tx_rec, comp1)
        });
        let exec2 = exec.clone();
        let comp2 = Arc::clone(&completions);
        let h2 = spawn_named("spmv-svc-kernel", move || {
            let _failsafe = StageGuard { comp: comp2, stage: "kernel" };
            stage_kernel(exec2, rx_blk, tx_mrg)
        });
        let comp3 = Arc::clone(&completions);
        let h3 = spawn_named("spmv-svc-merge", move || {
            let _failsafe = StageGuard { comp: Arc::clone(&comp3), stage: "merge" };
            stage_merge(exec, rx_mrg, tx_fb, rx_rec, comp3)
        });

        RequestQueue { intake: Some(tx_in), completions, handles: vec![h1, h2, h3] }
    }

    /// Issue a ticket id into the completion store (before enqueueing
    /// its job, so a fast pipeline can never publish an unregistered
    /// ticket).
    pub(crate) fn register(&self, ticket: u64) {
        self.completions.register(ticket);
    }

    /// Publish a response directly, bypassing the pipeline (trivial
    /// requests like an empty batch).
    pub(crate) fn publish_direct(&self, ticket: u64, resp: Result<Response<T>>) {
        self.completions.publish(ticket, resp);
    }

    /// Retract a registered ticket that never made it into the pipeline
    /// (a failed `submit` returns an error instead of a ticket, so
    /// nothing could ever claim a parked response for it).
    pub(crate) fn cancel(&self, ticket: u64) {
        let mut state = self.completions.state.lock().expect("completion store poisoned");
        state.pending.remove(&ticket);
        state.done.remove(&ticket);
        // The request was never accepted: keep submitted == completed +
        // in-flight truthful.
        self.completions.submitted.fetch_sub(1, Ordering::Relaxed);
    }

    /// Enqueue a job; blocks while the intake queue is at capacity
    /// (backpressure toward submitters).
    pub(crate) fn submit(&self, job: Job<T>) -> Result<()> {
        let ticket = job.ticket;
        match self.intake.as_ref().expect("request queue already closed").send(job) {
            Ok(()) => Ok(()),
            Err(_) => {
                // Pipeline stage died. The caller gets an Err instead of
                // a ticket, so retract the registration entirely — a
                // parked error response could never be claimed.
                self.cancel(ticket);
                Err(format_err!("request pipeline is down"))
            }
        }
    }

    /// Block until `ticket`'s response is published, then claim it.
    pub(crate) fn wait(&self, ticket: u64) -> Result<Response<T>> {
        self.completions.wait(ticket)
    }

    /// Bounded wait (see [`Completions::wait_timeout`]).
    pub(crate) fn wait_timeout(
        &self,
        ticket: u64,
        timeout: std::time::Duration,
    ) -> Result<Response<T>> {
        self.completions.wait_timeout(ticket, timeout)
    }

    /// Non-blocking poll for `ticket`'s response (see
    /// [`Completions::try_claim`]).
    pub(crate) fn try_wait(&self, ticket: u64) -> Result<Option<Response<T>>> {
        self.completions.try_claim(ticket)
    }

    pub(crate) fn submitted(&self) -> u64 {
        self.completions.submitted.load(Ordering::Relaxed)
    }

    pub(crate) fn completed(&self) -> u64 {
        self.completions.completed.load(Ordering::Relaxed)
    }
}

impl<T: SpElem> Drop for RequestQueue<T> {
    fn drop(&mut self) {
        // Closing the intake lets stage 1 drain remaining queued jobs
        // and exit; the close then cascades down the stage channels.
        self.intake.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Stage 1: normalize each job into per-iteration waves of vector
/// blocks. For iterated jobs, wait for stage 3's feedback (the previous
/// iteration's output) before emitting the next wave.
fn stage_prep<T: SpElem>(
    rx_in: Receiver<Job<T>>,
    tx_blk: SyncSender<BlockMsg<T>>,
    rx_fb: Receiver<Vec<T>>,
    tx_rec: Sender<Vec<T>>,
    comp: Arc<Completions<T>>,
) {
    // Retire an iterate payload: if stage 2 has dropped its block
    // clones, the buffer flows back to the merge stage's pool instead
    // of being freed (send errors just mean stage 3 is shutting down).
    let recycle = |xs: WaveXs<T>| {
        if let WaveXs::Fed(arc) = xs {
            if let Ok(buf) = Arc::try_unwrap(arc) {
                let _ = tx_rec.send(buf);
            }
        }
    };
    while let Ok(job) = rx_in.recv() {
        let Job { ticket, plan, xs, iters, block, kind } = job;
        debug_assert!(!xs.is_empty(), "empty batches resolve at submit");
        let mut xs = WaveXs::Shared(Arc::new(xs));
        let mut alive = true;
        'iterations: for iter in 0..iters {
            let n = xs.len();
            let blocks: Vec<Range<usize>> =
                (0..n).step_by(block.max(1)).map(|s| s..(s + block.max(1)).min(n)).collect();
            let n_blocks = blocks.len();
            for (bi, blk) in blocks.into_iter().enumerate() {
                let msg = BlockMsg {
                    ticket,
                    plan: Arc::clone(&plan),
                    xs: xs.clone(),
                    blk,
                    wave: WaveInfo {
                        kind,
                        n_blocks,
                        block_index: bi,
                        iter_index: iter,
                        iters_total: iters,
                    },
                };
                if tx_blk.send(msg).is_err() {
                    alive = false;
                    break 'iterations;
                }
            }
            if iter + 1 < iters {
                match rx_fb.recv() {
                    // Zero-copy feedback: the iteration's owned output
                    // becomes the next wave's input without touching the
                    // buffer; the retired previous input goes back to
                    // the pool.
                    Ok(y) => recycle(std::mem::replace(&mut xs, WaveXs::Fed(Arc::new(y)))),
                    Err(_) => {
                        alive = false;
                        break 'iterations;
                    }
                }
            }
        }
        recycle(xs);
        if !alive {
            comp.publish(ticket, Err(format_err!("request pipeline shut down mid-request")));
            // Downstream stages are gone. Fail everything already queued
            // (and anything submitted from now on) so no wait() hangs;
            // this loop ends when the service drops the intake sender.
            while let Ok(dead) = rx_in.recv() {
                comp.publish(
                    dead.ticket,
                    Err(format_err!("request pipeline went down before this request ran")),
                );
            }
            return;
        }
    }
}

/// Stage 2: one engine wave per block over the plan's work items. The
/// per-(item, block) computation is exactly the synchronous path's
/// [`plan::run_item_batch`], so outputs are bit-identical by
/// construction.
fn stage_kernel<T: SpElem>(
    exec: SpmvExecutor,
    rx_blk: Receiver<BlockMsg<T>>,
    tx_mrg: SyncSender<MergeMsg<T>>,
) {
    while let Ok(BlockMsg { ticket, plan, xs, blk, wave }) = rx_blk.recv() {
        let cfg = &exec.sys.cfg;
        let windows: Vec<&[T]> = blk.map(|i| xs.window(i)).collect();
        let items = plan.items();
        let outputs: Vec<Vec<DpuKernelOutput<T>>> = exec
            .engine
            .map_indexed(items.len(), |i| {
                plan::run_item_batch(cfg, &plan.spec, &items[i], &windows)
            });
        if tx_mrg.send(MergeMsg { ticket, plan, wave, outputs }).is_err() {
            return;
        }
    }
}

/// How many spare buffers [`BufferPool`] keeps per output length.
pub(crate) const BUFFER_POOL_PER_LEN: usize = 8;

/// How many distinct output lengths [`BufferPool`] retains at once. A
/// long-lived service sees a new length per distinct matrix row count
/// (load/unload churn, multi-tenant); without this cap the pool would
/// pin up to [`BUFFER_POOL_PER_LEN`] dead buffers per length forever.
pub(crate) const BUFFER_POOL_LENS: usize = 8;

/// Free-list of merge-output buffers keyed by length, local to the
/// merge stage (single-threaded: no locks). Iterate payloads are the
/// only buffers that die inside the pipeline: an iteration's output is
/// moved (never copied) to stage 1 as the next wave's input, and once
/// that wave retires it, stage 1 returns the buffer over the recycle
/// channel — the next iteration's merge takes it back zeroed. A
/// steady-state iterate therefore ping-pongs two `nrows`-sized buffers
/// with no allocation per iteration. Keying is by vector length: one
/// request's batch width only decides how many same-length buffers are
/// in flight at once, which the per-length cap bounds.
/// (`pub(crate)` so the loom model in [`super::verify`] can drive the
/// stage-1 ↔ stage-3 recycle protocol against the real pool, and so the
/// network front end ([`crate::net`]) can recycle its byte buffers
/// through the same free-list. The element bound is `Copy`, not
/// [`SpElem`], for exactly that reason — the "zero" fill value is
/// stored at construction instead of coming from the element trait.)
pub(crate) struct BufferPool<T: Copy> {
    free: HashMap<usize, Vec<Vec<T>>>,
    zero: T,
}

impl<T: Copy> BufferPool<T> {
    pub(crate) fn new(zero: T) -> BufferPool<T> {
        BufferPool { free: HashMap::new(), zero }
    }

    /// A zeroed buffer of `len` elements, recycled when available.
    pub(crate) fn take_zeroed(&mut self, len: usize) -> Vec<T> {
        match self.free.get_mut(&len).and_then(Vec::pop) {
            Some(mut buf) => {
                buf.fill(self.zero);
                buf
            }
            None => vec![self.zero; len],
        }
    }

    /// Return a dead buffer for reuse (bounded: at most
    /// [`BUFFER_POOL_PER_LEN`] buffers for each of at most
    /// [`BUFFER_POOL_LENS`] distinct lengths; anything beyond is simply
    /// dropped, so the pool's footprint cannot grow with the number of
    /// matrix shapes a long-lived service ever iterates).
    pub(crate) fn put(&mut self, buf: Vec<T>) {
        let len = buf.len();
        if let Some(list) = self.free.get_mut(&len) {
            if list.len() < BUFFER_POOL_PER_LEN {
                list.push(buf);
            }
        } else if self.free.len() < BUFFER_POOL_LENS {
            // Evict empty per-length lists before refusing a new length
            // (take() drains lists; a dead length must not squat a slot).
            self.free.retain(|_, list| !list.is_empty());
            if self.free.len() < BUFFER_POOL_LENS {
                self.free.insert(len, vec![buf]);
            }
        }
    }
}

/// Stage 3: merge per-DPU partials vector by vector, accumulate
/// iteration totals, feed iterate outputs back to stage 1, and publish
/// completed responses. Waves of one ticket arrive contiguously (the
/// stages are FIFO), so a little local state suffices.
fn stage_merge<T: SpElem>(
    exec: SpmvExecutor,
    rx_mrg: Receiver<MergeMsg<T>>,
    tx_fb: Sender<Vec<T>>,
    rx_rec: Receiver<Vec<T>>,
    comp: Arc<Completions<T>>,
) {
    let mut runs: Vec<RunResult<T>> = Vec::new();
    let mut total = Breakdown::default();
    let mut energy = Energy::default();
    let mut pool: BufferPool<T> = BufferPool::new(T::zero());
    while let Ok(MergeMsg { ticket, plan, wave, outputs }) = rx_mrg.recv() {
        // Collect buffers stage 1 retired since the last merge (iterate
        // payloads whose wave finished): the pool hands them back below.
        while let Ok(buf) = rx_rec.try_recv() {
            pool.put(buf);
        }
        if wave.block_index == 0 && wave.iter_index == 0 {
            runs.clear();
            total = Breakdown::default();
            energy = Energy::default();
        }
        // outputs[item][vec]: regroup by vector through the same
        // per-plan merge as the synchronous path, in vector order.
        let blk_len = outputs.first().map_or(0, |o| o.len());
        let mut per_item: Vec<std::vec::IntoIter<DpuKernelOutput<T>>> =
            outputs.into_iter().map(|o| o.into_iter()).collect();
        for _ in 0..blk_len {
            let outs: Vec<DpuKernelOutput<T>> = per_item
                .iter_mut()
                .map(|it| it.next().expect("batched kernel returned too few outputs"))
                .collect();
            let mut y = pool.take_zeroed(plan.nrows());
            plan.merge_partials_into(&outs, &mut y);
            runs.push(exec.finish(&plan, &outs, y));
        }
        if wave.block_index + 1 != wave.n_blocks {
            continue; // wave still streaming in
        }
        match wave.kind {
            ResponseKind::Spmv => {
                let run = runs.pop().expect("spmv wave produced no run");
                runs.clear();
                comp.publish(ticket, Ok(Response::Spmv(run)));
            }
            ResponseKind::Batch => {
                comp.publish(ticket, Ok(Response::Batch(BatchResult { runs: std::mem::take(&mut runs) })));
            }
            ResponseKind::Iterate => {
                // Same accumulation sequence as the synchronous
                // run_iterations: totals per iteration, in order.
                for r in &runs {
                    total.accumulate(&r.breakdown);
                    energy = energy.add(r.energy);
                }
                let last = runs.pop().expect("iterate wave produced no run");
                runs.clear();
                if wave.iter_index + 1 < wave.iters_total {
                    // Zero-copy feedback: move the owned output vector
                    // to stage 1 — it becomes the next wave's input
                    // without copying, and comes back through the
                    // recycle channel once that wave retires it.
                    if tx_fb.send(last.y).is_err() {
                        return; // stage 1 is gone; shutting down
                    }
                } else {
                    comp.publish(
                        ticket,
                        Ok(Response::Iterate(IterationsResult {
                            last,
                            total,
                            energy,
                            iters: wave.iters_total,
                        })),
                    );
                    total = Breakdown::default();
                    energy = Energy::default();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wait_timeout_returns_typed_error_instead_of_hanging() {
        // The infinite-block hazard fix: a registered ticket whose
        // publisher is wedged must come back as a typed ShardTimeout
        // within the bound, not hang the waiter forever.
        let comp: Completions<f64> = Completions::new();
        comp.register(7);
        let t0 = std::time::Instant::now();
        let e = comp.wait_timeout(7, std::time::Duration::from_millis(30)).unwrap_err();
        let waited = t0.elapsed();
        assert!(e.is_shard_timeout(), "kind must be ShardTimeout: {e}");
        assert_eq!(e.timed_out_shard(), None, "a bare store waiter knows no shard");
        assert!(e.to_string().contains("ticket 7"), "{e}");
        assert!(waited >= std::time::Duration::from_millis(30), "returned early: {waited:?}");
        assert!(
            waited < std::time::Duration::from_secs(10),
            "wildly overshot the bound: {waited:?}"
        );
        // The ticket survives the timeout: a late publish is claimable.
        comp.publish(7, Ok(Response::Spmv(RunResult {
            y: vec![1.0],
            breakdown: Breakdown::default(),
            stats: Default::default(),
            energy: Energy::default(),
        })));
        let r = comp.wait_timeout(7, std::time::Duration::from_millis(30)).unwrap();
        match r {
            Response::Spmv(run) => assert_eq!(run.y, vec![1.0]),
            other => panic!("unexpected response kind {:?}", other.kind()),
        }
        // Claimed: a second wait is the unknown-ticket error (not a
        // timeout), same contract as the unbounded wait.
        let e = comp.wait_timeout(7, std::time::Duration::from_millis(5)).unwrap_err();
        assert!(!e.is_shard_timeout());
        assert!(e.to_string().contains("unknown ticket"), "{e}");
    }

    #[test]
    fn wait_timeout_with_ready_response_returns_immediately() {
        let comp: Completions<f64> = Completions::new();
        comp.register(1);
        comp.publish(1, Err(format_err!("already failed")));
        let t0 = std::time::Instant::now();
        let e = comp.wait_timeout(1, std::time::Duration::from_secs(60)).unwrap_err();
        assert!(t0.elapsed() < std::time::Duration::from_secs(10), "must not sleep");
        assert_eq!(e.to_string(), "already failed");
    }

    #[test]
    fn notify_before_wait_is_never_missed() {
        // Missed-notify regression (paused-waiter shape): the publisher
        // fires notify_all while nobody is waiting yet — e.g. a paused
        // scheduler thread that only reaches wait_timeout after its
        // ticket already completed. Because the condvar wait is
        // predicate-guarded (the done-map is checked under the lock
        // BEFORE the first wait and after every wake), the stale notify
        // is irrelevant: the waiter must claim immediately rather than
        // block for the full bound.
        let comp: Completions<f64> = Completions::new();
        comp.register(3);
        comp.publish(3, Ok(Response::Spmv(RunResult {
            y: vec![2.5],
            breakdown: Breakdown::default(),
            stats: Default::default(),
            energy: Energy::default(),
        })));
        // The notify above is long gone by the time this waiter arrives.
        let t0 = std::time::Instant::now();
        let r = comp.wait_timeout(3, std::time::Duration::from_secs(60)).unwrap();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(10),
            "predicate-guarded wait must claim a pre-published response without sleeping"
        );
        match r {
            Response::Spmv(run) => assert_eq!(run.y, vec![2.5]),
            other => panic!("unexpected response kind {:?}", other.kind()),
        }
    }

    #[test]
    fn foreign_publish_wakes_but_does_not_satisfy_the_predicate() {
        // The condvar is shared by every ticket, so a publish for ticket
        // A wakes a waiter on ticket B. Predicate guarding means that
        // wake must neither mis-claim A's response nor end B's wait
        // early: B still times out with the typed error, and A's
        // response stays claimable afterwards.
        let comp: Arc<Completions<f64>> = Arc::new(Completions::new());
        comp.register(1);
        comp.register(2);
        let c2 = Arc::clone(&comp);
        let publisher = spawn_named("test-foreign-publish", move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            c2.publish(1, Ok(Response::Spmv(RunResult {
                y: vec![9.0],
                breakdown: Breakdown::default(),
                stats: Default::default(),
                energy: Energy::default(),
            })));
        });
        let e = comp.wait_timeout(2, std::time::Duration::from_millis(120)).unwrap_err();
        assert!(e.is_shard_timeout(), "foreign wake must not end the wait early: {e}");
        publisher.join().expect("publisher thread panicked");
        // Ticket 1's response survived the foreign waiter untouched.
        match comp.try_claim(1).unwrap() {
            Some(Response::Spmv(run)) => assert_eq!(run.y, vec![9.0]),
            Some(other) => panic!("ticket 1 wrong response kind {:?}", other.kind()),
            None => panic!("ticket 1 response lost"),
        }
    }

    #[test]
    fn publish_racing_an_active_waiter_is_claimed_not_dropped() {
        // Live-race shape of the missed-notify regression: the waiter is
        // already parked in wait_timeout when the publish lands. The
        // publish inserts under the same mutex the waiter holds across
        // its predicate check, so there is no window where the notify
        // can fire between check and park — the waiter must claim the
        // response well inside the (generous) bound.
        for _ in 0..16 {
            let comp: Arc<Completions<f64>> = Arc::new(Completions::new());
            comp.register(5);
            let c2 = Arc::clone(&comp);
            let publisher = spawn_named("test-racing-publish", move || {
                c2.publish(5, Ok(Response::Spmv(RunResult {
                    y: vec![4.0],
                    breakdown: Breakdown::default(),
                    stats: Default::default(),
                    energy: Energy::default(),
                })));
            });
            let r = comp.wait_timeout(5, std::time::Duration::from_secs(60)).unwrap();
            match r {
                Response::Spmv(run) => assert_eq!(run.y, vec![4.0]),
                other => panic!("unexpected response kind {:?}", other.kind()),
            }
            publisher.join().expect("publisher thread panicked");
        }
    }

    #[test]
    fn fed_wave_moves_the_buffer_without_copying() {
        // The iterate-feedback zero-copy lock: wrapping an owned output
        // into a Fed wave must reuse the exact heap buffer (Arc<Vec<T>>
        // moves the Vec header, never the data), reads must see it, and
        // retiring a uniquely-owned Fed must hand the SAME buffer back
        // for recycling.
        let y: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let ptr = y.as_ptr();
        let xs: WaveXs<f64> = WaveXs::Fed(Arc::new(y));
        assert_eq!(xs.len(), 1);
        assert_eq!(xs.window(0).as_ptr(), ptr, "feedback wrap must not copy the buffer");
        // Block-message clones share; once they drop, the buffer is
        // uniquely owned again and unwraps to the original allocation.
        let block_clone = xs.clone();
        drop(block_clone);
        match xs {
            WaveXs::Fed(arc) => {
                let back = Arc::try_unwrap(arc).expect("uniquely owned after clones drop");
                assert_eq!(back.as_ptr(), ptr, "recycled buffer is the original allocation");
            }
            WaveXs::Shared(_) => unreachable!(),
        }
    }

    #[test]
    fn buffer_pool_recycles_zeroed_and_stays_bounded() {
        let mut pool: BufferPool<f64> = BufferPool::new(0.0);
        let buf = vec![7.0f64; 32];
        let ptr = buf.as_ptr();
        pool.put(buf);
        let back = pool.take_zeroed(32);
        assert_eq!(back.as_ptr(), ptr, "same-length take must reuse the recycled buffer");
        assert!(back.iter().all(|&v| v == 0.0), "recycled buffers come back zeroed");
        // Unknown lengths allocate fresh.
        assert_eq!(pool.take_zeroed(5).len(), 5);
        // Retention is bounded in both dimensions: per length and in
        // distinct lengths.
        for round in 0..3 {
            for len in 1..=4 * BUFFER_POOL_LENS {
                pool.put(vec![round as f64; len]);
            }
        }
        assert!(pool.free.len() <= BUFFER_POOL_LENS, "distinct-length cap breached");
        assert!(
            pool.free.values().all(|l| l.len() <= BUFFER_POOL_PER_LEN),
            "per-length cap breached"
        );
    }
}
