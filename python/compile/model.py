"""Layer 2: the JAX compute graph around the Pallas kernels.

The functions here are what `aot.py` lowers to HLO text for the Rust
runtime. Besides the raw SpMV entry points they include the two small
"applications" of SpMV the paper's introduction motivates — a power-
iteration step (PageRank-style graph analytics) and a CG-style residual
update (scientific computing) — so the AOT path exercises SpMV *composed
into* a larger graph, not just standalone.
"""

import jax.numpy as jnp

from compile.kernels.bell_spmv import bell_spmv
from compile.kernels.ell_spmv import ell_spmv


def spmv_ell(vals, cols, x):
    """y = A @ x, A in padded ELL layout (Pallas kernel inside)."""
    return (ell_spmv(vals, cols, x),)


def spmv_bell(vals, cols, x):
    """y = A @ x, A in block-ELL layout (Pallas kernel inside)."""
    return (bell_spmv(vals, cols, x),)


def spmv_dense(a, x):
    """Dense mat-vec baseline (the 'GPU library' comparison path)."""
    return (a @ x,)


def power_iteration_step(vals, cols, x):
    """One PageRank-flavoured power-iteration step: normalize(A @ x).

    Exercises SpMV composed with elementwise + reduction ops in a single
    lowered module, matching how graph-analytics workloads consume SpMV.
    """
    y = ell_spmv(vals, cols, x)
    norm = jnp.sqrt(jnp.sum(y * y)) + jnp.asarray(1e-12, y.dtype)
    return (y / norm,)


def cg_residual_step(vals, cols, x, b):
    """CG-style residual: r = b - A @ x, plus its squared norm.

    The scientific-computing shape: SpMV + axpy + dot in one graph.
    """
    r = b - ell_spmv(vals, cols, x)
    return (r, jnp.sum(r * r))
