//! BCSR (block compressed sparse row) format.
//!
//! The matrix is tiled into dense `R x C` blocks; only blocks containing
//! at least one non-zero are stored, each as a dense `R*C` value tile.
//! Index overhead is amortized over a whole block (one column index per
//! block instead of per element) at the cost of storing explicit zeros
//! inside blocks. The paper uses BCSR/BCOO because the small dense tiles
//! fit the DPU's WRAM nicely and cut DRAM traffic for matrices with block
//! structure — the same reason our Pallas `bell_spmv` kernel feeds dense
//! blocks to the MXU (see DESIGN.md §Hardware-Adaptation).

use super::coo::CooMatrix;
use super::dtype::SpElem;

/// A sparse matrix in BCSR format with runtime-chosen block shape.
#[derive(Clone, Debug, PartialEq)]
pub struct BcsrMatrix<T: SpElem> {
    nrows: usize,
    ncols: usize,
    /// Block height (rows per block).
    pub br: usize,
    /// Block width (cols per block).
    pub bc: usize,
    /// `block_row_ptr[i]..block_row_ptr[i+1]` indexes the blocks of block
    /// row `i` (there are `ceil(nrows/br)` block rows).
    pub block_row_ptr: Vec<u32>,
    /// Block-column index of each stored block.
    pub block_cols: Vec<u32>,
    /// Dense block values, row-major within each `br*bc` block.
    pub vals: Vec<T>,
    /// Number of *original* non-zeros (excluding fill), kept for
    /// balancing decisions and GFLOP accounting.
    nnz_orig: usize,
}

impl<T: SpElem> BcsrMatrix<T> {
    /// Convert from COO with the given block shape.
    ///
    /// COO is canonically sorted by (row, col), so the non-zeros of one
    /// *block row* form a contiguous span (found by binary search); the
    /// span is bucket-sorted by block column with one scratch index sort
    /// per block row. No global map, no per-block allocations (§Perf
    /// iteration 6 — the BTreeMap version was ~23% of the full
    /// characterization run).
    pub fn from_coo(coo: &CooMatrix<T>, br: usize, bc: usize) -> Self {
        assert!(br > 0 && bc > 0);
        let n_block_rows = crate::util::ceil_div(coo.nrows().max(1), br);
        let mut block_row_ptr = vec![0u32; n_block_rows + 1];
        let mut block_cols: Vec<u32> = Vec::new();
        let mut vals: Vec<T> = Vec::new();
        let mut scratch: Vec<(u32, usize)> = Vec::new(); // (block_col, elem idx)
        let mut span_start = 0usize;
        while span_start < coo.nnz() {
            let bri = coo.rows[span_start] as usize / br;
            let row_end = ((bri + 1) * br) as u32;
            // End of this block row's span.
            let span_end = span_start
                + coo.rows[span_start..].partition_point(|&r| r < row_end);
            // Sort the span's elements by block column.
            scratch.clear();
            scratch.extend(
                (span_start..span_end).map(|i| (coo.cols[i] / bc as u32, i)),
            );
            scratch.sort_unstable_by_key(|&(bcol, _)| bcol);
            // Emit dense blocks in block-column order.
            let mut k = 0usize;
            while k < scratch.len() {
                let bcol = scratch[k].0;
                let base = vals.len();
                vals.resize(base + br * bc, T::zero());
                while k < scratch.len() && scratch[k].0 == bcol {
                    let i = scratch[k].1;
                    let rr = coo.rows[i] as usize % br;
                    let cc = coo.cols[i] as usize % bc;
                    let slot = &mut vals[base + rr * bc + cc];
                    *slot = slot.add(coo.vals[i]);
                    k += 1;
                }
                block_cols.push(bcol);
                block_row_ptr[bri + 1] += 1;
            }
            span_start = span_end;
        }
        for i in 0..n_block_rows {
            block_row_ptr[i + 1] += block_row_ptr[i];
        }
        BcsrMatrix {
            nrows: coo.nrows(),
            ncols: coo.ncols(),
            br,
            bc,
            block_row_ptr,
            block_cols,
            vals,
            nnz_orig: coo.nnz(),
        }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }
    pub fn ncols(&self) -> usize {
        self.ncols
    }
    /// Original (unfilled) non-zero count.
    pub fn nnz(&self) -> usize {
        self.nnz_orig
    }
    /// Number of stored blocks.
    pub fn nblocks(&self) -> usize {
        self.block_cols.len()
    }
    /// Number of block rows.
    pub fn n_block_rows(&self) -> usize {
        self.block_row_ptr.len() - 1
    }
    /// Stored values including fill (`nblocks * br * bc`).
    pub fn stored_vals(&self) -> usize {
        self.vals.len()
    }
    /// Fill-in ratio: stored values / original nnz (>= 1).
    pub fn fill_ratio(&self) -> f64 {
        if self.nnz_orig == 0 {
            1.0
        } else {
            self.stored_vals() as f64 / self.nnz_orig as f64
        }
    }

    /// Blocks of block row `i`: (block_cols, concatenated values).
    pub fn block_row(&self, i: usize) -> (&[u32], &[T]) {
        let lo = self.block_row_ptr[i] as usize;
        let hi = self.block_row_ptr[i + 1] as usize;
        (&self.block_cols[lo..hi], &self.vals[lo * self.br * self.bc..hi * self.br * self.bc])
    }

    /// Number of blocks in block row `i`.
    pub fn block_row_nblocks(&self, i: usize) -> usize {
        (self.block_row_ptr[i + 1] - self.block_row_ptr[i]) as usize
    }

    /// Reference SpMV: `y = A * x`.
    pub fn spmv(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![T::zero(); self.nrows];
        let (br, bc) = (self.br, self.bc);
        for i in 0..self.n_block_rows() {
            let (bcols, bvals) = self.block_row(i);
            for (bi, &bcol) in bcols.iter().enumerate() {
                let blk = &bvals[bi * br * bc..(bi + 1) * br * bc];
                let row0 = i * br;
                let col0 = bcol as usize * bc;
                for rr in 0..br {
                    let r = row0 + rr;
                    if r >= self.nrows {
                        break;
                    }
                    let mut acc = y[r];
                    for cc in 0..bc {
                        let c = col0 + cc;
                        if c >= self.ncols {
                            break;
                        }
                        acc = T::mac(acc, blk[rr * bc + cc], x[c]);
                    }
                    y[r] = acc;
                }
            }
        }
        y
    }

    /// Convert back to COO (drops fill zeros it can identify: entries that
    /// are exactly `T::zero()` inside blocks are not emitted).
    pub fn to_coo(&self) -> CooMatrix<T> {
        let mut triples = Vec::with_capacity(self.nnz_orig);
        let (br, bc) = (self.br, self.bc);
        for i in 0..self.n_block_rows() {
            let (bcols, bvals) = self.block_row(i);
            for (bi, &bcol) in bcols.iter().enumerate() {
                let blk = &bvals[bi * br * bc..(bi + 1) * br * bc];
                for rr in 0..br {
                    for cc in 0..bc {
                        let v = blk[rr * bc + cc];
                        if v != T::zero() {
                            let r = i * br + rr;
                            let c = bcol as usize * bc + cc;
                            if r < self.nrows && c < self.ncols {
                                triples.push((r as u32, c as u32, v));
                            }
                        }
                    }
                }
            }
        }
        CooMatrix::from_triples(self.nrows, self.ncols, triples)
    }

    /// Storage footprint in bytes.
    pub fn size_bytes(&self) -> usize {
        (self.block_row_ptr.len() + self.block_cols.len()) * 4
            + self.stored_vals() * T::DTYPE.size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CooMatrix<f64> {
        // 4x4 with a dense 2x2 block at (0,0) and scattered elements.
        CooMatrix::from_triples(
            4,
            4,
            vec![
                (0, 0, 1.0),
                (0, 1, 2.0),
                (1, 0, 3.0),
                (1, 1, 4.0),
                (2, 3, 5.0),
                (3, 0, 6.0),
            ],
        )
    }

    #[test]
    fn block_structure() {
        let b = BcsrMatrix::from_coo(&small(), 2, 2);
        // Blocks: (0,0) dense; (1,1) holds (2,3); (1,0) holds (3,0).
        assert_eq!(b.nblocks(), 3);
        assert_eq!(b.nnz(), 6);
        assert_eq!(b.stored_vals(), 12);
        assert!((b.fill_ratio() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn spmv_matches_coo() {
        let m = small();
        let x = [1.0, 10.0, 100.0, 1000.0];
        for (br, bc) in [(1, 1), (2, 2), (3, 2), (4, 4), (2, 4)] {
            let b = BcsrMatrix::from_coo(&m, br, bc);
            assert_eq!(b.spmv(&x), m.spmv(&x), "block {br}x{bc}");
        }
    }

    #[test]
    fn spmv_with_ragged_edge() {
        // 5x5 matrix, 2x2 blocks: last block row/col are partial.
        let m = CooMatrix::from_triples(
            5,
            5,
            vec![(4, 4, 2.0f32), (4, 0, 1.0), (0, 4, 3.0)],
        );
        let b = BcsrMatrix::from_coo(&m, 2, 2);
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(b.spmv(&x), m.spmv(&x));
    }

    #[test]
    fn coo_roundtrip() {
        let m = small();
        let b = BcsrMatrix::from_coo(&m, 2, 2);
        assert_eq!(b.to_coo(), m);
    }

    #[test]
    fn bcsr_1x1_equals_csr_pattern() {
        let m = small();
        let b = BcsrMatrix::from_coo(&m, 1, 1);
        assert_eq!(b.nblocks(), m.nnz());
        assert!((b.fill_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn duplicate_triples_accumulate_into_block() {
        let m = CooMatrix::from_triples(2, 2, vec![(0, 0, 1.0f64), (0, 0, 2.0)]);
        let b = BcsrMatrix::from_coo(&m, 2, 2);
        assert_eq!(b.spmv(&[1.0, 0.0])[0], 3.0);
    }
}
