//! SpMV-consuming applications — the workloads the paper's introduction
//! motivates (scientific computing, graph analytics, machine learning).
//!
//! Each solver registers its matrix with an
//! [`crate::coordinator::SpmvService`] once and iterates SpMV requests
//! against the handle while the host performs the vector operations,
//! accumulating the full cost model across iterations (the setting
//! where the paper's "matrix placement is one-time, vector transfer is
//! per-iteration" methodology matters: an iterative solver calls SpMV
//! hundreds of times on the same matrix).

pub mod cg;
pub mod pagerank;
pub mod jacobi;

use crate::coordinator::Breakdown;

/// Accumulated cost of an iterative run.
#[derive(Clone, Copy, Debug, Default)]
pub struct SolveStats {
    pub iterations: usize,
    /// Sum of per-iteration PIM breakdowns.
    pub pim: Breakdown,
    /// One-time matrix placement.
    pub matrix_load_s: f64,
    /// Total modeled energy, joules.
    pub energy_j: f64,
}

impl SolveStats {
    pub(crate) fn absorb(&mut self, r: &crate::coordinator::RunResult<f64>) {
        self.iterations += 1;
        self.pim.load_s += r.breakdown.load_s;
        self.pim.kernel_s += r.breakdown.kernel_s;
        self.pim.retrieve_s += r.breakdown.retrieve_s;
        self.pim.merge_s += r.breakdown.merge_s;
        self.energy_j += r.energy.total_j();
        self.matrix_load_s = r.stats.matrix_load_s; // one-time
    }

    pub fn total_s(&self) -> f64 {
        self.matrix_load_s + self.pim.total_s()
    }
}

/// Dot product (host-side vector op).
pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `y += alpha * x` (host-side).
pub(crate) fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

