//! CSR (compressed sparse row) format.
//!
//! The de-facto standard SpMV format and the paper's baseline: a row
//! pointer array of length `nrows+1`, plus column-index and value arrays
//! of length `nnz`. Row boundaries are explicit, which makes row-granular
//! partitioning free but in-row splitting impossible — the key structural
//! difference from COO that drives the paper's balancing analysis.

use super::coo::CooMatrix;
use super::dtype::SpElem;

/// A sparse matrix in CSR format.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix<T: SpElem> {
    nrows: usize,
    ncols: usize,
    /// `row_ptr[r]..row_ptr[r+1]` indexes the non-zeros of row `r`.
    pub row_ptr: Vec<u32>,
    /// Column index of each non-zero.
    pub cols: Vec<u32>,
    /// Value of each non-zero.
    pub vals: Vec<T>,
}

impl<T: SpElem> CsrMatrix<T> {
    /// Convert from COO (which is canonically sorted).
    pub fn from_coo(coo: &CooMatrix<T>) -> Self {
        let mut row_ptr = vec![0u32; coo.nrows() + 1];
        for &r in &coo.rows {
            row_ptr[r as usize + 1] += 1;
        }
        for r in 0..coo.nrows() {
            row_ptr[r + 1] += row_ptr[r];
        }
        CsrMatrix {
            nrows: coo.nrows(),
            ncols: coo.ncols(),
            row_ptr,
            cols: coo.cols.clone(),
            vals: coo.vals.clone(),
        }
    }

    /// Build directly from raw parts (validated).
    pub fn from_parts(
        nrows: usize,
        ncols: usize,
        row_ptr: Vec<u32>,
        cols: Vec<u32>,
        vals: Vec<T>,
    ) -> Self {
        assert_eq!(row_ptr.len(), nrows + 1, "row_ptr length");
        assert_eq!(row_ptr[0], 0, "row_ptr[0] must be 0");
        assert_eq!(*row_ptr.last().unwrap() as usize, vals.len(), "row_ptr end");
        assert_eq!(cols.len(), vals.len(), "cols/vals length");
        assert!(row_ptr.windows(2).all(|w| w[0] <= w[1]), "row_ptr monotone");
        assert!(cols.iter().all(|&c| (c as usize) < ncols), "col in bounds");
        CsrMatrix { nrows, ncols, row_ptr, cols, vals }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }
    pub fn ncols(&self) -> usize {
        self.ncols
    }
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Number of non-zeros in row `r`.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        (self.row_ptr[r + 1] - self.row_ptr[r]) as usize
    }

    /// The (cols, vals) slices of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> (&[u32], &[T]) {
        let lo = self.row_ptr[r] as usize;
        let hi = self.row_ptr[r + 1] as usize;
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// Reference SpMV: `y = A * x`.
    pub fn spmv(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![T::zero(); self.nrows];
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            let mut acc = T::zero();
            for (c, v) in cols.iter().zip(vals) {
                acc = T::mac(acc, *v, x[*c as usize]);
            }
            y[r] = acc;
        }
        y
    }

    /// Convert back to COO.
    pub fn to_coo(&self) -> CooMatrix<T> {
        let mut triples = Vec::with_capacity(self.nnz());
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                triples.push((r as u32, *c, *v));
            }
        }
        CooMatrix::from_triples(self.nrows, self.ncols, triples)
    }

    /// Extract rows `[r0, r1)` as a new CSR matrix (column space kept).
    /// This is the 1D row-partitioning primitive.
    pub fn row_slice(&self, r0: usize, r1: usize) -> CsrMatrix<T> {
        assert!(r0 <= r1 && r1 <= self.nrows);
        let lo = self.row_ptr[r0] as usize;
        let hi = self.row_ptr[r1] as usize;
        let row_ptr = self.row_ptr[r0..=r1].iter().map(|&p| p - self.row_ptr[r0]).collect();
        CsrMatrix {
            nrows: r1 - r0,
            ncols: self.ncols,
            row_ptr,
            cols: self.cols[lo..hi].to_vec(),
            vals: self.vals[lo..hi].to_vec(),
        }
    }

    /// Storage footprint in bytes: row pointers + column indices + values.
    pub fn size_bytes(&self) -> usize {
        (self.row_ptr.len() + self.cols.len()) * 4 + self.nnz() * T::DTYPE.size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CsrMatrix<f64> {
        // [ 1 0 2 ]
        // [ 0 0 0 ]
        // [ 3 4 0 ]
        let coo = CooMatrix::from_triples(
            3,
            3,
            vec![(0, 0, 1.0), (0, 2, 2.0), (2, 0, 3.0), (2, 1, 4.0)],
        );
        CsrMatrix::from_coo(&coo)
    }

    #[test]
    fn from_coo_structure() {
        let m = small();
        assert_eq!(m.row_ptr, vec![0, 2, 2, 4]);
        assert_eq!(m.cols, vec![0, 2, 0, 1]);
        assert_eq!(m.row_nnz(0), 2);
        assert_eq!(m.row_nnz(1), 0);
    }

    #[test]
    fn spmv_matches_coo() {
        let m = small();
        let x = [1.0, 10.0, 100.0];
        assert_eq!(m.spmv(&x), m.to_coo().spmv(&x));
    }

    #[test]
    fn coo_roundtrip() {
        let m = small();
        let back = CsrMatrix::from_coo(&m.to_coo());
        assert_eq!(m, back);
    }

    #[test]
    fn row_slice_preserves_values() {
        let m = small();
        let s = m.row_slice(1, 3);
        assert_eq!(s.nrows(), 2);
        assert_eq!(s.row_ptr, vec![0, 0, 2]);
        let y = s.spmv(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![0.0, 7.0]);
    }

    #[test]
    fn empty_matrix() {
        let m = CsrMatrix::from_coo(&CooMatrix::<f32>::zeros(4, 5));
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.spmv(&vec![1.0; 5]), vec![0.0; 4]);
    }

    #[test]
    #[should_panic]
    fn bad_row_ptr_panics() {
        CsrMatrix::from_parts(2, 2, vec![0, 3, 2], vec![0, 1], vec![1.0f32, 2.0]);
    }

    #[test]
    fn size_bytes_accounting() {
        let m = small();
        // 4 row_ptr entries + 4 cols (4B each) + 4 f64 vals.
        assert_eq!(m.size_bytes(), (4 + 4) * 4 + 4 * 8);
    }
}
