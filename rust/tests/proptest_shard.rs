//! Property tests for shard planning and the scatter/gather layer
//! (hand-rolled; proptest is not in the offline vendor set): for random
//! COO matrices and shard counts,
//!
//! * the planned shard row-ranges tile `[0, nrows)` contiguously with
//!   no empty shard (effective count `min(shards, nrows)`), so every
//!   row — and therefore every stored non-zero — lands in exactly one
//!   shard;
//! * slicing the matrix by those ranges partitions the non-zeros
//!   exactly (counts and triples add back up);
//! * gathering a `ShardedService`'s per-shard outputs reconstructs the
//!   host-oracle SpMV bit-exactly.

//! * killing a random shard backend respawns it from the shared plan
//!   cache (no plan-build leak) and the post-recovery gather still
//!   equals the oracle.

use sparsep::coordinator::{
    plan_shards, Fault, FaultPlan, KernelSpec, Request, ShardedService, ShardedServiceBuilder,
};
use sparsep::matrix::CooMatrix;
use sparsep::pim::PimSystem;
use sparsep::util::rng::Rng;
use std::sync::Arc;

/// Random sparse matrix with rng-chosen shape and density (integer
/// values: sums are exact in f64, so bit-equality with the host oracle
/// is meaningful).
fn random_matrix(rng: &mut Rng) -> CooMatrix<f64> {
    let nrows = 1 + rng.gen_range(200);
    let ncols = 1 + rng.gen_range(200);
    let nnz = rng.gen_range(4 * nrows.min(ncols) + 1);
    let mut triples = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        triples.push((
            rng.gen_range(nrows) as u32,
            rng.gen_range(ncols) as u32,
            (rng.gen_range(9) as f64) - 4.0,
        ));
    }
    CooMatrix::from_triples(nrows, ncols, triples)
}

/// PROPERTY: shard ranges tile the row space, never empty, and
/// partition the non-zeros exactly.
#[test]
fn prop_shard_ranges_tile_rows_and_nnz() {
    let mut rng = Rng::new(0x5AADED);
    for trial in 0..200 {
        let m = random_matrix(&mut rng);
        let shards = 1 + rng.gen_range(12);
        let ranges = plan_shards(&m, shards);
        let tag = format!(
            "trial {trial}: {}x{} nnz={} shards={shards}",
            m.nrows(),
            m.ncols(),
            m.nnz()
        );
        assert_eq!(ranges.len(), shards.min(m.nrows()).max(1), "{tag}: shard count");
        assert_eq!(ranges[0].start, 0, "{tag}: first range must start at row 0");
        assert_eq!(ranges.last().unwrap().end, m.nrows(), "{tag}: last range must end at nrows");
        for w in ranges.windows(2) {
            assert_eq!(w[0].end, w[1].start, "{tag}: ranges must tile contiguously");
        }
        if m.nrows() > 0 {
            assert!(ranges.iter().all(|r| !r.is_empty()), "{tag}: empty shard range");
        }
        // Row/nnz partition: slicing by the ranges recovers every
        // non-zero exactly once, in order.
        let mut sliced_nnz = 0usize;
        let mut gathered: Vec<(u32, u32, f64)> = Vec::with_capacity(m.nnz());
        for r in &ranges {
            let slice = m.row_range_slice(r.start, r.end);
            assert_eq!(slice.nrows(), r.len(), "{tag}: slice row count");
            assert_eq!(slice.ncols(), m.ncols(), "{tag}: slices keep the column space");
            sliced_nnz += slice.nnz();
            gathered.extend(
                slice.iter().map(|(row, col, v)| (row + r.start as u32, col, v)),
            );
        }
        assert_eq!(sliced_nnz, m.nnz(), "{tag}: non-zeros must partition exactly");
        let original: Vec<(u32, u32, f64)> = m.iter().collect();
        assert_eq!(gathered, original, "{tag}: gathered triples must reconstruct the matrix");
    }
}

/// PROPERTY: shard-count balance — nnz-weighted planning never gives a
/// shard more non-zeros than one row short of the whole matrix, and on
/// matrices with spread-out rows the heaviest shard is within a row of
/// the greedy balanced cut (sanity envelope, not a tight bound).
#[test]
fn prop_shard_planning_balances_nnz() {
    let mut rng = Rng::new(0xBA1A2CE);
    for _ in 0..100 {
        let m = random_matrix(&mut rng);
        let shards = 2 + rng.gen_range(6);
        let ranges = plan_shards(&m, shards);
        let counts = m.row_counts();
        let per_shard: Vec<usize> =
            ranges.iter().map(|r| counts[r.clone()].iter().sum()).collect();
        let total: usize = per_shard.iter().sum();
        assert_eq!(total, m.nnz());
        let max_row = counts.iter().copied().max().unwrap_or(0);
        let ideal = m.nnz().div_ceil(ranges.len());
        let heaviest = per_shard.iter().copied().max().unwrap_or(0);
        // Loose envelope: greedy row-granular splitting underfills each
        // chunk by < one row, and the shortfall compounds harmonically
        // into the tail chunk — 3x the heaviest row safely covers every
        // shard count the suite uses. The point is "roughly balanced",
        // not "one shard takes all".
        assert!(
            heaviest <= ideal + 3 * max_row,
            "heaviest shard {heaviest} exceeds ideal {ideal} + 3 * max row {max_row} ({}x{} nnz={} shards={})",
            m.nrows(),
            m.ncols(),
            m.nnz(),
            ranges.len()
        );
    }
}

/// PROPERTY: gather reconstructs the host oracle bit-exactly for random
/// matrices, shard counts and kernels — spmv, batch and iterate.
#[test]
fn prop_sharded_gather_reconstructs_oracle() {
    let mut rng = Rng::new(0xC0DE5A);
    let kernels =
        [KernelSpec::coo_nnz(), KernelSpec::csr_nnz(), KernelSpec::coo_row(), KernelSpec::bcoo_nnz()];
    for trial in 0..25usize {
        let m = random_matrix(&mut rng);
        let shards = 1 + rng.gen_range(6);
        let spec = &kernels[rng.gen_range(kernels.len())];
        let n_dpus = 1 + rng.gen_range(12);
        let tag = format!(
            "trial {trial}: {}x{} nnz={} shards={shards} dpus={n_dpus} {}",
            m.nrows(),
            m.ncols(),
            m.nnz(),
            spec.name
        );
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(shards)
            .build(PimSystem::with_dpus(n_dpus))
            .unwrap();
        let h = svc.load(&m, spec).unwrap();
        let x: Vec<f64> =
            (0..m.ncols()).map(|i| ((i * 7 + trial) % 11) as f64 - 5.0).collect();
        let r = svc.spmv(&h, &x).unwrap();
        assert_eq!(r.y, m.spmv(&x), "{tag}: spmv");
        assert_eq!(r.stats.nnz, m.nnz(), "{tag}: merged nnz");
        let xs: Vec<Vec<f64>> = (0..3usize)
            .map(|b| (0..m.ncols()).map(|i| ((i + 3 * b) % 9) as f64 - 4.0).collect())
            .collect();
        let batch = svc.spmv_batch(&h, &xs).unwrap();
        for (x, run) in xs.iter().zip(&batch.runs) {
            assert_eq!(run.y, m.spmv(x), "{tag}: batch");
        }
        if m.nrows() == m.ncols() {
            let it = svc.iterate(&h, &x, 3).unwrap();
            let mut want = x.clone();
            for _ in 0..3 {
                want = m.spmv(&want);
            }
            assert_eq!(it.last.y, want, "{tag}: iterate");
        }
    }
}

/// PROPERTY: kill-one-shard-and-recover — for random matrices and a
/// random target shard killed at the first ticket's dispatch, the
/// backend respawns from the shared plan cache (exactly one respawn,
/// zero new plan builds — the cache already holds every slice's plan),
/// the post-recovery gather is bit-identical to the host oracle, and
/// the facade stays fully serviceable.
#[test]
fn prop_killed_shard_recovers_bit_exactly() {
    let mut rng = Rng::new(0xDEAD_BEA7);
    for trial in 0..20usize {
        let m = random_matrix(&mut rng);
        let shards = 1 + rng.gen_range(5);
        // Matrices with fewer rows than shards use fewer shards: aim
        // the kill at a shard that actually exists.
        let effective = plan_shards(&m, shards).len();
        let target = rng.gen_range(effective);
        let seed = 0x5EED ^ trial as u64;
        let tag = format!(
            "trial {trial}: {}x{} nnz={} shards={shards} effective={effective} target={target} seed={seed:#x}",
            m.nrows(),
            m.ncols(),
            m.nnz()
        );
        let plan = FaultPlan::new(seed).on_dispatch(1, Fault::KillShard { shard: target });
        let svc: ShardedService<f64> = ShardedServiceBuilder::new()
            .shards(shards)
            .fault_injector(Arc::new(plan))
            .build(PimSystem::with_dpus(4))
            .unwrap();
        let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
        assert_eq!(svc.shard_ranges(&h).unwrap().len(), effective, "{tag}: effective shards");
        let builds_before = svc.stats().plan_builds;
        let x: Vec<f64> =
            (0..m.ncols()).map(|i| ((i * 5 + trial) % 13) as f64 - 6.0).collect();
        let t = svc.submit(h, Request::spmv(x.clone())).unwrap();
        let run = svc.wait(t).unwrap().into_spmv().unwrap();
        assert_eq!(run.y, m.spmv(&x), "{tag}: post-recovery gather vs oracle");
        let st = svc.stats();
        assert_eq!(st.respawns, 1, "{tag}: exactly one respawn");
        assert_eq!(
            st.plan_builds, builds_before,
            "{tag}: respawn must re-load through cache hits, never leak plan builds"
        );
        assert_eq!(svc.spmv(&h, &x).unwrap().y, m.spmv(&x), "{tag}: facade after recovery");
    }
}
