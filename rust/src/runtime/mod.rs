//! PJRT runtime: load and execute AOT-compiled artifacts from Rust.
//!
//! `python/compile/aot.py` lowers the JAX/Pallas model to HLO *text*
//! (see DESIGN.md §5 for why text, not serialized protos) plus a
//! `manifest.json`. This module loads that manifest, compiles artifacts
//! on the PJRT CPU client (once — compilation is cached per artifact),
//! and executes them with concrete inputs. Python never runs here; the
//! Rust binary is self-contained once `make artifacts` has been run.

pub mod ell_host;
mod xla_shim;

use crate::bail;
use crate::util::json::Json;
use crate::util::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
// The real `xla` crate is not in the offline vendor set; the shim keeps
// the PJRT surface compiling and turns artifact execution into a clear
// "backend unavailable" error. Swap this import for the real crate to
// re-enable the path.
use self::xla_shim as xla;

/// Metadata of one AOT artifact (a row of `manifest.json`).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Artifact kind: "ell", "bell", "dense", "power_iter", "cg_residual".
    pub kind: String,
    pub dtype: String,
    /// Input signatures: (dtype-name, shape).
    pub inputs: Vec<(String, Vec<usize>)>,
    /// Kind-specific size fields (rows, k, n, nbr, ...).
    pub dims: HashMap<String, usize>,
}

/// The artifact index + a PJRT client; compiles lazily, caches compiled
/// executables by name.
pub struct ArtifactRunner {
    dir: PathBuf,
    client: xla::PjRtClient,
    metas: HashMap<String, ArtifactMeta>,
    compiled: std::cell::RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactRunner {
    /// Load `manifest.json` from `dir` and create a PJRT CPU client.
    pub fn load(dir: &Path) -> Result<ArtifactRunner> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!("read {} (run `make artifacts` first)", manifest_path.display())
        })?;
        let json = Json::parse(&text).map_err(|e| crate::format_err!("manifest parse: {e}"))?;
        if json.get("format").as_str() != Some("hlo-text") {
            bail!("unexpected manifest format field");
        }
        let mut metas = HashMap::new();
        for a in json.get("artifacts").as_arr().context("artifacts array")? {
            let name = a.get("name").as_str().context("artifact name")?.to_string();
            let mut dims = HashMap::new();
            if let Some(obj) = a.as_obj() {
                for (k, v) in obj {
                    if let Some(n) = v.as_f64() {
                        dims.insert(k.clone(), n as usize);
                    }
                }
            }
            let inputs = a
                .get("inputs")
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|sig| {
                    let arr = sig.as_arr().context("input sig")?;
                    let dt = arr[0].as_str().context("dtype")?.to_string();
                    let shape = arr[1]
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<Vec<_>>>()?;
                    Ok((dt, shape))
                })
                .collect::<Result<Vec<_>>>()?;
            metas.insert(
                name.clone(),
                ArtifactMeta {
                    name,
                    file: a.get("file").as_str().context("file")?.to_string(),
                    kind: a.get("kind").as_str().unwrap_or("unknown").to_string(),
                    dtype: a.get("dtype").as_str().unwrap_or("f32").to_string(),
                    inputs,
                    dims,
                },
            );
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(ArtifactRunner { dir: dir.to_path_buf(), client, metas, compiled: Default::default() })
    }

    /// Load from the conventional `artifacts/` directory (what the
    /// examples and benches use): `./artifacts` relative to the current
    /// directory, falling back to the crate root (so binaries work from
    /// any cwd).
    pub fn load_default() -> Result<ArtifactRunner> {
        let cwd_rel = Path::new("artifacts");
        if cwd_rel.join("manifest.json").exists() {
            return Self::load(cwd_rel);
        }
        Self::load(&Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    /// Artifact names available (sorted).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.metas.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.get(name)
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.compiled.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self.metas.get(name).with_context(|| format!("unknown artifact {name}"))?;
        let path = self.dir.join(&meta.file);
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("artifact path utf-8")?)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp)?);
        self.compiled.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name` with the given literals; returns the
    /// elements of the (single-level) output tuple.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let meta = self.metas.get(name).with_context(|| format!("unknown artifact {name}"))?;
        crate::ensure!(
            inputs.len() == meta.inputs.len(),
            "artifact {name} expects {} inputs, got {}",
            meta.inputs.len(),
            inputs.len()
        );
        let exe = self.executable(name)?;
        let outer = exe.execute::<xla::Literal>(inputs)?;
        let mut result = outer[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        Ok(result.decompose_tuple()?)
    }

    /// Convenience: run an f32 ELL artifact (`vals (R,K)`, `cols (R,K)`,
    /// `x (N,)`) and return y as `Vec<f32>`.
    pub fn run_ell_f32(&self, name: &str, vals: &[f32], cols: &[i32], x: &[f32]) -> Result<Vec<f32>> {
        let meta = self.metas.get(name).with_context(|| format!("unknown artifact {name}"))?;
        let (r, k) = (meta.dims["rows"] as i64, meta.dims["k"] as i64);
        let n = meta.dims["n"] as i64;
        crate::ensure!(vals.len() as i64 == r * k, "vals size");
        crate::ensure!(cols.len() as i64 == r * k, "cols size");
        crate::ensure!(x.len() as i64 == n, "x size");
        let lv = xla::Literal::vec1(vals).reshape(&[r, k])?;
        let lc = xla::Literal::vec1(cols).reshape(&[r, k])?;
        let lx = xla::Literal::vec1(x);
        let out = self.execute(name, &[lv, lc, lx])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Convenience: run the f32 dense artifact (`a (N,N)`, `x (N,)`).
    pub fn run_dense_f32(&self, name: &str, a: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let meta = self.metas.get(name).with_context(|| format!("unknown artifact {name}"))?;
        let n = meta.dims["n"] as i64;
        crate::ensure!(a.len() as i64 == n * n, "a size");
        crate::ensure!(x.len() as i64 == n, "x size");
        let la = xla::Literal::vec1(a).reshape(&[n, n])?;
        let lx = xla::Literal::vec1(x);
        let out = self.execute(name, &[la, lx])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Pick the smallest ELL artifact bucket fitting `(rows, k)` for a
    /// dtype, or None if nothing fits.
    pub fn pick_ell_bucket(&self, dtype: &str, rows: usize, k: usize) -> Option<&ArtifactMeta> {
        self.metas
            .values()
            .filter(|m| {
                m.kind == "ell" && m.dtype == dtype && m.dims["rows"] >= rows && m.dims["k"] >= k
            })
            .min_by_key(|m| m.dims["rows"] * m.dims["k"])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runner() -> Option<ArtifactRunner> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: run `make artifacts` first");
            return None;
        }
        Some(ArtifactRunner::load(&dir).expect("load artifacts"))
    }

    #[test]
    fn manifest_loads_and_lists() {
        let Some(r) = runner() else { return };
        let names = r.names();
        assert!(names.iter().any(|n| n.starts_with("ell_f32")), "{names:?}");
        assert!(names.iter().any(|n| n.starts_with("dense_f32")));
        let m = r.meta("ell_f32_r1024_k8_n1024").unwrap();
        assert_eq!(m.kind, "ell");
        assert_eq!(m.inputs.len(), 3);
    }

    #[test]
    fn ell_artifact_matches_host_reference() {
        let Some(r) = runner() else { return };
        let (rows, k, n) = (1024usize, 8usize, 1024usize);
        // Identity-ish ELL: row i picks x[i] with weight 2.
        let mut vals = vec![0f32; rows * k];
        let mut cols = vec![0i32; rows * k];
        for i in 0..rows {
            vals[i * k] = 2.0;
            cols[i * k] = (i % n) as i32;
        }
        let x: Vec<f32> = (0..n).map(|i| (i % 13) as f32 - 6.0).collect();
        let y = r.run_ell_f32("ell_f32_r1024_k8_n1024", &vals, &cols, &x).unwrap();
        for i in 0..rows {
            assert_eq!(y[i], 2.0 * x[i % n], "row {i}");
        }
    }

    #[test]
    fn dense_artifact_matches() {
        let Some(r) = runner() else { return };
        let n = 512usize;
        let mut a = vec![0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 3.0;
        }
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let y = r.run_dense_f32("dense_f32_n512", &a, &x).unwrap();
        for i in 0..n {
            assert_eq!(y[i], 3.0 * i as f32);
        }
    }

    #[test]
    fn bucket_picker_finds_smallest_fit() {
        let Some(r) = runner() else { return };
        let m = r.pick_ell_bucket("f32", 900, 7).unwrap();
        assert_eq!(m.dims["rows"], 1024);
        assert!(r.pick_ell_bucket("f32", 1_000_000, 1).is_none());
    }

    #[test]
    fn unknown_artifact_is_error() {
        let Some(r) = runner() else { return };
        assert!(r.execute("nope", &[]).is_err());
    }
}
