//! Property tests for the serving API: `SpmvService` responses must be
//! bit-identical — output vectors, breakdowns, stats and energy — to
//! the synchronous `ExecutionPlan` path, across all 25 kernel specs,
//! both engines, and every request kind (single SpMV, ragged batch,
//! iterate), including out-of-order waits on >= 4 concurrent tickets.
//! The pipelined request queue, the vector-block policy and the queue
//! depth are wall-clock knobs only; any answer drift is a bug.

use sparsep::coordinator::{
    BatchResult, BlockPolicy, Engine, IterationsResult, KernelSpec, Request, Response, RunResult,
    ServiceBuilder, SpmvExecutor, SpmvService, Ticket, VECTOR_BLOCK,
};
use sparsep::matrix::{generate, CooMatrix, SpElem};
use sparsep::pim::PimSystem;

const BATCH: usize = VECTOR_BLOCK + 3; // one full block + a ragged tail

fn assert_identical<T: SpElem>(a: &RunResult<T>, b: &RunResult<T>, tag: &str) {
    assert_eq!(a.y, b.y, "{tag}: output vector differs");
    assert_eq!(a.breakdown, b.breakdown, "{tag}: breakdown differs");
    assert_eq!(a.stats, b.stats, "{tag}: stats differ");
    assert_eq!(a.energy, b.energy, "{tag}: energy differs");
}

fn assert_batch_identical<T: SpElem>(a: &BatchResult<T>, b: &BatchResult<T>, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: batch size differs");
    for (i, (ra, rb)) in a.runs.iter().zip(&b.runs).enumerate() {
        assert_identical(ra, rb, &format!("{tag} vec={i}"));
    }
}

fn assert_iters_identical<T: SpElem>(
    a: &IterationsResult<T>,
    b: &IterationsResult<T>,
    tag: &str,
) {
    assert_identical(&a.last, &b.last, &format!("{tag} last"));
    assert_eq!(a.total, b.total, "{tag}: iteration totals differ");
    assert_eq!(a.energy, b.energy, "{tag}: iteration energy differs");
    assert_eq!(a.iters, b.iters, "{tag}: iteration count differs");
}

fn vectors(ncols: usize, batch: usize) -> Vec<Vec<f64>> {
    (0..batch)
        .map(|b| (0..ncols).map(|i| ((i + 5 * b) % 11) as f64 - 5.0).collect())
        .collect()
}

/// Submit the full request mix as >= 4 concurrent tickets, wait for
/// them OUT of submission order, and compare every response against
/// the synchronous `ExecutionPlan` path on an equally-configured
/// executor.
fn check_service(engine: Engine, spec: &KernelSpec, m: &CooMatrix<f64>, tag: &str) {
    const ITERS: usize = 5;
    let sys = PimSystem::with_dpus(16);
    let exec = SpmvExecutor::with_engine(sys.clone(), engine);
    let plan = exec.plan(spec, m).unwrap();
    let svc: SpmvService<f64> =
        ServiceBuilder::new().engine(engine).build(sys).unwrap();
    let handle = svc.load(m, spec).unwrap();

    let x1: Vec<f64> = (0..m.ncols()).map(|i| ((i % 13) as f64) - 6.0).collect();
    let x2: Vec<f64> = (0..m.ncols()).map(|i| ((i % 7) as f64) - 3.0).collect();
    let xs = vectors(m.ncols(), BATCH);
    let square = m.nrows() == m.ncols();
    let iters = if square { ITERS } else { 1 };

    // Four tickets in flight at once...
    let t_spmv1 = svc.submit(handle, Request::spmv(x1.clone())).unwrap();
    let t_batch = svc.submit(handle, Request::batch(xs.clone())).unwrap();
    let t_iter = svc.submit(handle, Request::iterate(x1.clone(), iters)).unwrap();
    let t_spmv2 = svc.submit(handle, Request::spmv(x2.clone())).unwrap();

    // ...claimed out of submission order.
    let iter_resp = match svc.wait(t_iter).unwrap() {
        Response::Iterate(it) => it,
        other => panic!("{tag}: expected iterate, got {}", other.kind()),
    };
    let spmv2_resp = match svc.wait(t_spmv2).unwrap() {
        Response::Spmv(r) => r,
        other => panic!("{tag}: expected spmv, got {}", other.kind()),
    };
    let batch_resp = match svc.wait(t_batch).unwrap() {
        Response::Batch(b) => b,
        other => panic!("{tag}: expected batch, got {}", other.kind()),
    };
    let spmv1_resp = match svc.wait(t_spmv1).unwrap() {
        Response::Spmv(r) => r,
        other => panic!("{tag}: expected spmv, got {}", other.kind()),
    };

    // The synchronous ExecutionPlan path is the reference.
    assert_identical(&spmv1_resp, &plan.execute(&exec, &x1).unwrap(), &format!("{tag} spmv1"));
    assert_identical(&spmv2_resp, &plan.execute(&exec, &x2).unwrap(), &format!("{tag} spmv2"));
    assert_batch_identical(
        &batch_resp,
        &plan.execute_batch_runs(&exec, &xs).unwrap(),
        &format!("{tag} batch"),
    );
    assert_iters_identical(
        &iter_resp,
        &plan.run_iterations(&exec, &x1, iters).unwrap(),
        &format!("{tag} iterate"),
    );
}

/// PROPERTY: all 25 kernels x {serial, threaded} serve the full request
/// mix bit-identically to synchronous execution, with >= 4 concurrent
/// tickets waited out of order.
#[test]
fn prop_all25_service_identical_to_synchronous() {
    let m = generate::scale_free::<f64>(256, 256, 6, 0.7, 29);
    for spec in KernelSpec::all25(4) {
        check_service(Engine::Serial, &spec, &m, &format!("{} serial", spec.name));
        check_service(Engine::threaded(4), &spec, &m, &format!("{} threaded", spec.name));
    }
}

/// PROPERTY: neither the vector-block policy nor the queue depth can
/// change a response — only the wall clock.
#[test]
fn prop_block_policy_and_queue_depth_do_not_change_responses() {
    let m = generate::scale_free::<f64>(192, 192, 6, 0.6, 51);
    let spec = KernelSpec::coo_nnz();
    let xs = vectors(192, BATCH);
    let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
    let gold = exec.plan(&spec, &m).unwrap().execute_batch_runs(&exec, &xs).unwrap();
    for policy in [
        BlockPolicy::Fixed(1),
        BlockPolicy::Fixed(2),
        BlockPolicy::Fixed(VECTOR_BLOCK),
        BlockPolicy::Fixed(1024),
        BlockPolicy::Adaptive,
    ] {
        for depth in [1usize, 2, 64] {
            let svc: SpmvService<f64> = ServiceBuilder::new()
                .vector_block(policy)
                .queue_depth(depth)
                .build(PimSystem::with_dpus(8))
                .unwrap();
            let h = svc.load(&m, &spec).unwrap();
            // Through the pipelined queue...
            let t = svc.submit(h, Request::batch(xs.clone())).unwrap();
            let b = svc.wait(t).unwrap().into_batch().unwrap();
            assert_batch_identical(&b, &gold, &format!("{policy:?} depth={depth} queued"));
            // ...and through the synchronous fast path.
            let fast = svc.spmv_batch(&h, &xs).unwrap();
            assert_batch_identical(&fast, &gold, &format!("{policy:?} depth={depth} fast"));
        }
    }
}

/// PROPERTY: a deep pipeline of interleaved request kinds, all in
/// flight simultaneously and waited in reverse, matches per-request
/// synchronous execution (requests must not bleed into each other in
/// the stage hand-off).
#[test]
fn prop_deep_interleaved_pipeline_isolates_requests() {
    let m = generate::uniform::<f64>(160, 160, 5, 43);
    let spec = KernelSpec::csr_nnz();
    for engine in [Engine::Serial, Engine::threaded(2)] {
        let sys = PimSystem::with_dpus(8);
        let exec = SpmvExecutor::with_engine(sys.clone(), engine);
        let plan = exec.plan(&spec, &m).unwrap();
        let svc: SpmvService<f64> = ServiceBuilder::new()
            .engine(engine)
            .queue_depth(3) // deliberately shallow: submit must backpressure, not wedge
            .build(sys)
            .unwrap();
        let h = svc.load(&m, &spec).unwrap();

        enum Want {
            Spmv(Vec<f64>),
            Batch(Vec<Vec<f64>>),
            Iter(Vec<f64>, usize),
        }
        let mut tickets: Vec<(Ticket, Want)> = Vec::new();
        for r in 0..12usize {
            let x: Vec<f64> = (0..160).map(|i| ((i + 9 * r) % 7) as f64 - 3.0).collect();
            match r % 3 {
                0 => {
                    let t = svc.submit(h, Request::spmv(x.clone())).unwrap();
                    tickets.push((t, Want::Spmv(x)));
                }
                1 => {
                    let xs = vec![x.clone(), x.iter().map(|v| v + 1.0).collect(), x];
                    let t = svc.submit(h, Request::batch(xs.clone())).unwrap();
                    tickets.push((t, Want::Batch(xs)));
                }
                _ => {
                    let iters = 1 + r % 4;
                    let t = svc.submit(h, Request::iterate(x.clone(), iters)).unwrap();
                    tickets.push((t, Want::Iter(x, iters)));
                }
            }
        }
        for (i, (ticket, want)) in tickets.into_iter().enumerate().rev() {
            let tag = format!("req {i}");
            match (svc.wait(ticket).unwrap(), want) {
                (Response::Spmv(r), Want::Spmv(x)) => {
                    assert_identical(&r, &plan.execute(&exec, &x).unwrap(), &tag);
                }
                (Response::Batch(b), Want::Batch(xs)) => {
                    assert_batch_identical(
                        &b,
                        &plan.execute_batch_runs(&exec, &xs).unwrap(),
                        &tag,
                    );
                }
                (Response::Iterate(it), Want::Iter(x, iters)) => {
                    assert_iters_identical(
                        &it,
                        &plan.run_iterations(&exec, &x, iters).unwrap(),
                        &tag,
                    );
                }
                (resp, _) => panic!("{tag}: response kind {} mismatched", resp.kind()),
            }
        }
    }
}

/// PROPERTY: integer dtypes (wrapping arithmetic) serve identically
/// too — a different code path through the MAC accounting.
#[test]
fn prop_integer_service_identical_to_synchronous() {
    let m64 = generate::uniform::<f64>(128, 128, 5, 31);
    let mi: CooMatrix<i32> = m64.cast();
    let xs: Vec<Vec<i32>> = (0..5)
        .map(|b| (0..128).map(|i| ((i + b) % 7) as i32 - 3).collect())
        .collect();
    for spec in [KernelSpec::coo_nnz(), KernelSpec::csr_nnz(), KernelSpec::bcoo_nnz()] {
        let sys = PimSystem::with_dpus(8);
        let exec = SpmvExecutor::with_engine(sys.clone(), Engine::threaded(3));
        let plan = exec.plan(&spec, &mi).unwrap();
        let svc: SpmvService<i32> =
            ServiceBuilder::new().threads(3).build(sys).unwrap();
        let h = svc.load(&mi, &spec).unwrap();
        let b = svc.spmv_batch(&h, &xs).unwrap();
        assert_batch_identical(
            &b,
            &plan.execute_batch_runs(&exec, &xs).unwrap(),
            &format!("{} i32", spec.name),
        );
        let it = svc.iterate(&h, &xs[0], 4).unwrap();
        assert_iters_identical(
            &it,
            &plan.run_iterations(&exec, &xs[0], 4).unwrap(),
            &format!("{} i32 iterate", spec.name),
        );
    }
}

/// PROPERTY: many handles on one service stay isolated — interleaved
/// tickets against different matrices and specs answer from the right
/// plan.
#[test]
fn prop_multiple_handles_do_not_cross_talk() {
    let ma = generate::scale_free::<f64>(120, 120, 6, 0.6, 3);
    let mb = generate::uniform::<f64>(96, 96, 4, 9);
    let sys = PimSystem::with_dpus(8);
    let exec = SpmvExecutor::new(sys.clone());
    let plan_a = exec.plan(&KernelSpec::coo_nnz(), &ma).unwrap();
    let plan_b = exec.plan(&KernelSpec::csr_row(), &mb).unwrap();
    let svc: SpmvService<f64> = ServiceBuilder::new().build(sys).unwrap();
    let ha = svc.load(&ma, &KernelSpec::coo_nnz()).unwrap();
    let hb = svc.load(&mb, &KernelSpec::csr_row()).unwrap();
    let xa: Vec<f64> = (0..120).map(|i| (i % 9) as f64 - 4.0).collect();
    let xb: Vec<f64> = (0..96).map(|i| (i % 5) as f64 - 2.0).collect();
    let ta = svc.submit(ha, Request::spmv(xa.clone())).unwrap();
    let tb = svc.submit(hb, Request::spmv(xb.clone())).unwrap();
    let rb = svc.wait(tb).unwrap().into_spmv().unwrap();
    let ra = svc.wait(ta).unwrap().into_spmv().unwrap();
    assert_identical(&ra, &plan_a.execute(&exec, &xa).unwrap(), "handle a");
    assert_identical(&rb, &plan_b.execute(&exec, &xb).unwrap(), "handle b");
}
