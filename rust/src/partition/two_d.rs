//! 2D (tiled) partitioning across DPUs.
//!
//! The matrix is cut into `n_col_stripes` vertical stripes, each stripe
//! into row tiles, one tile per DPU. Each DPU then needs only the
//! x-slice of its stripe (not the whole vector — the 1D broadcast wall
//! disappears), but every stripe produces a *partial* y for its rows, so
//! the host must gather `n_col_stripes` partial vectors and reduce them
//! (the 2D retrieve/merge wall, amplified by the same-size padding rule).
//!
//! The paper's three 2D schemes:
//! * [`TwoDScheme::EquallySized`] (`DCSR`/`DCOO`/...): uniform grid —
//!   cheapest planning, worst compute balance;
//! * [`TwoDScheme::EquallyWide`] (`RBDCSR`/...): equal-width stripes,
//!   variable-height tiles balancing nnz within each stripe;
//! * [`TwoDScheme::BalancedNnz`] (`BDCSR`/...): variable-width stripes
//!   *and* variable-height tiles — best balance, raggedest transfers.

use super::balance::{split_even, split_weighted};
use crate::matrix::{CooMatrix, SpElem};
use std::ops::Range;

/// The paper's three 2D tile-shaping schemes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TwoDScheme {
    EquallySized,
    EquallyWide,
    BalancedNnz,
}

impl TwoDScheme {
    pub fn name(self) -> &'static str {
        match self {
            TwoDScheme::EquallySized => "equally-sized",
            TwoDScheme::EquallyWide => "equally-wide",
            TwoDScheme::BalancedNnz => "balanced-nnz",
        }
    }

    pub fn all() -> [TwoDScheme; 3] {
        [TwoDScheme::EquallySized, TwoDScheme::EquallyWide, TwoDScheme::BalancedNnz]
    }
}

/// One DPU's tile.
#[derive(Clone, Debug, PartialEq)]
pub struct Tile {
    /// Original row range covered.
    pub rows: Range<usize>,
    /// Original column range covered (also the x-slice sent to the DPU).
    pub cols: Range<usize>,
}

/// A 2D partition: tiles in stripe-major order.
#[derive(Clone, Debug)]
pub struct TwoDPartition {
    pub scheme: TwoDScheme,
    pub n_col_stripes: usize,
    /// Tiles per stripe (row tiles).
    pub n_row_tiles: usize,
    /// `tiles[s * n_row_tiles + i]` = row tile i of stripe s.
    pub tiles: Vec<Tile>,
    /// Max tile nnz / ideal tile nnz.
    pub imbalance: f64,
}

impl TwoDPartition {
    /// Which tile indices contribute partial sums for original row `r`?
    /// (One per stripe whose row tile covers r.)
    pub fn tiles_covering_row(&self, r: usize) -> Vec<usize> {
        let mut out = Vec::new();
        for (i, t) in self.tiles.iter().enumerate() {
            if t.rows.contains(&r) {
                out.push(i);
            }
        }
        out
    }
}

/// Plans 2D partitions.
pub struct TwoDPartitioner;

impl TwoDPartitioner {
    /// Plan a 2D partition of `m` across `n_dpus = n_col_stripes *
    /// n_row_tiles` DPUs. `n_dpus` must be divisible by `n_col_stripes`.
    pub fn plan<T: SpElem>(
        m: &CooMatrix<T>,
        n_dpus: usize,
        n_col_stripes: usize,
        scheme: TwoDScheme,
    ) -> crate::util::Result<TwoDPartition> {
        crate::ensure!(n_col_stripes > 0, "need at least one column stripe");
        crate::ensure!(
            n_dpus % n_col_stripes == 0,
            "n_dpus {n_dpus} not divisible by column stripes {n_col_stripes}"
        );
        let n_row_tiles = n_dpus / n_col_stripes;

        // Column stripe boundaries.
        let col_ranges: Vec<Range<usize>> = match scheme {
            TwoDScheme::EquallySized | TwoDScheme::EquallyWide => {
                split_even(m.ncols(), n_col_stripes)
            }
            TwoDScheme::BalancedNnz => {
                let mut col_w = vec![0usize; m.ncols()];
                for &c in &m.cols {
                    col_w[c as usize] += 1;
                }
                split_weighted(&col_w, n_col_stripes)
            }
        };

        // Row tile boundaries per stripe. The nnz-balanced schemes need
        // per-stripe row weights; compute them for ALL stripes in one
        // pass over the non-zeros (one binary search per element)
        // instead of one full scan per stripe (§Perf iteration 7).
        let per_stripe_weights: Vec<Vec<usize>> = if scheme == TwoDScheme::EquallySized {
            Vec::new()
        } else {
            let ends: Vec<usize> = col_ranges.iter().map(|cr| cr.end).collect();
            let mut w = vec![vec![0usize; m.nrows()]; n_col_stripes];
            for i in 0..m.nnz() {
                let s = ends.partition_point(|&e| e <= m.cols[i] as usize);
                w[s][m.rows[i] as usize] += 1;
            }
            w
        };
        let mut tiles = Vec::with_capacity(n_dpus);
        for (si, cr) in col_ranges.iter().enumerate() {
            let row_ranges: Vec<Range<usize>> = match scheme {
                TwoDScheme::EquallySized => split_even(m.nrows(), n_row_tiles),
                TwoDScheme::EquallyWide | TwoDScheme::BalancedNnz => {
                    split_weighted(&per_stripe_weights[si], n_row_tiles)
                }
            };
            for rr in row_ranges {
                tiles.push(Tile { rows: rr, cols: cr.clone() });
            }
        }

        // Imbalance: max tile nnz over ideal. O(nnz log) via boundary
        // binary searches instead of per-element linear scans (§Perf
        // iteration 5: this was 30% of the full characterization).
        let stripe_ends: Vec<usize> = col_ranges.iter().map(|cr| cr.end).collect();
        let tile_row_ends: Vec<Vec<usize>> = (0..n_col_stripes)
            .map(|s| {
                tiles[s * n_row_tiles..(s + 1) * n_row_tiles]
                    .iter()
                    .map(|t| t.rows.end)
                    .collect()
            })
            .collect();
        let mut tile_nnz = vec![0usize; tiles.len()];
        for i in 0..m.nnz() {
            let (r, c) = (m.rows[i] as usize, m.cols[i] as usize);
            let s = stripe_ends.partition_point(|&e| e <= c);
            let j = tile_row_ends[s].partition_point(|&e| e <= r);
            tile_nnz[s * n_row_tiles + j] += 1;
        }
        let ideal = m.nnz() as f64 / n_dpus as f64;
        let imbalance = if ideal == 0.0 {
            1.0
        } else {
            tile_nnz.iter().copied().max().unwrap_or(0) as f64 / ideal
        };

        Ok(TwoDPartition { scheme, n_col_stripes, n_row_tiles, tiles, imbalance })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate;

    #[test]
    fn tiles_partition_the_matrix() {
        let m = generate::uniform::<f64>(256, 256, 8, 1);
        for scheme in TwoDScheme::all() {
            let p = TwoDPartitioner::plan(&m, 16, 4, scheme).unwrap();
            assert_eq!(p.tiles.len(), 16);
            assert_eq!(p.n_row_tiles, 4);
            // Every (r, c) belongs to exactly one tile.
            for (r, c, _) in m.iter() {
                let n = p
                    .tiles
                    .iter()
                    .filter(|t| t.rows.contains(&(r as usize)) && t.cols.contains(&(c as usize)))
                    .count();
                assert_eq!(n, 1, "({r},{c}) in {n} tiles under {scheme:?}");
            }
        }
    }

    #[test]
    fn indivisible_dpus_rejected() {
        let m = generate::uniform::<f64>(64, 64, 4, 1);
        assert!(TwoDPartitioner::plan(&m, 10, 4, TwoDScheme::EquallySized).is_err());
    }

    #[test]
    fn balanced_schemes_improve_imbalance() {
        let m = generate::scale_free::<f64>(2048, 2048, 10, 0.8, 5);
        let eq = TwoDPartitioner::plan(&m, 64, 8, TwoDScheme::EquallySized).unwrap();
        let ew = TwoDPartitioner::plan(&m, 64, 8, TwoDScheme::EquallyWide).unwrap();
        let bn = TwoDPartitioner::plan(&m, 64, 8, TwoDScheme::BalancedNnz).unwrap();
        assert!(ew.imbalance <= eq.imbalance, "ew {} > eq {}", ew.imbalance, eq.imbalance);
        assert!(bn.imbalance <= eq.imbalance * 1.05, "bn {} >> eq {}", bn.imbalance, eq.imbalance);
    }

    #[test]
    fn one_stripe_degenerates_to_1d() {
        let m = generate::uniform::<f64>(128, 128, 4, 2);
        let p = TwoDPartitioner::plan(&m, 8, 1, TwoDScheme::EquallyWide).unwrap();
        assert_eq!(p.n_col_stripes, 1);
        assert!(p.tiles.iter().all(|t| t.cols == (0..128)));
    }

    #[test]
    fn tiles_covering_row_finds_all_stripes() {
        let m = generate::uniform::<f64>(64, 64, 4, 3);
        let p = TwoDPartitioner::plan(&m, 8, 4, TwoDScheme::EquallySized).unwrap();
        let covering = p.tiles_covering_row(10);
        assert_eq!(covering.len(), 4, "one tile per stripe covers row 10");
    }
}
