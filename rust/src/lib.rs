//! # SparseP (reproduction)
//!
//! A reproduction of *"Towards Efficient Sparse Matrix Vector Multiplication
//! on Real Processing-In-Memory Systems"* (Giannoula et al., 2022) — the
//! SparseP library of 25 SpMV kernels for near-bank PIM systems, together
//! with the substrate the paper runs on: a calibrated simulator of the
//! UPMEM PIM architecture (the first publicly-available real-world PIM
//! system), host CPU baselines, and an XLA/PJRT accelerator path fed by
//! AOT-compiled JAX/Pallas kernels.
//!
//! ## Layout
//!
//! * [`matrix`] — sparse matrix formats (COO/CSR/BCSR/BCOO), generators,
//!   MatrixMarket I/O and sparsity statistics.
//! * [`pim`] — the UPMEM-class PIM system simulator: DPU pipeline timing,
//!   WRAM/MRAM DMA model, tasklet synchronization costs, host<->PIM
//!   transfer collectives (with the real system's same-size padding rule)
//!   and the energy model.
//! * [`kernels`] — per-DPU SpMV kernels (format x tasklet-balancing x
//!   synchronization scheme), executed functionally with cycle accounting.
//! * [`partition`] — 1D and 2D matrix partitioning across DPUs, and
//!   tasklet-level load balancers.
//! * [`coordinator`] — the host-side library. The serving front door is
//!   [`coordinator::SpmvService`]: a builder-configured, long-lived
//!   service that owns the plan cache and the execution engine;
//!   matrices are registered once ([`coordinator::SpmvService::load`]
//!   -> [`coordinator::MatrixHandle`], content-fingerprinted), and
//!   typed requests ([`coordinator::Request`]) flow through a pipelined
//!   worker queue ([`coordinator::SpmvService::submit`] ->
//!   [`coordinator::Ticket`] / [`coordinator::SpmvService::wait`]).
//!   Underneath: [`coordinator::SpmvExecutor::plan`] partitions +
//!   converts + prices transfers once per (matrix, kernel) pair, and
//!   [`coordinator::ExecutionPlan::execute`] runs the per-DPU kernels —
//!   serially or on host threads via [`coordinator::Engine`] — and
//!   produces the paper's load/kernel/retrieve/merge breakdowns.
//!   Batched (SpMM-style) execution streams each matrix slice once per
//!   vector block, with the width set by a
//!   [`coordinator::BlockPolicy`]; everything is bit-identical to
//!   synchronous serial execution. One level up,
//!   [`coordinator::ShardedService`] shards one logical matrix's rows
//!   across several backend services (simulated rank groups sharing one
//!   plan cache) with scatter/gather request routing and a
//!   deterministic weighted-round-robin multi-tenant scheduler
//!   ([`coordinator::scheduler`]) — gathered outputs stay bit-identical
//!   to the unsharded path (`tests/shard_equivalence.rs`).
//! * [`baselines`] — processor-centric comparators (multithreaded host CPU
//!   SpMV; analytic CPU/GPU roofline models).
//! * [`runtime`] — PJRT runtime that loads AOT artifacts (HLO text) built
//!   by `python/compile/aot.py` and executes them from Rust.
//! * [`bench_harness`] — a small measurement harness (criterion is not
//!   available offline) + per-figure drivers for the paper's evaluation.
//!
//! ## Quickstart: load once, serve many
//!
//! Serving workloads (and the iterative apps in [`apps`] — CG, Jacobi,
//! PageRank: hundreds of SpMVs on one matrix) register the matrix once
//! and stream requests against the handle; that mirrors the paper's
//! cost model, where matrix placement is a one-time cost and only the
//! input vector moves per request:
//!
//! ```no_run
//! use sparsep::matrix::generate;
//! use sparsep::pim::PimSystem;
//! use sparsep::coordinator::{KernelSpec, Request, ServiceBuilder};
//!
//! let m = generate::scale_free::<f32>(10_000, 10_000, 8, 0.6, 7);
//! // Pooled engine + pipelined request queue: wall-clock knobs only,
//! // responses are bit-identical to synchronous serial execution.
//! // `.threads(0)` is the persistent worker-pool engine
//! // (`coordinator::PooledEngine`) on all cores — waves run on
//! // long-lived workers, never paying thread spawn/join per request.
//! let svc = ServiceBuilder::new()
//!     .threads(0)
//!     .build::<f32>(PimSystem::with_dpus(256))
//!     .unwrap();
//!
//! // Load once: partitioning, per-DPU format conversion, per-tasklet
//! // splits and transfer sizing — content-fingerprinted through the
//! // service's plan cache.
//! let h = svc.load(&m, &KernelSpec::csr_nnz()).unwrap();
//!
//! // Serve many: typed requests, tickets claimable in any order.
//! // Payloads are shared `Arc<[T]>` slices — `Vec<T>` converts in, and
//! // an Arc you already hold is shared, never copied (a sharded
//! // facade's scatter hands the same allocation to every shard).
//! let x = vec![1.0f32; m.ncols()];
//! let t1 = svc.submit(h, Request::spmv(x.clone())).unwrap();
//! let t2 = svc.submit(h, Request::batch(
//!     (0..32).map(|_| x.clone()).collect::<Vec<_>>(),
//! )).unwrap();
//! let t3 = svc.submit(h, Request::iterate(x.clone(), 50)).unwrap();
//!
//! let batch = svc.wait(t2).unwrap().into_batch().unwrap();
//! println!("{} outputs, {:.3} ms modeled", batch.len(), batch.total().total_s() * 1e3);
//! let run = svc.wait(t1).unwrap().into_spmv().unwrap();
//! println!("y[0]={} breakdown={:?}", run.y[0], run.breakdown);
//! let iterated = svc.wait(t3).unwrap().into_iterations().unwrap();
//! println!("50 iterations: {:.3} ms total", iterated.total.total_s() * 1e3);
//! ```
//!
//! For one-shot synchronous execution, plan directly:
//! `exec.plan(&spec, &m)?` then [`coordinator::ExecutionPlan::execute`]
//! — the service's responses are bit-identical to that path by
//! construction (locked by `tests/service_equivalence.rs`).
//!
//! The full picture — the sharded multi-tenant tier, service / request
//! / queue layer, plan → execute → merge pipeline, the batched path and
//! the plan cache — is documented with data-flow diagrams in
//! `docs/ARCHITECTURE.md` at the repository root.

// Unsafe-code audit (docs/ARCHITECTURE.md "Concurrency model &
// verification"): every unsafe operation must sit in its own `unsafe`
// block with a written `// SAFETY:` contract, even inside an `unsafe
// fn` — the only unsafe code in the crate is the lifetime-erased
// `TaskPtr` protocol in `coordinator::engine`.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod util;
pub mod matrix;
pub mod pim;
pub mod kernels;
pub mod partition;
pub mod coordinator;
pub mod net;
pub mod apps;
pub mod baselines;
pub mod runtime;
pub mod bench_harness;
pub mod cli;

pub use matrix::dtype::{DType, SpElem};
