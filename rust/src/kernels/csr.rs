//! CSR DPU kernel.
//!
//! The SparseP CSR DPU program: each tasklet owns a contiguous row range
//! of the DPU's local matrix slice (rows are never split, so no output
//! synchronization is needed — CSR kernels are always lock-free). The
//! tasklet streams its row pointers / column indices / values MRAM->WRAM
//! in 2 KB tiles, gathers x[col] from MRAM per non-zero, accumulates in
//! WRAM and writes its y range back.
//!
//! Balancing across tasklets is `Rows` (equal row counts: cheap, but
//! collapses on skewed matrices) or `Nnz` (equal non-zeros at row
//! granularity — the paper's `CSR.nnz`).

use super::{acct, DpuKernelOutput, SyncScheme, TaskletBalance};
use crate::matrix::{CsrMatrix, SpElem};
use crate::partition::balance::{split_even, split_weighted};
use crate::pim::{PimConfig, TaskletCounters};

/// Run the CSR kernel on one DPU.
///
/// `slice` is the DPU-local matrix (rows re-indexed to 0); `x` is the
/// DPU-local input vector (the full vector for 1D partitioning, a column
/// slice for 2D). `sync` is accepted for interface uniformity but CSR is
/// row-granular and therefore lock-free by construction.
pub fn run_csr_dpu<T: SpElem>(
    cfg: &PimConfig,
    slice: &CsrMatrix<T>,
    x: &[T],
    bal: TaskletBalance,
    sync: SyncScheme,
) -> DpuKernelOutput<T> {
    run_csr_dpu_cached(cfg, slice, x, &csr_split(slice, cfg.tasklets, bal), sync)
}

/// [`run_csr_dpu`] with a precomputed [`CsrSplit`] — the plan-time-split
/// entry point: [`crate::coordinator::ExecutionPlan`] caches the split
/// per work item so repeated invocations (iterative apps, batched
/// serving) skip the O(nrows) weight scan + `split_weighted` pass.
/// `split` must have been computed for `cfg.tasklets` tasklets.
pub fn run_csr_dpu_cached<T: SpElem>(
    cfg: &PimConfig,
    slice: &CsrMatrix<T>,
    x: &[T],
    split: &CsrSplit,
    _sync: SyncScheme,
) -> DpuKernelOutput<T> {
    assert_eq!(x.len(), slice.ncols(), "x length mismatch");
    let t = cfg.tasklets;
    debug_assert_eq!(split.tasklets, t, "split cached for a different tasklet count");
    let ranges = &split.ranges;

    let mut y = vec![T::zero(); slice.nrows()];
    let mut counters = vec![TaskletCounters::default(); t];
    let dt = T::DTYPE;

    for (tid, range) in ranges.iter().enumerate() {
        let c = &mut counters[tid];
        if range.is_empty() {
            continue;
        }
        // Matrix bytes this tasklet streams: its row_ptr window, plus its
        // cols + vals windows.
        let nnz_here: usize = range.clone().map(|r| slice.row_nnz(r)).sum();
        acct::stream_matrix(
            c,
            (range.len() + 1) * 4 + nnz_here * (4 + dt.size_bytes()),
        );
        for r in range.clone() {
            acct::row(c);
            let (cols, vals) = slice.row(r);
            let mut acc = T::zero();
            for (col, v) in cols.iter().zip(vals) {
                acct::element(c, dt);
                acc = T::mac(acc, *v, x[*col as usize]);
            }
            y[r] = acc;
        }
        acct::writeback(c, range.len(), dt);
    }

    DpuKernelOutput::finish(cfg, y, counters)
}

/// Plan-time per-tasklet split for the CSR kernel: the row ranges for
/// one tasklet count under one balancing scheme. Computing it costs an
/// O(nrows) weight scan for `Nnz` balancing, which is why the execution
/// plan caches one per work item instead of re-splitting per kernel
/// invocation.
#[derive(Clone, Debug)]
pub struct CsrSplit {
    /// Tasklet count the ranges were computed for.
    pub(crate) tasklets: usize,
    pub(crate) ranges: Vec<std::ops::Range<usize>>,
}

/// Compute the per-tasklet row split — shared by the single-vector and
/// batched entry points (and cached at plan time) so every walk splits
/// identically.
pub fn csr_split<T: SpElem>(slice: &CsrMatrix<T>, t: usize, bal: TaskletBalance) -> CsrSplit {
    let ranges = match bal {
        TaskletBalance::Rows => split_even(slice.nrows(), t),
        TaskletBalance::Nnz => {
            let weights: Vec<usize> = (0..slice.nrows()).map(|r| slice.row_nnz(r)).collect();
            split_weighted(&weights, t)
        }
        other => panic!("CSR kernel does not support {:?} tasklet balancing", other),
    };
    CsrSplit { tasklets: t, ranges }
}

/// Run the CSR kernel on one DPU for a whole block of input vectors.
///
/// Fused SpMM-style variant of [`run_csr_dpu`]: the matrix slice is
/// walked once and every vector's accumulator advances per non-zero, so
/// the host-side simulation streams the slice (and runs the cycle
/// accounting) once per *block* instead of once per *vector*. Results
/// are bit-identical to calling [`run_csr_dpu`] once per vector: the
/// per-vector MAC chains are evaluated in the same order, and the
/// accounting is structure-only (see `finish_batch` in the module root).
///
/// The tasklet walk below deliberately mirrors [`run_csr_dpu`]'s (a
/// shared walk would put a per-element vector loop on the single-vector
/// hot path): any change to the accounting sequence there must be
/// mirrored here, and `tests/batch_equivalence.rs` fails on any drift.
pub fn run_csr_dpu_batch<T: SpElem>(
    cfg: &PimConfig,
    slice: &CsrMatrix<T>,
    xs: &[&[T]],
    bal: TaskletBalance,
    sync: SyncScheme,
) -> Vec<DpuKernelOutput<T>> {
    run_csr_dpu_batch_cached(cfg, slice, xs, &csr_split(slice, cfg.tasklets, bal), sync)
}

/// [`run_csr_dpu_batch`] with a precomputed [`CsrSplit`] (see
/// [`run_csr_dpu_cached`]).
pub fn run_csr_dpu_batch_cached<T: SpElem>(
    cfg: &PimConfig,
    slice: &CsrMatrix<T>,
    xs: &[&[T]],
    split: &CsrSplit,
    sync: SyncScheme,
) -> Vec<DpuKernelOutput<T>> {
    if xs.is_empty() {
        return Vec::new();
    }
    if xs.len() == 1 {
        return vec![run_csr_dpu_cached(cfg, slice, xs[0], split, sync)];
    }
    for x in xs {
        assert_eq!(x.len(), slice.ncols(), "x length mismatch");
    }
    let t = cfg.tasklets;
    debug_assert_eq!(split.tasklets, t, "split cached for a different tasklet count");
    let nb = xs.len();
    let dt = T::DTYPE;
    let ranges = &split.ranges;
    let mut ys: Vec<Vec<T>> = (0..nb).map(|_| vec![T::zero(); slice.nrows()]).collect();
    let mut counters = vec![TaskletCounters::default(); t];
    let mut accs: Vec<T> = vec![T::zero(); nb];

    for (tid, range) in ranges.iter().enumerate() {
        let c = &mut counters[tid];
        if range.is_empty() {
            continue;
        }
        let nnz_here: usize = range.clone().map(|r| slice.row_nnz(r)).sum();
        acct::stream_matrix(
            c,
            (range.len() + 1) * 4 + nnz_here * (4 + dt.size_bytes()),
        );
        for r in range.clone() {
            acct::row(c);
            let (cols, vals) = slice.row(r);
            accs.fill(T::zero());
            for (col, v) in cols.iter().zip(vals) {
                acct::element(c, dt);
                let xi = *col as usize;
                for (b, acc) in accs.iter_mut().enumerate() {
                    *acc = T::mac(*acc, *v, xs[b][xi]);
                }
            }
            for (b, acc) in accs.iter().enumerate() {
                ys[b][r] = *acc;
            }
        }
        acct::writeback(c, range.len(), dt);
    }

    super::finish_batch(cfg, ys, counters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{generate, CooMatrix};

    fn cfg(t: usize) -> PimConfig {
        PimConfig { tasklets: t, ..Default::default() }
    }

    fn check_correct(m: &CooMatrix<f64>, t: usize, bal: TaskletBalance) {
        let csr = CsrMatrix::from_coo(m);
        let x: Vec<f64> = (0..m.ncols()).map(|i| (i % 7) as f64 - 3.0).collect();
        let out = run_csr_dpu(&cfg(t), &csr, &x, bal, SyncScheme::LockFree);
        assert_eq!(out.y, csr.spmv(&x));
    }

    #[test]
    fn correct_for_all_tasklet_counts() {
        let m = generate::scale_free::<f64>(300, 300, 6, 0.6, 3);
        for t in [1, 2, 8, 16, 24] {
            check_correct(&m, t, TaskletBalance::Rows);
            check_correct(&m, t, TaskletBalance::Nnz);
        }
    }

    #[test]
    fn correct_on_empty_rows() {
        let m = CooMatrix::from_triples(5, 5, vec![(4, 4, 2.0f64)]);
        check_correct(&m, 4, TaskletBalance::Nnz);
    }

    #[test]
    fn nnz_balancing_reduces_imbalance_on_skewed_matrix() {
        let m = generate::scale_free::<f64>(2000, 2000, 10, 0.7, 5);
        let csr = CsrMatrix::from_coo(&m);
        let x = vec![1.0; 2000];
        let c = cfg(16);
        let rows = run_csr_dpu(&c, &csr, &x, TaskletBalance::Rows, SyncScheme::LockFree);
        let nnz = run_csr_dpu(&c, &csr, &x, TaskletBalance::Nnz, SyncScheme::LockFree);
        // Paper Fig. 5: nnz balancing is faster on scale-free inputs.
        assert!(
            nnz.timing.cycles < rows.timing.cycles,
            "nnz {} !< rows {}",
            nnz.timing.cycles,
            rows.timing.cycles
        );
    }

    #[test]
    fn more_tasklets_help_until_knee() {
        let m = generate::banded::<f64>(4096, 16, 2);
        let csr = CsrMatrix::from_coo(&m);
        let x = vec![1.0; 4096];
        let c1 = run_csr_dpu(&cfg(1), &csr, &x, TaskletBalance::Rows, SyncScheme::LockFree);
        let c8 = run_csr_dpu(&cfg(8), &csr, &x, TaskletBalance::Rows, SyncScheme::LockFree);
        assert!(c8.timing.cycles < c1.timing.cycles);
    }

    #[test]
    fn spmv_is_memory_bound() {
        // The paper's headline single-DPU observation: SpMV is bound by
        // MRAM access, not the pipeline, for the fp32 CSR kernel at 16
        // tasklets... for int8 where MACs are cheap. For fp64 the
        // software float emulation can flip it to pipeline-bound.
        let m = generate::uniform::<f64>(1024, 1024, 8, 3);
        let mi: CooMatrix<i8> = m.cast();
        let csr = CsrMatrix::from_coo(&mi);
        let x = vec![1i8; 1024];
        let out = run_csr_dpu(&cfg(16), &csr, &x, TaskletBalance::Nnz, SyncScheme::LockFree);
        assert_eq!(out.timing.bottleneck(), "mram-dma");
    }

    #[test]
    fn batch_matches_looped_single_vector() {
        let m = generate::scale_free::<f64>(200, 200, 6, 0.6, 41);
        let csr = CsrMatrix::from_coo(&m);
        let xs: Vec<Vec<f64>> = (0..5)
            .map(|b| (0..200).map(|i| ((i + 3 * b) % 9) as f64 - 4.0).collect())
            .collect();
        let refs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
        for bal in [TaskletBalance::Rows, TaskletBalance::Nnz] {
            let batch = run_csr_dpu_batch(&cfg(8), &csr, &refs, bal, SyncScheme::LockFree);
            assert_eq!(batch.len(), 5);
            for (x, out) in xs.iter().zip(&batch) {
                let single = run_csr_dpu(&cfg(8), &csr, x, bal, SyncScheme::LockFree);
                assert_eq!(out.y, single.y, "{bal:?}: y differs");
                assert_eq!(out.counters, single.counters, "{bal:?}: counters differ");
                assert_eq!(out.timing, single.timing, "{bal:?}: timing differs");
            }
        }
        // Degenerate batches.
        assert!(run_csr_dpu_batch(&cfg(4), &csr, &[], TaskletBalance::Nnz, SyncScheme::LockFree)
            .is_empty());
        let one = run_csr_dpu_batch(&cfg(4), &csr, &refs[..1], TaskletBalance::Nnz, SyncScheme::LockFree);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].y, csr.spmv(&xs[0]));
    }

    #[test]
    fn counters_cover_all_nnz() {
        let m = generate::uniform::<f32>(256, 256, 4, 9);
        let csr = CsrMatrix::from_coo(&m);
        let x = vec![1.0f32; 256];
        let out = run_csr_dpu(&cfg(8), &csr, &x, TaskletBalance::Nnz, SyncScheme::LockFree);
        // Each nnz performs one x-gather DMA (8B min) plus streamed
        // matrix bytes; so dma_transfers >= nnz.
        let total_dma: u64 = out.counters.iter().map(|c| c.dma_transfers).sum();
        assert!(total_dma >= m.nnz() as u64);
    }
}
