#!/usr/bin/env bash
# Coordinator perf smoke: wall-clock of 50 plan-once CG iterations on a
# 100k x 100k scale-free SPD system, serial vs threaded engine. Emits
# BENCH_coordinator.json at the repo root so successive PRs can track
# the perf trajectory. Knobs:
#
#   BENCH_ROWS   (default 100000)   matrix dimension
#   BENCH_ITERS  (default 50)       CG iterations
#   BENCH_DPUS   (default 256)      simulated DPU count
#   BENCH_THREADS (default: nproc)  threaded-engine workers
set -euo pipefail
cd "$(dirname "$0")/.."

THREADS="${BENCH_THREADS:-$(nproc 2>/dev/null || echo 4)}"

cargo run --release -- bench-coordinator \
  --rows "${BENCH_ROWS:-100000}" \
  --deg 8 \
  --iters "${BENCH_ITERS:-50}" \
  --dpus "${BENCH_DPUS:-256}" \
  --threads "$THREADS" \
  --out BENCH_coordinator.json

cat BENCH_coordinator.json
