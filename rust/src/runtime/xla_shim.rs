//! Offline shim for the `xla` PJRT bindings.
//!
//! The real PJRT path needs the `xla` crate (Rust bindings over
//! libxla), which is not in the offline vendor set. This shim exposes
//! the exact type/method surface [`super`] uses so the runtime module
//! compiles unchanged; [`PjRtClient::cpu`] fails with a clear message,
//! so every artifact-backed path degrades to "skipped: PJRT
//! unavailable" (the examples and CLI already handle that). Dropping
//! the real crate back in is a one-line change in `runtime/mod.rs`.

use crate::util::{Error, Result};

fn unavailable() -> Error {
    Error::msg(
        "XLA/PJRT backend unavailable: the `xla` crate is not in the offline vendor set \
         (vendor it and switch runtime/mod.rs off the shim to enable the AOT artifact path)",
    )
}

/// Uninhabited: proves at the type level that no PJRT object can exist
/// under the shim, so post-construction methods are unreachable.
enum Never {}

pub struct PjRtClient {
    never: Never,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        match self.never {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.never {}
    }
}

pub struct PjRtLoadedExecutable {
    never: Never,
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.never {}
    }
}

pub struct PjRtBuffer {
    never: Never,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.never {}
    }
}

/// Host-side literal placeholder. Constructible (callers build inputs
/// before executing), but every operation that would need real XLA
/// data fails with the `unavailable` error above.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("unavailable"));
    }

    #[test]
    fn literals_construct_but_do_not_read() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
