//! The SparseP host coordinator.
//!
//! The serving front door is [`SpmvService`]: a builder-configured,
//! long-lived service that owns the [`PlanCache`] and the execution
//! engine. Matrices are registered once with
//! [`SpmvService::load`] -> [`MatrixHandle`] (content-fingerprinted,
//! cache-backed); work is submitted as typed requests —
//! [`Request::Spmv`], [`Request::Batch`], [`Request::Iterate`] —
//! through [`SpmvService::submit`] -> [`Ticket`] /
//! [`SpmvService::wait`] -> [`Response`]. A worker-thread request queue
//! ([`queue`]) pipelines the plan/load, kernel, and retrieve/merge
//! stages across queued requests and across vector blocks;
//! responses are bit-identical to the synchronous path (locked by
//! `tests/service_equivalence.rs`).
//!
//! Underneath the service sits an explicit three-stage pipeline:
//!
//! 1. **Plan** ([`SpmvExecutor::plan`] -> [`ExecutionPlan`]): given a
//!    [`KernelSpec`] and a sparse matrix, partition the matrix across
//!    DPUs (1D or 2D), convert every per-DPU slice to the kernel's
//!    compressed format, and price the transfers (one-time matrix
//!    placement, per-iteration vector load, output gather, host merge).
//!    All of it depends only on the matrix and the spec — never on the
//!    input vector — so iterative apps do it exactly once.
//! 2. **Execute** ([`ExecutionPlan::execute`]): run the per-DPU kernels
//!    (exactly, with cycle accounting) over an input vector through an
//!    [`Engine`] — serially or on real host threads — then merge
//!    partials and return the exact output together with the paper's
//!    load/kernel/retrieve/merge breakdown, structural statistics and
//!    energy estimate. Results are bit-identical across engines.
//! 3. **Iterate** ([`ExecutionPlan::run_iterations`]): repeated
//!    self-application `y <- A*y` with accumulated cost, the shape of
//!    every solver in [`crate::apps`].
//!
//! Batched (SpMM-style) execution fans (work-item x vector-block)
//! units across the engine; every kernel streams each matrix slice
//! once per block instead of once per vector, and the block width is
//! set by a [`BlockPolicy`]. The [`PlanCache`] keys plans by (matrix
//! fingerprint, kernel spec, system shape) with single-flight builds,
//! so concurrent requests for an equal matrix plan exactly once.
//!
//! Above the single service sits the multi-rank serving tier:
//! [`ShardedService`] ([`shard`]) splits one logical matrix across an
//! R×C [`GridSpec`] grid of backend services (row bands × nnz-balanced
//! column tiles, optionally replicated per tile for read scaling; one
//! backend per simulated rank group, sharing one plan cache), scatters
//! each request, gathers and merges/reduces the partial responses
//! (bit-identical outputs to the unsharded path —
//! `tests/shard_equivalence.rs` and `tests/grid_equivalence.rs`), and
//! admits multi-tenant traffic
//! through a deterministic weighted-round-robin scheduler with
//! per-tenant in-flight quotas ([`scheduler`]). The sharded tier is
//! chaos-tested: seed-reproducible fault injection ([`fault`]) drives
//! shard supervision (kill -> respawn from the shared plan cache ->
//! re-scatter), while deadline-aware dispatch, per-tenant latency
//! histograms and typed load shedding ([`Response::Overloaded`]) give
//! it production semantics (locked by `tests/chaos_equivalence.rs`).
//!
//! The hand-tuned selection knobs (kernel heuristics, vector-block
//! cutoffs, shard count) can be replaced wholesale by measurement: the
//! offline search loop in [`tuner`] sweeps kernel × block × shard-grid
//! × replica configurations over the generated suite and persists the
//! winners in
//! a checksummed [`calibration::CalibrationTable`]; at serve time
//! [`adaptive::select_auto`], the service's block resolution, and
//! [`ShardedServiceBuilder::shards_for_matrix`] consult it by
//! nearest-neighbor over sparsity statistics, falling back to the
//! heuristics when no table is loaded.
//!
//! The historical `SpmvExecutor::{execute, execute_batch,
//! run_iterations, run_iterations_batch, run}` entry points remain as
//! thin deprecated wrappers over the same one-shot execution path the
//! service drives; new code should hold a service (serving) or an
//! [`ExecutionPlan`] (synchronous). See `docs/ARCHITECTURE.md` for the
//! full data-flow picture.

pub mod adaptive;
pub mod cache;
pub mod calibration;
pub mod engine;
pub mod fault;
pub mod metrics;
pub mod plan;
pub mod queue;
pub mod scheduler;
pub mod service;
pub mod shard;
pub mod spec;
pub mod tuner;
#[cfg(loom)]
pub mod verify;

pub use cache::PlanCache;
pub use calibration::{CalibrationEntry, CalibrationTable};
pub use engine::{Engine, ExecutionEngine, PooledEngine, SerialEngine, ThreadedEngine};
pub use fault::{Fault, FaultInjector, FaultPlan, Scenario};
pub use metrics::{
    BatchIterationsResult, BatchResult, Breakdown, IterationsResult, LatencyHistogram,
    LatencySnapshot, RunResult, RunStats, ServiceStats, ShardedStats, TenantStats,
};
pub use plan::{DpuSlice, ExecutionPlan, WorkItem};
pub use scheduler::{FairScheduler, TenantId, TenantSpec};
pub use service::{
    BlockPolicy, MatrixHandle, Request, Response, ServiceBuilder, SpmvService, Ticket,
};
pub use shard::{
    plan_shards, plan_shards_counted, GridSpec, ScheduleLog, ShardedHandle, ShardedService,
    ShardedServiceBuilder, ShardedTicket,
};
pub use spec::{KernelSpec, Partitioning};
pub use tuner::{tune, TuneOpts, TuneReport, TuneRow};

use crate::kernels::{self, DpuKernelOutput};
use crate::matrix::{CooMatrix, SpElem};
use crate::pim::{calib, Energy, PimSystem};
use crate::util::Result;
use std::ops::Range;

/// Default vectors per batched kernel invocation: batched execution
/// splits a batch into blocks of this many vectors and schedules one
/// (work-item x vector-block) unit per block per DPU slice. The value
/// trades scheduling freedom (more, smaller units) against matrix-stream
/// amortization (each unit walks its slice once for the whole block);
/// the last block of a batch may be smaller ("ragged"). [`SpmvService`]
/// replaces this constant with a [`BlockPolicy`] resolved per batch;
/// the block width never affects results, only wall-clock.
pub const VECTOR_BLOCK: usize = 8;

/// Host-side SpMV executor over a (simulated) PIM system.
#[derive(Clone, Debug)]
pub struct SpmvExecutor {
    pub sys: PimSystem,
    /// How per-DPU kernel simulations are driven (serial or threaded);
    /// never affects results, only wall-clock.
    pub engine: Engine,
}

impl SpmvExecutor {
    /// Executor with the default (serial) engine.
    pub fn new(sys: PimSystem) -> Self {
        SpmvExecutor { sys, engine: Engine::Serial }
    }

    /// Executor with an explicit engine.
    pub fn with_engine(sys: PimSystem, engine: Engine) -> Self {
        SpmvExecutor { sys, engine }
    }

    /// Shorthand: threaded engine with `threads` workers (0 = all cores).
    pub fn threaded(sys: PimSystem, threads: usize) -> Self {
        Self::with_engine(sys, Engine::threaded(threads))
    }

    /// Plan `spec` over `m` once: partition, convert per-DPU slices,
    /// price transfers. Reuse the plan across [`Self::execute`] calls.
    pub fn plan<T: SpElem>(
        &self,
        spec: &KernelSpec,
        m: &CooMatrix<T>,
    ) -> Result<ExecutionPlan<T>> {
        plan::build(&self.sys.cfg, spec, m)
    }

    /// Shared execute-time compatibility checks: plans may legitimately
    /// be executed on a different executor (e.g. sweeping tasklet counts
    /// over one plan), so validate this executor's config too, not just
    /// the planning one's — and reject executors whose bus model
    /// disagrees with the one the plan's transfer costs were priced
    /// under.
    fn check_plan<T: SpElem>(&self, plan: &ExecutionPlan<T>) -> Result<()> {
        crate::ensure!(
            plan.n_dpus == self.sys.cfg.n_dpus,
            "plan was built for {} DPUs but the executor has {}",
            plan.n_dpus,
            self.sys.cfg.n_dpus
        );
        self.sys.cfg.validate()?;
        crate::ensure!(
            plan.dpus_per_rank == self.sys.cfg.dpus_per_rank
                && plan.bus_scale == self.sys.cfg.bus_scale,
            "plan priced transfers for dpus_per_rank={} bus_scale={} but the executor has dpus_per_rank={} bus_scale={}; re-plan on this executor",
            plan.dpus_per_rank,
            plan.bus_scale,
            self.sys.cfg.dpus_per_rank,
            self.sys.cfg.bus_scale
        );
        Ok(())
    }

    /// Execute one SpMV `y = A * x` over a prebuilt plan.
    #[deprecated(
        note = "call ExecutionPlan::execute for the synchronous path, or route requests through coordinator::SpmvService"
    )]
    pub fn execute<T: SpElem>(
        &self,
        plan: &ExecutionPlan<T>,
        x: &[T],
    ) -> Result<RunResult<T>> {
        self.execute_inner(plan, x)
    }

    /// Shared synchronous single-vector execution (the body behind both
    /// the deprecated [`Self::execute`] wrapper and
    /// [`ExecutionPlan::execute`]).
    pub(crate) fn execute_inner<T: SpElem>(
        &self,
        plan: &ExecutionPlan<T>,
        x: &[T],
    ) -> Result<RunResult<T>> {
        crate::ensure!(
            x.len() == plan.ncols(),
            "x length {} != ncols {}",
            x.len(),
            plan.ncols()
        );
        self.check_plan(plan)?;
        let cfg = &self.sys.cfg;
        let spec = &plan.spec;
        let items = plan.items();

        // Kernel simulations fan out across the engine; everything after
        // this line is serial and in item order, so results do not depend
        // on the engine or on thread scheduling.
        let outputs: Vec<DpuKernelOutput<T>> =
            self.engine.map_indexed(items.len(), |i| plan::run_item(cfg, spec, &items[i], x));

        let y = plan.merge_partials(&outputs);
        Ok(self.finish(plan, &outputs, y))
    }

    /// Execute a batched SpMM-style run `Y = A * X` over a prebuilt
    /// plan: one full [`RunResult`] per vector in `xs`, in input order,
    /// each bit-identical to a single-vector [`Self::execute`] of the
    /// same plan (locked by `tests/batch_equivalence.rs`).
    ///
    /// The batch is split into [`VECTOR_BLOCK`]-sized vector blocks and
    /// every (work-item, block) pair becomes one engine unit, so:
    ///
    /// * batches scale across host threads even when the DPU count alone
    ///   would leave workers idle, and the whole batch costs one thread
    ///   fan-out instead of one per vector;
    /// * the CSR/COO batched kernels stream each DPU slice once per
    ///   block instead of once per vector (see
    ///   [`crate::kernels::csr::run_csr_dpu_batch`]).
    ///
    /// An empty `xs` yields an empty result.
    #[deprecated(
        note = "call ExecutionPlan::execute_batch_runs for the synchronous path, or submit Request::Batch to coordinator::SpmvService"
    )]
    pub fn execute_batch<T: SpElem>(
        &self,
        plan: &ExecutionPlan<T>,
        xs: &[Vec<T>],
    ) -> Result<BatchResult<T>> {
        self.execute_batch_inner(plan, xs, VECTOR_BLOCK)
    }

    /// Shared synchronous batched execution with an explicit vector-block
    /// width (the body behind the deprecated [`Self::execute_batch`]
    /// wrapper, [`ExecutionPlan::execute_batch_runs`] and the service's
    /// [`BlockPolicy`]-sized batches). The block width shapes engine
    /// units only; results are block-independent.
    pub(crate) fn execute_batch_inner<T: SpElem>(
        &self,
        plan: &ExecutionPlan<T>,
        xs: &[Vec<T>],
        block: usize,
    ) -> Result<BatchResult<T>> {
        for (i, x) in xs.iter().enumerate() {
            crate::ensure!(
                x.len() == plan.ncols(),
                "xs[{i}] length {} != ncols {}",
                x.len(),
                plan.ncols()
            );
        }
        self.check_plan(plan)?;
        if xs.is_empty() {
            return Ok(BatchResult { runs: Vec::new() });
        }
        let block = block.max(1);
        let cfg = &self.sys.cfg;
        let spec = &plan.spec;
        let items = plan.items();
        let n_items = items.len();
        let blocks: Vec<Range<usize>> = (0..xs.len())
            .step_by(block)
            .map(|s| s..(s + block).min(xs.len()))
            .collect();

        // Per-block vector windows, built once here — not once per
        // (item, block) unit inside the engine fan-out.
        let windows: Vec<Vec<&[T]>> = blocks
            .iter()
            .map(|blk| xs[blk.clone()].iter().map(|x| x.as_slice()).collect())
            .collect();

        // (work-item x vector-block) units fan out across the engine in
        // one wave; unit u covers item (u % n_items) for block
        // (u / n_items). Reassembly below is by index, so results stay
        // engine- and scheduling-independent.
        let n_units = n_items * blocks.len();
        let unit_outputs: Vec<Vec<DpuKernelOutput<T>>> =
            self.engine.map_indexed(n_units, |u| {
                plan::run_item_batch(cfg, spec, &items[u % n_items], &windows[u / n_items])
            });

        // Regroup: unit (b, i) holds item i's outputs for block b's
        // vectors; each vector merges through the same per-plan merge as
        // the single-vector path.
        let mut runs = Vec::with_capacity(xs.len());
        let mut unit_iter = unit_outputs.into_iter();
        for blk in &blocks {
            let mut per_item: Vec<std::vec::IntoIter<DpuKernelOutput<T>>> = (0..n_items)
                .map(|_| unit_iter.next().expect("unit count mismatch").into_iter())
                .collect();
            for _ in blk.clone() {
                let outputs: Vec<DpuKernelOutput<T>> = per_item
                    .iter_mut()
                    .map(|it| it.next().expect("batched kernel returned too few outputs"))
                    .collect();
                let y = plan.merge_partials(&outputs);
                runs.push(self.finish(plan, &outputs, y));
            }
        }
        Ok(BatchResult { runs })
    }

    /// Iterated SpMV `y <- A*y`, `iters` times starting from `x`, over a
    /// prebuilt plan (requires a square matrix for `iters > 1`). Returns
    /// the final run plus cost totals across all iterations — the
    /// plan-once/execute-many usage iterative solvers are built on.
    #[deprecated(
        note = "call ExecutionPlan::run_iterations for the synchronous path, or submit Request::Iterate to coordinator::SpmvService"
    )]
    pub fn run_iterations<T: SpElem>(
        &self,
        plan: &ExecutionPlan<T>,
        x: &[T],
        iters: usize,
    ) -> Result<IterationsResult<T>> {
        self.run_iterations_inner(plan, x, iters)
    }

    /// Shared synchronous iterated execution (the body behind the
    /// deprecated [`Self::run_iterations`] wrapper and
    /// [`ExecutionPlan::run_iterations`]).
    pub(crate) fn run_iterations_inner<T: SpElem>(
        &self,
        plan: &ExecutionPlan<T>,
        x: &[T],
        iters: usize,
    ) -> Result<IterationsResult<T>> {
        crate::ensure!(iters >= 1, "run_iterations needs iters >= 1");
        crate::ensure!(
            iters == 1 || plan.nrows() == plan.ncols(),
            "iterated SpMV needs a square matrix, got {}x{}",
            plan.nrows(),
            plan.ncols()
        );
        let mut cur = x.to_vec();
        let mut total = Breakdown::default();
        let mut energy = Energy::default();
        let mut last: Option<RunResult<T>> = None;
        for _ in 0..iters {
            let r = self.execute_inner(plan, &cur)?;
            total.accumulate(&r.breakdown);
            energy = energy.add(r.energy);
            cur.clone_from(&r.y);
            last = Some(r);
        }
        Ok(IterationsResult { last: last.unwrap(), total, energy, iters })
    }

    /// Iterated batched SpMV: every vector in `xs` is independently
    /// self-applied (`y_b <- A*y_b`) `iters` times, advancing in
    /// lockstep so each iteration is one [`Self::execute_batch`] wave —
    /// the shape of multi-query iterative workloads like multi-seed
    /// personalized PageRank ([`crate::apps::pagerank`]).
    ///
    /// Per-vector results are bit-identical to running
    /// [`Self::run_iterations`] on each vector alone; `total` and
    /// `energy` sum over all iterations *and* vectors.
    #[deprecated(
        note = "call ExecutionPlan::run_iterations_batch for the synchronous path, or submit requests to coordinator::SpmvService"
    )]
    pub fn run_iterations_batch<T: SpElem>(
        &self,
        plan: &ExecutionPlan<T>,
        xs: &[Vec<T>],
        iters: usize,
    ) -> Result<BatchIterationsResult<T>> {
        self.run_iterations_batch_inner(plan, xs, iters, VECTOR_BLOCK)
    }

    /// Shared synchronous iterated batched execution (the body behind
    /// the deprecated [`Self::run_iterations_batch`] wrapper and
    /// [`ExecutionPlan::run_iterations_batch`]).
    pub(crate) fn run_iterations_batch_inner<T: SpElem>(
        &self,
        plan: &ExecutionPlan<T>,
        xs: &[Vec<T>],
        iters: usize,
        block: usize,
    ) -> Result<BatchIterationsResult<T>> {
        crate::ensure!(iters >= 1, "run_iterations_batch needs iters >= 1");
        crate::ensure!(
            iters == 1 || plan.nrows() == plan.ncols(),
            "iterated SpMV needs a square matrix, got {}x{}",
            plan.nrows(),
            plan.ncols()
        );
        crate::ensure!(!xs.is_empty(), "run_iterations_batch needs at least one vector");
        let mut cur: Vec<Vec<T>> = xs.to_vec();
        let mut total = Breakdown::default();
        let mut energy = Energy::default();
        let mut last: Option<BatchResult<T>> = None;
        for _ in 0..iters {
            let batch = self.execute_batch_inner(plan, &cur, block)?;
            for (c, r) in cur.iter_mut().zip(batch.runs.iter()) {
                total.accumulate(&r.breakdown);
                energy = energy.add(r.energy);
                c.clone_from(&r.y);
            }
            last = Some(batch);
        }
        Ok(BatchIterationsResult { last: last.unwrap(), total, energy, iters })
    }

    /// Execute one SpMV: `y = A * x` under `spec` (plan + execute in one
    /// call).
    #[deprecated(
        note = "use SpmvService::load + submit for serving, or plan() + ExecutionPlan::execute for one-shot execution"
    )]
    pub fn run<T: SpElem>(
        &self,
        spec: &KernelSpec,
        m: &CooMatrix<T>,
        x: &[T],
    ) -> Result<RunResult<T>> {
        crate::ensure!(x.len() == m.ncols(), "x length {} != ncols {}", x.len(), m.ncols());
        let plan = self.plan(spec, m)?;
        self.execute_inner(&plan, x)
    }

    pub(crate) fn finish<T: SpElem>(
        &self,
        plan: &ExecutionPlan<T>,
        outputs: &[DpuKernelOutput<T>],
        y: Vec<T>,
    ) -> RunResult<T> {
        let cfg = &self.sys.cfg;
        let kernel_cycles = kernels::slowest_dpu_cycles(
            &outputs.iter().map(|o| o.timing).collect::<Vec<_>>(),
        );
        let kernel_s = kernel_cycles as f64 * cfg.cycle_s();
        let merge_s = plan.merged_bytes as f64 / (calib::HOST_MERGE_GBS * 1e9);

        let breakdown = Breakdown {
            load_s: plan.load.seconds,
            kernel_s,
            retrieve_s: plan.retrieve.seconds,
            merge_s,
        };

        let ideal = plan.nnz() as f64 / cfg.n_dpus as f64;
        let dpu_imbalance = if ideal == 0.0 {
            1.0
        } else {
            plan.items().iter().map(|it| it.nnz).max().unwrap_or(0) as f64 / ideal
        };

        let per_dpu_s: Vec<f64> =
            outputs.iter().map(|o| o.timing.cycles as f64 * cfg.cycle_s()).collect();
        let energy = Energy::pim_kernel(cfg.n_dpus, &per_dpu_s)
            .add(Energy::transfer(
                plan.load.moved_bytes + plan.retrieve.moved_bytes,
                plan.load.seconds + plan.retrieve.seconds,
            ))
            .add(Energy::host(merge_s));

        let stats = RunStats {
            dpu_imbalance,
            kernel_cycles,
            bus_bytes_moved: plan.load.moved_bytes + plan.retrieve.moved_bytes,
            bus_bytes_payload: plan.load.payload_bytes + plan.retrieve.payload_bytes,
            matrix_load_s: plan.mat_load.seconds,
            n_dpus: cfg.n_dpus,
            nnz: plan.nnz(),
        };

        RunResult { y, breakdown, stats, energy }
    }
}

// These tests deliberately exercise the deprecated executor entry
// points: they are compatibility wrappers whose behavior stays locked
// until a future major removal.
#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::matrix::{generate, Format};
    use crate::pim::PimConfig;

    fn x_for(n: usize) -> Vec<f64> {
        (0..n).map(|i| ((i % 13) as f64) - 6.0).collect()
    }

    #[test]
    fn all_25_kernels_are_exact() {
        let m = generate::scale_free::<f64>(600, 600, 6, 0.5, 17);
        let x = x_for(600);
        let gold = m.spmv(&x);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        for spec in KernelSpec::all25(4) {
            let r = exec.run(&spec, &m, &x).unwrap();
            assert_eq!(r.y, gold, "kernel {} wrong", spec.name);
        }
    }

    #[test]
    fn plan_once_execute_many_matches_run() {
        let m = generate::scale_free::<f64>(400, 400, 7, 0.6, 23);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(16));
        for spec in [KernelSpec::coo_nnz(), KernelSpec::csr_nnz(), KernelSpec::two_d(Format::Coo, 4)] {
            let plan = exec.plan(&spec, &m).unwrap();
            for seed in 0..3u64 {
                let x: Vec<f64> =
                    (0..400).map(|i| ((i as u64 * 7 + seed) % 11) as f64 - 5.0).collect();
                let fresh = exec.run(&spec, &m, &x).unwrap();
                let reused = exec.execute(&plan, &x).unwrap();
                assert_eq!(reused.y, fresh.y, "{}", spec.name);
                assert_eq!(reused.breakdown, fresh.breakdown, "{}", spec.name);
                assert_eq!(reused.stats, fresh.stats, "{}", spec.name);
            }
        }
    }

    #[test]
    fn run_iterations_matches_host_power_iteration() {
        let m = generate::uniform::<f64>(200, 200, 5, 3);
        let x: Vec<f64> = (0..200).map(|i| ((i % 3) as f64) - 1.0).collect();
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        let plan = exec.plan(&KernelSpec::coo_nnz(), &m).unwrap();
        let it = exec.run_iterations(&plan, &x, 3).unwrap();
        let mut want = x.clone();
        for _ in 0..3 {
            want = m.spmv(&want);
        }
        assert_eq!(it.last.y, want);
        assert_eq!(it.iters, 3);
        // Totals accumulate three per-iteration breakdowns.
        assert!(it.total.load_s >= 3.0 * it.last.breakdown.load_s * 0.999);
        assert!(it.total.total_s() > it.last.breakdown.total_s());
        assert!(it.energy.total_j() > it.last.energy.total_j());
    }

    #[test]
    fn run_iterations_rejects_non_square() {
        let m = generate::uniform::<f64>(64, 48, 4, 1);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(4));
        let plan = exec.plan(&KernelSpec::coo_nnz(), &m).unwrap();
        assert!(exec.run_iterations(&plan, &vec![1.0; 48], 2).is_err());
        assert!(exec.run_iterations(&plan, &vec![1.0; 48], 1).is_ok());
    }

    #[test]
    fn execute_rejects_mismatched_system() {
        let m = generate::uniform::<f64>(128, 128, 4, 5);
        let exec8 = SpmvExecutor::new(PimSystem::with_dpus(8));
        let exec16 = SpmvExecutor::new(PimSystem::with_dpus(16));
        let plan = exec8.plan(&KernelSpec::csr_nnz(), &m).unwrap();
        assert!(exec16.execute(&plan, &vec![1.0; 128]).is_err());
        // Same DPU count but a different bus model: the plan's cached
        // transfer pricing would be stale -> rejected.
        let fast_bus = SpmvExecutor::new(PimSystem {
            cfg: PimConfig { n_dpus: 8, bus_scale: 4.0, ..Default::default() },
        });
        assert!(fast_bus.execute(&plan, &vec![1.0; 128]).is_err());
        // Differing tasklet count is allowed (kernel time is priced at
        // execute time).
        let more_tasklets = SpmvExecutor::new(PimSystem {
            cfg: PimConfig { n_dpus: 8, tasklets: 4, ..Default::default() },
        });
        let r = more_tasklets.execute(&plan, &vec![1.0; 128]).unwrap();
        assert_eq!(r.y, m.spmv(&vec![1.0; 128]));
    }

    #[test]
    fn one_d_breakdown_has_no_merge() {
        let m = generate::banded::<f64>(1024, 8, 3);
        let x = x_for(1024);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(16));
        let r = exec.run(&KernelSpec::csr_nnz(), &m, &x).unwrap();
        assert_eq!(r.breakdown.merge_s, 0.0);
        assert!(r.breakdown.load_s > 0.0);
        assert!(r.breakdown.kernel_s > 0.0);
        assert!(r.breakdown.retrieve_s > 0.0);
    }

    #[test]
    fn two_d_merges_partials() {
        let m = generate::uniform::<f64>(512, 512, 8, 5);
        let x = x_for(512);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(16));
        let spec = KernelSpec::two_d(Format::Coo, 4);
        let r = exec.run(&spec, &m, &x).unwrap();
        assert_eq!(r.y, m.spmv(&x));
        assert!(r.breakdown.merge_s > 0.0);
    }

    #[test]
    fn two_d_loads_less_than_one_d_on_many_dpus() {
        // The paper's core 1D-vs-2D trade: 2D scatters slices instead of
        // broadcasting the whole vector.
        let m = generate::uniform::<f64>(4096, 4096, 8, 7);
        let x = x_for(4096);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(256));
        let one_d = exec.run(&KernelSpec::coo_nnz_rgrn(), &m, &x).unwrap();
        let two_d = exec.run(&KernelSpec::two_d_equally_wide(Format::Coo, 16), &m, &x).unwrap();
        assert!(
            two_d.breakdown.load_s < one_d.breakdown.load_s,
            "2D load {} !< 1D load {}",
            two_d.breakdown.load_s,
            one_d.breakdown.load_s
        );
        // ...but pays more on retrieve (partials from every stripe).
        assert!(
            two_d.breakdown.retrieve_s > one_d.breakdown.retrieve_s,
            "2D retrieve {} !> 1D retrieve {}",
            two_d.breakdown.retrieve_s,
            one_d.breakdown.retrieve_s
        );
    }

    #[test]
    fn x_length_checked() {
        let m = generate::banded::<f64>(64, 4, 1);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(4));
        assert!(exec.run(&KernelSpec::csr_row(), &m, &vec![0.0; 63]).is_err());
        let plan = exec.plan(&KernelSpec::csr_row(), &m).unwrap();
        assert!(exec.execute(&plan, &vec![0.0; 63]).is_err());
    }

    #[test]
    fn integer_kernels_are_exact() {
        let m = generate::uniform::<f64>(256, 256, 6, 9);
        let mi: CooMatrix<i32> = m.cast();
        let x: Vec<i32> = (0..256).map(|i| (i % 7) as i32 - 3).collect();
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        for spec in [KernelSpec::coo_nnz(), KernelSpec::bcoo_nnz(), KernelSpec::csr_row()] {
            let r = exec.run(&spec, &mi, &x).unwrap();
            assert_eq!(r.y, mi.spmv(&x), "{}", spec.name);
        }
    }

    #[test]
    fn energy_is_positive_and_decomposed() {
        let m = generate::banded::<f64>(512, 8, 2);
        let x = x_for(512);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        let r = exec.run(&KernelSpec::csr_nnz(), &m, &x).unwrap();
        assert!(r.energy.total_j() > 0.0);
        assert!(r.energy.dpu_j > 0.0);
        assert!(r.energy.bus_j > 0.0);
    }

    #[test]
    fn execute_batch_matches_looped_execute() {
        let m = generate::scale_free::<f64>(300, 300, 6, 0.6, 13);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        // 11 vectors: one full VECTOR_BLOCK plus a ragged tail.
        let xs: Vec<Vec<f64>> = (0..11)
            .map(|s| (0..300).map(|i| ((i + 7 * s) % 9) as f64 - 4.0).collect())
            .collect();
        for spec in [KernelSpec::coo_nnz(), KernelSpec::csr_nnz(), KernelSpec::two_d(Format::Coo, 4)] {
            let plan = exec.plan(&spec, &m).unwrap();
            let batch = exec.execute_batch(&plan, &xs).unwrap();
            assert_eq!(batch.len(), xs.len(), "{}", spec.name);
            for (x, r) in xs.iter().zip(&batch.runs) {
                let single = exec.execute(&plan, x).unwrap();
                assert_eq!(r.y, single.y, "{}", spec.name);
                assert_eq!(r.breakdown, single.breakdown, "{}", spec.name);
                assert_eq!(r.stats, single.stats, "{}", spec.name);
                assert_eq!(r.energy, single.energy, "{}", spec.name);
            }
            // The plan-level convenience returns the same outputs.
            let ys = plan.execute_batch(&exec, &xs).unwrap();
            assert_eq!(ys, batch.runs.iter().map(|r| r.y.clone()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn execute_batch_edge_cases() {
        let m = generate::uniform::<f64>(64, 64, 4, 3);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(4));
        let plan = exec.plan(&KernelSpec::csr_row(), &m).unwrap();
        assert!(exec.execute_batch(&plan, &[]).unwrap().is_empty());
        // Batch of one behaves like execute.
        let x = vec![1.0; 64];
        let b = exec.execute_batch(&plan, std::slice::from_ref(&x)).unwrap();
        assert_eq!(b.runs[0].y, exec.execute(&plan, &x).unwrap().y);
        // Any wrong-length vector rejects the whole batch.
        assert!(exec.execute_batch(&plan, &[vec![0.0; 64], vec![0.0; 63]]).is_err());
    }

    #[test]
    fn run_iterations_batch_matches_per_vector_iterations() {
        let m = generate::uniform::<f64>(128, 128, 5, 11);
        let exec = SpmvExecutor::new(PimSystem::with_dpus(8));
        let plan = exec.plan(&KernelSpec::coo_nnz(), &m).unwrap();
        let xs: Vec<Vec<f64>> =
            (0..3).map(|s| (0..128).map(|i| ((i + s) % 5) as f64 - 2.0).collect()).collect();
        let batch = exec.run_iterations_batch(&plan, &xs, 4).unwrap();
        assert_eq!(batch.batch(), 3);
        assert_eq!(batch.iters, 4);
        let mut total = Breakdown::default();
        for (x, last) in xs.iter().zip(&batch.last.runs) {
            let single = exec.run_iterations(&plan, x, 4).unwrap();
            assert_eq!(last.y, single.last.y);
            total.accumulate(&single.total);
        }
        assert_eq!(batch.total, total);
        assert!(exec.run_iterations_batch(&plan, &[], 2).is_err());
        assert!(exec.run_iterations_batch(&plan, &xs, 0).is_err());
    }

    #[test]
    fn threaded_executor_is_exact_too() {
        let m = generate::scale_free::<f64>(500, 500, 6, 0.6, 31);
        let x = x_for(500);
        let gold = m.spmv(&x);
        let exec = SpmvExecutor::threaded(
            PimSystem { cfg: PimConfig { n_dpus: 32, ..Default::default() } },
            4,
        );
        for spec in [KernelSpec::coo_nnz(), KernelSpec::csr_nnz(), KernelSpec::two_d(Format::Coo, 4)]
        {
            let r = exec.run(&spec, &m, &x).unwrap();
            assert_eq!(r.y, gold, "{}", spec.name);
        }
    }
}
