//! Network serving front end: a TCP wire protocol over the sharded
//! coordinator facade.
//!
//! Everything below the wire is the existing serving stack
//! ([`crate::coordinator::ShardedService`]); this module only adds a
//! transport:
//!
//! - [`protocol`] — the length-prefixed binary frame catalogue
//!   (`SPRP` magic). Floats travel as raw IEEE-754 bits, so a served
//!   result is bit-identical to an in-process one.
//! - [`server`] — `sparsep serve --listen ADDR`: one event-loop
//!   thread drives every connection over non-blocking sockets, one
//!   dispatch thread forwards facade completions; no thread per
//!   connection, no poll loop per ticket.
//! - [`client`] — a small blocking client returning the
//!   coordinator's own [`crate::coordinator::Response`] / typed
//!   [`crate::util::Error`] values.
//! - [`loadgen`] — the open-loop Poisson generator behind
//!   `sparsep bench-net` (`BENCH_net.json`).
//!
//! Backpressure is typed at both layers: the server's per-connection
//! in-flight cap and the facade's per-tenant admission cap each
//! surface as `Overloaded` frames, never as dropped connections or
//! silent queuing.

pub mod client;
pub mod loadgen;
pub mod protocol;
pub mod server;

pub use client::Client;
pub use loadgen::LoadgenOpts;
pub use protocol::{decode_stream, Completion, Frame, WireErrorCode};
pub use server::{Server, ServerOpts};
