//! Element types supported by SparseP.
//!
//! The paper evaluates six data types — int8, int16, int32, int64, fp32,
//! fp64 — because the UPMEM DPU has no FPU and only an 8x8-bit hardware
//! multiplier, so the *choice of type changes the instruction count per
//! multiply-accumulate* dramatically. [`DType`] is the runtime tag the
//! simulator's cost model keys on; [`SpElem`] is the compile-time trait
//! the kernels are generic over.

/// Runtime tag for the six element types of the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    I8,
    I16,
    I32,
    I64,
    F32,
    F64,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size_bytes(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I16 => 2,
            DType::I32 => 4,
            DType::I64 => 8,
            DType::F32 => 4,
            DType::F64 => 8,
        }
    }

    /// All six types, in the paper's order.
    pub fn all() -> [DType; 6] {
        [DType::I8, DType::I16, DType::I32, DType::I64, DType::F32, DType::F64]
    }

    /// Paper-style name.
    pub fn name(self) -> &'static str {
        match self {
            DType::I8 => "int8",
            DType::I16 => "int16",
            DType::I32 => "int32",
            DType::I64 => "int64",
            DType::F32 => "fp32",
            DType::F64 => "fp64",
        }
    }

    pub fn from_name(s: &str) -> Option<DType> {
        Some(match s {
            "int8" | "i8" => DType::I8,
            "int16" | "i16" => DType::I16,
            "int32" | "i32" => DType::I32,
            "int64" | "i64" => DType::I64,
            "fp32" | "f32" | "float" => DType::F32,
            "fp64" | "f64" | "double" => DType::F64,
            _ => return None,
        })
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Element trait for SpMV kernels.
///
/// Deliberately smaller than `num_traits::Num`: kernels only ever need
/// zero, addition, multiplication and f64 conversion (for verification and
/// MatrixMarket I/O). Implementations exist exactly for the paper's six
/// types.
pub trait SpElem: Copy + Send + Sync + PartialEq + std::fmt::Debug + 'static {
    const DTYPE: DType;

    fn zero() -> Self;
    fn one() -> Self;
    fn add(self, rhs: Self) -> Self;
    fn mul(self, rhs: Self) -> Self;
    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;

    /// The element's native bit pattern, widened to 64 bits — a lossless
    /// identity for hashing (unlike `to_f64`, which collapses i64/u64
    /// values beyond f64's 53-bit mantissa). Used by
    /// [`crate::matrix::CooMatrix::fingerprint`].
    fn fingerprint_bits(self) -> u64;

    /// Fused-style multiply-accumulate: `acc + a*b`. Kernels use this so
    /// that integer types get wrapping semantics (matching what the DPU's
    /// C code would do) and floats get the obvious thing.
    #[inline]
    fn mac(acc: Self, a: Self, b: Self) -> Self {
        acc.add(a.mul(b))
    }
}

macro_rules! impl_int {
    ($t:ty, $tag:expr) => {
        impl SpElem for $t {
            const DTYPE: DType = $tag;
            #[inline]
            fn zero() -> Self {
                0
            }
            #[inline]
            fn one() -> Self {
                1
            }
            #[inline]
            fn add(self, rhs: Self) -> Self {
                self.wrapping_add(rhs)
            }
            #[inline]
            fn mul(self, rhs: Self) -> Self {
                self.wrapping_mul(rhs)
            }
            #[inline]
            fn from_f64(v: f64) -> Self {
                v as $t
            }
            #[inline]
            fn to_f64(self) -> f64 {
                self as f64
            }
            #[inline]
            fn fingerprint_bits(self) -> u64 {
                // Sign-extend through i64 so negative values keep a
                // distinct, deterministic pattern per value.
                self as i64 as u64
            }
        }
    };
}

macro_rules! impl_float {
    ($t:ty, $tag:expr) => {
        impl SpElem for $t {
            const DTYPE: DType = $tag;
            #[inline]
            fn zero() -> Self {
                0.0
            }
            #[inline]
            fn one() -> Self {
                1.0
            }
            #[inline]
            fn add(self, rhs: Self) -> Self {
                self + rhs
            }
            #[inline]
            fn mul(self, rhs: Self) -> Self {
                self * rhs
            }
            #[inline]
            fn from_f64(v: f64) -> Self {
                v as $t
            }
            #[inline]
            fn to_f64(self) -> f64 {
                self as f64
            }
            #[inline]
            fn fingerprint_bits(self) -> u64 {
                self.to_bits() as u64
            }
        }
    };
}

impl_int!(i8, DType::I8);
impl_int!(i16, DType::I16);
impl_int!(i32, DType::I32);
impl_int!(i64, DType::I64);
impl_float!(f32, DType::F32);
impl_float!(f64, DType::F64);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_names() {
        assert_eq!(DType::I8.size_bytes(), 1);
        assert_eq!(DType::F64.size_bytes(), 8);
        for d in DType::all() {
            assert_eq!(DType::from_name(d.name()), Some(d));
        }
        assert_eq!(DType::from_name("bogus"), None);
    }

    #[test]
    fn mac_semantics() {
        assert_eq!(<i32 as SpElem>::mac(1, 2, 3), 7);
        assert_eq!(<f64 as SpElem>::mac(0.5, 2.0, 0.25), 1.0);
        // Integer overflow wraps instead of panicking (DPU C semantics).
        assert_eq!(<i8 as SpElem>::mac(0, 127, 2), (127i8).wrapping_mul(2));
    }

    #[test]
    fn dtype_constants() {
        assert_eq!(<i16 as SpElem>::DTYPE, DType::I16);
        assert_eq!(<f32 as SpElem>::DTYPE, DType::F32);
        assert_eq!(<f32 as SpElem>::one().to_f64(), 1.0);
    }
}
