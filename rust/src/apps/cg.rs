//! Conjugate Gradient on the PIM service (scientific-computing workload).
//!
//! Solves `A x = b` for a symmetric positive-definite sparse `A`. One
//! SpMV per iteration runs on the (simulated) PIM system; dot products
//! and axpys run on the host, which is how a real UPMEM deployment would
//! structure it (the DPUs have no inter-core communication for global
//! reductions — paper hardware suggestion #4).

use super::{axpy, dot, SolveStats};
use crate::coordinator::{KernelSpec, SpmvService};
use crate::matrix::CooMatrix;
use crate::util::Result;

/// CG outcome.
#[derive(Clone, Debug)]
pub struct CgResult {
    pub x: Vec<f64>,
    /// Residual norm per iteration (for convergence plots).
    pub residuals: Vec<f64>,
    pub converged: bool,
    pub stats: SolveStats,
}

/// Run CG with the given kernel until `||r|| < tol * ||b||` or
/// `max_iters`. Each iteration's SpMV is a request against the matrix
/// registered with `svc`.
pub fn solve(
    svc: &SpmvService<f64>,
    spec: &KernelSpec,
    a: &CooMatrix<f64>,
    b: &[f64],
    tol: f64,
    max_iters: usize,
) -> Result<CgResult> {
    crate::ensure!(a.nrows() == a.ncols(), "CG needs a square matrix");
    crate::ensure!(b.len() == a.nrows(), "b length");
    let n = a.nrows();
    // Load once: partitioning + format conversion + transfer pricing are
    // amortized across every CG iteration (the paper's matrix placement
    // is one-time, only the vector moves per iteration) — the handle
    // pins the plan in the service's cache.
    let handle = svc.load(a, spec)?;
    let mut stats = SolveStats::default();
    let mut x = vec![0.0f64; n];
    let mut r = b.to_vec(); // r = b - A*0
    let mut p = r.clone();
    let mut rs_old = dot(&r, &r);
    let b_norm = dot(b, b).sqrt().max(1e-300);
    let mut residuals = vec![rs_old.sqrt() / b_norm];
    let mut converged = residuals[0] < tol;

    for _ in 0..max_iters {
        if converged {
            break;
        }
        // Ap = A * p on the PIM system (the service's synchronous fast
        // path: a blocking solver has nothing for the queue to overlap).
        let run = svc.spmv(&handle, &p)?;
        stats.absorb(&run);
        let ap = run.y;
        let denom = dot(&p, &ap);
        if denom.abs() < 1e-300 {
            break; // breakdown (non-SPD or numerical trouble)
        }
        let alpha = rs_old / denom;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        residuals.push(rs_new.sqrt() / b_norm);
        converged = *residuals.last().unwrap() < tol;
        let beta = rs_new / rs_old;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs_old = rs_new;
    }
    // Release the handle's plan pin: a long-lived service must not
    // accumulate one resident plan per solve call.
    svc.unload(handle);
    Ok(CgResult { x, residuals, converged, stats })
}

/// Build a well-conditioned SPD test system: `A = L + L^T + d*I` from a
/// generated sparse pattern (diagonally dominant by construction).
pub fn spd_from(m: &CooMatrix<f64>) -> CooMatrix<f64> {
    let n = m.nrows().min(m.ncols());
    let mut triples: Vec<(u32, u32, f64)> = Vec::with_capacity(m.nnz() * 2 + n);
    let mut row_abs = vec![0.0f64; n];
    for (r, c, v) in m.iter() {
        if (r as usize) < n && (c as usize) < n && r != c {
            let v = v.abs() * 0.5 + 0.1;
            triples.push((r, c, -v));
            triples.push((c, r, -v));
            row_abs[r as usize] += v;
            row_abs[c as usize] += v;
        }
    }
    for i in 0..n {
        triples.push((i as u32, i as u32, row_abs[i] + 1.0));
    }
    CooMatrix::from_triples(n, n, triples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServiceBuilder;
    use crate::matrix::generate;
    use crate::pim::PimSystem;

    fn service(n_dpus: usize) -> SpmvService<f64> {
        ServiceBuilder::new().build(PimSystem::with_dpus(n_dpus)).unwrap()
    }

    #[test]
    fn cg_converges_on_spd_system() {
        let base = generate::uniform::<f64>(300, 300, 4, 5);
        let a = spd_from(&base);
        let b: Vec<f64> = (0..300).map(|i| ((i % 7) as f64) - 3.0).collect();
        let svc = service(16);
        let res = solve(&svc, &KernelSpec::csr_nnz(), &a, &b, 1e-8, 500).unwrap();
        assert!(res.converged, "CG should converge: {:?}", res.residuals.last());
        // Check the solution actually solves the system.
        let ax = a.spmv(&res.x);
        for i in 0..300 {
            assert!((ax[i] - b[i]).abs() < 1e-5, "row {i}: {} vs {}", ax[i], b[i]);
        }
        // Residuals decrease overall.
        assert!(res.residuals.last().unwrap() < &res.residuals[0]);
        assert!(res.stats.iterations > 0);
        assert!(res.stats.total_s() > 0.0);
    }

    #[test]
    fn cg_counts_per_iteration_costs() {
        let base = generate::banded::<f64>(200, 4, 7);
        let a = spd_from(&base);
        let b = vec![1.0f64; 200];
        let svc = service(8);
        let res = solve(&svc, &KernelSpec::coo_nnz(), &a, &b, 1e-10, 300).unwrap();
        assert!(res.converged);
        // load_s accumulates once per iteration.
        assert!(res.stats.pim.load_s > 0.0);
        let per_iter = res.stats.pim.load_s / res.stats.iterations as f64;
        assert!(per_iter > 0.0);
    }

    #[test]
    fn cg_rejects_bad_shapes() {
        let a = generate::uniform::<f64>(10, 12, 2, 1);
        let svc = service(2);
        assert!(solve(&svc, &KernelSpec::csr_row(), &a, &vec![1.0; 10], 1e-6, 10).is_err());
    }
}
