//! Bench E7: 2D partitioning trade-offs (paper Figs. 11-13): the three
//! tile-shaping schemes swept over the number of vertical stripes.

mod common;
use sparsep::bench_harness::figures;

fn main() {
    common::banner("scaling_2d", "Figs. 11-13 2D schemes vs stripes");
    common::timed("e7_two_d", || {
        figures::e7_two_d(common::scale());
    });
}
