//! Chaos-equivalence harness for the resilient sharded tier.
//!
//! The resilience contract: fault injection may change *how* the
//! sharded service computes (respawns, re-scatters, re-executions,
//! delays) but never *what* it answers. Every scenario in
//! [`Scenario::ALL`] is swept across both engines, shard counts
//! {1, 2, 3, 5} and all three request shapes, and the chaos run's
//! responses must be
//!
//! 1. **bit-identical to the host oracle** (`m.spmv(&x)` composed per
//!    shape), and
//! 2. **bit-identical in full** — breakdown, stats, energy — to an
//!    identically-configured *fault-free* sharded reference (recovery
//!    re-executes deterministic simulated work, and a delay only burns
//!    wall-clock, never simulated time).
//!
//! Every assertion message carries the scenario name and seed, so a
//! failing chaos run reproduces from its printed line alone. The same
//! file locks the SLO semantics: typed stall timeouts naming the
//! wedged shard, typed overload shedding under a tenant flood (with
//! the starvation bound and latency-histogram invariants), and the
//! bounded `try_wait` poll loop.

use sparsep::coordinator::{
    BatchResult, Engine, Fault, FaultPlan, IterationsResult, KernelSpec, Request, Response,
    RunResult, Scenario, ShardedService, ShardedServiceBuilder, ShardedTicket, TenantSpec,
};
use sparsep::matrix::{generate, CooMatrix};
use sparsep::pim::PimSystem;
use std::sync::Arc;
use std::time::Duration;

const N: usize = 96;
const ITERS: usize = 3;
const DPUS_PER_SHARD: usize = 4;
const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 5];
const SEED: u64 = 0xC405_F00D;

fn matrix() -> CooMatrix<f64> {
    generate::scale_free::<f64>(N, N, 5, 0.7, 23)
}

fn x1() -> Vec<f64> {
    (0..N).map(|i| ((i % 11) as f64) - 5.0).collect()
}

fn batch_xs() -> Vec<Vec<f64>> {
    (0..3)
        .map(|b| (0..N).map(|i| ((i + 3 * b) % 7) as f64 - 3.0).collect())
        .collect()
}

fn builder(shards: usize, engine: Engine) -> ShardedServiceBuilder {
    ShardedServiceBuilder::new().shards(shards).engine(engine)
}

/// Inject scenario `s` on every one of tickets `1..=tickets`, always
/// targeting `shard`.
fn plan_all_tickets(s: Scenario, tickets: u64, shard: usize, seed: u64) -> FaultPlan {
    let mut plan = FaultPlan::new(seed);
    for t in 1..=tickets {
        plan = match s {
            Scenario::KillAtDispatch => plan.on_dispatch(t, Fault::KillShard { shard }),
            Scenario::KillAtGather => plan.on_gather(t, Fault::KillShard { shard }),
            Scenario::DroppedCompletion => plan.on_gather(t, Fault::DropCompletion { shard }),
            Scenario::DelayedStage => plan.on_dispatch(t, Fault::Delay { millis: 2 }),
        };
    }
    plan
}

/// The canonical 3-request mix — spmv (ticket 1), ragged-free batch
/// (ticket 2), iterate (ticket 3) — waited out of submission order.
fn serve_mix(
    svc: &ShardedService<f64>,
    m: &CooMatrix<f64>,
    spec: &KernelSpec,
) -> (RunResult<f64>, BatchResult<f64>, IterationsResult<f64>) {
    let h = svc.load(m, spec).unwrap();
    let t1 = svc.submit(h, Request::spmv(x1())).unwrap();
    let t2 = svc.submit(h, Request::batch(batch_xs())).unwrap();
    let t3 = svc.submit(h, Request::iterate(x1(), ITERS)).unwrap();
    let it = svc.wait(t3).unwrap().into_iterations().unwrap();
    let run = svc.wait(t1).unwrap().into_spmv().unwrap();
    let batch = svc.wait(t2).unwrap().into_batch().unwrap();
    (run, batch, it)
}

fn assert_runs_identical(a: &RunResult<f64>, b: &RunResult<f64>, tag: &str) {
    assert_eq!(a.y, b.y, "{tag}: output vector differs");
    assert_eq!(a.breakdown, b.breakdown, "{tag}: breakdown differs");
    assert_eq!(a.stats, b.stats, "{tag}: stats differ");
    assert_eq!(a.energy, b.energy, "{tag}: energy differs");
}

fn assert_mixes_identical(
    a: &(RunResult<f64>, BatchResult<f64>, IterationsResult<f64>),
    b: &(RunResult<f64>, BatchResult<f64>, IterationsResult<f64>),
    tag: &str,
) {
    assert_runs_identical(&a.0, &b.0, &format!("{tag} spmv"));
    assert_eq!(a.1.len(), b.1.len(), "{tag}: batch size differs");
    for (i, (ra, rb)) in a.1.runs.iter().zip(&b.1.runs).enumerate() {
        assert_runs_identical(ra, rb, &format!("{tag} batch vec={i}"));
    }
    assert_runs_identical(&a.2.last, &b.2.last, &format!("{tag} iterate last"));
    assert_eq!(a.2.total, b.2.total, "{tag}: iterate totals differ");
    assert_eq!(a.2.energy, b.2.energy, "{tag}: iterate energy differs");
    assert_eq!(a.2.iters, b.2.iters, "{tag}: iterate count differs");
}

/// What the host oracle answers for the mix.
fn host_oracle(m: &CooMatrix<f64>) -> (Vec<f64>, Vec<Vec<f64>>, Vec<f64>) {
    let spmv_y = m.spmv(&x1());
    let batch_ys: Vec<Vec<f64>> = batch_xs().iter().map(|x| m.spmv(x)).collect();
    let mut it_y = x1();
    for _ in 0..ITERS {
        it_y = m.spmv(&it_y);
    }
    (spmv_y, batch_ys, it_y)
}

#[test]
fn every_scenario_matches_the_fault_free_oracle_bit_for_bit() {
    let m = matrix();
    let spec = KernelSpec::coo_nnz();
    let (oracle_spmv, oracle_batch, oracle_iter) = host_oracle(&m);
    for engine in [Engine::Serial, Engine::threaded(2)] {
        for shards in SHARD_COUNTS {
            // The fault-free sharded reference for this configuration.
            let reference: ShardedService<f64> = builder(shards, engine)
                .build(PimSystem::with_dpus(DPUS_PER_SHARD))
                .unwrap();
            let ref_mix = serve_mix(&reference, &m, &spec);
            for sc in Scenario::ALL {
                // Target the last shard: shard 0 when S == 1, so even
                // the degenerate single-shard facade loses (and
                // recovers) its only backend.
                let target = shards - 1;
                let plan = plan_all_tickets(sc, 3, target, SEED);
                let tag = format!(
                    "scenario={} engine={engine:?} shards={shards} target={target} seed={SEED:#x}",
                    sc.name()
                );
                let chaos: ShardedService<f64> = builder(shards, engine)
                    .fault_injector(Arc::new(plan))
                    .build(PimSystem::with_dpus(DPUS_PER_SHARD))
                    .unwrap();
                let mix = serve_mix(&chaos, &m, &spec);
                // Host oracle: the values are right.
                assert_eq!(mix.0.y, oracle_spmv, "{tag}: spmv vs host oracle");
                for (i, want) in oracle_batch.iter().enumerate() {
                    assert_eq!(&mix.1.runs[i].y, want, "{tag}: batch vec={i} vs host oracle");
                }
                assert_eq!(mix.2.last.y, oracle_iter, "{tag}: iterate vs host oracle");
                // Fault-free reference: the whole responses (metrics
                // included) are bit-identical — chaos changed nothing
                // observable.
                assert_mixes_identical(&mix, &ref_mix, &tag);
                let st = chaos.stats();
                match sc {
                    Scenario::KillAtDispatch | Scenario::KillAtGather => {
                        assert!(st.respawns >= 1, "{tag}: a killed backend must respawn");
                    }
                    Scenario::DroppedCompletion | Scenario::DelayedStage => {
                        assert_eq!(st.respawns, 0, "{tag}: no backend died, none may respawn");
                    }
                }
                assert_eq!(st.completed, st.submitted, "{tag}: every ticket must resolve");
            }
        }
    }
}

#[test]
fn random_fault_plans_reproduce_from_their_seed_end_to_end() {
    let m = matrix();
    let spec = KernelSpec::csr_nnz();
    let (oracle_spmv, _, _) = host_oracle(&m);
    for seed in [1u64, 0xBA5E_BA11] {
        // Same (seed, tickets, shards, p) -> same plan, twice over.
        let plan_a = FaultPlan::random(seed, 6, 3, 0.5);
        let plan_b = FaultPlan::random(seed, 6, 3, 0.5);
        assert_eq!(plan_a, plan_b, "seed={seed:#x}: random plan must rebuild identically");
        assert_eq!(plan_a.seed(), seed);
        // And two facades under that plan answer identically — and
        // correctly. Two mixes = 6 tickets, covering the whole plan.
        let svc_a: ShardedService<f64> = builder(3, Engine::Serial)
            .fault_injector(Arc::new(plan_a))
            .build(PimSystem::with_dpus(DPUS_PER_SHARD))
            .unwrap();
        let svc_b: ShardedService<f64> = builder(3, Engine::Serial)
            .fault_injector(Arc::new(plan_b))
            .build(PimSystem::with_dpus(DPUS_PER_SHARD))
            .unwrap();
        let tag = format!("random plan seed={seed:#x}");
        let (a1, a2) = (serve_mix(&svc_a, &m, &spec), serve_mix(&svc_a, &m, &spec));
        let (b1, b2) = (serve_mix(&svc_b, &m, &spec), serve_mix(&svc_b, &m, &spec));
        assert_mixes_identical(&a1, &b1, &format!("{tag} mix 1"));
        assert_mixes_identical(&a2, &b2, &format!("{tag} mix 2"));
        assert_eq!(a1.0.y, oracle_spmv, "{tag}: spmv vs host oracle");
    }
}

#[test]
fn stalled_shard_times_out_with_its_name() {
    let m = matrix();
    let plan = FaultPlan::new(7).on_gather(1, Fault::StallShard { shard: 1 });
    let svc: ShardedService<f64> = builder(3, Engine::Serial)
        .wait_timeout(Duration::from_millis(100))
        .fault_injector(Arc::new(plan))
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap();
    let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
    let x = x1();
    let t = svc.submit(h, Request::spmv(x.clone())).unwrap();
    // The gather stage sleeps out the stall bound before failing the
    // ticket, so the facade-level wait may time out (shard unknown)
    // first; keep claiming until the gather's verdict arrives.
    let err = loop {
        match svc.wait_timeout(t, Duration::from_secs(2)) {
            Err(e) if e.timed_out_shard() == Some(1) => break e,
            Err(e) if e.is_shard_timeout() => continue,
            Ok(r) => panic!("stalled request must not succeed, got {}", r.kind()),
            Err(e) => panic!("unexpected error: {e}"),
        }
    };
    assert!(err.is_shard_timeout(), "stall must surface as a typed ShardTimeout");
    assert_eq!(err.timed_out_shard(), Some(1), "the error must name the wedged shard");
    // The stall poisoned one ticket, not the facade.
    assert_eq!(svc.spmv(&h, &x).unwrap().y, m.spmv(&x));
}

/// Regression: the gather thread used to serve a stalled shard by
/// `thread::sleep`ing out the whole stall bound inline, head-of-line
/// blocking completions for every other ticket. The stalled item is
/// now parked behind its deadline while healthy tickets keep flowing,
/// so a fault-free ticket submitted after the stalled one must
/// complete in a small fraction of the stall bound.
#[test]
fn stalled_shard_does_not_block_healthy_tickets() {
    let m = matrix();
    // Stall shard 0 on ticket 1 only; ticket 2 is fault-free.
    let stall_bound = Duration::from_secs(2);
    let plan = FaultPlan::new(11).on_gather(1, Fault::StallShard { shard: 0 });
    let svc: ShardedService<f64> = builder(2, Engine::Serial)
        .wait_timeout(stall_bound)
        .fault_injector(Arc::new(plan))
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap();
    let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
    let x = x1();
    let started = std::time::Instant::now();
    let t1 = svc.submit(h, Request::spmv(x.clone())).unwrap();
    let t2 = svc.submit(h, Request::spmv(x.clone())).unwrap();
    let r2 = svc.wait(t2).unwrap().into_spmv().unwrap();
    let healthy_latency = started.elapsed();
    assert_eq!(r2.y, m.spmv(&x), "healthy ticket must compute the oracle answer");
    // Generous margin (the work itself is milliseconds-scale): pre-fix
    // the gather thread slept the full 2 s bound on ticket 1 before
    // even looking at ticket 2.
    assert!(
        healthy_latency < stall_bound / 2,
        "healthy ticket took {healthy_latency:?}; a stalled sibling must not head-of-line-block it"
    );
    // The stalled ticket still expires into the typed ShardTimeout
    // naming the wedged shard (same claim loop as above: a facade-level
    // wait may time out, shard unknown, before the gather's verdict).
    let err = loop {
        match svc.wait_timeout(t1, Duration::from_secs(20)) {
            Err(e) if e.timed_out_shard() == Some(0) => break e,
            Err(e) if e.is_shard_timeout() => continue,
            Ok(r) => panic!("stalled request must not succeed, got {}", r.kind()),
            Err(e) => panic!("unexpected error: {e}"),
        }
    };
    assert!(err.is_shard_timeout());
    // And the facade stays healthy afterwards.
    assert_eq!(svc.spmv(&h, &x).unwrap().y, m.spmv(&x));
}

#[test]
fn flooding_tenant_is_shed_typed_and_cannot_starve_the_victim() {
    let m = matrix();
    let svc: ShardedService<f64> = ShardedServiceBuilder::new()
        .shards(2)
        .tenants(vec![TenantSpec::new("flooder", 1), TenantSpec::new("victim", 1)])
        .max_queue(4)
        .start_paused(true)
        .record_schedule(true)
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap();
    let (tf, tv) = (svc.tenant("flooder").unwrap(), svc.tenant("victim").unwrap());
    let hf = svc.load_for(tf, &m, &KernelSpec::coo_nnz()).unwrap();
    let hv = svc.load_for(tv, &m, &KernelSpec::coo_nnz()).unwrap();
    let x = x1();
    // 20 flooder submits against a per-tenant cap of 4: exactly 4
    // queue, 16 shed. The victim's own queue is untouched by the
    // flooder's — all 4 of its submits are admitted.
    let flood: Vec<ShardedTicket> = (0..20)
        .map(|_| svc.submit_for(tf, hf, Request::spmv(x.clone())).unwrap())
        .collect();
    let victims: Vec<ShardedTicket> = (0..4)
        .map(|_| svc.submit_for(tv, hv, Request::spmv(x.clone())).unwrap())
        .collect();
    svc.resume();
    let (mut served, mut shed) = (0u64, 0u64);
    for t in flood {
        match svc.wait(t).unwrap() {
            Response::Overloaded => shed += 1,
            r => {
                assert_eq!(r.into_spmv().unwrap().y, m.spmv(&x));
                served += 1;
            }
        }
    }
    assert_eq!((served, shed), (4, 16), "cap 4: 4 flooder requests served, 16 shed typed");
    for t in victims {
        let r = svc.wait(t).unwrap();
        assert!(!r.is_overloaded(), "the victim was under its cap and must not shed");
        assert_eq!(r.into_spmv().unwrap().y, m.spmv(&x), "victim must serve despite the flood");
    }
    let st = svc.stats();
    let (f, v) = (&st.tenants[tf.index()], &st.tenants[tv.index()]);
    assert_eq!((f.completed, f.shed), (4, 16));
    assert_eq!((v.completed, v.shed), (4, 0));
    // Starvation bound: at equal weights the WRR dispatcher interleaves
    // the two queues, so all 4 victim dispatches land in the first 8.
    let log = svc.schedule_log().unwrap();
    let victim_early = log.dispatched.iter().take(8).filter(|t| **t == tv).count();
    assert_eq!(victim_early, 4, "equal-weight WRR must not let the flood starve the victim");
    // Latency histograms observed every completion, and the quantile
    // chain is monotone.
    assert_eq!(v.latency.count, 4);
    assert_eq!(f.latency.count, 4, "shed requests must not pollute the latency histogram");
    assert!(v.latency.p50_us <= v.latency.p99_us);
    assert!(v.latency.p99_us <= v.latency.p999_us);
    assert!(v.latency.p999_us <= v.latency.max_us.max(1));
}

#[test]
fn try_wait_polls_through_a_paused_then_resumed_scheduler() {
    let m = matrix();
    let svc: ShardedService<f64> = ShardedServiceBuilder::new()
        .shards(2)
        .start_paused(true)
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap();
    let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
    let x = x1();
    let t = svc.submit(h, Request::spmv(x.clone())).unwrap();
    // While the scheduler is paused the poll reports not-ready — it
    // never blocks and never errors.
    for _ in 0..10 {
        assert!(svc.try_wait(t).unwrap().is_none(), "paused request cannot be ready");
    }
    svc.resume();
    // Bounded poll loop with sleep backoff: the request must land well
    // inside the bound once dispatching resumes.
    let mut got = None;
    for _ in 0..500 {
        if let Some(r) = svc.try_wait(t).unwrap() {
            got = Some(r);
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let r = got.expect("request must complete within the bounded poll loop");
    assert_eq!(r.into_spmv().unwrap().y, m.spmv(&x));
    // The successful poll claimed the ticket; polling again is a loud
    // error, not a hang or a duplicate response.
    assert!(svc.try_wait(t).is_err(), "claimed ticket must not be pollable again");
}
