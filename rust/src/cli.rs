//! Hand-rolled CLI (clap is not in the offline vendor set).
//!
//! Grammar: `sparsep <command> [--flag value]...`. See
//! [`print_usage`] or run `sparsep help` for the command list.

use crate::baselines::cpu;
use crate::bench_harness::figures::{self, Scale};
use crate::coordinator::{Engine, KernelSpec, SpmvExecutor};
use crate::matrix::{generate, CooMatrix, CsrMatrix, DType};
use crate::pim::{PimConfig, PimSystem};
use crate::util::{Context, Result};
use crate::bail;
use std::collections::HashMap;

/// Parsed command line: positional command + `--key value` flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(cmd) = it.next() {
            if cmd.starts_with("--") {
                bail!("expected a command before flags, got {cmd}");
            }
            out.command = cmd;
        }
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument: {a}");
            };
            // Boolean flags (no value / next is a flag).
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap(),
                _ => "true".to_string(),
            };
            out.flags.insert(key.to_string(), val);
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

pub fn print_usage() {
    println!(
        "sparsep — SpMV on a (simulated) real PIM system [SparseP reproduction]

USAGE: sparsep <command> [--flag value]...

COMMANDS:
  kernels                         list the 25 SpMV kernels
  suite [--full]                  print the matrix-suite table (Table 2)
  run --kernel K --matrix M       run one kernel; flags:
      [--dpus N] [--tasklets T] [--dtype D] [--stripes S] [--seed X]
      [--batch B]                 B > 1: batched SpMM-style execution of
                                  B vectors over one plan, all verified
  exp <id> [--scale F] [--full]   regenerate an experiment:
      e1 tasklet-scaling   e2 sync-schemes    e3 dtype
      e4 block-formats     e5 1d-scaling      e6 1d-breakdown
      e7 2d-tradeoff       e8 1d-vs-2d        e9 cpu-gpu-pim
      e10 suite            ablation           all
  adaptive --matrix M [--dpus N]  heuristic vs autotuned kernel choice
  solve --app cg|jacobi|pagerank --matrix M [--dpus N]
                                  iterative solver with SpMV on PIM
      [--seeds a,b,c]             pagerank only: multi-seed personalized
                                  PageRank via the batched serving path
  bench-coordinator               plan-once CG wall-clock, serial vs
      [--rows N] [--deg K] [--iters I] [--dpus N] [--out F]
                                  threaded; writes BENCH_coordinator.json
  bench-batch                     batched vs looped single-vector SpMV
      [--rows N] [--deg K] [--batch B] [--dpus N] [--kernel K]
      [--threads T] [--samples S] [--out F]
                                  wall-clock; writes BENCH_batch.json
  artifacts                       list AOT artifacts + PJRT platform
  xla --rows N --deg K            SpMV through the AOT XLA path, verified
  cpu --rows N --deg K [--threads T]  measured host-CPU baseline
  help                            this message

ENGINE FLAGS (run / exp / adaptive / solve):
  --engine serial|threaded        how per-DPU kernel simulations execute
  --threads N                     worker threads for the threaded engine
  (results are bit-identical across engines; only wall-clock changes)"
    );
}

/// Engine selection from `--engine` / `--threads` (defaults to the
/// `SPARSEP_ENGINE` / `SPARSEP_THREADS` environment, i.e. serial).
fn engine_from_args(args: &Args) -> Result<Engine> {
    let threads = args.get_usize("threads", 0)?;
    match args.get("engine") {
        None if threads > 0 => Ok(Engine::threaded(threads)),
        None => Ok(Engine::from_env()),
        Some("serial") => Ok(Engine::Serial),
        Some("threaded") => Ok(Engine::threaded(threads)),
        Some(other) => bail!("unknown --engine {other} (serial|threaded)"),
    }
}

fn matrix_by_name(name: &str, seed: u64) -> Result<CooMatrix<f64>> {
    if let Some(e) = generate::suite().into_iter().find(|e| e.name == name) {
        return Ok((e.gen)(seed));
    }
    if let Some(e) = generate::mini_suite().into_iter().find(|e| e.name == name) {
        return Ok((e.gen)(seed));
    }
    if let Some(path) = name.strip_prefix('@') {
        return crate::matrix::mtx::read_mtx(std::path::Path::new(path));
    }
    bail!(
        "unknown matrix {name}; use a suite name ({}) or @path/to/file.mtx",
        generate::suite().iter().map(|e| e.name).collect::<Vec<_>>().join(", ")
    )
}

fn run_spec<T: crate::matrix::SpElem>(
    spec: &KernelSpec,
    m64: &CooMatrix<f64>,
    exec: &SpmvExecutor,
    batch: usize,
) -> Result<()> {
    let m: CooMatrix<T> = m64.cast();
    let plan = exec.plan(spec, &m)?;
    if batch > 1 {
        return run_spec_batch(spec, &m, exec, &plan, batch);
    }
    let x: Vec<T> = (0..m.ncols()).map(|i| T::from_f64(((i % 9) as f64) - 4.0)).collect();
    let r = exec.execute(&plan, &x)?;
    // Verify against the host oracle.
    let ok = r.y == m.spmv(&x);
    let b = r.breakdown;
    println!("kernel     : {}", spec.name);
    println!("dtype      : {}", T::DTYPE.name());
    println!("matrix     : {} x {}, {} nnz", m.nrows(), m.ncols(), m.nnz());
    println!("dpus       : {} ({} tasklets)", r.stats.n_dpus, exec.sys.tasklets());
    println!("verified   : {}", if ok { "OK (matches host oracle)" } else { "MISMATCH" });
    println!("matrix load: {:.3} ms (one-time)", r.stats.matrix_load_s * 1e3);
    println!(
        "breakdown  : load {:.3} ms | kernel {:.3} ms | retrieve {:.3} ms | merge {:.3} ms",
        b.load_s * 1e3,
        b.kernel_s * 1e3,
        b.retrieve_s * 1e3,
        b.merge_s * 1e3
    );
    println!("total      : {:.3} ms ({} dominated)", b.total_s() * 1e3, b.dominant());
    println!("kernel perf: {:.3} GFLOP/s  e2e {:.3} GFLOP/s", r.kernel_gflops(), r.e2e_gflops());
    println!("imbalance  : {:.2}x   padding {:.2}x", r.stats.dpu_imbalance, r.stats.padding_overhead());
    println!("energy     : {:.3e} J (dpu {:.1e} / bus {:.1e} / host {:.1e})",
        r.energy.total_j(), r.energy.dpu_j + r.energy.dpu_idle_j, r.energy.bus_j, r.energy.host_j);
    if !ok {
        bail!("verification failed");
    }
    Ok(())
}

/// Batched `run`: B deterministic vectors through one plan via
/// [`SpmvExecutor::execute_batch`], every output verified against the
/// host oracle.
fn run_spec_batch<T: crate::matrix::SpElem>(
    spec: &KernelSpec,
    m: &CooMatrix<T>,
    exec: &SpmvExecutor,
    plan: &crate::coordinator::ExecutionPlan<T>,
    batch: usize,
) -> Result<()> {
    let xs: Vec<Vec<T>> = (0..batch)
        .map(|b| {
            (0..m.ncols()).map(|i| T::from_f64((((i + 3 * b) % 9) as f64) - 4.0)).collect()
        })
        .collect();
    let t0 = std::time::Instant::now();
    let res = exec.execute_batch(plan, &xs)?;
    let wall = t0.elapsed().as_secs_f64();
    let ok = res.runs.iter().zip(&xs).all(|(r, x)| r.y == m.spmv(x));
    let total = res.total();
    println!("kernel     : {} (batched x{batch})", spec.name);
    println!("dtype      : {}", T::DTYPE.name());
    println!("matrix     : {} x {}, {} nnz", m.nrows(), m.ncols(), m.nnz());
    println!("dpus       : {} ({} tasklets)", exec.sys.n_dpus(), exec.sys.tasklets());
    println!(
        "verified   : {}",
        if ok { "OK (all outputs match host oracle)" } else { "MISMATCH" }
    );
    println!("matrix load: {:.3} ms (one-time, shared by the whole batch)", plan.matrix_load_s() * 1e3);
    println!(
        "modeled    : {:.3} ms total over the batch ({:.3} ms/vector)",
        total.total_s() * 1e3,
        total.total_s() / batch as f64 * 1e3
    );
    println!(
        "host wall  : {:.3} ms for the batch ({:.3} ms/vector, {} engine)",
        wall * 1e3,
        wall / batch as f64 * 1e3,
        engine_name(exec.engine)
    );
    if !ok {
        bail!("batched verification failed");
    }
    Ok(())
}

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn run(args: Args) -> Result<()> {
    match args.command.as_str() {
        "" | "help" | "--help" | "-h" => print_usage(),
        "kernels" => {
            let stripes = args.get_usize("stripes", 8)?;
            println!("{:<14} {:>6} {:>12} {:>10} {:>11}", "name", "format", "partition", "tasklet", "sync");
            for k in KernelSpec::all25(stripes) {
                let part = match k.partitioning {
                    crate::coordinator::Partitioning::OneD(b) => format!("1D/{}", b.name()),
                    crate::coordinator::Partitioning::TwoD(s, n) => format!("2D/{}x{n}", s.name()),
                };
                println!(
                    "{:<14} {:>6} {:>12} {:>10} {:>11}",
                    k.name,
                    k.format.name(),
                    part,
                    k.tasklet_balance.name(),
                    k.sync.name()
                );
            }
        }
        "suite" => {
            figures::e10_suite_table(args.get_bool("full"));
        }
        "run" => {
            let kname = args.get("kernel").context("--kernel required (see `sparsep kernels`)")?;
            let stripes = args.get_usize("stripes", 8)?;
            let spec = KernelSpec::by_name(kname, stripes)
                .with_context(|| format!("unknown kernel {kname}"))?;
            let mname = args.get("matrix").unwrap_or("mini-sf");
            let m = matrix_by_name(mname, args.get_usize("seed", 7)? as u64)?;
            let cfg = PimConfig {
                n_dpus: args.get_usize("dpus", 64)?,
                tasklets: args.get_usize("tasklets", 16)?,
                ..Default::default()
            };
            let exec = SpmvExecutor::with_engine(PimSystem::new(cfg)?, engine_from_args(&args)?);
            let dt = DType::from_name(args.get("dtype").unwrap_or("fp64"))
                .context("bad --dtype (int8|int16|int32|int64|fp32|fp64)")?;
            let batch = args.get_usize("batch", 1)?;
            match dt {
                DType::I8 => run_spec::<i8>(&spec, &m, &exec, batch)?,
                DType::I16 => run_spec::<i16>(&spec, &m, &exec, batch)?,
                DType::I32 => run_spec::<i32>(&spec, &m, &exec, batch)?,
                DType::I64 => run_spec::<i64>(&spec, &m, &exec, batch)?,
                DType::F32 => run_spec::<f32>(&spec, &m, &exec, batch)?,
                DType::F64 => run_spec::<f64>(&spec, &m, &exec, batch)?,
            }
        }
        "exp" => {
            let id = args.get("id").map(str::to_string).unwrap_or_else(|| {
                // allow `sparsep exp e5 --scale ..` via flags-only too
                String::new()
            });
            let id = if id.is_empty() {
                args.flags
                    .keys()
                    .find(|k| k.starts_with('e') || *k == "ablation" || *k == "all")
                    .cloned()
                    .context("usage: sparsep exp --id e5 (or e1..e10, ablation, all)")?
            } else {
                id
            };
            // Figure drivers build their own executors; publish the
            // engine choice through the environment so they pick it up.
            engine_from_args(&args)?.export_env();
            let sc = Scale(args.get_f64("scale", 0.25)?);
            match id.as_str() {
                "e1" => drop(figures::e1_tasklet_scaling(sc)),
                "e2" => drop(figures::e2_sync_schemes(sc)),
                "e3" => drop(figures::e3_dtype_sweep(sc)),
                "e4" => drop(figures::e4_block_formats(sc)),
                "e5" => drop(figures::e5_scaling_1d(sc)),
                "e6" => drop(figures::e6_breakdown_1d(sc)),
                "e7" => drop(figures::e7_two_d(sc)),
                "e8" => drop(figures::e8_one_vs_two(sc)),
                "e9" => drop(figures::e9_cpu_gpu_pim(sc)),
                "e10" => drop(figures::e10_suite_table(args.get_bool("full"))),
                "ablation" => drop(figures::ablation_hw(sc)),
                "all" => {
                    figures::e10_suite_table(args.get_bool("full"));
                    figures::e1_tasklet_scaling(sc);
                    figures::e2_sync_schemes(sc);
                    figures::e3_dtype_sweep(sc);
                    figures::e4_block_formats(sc);
                    figures::e5_scaling_1d(sc);
                    figures::e6_breakdown_1d(sc);
                    figures::e7_two_d(sc);
                    figures::e8_one_vs_two(sc);
                    figures::e9_cpu_gpu_pim(sc);
                    figures::ablation_hw(sc);
                }
                other => bail!("unknown experiment {other}"),
            }
        }
        "adaptive" => {
            let mname = args.get("matrix").unwrap_or("sf-mid");
            let m = matrix_by_name(mname, 7)?;
            let cfg = PimConfig { n_dpus: args.get_usize("dpus", 256)?, ..Default::default() };
            let exec = SpmvExecutor::with_engine(PimSystem::new(cfg)?, engine_from_args(&args)?);
            let choice = crate::coordinator::adaptive::select_heuristic(&m, &exec.sys.cfg);
            println!("heuristic  : {}  ({})", choice.spec.name, choice.reason);
            let x: Vec<f64> = (0..m.ncols()).map(|i| (i % 7) as f64).collect();
            let t_h = exec.run(&choice.spec, &m, &x)?.breakdown.total_s();
            let (best, ranking) =
                crate::coordinator::adaptive::autotune(&exec, &m, &x, args.get_usize("stripes", 8)?)?;
            println!("autotuned  : {}  ({:.3} ms)", best.name, ranking[0].1 * 1e3);
            println!("heuristic time: {:.3} ms ({:.2}x of best)", t_h * 1e3, t_h / ranking[0].1);
            println!("top 5:");
            for (name, t) in ranking.iter().take(5) {
                println!("  {:<14} {:>9.3} ms", name, t * 1e3);
            }
        }
        "solve" => {
            let app = args.get("app").context("--app cg|jacobi|pagerank")?;
            let mname = args.get("matrix").unwrap_or("mini-unif");
            let m = matrix_by_name(mname, 7)?;
            let cfg = PimConfig { n_dpus: args.get_usize("dpus", 64)?, ..Default::default() };
            let exec = SpmvExecutor::with_engine(PimSystem::new(cfg)?, engine_from_args(&args)?);
            let spec = crate::coordinator::adaptive::select_heuristic(&m, &exec.sys.cfg).spec;
            println!("matrix {} ({}x{}, {} nnz), kernel {}", mname, m.nrows(), m.ncols(), m.nnz(), spec.name);
            match app {
                "cg" => {
                    let a = crate::apps::cg::spd_from(&m);
                    let b = vec![1.0f64; a.nrows()];
                    let r = crate::apps::cg::solve(&exec, &spec, &a, &b, 1e-8, 1000)?;
                    println!(
                        "CG: converged={} iters={} residual={:.2e}",
                        r.converged,
                        r.stats.iterations,
                        r.residuals.last().unwrap()
                    );
                    print_solve_stats(&r.stats);
                }
                "jacobi" => {
                    let a = crate::apps::cg::spd_from(&m);
                    let b = vec![1.0f64; a.nrows()];
                    let r = crate::apps::jacobi::solve(&exec, &spec, &a, &b, 1e-10, 5000)?;
                    println!("Jacobi: converged={} iters={}", r.converged, r.iterations);
                    print_solve_stats(&r.stats);
                }
                "pagerank" => {
                    let p = crate::apps::pagerank::transition_matrix(&m);
                    if let Some(list) = args.get("seeds") {
                        // Multi-seed personalized PageRank: one batched
                        // power iteration serves every seed.
                        let seeds: Vec<usize> = list
                            .split(',')
                            .map(|t| t.trim().parse::<usize>())
                            .collect::<std::result::Result<_, _>>()
                            .context("--seeds must be a comma-separated list of node ids")?;
                        let r = crate::apps::pagerank::personalized_pagerank(
                            &exec, &spec, &p, &seeds, 0.85, 1e-9, 200,
                        )?;
                        println!(
                            "personalized PageRank: {} seeds, converged={} iters={}",
                            seeds.len(),
                            r.converged,
                            r.iterations
                        );
                        for (ranks, &seed) in r.ranks.iter().zip(&seeds) {
                            let mut top: Vec<(usize, f64)> =
                                ranks.iter().copied().enumerate().collect();
                            top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                            println!("  seed {seed}: top {:?}", &top[..top.len().min(3)]);
                        }
                        print_solve_stats(&r.stats);
                    } else {
                        let r =
                            crate::apps::pagerank::pagerank(&exec, &spec, &p, 0.85, 1e-9, 200)?;
                        let mut top: Vec<(usize, f64)> =
                            r.ranks.iter().copied().enumerate().collect();
                        top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                        println!("PageRank: converged={} iters={}", r.converged, r.iterations);
                        println!("top nodes: {:?}", &top[..top.len().min(5)]);
                        print_solve_stats(&r.stats);
                    }
                }
                other => bail!("unknown app {other}"),
            }
        }
        "bench-coordinator" => {
            bench_coordinator(&args)?;
        }
        "bench-batch" => {
            let d = crate::bench_harness::batch::BatchBenchOpts::default();
            let opts = crate::bench_harness::batch::BatchBenchOpts {
                rows: args.get_usize("rows", d.rows)?,
                deg: args.get_usize("deg", d.deg)?,
                batch: args.get_usize("batch", d.batch)?,
                n_dpus: args.get_usize("dpus", d.n_dpus)?,
                threads: args.get_usize("threads", cpu::hw_threads())?,
                kernel: args.get("kernel").unwrap_or(d.kernel.as_str()).to_string(),
                samples: args.get_usize("samples", d.samples)?,
                out: args.get("out").unwrap_or(d.out.as_str()).to_string(),
            };
            crate::bench_harness::batch::run(&opts)?;
        }
        "artifacts" => {
            let r = crate::runtime::ArtifactRunner::load_default()?;
            println!("PJRT platform: {}", r.platform());
            for n in r.names() {
                let m = r.meta(n).unwrap();
                println!("  {:<34} kind={:<11} dtype={}", n, m.kind, m.dtype);
            }
        }
        "xla" => {
            let rows = args.get_usize("rows", 1000)?;
            let deg = args.get_usize("deg", 6)?;
            let rn = crate::runtime::ArtifactRunner::load_default()?;
            let m = generate::uniform::<f64>(rows, rows, deg, 5).cast::<f32>();
            let csr = CsrMatrix::from_coo(&m);
            let staged = crate::runtime::ell_host::stage(&rn, &csr)?;
            let x: Vec<f32> = (0..rows).map(|i| ((i % 7) as f32) - 3.0).collect();
            let t0 = std::time::Instant::now();
            let y = staged.spmv(&rn, &x)?;
            let dt = t0.elapsed().as_secs_f64();
            let want = csr.spmv(&x);
            let ok = y
                .iter()
                .zip(&want)
                .all(|(a, b)| (a - b).abs() <= 1e-3 * b.abs().max(1.0));
            println!(
                "xla path: artifact {} pad {:.1}x  {:.3} ms  {:.3} GFLOP/s  verified: {}",
                staged.artifact,
                staged.pad_ratio,
                dt * 1e3,
                gfl(m.nnz(), dt),
                if ok { "OK" } else { "MISMATCH" }
            );
            if !ok {
                bail!("xla path verification failed");
            }
        }
        "cpu" => {
            let rows = args.get_usize("rows", 8192)?;
            let deg = args.get_usize("deg", 16)?;
            let threads = args.get_usize("threads", cpu::hw_threads())?;
            let m = generate::uniform::<f64>(rows, rows, deg, 5);
            let csr = CsrMatrix::from_coo(&m);
            let x = vec![1.0f64; rows];
            let run = cpu::spmv_parallel(&csr, &x, threads, 5);
            println!(
                "cpu baseline: {} threads  {:.3} ms/iter  {:.3} GFLOP/s",
                run.threads,
                run.seconds * 1e3,
                run.gflops(m.nnz())
            );
        }
        other => {
            print_usage();
            bail!("unknown command {other}");
        }
    }
    Ok(())
}

fn gfl(nnz: usize, s: f64) -> f64 {
    2.0 * nnz as f64 / s / 1e9
}

/// Wall-clock smoke benchmark for the plan/execute coordinator: CG
/// iterations on a scale-free SPD system, serial vs threaded engine.
/// Emits a JSON summary so successive PRs have a perf trajectory.
fn bench_coordinator(args: &Args) -> Result<()> {
    let rows = args.get_usize("rows", 100_000)?;
    let deg = args.get_usize("deg", 8)?;
    let iters = args.get_usize("iters", 50)?;
    let n_dpus = args.get_usize("dpus", 256)?;
    let threads = args.get_usize("threads", cpu::hw_threads())?;
    let out_path = args.get("out").unwrap_or("BENCH_coordinator.json");

    let base = generate::scale_free::<f64>(rows, rows, deg, 0.6, 7);
    let a = crate::apps::cg::spd_from(&base);
    let b = vec![1.0f64; a.nrows()];
    println!(
        "bench-coordinator: CG x{iters} on {}x{} ({} nnz), {n_dpus} DPUs, {threads} host threads",
        a.nrows(),
        a.ncols(),
        a.nnz()
    );
    let sys = PimSystem::new(PimConfig { n_dpus, ..Default::default() })?;
    let spec = KernelSpec::coo_nnz();
    // tol = 0 forces exactly `iters` SpMV iterations (no early exit), so
    // the two engines do identical work.
    let wall = |engine: Engine| -> Result<(f64, usize)> {
        let exec = SpmvExecutor::with_engine(sys.clone(), engine);
        let t0 = std::time::Instant::now();
        let r = crate::apps::cg::solve(&exec, &spec, &a, &b, 0.0, iters)?;
        let dt = t0.elapsed().as_secs_f64();
        println!("  {:<8} {:>8.3}s wall ({} iters)", engine_name(engine), dt, r.stats.iterations);
        Ok((dt, r.stats.iterations))
    };
    let (serial_s, iters_done) = wall(Engine::Serial)?;
    let (threaded_s, _) = wall(Engine::threaded(threads))?;
    let speedup = serial_s / threaded_s.max(1e-12);
    println!("  speedup  {speedup:>8.2}x (threaded vs serial)");

    use crate::util::json::{num, obj, s};
    let j = obj(vec![
        ("bench", s("coordinator_cg_plan_execute")),
        ("rows", num(a.nrows() as f64)),
        ("nnz", num(a.nnz() as f64)),
        ("iters", num(iters_done as f64)),
        ("dpus", num(n_dpus as f64)),
        ("host_threads", num(threads as f64)),
        ("host_cores", num(cpu::hw_threads() as f64)),
        ("serial_wall_s", num(serial_s)),
        ("threaded_wall_s", num(threaded_s)),
        ("speedup", num(speedup)),
    ]);
    std::fs::write(out_path, j.to_string() + "\n")
        .with_context(|| format!("write {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

fn engine_name(e: Engine) -> &'static str {
    use crate::coordinator::ExecutionEngine;
    e.name()
}

fn print_solve_stats(st: &crate::apps::SolveStats) {
    println!(
        "PIM cost: matrix-load {:.3} ms (once) + per-iter avg [load {:.3} | kernel {:.3} | retrieve {:.3} | merge {:.3}] ms, energy {:.2e} J",
        st.matrix_load_s * 1e3,
        st.pim.load_s / st.iterations.max(1) as f64 * 1e3,
        st.pim.kernel_s / st.iterations.max(1) as f64 * 1e3,
        st.pim.retrieve_s / st.iterations.max(1) as f64 * 1e3,
        st.pim.merge_s / st.iterations.max(1) as f64 * 1e3,
        st.energy_j
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_command_and_flags() {
        let a = Args::parse(
            ["run", "--kernel", "CSR.nnz", "--dpus", "64", "--full"].map(String::from),
        )
        .unwrap();
        assert_eq!(a.command, "run");
        assert_eq!(a.get("kernel"), Some("CSR.nnz"));
        assert_eq!(a.get_usize("dpus", 0).unwrap(), 64);
        assert!(a.get_bool("full"));
        assert_eq!(a.get_usize("missing", 9).unwrap(), 9);
    }

    #[test]
    fn parse_rejects_stray_positional() {
        assert!(Args::parse(["run", "oops"].map(String::from)).is_err());
        assert!(Args::parse(["--flag-first"].map(String::from)).is_err());
    }

    #[test]
    fn matrix_lookup() {
        assert!(matrix_by_name("mini-sf", 1).is_ok());
        assert!(matrix_by_name("sf-mid", 1).is_ok());
        assert!(matrix_by_name("nope", 1).is_err());
    }

    #[test]
    fn run_command_smoke() {
        let a = Args::parse(
            ["run", "--kernel", "COO.nnz", "--matrix", "mini-band", "--dpus", "8", "--dtype", "int32"]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();
    }

    #[test]
    fn kernels_command_smoke() {
        run(Args::parse(["kernels"].map(String::from)).unwrap()).unwrap();
    }

    #[test]
    fn run_command_batched_smoke() {
        let a = Args::parse(
            ["run", "--kernel", "CSR.nnz", "--matrix", "mini-band", "--dpus", "8", "--batch", "5"]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();
    }

    #[test]
    fn solve_personalized_pagerank_smoke() {
        let a = Args::parse(
            ["solve", "--app", "pagerank", "--matrix", "mini-sf", "--dpus", "8", "--seeds", "0,3"]
                .map(String::from),
        )
        .unwrap();
        run(a).unwrap();
        assert!(Args::parse(
            ["solve", "--app", "pagerank", "--matrix", "mini-sf", "--seeds", "zero"]
                .map(String::from)
        )
        .map(run)
        .unwrap()
        .is_err());
    }
}
