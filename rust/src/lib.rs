//! # SparseP (reproduction)
//!
//! A reproduction of *"Towards Efficient Sparse Matrix Vector Multiplication
//! on Real Processing-In-Memory Systems"* (Giannoula et al., 2022) — the
//! SparseP library of 25 SpMV kernels for near-bank PIM systems, together
//! with the substrate the paper runs on: a calibrated simulator of the
//! UPMEM PIM architecture (the first publicly-available real-world PIM
//! system), host CPU baselines, and an XLA/PJRT accelerator path fed by
//! AOT-compiled JAX/Pallas kernels.
//!
//! ## Layout
//!
//! * [`matrix`] — sparse matrix formats (COO/CSR/BCSR/BCOO), generators,
//!   MatrixMarket I/O and sparsity statistics.
//! * [`pim`] — the UPMEM-class PIM system simulator: DPU pipeline timing,
//!   WRAM/MRAM DMA model, tasklet synchronization costs, host<->PIM
//!   transfer collectives (with the real system's same-size padding rule)
//!   and the energy model.
//! * [`kernels`] — per-DPU SpMV kernels (format x tasklet-balancing x
//!   synchronization scheme), executed functionally with cycle accounting.
//! * [`partition`] — 1D and 2D matrix partitioning across DPUs, and
//!   tasklet-level load balancers.
//! * [`coordinator`] — the host-side library, a plan/execute pipeline:
//!   [`coordinator::SpmvExecutor::plan`] partitions + converts + prices
//!   transfers once per (matrix, kernel) pair, and
//!   [`coordinator::SpmvExecutor::execute`] runs the per-DPU kernels —
//!   serially or on host threads via [`coordinator::Engine`] — and
//!   produces the paper's load/kernel/retrieve/merge breakdowns. For
//!   serving-style workloads, [`coordinator::SpmvExecutor::execute_batch`]
//!   multiplies many vectors against one resident plan in a single
//!   engine wave (SpMM-style, bit-identical to looped `execute`), and a
//!   [`coordinator::PlanCache`] keys plans by matrix fingerprint so
//!   callers without a place to hold plans still plan once.
//! * [`baselines`] — processor-centric comparators (multithreaded host CPU
//!   SpMV; analytic CPU/GPU roofline models).
//! * [`runtime`] — PJRT runtime that loads AOT artifacts (HLO text) built
//!   by `python/compile/aot.py` and executes them from Rust.
//! * [`bench_harness`] — a small measurement harness (criterion is not
//!   available offline) + per-figure drivers for the paper's evaluation.
//!
//! ## Quickstart: plan once, execute many
//!
//! Iterative apps (CG, Jacobi, PageRank — hundreds of SpMVs on one
//! matrix) plan once and stream vectors through the plan; that mirrors
//! the paper's cost model, where matrix placement is a one-time cost and
//! only the input vector moves per iteration:
//!
//! ```no_run
//! use sparsep::matrix::generate;
//! use sparsep::pim::PimSystem;
//! use sparsep::coordinator::{Engine, SpmvExecutor, KernelSpec};
//!
//! let m = generate::scale_free::<f32>(10_000, 10_000, 8, 0.6, 7);
//! // Threaded engine: per-DPU kernel simulations run on host threads
//! // (results are bit-identical to Engine::Serial).
//! let exec = SpmvExecutor::with_engine(PimSystem::with_dpus(256), Engine::threaded(0));
//!
//! // Plan once: partitioning, per-DPU format conversion, transfer sizing.
//! let plan = exec.plan(&KernelSpec::csr_nnz(), &m).unwrap();
//!
//! // Execute many: only the vector changes per call.
//! let x = vec![1.0f32; m.ncols()];
//! let run = exec.execute(&plan, &x).unwrap();
//! println!("y[0]={} breakdown={:?}", run.y[0], run.breakdown);
//! let iterated = exec.run_iterations(&plan, &x, 50).unwrap();
//! println!("50 iterations: {:.3} ms total", iterated.total.total_s() * 1e3);
//!
//! // One-shot convenience (plan + execute in one call):
//! let once = exec.run(&KernelSpec::coo_nnz(), &m, &x).unwrap();
//! assert_eq!(once.y, run.y);
//!
//! // Batched serving (SpMM-style): N queries against the resident
//! // matrix in one engine wave, bit-identical to looping `execute`.
//! let xs: Vec<Vec<f32>> = (0..32).map(|_| x.clone()).collect();
//! let batch = exec.execute_batch(&plan, &xs).unwrap();
//! println!("{} outputs, {:.3} ms modeled", batch.len(), batch.total().total_s() * 1e3);
//! ```
//!
//! The full pipeline — plan → execute → merge, the batched path, the
//! plan cache and the module map — is documented with a data-flow
//! diagram in `docs/ARCHITECTURE.md` at the repository root.

pub mod util;
pub mod matrix;
pub mod pim;
pub mod kernels;
pub mod partition;
pub mod coordinator;
pub mod apps;
pub mod baselines;
pub mod runtime;
pub mod bench_harness;
pub mod cli;

pub use matrix::dtype::{DType, SpElem};
