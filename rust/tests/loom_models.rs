//! Exhaustive concurrency models (loom) for the four hottest protocols
//! in the serving tier, plus the shard respawn race and the scheduler
//! pause/resume protocol.
//!
//! Compiled only under `--cfg loom` (a plain `cargo test` sees an empty
//! binary and needs no `loom` dependency). Run via `scripts/analyze.sh`,
//! which temporarily injects the loom dependency and sets
//! `RUSTFLAGS="--cfg loom"`; or by hand:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_models
//! ```
//!
//! Every model body lives in `sparsep::coordinator::verify` (so it can
//! drive the real `pub(crate)` machinery) or uses public facade types
//! directly. Models are scaled down — ≤ 3 threads, 2-element waves —
//! because loom explores every interleaving; the protocols themselves
//! are the production code paths, reached through the
//! `sparsep::util::sync` facade the whole crate is built on.

#![cfg(loom)]

use sparsep::coordinator::verify;
use sparsep::util::sync::atomic::{AtomicUsize, Ordering};
use sparsep::util::sync::{thread, Arc, RespawnSlot};

/// Bounded-exhaustive exploration: preemption bounding (3) keeps the
/// deeper models tractable while still covering every interleaving
/// that at most 3 forced preemptions can reach — the standard loom
/// configuration for condvar-heavy protocols.
fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    let mut b = loom::model::Builder::new();
    b.preemption_bound = Some(3);
    b.check(f);
}

#[test]
fn pool_wave_protocol_runs_every_index_exactly_once() {
    model(|| verify::pool_wave_round(2, 2));
}

#[test]
fn pool_wave_single_worker_with_wide_wave() {
    model(|| verify::pool_wave_round(1, 3));
}

#[test]
fn pool_task_panic_reraises_on_submitter_and_spares_workers() {
    model(verify::pool_panic_round);
}

#[test]
fn completions_wait_timeout_never_loses_a_racing_publish() {
    model(verify::completions_claim_round);
}

#[test]
fn buffer_pool_recycle_handoff_is_race_free() {
    model(verify::buffer_pool_recycle_round);
}

#[test]
fn respawn_slot_rebuilds_exactly_once_under_racing_respawners() {
    model(|| {
        // The shard dead-flag protocol (`Backends::ensure_alive`): two
        // threads race to respawn one killed backend. Exactly one may
        // rebuild (the double-checked write-lock protocol), exactly one
        // may report having respawned, and the slot must end alive.
        let slot: Arc<RespawnSlot<u32>> = Arc::new(RespawnSlot::new(0));
        slot.kill();
        let rebuilds = Arc::new(AtomicUsize::new(0));
        let respawn_credits = Arc::new(AtomicUsize::new(0));

        let racer = {
            let (slot, rebuilds, credits) =
                (Arc::clone(&slot), Arc::clone(&rebuilds), Arc::clone(&respawn_credits));
            thread::spawn_named("respawn-racer", move || {
                let did = slot
                    .ensure_alive(|s: &mut u32| {
                        rebuilds.fetch_add(1, Ordering::SeqCst);
                        *s += 1;
                        Ok::<(), ()>(())
                    })
                    .expect("rebuild cannot fail here");
                if did {
                    credits.fetch_add(1, Ordering::SeqCst);
                }
            })
        };
        let did = slot
            .ensure_alive(|s: &mut u32| {
                rebuilds.fetch_add(1, Ordering::SeqCst);
                *s += 1;
                Ok::<(), ()>(())
            })
            .expect("rebuild cannot fail here");
        if did {
            respawn_credits.fetch_add(1, Ordering::SeqCst);
        }
        racer.join().expect("racing respawner panicked");

        assert_eq!(rebuilds.load(Ordering::SeqCst), 1, "exactly one rebuild may run");
        assert_eq!(
            respawn_credits.load(Ordering::SeqCst),
            1,
            "exactly one caller may count the respawn"
        );
        assert!(!slot.is_dead(), "slot must end alive");
        assert_eq!(*slot.read(), 1, "the single rebuild's effect must be visible");
    });
}

#[test]
fn scheduler_pause_resume_with_full_tenant_queue_never_deadlocks() {
    model(verify::scheduler_pause_resume_round);
}
