//! Measurement harness + per-figure experiment drivers.
//!
//! `criterion` is not available in the offline vendor set, so this is a
//! small, honest stand-in: warmup + N timed samples, reporting min /
//! mean / p50, plus an aligned-table printer and a JSON-lines emitter so
//! results are machine-readable. The per-figure drivers in [`figures`]
//! regenerate every table/figure of the paper's evaluation (see
//! DESIGN.md §3 for the experiment index).

pub mod batch;
pub mod check;
pub mod figures;
pub mod grid;
pub mod hotpath;
pub mod resilience;
pub mod service;
pub mod shard;
pub mod tune;

use std::time::Instant;

/// Timing statistics over samples, seconds.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub min: f64,
    pub mean: f64,
    pub p50: f64,
    pub n: usize,
}

/// Measure `f` with `warmup` unrecorded calls and `samples` timed calls.
pub fn measure<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Sample {
    assert!(samples > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Sample {
        min: times[0],
        mean: times.iter().sum::<f64>() / times.len() as f64,
        p50: times[times.len() / 2],
        n: samples,
    }
}

/// Aligned-column table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Append a JSON line to `target/bench_results/<file>.jsonl` (best
/// effort; ignored on failure so benches run in read-only checkouts).
pub fn emit_jsonl(file: &str, value: &crate::util::json::Json) {
    let dir = std::path::Path::new("target/bench_results");
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let path = dir.join(format!("{file}.jsonl"));
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        use std::io::Write;
        let _ = writeln!(f, "{}", value.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_reports_sane_stats() {
        let mut x = 0u64;
        let s = measure(1, 5, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(s.n, 5);
        assert!(s.min <= s.mean);
        assert!(s.min > 0.0);
        std::hint::black_box(x);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
