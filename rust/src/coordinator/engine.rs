//! Execution engines: how per-DPU kernel simulations are driven.
//!
//! A real UPMEM deployment launches all allocated DPUs at once and waits
//! for the slowest; the simulator used to walk them one by one in the
//! host thread, which made iterative apps and the figure drivers scale
//! with `n_dpus` in *wall-clock* even though the modeled system is
//! parallel. An [`ExecutionEngine`] closes that gap: it maps a pure
//! per-DPU function over the work items, either serially
//! ([`SerialEngine`]) or on `std::thread` scoped threads
//! ([`ThreadedEngine`]).
//!
//! Engines only change *where* the per-item closures run. Results are
//! collected back in item order and every aggregation (output vector,
//! cycle maxima, energy sums) happens serially afterwards, so the two
//! engines are bit-identical by construction — a property the
//! `engine_equivalence` test suite locks in.
//!
//! The unit of work an engine schedules is whatever the caller indexes:
//! single-vector execution maps over work items (one per DPU slice),
//! and the batched path ([`super::ExecutionPlan::execute_batch_runs`])
//! maps over (work-item x vector-block) units — so a batch keeps every
//! worker busy even when the DPU count alone would not, with no engine
//! changes and the same by-index determinism (locked by the
//! `batch_equivalence` suite).
//!
//! [`super::SpmvService`]'s pipelined request engine layers on top: its
//! kernel stage drives one engine wave per vector block while separate
//! stage threads prepare the next block and merge the previous one, so
//! the engine choice composes with (rather than competes against)
//! request pipelining. The `service_equivalence` suite locks that the
//! composition stays bit-identical to synchronous execution.

/// Strategy for running independent per-DPU work items.
pub trait ExecutionEngine {
    /// Engine name for logs and JSON output.
    fn name(&self) -> &'static str;

    /// Apply `f` to every index in `0..n` and return the results in
    /// index order. `f` must be pure with respect to ordering: engines
    /// are free to evaluate indices concurrently and in any order.
    fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync;
}

/// Runs every work item on the calling thread, in order.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SerialEngine;

impl ExecutionEngine for SerialEngine {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        (0..n).map(f).collect()
    }
}

/// Runs work items on scoped OS threads (no external dependencies).
///
/// Workers pull item indices from a shared atomic counter (dynamic load
/// balancing — skewed per-DPU work cannot strand one worker with all
/// the heavy slices), and results are reassembled by index — completion
/// order never leaks into results.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ThreadedEngine {
    /// Worker count; 0 means "all available hardware threads".
    pub threads: usize,
}

impl ThreadedEngine {
    pub fn new(threads: usize) -> ThreadedEngine {
        ThreadedEngine { threads }
    }

    /// Resolved worker count (>= 1).
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

impl Default for ThreadedEngine {
    fn default() -> ThreadedEngine {
        ThreadedEngine { threads: 0 }
    }
}

impl ExecutionEngine for ThreadedEngine {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let workers = self.effective_threads().min(n);
        if workers <= 1 {
            return (0..n).map(f).collect();
        }
        // Dynamic work distribution: workers pull the next index from a
        // shared counter, so skewed per-item cost (a hot DPU slice on a
        // scale-free matrix) cannot gate wall-clock on one unlucky
        // worker. Each worker tags results with their index and the
        // reassembly below is by index — bit-deterministic regardless
        // of which worker ran what.
        let f = &f;
        let next = AtomicUsize::new(0);
        let next = &next;
        let mut parts: Vec<Vec<(usize, R)>> = Vec::with_capacity(workers);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    s.spawn(move || {
                        let mut local: Vec<(usize, R)> = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            local.push((i, f(i)));
                        }
                        local
                    })
                })
                .collect();
            for h in handles {
                parts.push(h.join().expect("execution-engine worker panicked"));
            }
        });
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in parts.into_iter().flatten() {
            debug_assert!(out[i].is_none());
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("execution engine missed an index")).collect()
    }
}

/// Runtime-selectable engine (what [`super::SpmvExecutor`] carries).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Serial,
    Threaded(ThreadedEngine),
}

impl Engine {
    /// Threaded engine with `threads` workers (0 = all hardware threads).
    pub fn threaded(threads: usize) -> Engine {
        Engine::Threaded(ThreadedEngine::new(threads))
    }

    /// Engine selection from the environment: `SPARSEP_ENGINE`
    /// (`serial` | `threaded`, default serial) and `SPARSEP_THREADS`
    /// (worker count for the threaded engine, default all cores). This
    /// is how the CLI's `--engine` / `--threads` flags reach code that
    /// builds its own executors (the bench-harness figure drivers call
    /// this explicitly; `SpmvExecutor::new` itself stays deterministic
    /// and defaults to serial).
    pub fn from_env() -> Engine {
        let threads = std::env::var("SPARSEP_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(0);
        match std::env::var("SPARSEP_ENGINE").as_deref() {
            Ok("threaded") => Engine::threaded(threads),
            Ok("serial") | Err(_) => Engine::Serial,
            Ok(other) => {
                eprintln!(
                    "warning: unrecognized SPARSEP_ENGINE={other:?} (expected serial|threaded); using serial"
                );
                Engine::Serial
            }
        }
    }

    /// Publish this engine choice to the environment (see
    /// [`Engine::from_env`]). Call before spawning any threads
    /// (`std::env::set_var` is not thread-safe); the CLI does this once
    /// at startup, before the first executor exists.
    pub fn export_env(&self) {
        match self {
            Engine::Serial => std::env::set_var("SPARSEP_ENGINE", "serial"),
            Engine::Threaded(t) => {
                std::env::set_var("SPARSEP_ENGINE", "threaded");
                std::env::set_var("SPARSEP_THREADS", t.threads.to_string());
            }
        }
    }
}

impl Default for Engine {
    fn default() -> Engine {
        Engine::Serial
    }
}

impl ExecutionEngine for Engine {
    fn name(&self) -> &'static str {
        match self {
            Engine::Serial => SerialEngine.name(),
            Engine::Threaded(t) => t.name(),
        }
    }

    fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        match self {
            Engine::Serial => SerialEngine.map_indexed(n, f),
            Engine::Threaded(t) => t.map_indexed(n, f),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_preserves_order() {
        let v = SerialEngine.map_indexed(5, |i| i * 2);
        assert_eq!(v, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn threaded_matches_serial_for_any_thread_count() {
        let work = |i: usize| (i, i * i + 1);
        let want = SerialEngine.map_indexed(97, work);
        for t in [1usize, 2, 3, 8, 64, 200] {
            let got = ThreadedEngine::new(t).map_indexed(97, work);
            assert_eq!(got, want, "threads={t}");
        }
    }

    #[test]
    fn threaded_handles_empty_and_single() {
        assert_eq!(ThreadedEngine::new(4).map_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(ThreadedEngine::new(4).map_indexed(1, |i| i + 9), vec![9]);
    }

    #[test]
    fn threaded_actually_uses_multiple_threads() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        // Per-item work must be slow enough that one worker cannot
        // drain the whole range before the others are even scheduled
        // (threads take tens of microseconds to spawn).
        ThreadedEngine::new(4).map_indexed(64, |i| {
            ids.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_micros(500));
            i
        });
        assert!(ids.lock().unwrap().len() > 1, "expected >1 worker thread");
    }

    #[test]
    fn engine_enum_delegates() {
        assert_eq!(Engine::Serial.name(), "serial");
        assert_eq!(Engine::threaded(2).name(), "threaded");
        assert_eq!(
            Engine::threaded(3).map_indexed(10, |i| i),
            Engine::Serial.map_indexed(10, |i| i)
        );
    }

    #[test]
    fn effective_threads_resolves_zero() {
        assert!(ThreadedEngine::new(0).effective_threads() >= 1);
        assert_eq!(ThreadedEngine::new(6).effective_threads(), 6);
    }
}
