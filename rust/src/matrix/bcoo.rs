//! BCOO (block coordinate) format.
//!
//! Like BCSR, the matrix is tiled into dense `R x C` blocks, but each
//! stored block carries both its block-row and block-column index — the
//! block analogue of COO. As with COO vs CSR, the explicit block-row
//! index is what lets nnz-balanced partitions split *inside* a block row,
//! which the `BCOO.nnz` kernels exploit.

use super::bcsr::BcsrMatrix;
use super::coo::CooMatrix;
use super::dtype::SpElem;

/// A sparse matrix in BCOO format, blocks sorted by (block_row, block_col).
#[derive(Clone, Debug, PartialEq)]
pub struct BcooMatrix<T: SpElem> {
    nrows: usize,
    ncols: usize,
    /// Block height.
    pub br: usize,
    /// Block width.
    pub bc: usize,
    /// Block-row index of each stored block.
    pub block_rows: Vec<u32>,
    /// Block-column index of each stored block.
    pub block_cols: Vec<u32>,
    /// Dense block values, row-major within each `br*bc` block.
    pub vals: Vec<T>,
    nnz_orig: usize,
}

impl<T: SpElem> BcooMatrix<T> {
    /// Convert from COO via BCSR (reuses the grouping logic).
    pub fn from_coo(coo: &CooMatrix<T>, br: usize, bc: usize) -> Self {
        let bcsr = BcsrMatrix::from_coo(coo, br, bc);
        Self::from_bcsr(&bcsr)
    }

    /// Convert from BCSR by materializing block-row indices.
    pub fn from_bcsr(b: &BcsrMatrix<T>) -> Self {
        let mut block_rows = Vec::with_capacity(b.nblocks());
        for i in 0..b.n_block_rows() {
            for _ in 0..b.block_row_nblocks(i) {
                block_rows.push(i as u32);
            }
        }
        BcooMatrix {
            nrows: b.nrows(),
            ncols: b.ncols(),
            br: b.br,
            bc: b.bc,
            block_rows,
            block_cols: b.block_cols.clone(),
            vals: b.vals.clone(),
            nnz_orig: b.nnz(),
        }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }
    pub fn ncols(&self) -> usize {
        self.ncols
    }
    /// Original (unfilled) non-zero count.
    pub fn nnz(&self) -> usize {
        self.nnz_orig
    }
    pub fn nblocks(&self) -> usize {
        self.block_cols.len()
    }
    pub fn stored_vals(&self) -> usize {
        self.vals.len()
    }

    /// Dense values of block `i`.
    #[inline]
    pub fn block(&self, i: usize) -> &[T] {
        &self.vals[i * self.br * self.bc..(i + 1) * self.br * self.bc]
    }

    /// Reference SpMV: `y = A * x`.
    pub fn spmv(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![T::zero(); self.nrows];
        let (br, bc) = (self.br, self.bc);
        for i in 0..self.nblocks() {
            let blk = self.block(i);
            let row0 = self.block_rows[i] as usize * br;
            let col0 = self.block_cols[i] as usize * bc;
            for rr in 0..br {
                let r = row0 + rr;
                if r >= self.nrows {
                    break;
                }
                let mut acc = y[r];
                for cc in 0..bc {
                    let c = col0 + cc;
                    if c >= self.ncols {
                        break;
                    }
                    acc = T::mac(acc, blk[rr * bc + cc], x[c]);
                }
                y[r] = acc;
            }
        }
        y
    }

    /// Storage footprint in bytes (two 4-byte indices per block).
    pub fn size_bytes(&self) -> usize {
        self.nblocks() * 8 + self.stored_vals() * T::DTYPE.size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CooMatrix<f64> {
        CooMatrix::from_triples(
            4,
            4,
            vec![
                (0, 0, 1.0),
                (0, 1, 2.0),
                (1, 0, 3.0),
                (1, 1, 4.0),
                (2, 3, 5.0),
                (3, 0, 6.0),
            ],
        )
    }

    #[test]
    fn structure_matches_bcsr() {
        let m = small();
        let bcsr = BcsrMatrix::from_coo(&m, 2, 2);
        let bcoo = BcooMatrix::from_bcsr(&bcsr);
        assert_eq!(bcoo.nblocks(), bcsr.nblocks());
        assert_eq!(bcoo.block_rows, vec![0, 1, 1]);
        assert_eq!(bcoo.block_cols, bcsr.block_cols);
    }

    #[test]
    fn spmv_matches_coo() {
        let m = small();
        let x = [1.0, 10.0, 100.0, 1000.0];
        for (br, bc) in [(1, 1), (2, 2), (4, 2), (3, 3)] {
            let b = BcooMatrix::from_coo(&m, br, bc);
            assert_eq!(b.spmv(&x), m.spmv(&x), "block {br}x{bc}");
        }
    }

    #[test]
    fn ragged_edge() {
        let m = CooMatrix::from_triples(5, 3, vec![(4, 2, 7.0f32), (0, 0, 1.0)]);
        let b = BcooMatrix::from_coo(&m, 2, 2);
        assert_eq!(b.spmv(&[1.0, 1.0, 1.0]), m.spmv(&[1.0, 1.0, 1.0]));
    }

    #[test]
    fn empty() {
        let b = BcooMatrix::from_coo(&CooMatrix::<i32>::zeros(3, 3), 2, 2);
        assert_eq!(b.nblocks(), 0);
        assert_eq!(b.spmv(&[1, 1, 1]), vec![0, 0, 0]);
    }
}
