//! Batched-serving wall-clock benchmark (`sparsep bench-batch`).
//!
//! Measures the amortization the SpMM-style serving path buys: a batch
//! of right-hand sides multiplied against one resident matrix through
//! [`crate::coordinator::ExecutionPlan::execute_batch_runs`] versus the
//! same vectors looped through single-vector
//! [`crate::coordinator::ExecutionPlan::execute`], on both engines.
//! The plan comes from a [`PlanCache`] built once before any timing —
//! the matrix fingerprint and plan build stay out of the timed region,
//! so the numbers measure execution, not hashing. The JSON summary
//! lands in `BENCH_batch.json` so successive PRs can track the
//! batched-throughput trajectory next to `BENCH_coordinator.json` and
//! `BENCH_service.json`.

use crate::coordinator::{Engine, KernelSpec, PlanCache, SpmvExecutor, VECTOR_BLOCK};
use crate::matrix::generate;
use crate::pim::{PimConfig, PimSystem};
use crate::util::json::{num, obj, s};
use crate::util::{Context, Result};
use std::time::Instant;

/// Knobs for [`run`] (CLI flags of `sparsep bench-batch`).
#[derive(Clone, Debug)]
pub struct BatchBenchOpts {
    /// Matrix dimension (square, scale-free class).
    pub rows: usize,
    /// Average degree (non-zeros per row).
    pub deg: usize,
    /// Number of right-hand-side vectors.
    pub batch: usize,
    /// Simulated DPU count.
    pub n_dpus: usize,
    /// Threaded-engine worker count (0 = all cores).
    pub threads: usize,
    /// Kernel name (see `sparsep kernels`).
    pub kernel: String,
    /// Timed samples per measurement (min is reported).
    pub samples: usize,
    /// Output JSON path.
    pub out: String,
}

impl Default for BatchBenchOpts {
    fn default() -> BatchBenchOpts {
        BatchBenchOpts {
            rows: 50_000,
            deg: 8,
            batch: 32,
            n_dpus: 256,
            threads: 0,
            kernel: "CSR.nnz".to_string(),
            samples: 2,
            out: "BENCH_batch.json".to_string(),
        }
    }
}

/// Run the benchmark and write the JSON summary to `opts.out`.
pub fn run(opts: &BatchBenchOpts) -> Result<()> {
    crate::ensure!(opts.batch >= 1, "bench-batch needs --batch >= 1");
    crate::ensure!(opts.samples >= 1, "bench-batch needs --samples >= 1");
    let spec = KernelSpec::by_name(&opts.kernel, 8)
        .with_context(|| format!("unknown kernel {} (see `sparsep kernels`)", opts.kernel))?;
    let m = generate::scale_free::<f64>(opts.rows, opts.rows, opts.deg, 0.6, 7);
    let xs: Vec<Vec<f64>> = (0..opts.batch)
        .map(|b| (0..m.ncols()).map(|i| ((i + 3 * b) % 9) as f64 - 4.0).collect())
        .collect();
    let sys = PimSystem::new(PimConfig { n_dpus: opts.n_dpus, ..Default::default() })?;
    println!(
        "bench-batch: {} x{} vectors on {}x{} ({} nnz), {} DPUs, vector block {}",
        spec.name,
        opts.batch,
        m.nrows(),
        m.ncols(),
        m.nnz(),
        opts.n_dpus,
        VECTOR_BLOCK
    );

    // One shared cache, planned ONCE before any timing: plans do not
    // depend on the engine, so both engines reuse the same resident
    // plan. Fingerprinting the matrix is O(nnz) — hoisting it (and the
    // plan build) out of the timed region keeps the cache-hit timings
    // below measuring execution, not hashing.
    let cache: PlanCache<f64> = PlanCache::new();
    let plan = cache.plan(&SpmvExecutor::new(sys.clone()), &spec, &m)?;
    let wall = |engine: Engine| -> Result<(f64, f64)> {
        let exec = SpmvExecutor::with_engine(sys.clone(), engine);
        // Warmup + sanity: the batched path must agree with the looped
        // one bit-for-bit.
        let warm_single = plan.execute(&exec, &xs[0])?;
        let warm_batch = plan.execute_batch_runs(&exec, &xs[..2.min(xs.len())])?;
        crate::ensure!(
            warm_batch.runs[0].y == warm_single.y,
            "batched output diverged from single-vector output"
        );
        let mut looped = f64::INFINITY;
        let mut batched = f64::INFINITY;
        for _ in 0..opts.samples {
            let t0 = Instant::now();
            for x in &xs {
                let r = plan.execute(&exec, x)?;
                std::hint::black_box(&r.y);
            }
            looped = looped.min(t0.elapsed().as_secs_f64());
            let t1 = Instant::now();
            let b = plan.execute_batch_runs(&exec, &xs)?;
            std::hint::black_box(&b.runs.last().unwrap().y);
            batched = batched.min(t1.elapsed().as_secs_f64());
        }
        Ok((looped, batched))
    };

    let (serial_looped, serial_batched) = wall(Engine::Serial)?;
    let (thr_looped, thr_batched) = wall(Engine::threaded(opts.threads))?;
    let report = |name: &str, looped: f64, batched: f64| {
        println!(
            "  {:<8} looped {:>8.3}s | batched {:>8.3}s | speedup {:>5.2}x",
            name,
            looped,
            batched,
            looped / batched.max(1e-12)
        );
    };
    report("serial", serial_looped, serial_batched);
    report("threaded", thr_looped, thr_batched);
    println!(
        "  plan cache: {} hit(s), {} miss(es), {} resident",
        cache.hits(),
        cache.misses(),
        cache.len()
    );

    let j = obj(vec![
        ("bench", s("batch_spmm_serving")),
        ("kernel", s(&spec.name)),
        ("rows", num(m.nrows() as f64)),
        ("nnz", num(m.nnz() as f64)),
        ("batch", num(opts.batch as f64)),
        ("vector_block", num(VECTOR_BLOCK as f64)),
        ("dpus", num(opts.n_dpus as f64)),
        ("host_threads", num(opts.threads as f64)),
        ("samples", num(opts.samples as f64)),
        ("serial_looped_wall_s", num(serial_looped)),
        ("serial_batched_wall_s", num(serial_batched)),
        ("threaded_looped_wall_s", num(thr_looped)),
        ("threaded_batched_wall_s", num(thr_batched)),
        ("serial_speedup", num(serial_looped / serial_batched.max(1e-12))),
        ("threaded_speedup", num(thr_looped / thr_batched.max(1e-12))),
        ("plan_cache_hits", num(cache.hits() as f64)),
        ("plan_cache_misses", num(cache.misses() as f64)),
    ]);
    std::fs::write(&opts.out, j.to_string() + "\n")
        .with_context(|| format!("write {}", opts.out))?;
    println!("wrote {}", opts.out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_batch_smoke_writes_json() {
        let dir = std::env::temp_dir().join("sparsep_bench_batch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_batch_test.json");
        let opts = BatchBenchOpts {
            rows: 400,
            deg: 4,
            batch: 5,
            n_dpus: 8,
            threads: 2,
            samples: 1,
            out: out.to_str().unwrap().to_string(),
            ..Default::default()
        };
        run(&opts).unwrap();
        let txt = std::fs::read_to_string(&out).unwrap();
        let j = crate::util::json::Json::parse(&txt).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("batch_spmm_serving"));
        assert_eq!(j.get("batch").as_usize(), Some(5));
        assert!(j.get("threaded_batched_wall_s").as_f64().unwrap() > 0.0);
        std::fs::remove_file(&out).ok();
    }
}
