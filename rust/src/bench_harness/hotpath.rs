//! Host hot-path benchmark (`sparsep bench-hotpath`).
//!
//! Quantifies the hot-path overhaul end to end, old vs new:
//!
//! * **engine**: iterated SpMV over one plan on the legacy
//!   spawn-per-wave [`ThreadedEngine`] versus the persistent
//!   [`PooledEngine`] (and serial as the floor) — the purest view of
//!   what removing per-wave thread spawn/join buys, since an iterate is
//!   one engine wave per iteration.
//! * **serving**: the same engines behind a [`ShardedService`] at 1 and
//!   4 shards, for all three request kinds (spmv / batch / iterate) —
//!   this additionally exercises the `Arc` zero-copy scatter (payloads
//!   shared across shards instead of memcpy'd per shard) and the
//!   plan-time tasklet splits (kernels stop re-splitting per wave).
//!
//! Results are bit-identical across all engines and shard counts
//! (locked by `engine_equivalence` / `shard_equivalence`); only wall
//! clock differs. The JSON summary lands in `BENCH_hotpath.json` next
//! to the other `BENCH_*.json` trajectories.
//!
//! [`ThreadedEngine`]: crate::coordinator::ThreadedEngine
//! [`PooledEngine`]: crate::coordinator::PooledEngine

use crate::coordinator::{
    Engine, KernelSpec, ShardedService, ShardedServiceBuilder, SpmvExecutor,
};
use crate::matrix::generate;
use crate::pim::{PimConfig, PimSystem};
use crate::util::json::{num, s, Json};
use crate::util::{Context, Result};
use std::collections::BTreeMap;
use std::time::Instant;

/// Shard counts the serving matrix sweeps.
pub const SHARD_COUNTS: [usize; 2] = [1, 4];

/// Sequential spmv requests per sample (the spmv row measures per-call
/// overhead, so one call would be noise).
const SPMV_CALLS: usize = 8;

/// Knobs for [`run`] (CLI flags of `sparsep bench-hotpath`).
#[derive(Clone, Debug)]
pub struct HotpathBenchOpts {
    /// Matrix dimension (square, scale-free class).
    pub rows: usize,
    /// Average degree (non-zeros per row).
    pub deg: usize,
    /// Iterations of the iterate measurements (= engine waves).
    pub iters: usize,
    /// Right-hand-side vectors of the batch measurement.
    pub batch: usize,
    /// Simulated DPU count (per shard on the serving rows).
    pub n_dpus: usize,
    /// Worker count for both threaded engines (0 = all cores).
    pub threads: usize,
    /// Kernel name (see `sparsep kernels`).
    pub kernel: String,
    /// Timed samples per measurement (min is reported).
    pub samples: usize,
    /// Output JSON path.
    pub out: String,
}

impl Default for HotpathBenchOpts {
    fn default() -> HotpathBenchOpts {
        HotpathBenchOpts {
            rows: 20_000,
            deg: 8,
            iters: 80,
            batch: 16,
            n_dpus: 256,
            threads: 0,
            kernel: "CSR.nnz".to_string(),
            samples: 2,
            out: "BENCH_hotpath.json".to_string(),
        }
    }
}

fn x_for(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i % 9) as f64) - 4.0).collect()
}

/// Run the benchmark and write the JSON summary to `opts.out`.
pub fn run(opts: &HotpathBenchOpts) -> Result<()> {
    crate::ensure!(opts.iters >= 1, "bench-hotpath needs --iters >= 1");
    crate::ensure!(opts.batch >= 1, "bench-hotpath needs --batch >= 1");
    crate::ensure!(opts.samples >= 1, "bench-hotpath needs --samples >= 1");
    let spec = KernelSpec::by_name(&opts.kernel, 8)
        .with_context(|| format!("unknown kernel {} (see `sparsep kernels`)", opts.kernel))?;
    let m = generate::scale_free::<f64>(opts.rows, opts.rows, opts.deg, 0.6, 7);
    let sys = PimSystem::new(PimConfig { n_dpus: opts.n_dpus, ..Default::default() })?;
    let x = x_for(m.ncols());
    let xs: Vec<Vec<f64>> = (0..opts.batch)
        .map(|b| (0..m.ncols()).map(|i| ((i + 3 * b) % 9) as f64 - 4.0).collect())
        .collect();
    let engines = [
        ("serial", Engine::Serial),
        ("spawning", Engine::spawning(opts.threads)),
        ("pooled", Engine::threaded(opts.threads)),
    ];
    println!(
        "bench-hotpath: {} on {}x{} ({} nnz), {} DPUs, iterate x{}, batch x{}, spmv x{}",
        spec.name,
        m.nrows(),
        m.ncols(),
        m.nnz(),
        opts.n_dpus,
        opts.iters,
        opts.batch,
        SPMV_CALLS
    );

    let mut fields: BTreeMap<String, Json> = BTreeMap::new();
    fields.insert("bench".into(), s("hotpath_overhaul"));
    fields.insert("kernel".into(), s(&spec.name));
    fields.insert("rows".into(), num(m.nrows() as f64));
    fields.insert("nnz".into(), num(m.nnz() as f64));
    fields.insert("iters".into(), num(opts.iters as f64));
    fields.insert("batch".into(), num(opts.batch as f64));
    fields.insert("spmv_calls".into(), num(SPMV_CALLS as f64));
    fields.insert("dpus".into(), num(opts.n_dpus as f64));
    fields.insert("host_threads".into(), num(opts.threads as f64));
    fields.insert("samples".into(), num(opts.samples as f64));

    // --- engine level: one plan, `iters` waves of run_iterations -----
    // The plan is built once and shared (plans are engine-independent);
    // the timed region is purely waves of kernel simulation, so the
    // spawn-per-wave tax is the whole difference between the rows.
    let plan = SpmvExecutor::new(sys.clone()).plan(&spec, &m)?;
    let mut engine_iter = BTreeMap::new();
    for (name, engine) in engines {
        let exec = SpmvExecutor::with_engine(sys.clone(), engine);
        // Untimed warm-up wave: the pooled engine spawns its
        // process-wide workers on first use, and that one-time cost
        // must not land in the timed region (it is exactly the cost the
        // pool exists to amortize away).
        let _ = plan.run_iterations(&exec, &x, 1)?;
        let mut best = f64::INFINITY;
        for _ in 0..opts.samples {
            let t0 = Instant::now();
            let it = plan.run_iterations(&exec, &x, opts.iters)?;
            std::hint::black_box(&it.last.y);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        println!("  engine iterate {name:<9} {best:>8.3}s");
        fields.insert(format!("engine_iterate_{name}_wall_s"), num(best));
        engine_iter.insert(name, best);
    }
    let engine_speedup =
        engine_iter["spawning"] / engine_iter["pooled"].max(1e-12);
    println!("  engine iterate pooled-vs-spawning speedup {engine_speedup:>5.2}x");
    fields.insert("pooled_vs_spawning_iterate_speedup".into(), num(engine_speedup));

    // --- serving level: spmv / batch / iterate x engines x shards ----
    for shards in SHARD_COUNTS {
        for (name, engine) in engines {
            let svc: ShardedService<f64> = ShardedServiceBuilder::new()
                .shards(shards)
                .engine(engine)
                .build(sys.clone())?;
            let handle = svc.load(&m, &spec)?; // plans + splits, out of timing
            // Verify once per configuration (results never depend on
            // engine or shard count; the suites lock this, the bench
            // spot-checks it).
            crate::ensure!(
                svc.spmv(&handle, &x)?.y == m.spmv(&x),
                "hot-path output diverged from host oracle ({name}, {shards} shards)"
            );
            let (mut spmv_s, mut batch_s, mut iter_s) =
                (f64::INFINITY, f64::INFINITY, f64::INFINITY);
            for _ in 0..opts.samples {
                let t0 = Instant::now();
                for _ in 0..SPMV_CALLS {
                    std::hint::black_box(&svc.spmv(&handle, &x)?.y);
                }
                spmv_s = spmv_s.min(t0.elapsed().as_secs_f64());
                let t1 = Instant::now();
                std::hint::black_box(&svc.spmv_batch(&handle, &xs)?.runs.last().unwrap().y);
                batch_s = batch_s.min(t1.elapsed().as_secs_f64());
                let t2 = Instant::now();
                std::hint::black_box(&svc.iterate(&handle, &x, opts.iters)?.last.y);
                iter_s = iter_s.min(t2.elapsed().as_secs_f64());
            }
            println!(
                "  shards {shards} {name:<9} spmv {spmv_s:>8.3}s | batch {batch_s:>8.3}s | iterate {iter_s:>8.3}s"
            );
            fields.insert(format!("{name}_s{shards}_spmv_wall_s"), num(spmv_s));
            fields.insert(format!("{name}_s{shards}_batch_wall_s"), num(batch_s));
            fields.insert(format!("{name}_s{shards}_iterate_wall_s"), num(iter_s));
        }
    }

    let j = Json::Obj(fields);
    std::fs::write(&opts.out, j.to_string() + "\n")
        .with_context(|| format!("write {}", opts.out))?;
    println!("wrote {}", opts.out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_hotpath_smoke_writes_json() {
        let dir = std::env::temp_dir().join("sparsep_bench_hotpath_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_hotpath_test.json");
        let opts = HotpathBenchOpts {
            rows: 300,
            deg: 4,
            iters: 3,
            batch: 3,
            n_dpus: 8,
            threads: 2,
            samples: 1,
            out: out.to_str().unwrap().to_string(),
            ..Default::default()
        };
        run(&opts).unwrap();
        let txt = std::fs::read_to_string(&out).unwrap();
        let j = crate::util::json::Json::parse(&txt).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("hotpath_overhaul"));
        assert!(j.get("engine_iterate_pooled_wall_s").as_f64().unwrap() > 0.0);
        assert!(j.get("engine_iterate_spawning_wall_s").as_f64().unwrap() > 0.0);
        assert!(j.get("pooled_s1_iterate_wall_s").as_f64().unwrap() > 0.0);
        assert!(j.get("serial_s4_batch_wall_s").as_f64().unwrap() > 0.0);
        std::fs::remove_file(&out).ok();
    }
}
