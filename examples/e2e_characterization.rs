//! End-to-end driver: the full SparseP characterization on a real small
//! workload, proving all three layers compose.
//!
//! What it does, in order:
//! 1. generates the evaluation matrix suite and prints Table 2;
//! 2. runs **all 25 kernels** on every suite matrix on the simulated
//!    2048-DPU system, verifying every output against the host oracle;
//! 3. runs the *measured* host-CPU baseline (real threads);
//! 4. runs the *measured* accelerator path: the AOT-compiled JAX/Pallas
//!    ELL kernel through XLA/PJRT (L1 -> L2 -> HLO text -> Rust);
//! 5. reports the paper's headline metric: PIM fraction-of-peak vs
//!    CPU/GPU fraction-of-peak, plus the per-matrix best kernel
//!    (the paper's "adaptive selection" conclusion).
//!
//! Run with `--full` for the paper-sized suite (minutes), default is the
//! mini suite (~seconds). Results land in target/bench_results/*.jsonl
//! and are summarized in EXPERIMENTS.md.

use sparsep::baselines::{cpu, roofline};
use sparsep::bench_harness::figures;
use sparsep::bench_harness::Table;
use sparsep::coordinator::{Engine, KernelSpec, SpmvExecutor};
use sparsep::matrix::{generate, CooMatrix, CsrMatrix, DType, MatrixStats};
use sparsep::pim::PimSystem;
use sparsep::runtime::{ell_host, ArtifactRunner};

fn main() -> sparsep::util::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let t_start = std::time::Instant::now();
    println!("=== SparseP end-to-end characterization ({}) ===", if full { "full suite" } else { "mini suite" });

    // -- 1. suite + Table 2 ------------------------------------------
    let entries = if full { generate::suite() } else { generate::mini_suite() };
    println!("\n{}", MatrixStats::table_header());
    let suite: Vec<(String, CooMatrix<f64>)> = entries
        .iter()
        .map(|e| {
            let m = (e.gen)(7);
            println!("{}", MatrixStats::of(&m).table_row(e.name));
            (e.name.to_string(), m)
        })
        .collect();

    // -- 2. all 25 kernels x suite, verified ----------------------------
    // DPU count sized so every DPU has work (fraction-of-peak is
    // meaningless on starved DPUs); full suite uses the whole system.
    let n_dpus = if full { 2048usize } else { 64 };
    let exec = SpmvExecutor::with_engine(PimSystem::with_dpus(n_dpus), Engine::threaded(0));
    let mut best_rows = Table::new(&["matrix", "best-kernel", "e2e-ms", "kernel-GF/s", "%peak(fp64)"]);
    let mut verified = 0usize;
    let mut frac_sum = 0.0;
    for (name, m) in &suite {
        let x: Vec<f64> = (0..m.ncols()).map(|i| ((i % 9) as f64) - 4.0).collect();
        let gold = m.spmv(&x);
        let mut best: Option<(String, f64, f64)> = None;
        for spec in KernelSpec::all25(8) {
            let plan = exec.plan(&spec, m)?;
            let r = plan.execute(&exec, &x)?;
            sparsep::ensure!(r.y == gold, "{name}/{}: output mismatch", spec.name);
            verified += 1;
            let total = r.breakdown.total_s();
            if best.as_ref().map_or(true, |b| total < b.1) {
                best = Some((spec.name.clone(), total, r.kernel_gflops()));
            }
        }
        let (kname, total, kg) = best.unwrap();
        let frac = roofline::pim_fraction_of_peak(kg, n_dpus, DType::F64);
        frac_sum += frac;
        best_rows.row(&[
            name.clone(),
            kname,
            format!("{:.3}", total * 1e3),
            format!("{kg:.2}"),
            format!("{:.1}%", frac * 100.0),
        ]);
    }
    println!("\n== per-matrix best kernel (25 kernels x {} matrices, {verified} runs verified) ==", suite.len());
    best_rows.print();
    println!(
        "PIM mean fraction-of-peak across suite: {:.1}% (paper reports 51.7% avg for fp32)",
        100.0 * frac_sum / suite.len() as f64
    );

    // -- 3. measured CPU baseline --------------------------------------
    println!("\n== measured host-CPU baseline ==");
    let (bname, bm) = &suite[suite.len() - 1];
    let csr64 = CsrMatrix::from_coo(bm);
    let x64 = vec![1.0f64; bm.ncols()];
    let run = cpu::spmv_parallel(&csr64, &x64, cpu::hw_threads().min(8), 5);
    println!(
        "{bname}: {} threads, {:.3} ms/iter, {:.2} GFLOP/s (measured wall clock)",
        run.threads,
        run.seconds * 1e3,
        run.gflops(bm.nnz())
    );

    // -- 4. measured XLA/PJRT accelerator path -------------------------
    println!("\n== measured XLA/PJRT path (AOT JAX/Pallas ELL kernel) ==");
    match ArtifactRunner::load_default() {
        Err(e) => println!("skipped: {e} (run `make artifacts`)"),
        Ok(runner) => {
            let mf: CooMatrix<f32> = suite[0].1.cast();
            let csr = CsrMatrix::from_coo(&mf);
            match ell_host::stage(&runner, &csr) {
                Err(e) => println!("skipped ({}): {e}", suite[0].0),
                Ok(staged) => {
                    let x: Vec<f32> = (0..mf.ncols()).map(|i| ((i % 5) as f32) - 2.0).collect();
                    let t0 = std::time::Instant::now();
                    let y = staged.spmv(&runner, &x)?;
                    let dt = t0.elapsed().as_secs_f64();
                    let want = csr.spmv(&x);
                    let ok = y
                        .iter()
                        .zip(&want)
                        .all(|(a, b)| (a - b).abs() <= 1e-3 * b.abs().max(1.0));
                    sparsep::ensure!(ok, "XLA path mismatch");
                    println!(
                        "{}: artifact {} (platform {}), pad {:.1}x, {:.3} ms, {:.3} GFLOP/s, verified OK",
                        suite[0].0,
                        staged.artifact,
                        runner.platform(),
                        staged.pad_ratio,
                        dt * 1e3,
                        2.0 * mf.nnz() as f64 / dt / 1e9
                    );
                }
            }
        }
    }

    // -- 5. headline comparison (Fig. 16 / Table 3) ---------------------
    figures::e9_cpu_gpu_pim(figures::Scale(if full { 1.0 } else { 0.25 }));

    println!(
        "\nDONE: {} kernel runs verified exactly, wall time {:.1}s",
        verified,
        t_start.elapsed().as_secs_f64()
    );
    Ok(())
}
