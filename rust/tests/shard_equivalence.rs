//! Differential harness for the sharded serving tier.
//!
//! The multi-rank literature's validation rule: multi-rank behavior is
//! checked against a single-rank oracle. Two oracles lock
//! `ShardedService` down:
//!
//! 1. **Single-service oracle** — the whole matrix served by one
//!    unsharded `SpmvService` with the same per-rank system. The
//!    gathered output vectors must be **bit-identical** for every shard
//!    count S ∈ {1, 2, 3, 5}, all 25 kernel specs, both engines, and
//!    every request kind (spmv, ragged batch, iterate), with >= 4
//!    concurrent tickets waited out of submission order. (The suite's
//!    generator values are integer-exact, so even the element-granular
//!    and 2D kernels' partial-sum regroupings cannot round.) For
//!    **S = 1** the *entire* response — breakdown, stats, energy — must
//!    degenerate bit-exactly to the plain service's.
//! 2. **Per-shard synchronous reference** — each shard slice planned
//!    and executed independently on a plain `SpmvExecutor`, merged by a
//!    test-local reimplementation of the documented aggregation
//!    (concatenate outputs; max the per-phase times, placement and
//!    imbalance; sum bytes, DPUs, nnz, energy). The facade's full
//!    `Response` must be bit-identical — this pins the scatter/gather
//!    and scheduler plumbing to the simple sequential semantics.

use sparsep::coordinator::{
    BatchResult, Breakdown, Engine, IterationsResult, KernelSpec, Request, Response, RunResult,
    ServiceBuilder, ShardedService, ShardedServiceBuilder, ShardedTicket, SpmvExecutor,
    SpmvService, VECTOR_BLOCK,
};
use sparsep::matrix::{generate, CooMatrix};
use sparsep::pim::{Energy, PimSystem};
use std::ops::Range;

const N: usize = 120;
const BATCH: usize = VECTOR_BLOCK + 3; // one full block + a ragged tail
const ITERS: usize = 4;
const DPUS_PER_SHARD: usize = 8;
const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 5];

fn matrix() -> CooMatrix<f64> {
    generate::scale_free::<f64>(N, N, 6, 0.7, 29)
}

fn x1() -> Vec<f64> {
    (0..N).map(|i| ((i % 13) as f64) - 6.0).collect()
}

fn x2() -> Vec<f64> {
    (0..N).map(|i| ((i % 7) as f64) - 3.0).collect()
}

fn batch_xs() -> Vec<Vec<f64>> {
    (0..BATCH)
        .map(|b| (0..N).map(|i| ((i + 5 * b) % 11) as f64 - 5.0).collect())
        .collect()
}

fn assert_runs_identical(a: &RunResult<f64>, b: &RunResult<f64>, tag: &str) {
    assert_eq!(a.y, b.y, "{tag}: output vector differs");
    assert_eq!(a.breakdown, b.breakdown, "{tag}: breakdown differs");
    assert_eq!(a.stats, b.stats, "{tag}: stats differ");
    assert_eq!(a.energy, b.energy, "{tag}: energy differs");
}

fn assert_batches_identical(a: &BatchResult<f64>, b: &BatchResult<f64>, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: batch size differs");
    for (i, (ra, rb)) in a.runs.iter().zip(&b.runs).enumerate() {
        assert_runs_identical(ra, rb, &format!("{tag} vec={i}"));
    }
}

fn assert_iters_identical(a: &IterationsResult<f64>, b: &IterationsResult<f64>, tag: &str) {
    assert_runs_identical(&a.last, &b.last, &format!("{tag} last"));
    assert_eq!(a.total, b.total, "{tag}: iteration totals differ");
    assert_eq!(a.energy, b.energy, "{tag}: iteration energy differs");
    assert_eq!(a.iters, b.iters, "{tag}: iteration count differs");
}

/// What the single unsharded service answers for the request mix.
struct Oracle {
    spmv1: RunResult<f64>,
    spmv2: RunResult<f64>,
    batch: BatchResult<f64>,
    iter: IterationsResult<f64>,
}

fn single_service_oracle(engine: Engine, spec: &KernelSpec, m: &CooMatrix<f64>) -> Oracle {
    let svc: SpmvService<f64> = ServiceBuilder::new()
        .engine(engine)
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap();
    let h = svc.load(m, spec).unwrap();
    let t1 = svc.submit(h, Request::spmv(x1())).unwrap();
    let tb = svc.submit(h, Request::batch(batch_xs())).unwrap();
    let ti = svc.submit(h, Request::iterate(x1(), ITERS)).unwrap();
    let t2 = svc.submit(h, Request::spmv(x2())).unwrap();
    Oracle {
        iter: svc.wait(ti).unwrap().into_iterations().unwrap(),
        spmv2: svc.wait(t2).unwrap().into_spmv().unwrap(),
        batch: svc.wait(tb).unwrap().into_batch().unwrap(),
        spmv1: svc.wait(t1).unwrap().into_spmv().unwrap(),
    }
}

/// Test-local reimplementation of the documented shard-merge semantics
/// (deliberately independent of `coordinator::shard`'s code).
fn merge_expected(parts: Vec<RunResult<f64>>) -> RunResult<f64> {
    let mut y = Vec::new();
    let mut breakdown = Breakdown::default();
    let mut energy = Energy::default();
    let mut stats = parts[0].stats;
    stats.bus_bytes_moved = 0;
    stats.bus_bytes_payload = 0;
    stats.n_dpus = 0;
    stats.nnz = 0;
    stats.kernel_cycles = 0;
    stats.dpu_imbalance = f64::MIN;
    stats.matrix_load_s = f64::MIN;
    for (i, p) in parts.iter().enumerate() {
        y.extend_from_slice(&p.y);
        breakdown.load_s = breakdown.load_s.max(p.breakdown.load_s);
        breakdown.kernel_s = breakdown.kernel_s.max(p.breakdown.kernel_s);
        breakdown.retrieve_s = breakdown.retrieve_s.max(p.breakdown.retrieve_s);
        breakdown.merge_s = breakdown.merge_s.max(p.breakdown.merge_s);
        stats.dpu_imbalance = stats.dpu_imbalance.max(p.stats.dpu_imbalance);
        stats.kernel_cycles = stats.kernel_cycles.max(p.stats.kernel_cycles);
        stats.bus_bytes_moved += p.stats.bus_bytes_moved;
        stats.bus_bytes_payload += p.stats.bus_bytes_payload;
        stats.matrix_load_s = stats.matrix_load_s.max(p.stats.matrix_load_s);
        stats.n_dpus += p.stats.n_dpus;
        stats.nnz += p.stats.nnz;
        energy = if i == 0 { p.energy } else { energy.add(p.energy) };
    }
    RunResult { y, breakdown, stats, energy }
}

/// Per-shard synchronous reference: plan every slice on a plain
/// executor and execute the request mix shard by shard, merging with
/// [`merge_expected`].
struct Reference {
    exec: SpmvExecutor,
    plans: Vec<sparsep::coordinator::ExecutionPlan<f64>>,
}

impl Reference {
    fn new(
        engine: Engine,
        spec: &KernelSpec,
        m: &CooMatrix<f64>,
        ranges: &[Range<usize>],
    ) -> Reference {
        let exec = SpmvExecutor::with_engine(PimSystem::with_dpus(DPUS_PER_SHARD), engine);
        let plans = ranges
            .iter()
            .map(|r| exec.plan(spec, &m.row_range_slice(r.start, r.end)).unwrap())
            .collect();
        Reference { exec, plans }
    }

    fn spmv(&self, x: &[f64]) -> RunResult<f64> {
        merge_expected(self.plans.iter().map(|p| p.execute(&self.exec, x).unwrap()).collect())
    }

    fn batch(&self, xs: &[Vec<f64>]) -> BatchResult<f64> {
        let per_shard: Vec<BatchResult<f64>> =
            self.plans.iter().map(|p| p.execute_batch_runs(&self.exec, xs).unwrap()).collect();
        let runs = (0..xs.len())
            .map(|v| merge_expected(per_shard.iter().map(|b| b.runs[v].clone()).collect()))
            .collect();
        BatchResult { runs }
    }

    fn iterate(&self, x: &[f64], iters: usize) -> IterationsResult<f64> {
        let mut total = Breakdown::default();
        let mut energy = Energy::default();
        let mut cur = x.to_vec();
        let mut last = None;
        for _ in 0..iters {
            let merged = self.spmv(&cur);
            total.accumulate(&merged.breakdown);
            energy = energy.add(merged.energy);
            cur.clone_from(&merged.y);
            last = Some(merged);
        }
        IterationsResult { last: last.unwrap(), total, energy, iters }
    }
}

/// Serve the full request mix through a sharded facade (>= 4 tickets in
/// flight, waited out of submission order) and check both oracles.
fn check_sharded(
    engine: Engine,
    spec: &KernelSpec,
    m: &CooMatrix<f64>,
    shards: usize,
    oracle: &Oracle,
    tag: &str,
) {
    let svc: ShardedService<f64> = ShardedServiceBuilder::new()
        .shards(shards)
        .engine(engine)
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap();
    let h = svc.load(m, spec).unwrap();
    let ranges = svc.shard_ranges(&h).unwrap();
    assert_eq!(ranges.len(), shards.min(N), "{tag}: shard count");
    let reference = Reference::new(engine, spec, m, &ranges);

    // Four tickets in flight at once...
    let t1 = svc.submit(h, Request::spmv(x1())).unwrap();
    let tb = svc.submit(h, Request::batch(batch_xs())).unwrap();
    let ti = svc.submit(h, Request::iterate(x1(), ITERS)).unwrap();
    let t2 = svc.submit(h, Request::spmv(x2())).unwrap();

    // ...claimed out of submission order.
    let iter_resp = match svc.wait(ti).unwrap() {
        Response::Iterate(it) => it,
        other => panic!("{tag}: expected iterate, got {}", other.kind()),
    };
    let spmv2 = match svc.wait(t2).unwrap() {
        Response::Spmv(r) => r,
        other => panic!("{tag}: expected spmv, got {}", other.kind()),
    };
    let batch = match svc.wait(tb).unwrap() {
        Response::Batch(b) => b,
        other => panic!("{tag}: expected batch, got {}", other.kind()),
    };
    let spmv1 = match svc.wait(t1).unwrap() {
        Response::Spmv(r) => r,
        other => panic!("{tag}: expected spmv, got {}", other.kind()),
    };
    // A second wait on a claimed ticket errors instead of hanging.
    assert!(svc.wait(t1).is_err(), "{tag}: double wait must error");

    // Oracle 1: outputs bit-identical to the unsharded single service.
    assert_eq!(spmv1.y, oracle.spmv1.y, "{tag}: spmv1 output != single-service oracle");
    assert_eq!(spmv2.y, oracle.spmv2.y, "{tag}: spmv2 output != single-service oracle");
    assert_eq!(batch.len(), oracle.batch.len(), "{tag}: batch size");
    for (v, (a, b)) in batch.runs.iter().zip(&oracle.batch.runs).enumerate() {
        assert_eq!(a.y, b.y, "{tag}: batch vec {v} output != single-service oracle");
    }
    assert_eq!(iter_resp.last.y, oracle.iter.last.y, "{tag}: iterate output != oracle");
    assert_eq!(iter_resp.iters, oracle.iter.iters, "{tag}: iterate count");

    // S = 1 degenerates to the plain service, metrics and all.
    if shards == 1 {
        assert_runs_identical(&spmv1, &oracle.spmv1, &format!("{tag} S=1 spmv1"));
        assert_runs_identical(&spmv2, &oracle.spmv2, &format!("{tag} S=1 spmv2"));
        assert_batches_identical(&batch, &oracle.batch, &format!("{tag} S=1 batch"));
        assert_iters_identical(&iter_resp, &oracle.iter, &format!("{tag} S=1 iterate"));
    }

    // Oracle 2: the full responses (metrics included) are bit-identical
    // to the per-shard synchronous reference.
    assert_runs_identical(&spmv1, &reference.spmv(&x1()), &format!("{tag} ref spmv1"));
    assert_runs_identical(&spmv2, &reference.spmv(&x2()), &format!("{tag} ref spmv2"));
    assert_batches_identical(&batch, &reference.batch(&batch_xs()), &format!("{tag} ref batch"));
    assert_iters_identical(
        &iter_resp,
        &reference.iterate(&x1(), ITERS),
        &format!("{tag} ref iterate"),
    );
}

/// PROPERTY: all 25 kernels x {serial, threaded} x S in {1,2,3,5} serve
/// the full request mix with outputs bit-identical to the unsharded
/// single-service oracle, and full responses bit-identical to the
/// per-shard synchronous reference, with out-of-order waits.
#[test]
fn prop_all25_sharded_identical_to_single_service_oracle() {
    let m = matrix();
    for spec in KernelSpec::all25(4) {
        for (engine, ename) in [(Engine::Serial, "serial"), (Engine::threaded(2), "threaded")] {
            let oracle = single_service_oracle(engine, &spec, &m);
            for shards in SHARD_COUNTS {
                let tag = format!("{} {} S={}", spec.name, ename, shards);
                check_sharded(engine, &spec, &m, shards, &oracle, &tag);
            }
        }
    }
}

/// Deterministic end-to-end fairness: two tenants at weight 1:3
/// submitting identical request streams complete in exactly the
/// weighted-round-robin interleaving, and their answers are oracle-
/// exact. (Everything is enqueued while the scheduler is paused, so the
/// schedule is a pure function of the weights.)
#[test]
fn fairness_weighted_round_robin_completion_order() {
    use sparsep::coordinator::{TenantId, TenantSpec};
    let m = matrix();
    let spec = KernelSpec::csr_nnz();
    let svc: ShardedService<f64> = ShardedServiceBuilder::new()
        .shards(2)
        // Unlimited quotas: the dispatch order must be a pure function
        // of the weights (quota blocking is deterministically covered by
        // the scheduler's unit tests).
        .tenants(vec![TenantSpec::new("a", 1), TenantSpec::new("b", 3)])
        .start_paused(true)
        .record_schedule(true)
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap();
    let (ta, tb) = (svc.tenant("a").unwrap(), svc.tenant("b").unwrap());
    let ha = svc.load_for(ta, &m, &spec).unwrap();
    let hb = svc.load_for(tb, &m, &spec).unwrap();
    let want_y = m.spmv(&x1());
    let mut tickets: Vec<ShardedTicket> = Vec::new();
    for _ in 0..4 {
        tickets.push(svc.submit_for(ta, ha, Request::spmv(x1())).unwrap());
    }
    for _ in 0..12 {
        tickets.push(svc.submit_for(tb, hb, Request::spmv(x1())).unwrap());
    }
    svc.resume();
    for t in &tickets {
        let r = svc.wait(*t).unwrap().into_spmv().unwrap();
        assert_eq!(r.y, want_y);
    }
    let log = svc.schedule_log().unwrap();
    let want: Vec<TenantId> = (0..4).flat_map(|_| [ta, tb, tb, tb]).collect();
    assert_eq!(log.dispatched, want, "dispatch order != weighted round-robin schedule");
    assert_eq!(log.completed, want, "completion order != weighted round-robin schedule");
}

/// A flooding tenant cannot starve the other: with equal weights, the
/// victim's i-th completion happens by global position 2i + 1 no matter
/// how deep the flooder's backlog is (bounded wait).
#[test]
fn fairness_flooding_tenant_cannot_starve() {
    use sparsep::coordinator::TenantSpec;
    let m = matrix();
    let spec = KernelSpec::coo_row();
    let svc: ShardedService<f64> = ShardedServiceBuilder::new()
        .shards(2)
        .tenants(vec![TenantSpec::new("flooder", 1), TenantSpec::new("victim", 1)])
        .start_paused(true)
        .record_schedule(true)
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap();
    let (tf, tv) = (svc.tenant("flooder").unwrap(), svc.tenant("victim").unwrap());
    let hf = svc.load_for(tf, &m, &spec).unwrap();
    let hv = svc.load_for(tv, &m, &spec).unwrap();
    let mut tickets = Vec::new();
    for _ in 0..24 {
        tickets.push(svc.submit_for(tf, hf, Request::spmv(x2())).unwrap());
    }
    for _ in 0..6 {
        tickets.push(svc.submit_for(tv, hv, Request::spmv(x2())).unwrap());
    }
    svc.resume();
    for t in &tickets {
        svc.wait(*t).unwrap();
    }
    let log = svc.schedule_log().unwrap();
    assert_eq!(log.completed.len(), 30);
    let victim_positions: Vec<usize> = log
        .completed
        .iter()
        .enumerate()
        .filter_map(|(i, &t)| (t == tv).then_some(i))
        .collect();
    assert_eq!(victim_positions.len(), 6);
    for (i, &pos) in victim_positions.iter().enumerate() {
        assert!(
            pos <= 2 * i + 1,
            "victim completion {i} at position {pos} exceeds the bounded-wait bound {}",
            2 * i + 1
        );
    }
    let st = svc.stats();
    assert_eq!(st.tenants[tf.index()].completed, 24);
    assert_eq!(st.tenants[tv.index()].completed, 6);
}

/// Sharded tickets poll through `try_wait` to the same response `wait`
/// would have claimed, and a claimed ticket stays claimed.
#[test]
fn sharded_try_wait_polls_to_the_wait_response() {
    let m = matrix();
    let svc: ShardedService<f64> = ShardedServiceBuilder::new()
        .shards(3)
        .build(PimSystem::with_dpus(DPUS_PER_SHARD))
        .unwrap();
    let h = svc.load(&m, &KernelSpec::coo_nnz()).unwrap();
    let t_wait = svc.submit(h, Request::spmv(x1())).unwrap();
    let t_poll = svc.submit(h, Request::spmv(x1())).unwrap();
    let gold = svc.wait(t_wait).unwrap().into_spmv().unwrap();
    let polled = loop {
        match svc.try_wait(t_poll).unwrap() {
            Some(resp) => break resp.into_spmv().unwrap(),
            None => std::thread::yield_now(),
        }
    };
    assert_runs_identical(&polled, &gold, "sharded try_wait");
    assert!(svc.try_wait(t_poll).is_err(), "claimed ticket must not be claimable again");
    assert!(svc.wait(t_poll).is_err());
}

/// Concurrent submitters from many host threads share one facade: every
/// answer stays oracle-exact and the counters add up.
#[test]
fn concurrent_submitters_share_one_facade() {
    let m = matrix();
    let svc = std::sync::Arc::new(
        ShardedServiceBuilder::new()
            .shards(3)
            .build::<f64>(PimSystem::with_dpus(DPUS_PER_SHARD))
            .unwrap(),
    );
    let h = svc.load(&m, &KernelSpec::csr_nnz()).unwrap();
    std::thread::scope(|s| {
        for tid in 0..4usize {
            let svc = std::sync::Arc::clone(&svc);
            let m = &m;
            s.spawn(move || {
                for k in 0..3usize {
                    let x: Vec<f64> =
                        (0..N).map(|i| ((i + 7 * tid + k) % 5) as f64 - 2.0).collect();
                    let t = svc.submit(h, Request::spmv(x.clone())).unwrap();
                    let r = svc.wait(t).unwrap().into_spmv().unwrap();
                    assert_eq!(r.y, m.spmv(&x));
                }
            });
        }
    });
    let st = svc.stats();
    assert_eq!(st.submitted, 12);
    assert_eq!(st.completed, 12);
    assert_eq!(st.in_flight(), 0);
}
