//! Load-balanced range splitting — the primitive under both the
//! across-DPU partitioners and the across-tasklet work division.
//!
//! SparseP's central software lesson (recommendation #1) is that the
//! *unit of balance* matters: splitting rows evenly balances loop
//! iterations, splitting by non-zeros balances multiply-accumulates, and
//! for blocked formats splitting by blocks balances index overhead. All
//! three reduce to: split a weighted sequence into `k` contiguous chunks
//! minimizing the maximum chunk weight.

use std::ops::Range;

/// Split `n` items into `k` contiguous chunks of (nearly) equal count.
/// Chunks may be empty when `k > n`.
pub fn split_even(n: usize, k: usize) -> Vec<Range<usize>> {
    assert!(k > 0);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Split items with the given non-negative `weights` into `k` contiguous
/// chunks such that chunk weights are as even as a greedy prefix scan can
/// make them (each chunk closes once it reaches the ideal share). This is
/// the paper's "balance nnz across DPUs/tasklets at row granularity"
/// scheme: a single heavy item can still dominate a chunk, which is
/// exactly the imbalance pathology the paper measures on scale-free
/// matrices.
pub fn split_weighted(weights: &[usize], k: usize) -> Vec<Range<usize>> {
    assert!(k > 0);
    let n = weights.len();
    let total: usize = weights.iter().sum();
    if total == 0 {
        return split_even(n, k);
    }
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    let mut consumed = 0usize;
    for chunk in 0..k {
        let remaining_chunks = k - chunk;
        let target = (total - consumed).div_ceil(remaining_chunks);
        let mut end = start;
        let mut w = 0usize;
        while end < n && (w == 0 || w + weights[end] <= target || remaining_chunks == 1) {
            // Last chunk takes everything left; otherwise stop before
            // overshooting the per-chunk target (but always take >= 1).
            w += weights[end];
            end += 1;
            if remaining_chunks == 1 {
                continue;
            }
            if w >= target {
                break;
            }
        }
        // Make sure the tail can still be covered: leave at least one
        // item per remaining chunk only if items remain.
        out.push(start..end);
        consumed += w;
        start = end;
    }
    // Any leftovers (possible only from rounding) go to the last chunk.
    if start < n {
        let last = out.last_mut().unwrap();
        *last = last.start..n;
    }
    debug_assert_eq!(out.len(), k);
    debug_assert_eq!(out.last().unwrap().end, n);
    out
}

/// Like [`split_weighted`], but no chunk is ever empty. Requires
/// `1 <= k <= weights.len()`. The greedy prefix scan can exhaust the
/// items before the last chunks open (one mega-weight item swallows the
/// whole target); this re-derives the boundaries with a forward clamp
/// that leaves every later chunk at least one item. This is the
/// never-empty fixup the sharded facade's row planner has always
/// applied, extracted so the 2D grid planner can reuse it for the
/// per-band column splits.
pub fn split_weighted_nonempty(weights: &[usize], k: usize) -> Vec<Range<usize>> {
    let n = weights.len();
    assert!(k >= 1, "split_weighted_nonempty needs k >= 1");
    assert!(k <= n, "split_weighted_nonempty needs k <= len ({k} > {n})");
    if k == 1 {
        return vec![0..n];
    }
    let raw = split_weighted(weights, k);
    let mut b: Vec<usize> = Vec::with_capacity(k + 1);
    b.push(0);
    for r in &raw {
        b.push(r.end);
    }
    for i in 1..=k {
        let lo = b[i - 1] + 1; // at least one item in chunk i-1
        let hi = n - (k - i); // leave one item per later chunk
        b[i] = b[i].clamp(lo, hi);
    }
    (0..k).map(|i| b[i]..b[i + 1]).collect()
}

/// Split a total element count into `k` contiguous element ranges of
/// (nearly) equal size — the element-granularity split used by `COO.nnz`,
/// which may cut *inside* a row (requiring synchronization on the shared
/// boundary rows).
pub fn split_elements(nnz: usize, k: usize) -> Vec<Range<usize>> {
    split_even(nnz, k)
}

/// Maximum chunk weight / ideal chunk weight: 1.0 = perfect balance.
pub fn imbalance(weights: &[usize], chunks: &[Range<usize>]) -> f64 {
    let total: usize = weights.iter().sum();
    if total == 0 {
        return 1.0;
    }
    let ideal = total as f64 / chunks.len() as f64;
    let max = chunks
        .iter()
        .map(|r| weights[r.clone()].iter().sum::<usize>())
        .max()
        .unwrap_or(0);
    max as f64 / ideal
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_even_covers_all() {
        for (n, k) in [(10, 3), (3, 10), (0, 4), (100, 7)] {
            let chunks = split_even(n, k);
            assert_eq!(chunks.len(), k);
            assert_eq!(chunks[0].start, 0);
            assert_eq!(chunks.last().unwrap().end, n);
            for w in chunks.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            // Sizes differ by at most 1.
            let sizes: Vec<usize> = chunks.iter().map(|r| r.len()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1);
        }
    }

    #[test]
    fn split_weighted_balances_skewed_input() {
        // One heavy row among light ones.
        let mut w = vec![1usize; 100];
        w[0] = 50;
        let chunks = split_weighted(&w, 4);
        assert_eq!(chunks.len(), 4);
        assert_eq!(chunks.last().unwrap().end, 100);
        let imb = imbalance(&w, &chunks);
        // Greedy split should get within 40% of ideal here.
        assert!(imb < 1.4, "imbalance {imb}");
    }

    #[test]
    fn split_weighted_handles_uniform() {
        let w = vec![3usize; 64];
        let chunks = split_weighted(&w, 8);
        for c in &chunks {
            assert_eq!(c.len(), 8);
        }
        assert!((imbalance(&w, &chunks) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn split_weighted_zero_weights() {
        let w = vec![0usize; 10];
        let chunks = split_weighted(&w, 3);
        assert_eq!(chunks.last().unwrap().end, 10);
    }

    #[test]
    fn split_weighted_more_chunks_than_items() {
        let w = vec![5usize, 7];
        let chunks = split_weighted(&w, 5);
        assert_eq!(chunks.len(), 5);
        assert_eq!(chunks.last().unwrap().end, 2);
        // All items covered exactly once.
        let covered: usize = chunks.iter().map(|r| r.len()).sum();
        assert_eq!(covered, 2);
    }

    #[test]
    fn heavy_single_item_dominates() {
        // The pathology the paper observes: one mega-row cannot be split
        // at row granularity.
        let mut w = vec![1usize; 10];
        w[5] = 1000;
        let chunks = split_weighted(&w, 4);
        let imb = imbalance(&w, &chunks);
        assert!(imb > 3.0, "row-granularity split cannot fix this: {imb}");
    }

    #[test]
    fn split_weighted_nonempty_tiles_without_empties() {
        let cases: &[(Vec<usize>, usize)] = &[
            (vec![1; 100], 4),
            ({
                let mut w = vec![1usize; 10];
                w[5] = 1000; // mega-item swallows the greedy targets
                w
            }, 4),
            ({
                let mut w = vec![0usize; 12];
                w[11] = 7; // all weight on the last item
                w
            }, 5),
            (vec![0usize; 10], 3),
            (vec![2usize, 3, 4], 3), // k == len: singletons
        ];
        for (w, k) in cases {
            let chunks = split_weighted_nonempty(w, *k);
            assert_eq!(chunks.len(), *k);
            assert_eq!(chunks[0].start, 0);
            assert_eq!(chunks.last().unwrap().end, w.len());
            for pair in chunks.windows(2) {
                assert_eq!(pair[0].end, pair[1].start);
            }
            assert!(chunks.iter().all(|r| !r.is_empty()), "empty chunk in {chunks:?}");
        }
    }

    #[test]
    fn split_weighted_nonempty_matches_weighted_when_no_fixup_needed() {
        let w = vec![3usize; 64];
        assert_eq!(split_weighted_nonempty(&w, 8), split_weighted(&w, 8));
    }

    #[test]
    fn split_elements_is_even() {
        let chunks = split_elements(1000, 16);
        assert!(chunks.iter().all(|r| r.len() == 62 || r.len() == 63));
    }
}
