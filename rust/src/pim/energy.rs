//! Energy model.
//!
//! The paper's CPU/GPU comparison (Table 3) reports performance *and*
//! energy; PIM wins energy mostly because SpMV's bytes never cross a
//! power-hungry off-chip link during the kernel. We model energy as
//! component power x modeled component time plus per-byte bus energy —
//! the same first-order structure the UPMEM SDK's energy counters expose.

use super::calib;

/// Energy breakdown of one SpMV execution, joules.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Energy {
    /// DPU cores busy during the kernel.
    pub dpu_j: f64,
    /// Idle DPUs (allocated but waiting) during the kernel.
    pub dpu_idle_j: f64,
    /// Bus energy for host<->PIM transfers.
    pub bus_j: f64,
    /// Host CPU while orchestrating transfers + merging.
    pub host_j: f64,
}

impl Energy {
    pub fn total_j(&self) -> f64 {
        self.dpu_j + self.dpu_idle_j + self.bus_j + self.host_j
    }

    /// Energy of a PIM kernel phase: `n_busy` DPUs run for their own
    /// time; the rest of the allocation idles until the slowest finishes.
    pub fn pim_kernel(n_dpus: usize, dpu_busy_s: &[f64]) -> Energy {
        let max_s = dpu_busy_s.iter().copied().fold(0.0, f64::max);
        let busy: f64 = dpu_busy_s.iter().sum();
        let idle = (n_dpus as f64) * max_s - busy;
        Energy {
            dpu_j: busy * calib::DPU_ACTIVE_WATTS,
            dpu_idle_j: idle.max(0.0) * calib::DPU_IDLE_WATTS,
            ..Default::default()
        }
    }

    /// Energy of a transfer phase moving `bytes` over `seconds`.
    pub fn transfer(bytes: u64, seconds: f64) -> Energy {
        Energy {
            bus_j: bytes as f64 * calib::BUS_ENERGY_J_PER_BYTE,
            host_j: seconds * calib::HOST_ACTIVE_WATTS,
            ..Default::default()
        }
    }

    /// Energy of host-side merge work.
    pub fn host(seconds: f64) -> Energy {
        Energy { host_j: seconds * calib::HOST_ACTIVE_WATTS, ..Default::default() }
    }

    pub fn add(self, other: Energy) -> Energy {
        Energy {
            dpu_j: self.dpu_j + other.dpu_j,
            dpu_idle_j: self.dpu_idle_j + other.dpu_idle_j,
            bus_j: self.bus_j + other.bus_j,
            host_j: self.host_j + other.host_j,
        }
    }
}

/// TDP-based energy estimate for the processor-centric baselines
/// (paper's Table 3 methodology: package power x runtime).
pub fn baseline_energy_j(platform_watts: f64, seconds: f64) -> f64 {
    platform_watts * seconds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_energy_counts_idle() {
        // 4 DPUs allocated, skewed times: the laggard keeps 3 idle.
        let e = Energy::pim_kernel(4, &[1.0, 0.1, 0.1, 0.1]);
        assert!(e.dpu_j > 0.0);
        assert!(e.dpu_idle_j > 0.0);
        let balanced = Energy::pim_kernel(4, &[0.325; 4]);
        assert!(balanced.dpu_idle_j < 1e-12);
        // Same busy-seconds total => same active energy.
        assert!((balanced.dpu_j - e.dpu_j).abs() < 1e-12);
    }

    #[test]
    fn transfer_energy_scales_with_bytes() {
        let a = Energy::transfer(1 << 20, 0.001);
        let b = Energy::transfer(1 << 21, 0.001);
        assert!((b.bus_j / a.bus_j - 2.0).abs() < 1e-9);
        assert_eq!(a.host_j, b.host_j);
    }

    #[test]
    fn totals_add_up() {
        let e = Energy::pim_kernel(2, &[0.5, 0.5])
            .add(Energy::transfer(1024, 0.01))
            .add(Energy::host(0.002));
        let total = e.total_j();
        assert!((total - (e.dpu_j + e.dpu_idle_j + e.bus_j + e.host_j)).abs() < 1e-12);
        assert!(total > 0.0);
    }

    #[test]
    fn baseline_is_tdp_times_time() {
        assert_eq!(baseline_energy_j(300.0, 2.0), 600.0);
    }
}
