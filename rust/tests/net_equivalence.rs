//! Differential suite for the TCP serving front end: everything a
//! client receives over a real socket must be **bit-identical** to
//! what the in-process sharded facade answers — values, breakdowns,
//! stats, energy — and every failure must keep its type across the
//! wire.
//!
//! Method: build two identically-configured [`ShardedService`]s, put
//! one behind [`sparsep::net::Server`] and keep the other as the
//! in-process oracle, then drive both with the same request sequence
//! (same submission order, so the deterministic ticket ids line up and
//! seeded fault plans replay identically on both sides). Swept across
//! all three request shapes, both engines, shard counts {1, 2, 4} and
//! two tenants; chaos, admission shedding (typed `Overloaded`) and
//! stalled-shard timeouts (typed `ShardTimeout` naming the shard) get
//! their own scenarios.

use sparsep::coordinator::{
    Engine, Fault, FaultPlan, KernelSpec, Request, Response, RunResult, ShardedService,
    ShardedServiceBuilder, TenantSpec,
};
use sparsep::matrix::{generate, CooMatrix};
use sparsep::net::{Client, Server, ServerOpts};
use sparsep::pim::PimSystem;
use std::sync::Arc;
use std::time::Duration;

const N: usize = 64;
const ITERS: usize = 3;
const DPUS_PER_SHARD: usize = 4;
const SHARD_COUNTS: [usize; 3] = [1, 2, 4];
const KERNEL: &str = "COO.nnz";
const STRIPES: usize = 8;

fn matrix() -> CooMatrix<f64> {
    generate::scale_free::<f64>(N, N, 4, 0.7, 31)
}

fn x1() -> Vec<f64> {
    (0..N).map(|i| ((i % 9) as f64) - 4.0).collect()
}

fn batch_xs() -> Vec<Vec<f64>> {
    (0..3)
        .map(|b| (0..N).map(|i| ((i + 5 * b) % 11) as f64 - 5.0).collect())
        .collect()
}

fn engines() -> Vec<Engine> {
    vec![Engine::Serial, Engine::threaded(2)]
}

fn builder(shards: usize, engine: Engine) -> ShardedServiceBuilder {
    ShardedServiceBuilder::new()
        .shards(shards)
        .engine(engine)
        .tenants(vec![TenantSpec::new("alice", 2), TenantSpec::new("bob", 1)])
}

fn build(b: ShardedServiceBuilder) -> ShardedService<f64> {
    b.build(PimSystem::with_dpus(DPUS_PER_SHARD)).expect("sharded service builds")
}

fn assert_runs_identical(a: &RunResult<f64>, b: &RunResult<f64>, tag: &str) {
    assert_eq!(a.y, b.y, "{tag}: output vector differs");
    assert_eq!(a.breakdown, b.breakdown, "{tag}: breakdown differs");
    assert_eq!(a.stats, b.stats, "{tag}: stats differ");
    assert_eq!(a.energy, b.energy, "{tag}: energy differs");
}

/// Full structural equality of two responses, field by field — the
/// wire carries raw IEEE-754 bits, so nothing may drift.
fn assert_responses_identical(served: &Response<f64>, oracle: &Response<f64>, tag: &str) {
    match (served, oracle) {
        (Response::Spmv(a), Response::Spmv(b)) => assert_runs_identical(a, b, tag),
        (Response::Batch(a), Response::Batch(b)) => {
            assert_eq!(a.len(), b.len(), "{tag}: batch size differs");
            for (i, (ra, rb)) in a.runs.iter().zip(&b.runs).enumerate() {
                assert_runs_identical(ra, rb, &format!("{tag} vec={i}"));
            }
        }
        (Response::Iterate(a), Response::Iterate(b)) => {
            assert_runs_identical(&a.last, &b.last, &format!("{tag} last"));
            assert_eq!(a.total, b.total, "{tag}: iterate totals differ");
            assert_eq!(a.energy, b.energy, "{tag}: iterate energy differs");
            assert_eq!(a.iters, b.iters, "{tag}: iterate count differs");
        }
        (Response::Overloaded, Response::Overloaded) => {}
        _ => panic!(
            "{tag}: response kinds differ (served {:?}, oracle {:?})",
            served.kind(),
            oracle.kind()
        ),
    }
}

/// The canonical mix: all three request shapes from each of the two
/// tenants (6 tickets), one with an explicit deadline, submitted in
/// the same order on the served and in-process sides, waited out of
/// submission order. Returns (served, oracle) response pairs.
fn drive_mix(
    srv: &Server,
    oracle: &ShardedService<f64>,
    m: &CooMatrix<f64>,
) -> Vec<(Response<f64>, Response<f64>)> {
    let spec = KernelSpec::by_name(KERNEL, STRIPES).expect("test kernel exists");
    let deadline = Duration::from_millis(60_000);

    let mut cl = Client::connect(srv.local_addr()).expect("client connects");
    let wh_alice = cl.load("alice", m, KERNEL, STRIPES as u32).expect("wire load alice");
    let wh_bob = cl.load("bob", m, KERNEL, STRIPES as u32).expect("wire load bob");

    let oa = oracle.tenant("alice").expect("oracle tenant alice");
    let ob = oracle.tenant("bob").expect("oracle tenant bob");
    let oh_alice = oracle.load_for(oa, m, &spec).expect("oracle load alice");
    let oh_bob = oracle.load_for(ob, m, &spec).expect("oracle load bob");

    // Identical submission order on both sides: deterministic ticket
    // ids line up 1:1, which is what lets seeded fault plans replay.
    let wire = [
        cl.submit_spmv("alice", wh_alice, x1(), None).expect("wire submit 1"),
        cl.submit_batch("alice", wh_alice, batch_xs(), None).expect("wire submit 2"),
        cl.submit_iterate("alice", wh_alice, x1(), ITERS, None).expect("wire submit 3"),
        cl.submit_spmv("bob", wh_bob, x1(), Some(deadline)).expect("wire submit 4"),
        cl.submit_batch("bob", wh_bob, batch_xs(), None).expect("wire submit 5"),
        cl.submit_iterate("bob", wh_bob, x1(), ITERS, None).expect("wire submit 6"),
    ];
    let inproc = [
        oracle.submit_for(oa, oh_alice, Request::spmv(x1())).expect("oracle submit 1"),
        oracle.submit_for(oa, oh_alice, Request::batch(batch_xs())).expect("oracle submit 2"),
        oracle.submit_for(oa, oh_alice, Request::iterate(x1(), ITERS)).expect("oracle submit 3"),
        oracle
            .submit_with_deadline(ob, oh_bob, Request::spmv(x1()), deadline)
            .expect("oracle submit 4"),
        oracle.submit_for(ob, oh_bob, Request::batch(batch_xs())).expect("oracle submit 5"),
        oracle.submit_for(ob, oh_bob, Request::iterate(x1(), ITERS)).expect("oracle submit 6"),
    ];

    // Claim out of submission order so responses park on the client.
    [4usize, 1, 5, 0, 3, 2]
        .iter()
        .map(|&i| {
            let served = cl.wait(wire[i]).expect("served response");
            let oracled = oracle.wait(inproc[i]).expect("oracle response");
            (served, oracled)
        })
        .collect()
}

/// Host-oracle spot check: the served spmv answer is not just
/// self-consistent with the facade, it is the right answer.
fn assert_spmv_correct(pairs: &[(Response<f64>, Response<f64>)], m: &CooMatrix<f64>, tag: &str) {
    let want = m.spmv(&x1());
    for (served, _) in pairs {
        if let Response::Spmv(r) = served {
            assert_eq!(r.y, want, "{tag}: served spmv vs host oracle");
        }
    }
}

#[test]
fn served_responses_are_bit_identical_to_in_process_oracle() {
    let m = matrix();
    for shards in SHARD_COUNTS {
        for engine in engines() {
            let tag = format!("shards={shards} engine={engine:?}");
            let srv = Server::spawn(build(builder(shards, engine)), "127.0.0.1:0", ServerOpts::default())
                .expect("server binds");
            let oracle = build(builder(shards, engine));
            let pairs = drive_mix(&srv, &oracle, &m);
            assert_eq!(pairs.len(), 6, "{tag}: all six tickets answered");
            for (i, (served, oracled)) in pairs.iter().enumerate() {
                assert_responses_identical(served, oracled, &format!("{tag} req={i}"));
            }
            assert_spmv_correct(&pairs, &m, &tag);
        }
    }
}

/// Seeded chaos (kill / dropped completion / delay) replays identically
/// on both sides of the wire: recovery may change *how* the answer is
/// computed, never *what* arrives at the client.
#[test]
fn served_chaos_replay_matches_in_process_oracle() {
    let m = matrix();
    let shards = 2;
    for engine in engines() {
        for seed in [0xD1FF_u64, 0xFEED_u64] {
            let tag = format!("chaos engine={engine:?} seed={seed:#x}");
            // Same seed -> FaultPlan::random rebuilds the identical
            // plan; ticket ids line up because submission order does.
            let srv = Server::spawn(
                build(
                    builder(shards, engine)
                        .fault_injector(Arc::new(FaultPlan::random(seed, 6, shards, 0.4))),
                ),
                "127.0.0.1:0",
                ServerOpts::default(),
            )
            .expect("server binds");
            let oracle = build(
                builder(shards, engine)
                    .fault_injector(Arc::new(FaultPlan::random(seed, 6, shards, 0.4))),
            );
            let pairs = drive_mix(&srv, &oracle, &m);
            for (i, (served, oracled)) in pairs.iter().enumerate() {
                assert_responses_identical(served, oracled, &format!("{tag} req={i}"));
            }
            assert_spmv_correct(&pairs, &m, &tag);
        }
    }
}

/// Admission shedding is typed end to end: with the per-tenant cap at
/// 1 and dispatch paused, the same submissions shed on both sides, the
/// wire carries them as `Overloaded` frames, and the requests that
/// were admitted still answer bit-identically after resume.
#[test]
fn served_overload_shedding_matches_in_process_oracle() {
    let m = matrix();
    let spec = KernelSpec::by_name(KERNEL, STRIPES).expect("test kernel exists");
    let srv = Server::spawn(
        build(builder(2, Engine::Serial).max_queue(1)),
        "127.0.0.1:0",
        ServerOpts::default(),
    )
    .expect("server binds");
    let oracle = build(builder(2, Engine::Serial).max_queue(1));

    let mut cl = Client::connect(srv.local_addr()).expect("client connects");
    let wh = cl.load("alice", &m, KERNEL, STRIPES as u32).expect("wire load");
    let oa = oracle.tenant("alice").expect("oracle tenant");
    let oh = oracle.load_for(oa, &m, &spec).expect("oracle load");

    // Paused dispatch makes the shed pattern purely a function of the
    // submission sequence — identical on both sides by construction.
    srv.service().pause();
    oracle.pause();
    let wire: Vec<u64> = (0..6)
        .map(|i| cl.submit_spmv("alice", wh, x1(), None).unwrap_or_else(|e| panic!("wire submit {i}: {e}")))
        .collect();
    let inproc: Vec<_> = (0..6)
        .map(|i| {
            oracle
                .submit_for(oa, oh, Request::spmv(x1()))
                .unwrap_or_else(|e| panic!("oracle submit {i}: {e}"))
        })
        .collect();
    srv.service().resume();
    oracle.resume();

    let mut sheds = 0;
    for (i, (&wt, &ot)) in wire.iter().zip(&inproc).enumerate() {
        let served = cl.wait(wt).expect("served response");
        let oracled = oracle.wait(ot).expect("oracle response");
        assert_eq!(
            served.is_overloaded(),
            oracled.is_overloaded(),
            "req={i}: shed decisions must match across the wire"
        );
        assert_responses_identical(&served, &oracled, &format!("overload req={i}"));
        sheds += usize::from(served.is_overloaded());
    }
    assert!(sheds >= 1, "cap 1 with 6 paused submissions must shed");
    assert!(sheds < 6, "the admitted request must still complete");
}

/// A stalled shard surfaces as the same typed `ShardTimeout` — naming
/// the same shard — whether the caller sits on the facade or on the
/// far side of a TCP connection.
#[test]
fn served_shard_timeout_is_typed_end_to_end() {
    let m = matrix();
    let spec = KernelSpec::by_name(KERNEL, STRIPES).expect("test kernel exists");
    let stall = Duration::from_millis(100);
    let plan = || FaultPlan::new(7).on_gather(1, Fault::StallShard { shard: 0 });
    let srv = Server::spawn(
        build(builder(2, Engine::Serial).wait_timeout(stall).fault_injector(Arc::new(plan()))),
        "127.0.0.1:0",
        ServerOpts::default(),
    )
    .expect("server binds");
    let oracle = build(builder(2, Engine::Serial).wait_timeout(stall).fault_injector(Arc::new(plan())));

    let mut cl = Client::connect(srv.local_addr()).expect("client connects");
    let wh = cl.load("alice", &m, KERNEL, STRIPES as u32).expect("wire load");
    let oa = oracle.tenant("alice").expect("oracle tenant");
    let oh = oracle.load_for(oa, &m, &spec).expect("oracle load");

    let wt = cl.submit_spmv("alice", wh, x1(), None).expect("wire submit");
    let ot = oracle.submit_for(oa, oh, Request::spmv(x1())).expect("oracle submit");

    // The wire side only ever sees the gather's published verdict (the
    // dispatch thread claims completions, it never times out a wait),
    // so one blocking wait suffices.
    let served_err = cl.wait(wt).expect_err("stalled request must fail over the wire");
    // The in-process wait can time out facade-level (shard unknown)
    // before the gather's verdict is published; claim until it lands.
    let oracle_err = loop {
        match oracle.wait_timeout(ot, Duration::from_secs(10)) {
            Err(e) if e.timed_out_shard().is_some() => break e,
            Err(e) if e.is_shard_timeout() => continue,
            Ok(r) => panic!("stalled request must not succeed, got {}", r.kind()),
            Err(e) => panic!("unexpected oracle error: {e}"),
        }
    };
    assert!(served_err.is_shard_timeout(), "wire error must keep its type: {served_err}");
    assert_eq!(
        served_err.timed_out_shard(),
        oracle_err.timed_out_shard(),
        "both sides must name the same wedged shard"
    );
    assert_eq!(served_err.timed_out_shard(), Some(0), "the stalled shard is shard 0");

    // The stall poisoned one ticket, not the server: the connection
    // keeps serving and the next request answers correctly.
    let t2 = cl.submit_spmv("alice", wh, x1(), None).expect("submit after stall");
    let run = cl.wait(t2).expect("healthy request completes").into_spmv().expect("spmv");
    assert_eq!(run.y, m.spmv(&x1()), "post-stall result vs host oracle");
}
