//! Bench E1 + E4: single-DPU tasklet scaling (paper Fig. 5) and block
//! formats (Fig. 8). Regenerates the figures' rows on the simulated DPU.

mod common;
use sparsep::bench_harness::figures;

fn main() {
    common::banner("single_dpu", "Fig. 5 tasklet scaling + Fig. 8 block formats");
    let s = common::scale();
    common::timed("e1_tasklet_scaling", || {
        figures::e1_tasklet_scaling(s);
    });
    common::timed("e4_block_formats", || {
        figures::e4_block_formats(s);
    });
}
