"""Pallas ELL SpMV kernel (layer 1).

The TPU re-think of the paper's per-DPU SpMV loop (DESIGN.md
§Hardware-Adaptation): where a DPU streams matrix tiles MRAM->WRAM with
explicit DMA and gathers x[col] element by element, the Pallas kernel
expresses the same schedule with a `BlockSpec` that stages a
`(TILE_R, K)` tile of values + column indices into VMEM per grid step
and performs the gather as a vectorized take from the (VMEM-resident)
input vector.

VMEM budget per grid step (fp32, the DESIGN.md §Perf accounting):
`TILE_R*K*4` (vals) + `TILE_R*K*4` (cols) + `N*4` (x) + `TILE_R*4` (y).
With TILE_R=128, K=32, N=16384 that is 128*32*8 + 64KiB + 0.5KiB
~= 97 KiB — far below the ~16 MiB VMEM of a TPU core, leaving room to
double-buffer the next tile while this one computes.

`interpret=True` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the Rust
runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_kernel(vals_ref, cols_ref, x_ref, y_ref):
    """One grid step: SpMV for a (TILE_R, K) tile of rows."""
    vals = vals_ref[...]  # (TILE_R, K)
    cols = cols_ref[...]  # (TILE_R, K) int32
    x = x_ref[...]  # (N,) staged in VMEM, shared by all steps
    # Vectorized gather + row reduction. Padding slots carry value 0 and
    # column 0, so they contribute nothing.
    y_ref[...] = jnp.sum(vals * x[cols], axis=1)


@functools.partial(jax.jit, static_argnames=("tile_r",))
def ell_spmv(vals, cols, x, *, tile_r=128):
    """ELL SpMV via Pallas: y = A @ x with A in padded ELL layout.

    Args:
      vals: (R, K) padded row values; R must be a multiple of tile_r.
      cols: (R, K) int32 column indices (padding -> column 0, value 0).
      x:    (N,) input vector.
      tile_r: rows per grid step.

    Returns:
      (R,) output vector.
    """
    r, k = vals.shape
    tile_r = min(tile_r, r)
    if r % tile_r != 0:
        raise ValueError(f"rows {r} not a multiple of tile_r {tile_r}")
    n = x.shape[0]
    grid = (r // tile_r,)
    return pl.pallas_call(
        _ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_r, k), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), vals.dtype),
        interpret=True,
    )(vals, cols, x)
