//! Small blocking client for the SparseP wire protocol.
//!
//! The client speaks the exact frame catalogue in
//! [`crate::net::protocol`] and hands back the coordinator's own types
//! — [`Response<f64>`] out of completions, typed
//! [`crate::util::Error`]s out of `Error` frames (a wire
//! `ShardTimeout` becomes [`Error::shard_timeout`] again) — so callers
//! and the differential suite (`tests/net_equivalence.rs`) compare
//! served results against the in-process facade directly.
//!
//! One call is outstanding at a time (the client is synchronous), but
//! many tickets can be in flight: completions stream back in whatever
//! order the scheduler finishes them, and frames for tickets other
//! than the one being waited on are parked and handed out when their
//! ticket is claimed — mirroring the facade's own
//! submit-everything/wait-any-order contract.
//!
//! Two sheds, one surface: a connection-cap shed (the server's
//! `Overloaded { ticket: 0 }` answered before submission) is
//! synthesized into a local ticket whose response is
//! [`Response::Overloaded`], so callers handle both shed layers with
//! the same match arm they use for the facade's admission shed.

use crate::coordinator::Response;
use crate::matrix::CooMatrix;
use crate::net::protocol::{decode_stream, Completion, Frame, WireErrorCode};
use crate::util::{Context, Error, Result};
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Synthetic tickets (connection-cap sheds, answered locally) live in
/// the top half of the ticket space; the facade's real tickets start
/// at 1 and count up, so the ranges can never collide.
const LOCAL_TICKET_BIT: u64 = 1 << 63;

/// A blocking connection to a `sparsep serve --listen` server.
pub struct Client {
    stream: TcpStream,
    /// Bytes read but not yet framed.
    rbuf: Vec<u8>,
    /// Responses that streamed in while another ticket was being
    /// waited on, keyed by ticket.
    parked: HashMap<u64, Result<Response<f64>>>,
    next_local: u64,
}

impl Client {
    /// Connect to a serving front end.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect to sparsep server")?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream, rbuf: Vec::new(), parked: HashMap::new(), next_local: 0 })
    }

    /// Register `m` under `tenant` with the named kernel (see
    /// `sparsep kernels`). Returns the server's wire handle.
    pub fn load(
        &mut self,
        tenant: &str,
        m: &CooMatrix<f64>,
        kernel: &str,
        stripes: u32,
    ) -> Result<u64> {
        let frame = Frame::LoadMatrix {
            tenant: tenant.to_string(),
            kernel: kernel.to_string(),
            stripes,
            nrows: m.nrows() as u64,
            ncols: m.ncols() as u64,
            triples: m.iter().collect(),
        };
        self.send(&frame)?;
        loop {
            match self.read_frame()? {
                Frame::Loaded { handle, .. } => return Ok(handle),
                Frame::Error { ticket: 0, code, shard, message } => {
                    return Err(wire_error(code, shard, message));
                }
                other => self.park(other)?,
            }
        }
    }

    /// Submit one SpMV; returns a claimable ticket (possibly already
    /// answered locally when the server shed at the connection cap).
    pub fn submit_spmv(
        &mut self,
        tenant: &str,
        handle: u64,
        x: Vec<f64>,
        deadline: Option<Duration>,
    ) -> Result<u64> {
        let frame = Frame::SubmitSpmv {
            tenant: tenant.to_string(),
            handle,
            deadline_ms: deadline_ms(deadline),
            x,
        };
        self.submit(&frame)
    }

    /// Submit one batched (multi-vector) request.
    pub fn submit_batch(
        &mut self,
        tenant: &str,
        handle: u64,
        xs: Vec<Vec<f64>>,
        deadline: Option<Duration>,
    ) -> Result<u64> {
        let frame = Frame::SubmitBatch {
            tenant: tenant.to_string(),
            handle,
            deadline_ms: deadline_ms(deadline),
            xs,
        };
        self.submit(&frame)
    }

    /// Submit one iterated request (`iters` self-applications).
    pub fn submit_iterate(
        &mut self,
        tenant: &str,
        handle: u64,
        x: Vec<f64>,
        iters: usize,
        deadline: Option<Duration>,
    ) -> Result<u64> {
        let frame = Frame::SubmitIterate {
            tenant: tenant.to_string(),
            handle,
            deadline_ms: deadline_ms(deadline),
            iters: iters as u32,
            x,
        };
        self.submit(&frame)
    }

    /// Block until `ticket`'s response arrives (or is already parked).
    pub fn wait(&mut self, ticket: u64) -> Result<Response<f64>> {
        if let Some(resp) = self.parked.remove(&ticket) {
            return resp;
        }
        if ticket & LOCAL_TICKET_BIT != 0 {
            // Synthetic tickets are answered at submit; an unknown one
            // was either claimed already or never issued.
            return Err(Error::msg(format!("unknown local ticket {ticket}")));
        }
        loop {
            match self.read_frame()? {
                Frame::Completion { ticket: t, body } => {
                    let resp = Ok(completion_response(*body));
                    if t == ticket {
                        return resp;
                    }
                    self.parked.insert(t, resp);
                }
                Frame::Overloaded { ticket: t } if t != 0 => {
                    if t == ticket {
                        return Ok(Response::Overloaded);
                    }
                    self.parked.insert(t, Ok(Response::Overloaded));
                }
                Frame::Error { ticket: 0, code, shard, message } => {
                    return Err(wire_error(code, shard, message));
                }
                Frame::Error { ticket: t, code, shard, message } => {
                    let err = Err(wire_error(code, shard, message));
                    if t == ticket {
                        return err;
                    }
                    self.parked.insert(t, err);
                }
                other => {
                    return Err(Error::msg(format!("unexpected frame while waiting: {other:?}")));
                }
            }
        }
    }

    /// Non-blocking-ish check: `Some(response)` when `ticket` has
    /// finished, `None` while it is still in flight. Exchanges one
    /// `Poll` round trip with the server unless the response is
    /// already parked.
    pub fn poll(&mut self, ticket: u64) -> Result<Option<Response<f64>>> {
        if let Some(resp) = self.parked.remove(&ticket) {
            return resp.map(Some);
        }
        if ticket & LOCAL_TICKET_BIT != 0 {
            return Err(Error::msg(format!("unknown local ticket {ticket}")));
        }
        self.send(&Frame::Poll { ticket })?;
        loop {
            match self.read_frame()? {
                Frame::NotReady { ticket: t } if t == ticket => return Ok(None),
                Frame::Completion { ticket: t, body } => {
                    let resp = completion_response(*body);
                    if t == ticket {
                        // The completion raced the poll; the NotReady
                        // cannot come anymore (the server answers from
                        // its map, which no longer holds the ticket) —
                        // but an unknown-ticket error for our poll can.
                        self.absorb_stale_poll_error(ticket)?;
                        return Ok(Some(resp));
                    }
                    self.parked.insert(t, Ok(resp));
                }
                Frame::Overloaded { ticket: t } if t != 0 => {
                    if t == ticket {
                        self.absorb_stale_poll_error(ticket)?;
                        return Ok(Some(Response::Overloaded));
                    }
                    self.parked.insert(t, Ok(Response::Overloaded));
                }
                Frame::Error { ticket: t, code, shard, message } if t == ticket => {
                    return Err(wire_error(code, shard, message));
                }
                Frame::Error { ticket: 0, code, shard, message } => {
                    return Err(wire_error(code, shard, message));
                }
                Frame::Error { ticket: t, code, shard, message } => {
                    self.parked.insert(t, Err(wire_error(code, shard, message)));
                }
                other => {
                    return Err(Error::msg(format!("unexpected frame while polling: {other:?}")));
                }
            }
        }
    }

    /// Hand the underlying socket (and any unframed bytes must have
    /// been consumed) to callers that drive the wire directly — the
    /// load generator uses this after its synchronous load phase.
    pub(crate) fn into_stream(self) -> Result<TcpStream> {
        crate::ensure!(
            self.rbuf.is_empty() && self.parked.is_empty(),
            "cannot unwrap a client with buffered frames"
        );
        Ok(self.stream)
    }

    /// Send a `Submit*` frame and consume its ack (acks arrive in
    /// request order): `Submitted` yields the server ticket,
    /// `Overloaded {0}` synthesizes a locally-answered shed ticket,
    /// `Error {0}` propagates typed.
    fn submit(&mut self, frame: &Frame) -> Result<u64> {
        self.send(frame)?;
        loop {
            match self.read_frame()? {
                Frame::Submitted { ticket } => return Ok(ticket),
                Frame::Overloaded { ticket: 0 } => {
                    self.next_local += 1;
                    let t = LOCAL_TICKET_BIT | self.next_local;
                    self.parked.insert(t, Ok(Response::Overloaded));
                    return Ok(t);
                }
                Frame::Error { ticket: 0, code, shard, message } => {
                    return Err(wire_error(code, shard, message));
                }
                other => self.park(other)?,
            }
        }
    }

    /// Park a streamed frame that belongs to an earlier ticket.
    fn park(&mut self, frame: Frame) -> Result<()> {
        match frame {
            Frame::Completion { ticket, body } => {
                self.parked.insert(ticket, Ok(completion_response(*body)));
                Ok(())
            }
            Frame::Overloaded { ticket } if ticket != 0 => {
                self.parked.insert(ticket, Ok(Response::Overloaded));
                Ok(())
            }
            Frame::Error { ticket, code, shard, message } if ticket != 0 => {
                self.parked.insert(ticket, Err(wire_error(code, shard, message)));
                Ok(())
            }
            other => Err(Error::msg(format!("unexpected frame from server: {other:?}"))),
        }
    }

    /// After a completion raced an outstanding `Poll`, the server may
    /// still answer the poll with an unknown-ticket error — absorb
    /// exactly that reply so it cannot confuse a later wait.
    fn absorb_stale_poll_error(&mut self, ticket: u64) -> Result<()> {
        match self.read_frame()? {
            Frame::Error { ticket: t, .. } if t == ticket => Ok(()),
            other => self.park(other),
        }
    }

    fn send(&mut self, frame: &Frame) -> Result<()> {
        self.stream.write_all(&frame.encode()).context("write frame to server")
    }

    /// Read one complete frame, blocking. EOF mid-stream is a typed
    /// transport error, never a panic or a hang.
    fn read_frame(&mut self) -> Result<Frame> {
        loop {
            if let Some((frame, n)) = decode_stream(&self.rbuf)? {
                self.rbuf.drain(..n);
                return Ok(frame);
            }
            let mut chunk = [0u8; 16 * 1024];
            match self.stream.read(&mut chunk) {
                Ok(0) => return Err(Error::msg("server closed the connection mid-stream")),
                Ok(n) => self.rbuf.extend_from_slice(&chunk[..n]),
                Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(Error::msg(format!("read from server: {e}"))),
            }
        }
    }
}

fn deadline_ms(d: Option<Duration>) -> u32 {
    match d {
        None => 0,
        // 0 means "no deadline" on the wire; clamp a sub-millisecond
        // deadline up rather than silently dropping it.
        Some(d) => (d.as_millis() as u32).max(1),
    }
}

fn completion_response(body: Completion) -> Response<f64> {
    match body {
        Completion::Spmv(r) => Response::Spmv(r),
        Completion::Batch(b) => Response::Batch(b),
        Completion::Iterate(it) => Response::Iterate(it),
    }
}

fn wire_error(code: WireErrorCode, shard: Option<u32>, message: String) -> Error {
    match code {
        WireErrorCode::ShardTimeout => {
            Error::shard_timeout(shard.map(|s| s as usize), message)
        }
        WireErrorCode::Other => Error::msg(message),
    }
}
